//! Scaling study: where does the Parallella's sgemm pay off?
//!
//! Sweeps problem size and reports projected GFLOPS of the Epiphany path
//! vs the host reference — the practical question the paper's
//! introduction asks ("real and practical possibilities ... for
//! Scientific Computing"). Also shows the K-dependence of the ir/or
//! ratios (§3.3's compromise).
//!
//!     cargo run --release --example scaling_study

use parallella_blas::epiphany::timing::CalibratedModel;
use parallella_blas::host::projection::{project_host_ref, project_ukr_call, ProjectionParams};
use parallella_blas::util::tables::Table;

fn main() {
    let model = CalibratedModel::default();

    let mut t = Table::new(
        "Projected sgemm µ-kernel vs host reference (m=192, n=256)",
        &["K", "host ref (s)", "epiphany (s)", "speedup", "GFLOPS", "ir %", "or %"],
    );
    for k in [64usize, 256, 1024, 4096, 16384] {
        let proj = project_ukr_call(&model, &ProjectionParams::kernel_same_process(k));
        let href = project_host_ref(&model, 192, 256, k);
        let flops = 2.0 * 192.0 * 256.0 * k as f64;
        t.row(&[
            k.to_string(),
            format!("{href:.4}"),
            format!("{:.4}", proj.total_s),
            format!("{:.1}x", href / proj.total_s),
            format!("{:.3}", flops / proj.total_s / 1e9),
            format!("{:.1}", 100.0 * proj.input_s / proj.total_s),
            format!("{:.1}", 100.0 * proj.post_s / proj.total_s),
        ]);
    }
    t.print();

    let mut t2 = Table::new(
        "Full BLIS sgemm nn projected GFLOPS by square size",
        &["m=n=K", "µ-calls", "projected s", "GFLOPS", "% of kernel-only"],
    );
    use parallella_blas::epiphany::timing::WalkClass;
    use parallella_blas::experiments::analytic_blis_gemm_s;
    let kernel_gf = {
        let p = project_ukr_call(&model, &ProjectionParams::kernel_same_process(4096));
        2.0 * 192.0 * 256.0 * 4096.0 / p.total_s / 1e9
    };
    for s in [512usize, 1024, 2048, 4096, 8192] {
        let secs =
            analytic_blis_gemm_s(&model, s, s, s, WalkClass::Contig, WalkClass::StridedB, false);
        let gf = 2.0 * (s as f64).powi(3) / secs / 1e9;
        let calls = s.div_ceil(192) * s.div_ceil(256);
        t2.row(&[
            s.to_string(),
            calls.to_string(),
            format!("{secs:.2}"),
            format!("{gf:.3}"),
            format!("{:.0}%", 100.0 * gf / kernel_gf),
        ]);
    }
    t2.print();
    println!(
        "observations: the accumulator makes or→0 with K; the kernel-level speedup vs the\n\
         Cortex-A9 host is ~33x; BLIS-level efficiency approaches the kernel-only rate as the\n\
         problem grows (IPC and edge-padding amortize)."
    );
}
