//! End-to-end driver: proves every layer composes on a real workload.
//!
//!     cargo run --release --example end_to_end
//!
//! Pipeline exercised, in order:
//!   1. AOT artifacts (jax L2 + pallas L1, lowered once by `make
//!      artifacts`) discovered — listed when present, skipped otherwise;
//!   2. the Epiphany functional simulator cross-checked against the PJRT
//!      artifact bit-class (pjrt-featured builds only; the two are the
//!      same math in independent implementations);
//!   3. the service process + BLIS layer serving a mixed BLAS workload;
//!   4. the L3 TCP coordinator under concurrent clients with batching —
//!      latency/throughput reported;
//!   5. an HPL solve (the paper's headline application) with its residual.
//!
//! Exit code 0 = the whole stack agrees everywhere.

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::{Request, ServerConfig};
use parallella_blas::hpl::driver::{run_hpl, HplConfig};
use parallella_blas::linalg::{max_scaled_err, Mat};
use parallella_blas::prelude::*;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    println!("=== 1. AOT artifacts → PJRT ===");
    match parallella_blas::runtime::ArtifactRegistry::discover() {
        Ok(reg) => {
            for e in reg.entries() {
                println!("  artifact {:<22} K={:<5} {} ({})", e.name, e.k, e.dtype, e.digest);
            }
        }
        Err(e) => println!("  no artifacts ({e:#}); continuing with the simulator backend"),
    }

    let sim = Platform::builder().backend(BackendKind::Simulator).build()?;

    println!("\n=== 2. simulator vs PJRT artifact cross-check ===");
    match Platform::builder().backend(BackendKind::Pjrt).build() {
        Ok(pjrt) => {
            let (m, n, k) = (192usize, 256usize, 512usize);
            let a = Mat::<f32>::randn(m, k, 1);
            let b = Mat::<f32>::randn(k, n, 2);
            let mut c_sim = Mat::<f32>::zeros(m, n);
            let mut c_pjrt = Mat::<f32>::zeros(m, n);
            sim.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c_sim)?;
            pjrt.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c_pjrt)?;
            let err = max_scaled_err(c_sim.view(), c_pjrt.view());
            println!("  functional-sim vs AOT-artifact max scaled err: {err:.2e}");
            anyhow::ensure!(err < 1e-5, "backends disagree");
        }
        Err(e) => {
            println!("  skipped — pjrt backend unavailable ({e:#})");
        }
    }

    println!("\n=== 3. mixed BLAS workload through the service ===");
    let blas = sim.blas();
    let t0 = Instant::now();
    let mut total_flops = 0.0f64;
    for i in 0..6 {
        let (mm, nn, kk) =
            ([150, 192, 400][i % 3], [100, 256, 300][i % 3], [64, 512, 200][i % 3]);
        let a = Mat::<f32>::randn(mm, kk, 10 + i as u64);
        let b = Mat::<f32>::randn(kk, nn, 20 + i as u64);
        let mut c = Mat::<f32>::zeros(mm, nn);
        let rep = blas.sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c)?;
        total_flops += rep.flops;
    }
    println!(
        "  6 gemms, {:.2} MFLOP total, wall {:.3}s",
        total_flops / 1e6,
        t0.elapsed().as_secs_f64()
    );

    println!("\n=== 4. L3 coordinator under concurrent load ===");
    let srv = BlasServer::start(ServerConfig::default())?;
    let addr = srv.addr();
    let weights = Mat::<f32>::randn(192, 256, 99).as_slice().to_vec();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for client in 0..4u64 {
        let w = weights.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let mut cli = BlasClient::connect(addr)?;
            for i in 0..6 {
                let bm = Mat::<f32>::randn(256, 64, client * 31 + i);
                let resp = cli.call(&Request::sgemm(
                    Trans::N,
                    Trans::N,
                    192,
                    64,
                    256,
                    1.0,
                    0.0,
                    w.clone(),
                    bm.as_slice().to_vec(),
                    vec![0.0; 192 * 64],
                ))?;
                anyhow::ensure!(resp.into_f32()?.len() == 192 * 64);
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("client")?;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let reqs = srv.metrics.requests();
    println!(
        "  24 requests / 4 clients in {elapsed:.3}s → {:.1} req/s, p50 {:.4}s p99 {:.4}s",
        24.0 / elapsed,
        srv.metrics.latency_quantile(0.5),
        srv.metrics.latency_quantile(0.99),
    );
    // Coalesced groups execute as one gemm, so executed-request count can
    // be below 24; it must be positive and the queue must be drained.
    anyhow::ensure!(reqs >= 4, "metrics lost requests (got {reqs})");

    println!("\n=== 5. HPL solve (paper §4.3 shape) ===");
    let res = run_hpl(blas, HplConfig::small(384, 96))?;
    println!(
        "  N=384: wall {:.2}s, projected {:.2}s ({:.3} GF), residue {:.2e} (f32-class)",
        res.wall_s, res.projected_s, res.projected_gflops, res.residual.raw
    );
    anyhow::ensure!(res.residual.raw > 1e-13 && res.residual.raw < 1e-4);

    println!("\nEND-TO-END OK — all layers compose.");
    Ok(())
}
