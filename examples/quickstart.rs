//! Quickstart: boot the stack and run one accelerated sgemm.
//!
//!     cargo run --release --example quickstart
//!
//! Shows the three numbers this library always reports side by side:
//! wall-clock on this machine, projected-Parallella seconds from the
//! calibrated model, and the paper's corresponding figure.

use parallella_blas::prelude::*;

fn main() -> anyhow::Result<()> {
    // Default backend = the functional Epiphany simulator (always
    // available). A `--features pjrt` build with `make artifacts` can
    // swap in `BackendKind::Pjrt` for the AOT jax+pallas artifact path.
    let plat = Platform::builder().build()?;
    let blas = plat.blas();

    // The paper's kernel-size problem: (192 × 4096) · (4096 × 256).
    let (m, n, k) = (192usize, 256usize, 4096usize);
    let a = Mat::<f32>::randn(m, k, 1);
    let b = Mat::<f32>::randn(k, n, 2);
    let mut c = Mat::<f32>::zeros(m, n);

    let report = blas.sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c)?;

    println!("sgemm {m}x{n}x{k} through the Epiphany service:");
    println!("  µ-kernel calls        : {}", report.calls);
    println!(
        "  wall-clock (this host): {:.4} s  ({:.2} GFLOPS)",
        report.wall_s,
        report.wall_gflops()
    );
    println!(
        "  projected (Parallella): {:.4} s  ({:.3} GFLOPS)",
        report.projected_s,
        report.projected_gflops()
    );
    println!("  paper (Table 2/3)     : ~0.158 s  (~2.5-2.6 GFLOPS)");

    // Sanity: verify against a host-side f64 oracle.
    let mut want = Mat::<f64>::zeros(m, n);
    parallella_blas::blis::level3::gemm_host(
        Trans::N,
        Trans::N,
        1.0,
        a.cast::<f64>().view(),
        b.cast::<f64>().view(),
        0.0,
        &mut want,
    );
    let err = parallella_blas::linalg::max_scaled_err(c.view(), want.view());
    println!("  max scaled error vs f64 oracle: {err:.2e} (paper: ~5.8e-7)");
    assert!(err < 1e-5);
    println!("OK");
    Ok(())
}
