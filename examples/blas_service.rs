//! The L3 coordinator as a network service: start the TCP BLAS server,
//! drive it with concurrent wire-v2 pipelined clients, print the typed
//! metrics report.
//!
//!     cargo run --release --example blas_service

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::{Request, Response, ServerConfig};
use parallella_blas::linalg::Mat;

fn main() -> anyhow::Result<()> {
    let srv = BlasServer::start(ServerConfig::default())?;
    println!("BLAS service listening on {}", srv.addr());

    // Serving-style workload: one shared weight matrix (A), many clients
    // sending activation batches (B) — the case the batcher coalesces.
    let (m, k) = (192usize, 256usize);
    let weights = Mat::<f32>::randn(m, k, 42).as_slice().to_vec();

    let addr = srv.addr();
    let mut handles = Vec::new();
    for client_id in 0..4u64 {
        let weights = weights.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<f64> {
            // Wire v2: keep 4 requests in flight per connection instead
            // of paying a full round trip each.
            let mut cli = BlasClient::connect_v2(addr)?;
            let n = 64;
            let t0 = std::time::Instant::now();
            let mut window = std::collections::VecDeque::new();
            for i in 0..8 {
                while window.len() >= 4 {
                    let p: parallella_blas::coordinator::Pending = window.pop_front().unwrap();
                    anyhow::ensure!(p.wait()?.into_f32()?.len() == m * n);
                }
                let b = Mat::<f32>::randn(k, n, 1000 + client_id * 100 + i);
                window.push_back(cli.submit(&Request::sgemm(
                    Trans::N,
                    Trans::N,
                    m,
                    n,
                    k,
                    1.0,
                    0.0,
                    weights.clone(),
                    b.as_slice().to_vec(),
                    vec![0.0; m * n],
                ))?);
            }
            while let Some(p) = window.pop_front() {
                anyhow::ensure!(p.wait()?.into_f32()?.len() == m * n);
            }
            Ok(t0.elapsed().as_secs_f64())
        }));
    }
    for (i, h) in handles.into_iter().enumerate() {
        let secs = h.join().expect("client thread")?;
        println!("client {i}: 8 requests in {secs:.3}s");
    }

    // Pull the typed metrics report through the wire protocol (a v1
    // no-hello client: old clients keep working against the v2 server).
    let mut cli = BlasClient::connect(addr)?;
    if let Response::Stats(stats) = cli.call(&Request::Stats)? {
        println!("server stats: {stats}");
        println!("batched executions: {}", stats.batched);
    }
    println!(
        "p50 latency: {:.4}s  p99: {:.4}s",
        srv.metrics.latency_quantile(0.5),
        srv.metrics.latency_quantile(0.99)
    );
    println!("OK");
    Ok(())
}
