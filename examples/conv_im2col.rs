//! im2col convolution demo: lower a small conv layer to a batch of
//! small gemms and run it through the Epiphany-accelerated path.
//!
//!     cargo run --release --example conv_im2col
//!
//! One image becomes one `patches @ filters` gemm; the whole NHWC batch
//! becomes a `GemmBatchOp`, with every item sharing the filter matrix as
//! its B operand — exactly the many-small-resident-gemms traffic shape
//! the workloads subsystem exists for. The result is checked against a
//! direct f64-accumulated convolution. The Python twin of this lowering
//! lives in `python/compile/conv.py`.

use parallella_blas::linalg::{max_scaled_err, XorShiftRng};
use parallella_blas::prelude::*;
use parallella_blas::workloads::{conv2d_naive, conv2d_via_batch, ConvShape};

fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = XorShiftRng::new(seed);
    (0..len).map(|_| rng.next_unit() as f32).collect()
}

fn main() -> anyhow::Result<()> {
    let plat = Platform::builder().chips(2).build()?;

    // A small conv layer: 6 images of 16×16×8, 3×3 kernels, 16 filters.
    let shape = ConvShape { batch: 6, h: 16, w: 16, c_in: 8, kh: 3, kw: 3, c_out: 16 };
    let input = rand_vec(shape.input_len(), 101);
    let filters = rand_vec(shape.filter_len(), 103);

    let (out, rep) = conv2d_via_batch(plat.blas(), &input, &filters, &shape)?;

    println!("conv {shape:?}");
    println!(
        "  lowered to {} gemms of {}x{} @ {}x{}",
        rep.items,
        shape.out_h() * shape.out_w(),
        shape.kh * shape.kw * shape.c_in,
        shape.kh * shape.kw * shape.c_in,
        shape.c_out
    );
    println!("  batch flops           : {:.3e}", rep.flops);
    println!("  µ-kernel calls        : {}", rep.calls);
    println!("  projected (Parallella): {:.4} s", rep.projected_s);

    // Oracle: direct f64-accumulated convolution, per image.
    let want = conv2d_naive(&input, &filters, &shape);
    let mut worst = 0.0f64;
    for (g, w) in out.iter().zip(&want) {
        worst = worst.max(max_scaled_err(g.view(), w.view()));
    }
    println!("  max scaled error vs f64 conv: {worst:.2e}");
    anyhow::ensure!(worst < 1e-4, "lowered conv diverged from the naive reference");
    println!("OK");
    Ok(())
}
