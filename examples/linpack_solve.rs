//! Solve a dense linear system with the HPL driver on the generated BLAS
//! (paper §4.3) — usage:
//!
//!     cargo run --release --example linpack_solve [N] [NB]
//!
//! Defaults to a laptop-friendly N=768, NB=96. `N=4608 NB=768` reproduces
//! the paper's Table 7 configuration (minutes of runtime).

use parallella_blas::hpl::driver::{run_hpl, HplConfig};
use parallella_blas::prelude::*;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(768);
    let nb: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(96);

    let plat = Platform::builder().build()?;
    println!("HPL: N={n} NB={nb} P=1 Q=1 (false-dgemm Epiphany path)");
    let res = run_hpl(plat.blas(), HplConfig::small(n, nb))?;

    println!("  wall-clock            : {:.2} s", res.wall_s);
    println!(
        "  projected (Parallella): {:.2} s  ({:.3} GFLOPS)",
        res.projected_s, res.projected_gflops
    );
    println!("  residue (raw)         : {:.2e}  (paper @N=4608: 2.34e-6)", res.residual.raw);
    println!("  residue (HPL-scaled)  : {:.4e}  (paper: 2.1098e10)", res.residual.hpl_scaled);
    println!(
        "  projected time split  : gemm {:.1}% | host panel/trsm {:.1}%",
        100.0 * res.lu.gemm_projected_s / res.projected_s,
        100.0 * res.lu.host_projected_s / res.projected_s,
    );
    println!(
        "  (the host share is the paper's §4.3 finding: unaccelerated level-2\n\
         \u{20}  BLAS caps HPL well below the sgemm kernel's 3.5 GFLOPS)"
    );
    anyhow::ensure!(res.residual.raw < 1e-4, "residual too large");
    println!("OK");
    Ok(())
}
