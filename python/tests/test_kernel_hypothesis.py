"""Property sweeps of the L1 Pallas kernel (hypothesis-driven).

Split from test_kernel.py so environments without `hypothesis` (or jax)
skip only these sweeps, not the deterministic L1 suite.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="jax unavailable — L1 Pallas sweeps skipped")
hypothesis = pytest.importorskip("hypothesis", reason="hypothesis unavailable — sweeps skipped")

from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import epiphany_gemm, ref  # noqa: E402
from compile.kernels.epiphany_gemm import KSUB, M_UKR, N_UKR  # noqa: E402

jax.config.update("jax_enable_x64", True)


def rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


@settings(max_examples=20, deadline=None)
@given(
    n_panels=st.integers(min_value=1, max_value=4),
    alpha=st.floats(min_value=-2, max_value=2, allow_nan=False, width=32),
    beta=st.floats(min_value=-2, max_value=2, allow_nan=False, width=32),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_sweep_paper_tile(n_panels, alpha, beta, seed):
    """Hypothesis sweep over reduction depth and scalars at the paper tile."""
    k = n_panels * KSUB
    a = rand((M_UKR, k), seed)
    b = rand((k, N_UKR), seed + 1)
    c = rand((M_UKR, N_UKR), seed + 2)
    got = epiphany_gemm.sgemm_inner(alpha, a, b, beta, c)
    want = ref.sgemm_inner_ref(alpha, a, b, beta, c)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(
    m_blocks=st.integers(min_value=1, max_value=6),
    n_mult=st.integers(min_value=1, max_value=4),
    ksub_pow=st.integers(min_value=4, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_sweep_shapes(m_blocks, n_mult, ksub_pow, seed):
    """Shape generality: the kernel is not hard-wired to 192x256x64."""
    m, n, ksub = 32 * m_blocks, 64 * n_mult, 2**ksub_pow
    a = rand((m, 2 * ksub), seed)
    b = rand((2 * ksub, n), seed + 1)
    c = rand((m, n), seed + 2)
    got = epiphany_gemm.sgemm_inner(1.0, a, b, 1.0, c, ksub=ksub)
    want = ref.sgemm_inner_ref(1.0, a, b, 1.0, c)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)
