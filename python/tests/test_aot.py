"""AOT pipeline tests: catalogue consistency, artifact_ksub policy, and
HLO-text emission invariants the rust loader depends on."""

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="jax unavailable — AOT pipeline tests skipped")

from compile import aot, model  # noqa: E402

jax.config.update("jax_enable_x64", True)


def test_artifact_ksub_policy():
    # VMEM-scale tiling: cap at 512, never exceed k.
    assert model.artifact_ksub(64) == 64
    assert model.artifact_ksub(256) == 256
    assert model.artifact_ksub(512) == 512
    assert model.artifact_ksub(1024) == 512
    assert model.artifact_ksub(4096) == 512


def test_catalogue_ks_cover_chaining():
    # The rust plan_k chains greedily descending; the smallest K must
    # divide the others so padding stays bounded by one small block.
    ks = sorted(model.SGEMM_KS)
    smallest = ks[0]
    for k in ks:
        assert k % smallest == 0, f"{k} not a multiple of {smallest}"


def test_hlo_text_has_expected_interface():
    fn, spec = model.catalogue()["sgemm_inner_k64"]
    text = aot.to_hlo_text(aot.lower_entry(fn, spec))
    # Entry signature the rust GemmExecutor relies on: 5 params, tuple out.
    assert "HloModule" in text
    assert "f32[64,192]" in text   # a1 (K, m)
    assert "f32[64,256]" in text   # b1 (K, n)
    assert "f32[256,192]" in text  # c (n, m)
    # 1-tuple result (HLO prints tuple result types in the entry computation)
    assert "(f32[256,192]" in text or "tuple(" in text


def test_false_dgemm_hlo_has_f64_interface_f32_compute():
    fn, spec = model.catalogue()["false_dgemm_k512"]
    text = aot.to_hlo_text(aot.lower_entry(fn, spec))
    assert "f64[512,192]" in text  # f64 API
    assert "f32[" in text          # downcast interior (the "false" part)


def test_all_entries_lower():
    # Every catalogue entry must lower without error (smoke at trace level
    # only for the big ones — lowering is the expensive step that matters).
    cat = model.catalogue()
    small = [n for n in cat if n.endswith("k64") or n.endswith("k256")]
    for name in small:
        fn, spec = cat[name]
        text = aot.to_hlo_text(aot.lower_entry(fn, spec))
        assert len(text) > 1000, name
