"""L2 tests: the artifact-entry functions (layout wrappers, false dgemm)
and the AOT catalogue."""

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="jax unavailable — L2 model tests skipped")

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402
from compile.kernels.epiphany_gemm import KSUB, M_UKR, N_UKR  # noqa: E402

jax.config.update("jax_enable_x64", True)


def rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


def test_layout_wrapper_matches_logical_gemm():
    # a passed as (K, m) = col-major (m, K); c as (n, m) = col-major (m, n).
    k = 2 * KSUB
    a = rand((M_UKR, k), 0)
    b = rand((k, N_UKR), 1)
    c = rand((M_UKR, N_UKR), 2)
    got_t = model.sgemm_inner_microkernel(1.0, a.T.copy(), b, 1.0, c.T.copy())
    want = ref.sgemm_inner_ref(1.0, a, b, 1.0, c)
    np.testing.assert_allclose(np.asarray(got_t).T, want, rtol=3e-5, atol=3e-5)


def test_false_dgemm_entry_matches_ref():
    k = 512
    a = rand((M_UKR, k), 3, np.float64)
    b = rand((k, N_UKR), 4, np.float64)
    c = rand((M_UKR, N_UKR), 5, np.float64)
    got_t = model.false_dgemm_microkernel(1.0, a.T.copy(), b, 1.0, c.T.copy())
    want = ref.false_dgemm_ref(1.0, a, b, 1.0, c)
    # Both are f32 compute, but the kernel accumulates in KSUB panels while
    # the ref contracts in one dot — f32 ordering differences only.
    scale = np.abs(np.asarray(want)).max()
    np.testing.assert_allclose(np.asarray(got_t).T / scale, want / scale, atol=2e-6)
    assert np.asarray(got_t).dtype == np.float64


def test_catalogue_entries():
    cat = model.catalogue()
    for k in model.SGEMM_KS:
        assert f"sgemm_inner_k{k}" in cat
    assert "false_dgemm_k512" in cat and "false_dgemm_k4096" in cat
    # Spec sanity: a1 is (K, m), b1 is (K, n), c is (n, m).
    fn, spec = cat["sgemm_inner_k512"]
    assert spec[1].shape == (512, M_UKR)
    assert spec[2].shape == (512, N_UKR)
    assert spec[4].shape == (N_UKR, M_UKR)


def test_catalogue_specs_lower():
    # The smallest artifact must lower to HLO text (fast smoke of aot.py's
    # pipeline without writing files).
    from compile import aot

    fn, spec = model.catalogue()["sgemm_inner_k64"]
    text = aot.to_hlo_text(aot.lower_entry(fn, spec))
    assert "HloModule" in text
    assert "f32[64,192]" in text  # a1 spec shape
