"""im2col lowering tests: the numpy twin of rust/src/workloads/conv.rs.

Pure numpy — these run even where jax is absent, because the lowering
itself (and its layout contract with the rust side) has no jax in it.
"""

import numpy as np
import pytest

from compile import conv


def rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


def test_lowered_conv_matches_direct_reference():
    batch = rand((3, 8, 8, 4), 0)
    filters = rand((3, 3, 4, 5), 1)
    got = conv.conv2d_via_batch(batch, filters)
    want = conv.conv2d_reference(batch, filters)
    assert got.shape == (3, 36, 5)
    np.testing.assert_allclose(got.astype(np.float64), want, rtol=1e-4, atol=1e-4)


def test_one_by_one_kernel_is_pointwise_matmul():
    batch = rand((2, 4, 5, 3), 2)
    filters = rand((1, 1, 3, 7), 3)
    got = conv.conv2d_via_batch(batch, filters)
    want = batch.reshape(2, 20, 3) @ filters.reshape(3, 7)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_patch_layout_matches_rust_contract():
    # Entry (p, q) of the patch matrix must be
    # image[oy+ky, ox+kx, ci] with p = oy*out_w + ox and
    # q = (ky*kw + kx)*c_in + ci — the exact index math of
    # rust/src/workloads/conv.rs::im2col.
    image = rand((5, 6, 2), 4)
    kh, kw = 3, 2
    patches = conv.im2col(image, kh, kw)
    ho, wo = conv.out_hw(5, 6, kh, kw)
    c_in = 2
    assert patches.shape == (ho * wo, kh * kw * c_in)
    for p in range(patches.shape[0]):
        oy, ox = divmod(p, wo)
        for q in range(patches.shape[1]):
            ky, kx = divmod(q // c_in, kw)
            ci = q % c_in
            assert patches[p, q] == image[oy + ky, ox + kx, ci]


def test_oversized_kernel_rejected():
    with pytest.raises(ValueError):
        conv.out_hw(2, 2, 3, 3)


def test_microkernel_padding_preserves_the_product():
    batch = rand((1, 10, 10, 3), 5)
    filters = rand((3, 3, 3, 4), 6)
    patches = conv.im2col(batch[0], 3, 3)
    fmat = conv.filter_matrix(filters)
    patches_p, fmat_p, (rows, cols) = conv.pad_to_microkernel(patches, fmat)
    # Padded dims are µ-kernel multiples …
    assert patches_p.shape[0] % conv.M_UKR == 0
    assert fmat_p.shape[1] % conv.N_UKR == 0
    assert patches_p.shape[1] % conv.KSUB == 0
    assert patches_p.shape[1] == fmat_p.shape[0]
    # … and cropping the padded product recovers the small gemm (zero
    # padding contributes zero; only BLAS summation order may differ).
    got = (patches_p @ fmat_p)[:rows, :cols]
    np.testing.assert_allclose(got, patches @ fmat, rtol=2e-6, atol=2e-6)
