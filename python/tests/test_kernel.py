"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path — everything the
rust runtime executes was lowered from exactly these functions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import epiphany_gemm, ref
from compile.kernels.epiphany_gemm import KSUB, M_UKR, N_UKR

jax.config.update("jax_enable_x64", True)


def rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


def test_paper_geometry_matches_ref():
    a = rand((M_UKR, 4 * KSUB), 0)
    b = rand((4 * KSUB, N_UKR), 1)
    c = rand((M_UKR, N_UKR), 2)
    got = epiphany_gemm.sgemm_inner(1.5, a, b, -0.5, c)
    want = ref.sgemm_inner_ref(1.5, a, b, -0.5, c)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_error_band_vs_f64_matches_paper():
    # The paper reports mean rel err 8.73e-8, max 5.83e-7 at K=4096.
    # The same order of magnitude must appear here (f32 accumulation).
    a = rand((M_UKR, 1024), 3)
    b = rand((1024, N_UKR), 4)
    c = np.zeros((M_UKR, N_UKR), np.float32)
    got = np.asarray(epiphany_gemm.sgemm_inner(1.0, a, b, 0.0, c))
    want = np.asarray(ref.sgemm_inner_ref_f64(1.0, a, b, 0.0, c))
    rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-3 * np.abs(want).max())
    assert 1e-9 < rel.mean() < 1e-6, rel.mean()
    assert rel.max() < 1e-4, rel.max()


def test_single_panel():
    a = rand((M_UKR, KSUB), 5)
    b = rand((KSUB, N_UKR), 6)
    c = rand((M_UKR, N_UKR), 7)
    got = epiphany_gemm.sgemm_inner(2.0, a, b, 1.0, c)
    want = ref.sgemm_inner_ref(2.0, a, b, 1.0, c)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_beta_zero_ignores_c():
    a = rand((M_UKR, KSUB), 8)
    b = rand((KSUB, N_UKR), 9)
    c_nan_free = rand((M_UKR, N_UKR), 10) * 1e6  # huge, must vanish
    got = epiphany_gemm.sgemm_inner(1.0, a, b, 0.0, c_nan_free)
    want = ref.sgemm_inner_ref(1.0, a, b, 0.0, np.zeros_like(c_nan_free))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_alpha_zero_scales_c_only():
    a = rand((M_UKR, KSUB), 11)
    b = rand((KSUB, N_UKR), 12)
    c = rand((M_UKR, N_UKR), 13)
    got = epiphany_gemm.sgemm_inner(0.0, a, b, 3.0, c)
    np.testing.assert_allclose(got, 3.0 * c, rtol=1e-6, atol=1e-6)


def test_acc_variant_chains():
    # Chaining two K-blocks through sgemm_acc == one big contraction.
    a = rand((M_UKR, 2 * KSUB), 14)
    b = rand((2 * KSUB, N_UKR), 15)
    c0 = np.zeros((M_UKR, N_UKR), np.float32)
    step1 = epiphany_gemm.sgemm_acc(a[:, :KSUB], b[:KSUB], c0)
    step2 = epiphany_gemm.sgemm_acc(a[:, KSUB:], b[KSUB:], np.asarray(step1))
    want = ref.sgemm_inner_ref(1.0, a, b, 0.0, c0)
    np.testing.assert_allclose(step2, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=20, deadline=None)
@given(
    n_panels=st.integers(min_value=1, max_value=4),
    alpha=st.floats(min_value=-2, max_value=2, allow_nan=False, width=32),
    beta=st.floats(min_value=-2, max_value=2, allow_nan=False, width=32),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_sweep_paper_tile(n_panels, alpha, beta, seed):
    """Hypothesis sweep over reduction depth and scalars at the paper tile."""
    k = n_panels * KSUB
    a = rand((M_UKR, k), seed)
    b = rand((k, N_UKR), seed + 1)
    c = rand((M_UKR, N_UKR), seed + 2)
    got = epiphany_gemm.sgemm_inner(alpha, a, b, beta, c)
    want = ref.sgemm_inner_ref(alpha, a, b, beta, c)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(
    m_blocks=st.integers(min_value=1, max_value=6),
    n_mult=st.integers(min_value=1, max_value=4),
    ksub_pow=st.integers(min_value=4, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_sweep_shapes(m_blocks, n_mult, ksub_pow, seed):
    """Shape generality: the kernel is not hard-wired to 192x256x64."""
    m, n, ksub = 32 * m_blocks, 64 * n_mult, 2 ** ksub_pow
    a = rand((m, 2 * ksub), seed)
    b = rand((2 * ksub, n), seed + 1)
    c = rand((m, n), seed + 2)
    got = epiphany_gemm.sgemm_inner(1.0, a, b, 1.0, c, ksub=ksub)
    want = ref.sgemm_inner_ref(1.0, a, b, 1.0, c)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_k_not_multiple_of_ksub_rejected():
    a = rand((M_UKR, KSUB + 1), 20)
    b = rand((KSUB + 1, N_UKR), 21)
    c = rand((M_UKR, N_UKR), 22)
    with pytest.raises(AssertionError, match="KSUB"):
        epiphany_gemm.sgemm_inner(1.0, a, b, 1.0, c)


def test_false_dgemm_precision_is_single():
    # f64 API but f32 compute: error vs true f64 must be f32-sized, and
    # the downcast-upcast must round-trip the f32 value exactly.
    a = rand((M_UKR, 512), 23, np.float64)
    b = rand((512, N_UKR), 24, np.float64)
    c = rand((M_UKR, N_UKR), 25, np.float64)
    got = np.asarray(ref.false_dgemm_ref(1.0, a, b, 1.0, c))
    true64 = a @ b + c
    rel = np.abs(got - true64) / np.abs(true64).max()
    assert 1e-9 < rel.max() < 1e-4, rel.max()
    got32 = np.asarray(
        ref.sgemm_inner_ref(
            np.float32(1.0), a.astype(np.float32), b.astype(np.float32),
            np.float32(1.0), c.astype(np.float32),
        )
    )
    np.testing.assert_array_equal(got.astype(np.float32), got32)
