"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path — everything the
rust runtime executes was lowered from exactly these functions.
"""

import numpy as np
import pytest

# The whole module needs jax + pallas; auto-skip when the wheels are not
# installed (offline CI images) so the rest of the suite still runs.
jax = pytest.importorskip("jax", reason="jax unavailable — L1 Pallas tests skipped")

from compile.kernels import epiphany_gemm, ref  # noqa: E402
from compile.kernels.epiphany_gemm import KSUB, M_UKR, N_UKR  # noqa: E402

jax.config.update("jax_enable_x64", True)


def rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


def test_paper_geometry_matches_ref():
    a = rand((M_UKR, 4 * KSUB), 0)
    b = rand((4 * KSUB, N_UKR), 1)
    c = rand((M_UKR, N_UKR), 2)
    got = epiphany_gemm.sgemm_inner(1.5, a, b, -0.5, c)
    want = ref.sgemm_inner_ref(1.5, a, b, -0.5, c)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_error_band_vs_f64_matches_paper():
    # The paper reports mean rel err 8.73e-8, max 5.83e-7 at K=4096.
    # The same order of magnitude must appear here (f32 accumulation).
    a = rand((M_UKR, 1024), 3)
    b = rand((1024, N_UKR), 4)
    c = np.zeros((M_UKR, N_UKR), np.float32)
    got = np.asarray(epiphany_gemm.sgemm_inner(1.0, a, b, 0.0, c))
    want = np.asarray(ref.sgemm_inner_ref_f64(1.0, a, b, 0.0, c))
    rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-3 * np.abs(want).max())
    assert 1e-9 < rel.mean() < 1e-6, rel.mean()
    assert rel.max() < 1e-4, rel.max()


def test_single_panel():
    a = rand((M_UKR, KSUB), 5)
    b = rand((KSUB, N_UKR), 6)
    c = rand((M_UKR, N_UKR), 7)
    got = epiphany_gemm.sgemm_inner(2.0, a, b, 1.0, c)
    want = ref.sgemm_inner_ref(2.0, a, b, 1.0, c)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_beta_zero_ignores_c():
    a = rand((M_UKR, KSUB), 8)
    b = rand((KSUB, N_UKR), 9)
    c_nan_free = rand((M_UKR, N_UKR), 10) * 1e6  # huge, must vanish
    got = epiphany_gemm.sgemm_inner(1.0, a, b, 0.0, c_nan_free)
    want = ref.sgemm_inner_ref(1.0, a, b, 0.0, np.zeros_like(c_nan_free))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_alpha_zero_scales_c_only():
    a = rand((M_UKR, KSUB), 11)
    b = rand((KSUB, N_UKR), 12)
    c = rand((M_UKR, N_UKR), 13)
    got = epiphany_gemm.sgemm_inner(0.0, a, b, 3.0, c)
    np.testing.assert_allclose(got, 3.0 * c, rtol=1e-6, atol=1e-6)


def test_acc_variant_chains():
    # Chaining two K-blocks through sgemm_acc == one big contraction.
    a = rand((M_UKR, 2 * KSUB), 14)
    b = rand((2 * KSUB, N_UKR), 15)
    c0 = np.zeros((M_UKR, N_UKR), np.float32)
    step1 = epiphany_gemm.sgemm_acc(a[:, :KSUB], b[:KSUB], c0)
    step2 = epiphany_gemm.sgemm_acc(a[:, KSUB:], b[KSUB:], np.asarray(step1))
    want = ref.sgemm_inner_ref(1.0, a, b, 0.0, c0)
    np.testing.assert_allclose(step2, want, rtol=3e-5, atol=3e-5)


def test_k_not_multiple_of_ksub_rejected():
    a = rand((M_UKR, KSUB + 1), 20)
    b = rand((KSUB + 1, N_UKR), 21)
    c = rand((M_UKR, N_UKR), 22)
    with pytest.raises(AssertionError, match="KSUB"):
        epiphany_gemm.sgemm_inner(1.0, a, b, 1.0, c)


def test_false_dgemm_precision_is_single():
    # f64 API but f32 compute: error vs true f64 must be f32-sized, and
    # the downcast-upcast must round-trip the f32 value exactly.
    a = rand((M_UKR, 512), 23, np.float64)
    b = rand((512, N_UKR), 24, np.float64)
    c = rand((M_UKR, N_UKR), 25, np.float64)
    got = np.asarray(ref.false_dgemm_ref(1.0, a, b, 1.0, c))
    true64 = a @ b + c
    rel = np.abs(got - true64) / np.abs(true64).max()
    assert 1e-9 < rel.max() < 1e-4, rel.max()
    got32 = np.asarray(
        ref.sgemm_inner_ref(
            np.float32(1.0), a.astype(np.float32), b.astype(np.float32),
            np.float32(1.0), c.astype(np.float32),
        )
    )
    np.testing.assert_array_equal(got.astype(np.float32), got32)
