"""L2: the sgemm inner micro-kernel compute graph (build-time JAX).

This is the function the rust coordinator calls on its request path (as an
AOT-compiled PJRT executable, never through python). It wraps the L1
Pallas kernel with the exact contract of the paper's section 3.3:

    given a1 (m x K, column-major), b1 (K x n, row-major),
    c_in (m x n, column-major):  c_out = alpha * a1 . b1 + beta * c_in

Row/column-major bookkeeping: PJRT executables see logical (row-major)
arrays; the rust packing layer hands buffers over in the layouts the paper
prescribes and flags the artifact shapes accordingly (a1 is passed as its
transpose, K x m, because a column-major m x K buffer *is* a row-major
K x m buffer — zero-copy on both sides).
"""

import jax
import jax.numpy as jnp

from .kernels import epiphany_gemm
from .kernels.epiphany_gemm import KSUB, M_UKR, N_UKR


def artifact_ksub(k):
    """Reduction-block size for the AOT artifact at depth k.

    The paper's KSUB=64 is an Epiphany local-store constraint (32 KB/core);
    the TPU/VMEM analog comfortably holds 192x512 + 512x256 panels
    (~0.9 MiB), so artifacts tile at KSUB=512 — fewer grid steps, same
    accumulator semantics. The structural KSUB=64 pipeline is preserved
    bit-for-bit in the rust simulator (DESIGN.md Hardware-Adaptation).
    """
    return min(k, 512)


def sgemm_inner_microkernel(alpha, a1_t, b1, beta, c_in_t):
    """The deployed artifact body.

    a1_t: (K, m) f32 — a column-major (m, K) a1 buffer, reinterpreted.
    b1:   (K, n) f32 — a row-major (K, n) b1 buffer, as-is.
    c_in_t: (n, m) f32 — a column-major (m, n) c buffer, reinterpreted.
    Returns c_out_t: (n, m) f32 — column-major (m, n) c_out.

    The transposes resolve inside XLA as layout assignments, not copies;
    the Pallas kernel still sees (m, K) @ (K, n).
    """
    a1 = a1_t.T
    c_in = c_in_t.T
    k = a1.shape[1]
    c_out = epiphany_gemm.sgemm_inner(alpha, a1, b1, beta, c_in, ksub=artifact_ksub(k))
    return c_out.T


def false_dgemm_microkernel(alpha, a1_t, b1, beta, c_in_t):
    """The paper's "false dgemm" artifact: f64 in/out, f32 compute.

    Implemented exactly as the paper describes — downcast the inputs, run
    the sgemm inner kernel, upcast the output — so the artifact reproduces
    both the precision (~1e-8 residues of Tables 5-6) and the cast cost.
    """
    a32 = a1_t.astype(jnp.float32)
    b32 = b1.astype(jnp.float32)
    c32 = c_in_t.astype(jnp.float32)
    out32 = sgemm_inner_microkernel(
        jnp.asarray(alpha, jnp.float32), a32, b32, jnp.asarray(beta, jnp.float32), c32
    )
    return out32.astype(jnp.float64)


def make_sgemm_spec(k):
    """ShapeDtypeStructs for an sgemm artifact with reduction depth k."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((), f32),            # alpha
        jax.ShapeDtypeStruct((k, M_UKR), f32),    # a1 (col-major m x K)
        jax.ShapeDtypeStruct((k, N_UKR), f32),    # b1 (row-major K x n)
        jax.ShapeDtypeStruct((), f32),            # beta
        jax.ShapeDtypeStruct((N_UKR, M_UKR), f32) # c_in (col-major m x n)
    )


def make_false_dgemm_spec(k):
    """ShapeDtypeStructs for a false-dgemm artifact (f64 API)."""
    f64 = jnp.float64
    return (
        jax.ShapeDtypeStruct((), f64),
        jax.ShapeDtypeStruct((k, M_UKR), f64),
        jax.ShapeDtypeStruct((k, N_UKR), f64),
        jax.ShapeDtypeStruct((), f64),
        jax.ShapeDtypeStruct((N_UKR, M_UKR), f64),
    )


# Artifact catalogue: name -> (function, spec builder, K).
# K variants let the rust runtime pick the largest block that divides the
# remaining reduction depth and chain with the accumulate path (beta = 1).
SGEMM_KS = (64, 256, 512, 1024, 2048, 4096)

def catalogue():
    cat = {}
    for k in SGEMM_KS:
        cat[f"sgemm_inner_k{k}"] = (sgemm_inner_microkernel, make_sgemm_spec(k))
    # The false dgemm is only ever called at the BLIS kernel block size.
    for k in (512, 4096):
        cat[f"false_dgemm_k{k}"] = (false_dgemm_microkernel, make_false_dgemm_spec(k))
    return cat
