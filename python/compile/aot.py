"""AOT lowering: jax (L2+L1) -> HLO text artifacts for the rust runtime.

HLO *text* is the interchange format, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Run via `make artifacts` (python is build-time only, never on the request
path):

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one `<name>.hlo.txt` per catalogue entry plus `manifest.txt` with
`name k dtype path` rows the rust ArtifactRegistry consumes.
"""

import argparse
import hashlib
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)  # the false dgemm needs f64 I/O


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for the rust
    side's `to_tuple1`)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, spec):
    def wrapped(*args):
        return (fn(*args),)

    return jax.jit(wrapped).lower(*spec)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    rows = []
    for name, (fn, spec) in sorted(model.catalogue().items()):
        if only and name not in only:
            continue
        text = to_hlo_text(lower_entry(fn, spec))
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        k = spec[1].shape[0]
        dtype = "f64" if "dgemm" in name else "f32"
        digest = hashlib.sha256(text.encode()).hexdigest()[:12]
        rows.append(f"{name} {k} {dtype} {os.path.basename(path)} {digest}")
        print(f"wrote {path} ({len(text)} chars, K={k}, {dtype})")

    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("# name K dtype file sha256_12\n")
        f.write("\n".join(rows) + "\n")
    print(f"wrote {manifest} ({len(rows)} artifacts)")


if __name__ == "__main__":
    main()
