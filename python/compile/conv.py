"""im2col lowering of a convolution layer to batched small gemm.

Python twin of ``rust/src/workloads/conv.rs`` — same conventions, same
index math, so the two sides can validate each other:

* images are NHWC (``batch x h x w x c_in``), filters are HWIO
  (``kh x kw x c_in x c_out``); padding is "valid", stride 1;
* the patch matrix of one image is ``out_h*out_w x kh*kw*c_in`` with
  row ``oy*out_w + ox`` and column ``(ky*kw + kx)*c_in + ci``;
* the filter bank flattens to ``kh*kw*c_in x c_out``;
* the convolution is then one ``patches @ filters`` gemm per image —
  exactly the ``GemmBatchOp`` traffic shape the rust side fans across
  the chip pool.

The core lowering is pure numpy (always available offline).
``pad_to_microkernel`` additionally zero-pads the lowered operands to
the AOT artifact's µ-kernel tile (192 x 256, K multiples of KSUB) so the
jax+pallas path can execute the same gemm; it needs no jax itself.
"""

import numpy as np

try:  # the kernel constants live next to the pallas kernel (jax import)
    from .kernels.epiphany_gemm import KSUB, M_UKR, N_UKR
except Exception:  # pragma: no cover - jax unavailable; paper constants
    M_UKR, N_UKR, KSUB = 192, 256, 64


def out_hw(h, w, kh, kw):
    """Valid-padding stride-1 output spatial dims."""
    if kh > h or kw > w:
        raise ValueError(f"kernel {kh}x{kw} does not fit input {h}x{w}")
    return h + 1 - kh, w + 1 - kw


def im2col(image, kh, kw):
    """Patch matrix of one HWC image: ``out_h*out_w x kh*kw*c_in``.

    Row ``oy*out_w + ox`` holds the receptive field of output pixel
    (oy, ox), flattened in (ky, kx, ci) order — the rust layout.
    """
    h, w, c_in = image.shape
    ho, wo = out_hw(h, w, kh, kw)
    patches = np.empty((ho * wo, kh * kw * c_in), dtype=image.dtype)
    for oy in range(ho):
        for ox in range(wo):
            patches[oy * wo + ox, :] = image[oy : oy + kh, ox : ox + kw, :].reshape(-1)
    return patches


def filter_matrix(filters):
    """HWIO filter bank as a ``kh*kw*c_in x c_out`` matrix."""
    kh, kw, c_in, c_out = filters.shape
    return filters.reshape(kh * kw * c_in, c_out)


def conv2d_via_batch(batch, filters):
    """The lowered convolution: one small gemm per image.

    batch: (n, h, w, c_in) NHWC; filters: (kh, kw, c_in, c_out) HWIO.
    Returns (n, out_h*out_w, c_out) — the stacked per-image gemm results,
    matching the rust ``conv2d_via_batch`` output item-for-item.
    """
    kh, kw = filters.shape[:2]
    fmat = filter_matrix(filters)
    return np.stack([im2col(img, kh, kw) @ fmat for img in batch])


def conv2d_reference(batch, filters):
    """Direct f64-accumulated convolution — the oracle."""
    n, h, w, c_in = batch.shape
    kh, kw, _, c_out = filters.shape
    ho, wo = out_hw(h, w, kh, kw)
    x = batch.astype(np.float64)
    f = filters.astype(np.float64)
    out = np.zeros((n, ho * wo, c_out))
    for oy in range(ho):
        for ox in range(wo):
            window = x[:, oy : oy + kh, ox : ox + kw, :].reshape(n, -1)
            out[:, oy * wo + ox, :] = window @ f.reshape(-1, c_out)
    return out


def pad_to_microkernel(patches, fmat, m_ukr=None, n_ukr=None, ksub=None):
    """Zero-pad a lowered (patches, filters) pair to µ-kernel multiples.

    The artifact executes (m_ukr x K) @ (K x n_ukr) tiles with K a
    multiple of KSUB; small conv gemms rarely land on those multiples,
    so this pads rows of `patches` to m_ukr, columns of `fmat` to n_ukr,
    and the shared K dim to a KSUB multiple. Returns
    ``(patches_p, fmat_p, (rows, cols))`` where (rows, cols) crops the
    padded product back: ``(patches_p @ fmat_p)[:rows, :cols]`` equals
    ``patches @ fmat`` exactly (zero padding contributes zero).
    """
    m_ukr = M_UKR if m_ukr is None else m_ukr
    n_ukr = N_UKR if n_ukr is None else n_ukr
    ksub = KSUB if ksub is None else ksub
    rows, k = patches.shape
    k2, cols = fmat.shape
    if k != k2:
        raise ValueError(f"K mismatch: patches {k} vs filters {k2}")

    def up(v, unit):
        return ((v + unit - 1) // unit) * unit

    patches_p = np.zeros((up(rows, m_ukr), up(k, ksub)), dtype=patches.dtype)
    patches_p[:rows, :k] = patches
    fmat_p = np.zeros((up(k, ksub), up(cols, n_ukr)), dtype=fmat.dtype)
    fmat_p[:k, :cols] = fmat
    return patches_p, fmat_p, (rows, cols)
