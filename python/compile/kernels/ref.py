"""Pure-jnp oracle for the L1 kernel — the correctness reference.

Everything the Pallas kernel (and, transitively, the AOT artifacts and the
rust simulator) computes is checked against these definitions in
python/tests/, and the rust side re-checks against an f64 port of the same
formulas.
"""

import jax.numpy as jnp


def sgemm_inner_ref(alpha, a, b, beta, c_in):
    """c_out = alpha * (a @ b) + beta * c_in in f32."""
    return (
        jnp.asarray(alpha, jnp.float32) * jnp.dot(a, b, preferred_element_type=jnp.float32)
        + jnp.asarray(beta, jnp.float32) * c_in
    )


def sgemm_inner_ref_f64(alpha, a, b, beta, c_in):
    """The same contraction in f64 — the error-measurement baseline the
    paper's 'Mean/Maximum Relative Error' rows are computed against."""
    a64 = a.astype(jnp.float64)
    b64 = b.astype(jnp.float64)
    c64 = c_in.astype(jnp.float64)
    return float(alpha) * jnp.dot(a64, b64) + float(beta) * c64


def false_dgemm_ref(alpha, a, b, beta, c_in):
    """The paper's "false dgemm": f64 API, downcast -> f32 compute -> upcast.

    Precision is 'expected to be close to that of Single Precision'.
    """
    out32 = sgemm_inner_ref(
        jnp.float32(alpha), a.astype(jnp.float32), b.astype(jnp.float32),
        jnp.float32(beta), c_in.astype(jnp.float32),
    )
    return out32.astype(jnp.float64)
