"""L1: the Epiphany sgemm micro-kernel as a Pallas kernel.

The paper's Epiphany kernel streams KSUB-deep panel pairs through the
chip's 32 KB-per-core scratchpads and accumulates the `m x n` result
on-chip (the "Accumulator" scheme, command protocol of paper section 3.3).
Re-thought for a TPU-shaped machine (DESIGN.md "Hardware-Adaptation"):

* the per-core local store becomes a VMEM accumulator scratch holding the
  full `m x n` micro-tile (192x256 f32 = 192 KiB, comfortably VMEM-sized;
  on the Epiphany the same tile was sharded 16 ways at 12 KB per core);
* the SUMMA-like host loop over KSUB panels becomes the Pallas *grid*'s
  reduction dimension: grid step `t` sees blocks `a[:, t*KSUB:(t+1)*KSUB]`
  and `b[t*KSUB:(t+1)*KSUB, :]`, and pallas' automatic HBM->VMEM block
  pipelining replaces the host's double-buffered `selector` uploads;
* the doMult/subMatmul rank-KSUB update becomes one MXU-shaped `jnp.dot`
  per grid step accumulated into scratch (the command=1 "accumulate,
  don't send back" path);
* the final grid step applies `alpha * acc + beta * c_in` and commits the
  output block (the command=2 "send results back" path, fused with the
  alpha/beta epilogue the Parallella host had to run on the slow ARM).

`interpret=True` throughout: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that the rust
runtime's PJRT CPU client runs directly (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The paper's production geometry (section 3.4, figure 3).
M_UKR = 192
N_UKR = 256
KSUB = 64


def _gemm_kernel(alpha_ref, beta_ref, a_ref, b_ref, c_ref, out_ref, acc_ref, *, n_steps):
    """One grid step = one "Epiphany Task": acc += a_panel @ b_panel."""
    t = pl.program_id(0)

    # command = 0 / 3: the first task clears the accumulator.
    @pl.when(t == 0)
    def _clear():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The Task: rank-KSUB update, fp32 accumulation on the MXU.
    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    # command = 2 / 3: the last task applies the alpha/beta epilogue and
    # sends the result back (commits the output block).
    @pl.when(t == n_steps - 1)
    def _send():
        out_ref[...] = alpha_ref[0] * acc_ref[...] + beta_ref[0] * c_ref[...]


def sgemm_inner(alpha, a, b, beta, c_in, *, ksub=KSUB):
    """The paper's "sgemm inner micro-kernel":

        c_out = alpha * (a @ b) + beta * c_in

    a: (m, K) f32, b: (K, n) f32, c_in: (m, n) f32. K must be a multiple
    of `ksub`; the rust packing layer zero-pads K exactly as it does for
    the simulator backend.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert k % ksub == 0, f"K={k} not a multiple of KSUB={ksub}"
    n_steps = k // ksub
    alpha = jnp.asarray(alpha, jnp.float32).reshape((1,))
    beta = jnp.asarray(beta, jnp.float32).reshape((1,))

    kernel = functools.partial(_gemm_kernel, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=(n_steps,),
        in_specs=[
            # alpha/beta scalars, replicated to every step.
            pl.BlockSpec((1,), lambda t: (0,)),
            pl.BlockSpec((1,), lambda t: (0,)),
            # a panel: all m rows, the t-th KSUB-column block.
            pl.BlockSpec((m, ksub), lambda t: (0, t)),
            # b panel: the t-th KSUB-row block, all n columns.
            pl.BlockSpec((ksub, n), lambda t: (t, 0)),
            # c_in: the whole tile (consumed only at the last step).
            pl.BlockSpec((m, n), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        # The on-chip accumulator (RES2's role, VMEM instead of 16 sharded
        # 12 KB scratchpads).
        scratch_shapes=[pltpu.VMEM((m, n), jnp.float32)],
        interpret=True,
    )(alpha, beta, a, b, c_in)


def sgemm_acc(a, b, c_in, *, ksub=KSUB):
    """Pure-accumulate variant: c_out = a @ b + c_in (alpha = beta = 1).

    Used by the rust runtime to chain K blocks beyond a single artifact's
    fixed K (the command=1 path across artifact calls).
    """
    return sgemm_inner(1.0, a, b, 1.0, c_in, ksub=ksub)
