"""Make the `compile` package importable when pytest runs from the repo
root (`python -m pytest python/tests -q`): this directory is the python
layer's source root."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
