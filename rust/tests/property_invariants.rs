//! Property-based tests (the crate's own mini-proptest; no external
//! crates offline) over the stack's core invariants:
//!
//! * coordinator: routing determinism, batcher FIFO per key, protocol
//!   encode/decode round-trip under random payloads;
//! * BLIS packing: pack/unpack round-trip, zero-pad correctness;
//! * Epiphany kernel: ring rotation covers every (core, target) pair,
//!   any divisible geometry multiplies correctly;
//! * gemm algebra: linearity in alpha, additivity over K splits.

use parallella_blas::blis::packing::{pack_a, pack_b, pack_c, unpack_c};
use parallella_blas::blis::Trans;
use parallella_blas::coordinator::protocol::{
    strided_len, FrameAccumulator, GemmBatchWire, GemmWire, GemvWire, Opcode, Request, Response,
    SolveWire, Tensor, PROTOCOL_V1, PROTOCOL_V2,
};
use parallella_blas::epiphany::mesh::{ring_core, ring_pos};
use parallella_blas::epiphany::CORES;
use parallella_blas::linalg::{max_scaled_err, Mat, XorShiftRng};
use parallella_blas::prelude::*;
use parallella_blas::util::proptest::{forall, Config};
use parallella_blas::workloads::Factorization;

#[test]
fn prop_packing_round_trips() {
    forall(
        Config { cases: 48, seed: 0xA11CE },
        |rng| {
            let m = 1 + rng.next_below(64);
            let n = 1 + rng.next_below(64);
            (m, n, rng.next_u64())
        },
        |&(m, n, seed)| {
            let c0 = Mat::<f32>::randn(m, n, seed);
            let (mt, nt) = (m + rng_pad(seed), n + rng_pad(seed ^ 1));
            let tile = pack_c(c0.view(), 0, 0, m, n, mt, nt);
            let mut c1 = Mat::<f32>::zeros(m, n);
            let mut v = c1.view_mut();
            unpack_c(&tile, &mut v, 0, 0, m, n, mt);
            c1 == c0
        },
    );
}

fn rng_pad(seed: u64) -> usize {
    (seed % 5) as usize
}

#[test]
fn prop_pack_a_padding_is_zero() {
    forall(
        Config { cases: 32, seed: 0xB0B },
        |rng| (1 + rng.next_below(50), 1 + rng.next_below(20), rng.next_u64()),
        |&(rows, k, seed)| {
            let a = Mat::<f32>::randn(rows, k, seed);
            let m_tile = rows + 7;
            let (panel, _) = pack_a(a.view(), 0, rows, m_tile);
            // all pad rows zero, all real entries exact
            (0..k).all(|l| {
                (rows..m_tile).all(|i| panel[l * m_tile + i] == 0.0)
                    && (0..rows).all(|i| panel[l * m_tile + i] == a.get(i, l))
            })
        },
    );
}

#[test]
fn prop_pack_b_transpose_consistency() {
    // Packing op(B)=Bᵀ from a stored Bᵀ must equal packing op(B)=B from B.
    forall(
        Config { cases: 32, seed: 0xCAFE },
        |rng| (1 + rng.next_below(20), 1 + rng.next_below(30), rng.next_u64()),
        |&(k, n, seed)| {
            let b = Mat::<f32>::randn(k, n, seed);
            let bt = b.transposed();
            let (p1, _) = pack_b(b.view(), 0, n, n);
            let (p2, _) = pack_b(bt.t(), 0, n, n);
            p1 == p2
        },
    );
}

#[test]
fn prop_ring_rotation_covers_all_targets() {
    // Over CORES iterations, each ring position computes every target
    // exactly once, and the final iteration computes its own block — the
    // §3.4.3 schedule invariant.
    for pos in 0..CORES {
        let mut seen = [false; CORES];
        for iter in 0..CORES {
            let target = (pos + CORES - (iter % CORES) - 1) % CORES;
            assert!(!seen[target], "target {target} repeated");
            seen[target] = true;
            if iter == CORES - 1 {
                assert_eq!(target, pos, "last iteration must be own block");
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}

#[test]
fn prop_ring_embedding_bijective() {
    for pos in 0..CORES {
        assert_eq!(ring_pos(ring_core(pos)), pos);
    }
}

/// Build a random tensor of `len` elements in the requested dtype.
fn rand_tensor(rng: &mut XorShiftRng, dtype: Dtype, len: usize) -> Tensor {
    match dtype {
        Dtype::F32 => Tensor::F32((0..len).map(|_| rng.next_unit() as f32).collect()),
        Dtype::F64 => Tensor::F64((0..len).map(|_| rng.next_unit()).collect()),
    }
}

/// Build a random request for one (opcode, dtype) cell; `(m, n, k)` sizes
/// the payload (0 = empty tensors are legal frames).
fn rand_request(
    rng: &mut XorShiftRng,
    op: Opcode,
    dtype: Dtype,
    m: usize,
    n: usize,
    k: usize,
) -> Request {
    let trans_of = |r: &mut XorShiftRng| [Trans::N, Trans::T, Trans::C, Trans::H][r.next_below(4)];
    match op {
        Opcode::Ping => Request::Ping,
        Opcode::Stats => Request::Stats,
        Opcode::Shutdown => Request::Shutdown,
        Opcode::Subscribe => Request::Subscribe,
        Opcode::Hello => {
            Request::Hello { version: PROTOCOL_V1 + rng.next_below(3) as u32 }
        }
        Opcode::Gemm => {
            let mut g = rand_gemm_item(rng, dtype, m, n, k);
            // A random shard hint (including none, and including values
            // past the flag nibble's ceiling of 14) must round-trip too.
            g.shard_hint = rand_hint(rng);
            Request::Gemm(g)
        }
        Opcode::GemmBatch => {
            // 1–3 items, all at the frame dtype; per-item hints do not
            // travel on a batch, so only the batch-level hint varies.
            let items = (0..1 + rng.next_below(3))
                .map(|_| rand_gemm_item(rng, dtype, m, n, k))
                .collect();
            Request::GemmBatch(GemmBatchWire { items, shard_hint: rand_hint(rng) })
        }
        Opcode::Solve => {
            let factorization = [Factorization::Lu, Factorization::Cholesky][rng.next_below(2)];
            let (tolerance, _) = scalars(rng, dtype);
            Request::Solve(SolveWire {
                factorization,
                n,
                nb: rng.next_below(64),
                max_iters: rng.next_below(40),
                tolerance,
                a: rand_tensor(rng, dtype, n * n),
                b: rand_tensor(rng, dtype, n),
            })
        }
        Opcode::Gemv => {
            let ta = trans_of(rng);
            let (incx, incy) = (1 + rng.next_below(3), 1 + rng.next_below(3));
            let (xl, yl) = if ta.is_trans() { (m, n) } else { (n, m) };
            let a = rand_tensor(rng, dtype, m * n);
            let x = rand_tensor(rng, dtype, strided_len(xl, incx));
            let y = rand_tensor(rng, dtype, strided_len(yl, incy));
            let (alpha, beta) = scalars(rng, dtype);
            Request::Gemv(GemvWire { ta, m, n, incx, incy, alpha, beta, a, x, y })
        }
    }
}

/// One random gemm descriptor (hintless) sized by `(m, n, k)` — the
/// shared item shape for `Gemm` frames and `GemmBatch` entries.
fn rand_gemm_item(rng: &mut XorShiftRng, dtype: Dtype, m: usize, n: usize, k: usize) -> GemmWire {
    let trans_of = |r: &mut XorShiftRng| [Trans::N, Trans::T, Trans::C, Trans::H][r.next_below(4)];
    let (ta, tb) = (trans_of(rng), trans_of(rng));
    let (am, an) = if ta.is_trans() { (k, m) } else { (m, k) };
    let (bm, bn) = if tb.is_trans() { (n, k) } else { (k, n) };
    let (a, b) = (rand_tensor(rng, dtype, am * an), rand_tensor(rng, dtype, bm * bn));
    let c = rand_tensor(rng, dtype, m * n);
    let (alpha, beta) = scalars(rng, dtype);
    GemmWire { ta, tb, m, n, k, alpha, beta, a, b, c, shard_hint: None }
}

/// A random chip-affinity hint: sometimes none, sometimes past the flag
/// nibble's ceiling of 14 (the codec must saturate, not reject).
fn rand_hint(rng: &mut XorShiftRng) -> Option<usize> {
    match rng.next_below(20) {
        0 => None,
        h => Some(h - 1),
    }
}

/// Random scalars exactly representable at the wire dtype's width.
fn scalars(rng: &mut XorShiftRng, dtype: Dtype) -> (f64, f64) {
    match dtype {
        Dtype::F32 => (rng.next_unit() as f32 as f64, rng.next_unit() as f32 as f64),
        Dtype::F64 => (rng.next_unit(), rng.next_unit()),
    }
}

/// Field-wise equality of two gemm descriptors, hints excluded (batch
/// items never carry one; single-gemm hints compare saturated).
fn gemm_items_equal(x: &GemmWire, y: &GemmWire) -> bool {
    x.ta == y.ta
        && x.tb == y.tb
        && (x.m, x.n, x.k) == (y.m, y.n, y.k)
        && (x.alpha, x.beta) == (y.alpha, y.beta)
        && x.a == y.a
        && x.b == y.b
        && x.c == y.c
}

fn requests_equal(a: &Request, b: &Request) -> bool {
    match (a, b) {
        (Request::Ping, Request::Ping)
        | (Request::Stats, Request::Stats)
        | (Request::Shutdown, Request::Shutdown)
        | (Request::Subscribe, Request::Subscribe) => true,
        (Request::Hello { version: a }, Request::Hello { version: b }) => a == b,
        (Request::Gemm(x), Request::Gemm(y)) => {
            // The flag nibble saturates hints at 14 by design, so the
            // round-trip identity holds on the *encoded* hint.
            gemm_items_equal(x, y)
                && x.shard_hint.map(|h| h.min(14)) == y.shard_hint.map(|h| h.min(14))
        }
        (Request::GemmBatch(x), Request::GemmBatch(y)) => {
            x.shard_hint.map(|h| h.min(14)) == y.shard_hint.map(|h| h.min(14))
                && x.items.len() == y.items.len()
                && x.items.iter().zip(&y.items).all(|(g, h)| gemm_items_equal(g, h))
        }
        (Request::Solve(x), Request::Solve(y)) => {
            x.factorization == y.factorization
                && (x.n, x.nb, x.max_iters) == (y.n, y.nb, y.max_iters)
                && x.tolerance == y.tolerance
                && x.a == y.a
                && x.b == y.b
        }
        (Request::Gemv(x), Request::Gemv(y)) => {
            x.ta == y.ta
                && (x.m, x.n) == (y.m, y.n)
                && (x.incx, x.incy) == (y.incx, y.incy)
                && (x.alpha, x.beta) == (y.alpha, y.beta)
                && x.a == y.a
                && x.x == y.x
                && x.y == y.y
        }
        _ => false,
    }
}

#[test]
fn prop_protocol_round_trip_every_opcode_dtype() {
    // encode→decode identity for EVERY opcode × dtype, including the empty
    // payload (m=n=k=0) and the µ-kernel max-tile payload (192×256).
    let mut rng = XorShiftRng::new(0xF00D);
    let shapes: [(usize, usize, usize); 4] = [
        (0, 0, 0),      // empty tensors
        (1, 1, 1),      // minimal
        (5, 3, 7),      // ragged
        (192, 256, 16), // µ-kernel max tile (m × n), K short to stay fast
    ];
    for op in Opcode::all() {
        for dtype in Dtype::all() {
            for &(m, n, k) in &shapes {
                let req = rand_request(&mut rng, op, dtype, m, n, k);
                let frame = req.encode();
                let back = Request::decode(&frame[4..])
                    .unwrap_or_else(|e| panic!("{op:?} {dtype:?} ({m},{n},{k}): {e:#}"));
                assert!(
                    requests_equal(&req, &back),
                    "round trip changed {op:?} {dtype:?} ({m},{n},{k})"
                );
                // The dtype byte in the header must match the descriptor.
                assert_eq!(frame[5], req.dtype().code(), "{op:?} {dtype:?} header dtype");
            }
        }
    }
}

#[test]
fn prop_protocol_round_trip_random() {
    forall(
        Config { cases: 60, seed: 0xF00D },
        |rng| {
            let m = 1 + rng.next_below(8);
            let n = 1 + rng.next_below(8);
            let k = 1 + rng.next_below(8);
            let op = [Opcode::Gemm, Opcode::Gemv][rng.next_below(2)];
            let dtype = [Dtype::F32, Dtype::F64][rng.next_below(2)];
            (op, dtype, m, n, k, rng.next_u64())
        },
        |&(op, dtype, m, n, k, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let req = rand_request(&mut rng, op, dtype, m, n, k);
            let frame = req.encode();
            match Request::decode(&frame[4..]) {
                Ok(back) => requests_equal(&req, &back),
                Err(_) => false,
            }
        },
    );
}

#[test]
fn prop_v2_round_trip_cid_and_deadline() {
    // encode_v2 → decode_v2 identity: the correlation id and optional
    // deadline budget ride every frame unchanged, payload untouched.
    forall(
        Config { cases: 40, seed: 0x51D },
        |rng| {
            let m = 1 + rng.next_below(6);
            let n = 1 + rng.next_below(6);
            let k = 1 + rng.next_below(6);
            let op = [Opcode::Gemm, Opcode::Gemv, Opcode::Ping, Opcode::Stats][rng.next_below(4)];
            let cid = rng.next_u64() as u32;
            let deadline = match rng.next_below(3) {
                0 => None,
                d => Some(d as u32 * 500),
            };
            (op, m, n, k, cid, deadline, rng.next_u64())
        },
        |&(op, m, n, k, cid, deadline, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let req = rand_request(&mut rng, op, Dtype::F32, m, n, k);
            let frame = req.encode_v2(cid, deadline);
            match Request::decode_v2(&frame[4..]) {
                Ok((c, d, back)) => c == cid && d == deadline && requests_equal(&req, &back),
                Err(_) => false,
            }
        },
    );
}

#[test]
fn prop_frame_accumulator_every_split_boundary() {
    // Concatenate a few frames and cut the byte stream at EVERY possible
    // boundary: the accumulator must yield identical frame bodies no
    // matter where the reads split.
    let frames = [
        Request::Hello { version: PROTOCOL_V2 }.encode(),
        Request::Ping.encode(),
        Request::Stats.encode(),
    ];
    let stream: Vec<u8> = frames.iter().flatten().copied().collect();
    let want: Vec<Vec<u8>> = frames.iter().map(|f| f[4..].to_vec()).collect();
    for cut in 0..=stream.len() {
        let mut acc = FrameAccumulator::new(1 << 20);
        let mut got = Vec::new();
        acc.extend(&stream[..cut]);
        while let Some(body) = acc.try_frame().unwrap() {
            got.push(body);
        }
        acc.extend(&stream[cut..]);
        while let Some(body) = acc.try_frame().unwrap() {
            got.push(body);
        }
        assert_eq!(got, want, "cut at byte {cut}");
        assert!(!acc.has_partial(), "cut at byte {cut} left residue");
    }
}

#[test]
fn prop_frame_accumulator_dribble_equals_coalesced() {
    // A 1-byte-at-a-time dribble and a single coalesced write must parse
    // to the same frames, for random gemm/gemv payloads in v2 framing.
    forall(
        Config { cases: 20, seed: 0xACC },
        |rng| {
            (1 + rng.next_below(5), 1 + rng.next_below(5), 1 + rng.next_below(5), rng.next_u64())
        },
        |&(m, n, k, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let frames: Vec<Vec<u8>> = (0..3usize)
                .map(|i| {
                    let op = [Opcode::Gemm, Opcode::Gemv, Opcode::Ping][i % 3];
                    rand_request(&mut rng, op, Dtype::F32, m, n, k).encode_v2(i as u32, None)
                })
                .collect();
            let want: Vec<Vec<u8>> = frames.iter().map(|f| f[4..].to_vec()).collect();
            let stream: Vec<u8> = frames.iter().flatten().copied().collect();
            let mut dribbled = Vec::new();
            let mut acc = FrameAccumulator::new(1 << 24);
            for b in &stream {
                acc.extend(std::slice::from_ref(b));
                while let Some(body) = acc.try_frame().unwrap() {
                    dribbled.push(body);
                }
            }
            let mut coalesced = Vec::new();
            let mut acc2 = FrameAccumulator::new(1 << 24);
            acc2.extend(&stream);
            while let Some(body) = acc2.try_frame().unwrap() {
                coalesced.push(body);
            }
            dribbled == want && coalesced == want && !acc.has_partial() && !acc2.has_partial()
        },
    );
}

#[test]
fn prop_response_round_trip() {
    forall(
        Config { cases: 24, seed: 0xE44 },
        |rng| (rng.next_below(4), rng.next_below(9), rng.next_u64()),
        |&(variant, len, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let resp = match variant {
                0 => Response::Ok(rand_tensor(&mut rng, Dtype::F32, len)),
                1 => Response::Ok(rand_tensor(&mut rng, Dtype::F64, len)),
                2 => Response::OkText(format!("text-{seed}")),
                _ => Response::Err(format!("error-{seed}")),
            };
            let back = Response::decode(&resp.encode()[4..]);
            match (&resp, back) {
                (Response::Ok(a), Ok(Response::Ok(b))) => *a == b,
                (Response::OkText(a), Ok(Response::OkText(b))) => *a == b,
                (Response::Err(a), Ok(Response::Err(b))) => *a == b,
                _ => false,
            }
        },
    );
}

#[test]
fn prop_gemm_linear_in_alpha() {
    // sgemm(2α) == 2·sgemm(α) when beta = 0 (checked through the full
    // service + simulator path).
    let plat = Platform::builder().backend(BackendKind::Simulator).build().unwrap();
    let (m, n, k) = (192, 256, 64);
    let a = Mat::<f32>::randn(m, k, 77);
    let b = Mat::<f32>::randn(k, n, 78);
    let mut c1 = Mat::<f32>::zeros(m, n);
    let mut c2 = Mat::<f32>::zeros(m, n);
    plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c1).unwrap();
    plat.blas().sgemm(Trans::N, Trans::N, 2.0, a.view(), b.view(), 0.0, &mut c2).unwrap();
    let scaled = Mat::from_fn(m, n, |i, j| 2.0 * c1.get(i, j));
    assert!(max_scaled_err(c2.view(), scaled.view()) < 1e-6);
}

#[test]
fn prop_gemm_additive_over_k_split() {
    // A·B == A1·B1 + A2·B2 for a K split — the accumulator protocol's
    // algebraic foundation (and what the chip does across tasks).
    let plat = Platform::builder().backend(BackendKind::Simulator).build().unwrap();
    let (m, n, k) = (192, 256, 256);
    let a = Mat::<f32>::randn(m, k, 80);
    let b = Mat::<f32>::randn(k, n, 81);
    let mut whole = Mat::<f32>::zeros(m, n);
    plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut whole).unwrap();

    let a1 = a.view().sub(0, 0, m, k / 2).to_mat();
    let a2 = a.view().sub(0, k / 2, m, k / 2).to_mat();
    let b1 = b.view().sub(0, 0, k / 2, n).to_mat();
    let b2 = b.view().sub(k / 2, 0, k / 2, n).to_mat();
    let mut split = Mat::<f32>::zeros(m, n);
    plat.blas().sgemm(Trans::N, Trans::N, 1.0, a1.view(), b1.view(), 0.0, &mut split).unwrap();
    plat.blas().sgemm(Trans::N, Trans::N, 1.0, a2.view(), b2.view(), 1.0, &mut split).unwrap();
    assert!(max_scaled_err(split.view(), whole.view()) < 1e-5);
}
