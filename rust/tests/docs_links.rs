//! Documentation link checker: the architecture doc and the README can't
//! rot silently. Every relative markdown link in `README.md` and
//! `docs/*.md` must resolve to a real file, and every backticked repo
//! path `docs/ARCHITECTURE.md` cross-references must exist. CI runs this
//! as part of the docs job.

use std::path::PathBuf;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn md_files() -> Vec<PathBuf> {
    let mut files = vec![repo_root().join("README.md")];
    if let Ok(rd) = std::fs::read_dir(repo_root().join("docs")) {
        for e in rd.flatten() {
            let p = e.path();
            if p.extension().map(|x| x == "md").unwrap_or(false) {
                files.push(p);
            }
        }
    }
    files
}

/// Extract `](target)` markdown link targets.
fn links(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(pos) = rest.find("](") {
        rest = &rest[pos + 2..];
        match rest.find(')') {
            Some(end) => {
                out.push(rest[..end].to_string());
                rest = &rest[end + 1..];
            }
            None => break,
        }
    }
    out
}

#[test]
fn markdown_links_resolve() {
    let mut checked = 0usize;
    for file in md_files() {
        let text = std::fs::read_to_string(&file).unwrap();
        let dir = file.parent().unwrap().to_path_buf();
        for link in links(&text) {
            // External URLs, in-page anchors and GitHub-virtual paths
            // (the CI badge's ../../actions) are out of scope.
            if link.starts_with("http://")
                || link.starts_with("https://")
                || link.starts_with('#')
                || link.contains("actions/")
            {
                continue;
            }
            let path = link.split('#').next().unwrap();
            if path.is_empty() {
                continue;
            }
            let target = dir.join(path);
            assert!(target.exists(), "{}: broken relative link `{link}`", file.display());
            checked += 1;
        }
    }
    assert!(checked > 0, "expected at least one relative link across README.md and docs/");
}

#[test]
fn architecture_doc_cross_references_exist() {
    let doc = repo_root().join("docs/ARCHITECTURE.md");
    let text = std::fs::read_to_string(&doc).expect("docs/ARCHITECTURE.md must exist");
    let mut checked = 0usize;
    // Every backticked repo-relative path the doc mentions must exist —
    // the paper-section → module cross-reference table stays truthful.
    for token in text.split('`').skip(1).step_by(2) {
        let is_path = token.starts_with("rust/")
            || token.starts_with("python/")
            || token.starts_with("docs/")
            || token.starts_with("examples/");
        if is_path && !token.contains(' ') && !token.contains('\n') {
            let p = repo_root().join(token);
            assert!(p.exists(), "ARCHITECTURE.md references a missing path `{token}`");
            checked += 1;
        }
    }
    assert!(checked >= 10, "the module cross-reference table should name repo paths ({checked})");
}
