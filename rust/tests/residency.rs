//! Operand-residency integration: the packed-A panel cache and the
//! wire/staging buffer pools, exercised end to end.
//!
//! The binary installs a counting global allocator so the tier-2
//! "zero pack-side allocations on a verified hit" claim is a hard
//! assertion, not a benchmark anecdote. The counter is thread-local,
//! so the other tests in this binary (which the harness runs on
//! sibling threads) cannot pollute a measured window.

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::{FrameAccumulator, Request, Response, ServerConfig};
use parallella_blas::linalg::Mat;
use parallella_blas::mem::{hash_operand, BufferPool, PanelCache};
use parallella_blas::platform::Platform;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Passes every call through to the system allocator, counting
/// allocations per thread on the way.
struct CountingAlloc;

// SAFETY: delegates verbatim to `System`; the counter bump cannot
// allocate (const-initialised thread-local `Cell`).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

/// Tier-2 allocation-count assertion: once a panel is resident, serving
/// it again — key build, lookup, bytewise verify, `Arc` handout — must
/// not touch the allocator at all.
#[test]
fn verified_panel_hit_performs_zero_allocations() {
    let cache = PanelCache::new(1 << 20);
    let a = Mat::<f32>::randn(8, 6, 42);
    let h = hash_operand(a.view());
    // First call packs and inserts (allocates, by design).
    let (first, _) = cache.get_or_pack::<f32>(h, 0, a.view(), 0, 8, 8);
    let before = allocs_on_this_thread();
    let (panel, _) = cache.get_or_pack::<f32>(h, 0, a.view(), 0, 8, 8);
    let during = allocs_on_this_thread() - before;
    assert!(Arc::ptr_eq(&first, &panel), "hit must serve the resident panel");
    assert_eq!(during, 0, "the verified hit path allocated {during} time(s)");
    let s = cache.stats();
    assert_eq!((s.hits, s.misses), (1, 1), "{s:?}");
}

/// The `panel_cache_bytes` knob must never change results: cache-on and
/// cache-off builds stay bit-identical on a single chip and on a 4-chip
/// pool, across repeated (hitting) calls.
#[test]
fn cache_on_and_off_bit_identical_on_pools_1_and_4() {
    let a = Mat::<f32>::randn(100, 50, 5);
    let b = Mat::<f32>::randn(50, 600, 6); // 3 column tiles → real sharding
    for chips in [1usize, 4] {
        let plain = Platform::builder().chips(chips).build().unwrap();
        let cached = Platform::builder().chips(chips).panel_cache_bytes(16 << 20).build().unwrap();
        let mut c0 = Mat::<f32>::zeros(100, 600);
        let mut c1 = Mat::<f32>::zeros(100, 600);
        for pass in 0..2 {
            plain.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c0).unwrap();
            cached.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c1).unwrap();
            assert_eq!(
                c0.as_slice(),
                c1.as_slice(),
                "cache on/off diverged on pass {pass} with {chips} chip(s)"
            );
        }
        let s = cached.blas().panel_cache().unwrap().stats();
        assert!(s.hits >= 1, "second pass must hit on {chips} chip(s): {s:?}");
    }
}

/// Concurrent pipelined v2 clients hammering the same weights: the
/// server-side cache takes verified hits under contention, and the
/// residency counters come back over the stats opcode.
#[test]
fn concurrent_v2_clients_hit_the_panel_cache() {
    let cfg = ServerConfig { panel_cache_bytes: 32 << 20, ..Default::default() };
    let srv = BlasServer::start(cfg).unwrap();

    let (m, n, k) = (48, 32, 40);
    let a = Mat::<f32>::randn(m, k, 77); // the shared "weights"
    let req = |seed: u64| {
        let b = Mat::<f32>::randn(k, n, seed);
        Request::sgemm(
            Trans::N,
            Trans::N,
            m,
            n,
            k,
            1.0,
            0.0,
            a.as_slice().to_vec(),
            b.as_slice().to_vec(),
            vec![0.0; m * n],
        )
    };

    std::thread::scope(|scope| {
        for t in 0..2u64 {
            let req = &req;
            let addr = srv.addr();
            scope.spawn(move || {
                let mut cli = BlasClient::connect_v2(addr).unwrap();
                // 4 requests in flight at once, per client.
                let pendings: Vec<_> =
                    (0..4u64).map(|i| cli.submit(&req(1000 * t + i)).unwrap()).collect();
                for p in pendings {
                    let out = p.wait().unwrap().into_f32().unwrap();
                    assert_eq!(out.len(), m * n);
                }
            });
        }
    });

    let mut ctl = BlasClient::connect(srv.addr()).unwrap();
    match ctl.call(&Request::Stats).unwrap() {
        Response::Stats(s) => {
            assert!(s.panel_misses >= 1, "first pack is a miss: {s}");
            assert!(s.panel_hits >= 1, "repeated weights must hit: {s}");
            assert!(s.pool_recycled >= 1, "wire/staging pools must recycle: {s}");
            let line = format!("{s}");
            assert!(line.contains("panel_hits="), "{line}");
        }
        other => panic!("{other:?}"),
    }
}

/// The frame accumulator recycles decoded frame bodies through a shared
/// wire pool: dropping one request's body funds the next one's buffer.
#[test]
fn frame_accumulator_recycles_through_the_shared_pool() {
    let pool = Arc::new(BufferPool::<u8>::new(8));
    let mut acc = FrameAccumulator::with_pool(1 << 16, Arc::clone(&pool));
    let frame = |fill: u8| {
        let body = vec![fill; 64];
        let mut f = (body.len() as u32).to_le_bytes().to_vec();
        f.extend_from_slice(&body);
        f
    };
    for round in 0..3u8 {
        acc.extend(&frame(round + 1));
        let body = acc.try_frame().unwrap().expect("one whole frame buffered");
        assert_eq!(body, &vec![round + 1; 64][..]);
        drop(body); // parks the buffer back in the pool
    }
    let s = pool.stats();
    assert_eq!(s.gets, 3);
    assert!(s.recycled >= 2, "rounds 2 and 3 must re-use round 1's buffer: {s:?}");
}
