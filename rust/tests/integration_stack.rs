//! Whole-stack integration: artifacts → runtime → service → BLIS →
//! coordinator, cross-checked between backends at every boundary.

use parallella_blas::blis::{level3, GemmTask, Trans};
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::{Request, ServerConfig};
use parallella_blas::linalg::{max_scaled_err, Mat};
use parallella_blas::prelude::*;
use std::sync::Arc;

fn oracle(
    ta: Trans,
    tb: Trans,
    alpha: f64,
    a: &Mat<f32>,
    b: &Mat<f32>,
    beta: f64,
    c0: &Mat<f32>,
) -> Mat<f64> {
    let a64 = a.cast::<f64>();
    let b64 = b.cast::<f64>();
    let mut c = c0.cast::<f64>();
    level3::gemm_host(ta, tb, alpha, a64.view(), b64.view(), beta, &mut c);
    c
}

// Cross-checking the two offload backends needs a pjrt-featured build
// with `make artifacts` output on disk.
#[cfg(feature = "pjrt")]
#[test]
fn simulator_and_pjrt_agree_across_shapes() {
    let sim = Platform::builder().backend(BackendKind::Simulator).build().unwrap();
    let pjrt = Platform::builder().backend(BackendKind::Pjrt).build().unwrap();
    let shapes = [(192, 256, 64, 1u64), (100, 300, 130, 2), (400, 100, 257, 3), (64, 64, 1, 4)];
    for (m, n, k, seed) in shapes {
        let a = Mat::<f32>::randn(m, k, seed);
        let b = Mat::<f32>::randn(k, n, seed + 10);
        let c0 = Mat::<f32>::randn(m, n, seed + 20);
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        sim.blas().sgemm(Trans::N, Trans::N, 1.5, a.view(), b.view(), -0.5, &mut c1).unwrap();
        pjrt.blas().sgemm(Trans::N, Trans::N, 1.5, a.view(), b.view(), -0.5, &mut c2).unwrap();
        let cross = max_scaled_err(c1.view(), c2.view());
        assert!(cross < 2e-6, "{m}x{n}x{k}: sim vs pjrt err {cross}");
        let want = oracle(Trans::N, Trans::N, 1.5, &a, &b, -0.5, &c0);
        assert!(max_scaled_err(c1.view(), want.view()) < 1e-5, "{m}x{n}x{k} vs oracle");
    }
}

#[test]
fn transpose_variants_through_full_stack() {
    let plat = Platform::builder().backend(BackendKind::Simulator).build().unwrap();
    let (m, n, k) = (250, 270, 90);
    for ta in Trans::all() {
        for tb in Trans::all() {
            let a =
                if ta.is_trans() { Mat::<f32>::randn(k, m, 5) } else { Mat::<f32>::randn(m, k, 5) };
            let b =
                if tb.is_trans() { Mat::<f32>::randn(n, k, 6) } else { Mat::<f32>::randn(k, n, 6) };
            let c0 = Mat::<f32>::randn(m, n, 7);
            let mut c = c0.clone();
            plat.blas().sgemm(ta, tb, 2.0, a.view(), b.view(), 1.0, &mut c).unwrap();
            let want = oracle(ta, tb, 2.0, &a, &b, 1.0, &c0);
            let e = max_scaled_err(c.view(), want.view());
            assert!(e < 1e-5, "{}{}: {e}", ta.code(), tb.code());
        }
    }
}

#[test]
fn tcp_stack_serves_false_dgemm() {
    let srv = BlasServer::start(ServerConfig::default()).unwrap();
    let mut cli = BlasClient::connect(srv.addr()).unwrap();
    let (m, n, k) = (96usize, 80usize, 64usize);
    let a = Mat::<f64>::randn(m, k, 8);
    let b = Mat::<f64>::randn(k, n, 9);
    let resp = cli
        .call(&Request::dgemm(
            Trans::N,
            Trans::N,
            m,
            n,
            k,
            1.0,
            0.0,
            a.as_slice().to_vec(),
            b.as_slice().to_vec(),
            vec![0.0; m * n],
        ))
        .unwrap();
    let got = Mat::from_col_major(m, n, &resp.into_f64().unwrap());
    let mut want = Mat::<f64>::zeros(m, n);
    level3::gemm_host(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut want);
    let e = max_scaled_err(got.view(), want.view());
    // f32-sized error through the f64 wire type: the "false" in false dgemm
    // must be visible end to end.
    assert!(e > 1e-12 && e < 1e-4, "err {e}");
}

#[test]
fn beta_semantics_preserved_through_stack() {
    // beta=0 must ignore (not propagate NaN from) C, like reference BLAS.
    let plat = Platform::builder().backend(BackendKind::Simulator).build().unwrap();
    let (m, n, k) = (192, 256, 64);
    let a = Mat::<f32>::randn(m, k, 10);
    let b = Mat::<f32>::randn(k, n, 11);
    let mut c = Mat::<f32>::full(m, n, f32::NAN);
    plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).unwrap();
    assert!(
        c.as_slice().iter().all(|v| v.is_finite()),
        "beta=0 must overwrite, not propagate NaN"
    );
}

#[test]
fn alpha_zero_is_pure_scale() {
    let plat = Platform::builder().backend(BackendKind::Simulator).build().unwrap();
    let (m, n, k) = (192, 256, 128);
    let a = Mat::<f32>::randn(m, k, 12);
    let b = Mat::<f32>::randn(k, n, 13);
    let c0 = Mat::<f32>::randn(m, n, 14);
    let mut c = c0.clone();
    plat.blas().sgemm(Trans::N, Trans::N, 0.0, a.view(), b.view(), 2.0, &mut c).unwrap();
    for j in 0..n {
        for i in 0..m {
            assert!((c.get(i, j) - 2.0 * c0.get(i, j)).abs() < 1e-4);
        }
    }
}

#[test]
fn async_submit_overlaps_two_gemms() {
    // The §3.2 service process, pipelined: two gemm tasks are submitted
    // back-to-back *before* either is waited on, so the second task's
    // packing overlaps the first task's in-flight µ-kernel batches (the
    // per-call HH-RAM exchange serializes inside the service handle).
    let plat = Platform::builder().backend(BackendKind::Simulator).build().unwrap();
    let blas = plat.blas_handle();
    let (m, n, k) = (200, 300, 96);
    let a1 = Mat::<f32>::randn(m, k, 30);
    let b1 = Mat::<f32>::randn(k, n, 31);
    let a2 = Mat::<f32>::randn(m, k, 32);
    let b2 = Mat::<f32>::randn(k, n, 33);

    let t1 = Arc::clone(&blas).submit(GemmTask {
        ta: Trans::N,
        tb: Trans::N,
        alpha: 1.0f32,
        a: a1.clone(),
        b: b1.clone(),
        beta: 0.0,
        c: Mat::zeros(m, n),
    });
    let t2 = Arc::clone(&blas).submit(GemmTask {
        ta: Trans::N,
        tb: Trans::N,
        alpha: 1.0f32,
        a: a2.clone(),
        b: b2.clone(),
        beta: 0.0,
        c: Mat::zeros(m, n),
    });
    // Both tickets are in flight here; wait in reverse submission order to
    // prove completion does not depend on wait order.
    let (c2, rep2) = t2.wait().unwrap();
    let (c1, rep1) = t1.wait().unwrap();
    assert!(rep1.calls >= 1 && rep2.calls >= 1);

    for (a, b, c) in [(&a1, &b1, &c1), (&a2, &b2, &c2)] {
        let mut want = Mat::<f64>::zeros(m, n);
        level3::gemm_host(
            Trans::N,
            Trans::N,
            1.0,
            a.cast::<f64>().view(),
            b.cast::<f64>().view(),
            0.0,
            &mut want,
        );
        assert!(max_scaled_err(c.view(), want.view()) < 1e-5);
    }
}
