//! f32-vs-f64 conformance suite: every classic shim on [`BlasLibrary`]
//! must match a naive f64 reference within a precision-scaled tolerance,
//! against both the functional `Simulator` and the naive `HostRef`
//! service backends.
//!
//! Tolerances scale with machine epsilon of the *compute* precision:
//! f32 routines and both gemms (dgemm is the paper's "false dgemm" — f64
//! API, f32 Epiphany compute) get f32-scaled bounds; the true-f64 host
//! routines get f64-scaled bounds, which would catch any accidental
//! downcast on those paths.

use parallella_blas::blis::{Blas, BlasLibrary, Trans};
use parallella_blas::epiphany::kernel::KernelGeometry;
use parallella_blas::epiphany::timing::CalibratedModel;
use parallella_blas::host::service::{ServiceBackend, ServiceHandle};
use parallella_blas::linalg::Mat;
use parallella_blas::platform::Platform;
use parallella_blas::workloads::{
    solve_refined, Factorization, GemmBatchItem, GemmBatchOp, RefinePolicy,
};
use std::sync::Arc;

fn lib(backend: ServiceBackend) -> BlasLibrary {
    let svc =
        ServiceHandle::spawn(backend, CalibratedModel::default(), KernelGeometry::paper()).unwrap();
    BlasLibrary::new(Arc::new(Blas::new(svc)))
}

const BACKENDS: [ServiceBackend; 2] = [ServiceBackend::Simulator, ServiceBackend::HostRef];

/// Precision-scaled tolerance: `eps · n · 32` (generous slack for
/// accumulation order differences, still orders of magnitude below the
/// other precision's epsilon).
fn tol(eps: f64, n: usize) -> f64 {
    eps * (n.max(1) as f64) * 32.0
}

fn assert_close(got: f64, want: f64, t: f64, what: &str) {
    let scale = want.abs().max(1.0);
    assert!((got - want).abs() <= t * scale, "{what}: got {got}, want {want} (tol {t:.3e})");
}

// ---------------------------------------------------------------------------
// level 1
// ---------------------------------------------------------------------------

/// One level-1 sweep in precision `$t`, via the `$prefix`-named shims.
macro_rules! level1_conformance {
    ($lib:expr, $t:ty, $eps:expr, $axpy:ident, $scal:ident, $copy:ident, $swap:ident,
     $dot:ident, $nrm2:ident, $asum:ident, $iamax:ident) => {{
        let lib = $lib;
        let n = 48usize;
        let x: Vec<$t> = (0..n).map(|i| ((i * 7 % 13) as $t) / 13.0 - 0.4).collect();
        let y0: Vec<$t> = (0..n).map(|i| ((i * 5 % 11) as $t) / 11.0 - 0.6).collect();
        let alpha: $t = 1.25;
        let t = tol($eps, n);

        // axpy
        let mut y = y0.clone();
        lib.$axpy(n, alpha, &x, 1, &mut y, 1);
        for i in 0..n {
            let want = alpha as f64 * x[i] as f64 + y0[i] as f64;
            assert_close(y[i] as f64, want, t, "axpy");
        }
        // scal
        let mut xs = x.clone();
        lib.$scal(n, alpha, &mut xs, 1);
        for i in 0..n {
            assert_close(xs[i] as f64, alpha as f64 * x[i] as f64, t, "scal");
        }
        // copy + swap
        let mut dst = vec![0.0 as $t; n];
        lib.$copy(n, &x, 1, &mut dst, 1);
        assert_eq!(dst, x, "copy must be exact");
        let mut a = x.clone();
        let mut b = y0.clone();
        lib.$swap(n, &mut a, 1, &mut b, 1);
        assert_eq!((a, b), (y0.clone(), x.clone()), "swap must be exact");
        // dot
        let got = lib.$dot(n, &x, 1, &y0, 1) as f64;
        let want: f64 = (0..n).map(|i| x[i] as f64 * y0[i] as f64).sum();
        assert_close(got, want, t, "dot");
        // nrm2
        let got = lib.$nrm2(n, &x, 1) as f64;
        let want = (0..n).map(|i| (x[i] as f64).powi(2)).sum::<f64>().sqrt();
        assert_close(got, want, t, "nrm2");
        // asum
        let got = lib.$asum(n, &x, 1) as f64;
        let want: f64 = (0..n).map(|i| (x[i] as f64).abs()).sum();
        assert_close(got, want, t, "asum");
        // iamax (exact, first index on ties)
        let mut want = 0usize;
        for i in 1..n {
            if x[i].abs() > x[want].abs() {
                want = i;
            }
        }
        assert_eq!(lib.$iamax(n, &x, 1), Some(want), "iamax");
        // strided variants agree with the dense ones
        let xs2: Vec<$t> = x.iter().flat_map(|&v| [v, -99.0]).collect();
        let got = lib.$dot(n, &xs2, 2, &y0, 1) as f64;
        let want: f64 = (0..n).map(|i| x[i] as f64 * y0[i] as f64).sum();
        assert_close(got, want, t, "strided dot");
    }};
}

#[test]
fn level1_f32_conformance() {
    for backend in BACKENDS {
        level1_conformance!(
            lib(backend),
            f32,
            f32::EPSILON as f64,
            saxpy,
            sscal,
            scopy,
            sswap,
            sdot,
            snrm2,
            sasum,
            isamax
        );
    }
}

#[test]
fn level1_f64_conformance() {
    for backend in BACKENDS {
        level1_conformance!(
            lib(backend),
            f64,
            f64::EPSILON,
            daxpy,
            dscal,
            dcopy,
            dswap,
            ddot,
            dnrm2,
            dasum,
            idamax
        );
    }
}

#[test]
fn srot_conformance() {
    for backend in BACKENDS {
        let lib = lib(backend);
        let n = 16usize;
        let x0: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let y0: Vec<f32> = (0..n).map(|i| (i as f32).cos()).collect();
        let (c, s) = (0.6f32, 0.8f32);
        let mut x = x0.clone();
        let mut y = y0.clone();
        lib.srot(n, &mut x, 1, &mut y, 1, c, s);
        let t = tol(f32::EPSILON as f64, n);
        for i in 0..n {
            let wx = c as f64 * x0[i] as f64 + s as f64 * y0[i] as f64;
            let wy = c as f64 * y0[i] as f64 - s as f64 * x0[i] as f64;
            assert_close(x[i] as f64, wx, t, "rot x");
            assert_close(y[i] as f64, wy, t, "rot y");
        }
    }
}

// ---------------------------------------------------------------------------
// level 2
// ---------------------------------------------------------------------------

/// gemv/ger/trsv conformance in precision `$t` via the `$prefix` shims.
macro_rules! level2_conformance {
    ($lib:expr, $t:ty, $eps:expr, $gemv:ident, $ger:ident, $trsv:ident) => {{
        let lib = $lib;
        let (m, n) = (24usize, 17usize);
        let a: Vec<$t> =
            (0..m * n).map(|i| ((i * 31 % 23) as $t) / 23.0 - 0.5).collect();
        let x: Vec<$t> = (0..n).map(|i| ((i * 3 % 7) as $t) / 7.0 - 0.3).collect();
        let y0: Vec<$t> = (0..m).map(|i| ((i * 11 % 5) as $t) / 5.0).collect();
        let t = tol($eps, m.max(n));

        // gemv N, unit strides
        let mut y = y0.clone();
        lib.$gemv(Trans::N, m, n, 2.0, &a, m, &x, 1, 0.5, &mut y, 1);
        for i in 0..m {
            let mut acc = 0.0f64;
            for j in 0..n {
                acc += a[i + j * m] as f64 * x[j] as f64;
            }
            let want = 2.0 * acc + 0.5 * y0[i] as f64;
            assert_close(y[i] as f64, want, t, "gemv N");
        }
        // gemv T with strided x and y
        let xt: Vec<$t> = (0..m).map(|i| ((i * 13 % 9) as $t) / 9.0 - 0.4).collect();
        let xt_strided: Vec<$t> = xt.iter().flat_map(|&v| [v, 77.0]).collect();
        let mut yt = vec![0.0 as $t; 3 * n];
        lib.$gemv(Trans::T, m, n, 1.0, &a, m, &xt_strided, 2, 0.0, &mut yt, 3);
        for j in 0..n {
            let mut want = 0.0f64;
            for i in 0..m {
                want += a[i + j * m] as f64 * xt[i] as f64;
            }
            assert_close(yt[3 * j] as f64, want, t, "gemv T strided");
        }
        // ger
        let mut g = a.clone();
        lib.$ger(m, n, 1.5, &xt, &x, &mut g, m);
        for j in 0..n {
            for i in 0..m {
                let want = a[i + j * m] as f64 + 1.5 * xt[i] as f64 * x[j] as f64;
                assert_close(g[i + j * m] as f64, want, t, "ger");
            }
        }
        // trsv against a well-conditioned lower-triangular system
        let nn = 12usize;
        let mut tri = vec![0.0 as $t; nn * nn];
        for j in 0..nn {
            for i in j..nn {
                tri[i + j * nn] =
                    if i == j { 3.0 + j as $t } else { 0.25 / (1.0 + (i - j) as $t) };
            }
        }
        let b: Vec<$t> = (0..nn).map(|i| ((i % 4) as $t) - 1.5).collect();
        let mut xs = b.clone();
        lib.$trsv(true, Trans::N, false, nn, &tri, nn, &mut xs);
        // residual check: tri · xs == b
        for i in 0..nn {
            let mut acc = 0.0f64;
            for j in 0..=i {
                acc += tri[i + j * nn] as f64 * xs[j] as f64;
            }
            assert_close(acc, b[i] as f64, tol($eps, nn) * 4.0, "trsv residual");
        }
    }};
}

#[test]
fn level2_f32_conformance() {
    for backend in BACKENDS {
        level2_conformance!(lib(backend), f32, f32::EPSILON as f64, sgemv, sger, strsv);
    }
}

#[test]
fn level2_f64_conformance() {
    for backend in BACKENDS {
        level2_conformance!(lib(backend), f64, f64::EPSILON, dgemv, dger, dtrsv);
    }
}

#[test]
fn strmv_conformance() {
    for backend in BACKENDS {
        let lib = lib(backend);
        let n = 10usize;
        let mut a = vec![0.0f32; n * n];
        for j in 0..n {
            for i in j..n {
                a[i + j * n] = 1.0 + ((i + 2 * j) % 5) as f32 * 0.3;
            }
        }
        let x0: Vec<f32> = (0..n).map(|i| (i as f32) * 0.25 - 1.0).collect();
        let mut x = x0.clone();
        lib.strmv(true, Trans::N, false, n, &a, n, &mut x);
        let t = tol(f32::EPSILON as f64, n);
        for i in 0..n {
            let mut want = 0.0f64;
            for j in 0..=i {
                want += a[i + j * n] as f64 * x0[j] as f64;
            }
            assert_close(x[i] as f64, want, t, "trmv");
        }
    }
}

// ---------------------------------------------------------------------------
// level 3
// ---------------------------------------------------------------------------

fn naive_gemm_f64(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    b: &[f64],
) -> Vec<f64> {
    // a, b in stored col-major orientation.
    let get_a = |i: usize, l: usize| if ta.is_trans() { a[l + i * k] } else { a[i + l * m] };
    let get_b = |l: usize, j: usize| if tb.is_trans() { b[j + l * n] } else { b[l + j * k] };
    let mut c = vec![0.0f64; m * n];
    for j in 0..n {
        for i in 0..m {
            let mut acc = 0.0;
            for l in 0..k {
                acc += get_a(i, l) * get_b(l, j);
            }
            c[i + j * m] = acc;
        }
    }
    c
}

#[test]
fn sgemm_conformance_both_backends() {
    for backend in BACKENDS {
        let lib = lib(backend);
        let (m, n, k) = (64usize, 48usize, 32usize);
        for (ta, tb) in [(Trans::N, Trans::N), (Trans::T, Trans::N), (Trans::N, Trans::T)] {
            let (ar, ac) = if ta.is_trans() { (k, m) } else { (m, k) };
            let (br, bc) = if tb.is_trans() { (n, k) } else { (k, n) };
            let a = Mat::<f32>::randn(ar, ac, 40);
            let b = Mat::<f32>::randn(br, bc, 41);
            let mut c = vec![0.0f32; m * n];
            lib.sgemm(ta, tb, m, n, k, 1.0, a.as_slice(), ar, b.as_slice(), br, 0.0, &mut c, m)
                .unwrap();
            let a64: Vec<f64> = a.as_slice().iter().map(|&v| v as f64).collect();
            let b64: Vec<f64> = b.as_slice().iter().map(|&v| v as f64).collect();
            let want = naive_gemm_f64(ta, tb, m, n, k, &a64, &b64);
            let t = tol(f32::EPSILON as f64, k);
            for i in 0..m * n {
                assert_close(c[i] as f64, want[i], t, "sgemm");
            }
        }
    }
}

#[test]
fn dgemm_conformance_is_f32_class_both_backends() {
    for backend in BACKENDS {
        let lib = lib(backend);
        let (m, n, k) = (48usize, 40usize, 36usize);
        let a = Mat::<f64>::randn(m, k, 50);
        let b = Mat::<f64>::randn(k, n, 51);
        let mut c = vec![0.0f64; m * n];
        lib.dgemm(Trans::N, Trans::N, m, n, k, 1.0, a.as_slice(), m, b.as_slice(), k, 0.0,
            &mut c, m)
            .unwrap();
        let want = naive_gemm_f64(Trans::N, Trans::N, m, n, k, a.as_slice(), b.as_slice());
        // f32-scaled tolerance passes ...
        let t32 = tol(f32::EPSILON as f64, k);
        let mut max_err = 0.0f64;
        for i in 0..m * n {
            assert_close(c[i], want[i], t32, "dgemm (false) f32-class");
            max_err = max_err.max((c[i] - want[i]).abs() / want[i].abs().max(1.0));
        }
        // ... and the error is visibly f32-sized, NOT true f64 (the
        // "false" in false dgemm must survive the shim rewrite).
        assert!(max_err > f64::EPSILON * 1e3, "dgemm unexpectedly exact: {max_err:.3e}");
    }
}

#[test]
fn dtrsm_dsyrk_conformance() {
    for backend in BACKENDS {
        let lib = lib(backend);
        // dtrsm: solve L·X = alpha·B, check residual in f64 precision.
        let (m, n) = (16usize, 9usize);
        let mut l = vec![0.0f64; m * m];
        for j in 0..m {
            for i in j..m {
                l[i + j * m] = if i == j { 2.0 + j as f64 } else { 0.3 / (1.0 + (i - j) as f64) };
            }
        }
        let b0 = Mat::<f64>::randn(m, n, 60);
        let mut b = b0.as_slice().to_vec();
        lib.dtrsm_left(true, Trans::N, false, m, n, 1.5, &l, m, &mut b, m);
        let t = tol(f64::EPSILON, m) * 4.0;
        for j in 0..n {
            for i in 0..m {
                let mut acc = 0.0;
                for p in 0..=i {
                    acc += l[i + p * m] * b[p + j * m];
                }
                assert_close(acc, 1.5 * b0.get(i, j), t, "dtrsm residual");
            }
        }
        // dsyrk: C ← A·Aᵀ (lower), true f64 host op.
        let (nn, k) = (12usize, 7usize);
        let a = Mat::<f64>::randn(nn, k, 61);
        let mut c = vec![0.0f64; nn * nn];
        lib.dsyrk_lower(Trans::N, nn, k, 1.0, a.as_slice(), nn, 0.0, &mut c, nn);
        let t = tol(f64::EPSILON, k);
        for j in 0..nn {
            for i in j..nn {
                let mut want = 0.0;
                for p in 0..k {
                    want += a.get(i, p) * a.get(j, p);
                }
                assert_close(c[i + j * nn], want, t, "dsyrk");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// workloads: batched gemm + refined solve
// ---------------------------------------------------------------------------

/// Reference solve: Gaussian elimination with partial pivoting, every
/// operation in true f64 (no accelerated path anywhere).
fn naive_solve_f64(a0: &Mat<f64>, b0: &[f64]) -> Vec<f64> {
    let n = a0.rows();
    let mut a: Vec<f64> = a0.as_slice().to_vec();
    let mut b = b0.to_vec();
    for j in 0..n {
        let p = (j..n).max_by(|&x, &y| {
            a[x + j * n].abs().partial_cmp(&a[y + j * n].abs()).unwrap()
        });
        let p = p.unwrap();
        if p != j {
            for l in 0..n {
                a.swap(j + l * n, p + l * n);
            }
            b.swap(j, p);
        }
        for i in j + 1..n {
            let f = a[i + j * n] / a[j + j * n];
            for l in j..n {
                a[i + l * n] -= f * a[j + l * n];
            }
            b[i] -= f * b[j];
        }
    }
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut acc = b[i];
        for l in i + 1..n {
            acc -= a[i + l * n] * x[l];
        }
        x[i] = acc / a[i + i * n];
    }
    x
}

#[test]
fn gemm_batch_conformance_pools_1_and_4() {
    for chips in [1usize, 4] {
        let plat = Platform::builder().chips(chips).build().unwrap();
        let (m, n, k) = (32usize, 24usize, 16usize);
        let items = || -> Vec<GemmBatchItem<f32>> {
            (0..4)
                .map(|i| {
                    let seed = 80 + i as u64 * 5;
                    GemmBatchItem {
                        ta: Trans::N,
                        tb: Trans::N,
                        alpha: 1.5,
                        a: Mat::<f32>::randn(m, k, seed),
                        b: Mat::<f32>::randn(k, n, seed + 1),
                        beta: -0.25,
                        c: Mat::<f32>::randn(m, n, seed + 2),
                    }
                })
                .collect()
        };
        let (got, rep) = plat.blas().execute(GemmBatchOp { items: items() }).unwrap();
        assert_eq!(rep.items, 4);
        let t = tol(f32::EPSILON as f64, k);
        for (i, it) in items().into_iter().enumerate() {
            // Bit-identical to a loop of single gemms on the same pool …
            let mut c = it.c.clone();
            plat.blas()
                .gemm(it.ta, it.tb, it.alpha, it.a.view(), it.b.view(), it.beta, &mut c)
                .unwrap();
            assert_eq!(got[i].as_slice(), c.as_slice(), "item {i}, chips {chips}");
            // … and within f32-scaled tolerance of the naive f64 oracle
            // (alpha/beta composed by hand around the plain product).
            let a64: Vec<f64> = it.a.as_slice().iter().map(|&v| v as f64).collect();
            let b64: Vec<f64> = it.b.as_slice().iter().map(|&v| v as f64).collect();
            let prod = naive_gemm_f64(it.ta, it.tb, m, n, k, &a64, &b64);
            for j in 0..m * n {
                let want = 1.5 * prod[j] - 0.25 * it.c.as_slice()[j] as f64;
                assert_close(got[i].as_slice()[j] as f64, want, t, "gemm batch");
            }
        }
    }
}

#[test]
fn refined_solve_conformance_pools_1_and_4() {
    let n = 48usize;
    // Diagonally dominant (well-conditioned) system, fixed entries.
    let mut a = Mat::<f64>::from_fn(n, n, |i, j| (((i * 7 + j * 3) % 13) as f64) / 13.0 - 0.4);
    for i in 0..n {
        let v = a.get(i, i) + n as f64;
        a.set(i, i, v);
    }
    let b: Vec<f64> = (0..n).map(|i| ((i % 9) as f64) / 9.0 - 0.5).collect();
    let want = naive_solve_f64(&a, &b);
    for chips in [1usize, 4] {
        let plat = Platform::builder().chips(chips).build().unwrap();
        let (x, rep) =
            solve_refined(plat.blas(), &a, &b, Factorization::Lu, &RefinePolicy::default())
                .unwrap();
        // The refined solution must agree with the all-f64 reference far
        // beyond f32 accuracy — that is the whole point of refinement.
        for i in 0..n {
            assert_close(x[i], want[i], 1e-9, "refined solve");
        }
        assert!(rep.final_residual() <= 16.0, "chips {chips}: {:?}", rep.residuals);
    }
}

// ---------------------------------------------------------------------------
// host µ-kernel variants
// ---------------------------------------------------------------------------

#[test]
fn ukr_variant_conformance_sweep() {
    // Every compiled-in host µ-kernel variant (scalar / blocked / SSE
    // under `--features simd`) over ragged shapes, all transpose pairs
    // and α,β combinations: f64-oracle accuracy plus bitwise agreement
    // with the scalar oracle. The sweep panics on the first divergence.
    let cases = parallella_blas::blis::testsuite::ukr_conformance_sweep();
    assert!(cases >= 6 * 16 * 5 * 2, "sweep ran {cases} cases");
}
