//! Whole-stack tests of the multi-chip sharded backend: a `ChipPool(N)`
//! must be **bit-identical** to the single-chip backend (same panels,
//! same µ-kernel math — only the jc column ranges move between chips),
//! shards must actually spread across the pool, and the coordinator's
//! per-chip scheduling (least-loaded + wire shard hints) must stay
//! correct under concurrent clients.

use parallella_blas::blis::level3::gemm_host;
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::{Request, Response, ServerConfig};
use parallella_blas::linalg::max_scaled_err;
use parallella_blas::prelude::*;

fn oracle(ta: Trans, tb: Trans, a: &Mat<f32>, b: &Mat<f32>, c0: &Mat<f32>) -> Mat<f64> {
    let op_a = if ta.is_trans() { a.transposed() } else { a.clone() };
    let op_b = if tb.is_trans() { b.transposed() } else { b.clone() };
    let mut want = Mat::<f64>::zeros(op_a.rows(), op_b.cols());
    gemm_host(
        Trans::N,
        Trans::N,
        1.5,
        op_a.cast::<f64>().view(),
        op_b.cast::<f64>().view(),
        0.0,
        &mut want,
    );
    for j in 0..want.cols() {
        for i in 0..want.rows() {
            let v = want.get(i, j) - 0.5 * c0.get(i, j) as f64;
            want.set(i, j, v);
        }
    }
    want
}

#[test]
fn pool_sizes_agree_bitwise_and_with_reference() {
    // 900 columns = 4 jc tiles: pools of 1, 2, 3 and 4 chips cover every
    // plan shape (even split, ragged split, more tiles than chips).
    let (m, n, k) = (200, 900, 96);
    let plats: Vec<Platform> =
        (1..=4).map(|chips| Platform::builder().chips(chips).build().unwrap()).collect();
    for (ta, tb) in [(Trans::N, Trans::N), (Trans::T, Trans::N), (Trans::N, Trans::T)] {
        let a = if ta.is_trans() { Mat::<f32>::randn(k, m, 1) } else { Mat::<f32>::randn(m, k, 1) };
        let b = if tb.is_trans() { Mat::<f32>::randn(n, k, 2) } else { Mat::<f32>::randn(k, n, 2) };
        let c0 = Mat::<f32>::randn(m, n, 3);
        let want = oracle(ta, tb, &a, &b, &c0);
        let mut results = Vec::new();
        for plat in &plats {
            let mut c = c0.clone();
            let rep = plat.blas().sgemm(ta, tb, 1.5, a.view(), b.view(), -0.5, &mut c).unwrap();
            assert_eq!(rep.calls, 8, "2 ic × 4 jc tiles");
            let e = max_scaled_err(c.view(), want.view());
            assert!(e < 1e-5, "chips={} {}{} err {e}", plat.chips(), ta.code(), tb.code());
            results.push(c);
        }
        for (i, c) in results.iter().enumerate().skip(1) {
            assert_eq!(
                results[0].as_slice(),
                c.as_slice(),
                "ChipPool({}) diverged from single chip on {}{}",
                i + 1,
                ta.code(),
                tb.code()
            );
        }
    }
}

#[test]
fn false_dgemm_shards_bitwise_too() {
    let (m, n, k) = (192, 600, 64); // 3 jc tiles
    let a = Mat::<f64>::randn(m, k, 10);
    let b = Mat::<f64>::randn(k, n, 11);
    let c0 = Mat::<f64>::randn(m, n, 12);
    let p1 = Platform::builder().build().unwrap();
    let p3 = Platform::builder().chips(3).build().unwrap();
    let mut c_single = c0.clone();
    let mut c_pooled = c0.clone();
    p1.blas().dgemm_false(Trans::N, Trans::N, 1.0, a.view(), b.view(), 1.0, &mut c_single).unwrap();
    p3.blas().dgemm_false(Trans::N, Trans::N, 1.0, a.view(), b.view(), 1.0, &mut c_pooled).unwrap();
    assert_eq!(c_single.as_slice(), c_pooled.as_slice());
}

#[test]
fn shards_spread_and_report_aggregates() {
    let plat = Platform::builder().chips(4).build().unwrap();
    let (m, n, k) = (192, 1024, 64); // exactly 4 jc tiles, one per chip
    let a = Mat::<f32>::randn(m, k, 20);
    let b = Mat::<f32>::randn(k, n, 21);
    let mut c = Mat::<f32>::zeros(m, n);
    let rep = plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).unwrap();
    assert_eq!(rep.calls, 4);
    assert_eq!(rep.chips, 4);
    assert!(rep.projected_s > 0.0 && rep.wall_s > 0.0);
    assert_eq!(plat.blas().pool().crossings(), vec![1, 1, 1, 1]);
}

#[test]
fn sharded_server_concurrent_clients_with_and_without_hints() {
    let srv = BlasServer::start(ServerConfig { chips: 4, ..Default::default() }).unwrap();
    let addr = srv.addr();
    let mut handles = Vec::new();
    for t in 0..4i64 {
        handles.push(std::thread::spawn(move || {
            let mut cli = BlasClient::connect(addr).unwrap();
            for i in 0..3i64 {
                let (m, n, k) = (32, 16, 24);
                let a = Mat::<f32>::randn(m, k, (t * 100 + i) as u64);
                let b = Mat::<f32>::randn(k, n, (t * 100 + i + 1) as u64);
                let mut req = Request::sgemm(
                    Trans::N,
                    Trans::N,
                    m,
                    n,
                    k,
                    1.0,
                    0.0,
                    a.as_slice().to_vec(),
                    b.as_slice().to_vec(),
                    vec![0.0; m * n],
                );
                if i % 2 == 0 {
                    // Half the traffic pins a chip, half lets the router
                    // pick the least-loaded queue.
                    req = req.with_shard_hint(t as usize);
                }
                let out = Mat::from_col_major(m, n, &cli.call(&req).unwrap().into_f32().unwrap());
                let mut want = Mat::<f64>::zeros(m, n);
                gemm_host(
                    Trans::N,
                    Trans::N,
                    1.0,
                    a.cast::<f64>().view(),
                    b.cast::<f64>().view(),
                    0.0,
                    &mut want,
                );
                assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(srv.metrics.requests() >= 12);
    // Stats must expose the per-chip execution labels.
    let mut cli = BlasClient::connect(addr).unwrap();
    match cli.call(&Request::Stats).unwrap() {
        Response::Stats(s) => {
            assert!(s.gemms_on(0) + s.gemms_on(1) + s.gemms_on(2) >= 1, "{s}");
            assert!(s.to_string().contains("chip0_gemms="), "{s}");
        }
        other => panic!("{other:?}"),
    }
}
