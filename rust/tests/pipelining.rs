//! Pipelined coordinator integration: wire v1 and v2 clients
//! interoperate against one server, and a deep in-flight window returns
//! results out of order that are bit-identical to serial execution.

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::{
    Pending, Request, Response, ServerConfig, PROTOCOL_V1, PROTOCOL_V2,
};
use parallella_blas::linalg::Mat;

/// A deterministic sgemm request keyed by seed.
fn gemm_req(seed: u64) -> Request {
    let (m, n, k) = (48, 32, 40);
    let a = Mat::<f32>::randn(m, k, seed);
    let b = Mat::<f32>::randn(k, n, seed + 1);
    Request::sgemm(
        Trans::N,
        Trans::N,
        m,
        n,
        k,
        1.0,
        0.0,
        a.as_slice().to_vec(),
        b.as_slice().to_vec(),
        vec![0.0; m * n],
    )
}

#[test]
fn v1_and_v2_clients_interoperate_on_one_server() {
    let srv = BlasServer::start(ServerConfig::default()).unwrap();
    let mut v1 = BlasClient::connect(srv.addr()).unwrap();
    let mut v2 = BlasClient::connect_v2(srv.addr()).unwrap();
    assert_eq!(v1.version(), PROTOCOL_V1);
    assert_eq!(v2.version(), PROTOCOL_V2);
    // Interleaved traffic from both wire versions, same answers.
    for seed in 0..3u64 {
        let req = gemm_req(seed * 10);
        let r1 = v1.call(&req).unwrap().into_f32().unwrap();
        let r2 = v2.submit(&req).unwrap().wait().unwrap().into_f32().unwrap();
        assert_eq!(r1, r2, "v1 and v2 disagree on seed {seed}");
    }
    // Both sessions stay healthy for control traffic.
    match v2.call(&Request::Stats).unwrap() {
        Response::Stats(s) => assert!(s.requests >= 6, "{s}"),
        other => panic!("{other:?}"),
    }
    match v1.call(&Request::Ping).unwrap() {
        Response::OkText(s) => assert_eq!(s, "pong"),
        other => panic!("{other:?}"),
    }
}

#[test]
fn eight_in_flight_gemms_complete_out_of_order_bit_identical() {
    let srv = BlasServer::start(ServerConfig { chips: 2, ..Default::default() }).unwrap();

    // Serial reference over wire v1.
    let mut serial = BlasClient::connect(srv.addr()).unwrap();
    let want: Vec<Vec<f32>> = (0..8u64)
        .map(|i| serial.call(&gemm_req(100 + i)).unwrap().into_f32().unwrap())
        .collect();

    // The same 8 requests in flight at once on ONE v2 connection.
    let mut cli = BlasClient::connect_v2(srv.addr()).unwrap();
    let mut pendings: Vec<Option<Pending>> =
        (0..8u64).map(|i| Some(cli.submit(&gemm_req(100 + i)).unwrap())).collect();

    // Claim in shuffled order: the correlation id must route each
    // response to its own ticket no matter the wait order, and every
    // result must match its serial run bit for bit.
    let mut cids = std::collections::HashSet::new();
    for &i in &[5usize, 2, 7, 0, 6, 3, 1, 4] {
        let p = pendings[i].take().unwrap();
        assert!(cids.insert(p.correlation_id()), "correlation id reused");
        let got = p.wait().unwrap().into_f32().unwrap();
        assert_eq!(got, want[i], "request {i} got another ticket's payload");
    }
    cli.drain().unwrap();
}

#[test]
fn dropped_tickets_do_not_desync_the_session() {
    let srv = BlasServer::start(ServerConfig::default()).unwrap();
    let mut cli = BlasClient::connect_v2(srv.addr()).unwrap();
    let p1 = cli.submit(&Request::Ping).unwrap();
    let _ = cli.submit(&gemm_req(7)).unwrap(); // ticket dropped immediately
    drop(p1);
    // drain() reads both abandoned responses off the socket...
    cli.drain().unwrap();
    // ...so the session is still framed correctly afterwards.
    match cli.call(&Request::Ping).unwrap() {
        Response::OkText(s) => assert_eq!(s, "pong"),
        other => panic!("{other:?}"),
    }
}
