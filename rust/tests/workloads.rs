//! Acceptance suite for the workloads subsystem (batched small gemm +
//! mixed-precision iterative refinement), exercised **over the wire**
//! against live servers:
//!
//! * a `GemmBatch` frame answers bit-identically to the same items sent
//!   as single `Gemm` frames — on chip pools of 1 and 4, with the
//!   packed-A panel cache off and on, unhinted and pinned;
//! * iterative refinement reaches a residual no worse than a direct
//!   solve with the f32-contaminated false-dgemm factorization (which
//!   fails the HPL criterion on its own — refinement is what buys the
//!   pass), locally and through the `Solve` opcode;
//! * divergence and iteration exhaustion surface as *typed* errors
//!   in-process, and singular input comes back as a wire error naming
//!   the cause.

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::{GemmWire, Request, Response, ServerConfig};
use parallella_blas::hpl::residual::hpl_residual;
use parallella_blas::hpl::{lu_factor_blocked, lu_solve};
use parallella_blas::linalg::{Mat, XorShiftRng};
use parallella_blas::platform::Platform;
use parallella_blas::workloads::{solve_refined, Factorization, RefineError, RefinePolicy};

/// `count` f32 items with varied α/β; even items share one A operand so
/// a panel-cache build gets real hits across the batch.
fn batch_items(count: usize, m: usize, n: usize, k: usize) -> Vec<GemmWire> {
    (0..count)
        .map(|i| {
            let seed = 700 + i as u64 * 3;
            let a_seed = if i % 2 == 0 { 700 } else { seed };
            GemmWire::f32(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.25,
                -0.5,
                Mat::<f32>::randn(m, k, a_seed).as_slice().to_vec(),
                Mat::<f32>::randn(k, n, seed + 1).as_slice().to_vec(),
                Mat::<f32>::randn(m, n, seed + 2).as_slice().to_vec(),
            )
        })
        .collect()
}

/// A well-conditioned (diagonally dominant) f64 system of order `n`.
fn dominant_system(n: usize, seed: u64) -> (Mat<f64>, Vec<f64>) {
    let mut rng = XorShiftRng::new(seed);
    let mut a = Mat::<f64>::from_fn(n, n, |_, _| rng.next_unit());
    for i in 0..n {
        let v = a.get(i, i) + n as f64;
        a.set(i, i, v);
    }
    let b = (0..n).map(|_| rng.next_unit()).collect();
    (a, b)
}

#[test]
fn gemm_batch_over_wire_bit_identical_pools_1_and_4_cache_off_and_on() {
    for chips in [1usize, 4] {
        for cache_bytes in [0usize, 16 << 20] {
            let srv = BlasServer::start(ServerConfig {
                chips,
                panel_cache_bytes: cache_bytes,
                ..Default::default()
            })
            .unwrap();
            let mut cli = BlasClient::connect(srv.addr()).unwrap();
            let items = batch_items(5, 48, 36, 24);
            // Reference: the identical items as five single Gemm frames.
            let mut want = Vec::new();
            for g in &items {
                want.extend(cli.call(&Request::Gemm(g.clone())).unwrap().into_f32().unwrap());
            }
            // The batch must answer with the same bytes, fanned
            // least-loaded and pinned alike.
            for hint in [None, Some(chips - 1)] {
                let mut req = Request::gemm_batch(items.clone());
                if let Some(chip) = hint {
                    req = req.with_shard_hint(chip);
                }
                let got = cli.call(&req).unwrap().into_f32().unwrap();
                assert_eq!(
                    got, want,
                    "batch diverged from single gemms (chips={chips}, \
                     cache={cache_bytes}, hint={hint:?})"
                );
            }
            // Both batches landed in the per-opcode accounting bucket.
            match cli.call(&Request::Stats).unwrap() {
                Response::Stats(s) => {
                    assert_eq!(s.batch_requests, 2, "chips={chips} cache={cache_bytes}");
                    assert!(s.batch_p99_s > 0.0, "{s}");
                }
                other => panic!("{other:?}"),
            }
        }
    }
}

#[test]
fn refined_solve_no_worse_than_direct_false_dgemm_solve() {
    let plat = Platform::builder().build().unwrap();
    let n = 96;
    let (a, b) = dominant_system(n, 9);

    // Direct: factor in the f32-class false-dgemm path, solve, stop.
    let mut af = a.clone();
    let (pivots, _) = lu_factor_blocked(plat.blas(), &mut af, 32).unwrap();
    let x_direct = lu_solve(&af, &pivots, &b);
    let direct = hpl_residual(&a, &x_direct, &b);

    let policy = RefinePolicy::default();
    let (x, rep) = solve_refined(plat.blas(), &a, &b, Factorization::Lu, &policy).unwrap();
    let refined = hpl_residual(&a, &x, &b);

    assert!(
        refined.hpl_scaled <= direct.hpl_scaled,
        "refined {} must be no worse than direct {}",
        refined.hpl_scaled,
        direct.hpl_scaled
    );
    assert!(refined.hpl_scaled <= policy.tolerance, "HPL pass: {}", refined.hpl_scaled);
    // The comparison is only meaningful because the unrefined solve
    // actually fails the criterion (the factorization is f32-class).
    assert!(direct.hpl_scaled > policy.tolerance, "direct {} vacuous", direct.hpl_scaled);
    assert!(rep.iters >= 1 && rep.final_residual() <= policy.tolerance);
}

#[test]
fn cholesky_refinement_holds_on_a_4_chip_pool() {
    let plat = Platform::builder().chips(4).build().unwrap();
    let n = 80;
    // SPD by construction: M·Mᵀ + n·I.
    let m = Mat::<f64>::randn(n, n, 12);
    let mut a = Mat::<f64>::zeros(n, n);
    for j in 0..n {
        for i in 0..n {
            let mut acc = if i == j { n as f64 } else { 0.0 };
            for p in 0..n {
                acc += m.get(i, p) * m.get(j, p);
            }
            a.set(i, j, acc);
        }
    }
    let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
    let policy = RefinePolicy::default();
    let (x, _) = solve_refined(plat.blas(), &a, &b, Factorization::Cholesky, &policy).unwrap();
    let r = hpl_residual(&a, &x, &b);
    assert!(r.hpl_scaled <= policy.tolerance, "scaled residual {}", r.hpl_scaled);
}

#[test]
fn solve_over_wire_reaches_hpl_pass_and_counts() {
    let srv = BlasServer::start(ServerConfig { chips: 2, ..Default::default() }).unwrap();
    let mut cli = BlasClient::connect_v2(srv.addr()).unwrap();
    let n = 64;
    let (a, b) = dominant_system(n, 21);
    // Zero nb/max_iters and non-positive tolerance pick server defaults.
    let req =
        Request::solve(Factorization::Lu, n, 0, 0, 0.0, a.as_slice().to_vec(), b.clone());
    let x = cli.call(&req).unwrap().into_f64().unwrap();
    let r = hpl_residual(&a, &x, &b);
    assert!(r.hpl_scaled <= 16.0, "wire solve residual {}", r.hpl_scaled);
    match cli.call(&Request::Stats).unwrap() {
        Response::Stats(s) => {
            assert_eq!(s.solve_requests, 1, "{s}");
            assert!(s.solve_p99_s > 0.0, "{s}");
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn refinement_failure_modes_are_typed_errors() {
    let plat = Platform::builder().build().unwrap();
    let (a, b) = dominant_system(24, 33);

    // An unreachable tolerance with a zero divergence budget must trip
    // the divergence bail-out on the very first refinement step.
    let diverge = RefinePolicy { tolerance: 0.0, divergence_factor: 0.0, ..Default::default() };
    let err = solve_refined(plat.blas(), &a, &b, Factorization::Lu, &diverge).unwrap_err();
    match err.downcast_ref::<RefineError>() {
        Some(RefineError::Diverged { iter, .. }) => assert_eq!(*iter, 1),
        other => panic!("expected Diverged, got {other:?} ({err:#})"),
    }

    // The same tolerance with an infinite divergence budget runs the
    // iteration allowance dry instead.
    let exhaust = RefinePolicy {
        tolerance: 0.0,
        divergence_factor: f64::INFINITY,
        max_iters: 2,
        ..Default::default()
    };
    let err = solve_refined(plat.blas(), &a, &b, Factorization::Lu, &exhaust).unwrap_err();
    match err.downcast_ref::<RefineError>() {
        Some(RefineError::DidNotConverge { iters, .. }) => assert_eq!(*iters, 2),
        other => panic!("expected DidNotConverge, got {other:?} ({err:#})"),
    }
}

#[test]
fn singular_input_reports_cause_over_the_wire() {
    let srv = BlasServer::start(ServerConfig::default()).unwrap();
    let mut cli = BlasClient::connect(srv.addr()).unwrap();
    let n = 16;
    // Rank-1 dyadic u·vᵀ: exactly singular, so the factorization (not
    // the refinement loop) is what must report.
    let u: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
    let v: Vec<f64> = (0..n).map(|i| 2.0 - i as f64 / 8.0).collect();
    let a = Mat::<f64>::from_fn(n, n, |i, j| u[i] * v[j]);
    let b = vec![1.0; n];
    let req = Request::solve(Factorization::Lu, n, 0, 0, 0.0, a.as_slice().to_vec(), b);
    match cli.call(&req).unwrap() {
        Response::Err(e) => assert!(e.contains("singular"), "unhelpful error: {e}"),
        other => panic!("singular solve must be a wire error, got {other:?}"),
    }
}
