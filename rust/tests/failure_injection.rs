//! Failure injection: the stack must degrade loudly, not silently.
//!
//! Covers the failure modes the paper's architecture is shaped around
//! (eSDK re-init instability, memory-map overflow) plus operational ones
//! (malformed network frames, mid-stream disconnects, bogus shapes).

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::protocol::{read_frame, Request, Response};
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::ServerConfig;
use parallella_blas::epiphany::kernel::KernelGeometry;
use parallella_blas::epiphany::timing::CalibratedModel;
use parallella_blas::epiphany::Chip;
use parallella_blas::esdk::{EHal, MAX_REINIT};
use parallella_blas::linalg::Mat;
use std::io::Write;

#[test]
fn esdk_reinit_instability_reproduced_and_cured() {
    // Reproduce: per-call init/finalize dies after MAX_REINIT (the bug the
    // paper hit when the BLAS process re-initialized per µ-kernel call).
    let mut hal = EHal::new(CalibratedModel::default());
    for i in 0..MAX_REINIT {
        hal.e_init(KernelGeometry::paper()).unwrap_or_else(|e| panic!("init {i}: {e}"));
        hal.e_finalize().unwrap();
    }
    assert!(hal.e_init(KernelGeometry::paper()).is_err(), "must fail after {MAX_REINIT} re-inits");

    // Cure: the resident service does one init for arbitrarily many calls
    // (service tests prove > MAX_REINIT calls; here prove one hal instance
    // stays open across many tasks).
    let mut hal = EHal::new(CalibratedModel::default());
    hal.e_init(KernelGeometry::paper()).unwrap();
    let g = KernelGeometry::paper();
    let a = vec![0.5f32; g.m * g.ksub];
    let b = vec![0.25f32; g.ksub * g.n];
    for t in 0..MAX_REINIT * 2 {
        hal.e_write_a(t & 1, &a).unwrap();
        hal.e_write_b(t & 1, &b).unwrap();
        hal.e_signal_task(parallella_blas::epiphany::kernel::Command::ClearSend, t & 1).unwrap();
    }
    hal.e_finalize().unwrap();
}

#[test]
fn local_memory_overflow_is_a_boot_error() {
    // Geometry beyond the Fig-3 budget must fail at Chip::new, not corrupt.
    for bad in [
        KernelGeometry { m: 192, n: 256, ksub: 128, nsub: 4 },
        KernelGeometry { m: 384, n: 256, ksub: 64, nsub: 4 },
        KernelGeometry { m: 192, n: 512, ksub: 64, nsub: 4 },
    ] {
        let err = match Chip::new(CalibratedModel::default(), bad) {
            Err(e) => e,
            Ok(_) => panic!("{bad:?} must not fit"),
        };
        assert!(format!("{err:#}").contains("overflow"), "{bad:?}: {err:#}");
    }
}

#[test]
fn invalid_geometry_rejected_with_reason() {
    let cases = [
        (KernelGeometry { m: 100, n: 256, ksub: 64, nsub: 4 }, "multiple of 32"),
        (KernelGeometry { m: 192, n: 250, ksub: 64, nsub: 4 }, "CORES*NSUB"),
        (KernelGeometry { m: 192, n: 256, ksub: 60, nsub: 4 }, "divide evenly"),
    ];
    for (geom, needle) in cases {
        let err = geom.validate().unwrap_err();
        assert!(format!("{err:#}").contains(needle), "{geom:?}: {err:#}");
    }
}

#[test]
fn server_survives_malformed_and_oversized_frames() {
    let srv = BlasServer::start(ServerConfig::default()).unwrap();
    // 1. Garbage opcode.
    {
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        cli.stream_mut().write_all(&4u32.to_le_bytes()).unwrap();
        cli.stream_mut().write_all(&[200u8, 0, 0, 0]).unwrap();
        let body = read_frame(cli.stream_mut()).unwrap();
        assert!(matches!(Response::decode(&body).unwrap(), Response::Err(_)));
    }
    // 2. Mid-frame disconnect: open, write half a frame, drop.
    {
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        cli.stream_mut().write_all(&100u32.to_le_bytes()).unwrap();
        cli.stream_mut().write_all(&[1u8, 2, 3]).unwrap();
        drop(cli);
    }
    // 3. Hostile 4 GiB length prefix: refused before any allocation, with
    //    an error response, then the connection is dropped (no resync is
    //    possible once framing is corrupt).
    {
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        cli.stream_mut().write_all(&u32::MAX.to_le_bytes()).unwrap();
        let body = read_frame(cli.stream_mut()).unwrap();
        match Response::decode(&body).unwrap() {
            Response::Err(e) => assert!(e.contains("frame length"), "{e}"),
            other => panic!("{other:?}"),
        }
        assert!(read_frame(cli.stream_mut()).is_err(), "server must drop the connection");
    }
    // 4. Read-side failures were counted, not swallowed (mid-frame
    //    disconnect + hostile prefix). The disconnect lands on another
    //    thread, so poll briefly.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while srv.metrics.io_errors() < 2 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(srv.metrics.io_errors() >= 2, "io_errors = {}", srv.metrics.io_errors());
    // 5. Server still serves new clients correctly afterwards.
    let mut cli = BlasClient::connect(srv.addr()).unwrap();
    match cli.call(&Request::Ping).unwrap() {
        Response::OkText(s) => assert_eq!(s, "pong"),
        other => panic!("{other:?}"),
    }
}

#[test]
fn shape_lies_in_header_are_errors_not_ub() {
    // Header says k=8 but payload sized for k=4: decode must reject.
    let srv = BlasServer::start(ServerConfig::default()).unwrap();
    let mut cli = BlasClient::connect(srv.addr()).unwrap();
    let good = Request::sgemm(
        Trans::N,
        Trans::N,
        4,
        4,
        4,
        1.0,
        0.0,
        vec![0.0; 16],
        vec![0.0; 16],
        vec![0.0; 16],
    );
    let mut frame = good.encode();
    // Corrupt the k field (offset: 4 len + 3 header + 2 trans + 8 m,n = 17).
    frame[17..21].copy_from_slice(&8u32.to_le_bytes());
    cli.stream_mut().write_all(&frame).unwrap();
    let body = read_frame(cli.stream_mut()).unwrap();
    assert!(matches!(Response::decode(&body).unwrap(), Response::Err(_)));
}

#[test]
fn hpl_singular_input_reported() {
    let plat = parallella_blas::platform::Platform::builder()
        .backend(parallella_blas::platform::BackendKind::Simulator)
        .build()
        .unwrap();
    // Exactly rank-1: A[i][j] = u[i]·v[j] with u a power of two and v a
    // small integer. Every elimination quantity is then exact in f64
    // (the multipliers are power-of-two ratios, the products small
    // integers), so column 1's tail reduces to exactly 0.0 and the zero
    // pivot fires deterministically — no rounding escape hatch, no
    // conditional assert.
    let n = 64;
    let mut a = Mat::<f64>::zeros(n, n);
    for j in 0..n {
        for i in 0..n {
            let u = (1u32 << (i % 5)) as f64;
            let v = (1 + j % 7) as f64;
            a.set(i, j, u * v);
        }
    }
    let err =
        parallella_blas::hpl::lu::lu_factor_blocked(plat.blas(), &mut a, 32).unwrap_err();
    assert!(format!("{err:#}").contains("singular"), "{err:#}");
}

#[test]
fn chip_death_mid_stream_is_survived() {
    // The ISSUE's acceptance scenario: one chip of a 4-chip pool dies
    // mid-stream. Every ticket must still complete, the rescued results
    // must be bit-identical to a healthy run, the stats report must show
    // the unhealthy chip and the requeue counter, and the coordinator
    // must keep serving new connections.
    let srv = BlasServer::start(ServerConfig { chips: 4, ..Default::default() }).unwrap();
    let blas = srv.blas_handle();
    let (m, n, k) = (32, 16, 24);
    let reqs: Vec<Request> = (0..12)
        .map(|i| {
            let a = Mat::<f32>::randn(m, k, 300 + i);
            let b = Mat::<f32>::randn(k, n, 400 + i);
            Request::sgemm(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.0,
                0.0,
                a.as_slice().to_vec(),
                b.as_slice().to_vec(),
                vec![0.0; m * n],
            )
        })
        .collect();
    // Healthy pass first: the bit-identity reference (every chip of the
    // pool computes the same simulator dataflow, so which chip rescues a
    // job must not change a single bit).
    let mut cli = BlasClient::connect_v2(srv.addr()).unwrap();
    let healthy: Vec<Vec<f32>> =
        reqs.iter().map(|r| cli.call(r).unwrap().into_f32().unwrap()).collect();
    // Kill chip 2: every service call on it now fails. Pin the whole
    // pipelined stream at it — the first group dies mid-execution, the
    // batcher wounds the chip, requeues, and later submissions degrade
    // to healthy chips.
    blas.pool().chip(2).fail_next_calls(usize::MAX);
    let pending: Vec<_> = reqs
        .iter()
        .map(|r| cli.submit(&r.clone().with_shard_hint(2)).unwrap())
        .collect();
    // Zero lost tickets: every wait returns, and with a rescued (not
    // errored) result.
    let rescued: Vec<Vec<f32>> =
        pending.into_iter().map(|p| p.wait().unwrap().into_f32().unwrap()).collect();
    assert_eq!(rescued, healthy, "rescued results must be bit-identical");
    // The report names the wounded chip and counts the rescues.
    match cli.call(&Request::Stats).unwrap() {
        Response::Stats(s) => {
            assert!(!s.healthy_on(2), "{s}");
            assert_eq!(s.unhealthy_chips(), 1, "{s}");
            assert!(s.requeued >= 1, "{s}");
            assert!(s.to_string().contains("chip2_healthy=0"), "{s}");
        }
        other => panic!("{other:?}"),
    }
    // The coordinator keeps serving brand-new connections.
    let mut cli2 = BlasClient::connect_v2(srv.addr()).unwrap();
    let again = cli2.call(&reqs[0]).unwrap().into_f32().unwrap();
    assert_eq!(again, healthy[0]);
    // Probe recovery: clear the fault, ping the chip back in.
    blas.pool().chip(2).clear_faults();
    blas.pool().probe(2).unwrap();
    match cli2.call(&Request::Stats).unwrap() {
        Response::Stats(s) => assert_eq!(s.unhealthy_chips(), 0, "{s}"),
        other => panic!("{other:?}"),
    }
}

#[test]
fn failed_submit_leaves_no_phantom_ticket() {
    // Regression: a submit whose frame never reached the wire used to
    // register its correlation id anyway, so drain() waited forever for
    // a response that could not exist.
    let srv = BlasServer::start(ServerConfig::default()).unwrap();
    let mut cli = BlasClient::connect_v2(srv.addr()).unwrap();
    match cli.call(&Request::Ping).unwrap() {
        Response::OkText(s) => assert_eq!(s, "pong"),
        other => panic!("{other:?}"),
    }
    // Kill the write half mid-session: the next submit cannot be sent.
    cli.stream_mut().shutdown(std::net::Shutdown::Write).unwrap();
    assert!(cli.submit(&Request::Ping).is_err(), "write on a dead socket must error");
    // No phantom cid: nothing is in flight, so drain returns at once.
    cli.drain().unwrap();
}

#[test]
fn telemetry_frame_captured_for_ci() {
    // Capture one pushed telemetry frame to disk; CI validates it with
    // `python3 -m json.tool` (the frame is hand-rendered JSON — prove it
    // parses outside this crate, not just that our own asserts like it).
    let srv = BlasServer::start(ServerConfig {
        chips: 2,
        telemetry_period_ms: 20,
        ..Default::default()
    })
    .unwrap();
    let cli = BlasClient::connect_v2(srv.addr()).unwrap();
    let mut stream = cli.subscribe().unwrap();
    let frame = stream.next_frame().unwrap();
    assert!(frame.starts_with('{') && frame.ends_with('}'), "{frame}");
    assert!(frame.contains("\"type\":\"telemetry\""), "{frame}");
    std::fs::create_dir_all("target").unwrap();
    std::fs::write("target/telemetry-frame.json", &frame).unwrap();
}

#[test]
fn zero_sized_problems_handled() {
    let plat = parallella_blas::platform::Platform::builder()
        .backend(parallella_blas::platform::BackendKind::Simulator)
        .build()
        .unwrap();
    // K = 0: C = beta·C, no service crossing required to be correct.
    let (m, n) = (8, 8);
    let a = Mat::<f32>::zeros(m, 0);
    let b = Mat::<f32>::zeros(0, n);
    let mut c = Mat::<f32>::full(m, n, 3.0);
    plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.5, &mut c).unwrap();
    for j in 0..n {
        for i in 0..m {
            assert!((c.get(i, j) - 1.5).abs() < 1e-6);
        }
    }
}
