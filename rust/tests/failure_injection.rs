//! Failure injection: the stack must degrade loudly, not silently.
//!
//! Covers the failure modes the paper's architecture is shaped around
//! (eSDK re-init instability, memory-map overflow) plus operational ones
//! (malformed network frames, mid-stream disconnects, bogus shapes).

use parallella_blas::blis::Trans;
use parallella_blas::coordinator::protocol::{read_frame, Request, Response};
use parallella_blas::coordinator::server::{BlasClient, BlasServer};
use parallella_blas::coordinator::ServerConfig;
use parallella_blas::epiphany::kernel::KernelGeometry;
use parallella_blas::epiphany::timing::CalibratedModel;
use parallella_blas::epiphany::Chip;
use parallella_blas::esdk::{EHal, MAX_REINIT};
use parallella_blas::linalg::Mat;
use std::io::Write;

#[test]
fn esdk_reinit_instability_reproduced_and_cured() {
    // Reproduce: per-call init/finalize dies after MAX_REINIT (the bug the
    // paper hit when the BLAS process re-initialized per µ-kernel call).
    let mut hal = EHal::new(CalibratedModel::default());
    for i in 0..MAX_REINIT {
        hal.e_init(KernelGeometry::paper()).unwrap_or_else(|e| panic!("init {i}: {e}"));
        hal.e_finalize().unwrap();
    }
    assert!(hal.e_init(KernelGeometry::paper()).is_err(), "must fail after {MAX_REINIT} re-inits");

    // Cure: the resident service does one init for arbitrarily many calls
    // (service tests prove > MAX_REINIT calls; here prove one hal instance
    // stays open across many tasks).
    let mut hal = EHal::new(CalibratedModel::default());
    hal.e_init(KernelGeometry::paper()).unwrap();
    let g = KernelGeometry::paper();
    let a = vec![0.5f32; g.m * g.ksub];
    let b = vec![0.25f32; g.ksub * g.n];
    for t in 0..MAX_REINIT * 2 {
        hal.e_write_a(t & 1, &a).unwrap();
        hal.e_write_b(t & 1, &b).unwrap();
        hal.e_signal_task(parallella_blas::epiphany::kernel::Command::ClearSend, t & 1).unwrap();
    }
    hal.e_finalize().unwrap();
}

#[test]
fn local_memory_overflow_is_a_boot_error() {
    // Geometry beyond the Fig-3 budget must fail at Chip::new, not corrupt.
    for bad in [
        KernelGeometry { m: 192, n: 256, ksub: 128, nsub: 4 },
        KernelGeometry { m: 384, n: 256, ksub: 64, nsub: 4 },
        KernelGeometry { m: 192, n: 512, ksub: 64, nsub: 4 },
    ] {
        let err = match Chip::new(CalibratedModel::default(), bad) {
            Err(e) => e,
            Ok(_) => panic!("{bad:?} must not fit"),
        };
        assert!(format!("{err:#}").contains("overflow"), "{bad:?}: {err:#}");
    }
}

#[test]
fn invalid_geometry_rejected_with_reason() {
    let cases = [
        (KernelGeometry { m: 100, n: 256, ksub: 64, nsub: 4 }, "multiple of 32"),
        (KernelGeometry { m: 192, n: 250, ksub: 64, nsub: 4 }, "CORES*NSUB"),
        (KernelGeometry { m: 192, n: 256, ksub: 60, nsub: 4 }, "divide evenly"),
    ];
    for (geom, needle) in cases {
        let err = geom.validate().unwrap_err();
        assert!(format!("{err:#}").contains(needle), "{geom:?}: {err:#}");
    }
}

#[test]
fn server_survives_malformed_and_oversized_frames() {
    let srv = BlasServer::start(ServerConfig::default()).unwrap();
    // 1. Garbage opcode.
    {
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        cli.stream_mut().write_all(&4u32.to_le_bytes()).unwrap();
        cli.stream_mut().write_all(&[200u8, 0, 0, 0]).unwrap();
        let body = read_frame(cli.stream_mut()).unwrap();
        assert!(matches!(Response::decode(&body).unwrap(), Response::Err(_)));
    }
    // 2. Mid-frame disconnect: open, write half a frame, drop.
    {
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        cli.stream_mut().write_all(&100u32.to_le_bytes()).unwrap();
        cli.stream_mut().write_all(&[1u8, 2, 3]).unwrap();
        drop(cli);
    }
    // 3. Hostile 4 GiB length prefix: refused before any allocation, with
    //    an error response, then the connection is dropped (no resync is
    //    possible once framing is corrupt).
    {
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        cli.stream_mut().write_all(&u32::MAX.to_le_bytes()).unwrap();
        let body = read_frame(cli.stream_mut()).unwrap();
        match Response::decode(&body).unwrap() {
            Response::Err(e) => assert!(e.contains("frame length"), "{e}"),
            other => panic!("{other:?}"),
        }
        assert!(read_frame(cli.stream_mut()).is_err(), "server must drop the connection");
    }
    // 4. Read-side failures were counted, not swallowed (mid-frame
    //    disconnect + hostile prefix). The disconnect lands on another
    //    thread, so poll briefly.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while srv.metrics.io_errors() < 2 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(srv.metrics.io_errors() >= 2, "io_errors = {}", srv.metrics.io_errors());
    // 5. Server still serves new clients correctly afterwards.
    let mut cli = BlasClient::connect(srv.addr()).unwrap();
    match cli.call(&Request::Ping).unwrap() {
        Response::OkText(s) => assert_eq!(s, "pong"),
        other => panic!("{other:?}"),
    }
}

#[test]
fn shape_lies_in_header_are_errors_not_ub() {
    // Header says k=8 but payload sized for k=4: decode must reject.
    let srv = BlasServer::start(ServerConfig::default()).unwrap();
    let mut cli = BlasClient::connect(srv.addr()).unwrap();
    let good = Request::sgemm(
        Trans::N,
        Trans::N,
        4,
        4,
        4,
        1.0,
        0.0,
        vec![0.0; 16],
        vec![0.0; 16],
        vec![0.0; 16],
    );
    let mut frame = good.encode();
    // Corrupt the k field (offset: 4 len + 3 header + 2 trans + 8 m,n = 17).
    frame[17..21].copy_from_slice(&8u32.to_le_bytes());
    cli.stream_mut().write_all(&frame).unwrap();
    let body = read_frame(cli.stream_mut()).unwrap();
    assert!(matches!(Response::decode(&body).unwrap(), Response::Err(_)));
}

#[test]
fn hpl_singular_input_reported() {
    let plat = parallella_blas::platform::Platform::builder()
        .backend(parallella_blas::platform::BackendKind::Simulator)
        .build()
        .unwrap();
    // Rank-deficient matrix: column 3 duplicated.
    let n = 64;
    let mut a = Mat::<f64>::randn(n, n, 9);
    for i in 0..n {
        let v = a.get(i, 3);
        a.set(i, 7, v);
    }
    let err = parallella_blas::hpl::lu::lu_factor_blocked(plat.blas(), &mut a, 32);
    // Exactly singular after elimination → error; f64 rounding may let it
    // squeak through as near-singular, in which case pivots stay finite.
    if let Err(e) = err {
        assert!(format!("{e:#}").contains("singular"));
    }
}

#[test]
fn zero_sized_problems_handled() {
    let plat = parallella_blas::platform::Platform::builder()
        .backend(parallella_blas::platform::BackendKind::Simulator)
        .build()
        .unwrap();
    // K = 0: C = beta·C, no service crossing required to be correct.
    let (m, n) = (8, 8);
    let a = Mat::<f32>::zeros(m, 0);
    let b = Mat::<f32>::zeros(0, n);
    let mut c = Mat::<f32>::full(m, n, 3.0);
    plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.5, &mut c).unwrap();
    for j in 0..n {
        for i in 0..m {
            assert!((c.get(i, j) - 1.5).abs() < 1e-6);
        }
    }
}
