//! Reproduction of every table in the paper's evaluation (§4).
//!
//! Each `tableN()` regenerates the corresponding paper table: it runs the
//! real code path (numerics verified on this machine) and reports the
//! paper's value next to the calibrated-model *projection* for the
//! Parallella and the wall-clock on this host. Absolute agreement is
//! expected only for projections; the *shape* criteria are in DESIGN.md §5.
//!
//! Sizing: the paper's full sizes (4096³, N=4608) are used for projections
//! (analytic — free), while the executed-numerics part can be scaled down
//! via [`ExperimentScale`] so the suite also runs in CI time
//! (`BENCH_FULL=1` forces paper sizes).

pub mod tables;

pub use tables::*;

/// How big the executed (wall-clock) runs are.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExperimentScale {
    /// Paper sizes everywhere (minutes of runtime).
    Full,
    /// Reduced executed sizes; projections still at paper size.
    Quick,
}

impl ExperimentScale {
    /// `Full` when `BENCH_FULL=1` is set, `Quick` otherwise.
    pub fn from_env() -> Self {
        if std::env::var("BENCH_FULL").ok().as_deref() == Some("1") {
            ExperimentScale::Full
        } else {
            ExperimentScale::Quick
        }
    }
}
