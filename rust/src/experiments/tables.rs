//! One function per paper table. See module docs for the
//! projected-vs-executed split.

use super::ExperimentScale;
use crate::blis::testsuite::{run_false_dgemm_case, run_sgemm_case, sweep_all_variants};
use crate::blis::{Blas, Trans};
use crate::epiphany::timing::{CalibratedModel, WalkClass};
use crate::esdk::EHal;
use crate::host::microkernel::{host_ref_sgemm, InnerMicroKernel, UkrBackend};
use crate::host::projection::{project_host_ref, project_ukr_call, ProjectionParams};
use crate::host::service::{ServiceBackend, ServiceHandle};
use crate::hpl::driver::{run_hpl, HplConfig};
use crate::linalg::{max_abs, Mat};
use crate::util::tables::{gf, sci, secs, Table};
use anyhow::Result;

/// A named paper-vs-ours comparison, asserted by tests and printed by
/// benches.
#[derive(Clone, Debug)]
pub struct Check {
    /// What is being compared (a table row/cell label).
    pub name: String,
    /// The paper's reported value.
    pub paper: f64,
    /// This reproduction's value.
    pub ours: f64,
}

impl Check {
    /// ours / paper — 1.0 is a perfect reproduction.
    pub fn ratio(&self) -> f64 {
        self.ours / self.paper
    }
}

/// Output of one table reproduction.
pub struct TableResult {
    /// The rendered table text (what the CLI prints).
    pub rendered: String,
    /// The paper-vs-ours comparisons the tests assert on.
    pub checks: Vec<Check>,
    /// Measured scalar-vs-vectorized host µ-kernel trajectory (tables
    /// 3–6). Wall clock on the current machine, so the bench comparator
    /// treats these cells as report-only; the deterministic [`Check`]s
    /// above stay the regression gate.
    pub ukr: Option<Table>,
}

impl TableResult {
    /// Machine-readable JSON for the bench artifacts
    /// (`BENCH_<name>.json` at the repo root): the rendered text plus
    /// every paper-vs-ours check with its ratio.
    pub fn to_json(&self, name: &str) -> String {
        let num = |v: f64| if v.is_finite() { format!("{v}") } else { "null".to_string() };
        let checks: Vec<String> = self
            .checks
            .iter()
            .map(|c| {
                format!(
                    "{{\"name\":{},\"paper\":{},\"ours\":{},\"ratio\":{}}}",
                    crate::util::tables::json_string(&c.name),
                    num(c.paper),
                    num(c.ours),
                    num(c.ratio())
                )
            })
            .collect();
        let ukr = match &self.ukr {
            Some(t) => format!(",\"ukr\":{}", t.to_json()),
            None => String::new(),
        };
        format!(
            "{{\"table\":{},\"rendered\":{},\"checks\":[{}]{ukr}}}",
            crate::util::tables::json_string(name),
            crate::util::tables::json_string(&self.rendered),
            checks.join(",")
        )
    }
}

/// Measure the host µ-kernel variants (scalar triple loop vs the
/// unroll-and-jam / SSE paths, see [`crate::host::microkernel`]) on one
/// kernel-shaped tile and tabulate wall time, GFLOPS and speedup vs
/// scalar. Outputs are asserted bit-identical across variants before any
/// number is reported. Appended to Tables 3–6 as the perf-trajectory
/// block the roadmap tracks.
pub fn ukr_trajectory(m: usize, n: usize, k: usize) -> Table {
    use crate::host::microkernel::{host_sgemm_variant, UkrVariant};
    let fill = |len: usize, scale: f32| -> Vec<f32> {
        (0..len).map(|i| ((i % 13) as f32 - 6.0) * scale).collect()
    };
    let a = fill(m * k, 0.01);
    let b = fill(k * n, 0.02);
    let c = vec![0.0f32; m * n];
    let time = |v: UkrVariant| {
        let mut best = f64::INFINITY;
        let mut out = Vec::new();
        for _ in 0..3 {
            let (o, s) =
                crate::util::timed(|| host_sgemm_variant(v, m, n, k, 1.0, &a, &b, 0.0, &c));
            out = o;
            best = best.min(s);
        }
        (out, best)
    };
    let (want, scalar_s) = time(UkrVariant::Scalar);
    let mut t = Table::new(
        &format!("host µ-kernel trajectory @ {m}x{n}x{k} (wall clock, this machine)"),
        &["variant", "wall (s)", "GFLOPS", "speedup"],
    );
    for v in UkrVariant::all() {
        if !v.available() {
            continue;
        }
        let (got, s) = if v == UkrVariant::Scalar { (want.clone(), scalar_s) } else { time(v) };
        assert!(got == want, "{} diverged from the scalar oracle", v.name());
        t.row(&[
            v.name().into(),
            secs(s),
            gf(crate::util::gemm_gflops(m, n, k, s)),
            format!("{:.2}x", scalar_s / s),
        ]);
    }
    t
}

fn blas(backend: ServiceBackend) -> Result<Blas> {
    Ok(Blas::new(ServiceHandle::spawn(
        backend,
        CalibratedModel::default(),
        crate::epiphany::kernel::KernelGeometry::paper(),
    )?))
}

/// Analytic projection of a full BLIS gemm at paper scale: tile calls ×
/// per-call projection.
pub fn analytic_blis_gemm_s(
    model: &CalibratedModel,
    m: usize,
    n: usize,
    k: usize,
    class_a: WalkClass,
    class_b: WalkClass,
    dgemm: bool,
) -> f64 {
    let calls = m.div_ceil(192) * n.div_ceil(256);
    let mut p = ProjectionParams::kernel_service(k);
    p.class_a = class_a;
    p.class_b = class_b;
    p.blis = true;
    p.dgemm = dgemm;
    calls as f64 * project_ukr_call(model, &p).total_s
}

/// Analytic projection of the HPL run (paper Table 7 parameters).
pub fn hpl_projection_s(model: &CalibratedModel, n: usize, nb: usize) -> f64 {
    let mut total = 0.0f64;
    let steps = n.div_ceil(nb);
    for s in 0..steps {
        let j0 = s * nb;
        let jb = nb.min(n - j0);
        let rows = (n - j0) as f64;
        // Panel factorization at the host level-2 rate.
        total += rows * (jb * jb) as f64 / (model.host_level2_f64_gflops * 1e9);
        let rest = n - (j0 + jb);
        if rest > 0 {
            // trsm at the host rate.
            total += (jb * jb * rest) as f64 / (model.host_trsm_f64_gflops * 1e9);
            // Trailing update through the false dgemm (L21 is col-major ⇒
            // contig A walk; U12 feeds the row-major panel ⇒ strided B walk).
            total += analytic_blis_gemm_s(
                model,
                rest,
                rest,
                jb,
                WalkClass::Contig,
                WalkClass::StridedB,
                true,
            );
        }
    }
    // Forward/backward solve.
    total += 2.0 * (n * n) as f64 / (model.host_level2_f64_gflops * 1e9);
    total
}

/// Table 1: custom test, kernel called from the same process
/// (M=192, N=256, K=4096).
pub fn table1(scale: ExperimentScale) -> Result<TableResult> {
    let model = CalibratedModel::default();
    let p = ProjectionParams::kernel_same_process(4096);
    let proj = project_ukr_call(&model, &p);
    let href_s = project_host_ref(&model, 192, 256, 4096);

    // Executed numerics: functional simulator at K (full = paper's 4096).
    let k_exec = if scale == ExperimentScale::Full { 4096 } else { 1024 };
    let a = Mat::<f32>::randn(192, k_exec, 11);
    let b = Mat::<f32>::randn(k_exec, 256, 12);
    let b_rm = {
        let mut v = vec![0.0f32; k_exec * 256];
        for l in 0..k_exec {
            for j in 0..256 {
                v[l * 256 + j] = b.get(l, j);
            }
        }
        v
    };
    let c = Mat::<f32>::zeros(192, 256);
    let mut ukr = InnerMicroKernel::new(
        UkrBackend::Simulator(EHal::new(model.clone())),
        model.clone(),
        crate::epiphany::kernel::KernelGeometry::paper(),
    )?;
    let out = ukr.sgemm(1.0, a.as_slice(), &b_rm, 0.0, c.as_slice(), p)?;
    // Error vs the f64 oracle (the paper's error rows).
    let mut want = Mat::<f64>::zeros(192, 256);
    crate::blis::level3::gemm_host(
        Trans::N,
        Trans::N,
        1.0,
        a.cast::<f64>().view(),
        b.cast::<f64>().view(),
        0.0,
        &mut want,
    );
    let got = Mat::from_col_major(192, 256, &out.c);
    // Scale-normalized errors (|diff| / max|want|): per-element relative
    // error is meaningless on the near-zero entries of a random-operand
    // product; the paper's operands evidently avoided that.
    let scale = max_abs(want.view());
    let (mut sum_err, mut max_err) = (0.0f64, 0.0f64);
    for j in 0..256 {
        for i in 0..192 {
            let d = (got.get(i, j) as f64 - want.get(i, j)).abs() / scale;
            sum_err += d;
            max_err = max_err.max(d);
        }
    }
    let mean_err = sum_err / (192.0 * 256.0);

    // Wall-clock of the naive host reference at the executed size.
    let k_href = k_exec.min(512);
    let (_, href_wall) = crate::util::timed(|| {
        host_ref_sgemm(
            192,
            256,
            k_href,
            1.0,
            &a.as_slice()[..192 * k_href],
            &b_rm[..k_href * 256],
            0.0,
            c.as_slice(),
        )
    });

    let mut t = Table::new(
        "Table 1 — sgemm kernel, same process (M=192, N=256, K=4096)",
        &["Description", "paper (s)", "projected (s)", "ratio"],
    );
    let r = |a: f64, b: f64| format!("{:.3}", b / a);
    #[rustfmt::skip]
    {
        t.row(&["Host reference code".into(), secs(3.778169), secs(href_s), r(3.778169, href_s)]);
        t.row(&["Input loading + preprocessing".into(), secs(0.094648), secs(proj.input_s), r(0.094648, proj.input_s)]);
        t.row(&["Coprocessor work".into(), secs(0.105652), secs(proj.coproc_s), r(0.105652, proj.coproc_s)]);
        t.row(&["Host retrieve + post-processing".into(), secs(0.005272), secs(proj.post_s), r(0.005272, proj.post_s)]);
        t.row(&["Total sgemm µ-kernel".into(), secs(0.114114), secs(proj.total_s), r(0.114114, proj.total_s)]);
    }
    let mut rendered = t.render();
    rendered.push_str(&format!(
        "GFLOPS: paper 3.529 | projected {} | host-ref paper 0.107 | projected {}\n\
         errors (executed @K={k_exec}, simulator): mean {} (paper 8.73e-8), \
         max {} (paper 5.83e-7)\n\
         host-ref wall-clock sample (K={}): {:.3}s on this machine\n",
        gf(proj.gflops(192, 256, 4096)),
        gf(2.0 * 192.0 * 256.0 * 4096.0 / href_s / 1e9),
        sci(mean_err),
        sci(max_err),
        k_href,
        href_wall,
    ));

    Ok(TableResult {
        rendered,
        ukr: None,
        checks: vec![
            Check { name: "t1.total_s".into(), paper: 0.114114, ours: proj.total_s },
            Check { name: "t1.input_s".into(), paper: 0.094648, ours: proj.input_s },
            Check { name: "t1.coproc_s".into(), paper: 0.105652, ours: proj.coproc_s },
            Check { name: "t1.gflops".into(), paper: 3.529, ours: proj.gflops(192, 256, 4096) },
            Check { name: "t1.hostref_s".into(), paper: 3.778169, ours: href_s },
            Check {
                name: "t1.mean_err_log10".into(),
                paper: (8.73e-8f64).log10(),
                ours: mean_err.max(1e-12).log10(),
            },
        ],
    })
}

/// Table 2: the kernel through the service process.
pub fn table2(scale: ExperimentScale) -> Result<TableResult> {
    let model = CalibratedModel::default();
    let proj = project_ukr_call(&model, &ProjectionParams::kernel_service(4096));

    // Executed: real service crossing at scaled K.
    let k_exec = if scale == ExperimentScale::Full { 4096 } else { 512 };
    let blas = blas(ServiceBackend::Simulator)?;
    let row = run_sgemm_case(&blas, Trans::N, Trans::N, 192, 256, k_exec, 21)?;

    let mut t = Table::new(
        "Table 2 — sgemm kernel via service process (M=192, N=256, K=4096)",
        &["Description", "paper", "projected", "ratio"],
    );
    let t2_gf = proj.gflops(192, 256, 4096);
    t.row(&[
        "Total sgemm µ-kernel (s)".into(),
        secs(0.158303),
        secs(proj.total_s),
        format!("{:.3}", proj.total_s / 0.158303),
    ]);
    t.row(&["GFLOPS/s".into(), gf(2.543), gf(t2_gf), format!("{:.3}", t2_gf / 2.543)]);
    let mut rendered = t.render();
    rendered.push_str(&format!(
        "executed @K={k_exec}: residue {} (service+simulator path), wall {:.4}s\n",
        sci(row.residue),
        row.report.wall_s
    ));
    Ok(TableResult {
        rendered,
        ukr: None,
        checks: vec![
            Check { name: "t2.total_s".into(), paper: 0.158303, ours: proj.total_s },
            Check { name: "t2.gflops".into(), paper: 2.543, ours: proj.gflops(192, 256, 4096) },
        ],
    })
}

/// Table 3: BLIS sgemm at kernel size.
pub fn table3(scale: ExperimentScale) -> Result<TableResult> {
    let model = CalibratedModel::default();
    let proj_s =
        analytic_blis_gemm_s(&model, 192, 256, 4096, WalkClass::Contig, WalkClass::StridedB, false);
    let proj_gf = 2.0 * 192.0 * 256.0 * 4096.0 / proj_s / 1e9;

    let k_exec = if scale == ExperimentScale::Full { 4096 } else { 512 };
    let blas = blas(ServiceBackend::Simulator)?;
    let row = run_sgemm_case(&blas, Trans::N, Trans::N, 192, 256, k_exec, 31)?;

    let mut t = Table::new(
        "Table 3 — BLIS sgemm kernel results (M=192, N=256, K=4096)",
        &["row", "paper GFLOPS", "projected GFLOPS", "residue paper", "residue ours"],
    );
    t.row(&[
        "blis_sgemm_nn_ccc".into(),
        gf(2.630),
        gf(proj_gf),
        sci(1.18e-7),
        sci(row.residue),
    ]);
    let mut rendered = t.render();
    rendered.push_str(
        "note: the paper's Table 3 (2.630 GF) exceeds its own Table 2 (2.543 GF) although BLIS\n\
         adds packing; our model cannot reproduce that inversion — see EXPERIMENTS.md.\n",
    );
    let traj = ukr_trajectory(192, 256, k_exec.min(512));
    rendered.push_str(&traj.render());
    Ok(TableResult {
        rendered,
        ukr: Some(traj),
        checks: vec![Check { name: "t3.gflops".into(), paper: 2.630, ours: proj_gf }],
    })
}

/// The 16 transpose variants of Table 4 (sgemm) / Table 6 (false dgemm).
fn variant_table(
    dgemm: bool,
    paper_vals: &[(&str, f64, f64)],
    scale: ExperimentScale,
) -> Result<TableResult> {
    let model = CalibratedModel::default();
    let (m, n, k) = (4096, 4096, 4096);
    let flops = 2.0 * (m as f64) * (n as f64) * (k as f64);

    // Projected at paper size per variant.
    let class_of = |t: Trans, is_a: bool| {
        if is_a {
            if t.is_trans() { WalkClass::StridedA } else { WalkClass::Contig }
        } else if t.is_trans() {
            WalkClass::Contig
        } else {
            WalkClass::StridedB
        }
    };
    let mut t = Table::new(
        &format!(
            "Table {} — BLIS {} results (M=N=K=4096)",
            if dgemm { 6 } else { 4 },
            if dgemm { "\"false dgemm\"" } else { "sgemm" }
        ),
        &["row", "paper GF", "projected GF", "ratio", "residue paper", "residue ours"],
    );
    let mut checks = Vec::new();

    // Executed sweep at reduced size for residues.
    let (em, en, ek) =
        if scale == ExperimentScale::Full { (4096, 4096, 4096) } else { (384, 512, 256) };
    let blas = blas(ServiceBackend::Simulator)?;
    let rows = sweep_all_variants(&blas, dgemm, em, en, ek)?;

    for (i, &(code, paper_gf, paper_res)) in paper_vals.iter().enumerate() {
        let ta = Trans::all()[i / 4];
        let tb = Trans::all()[i % 4];
        let proj_s =
            analytic_blis_gemm_s(&model, m, n, k, class_of(ta, true), class_of(tb, false), dgemm);
        let proj_gf = flops / proj_s / 1e9;
        let res = rows[i].residue;
        t.row(&[
            format!("blis_{}gemm_{code}_ccc", if dgemm { "d" } else { "s" }),
            gf(paper_gf),
            gf(proj_gf),
            format!("{:.3}", proj_gf / paper_gf),
            sci(paper_res),
            sci(res),
        ]);
        checks.push(Check {
            name: format!("t{}.{}", if dgemm { 6 } else { 4 }, code),
            paper: paper_gf,
            ours: proj_gf,
        });
    }
    let traj = ukr_trajectory(192, 256, ek.min(512));
    let mut rendered = t.render();
    rendered.push_str(&traj.render());
    Ok(TableResult { rendered, ukr: Some(traj), checks })
}

/// Table 4: BLIS sgemm, all 16 transpose variants at 4096³.
pub fn table4(scale: ExperimentScale) -> Result<TableResult> {
    #[rustfmt::skip]
    let paper = [
        ("nn", 2.381, 4.52e-7), ("nt", 2.455, 4.77e-7), ("nc", 2.381, 4.79e-7), ("nh", 2.456, 4.65e-7),
        ("tn", 2.034, 4.50e-7), ("tt", 2.090, 4.55e-7), ("tc", 2.036, 4.64e-7), ("th", 2.094, 4.89e-7),
        ("cn", 2.381, 4.69e-7), ("ct", 2.455, 4.67e-7), ("cc", 2.381, 4.75e-7), ("ch", 2.455, 4.59e-7),
        ("hn", 2.035, 4.67e-7), ("ht", 2.090, 4.69e-7), ("hc", 2.037, 4.69e-7), ("hh", 2.094, 4.63e-7),
    ];
    // Reorder to [N,T,C,H]² iteration order (paper groups differently).
    #[rustfmt::skip]
    let order = ["nn", "nt", "nc", "nh", "tn", "tt", "tc", "th",
                 "cn", "ct", "cc", "ch", "hn", "ht", "hc", "hh"];
    let mut vals = Vec::new();
    for (i, &code) in order.iter().enumerate() {
        // paper lists n,c aliases: map via code lookup
        let found = paper.iter().find(|(c, _, _)| *c == code).unwrap();
        let _ = i;
        vals.push(*found);
    }
    variant_table(false, &vals, scale)
}

/// Table 5: the false-dgemm kernel result.
pub fn table5(scale: ExperimentScale) -> Result<TableResult> {
    let model = CalibratedModel::default();
    let proj_s =
        analytic_blis_gemm_s(&model, 192, 256, 4096, WalkClass::Contig, WalkClass::StridedB, true);
    let proj_gf = 2.0 * 192.0 * 256.0 * 4096.0 / proj_s / 1e9;

    let k_exec = if scale == ExperimentScale::Full { 4096 } else { 512 };
    let blas = blas(ServiceBackend::Simulator)?;
    let row = run_false_dgemm_case(&blas, Trans::N, Trans::N, 192, 256, k_exec, 51)?;

    let mut t = Table::new(
        "Table 5 — BLIS \"false dgemm\" kernel results (M=192, N=256, K=4096)",
        &["row", "paper GFLOPS", "projected GFLOPS", "residue paper", "residue ours"],
    );
    t.row(&["blis_dgemm_nn_ccc".into(), gf(2.073), gf(proj_gf), sci(9.33e-9), sci(row.residue)]);
    let traj = ukr_trajectory(192, 256, k_exec.min(512));
    let mut rendered = t.render();
    rendered.push_str(&traj.render());
    Ok(TableResult {
        rendered,
        ukr: Some(traj),
        checks: vec![Check { name: "t5.gflops".into(), paper: 2.073, ours: proj_gf }],
    })
}

/// Table 6: false dgemm, all 16 variants at 4096³.
pub fn table6(scale: ExperimentScale) -> Result<TableResult> {
    #[rustfmt::skip]
    let paper = [
        ("nn", 1.785, 1.30e-8), ("nt", 1.829, 1.32e-8), ("nc", 1.785, 1.28e-8), ("nh", 1.828, 1.28e-8),
        ("tn", 1.580, 1.27e-8), ("tt", 1.613, 1.28e-8), ("tc", 1.578, 1.29e-8), ("th", 1.611, 1.26e-8),
        ("cn", 1.784, 1.30e-8), ("ct", 1.828, 1.28e-8), ("cc", 1.783, 1.29e-8), ("ch", 1.828, 1.29e-8),
        ("hn", 1.579, 1.29e-8), ("ht", 1.615, 1.31e-8), ("hc", 1.575, 1.29e-8), ("hh", 1.614, 1.28e-8),
    ];
    variant_table(true, &paper, scale)
}

/// Table 7: HPL Linpack (N=4608, NB=768, 1×1 grid).
pub fn table7(scale: ExperimentScale) -> Result<TableResult> {
    let model = CalibratedModel::default();
    let proj_s = hpl_projection_s(&model, 4608, 768);
    let cfg_full = HplConfig::paper();
    let proj_gf = cfg_full.flops() / proj_s / 1e9;

    // Executed at scaled size (full = the paper's N, minutes of runtime).
    let cfg = if scale == ExperimentScale::Full {
        cfg_full
    } else {
        HplConfig::small(576, 96)
    };
    let blas = blas(ServiceBackend::Simulator)?;
    let res = run_hpl(&blas, cfg)?;

    let mut t = Table::new(
        "Table 7 — HPL Linpack (N=4608, NB=768, P=Q=1)",
        &["row", "paper", "ours"],
    );
    t.row(&["Time (s, projected)".into(), secs(131.81), secs(proj_s)]);
    t.row(&["GFLOPS/s (projected)".into(), gf(0.495), gf(proj_gf)]);
    t.row(&[
        format!("Residue (*) executed @N={}", cfg.n),
        sci(2.34e-6),
        sci(res.residual.raw),
    ]);
    t.row(&[
        format!("HPL-scaled residual @N={}", cfg.n),
        format!("{:.4e}", 2.1097632504e10),
        format!("{:.4e}", res.residual.hpl_scaled),
    ]);
    let mut rendered = t.render();
    rendered.push_str(&format!(
        "executed wall {:.2}s; gemm share of projected time {:.0}% \
         (paper's §4.3: host level-2 dominates)\n",
        res.wall_s,
        100.0 * res.lu.gemm_projected_s / res.projected_s
    ));
    Ok(TableResult {
        rendered,
        ukr: None,
        checks: vec![
            Check { name: "t7.time_s".into(), paper: 131.81, ours: proj_s },
            Check { name: "t7.gflops".into(), paper: 0.495, ours: proj_gf },
            Check {
                name: "t7.residue_log10".into(),
                paper: (2.34e-6f64).log10(),
                ours: res.residual.raw.max(1e-12).log10(),
            },
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_band(checks: &[Check], name: &str, lo: f64, hi: f64) {
        let c = checks.iter().find(|c| c.name == name).unwrap_or_else(|| panic!("{name} missing"));
        let r = c.ratio();
        assert!((lo..hi).contains(&r), "{name}: paper {} ours {} ratio {r}", c.paper, c.ours);
    }

    #[test]
    fn table1_shape() {
        let t = table1(ExperimentScale::Quick).unwrap();
        assert_band(&t.checks, "t1.total_s", 0.95, 1.05);
        assert_band(&t.checks, "t1.input_s", 0.97, 1.03);
        assert_band(&t.checks, "t1.coproc_s", 0.97, 1.03);
        assert_band(&t.checks, "t1.gflops", 0.95, 1.05);
        assert_band(&t.checks, "t1.hostref_s", 0.99, 1.01);
        // error magnitude within an order of magnitude (log10 ratio band)
        assert_band(&t.checks, "t1.mean_err_log10", 0.8, 1.2);
    }

    #[test]
    fn table2_shape() {
        let t = table2(ExperimentScale::Quick).unwrap();
        assert_band(&t.checks, "t2.total_s", 0.95, 1.05);
        assert_band(&t.checks, "t2.gflops", 0.95, 1.05);
    }

    #[test]
    fn table3_shape() {
        // Known anomaly: paper's Table 3 exceeds its Table 2; we accept a
        // wider band here (see the rendered note).
        let t = table3(ExperimentScale::Quick).unwrap();
        assert_band(&t.checks, "t3.gflops", 0.80, 1.10);
        // The measured scalar-vs-vectorized block rides along, rendered
        // and machine-readable (nested table in the bench JSON, where the
        // comparator reads it as report-only wall-clock cells).
        let ukr = t.ukr.as_ref().expect("table3 carries the µ-kernel trajectory");
        let json = ukr.to_json();
        assert!(json.contains("\"scalar\"") && json.contains("\"blocked\""), "{json}");
        assert!(t.rendered.contains("host µ-kernel trajectory"));
        assert!(t.to_json("table3").contains("\"ukr\":{\"title\""));
    }

    #[test]
    fn ukr_trajectory_block_is_consistent() {
        // Small tile: the function itself asserts bit-identical outputs
        // across variants before reporting any number; here we check the
        // table shape (one row per compiled-in variant, speedup column).
        let t = ukr_trajectory(64, 48, 96);
        let json = t.to_json();
        let expect = if cfg!(all(feature = "simd", target_arch = "x86_64")) { 3 } else { 2 };
        assert_eq!(json.matches("x\"]").count(), expect, "{json}");
        assert!(json.contains("\"1.00x\""), "scalar speedup vs itself is 1.00x: {json}");
    }

    #[test]
    fn table4_shape() {
        let t = table4(ExperimentScale::Quick).unwrap();
        // Every variant within 15% of the paper.
        for c in &t.checks {
            let r = c.ratio();
            assert!((0.85..1.15).contains(&r), "{}: ratio {r}", c.name);
        }
        // Ordering: nt > nn > tt > tn (who wins, as in the paper).
        let get = |code: &str| t.checks.iter().find(|c| c.name.ends_with(code)).unwrap().ours;
        assert!(get(".nt") > get(".nn"));
        assert!(get(".nn") > get(".tt"));
        assert!(get(".tt") > get(".tn"));
    }

    #[test]
    fn table5_shape() {
        let t = table5(ExperimentScale::Quick).unwrap();
        assert_band(&t.checks, "t5.gflops", 0.80, 1.10);
    }

    #[test]
    fn table6_shape() {
        let t = table6(ExperimentScale::Quick).unwrap();
        for c in &t.checks {
            let r = c.ratio();
            assert!((0.85..1.15).contains(&r), "{}: ratio {r}", c.name);
        }
    }

    #[test]
    fn table7_shape() {
        let t = table7(ExperimentScale::Quick).unwrap();
        assert_band(&t.checks, "t7.time_s", 0.90, 1.10);
        assert_band(&t.checks, "t7.gflops", 0.90, 1.10);
        // The executed residue scales with N (quick runs use a smaller
        // system than the paper's 4608), so instead of a ratio we assert
        // the f32-contamination class: far above f64-exact (~1e-15), far
        // below garbage.
        let c = t.checks.iter().find(|c| c.name == "t7.residue_log10").unwrap();
        let res = 10f64.powf(c.ours);
        assert!(res > 1e-13 && res < 1e-4, "residue {res} not f32-class");
    }
}
