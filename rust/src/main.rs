//! `parallella-blas` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   serve [--addr HOST:PORT] [--backend pjrt|sim|hostref] [--chips N]
//!         [--max-in-flight W] [--max-frame-len B] [--panel-cache-mb MB]
//!         [--health-deadline-ms MS] [--telemetry-period-ms MS]
//!         run the L3 BLAS network service until a Shutdown frame arrives
//!   client [--addr HOST:PORT] [--reqs N] [--depth D] [--m --n --k]
//!         drive a serve instance with D-deep pipelined sgemms (wire v2)
//!   client --watch [--addr HOST:PORT] [--frames N]
//!         subscribe to the server's telemetry stream and print one JSON
//!         frame per line (N = 0, the default, streams until the server
//!         stops; a clean server stop exits 0)
//!   client --batch [--addr HOST:PORT] [--reqs N] [--items I] [--m --n --k]
//!         [--pin CHIP]
//!         drive a serve instance with batched small-gemm requests (I tiny
//!         matmuls per wire frame, fanned across the chip pool)
//!   solve [--n N] [--nb NB] [--kind lu|chol] [--max-iters I] [--tol T]
//!         [--addr HOST:PORT]
//!         mixed-precision iterative refinement: f32-class factorization +
//!         f64 residual loop, local by default, over the wire with --addr
//!   sgemm [--m M] [--n N] [--k K] [--ta n|t] [--tb n|t] [--chips N]
//!         [--autotune [--measure]]
//!         one accelerated gemm with the wall/projected/paper report;
//!         --autotune searches blocking candidates for the problem size
//!         first and boots the tuned geometry (--measure also times the
//!         leaderboard on the host before picking)
//!   bench-diff <committed.json> <fresh.json> [--threshold 0.30]
//!         diff a fresh bench snapshot against a committed one; exits
//!         nonzero when a deterministic `checks` metric drifts past the
//!         threshold (wall-clock table cells only annotate)
//!   hpl   [--n N] [--nb NB]
//!         the HPL Linpack run (paper Table 7 shape)
//!   table <1..7> [--full]
//!         regenerate a paper table (projections at paper size; --full
//!         also executes at paper size)
//!   memmap
//!         print the per-core Fig-3 local memory map
//!
//! (argument parsing is hand-rolled: no clap in the offline crate set.)

use anyhow::{bail, Context, Result};
use parallella_blas::blis::{AutotuneConfig, Trans};
use parallella_blas::coordinator::server::BlasServer;
use parallella_blas::coordinator::{BlasClient, Request, ServerConfig, PROTOCOL_V2};
use parallella_blas::epiphany::kernel::KernelGeometry;
use parallella_blas::epiphany::timing::CalibratedModel;
use parallella_blas::epiphany::Chip;
use parallella_blas::experiments::{self, ExperimentScale};
use parallella_blas::host::service::ServiceBackend;
use parallella_blas::coordinator::protocol::GemmWire;
use parallella_blas::hpl::driver::{run_hpl, HplConfig};
use parallella_blas::hpl::residual::hpl_residual;
use parallella_blas::linalg::Mat;
use parallella_blas::platform::{BackendKind, Platform};
use parallella_blas::workloads::{solve_refined, Factorization, RefinePolicy};

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: Vec<(String, String)>,
    switches: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = Vec::new();
        let mut switches = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.push((key.to_string(), argv[i + 1].clone()));
                    i += 2;
                } else {
                    switches.push(key.to_string());
                    i += 1;
                }
            } else {
                switches.push(argv[i].clone());
                i += 1;
            }
        }
        Args { flags, switches }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not a number")),
            None => Ok(default),
        }
    }

    fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

fn backend_of(args: &Args) -> Result<(BackendKind, ServiceBackend)> {
    // The simulator is always available; pjrt needs the feature + artifacts.
    let default_backend = if cfg!(feature = "pjrt") { "pjrt" } else { "sim" };
    Ok(match args.get("backend").unwrap_or(default_backend) {
        "pjrt" => (BackendKind::Pjrt, ServiceBackend::Pjrt),
        "sim" | "simulator" => (BackendKind::Simulator, ServiceBackend::Simulator),
        "hostref" | "host" => (BackendKind::HostRef, ServiceBackend::HostRef),
        other => bail!("unknown backend {other:?} (pjrt|sim|hostref)"),
    })
}

fn trans_of(s: Option<&str>) -> Result<Trans> {
    Ok(match s.unwrap_or("n") {
        "n" | "N" => Trans::N,
        "t" | "T" => Trans::T,
        "c" | "C" => Trans::C,
        "h" | "H" => Trans::H,
        other => bail!("bad trans {other:?}"),
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);

    match cmd.as_str() {
        "serve" => {
            let (_, sb) = backend_of(&args)?;
            let chips = args.usize("chips", 1)?.max(1);
            let defaults = ServerConfig::default();
            let cfg = ServerConfig {
                addr: args.get("addr").unwrap_or("127.0.0.1:7700").to_string(),
                backend: sb,
                batch: Default::default(),
                chips,
                max_in_flight: args.usize("max-in-flight", defaults.max_in_flight)?,
                max_frame_len: args.usize("max-frame-len", defaults.max_frame_len)?,
                panel_cache_bytes: args.usize("panel-cache-mb", 0)? << 20,
                health_deadline_ms: args.usize("health-deadline-ms", 0)? as u64,
                telemetry_period_ms: args
                    .usize("telemetry-period-ms", defaults.telemetry_period_ms as usize)?
                    as u64,
            };
            let window = cfg.max_in_flight;
            let srv = BlasServer::start(cfg)?;
            println!(
                "parallella-blas serving on {} with {chips} chip(s), \
                 {window} in-flight per connection \
                 (send a Shutdown frame or Ctrl-C to stop)",
                srv.addr()
            );
            // Park the main thread; the accept loop owns the work.
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "client" => {
            let addr = args.get("addr").unwrap_or("127.0.0.1:7700").to_string();
            if args.has("watch") {
                // Live telemetry: subscribe and print one JSON frame per
                // line until --frames is exhausted (0 = until killed).
                let frames = args.usize("frames", 0)?;
                let cli = BlasClient::connect_v2(&*addr)
                    .with_context(|| format!("connecting to {addr}"))?;
                if cli.version() < PROTOCOL_V2 {
                    bail!("--watch needs a v2 server (this one only speaks v1)");
                }
                let mut stream = cli.subscribe()?;
                let mut seen = 0usize;
                while frames == 0 || seen < frames {
                    // A clean server stop (EOF at a frame boundary after
                    // the stop-drain) ends the watch with exit 0; only a
                    // real I/O or codec failure propagates as an error.
                    match stream.try_next_frame()? {
                        Some(frame) => println!("{frame}"),
                        None => {
                            eprintln!("server stopped; telemetry stream closed cleanly");
                            return Ok(());
                        }
                    }
                    seen += 1;
                }
                return Ok(());
            }
            if args.has("batch") {
                return client_batch(&args, &addr);
            }
            let reqs = args.usize("reqs", 64)?.max(1);
            let depth = args.usize("depth", 8)?.max(1);
            let m = args.usize("m", 96)?;
            let n = args.usize("n", 64)?;
            let k = args.usize("k", 96)?;
            let mut cli = BlasClient::connect_v2(&*addr)
                .with_context(|| format!("connecting to {addr}"))?;
            if cli.version() < PROTOCOL_V2 {
                println!("server only speaks wire v1; falling back to serial calls");
            }
            let a = Mat::<f32>::randn(m, k, 1);
            let b = Mat::<f32>::randn(k, n, 2);
            let req = Request::sgemm(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.0,
                0.0,
                a.as_slice().to_vec(),
                b.as_slice().to_vec(),
                vec![0.0; m * n],
            );
            let t0 = std::time::Instant::now();
            if cli.version() >= PROTOCOL_V2 {
                // Sliding window: keep `depth` requests on the wire.
                let mut window = std::collections::VecDeque::new();
                for _ in 0..reqs {
                    while window.len() >= depth {
                        let _ = window.pop_front().unwrap().wait()?.into_f32()?;
                    }
                    window.push_back(cli.submit(&req)?);
                }
                while let Some(p) = window.pop_front() {
                    let _ = p.wait()?.into_f32()?;
                }
            } else {
                for _ in 0..reqs {
                    let _ = cli.call(&req)?.into_f32()?;
                }
            }
            let dt = t0.elapsed().as_secs_f64();
            let gflops = 2.0 * (m * n * k * reqs) as f64 / dt / 1e9;
            println!(
                "client: {reqs} sgemm {m}x{n}x{k} at depth {depth}: \
                 {dt:.3}s ({:.1} req/s, {gflops:.3} GF)",
                reqs as f64 / dt
            );
        }
        "sgemm" => {
            let (bk, _) = backend_of(&args)?;
            let m = args.usize("m", 192)?;
            let n = args.usize("n", 256)?;
            let k = args.usize("k", 4096)?;
            let chips = args.usize("chips", 1)?;
            let ta = trans_of(args.get("ta"))?;
            let tb = trans_of(args.get("tb"))?;
            let mut builder = Platform::builder().backend(bk).chips(chips);
            if args.has("autotune") {
                let mut cfg = AutotuneConfig::for_workload(m, n, k);
                if args.has("measure") {
                    cfg = cfg.measured();
                }
                builder = builder.autotune(cfg);
            }
            let plat = builder.build()?;
            if let Some(t) = &plat.tuned {
                println!("{}", t.report());
            }
            let a =
                if ta.is_trans() { Mat::<f32>::randn(k, m, 1) } else { Mat::<f32>::randn(m, k, 1) };
            let b =
                if tb.is_trans() { Mat::<f32>::randn(n, k, 2) } else { Mat::<f32>::randn(k, n, 2) };
            let mut c = Mat::<f32>::zeros(m, n);
            let rep = plat.blas().sgemm(ta, tb, 1.0, a.view(), b.view(), 0.0, &mut c)?;
            println!(
                "sgemm {}{} {m}x{n}x{k} [{:?} x{} chip(s)]: calls={} wall={:.4}s ({:.2} GF) \
                 projected={:.4}s ({:.3} GF)",
                ta.code(),
                tb.code(),
                plat.backend,
                rep.chips,
                rep.calls,
                rep.wall_s,
                rep.wall_gflops(),
                rep.projected_s,
                rep.projected_gflops(),
            );
        }
        "solve" => {
            let n = args.usize("n", 256)?;
            let nb = args.usize("nb", 64)?;
            let kind = match args.get("kind").unwrap_or("lu") {
                "lu" | "LU" => Factorization::Lu,
                "chol" | "cholesky" => Factorization::Cholesky,
                other => bail!("bad --kind {other:?} (lu|chol)"),
            };
            let max_iters = args.usize("max-iters", 0)?;
            let tol: f64 = match args.get("tol") {
                Some(v) => v.parse().with_context(|| format!("--tol {v:?} is not a number"))?,
                None => 0.0,
            };
            // A well-conditioned demo system of the right symmetry class.
            let mut rng = parallella_blas::linalg::XorShiftRng::new(42);
            let a = match kind {
                Factorization::Lu => {
                    let mut a = Mat::<f64>::from_fn(n, n, |_, _| rng.next_unit());
                    for i in 0..n {
                        a.set(i, i, a.get(i, i) + n as f64);
                    }
                    a
                }
                Factorization::Cholesky => {
                    let m = Mat::<f64>::randn(n, n, 43);
                    let mut a =
                        Mat::<f64>::from_fn(n, n, |i, j| if i == j { n as f64 } else { 0.0 });
                    parallella_blas::blis::level3::gemm_host(
                        Trans::N,
                        Trans::T,
                        1.0,
                        m.view(),
                        m.view(),
                        1.0,
                        &mut a,
                    );
                    a
                }
            };
            let b: Vec<f64> = (0..n).map(|_| rng.next_unit()).collect();
            if let Some(addr) = args.get("addr") {
                // Over the wire: the server factors, refines, and returns x.
                let mut cli = BlasClient::connect_v2(addr)
                    .with_context(|| format!("connecting to {addr}"))?;
                let t0 = std::time::Instant::now();
                let x = cli
                    .call(&Request::solve(
                        kind,
                        n,
                        nb,
                        max_iters,
                        tol,
                        a.as_slice().to_vec(),
                        b.clone(),
                    ))?
                    .into_f64()?;
                let res = hpl_residual(&a, &x, &b);
                println!(
                    "solve {kind:?} n={n} nb={nb} over the wire: {:.3}s \
                     residual(hpl)={:.3e} raw={:.3e}",
                    t0.elapsed().as_secs_f64(),
                    res.hpl_scaled,
                    res.raw
                );
            } else {
                let (bk, _) = backend_of(&args)?;
                let plat = Platform::builder().backend(bk).build()?;
                let mut policy = RefinePolicy { nb, ..Default::default() };
                if max_iters > 0 {
                    policy.max_iters = max_iters;
                }
                if tol > 0.0 {
                    policy.tolerance = tol;
                }
                let t0 = std::time::Instant::now();
                let (x, rep) = solve_refined(plat.blas(), &a, &b, kind, &policy)?;
                let res = hpl_residual(&a, &x, &b);
                println!(
                    "solve {kind:?} n={n} nb={nb}: {} refinement step(s) in {:.3}s\n\
                     residual trajectory (hpl-scaled): {:?}\n\
                     final residual(hpl)={:.3e} raw={:.3e}  [pass criterion: <= 16]",
                    rep.iters,
                    t0.elapsed().as_secs_f64(),
                    rep.residuals,
                    res.hpl_scaled,
                    res.raw
                );
            }
        }
        "hpl" => {
            let n = args.usize("n", 768)?;
            let nb = args.usize("nb", 96)?;
            let (bk, _) = backend_of(&args)?;
            let plat = Platform::builder().backend(bk).build()?;
            let res = run_hpl(plat.blas(), HplConfig::small(n, nb))?;
            println!(
                "HPL N={n} NB={nb}: wall={:.2}s projected={:.2}s ({:.3} GF) residue={:.2e}",
                res.wall_s, res.projected_s, res.projected_gflops, res.residual.raw
            );
        }
        "table" => {
            let which = args
                .switches
                .iter()
                .find_map(|s| s.parse::<usize>().ok())
                .context("usage: table <1..7> [--full]")?;
            let scale =
                if args.has("full") { ExperimentScale::Full } else { ExperimentScale::Quick };
            let t = match which {
                1 => experiments::table1(scale)?,
                2 => experiments::table2(scale)?,
                3 => experiments::table3(scale)?,
                4 => experiments::table4(scale)?,
                5 => experiments::table5(scale)?,
                6 => experiments::table6(scale)?,
                7 => experiments::table7(scale)?,
                _ => bail!("tables 1..7 exist"),
            };
            println!("{}", t.rendered);
        }
        "bench-diff" => {
            let (Some(committed), Some(fresh)) = (args.switches.first(), args.switches.get(1))
            else {
                bail!("usage: bench-diff <committed.json> <fresh.json> [--threshold 0.30]");
            };
            let threshold: f64 = match args.get("threshold") {
                Some(v) => {
                    v.parse().with_context(|| format!("--threshold {v:?} is not a number"))?
                }
                None => 0.30,
            };
            let old = std::fs::read_to_string(committed)
                .with_context(|| format!("reading {committed}"))?;
            let new =
                std::fs::read_to_string(fresh).with_context(|| format!("reading {fresh}"))?;
            let cmp = parallella_blas::util::bench::compare_bench_json(&old, &new)?;
            print!("{}", cmp.render(threshold));
            let regressions = cmp.regressions(threshold).len();
            if regressions > 0 {
                bail!("{regressions} gating metric(s) drifted past {:.0}%", 100.0 * threshold);
            }
        }
        "memmap" => {
            let chip = Chip::new(CalibratedModel::default(), KernelGeometry::paper())?;
            println!("per-core local memory map (paper Fig. 3):\n{}", chip.memory_map());
        }
        "help" | "--help" | "-h" => print_help(),
        other => {
            print_help();
            bail!("unknown command {other:?}");
        }
    }
    Ok(())
}

/// `client --batch`: drive a serve instance with batched small-gemm
/// requests — `--items` tiny matmuls per wire frame, fanned across the
/// pool by the server (`--pin` pins the whole batch to one chip).
fn client_batch(args: &Args, addr: &str) -> Result<()> {
    let reqs = args.usize("reqs", 8)?.max(1);
    let items = args.usize("items", 64)?.max(1);
    let m = args.usize("m", 32)?;
    let n = args.usize("n", 32)?;
    let k = args.usize("k", 32)?;
    let pin = args.get("pin").map(|v| v.parse::<usize>()).transpose()?;
    let mut cli =
        BlasClient::connect_v2(addr).with_context(|| format!("connecting to {addr}"))?;
    let wires: Vec<GemmWire> = (0..items)
        .map(|i| {
            let seed = 1 + 2 * i as u64;
            GemmWire::f32(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.0,
                0.0,
                Mat::<f32>::randn(m, k, seed).as_slice().to_vec(),
                Mat::<f32>::randn(k, n, seed + 1).as_slice().to_vec(),
                vec![0.0; m * n],
            )
        })
        .collect();
    let mut req = Request::gemm_batch(wires);
    if let Some(chip) = pin {
        req = req.with_shard_hint(chip);
    }
    let t0 = std::time::Instant::now();
    for _ in 0..reqs {
        let out = cli.call(&req)?.into_f32()?;
        anyhow::ensure!(out.len() == items * m * n, "short batch response: {}", out.len());
    }
    let dt = t0.elapsed().as_secs_f64();
    let gflops = 2.0 * (m * n * k * items * reqs) as f64 / dt / 1e9;
    println!(
        "client --batch: {reqs} batches x {items} sgemm {m}x{n}x{k}: {dt:.3}s \
         ({:.1} items/s, {gflops:.3} GF)",
        (reqs * items) as f64 / dt
    );
    Ok(())
}

fn print_help() {
    println!(
        "parallella-blas — Epiphany-accelerated BLAS (Tasende 2016) on a simulated Parallella\n\
         \n\
         usage: parallella-blas <command> [flags]\n\
         \n\
         commands:\n\
         \u{20} serve   [--addr H:P] [--backend sim|pjrt|hostref] [--chips N]\n\
         \u{20}         [--max-in-flight W] [--max-frame-len B] [--panel-cache-mb MB]\n\
         \u{20}         [--health-deadline-ms MS] [--telemetry-period-ms MS]\n\
         \u{20}                                                     run the network BLAS service\n\
         \u{20} client  [--addr H:P] [--reqs N] [--depth D] [--m --n --k]\n\
         \u{20}                                                     pipelined v2 load generator\n\
         \u{20} client  --watch [--addr H:P] [--frames N]           stream live telemetry JSON\n\
         \u{20} client  --batch [--addr H:P] [--reqs N] [--items I]\n\
         \u{20}         [--m --n --k] [--pin CHIP]                  batched small-gemm driver\n\
         \u{20} solve   [--n --nb] [--kind lu|chol] [--max-iters I]\n\
         \u{20}         [--tol T] [--addr H:P]                      mixed-precision refined solve\n\
         \u{20} sgemm   [--m --n --k --ta --tb --backend --chips]\n\
         \u{20}         [--autotune [--measure]]                    one gemm + report; --autotune\n\
         \u{20}                                                     searches blocking params first\n\
         \u{20} bench-diff <committed.json> <fresh.json>\n\
         \u{20}         [--threshold 0.30]                          gate bench snapshot drift\n\
         \u{20} hpl     [--n --nb --backend]                        HPL Linpack run\n\
         \u{20} table   <1..7> [--full]                             regenerate a paper table\n\
         \u{20} memmap                                              print the Fig-3 memory map\n\
         \n\
         the pjrt backend needs a `--features pjrt` build plus `make artifacts`."
    );
}
