//! LAPACK-lite Cholesky (`potrf`/`potrs`) on the generated BLAS — the
//! "may be any scientific software, or library like LAPACK" use case of
//! paper §3.1, and a second consumer of the accelerated gemm beyond HPL.
//!
//! Blocked right-looking factorization (lower): per NB panel,
//! `potf2` on the diagonal block (host), `trsm` below (host), and the
//! trailing `syrk`-shaped update done through the **false dgemm** — on the
//! Epiphany path wherever the flops are.

use crate::blis::{level3, Blas, Trans};
use crate::linalg::Mat;
use anyhow::{ensure, Result};

/// Unblocked lower Cholesky of the `jb × jb` block at `(j0, j0)`.
fn potf2(a: &mut Mat<f64>, j0: usize, jb: usize) -> Result<()> {
    for j in j0..j0 + jb {
        let mut d = a.get(j, j);
        for l in j0..j {
            let v = a.get(j, l);
            d -= v * v;
        }
        ensure!(d > 0.0, "matrix not positive definite at column {j} (d = {d})");
        let d = d.sqrt();
        a.set(j, j, d);
        for i in j + 1..j0 + jb {
            let mut v = a.get(i, j);
            for l in j0..j {
                v -= a.get(i, l) * a.get(j, l);
            }
            a.set(i, j, v / d);
        }
    }
    Ok(())
}

/// Blocked lower Cholesky in place: A = L·Lᵀ (upper triangle untouched).
/// Returns projected/wall accounting like the LU path.
pub fn potrf_lower(blas: &Blas, a: &mut Mat<f64>, nb: usize) -> Result<super::lu::LuReport> {
    let n = a.rows();
    ensure!(a.cols() == n, "square only");
    let mut report = super::lu::LuReport::default();
    let t0 = std::time::Instant::now();
    let model = crate::epiphany::timing::CalibratedModel::default();

    let mut j0 = 0usize;
    while j0 < n {
        let jb = nb.min(n - j0);
        potf2(a, j0, jb)?;
        let panel_flops = (jb * jb * jb) as f64 / 3.0;
        report.host_flops += panel_flops;
        report.host_projected_s += panel_flops / (model.host_level2_f64_gflops * 1e9);

        let rest0 = j0 + jb;
        if rest0 < n {
            // L21 = A21 · L11⁻ᵀ  (trsm right-transpose == trsm_left on Aᵀ).
            let l11 = a.view().sub(j0, j0, jb, jb).to_mat();
            let a21 = a.view().sub(rest0, j0, n - rest0, jb).to_mat();
            let mut a21_t = a21.transposed();
            // Solve L11 · X = A21ᵀ  ⇒ X = L11⁻¹ A21ᵀ, L21 = Xᵀ.
            level3::trsm_left(true, Trans::N, false, 1.0, l11.view(), &mut a21_t);
            let l21 = a21_t.transposed();
            for j in 0..jb {
                for i in 0..n - rest0 {
                    a.set(rest0 + i, j0 + j, l21.get(i, j));
                }
            }
            let trsm_flops = (jb * jb) as f64 * (n - rest0) as f64;
            report.host_flops += trsm_flops;
            report.host_projected_s += trsm_flops / (model.host_trsm_f64_gflops * 1e9);

            // A22 -= L21 · L21ᵀ — syrk-shaped, routed through false dgemm
            // (full update; the upper half is ignored downstream).
            let mut a22 = a.view().sub(rest0, rest0, n - rest0, n - rest0).to_mat();
            let rep =
                blas.dgemm_false(Trans::N, Trans::T, -1.0, l21.view(), l21.view(), 1.0, &mut a22)?;
            for j in 0..n - rest0 {
                for i in 0..n - rest0 {
                    a.set(rest0 + i, rest0 + j, a22.get(i, j));
                }
            }
            report.gemm_projected_s += rep.projected_s;
            report.gemm_flops += rep.flops;
        }
        j0 += jb;
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Solve A·x = b given the Cholesky factor (lower).
pub fn potrs_lower(a: &Mat<f64>, b: &[f64]) -> Vec<f64> {
    let mut x = b.to_vec();
    crate::blis::level2::trsv(true, Trans::N, false, a.view(), &mut x);
    crate::blis::level2::trsv(true, Trans::T, false, a.view(), &mut x);
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::XorShiftRng;

    fn blas() -> Blas {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        Blas::new(svc)
    }

    /// SPD matrix: M·Mᵀ + n·I.
    fn spd(n: usize, seed: u64) -> Mat<f64> {
        let m = Mat::<f64>::randn(n, n, seed);
        let mut a = Mat::<f64>::from_fn(n, n, |i, j| if i == j { n as f64 } else { 0.0 });
        level3::gemm_host(Trans::N, Trans::T, 1.0, m.view(), m.view(), 1.0, &mut a);
        a
    }

    #[test]
    fn factor_solve_round_trip() {
        let blas = blas();
        let n = 160; // crosses one block boundary at nb=64
        let a0 = spd(n, 3);
        let mut a = a0.clone();
        potrf_lower(&blas, &mut a, 64).unwrap();
        let mut rng = XorShiftRng::new(7);
        let b: Vec<f64> = (0..n).map(|_| rng.next_unit()).collect();
        let x = potrs_lower(&a, &b);
        let mut worst = 0.0f64;
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..n {
                acc += a0.get(i, j) * x[j];
            }
            worst = worst.max((acc - b[i]).abs());
        }
        // f32-contaminated trailing updates ⇒ residual beyond f64-exact.
        assert!(worst < 1e-2, "residual {worst}");
    }

    #[test]
    fn factor_matches_reference_class() {
        let blas = blas();
        let n = 96;
        let a0 = spd(n, 5);
        let mut a = a0.clone();
        potrf_lower(&blas, &mut a, 48).unwrap();
        // L·Lᵀ ≈ A0 (lower half).
        let mut recon = Mat::<f64>::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for l in 0..=i.min(j) {
                    acc += a.get(i, l) * a.get(j, l);
                }
                recon.set(i, j, acc);
            }
        }
        let e = crate::linalg::max_scaled_err(recon.view(), a0.view());
        assert!(e < 1e-4, "reconstruction err {e}");
    }

    #[test]
    fn non_spd_rejected() {
        let blas = blas();
        let mut a = Mat::<f64>::from_fn(8, 8, |i, j| if i == j { -1.0 } else { 0.0 });
        let err = potrf_lower(&blas, &mut a, 4).unwrap_err();
        assert!(format!("{err:#}").contains("positive definite"));
    }
}
