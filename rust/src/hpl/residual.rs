//! The HPL residual (paper Table 7):
//!
//!   r_hpl = ‖Ax − b‖∞ / (ε · (‖A‖∞·‖x‖∞ + ‖b‖∞) · N)
//!
//! and the paper's "Residue (*)" row, which multiplies r_hpl back by
//! ε = 2⁻⁵³ (i.e. drops the ε normalization).

use crate::linalg::{inf_norm, Mat};

/// ε used by HPL's double-precision check (2⁻⁵³, as the paper's footnote).
pub const HPL_EPS: f64 = 1.1102230246251565e-16;

/// Both residual flavours HPL's check reports.
#[derive(Clone, Copy, Debug)]
pub struct HplResidual {
    /// The HPL-normalized value (Table 7 row: ~2.1e10 for the paper's run,
    /// because the compute was only f32-precise).
    pub hpl_scaled: f64,
    /// × ε — the paper's "(*) Residue" row (~2.34e-6).
    pub raw: f64,
}

/// Compute both residual flavours for a candidate solution.
pub fn hpl_residual(a: &Mat<f64>, x: &[f64], b: &[f64]) -> HplResidual {
    let n = a.rows();
    // ‖Ax − b‖∞
    let mut rinf = 0.0f64;
    for i in 0..n {
        let mut acc = 0.0f64;
        for j in 0..n {
            acc += a.get(i, j) * x[j];
        }
        rinf = rinf.max((acc - b[i]).abs());
    }
    let a_inf = inf_norm(a.view());
    let x_inf = x.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    let b_inf = b.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    let denom = (a_inf * x_inf + b_inf) * n as f64;
    let raw = rinf / denom;
    HplResidual { hpl_scaled: raw / HPL_EPS, raw }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_solution_zero_residual() {
        // A = I, x = b.
        let n = 8;
        let a = Mat::<f64>::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 });
        let b: Vec<f64> = (0..n).map(|v| v as f64).collect();
        let r = hpl_residual(&a, &b, &b);
        assert_eq!(r.raw, 0.0);
        assert_eq!(r.hpl_scaled, 0.0);
    }

    #[test]
    fn f32_precision_solution_lands_in_paper_band() {
        // Perturb the exact solution at f32 scale: residue must land in
        // the paper's magnitude (~1e-7..1e-5 raw), i.e. hpl_scaled ~1e9+.
        let n = 64;
        let a =
            Mat::<f64>::from_fn(n, n, |i, j| if i == j { 2.0 } else { 0.5 / (1 + i + j) as f64 });
        let x_true: Vec<f64> = (0..n).map(|v| ((v * 37) % 11) as f64 / 11.0 - 0.5).collect();
        let mut b = vec![0.0f64; n];
        for i in 0..n {
            for j in 0..n {
                b[i] += a.get(i, j) * x_true[j];
            }
        }
        let x32: Vec<f64> = x_true.iter().map(|&v| v as f32 as f64).collect();
        let r = hpl_residual(&a, &x32, &b);
        assert!(r.raw > 1e-12 && r.raw < 1e-4, "raw {}", r.raw);
        assert!(r.hpl_scaled > 1e4, "scaled {}", r.hpl_scaled);
    }

    #[test]
    fn scaling_relation_holds() {
        let n = 4;
        let a = Mat::<f64>::full(n, n, 1.0);
        let b = vec![1.0; n];
        let x = vec![0.3; n];
        let r = hpl_residual(&a, &x, &b);
        assert!((r.hpl_scaled * HPL_EPS / r.raw - 1.0).abs() < 1e-12);
    }
}
