//! Right-looking blocked LU with partial pivoting (HPL's factorization),
//! on top of the generated BLAS:
//!
//! * panel factorization (`dgetf2`-style): level-1/2 host ops
//!   (`iamax`, `dscal`/`dger` structure) — the unaccelerated part;
//! * row swaps (`dlaswp`);
//! * `dtrsm` on the panel's right block — host level-3;
//! * the trailing update `A22 -= L21·U12` — **the false dgemm**, i.e. the
//!   Epiphany-accelerated path, where almost all the flops live.

use crate::blis::level1;
use crate::blis::level3;
use crate::blis::{Blas, Trans};
use crate::linalg::Mat;
use anyhow::{ensure, Result};

/// Accounting for one factorization.
#[derive(Clone, Copy, Debug, Default)]
pub struct LuReport {
    /// Projected seconds in the accelerated gemm updates.
    pub gemm_projected_s: f64,
    /// Projected seconds in host panel/trsm work (calibrated rates).
    pub host_projected_s: f64,
    /// Wall-clock seconds total.
    pub wall_s: f64,
    /// gemm flops (accelerated) and host flops.
    pub gemm_flops: f64,
    /// Flops done in unaccelerated host work (panels, trsm).
    pub host_flops: f64,
}

impl LuReport {
    /// Projected seconds, accelerated + host work combined.
    pub fn total_projected_s(&self) -> f64 {
        self.gemm_projected_s + self.host_projected_s
    }
}

/// Unblocked panel factorization with partial pivoting on columns
/// `j0..j0+nb` of `a`, rows `j0..m`. Returns pivot rows (global indices).
fn panel_factor(a: &mut Mat<f64>, j0: usize, nb: usize) -> Result<Vec<usize>> {
    let m = a.rows();
    let mut pivots = Vec::with_capacity(nb);
    for j in j0..j0 + nb {
        // Find the pivot with iamax over the column tail.
        let tail: Vec<f64> = (j..m).map(|i| a.get(i, j)).collect();
        let p = j + level1::iamax(tail.len(), &tail, 1).expect("non-empty column");
        ensure!(a.get(p, j) != 0.0, "singular matrix at column {j}");
        pivots.push(p);
        // Swap rows j and p across the whole matrix (HPL swaps lazily per
        // panel + applies to the trailing part; full swap is equivalent).
        if p != j {
            for col in 0..a.cols() {
                let t = a.get(j, col);
                a.set(j, col, a.get(p, col));
                a.set(p, col, t);
            }
        }
        // Scale multipliers and rank-1 update the rest of the panel.
        let piv = a.get(j, j);
        for i in j + 1..m {
            let l = a.get(i, j) / piv;
            a.set(i, j, l);
        }
        for col in j + 1..j0 + nb {
            let ujc = a.get(j, col);
            if ujc == 0.0 {
                continue;
            }
            for i in j + 1..m {
                let v = a.get(i, col) - a.get(i, j) * ujc;
                a.set(i, col, v);
            }
        }
    }
    Ok(pivots)
}

/// Blocked right-looking LU: factor `a` in place (L unit-lower, U upper),
/// returning pivots and the accounting report. `nb` is HPL's NB.
pub fn lu_factor_blocked(
    blas: &Blas,
    a: &mut Mat<f64>,
    nb: usize,
) -> Result<(Vec<usize>, LuReport)> {
    let n = a.rows();
    ensure!(a.cols() == n, "square matrices only (HPL solves N×N)");
    let mut report = LuReport::default();
    let t0 = std::time::Instant::now();
    let model = crate::epiphany::timing::CalibratedModel::default();
    let mut pivots = Vec::with_capacity(n);

    let mut j0 = 0usize;
    while j0 < n {
        let jb = nb.min(n - j0);
        // --- panel (host level-1/2; projected at the calibrated rate) ----
        let mut p = panel_factor(a, j0, jb)?;
        pivots.append(&mut p);
        let panel_flops = {
            let rows = (n - j0) as f64;
            // ~ Σ over jb columns of 2·rows·jb ≈ rows·jb²
            rows * (jb * jb) as f64
        };
        report.host_flops += panel_flops;
        report.host_projected_s += panel_flops / (model.host_level2_f64_gflops * 1e9);

        let rest0 = j0 + jb;
        if rest0 < n {
            // --- U12 = L11⁻¹ · A12 (unit-lower trsm, host) ---------------
            let l11 = a.view().sub(j0, j0, jb, jb).to_mat();
            let mut a12 = a.view().sub(j0, rest0, jb, n - rest0).to_mat();
            level3::trsm_left(true, Trans::N, true, 1.0, l11.view(), &mut a12);
            for j in 0..n - rest0 {
                for i in 0..jb {
                    a.set(j0 + i, rest0 + j, a12.get(i, j));
                }
            }
            let trsm_flops = (jb * jb) as f64 * (n - rest0) as f64;
            report.host_flops += trsm_flops;
            report.host_projected_s += trsm_flops / (model.host_trsm_f64_gflops * 1e9);

            // --- A22 -= L21 · U12 (the Epiphany false dgemm) --------------
            let l21 = a.view().sub(rest0, j0, n - rest0, jb).to_mat();
            let mut a22 = a.view().sub(rest0, rest0, n - rest0, n - rest0).to_mat();
            let rep = blas.dgemm_false(
                Trans::N,
                Trans::N,
                -1.0,
                l21.view(),
                a12.view(),
                1.0,
                &mut a22,
            )?;
            for j in 0..n - rest0 {
                for i in 0..n - rest0 {
                    a.set(rest0 + i, rest0 + j, a22.get(i, j));
                }
            }
            report.gemm_projected_s += rep.projected_s;
            report.gemm_flops += rep.flops;
        }
        j0 += jb;
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok((pivots, report))
}

/// Solve A·x = b given the factored matrix + pivots (forward/backward
/// substitution — host level-2).
pub fn lu_solve(a: &Mat<f64>, pivots: &[usize], b: &[f64]) -> Vec<f64> {
    let _n = a.rows();
    let mut x = b.to_vec();
    // Apply pivots in order.
    for (j, &p) in pivots.iter().enumerate() {
        if p != j {
            x.swap(j, p);
        }
    }
    // L y = Pb (unit lower).
    crate::blis::level2::trsv(true, Trans::N, true, a.view(), &mut x);
    // U x = y.
    crate::blis::level2::trsv(false, Trans::N, false, a.view(), &mut x);
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::XorShiftRng;

    fn blas() -> Blas {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        Blas::new(svc)
    }

    /// HPL-style random diagonally-balanced system.
    fn system(n: usize, seed: u64) -> (Mat<f64>, Vec<f64>) {
        let mut rng = XorShiftRng::new(seed);
        let a = Mat::<f64>::from_fn(n, n, |_, _| rng.next_unit());
        let b: Vec<f64> = (0..n).map(|_| rng.next_unit()).collect();
        (a, b)
    }

    #[test]
    fn factor_and_solve_small() {
        let blas = blas();
        let n = 96;
        let (a0, b) = system(n, 1);
        let mut a = a0.clone();
        let (piv, _rep) = lu_factor_blocked(&blas, &mut a, 32).unwrap();
        let x = lu_solve(&a, &piv, &b);
        // Residual ‖Ax − b‖∞ scaled: single-precision-made error expected
        // (the gemm update ran through the false dgemm).
        let mut worst = 0.0f64;
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..n {
                acc += a0.get(i, j) * x[j];
            }
            worst = worst.max((acc - b[i]).abs());
        }
        assert!(worst < 1e-2, "residual {worst}");
        assert!(worst > 1e-12, "suspiciously exact for f32 compute: {worst}");
    }

    #[test]
    fn report_attributes_flops() {
        let blas = blas();
        let n = 256;
        let (mut a, _b) = system(n, 2);
        let (_piv, rep) = lu_factor_blocked(&blas, &mut a, 64).unwrap();
        assert!(rep.gemm_flops > 0.0);
        assert!(rep.host_flops > 0.0);
        // gemm dominates flops at this shape but host dominates projected
        // time at small n — the §4.3 effect in miniature.
        assert!(rep.gemm_flops > rep.host_flops);
        assert!(rep.gemm_projected_s > 0.0 && rep.host_projected_s > 0.0);
    }

    #[test]
    fn pivoting_handles_zero_leading_element() {
        let blas = blas();
        let mut a = Mat::<f64>::from_col_major(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        let (piv, _) = lu_factor_blocked(&blas, &mut a, 2).unwrap();
        assert_eq!(piv[0], 1, "must pivot away from the zero");
        let x = lu_solve(&a, &piv, &[2.0, 3.0]);
        // A = [[0,1],[1,0]] ⇒ x = [3, 2].
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn singular_matrix_rejected() {
        let blas = blas();
        let mut a = Mat::<f64>::zeros(4, 4);
        assert!(lu_factor_blocked(&blas, &mut a, 2).is_err());
    }
}
