//! High Performance Linpack (paper §4.3, Table 7): solve A·x = b with
//! blocked LU + partial pivoting, built entirely on the generated BLAS —
//! dgemm through the "false dgemm" Epiphany path, panel factorization and
//! triangular solves through the unaccelerated host level-1/2 ops (whose
//! low rate is the paper's explanation for the 0.495 GFLOPS result).

pub mod cholesky;
pub mod driver;
pub mod lu;
pub mod residual;

pub use driver::{HplConfig, HplResult};
pub use cholesky::{potrf_lower, potrs_lower};
pub use lu::{lu_factor_blocked, lu_solve};
