//! The HPL driver: generate the random system, factor + solve on the
//! generated BLAS, time it, compute the residual — the paper's Table 7
//! run (N=4608, NB=768, P=Q=1, one node).

use super::lu::{lu_factor_blocked, lu_solve, LuReport};
use super::residual::{hpl_residual, HplResidual};
use crate::blis::Blas;
use crate::linalg::{Mat, XorShiftRng};
use anyhow::Result;

/// HPL.dat-style configuration (single node, 1×1 grid).
#[derive(Clone, Copy, Debug)]
pub struct HplConfig {
    /// Problem order N.
    pub n: usize,
    /// Block size NB.
    pub nb: usize,
    /// Process grid — fixed 1×1 in the paper's run; kept for config
    /// fidelity (validated).
    pub p: usize,
    /// Process-grid columns (see `p`).
    pub q: usize,
    /// Seed for the random system generator.
    pub seed: u64,
}

impl HplConfig {
    /// The paper's Table 7 parameters.
    pub fn paper() -> Self {
        HplConfig { n: 4608, nb: 768, p: 1, q: 1, seed: 0xB1A5 }
    }

    /// Same shape scaled down for tests/CI.
    pub fn small(n: usize, nb: usize) -> Self {
        HplConfig { n, nb, p: 1, q: 1, seed: 0xB1A5 }
    }

    /// LU + solve flop count, HPL's formula: 2/3·N³ + 3/2·N².
    pub fn flops(&self) -> f64 {
        let n = self.n as f64;
        2.0 / 3.0 * n * n * n + 1.5 * n * n
    }
}

/// Table 7's rows.
#[derive(Clone, Copy, Debug)]
pub struct HplResult {
    /// The configuration that produced this row.
    pub config: HplConfig,
    /// Projected-Parallella seconds (Table 7 "Time").
    pub projected_s: f64,
    /// Projected GFLOPS (Table 7 "GFLOPS/s").
    pub projected_gflops: f64,
    /// Wall-clock on this machine.
    pub wall_s: f64,
    /// Both residual flavours (Table 7's check rows).
    pub residual: HplResidual,
    /// The factorization's timing/flop breakdown.
    pub lu: LuReport,
}

/// Run the benchmark.
pub fn run_hpl(blas: &Blas, config: HplConfig) -> Result<HplResult> {
    anyhow::ensure!(config.p == 1 && config.q == 1, "only a 1×1 process grid (paper Table 7)");
    let n = config.n;
    let mut rng = XorShiftRng::new(config.seed);
    // HPL generates a uniform random matrix and rhs.
    let a0 = Mat::<f64>::from_fn(n, n, |_, _| rng.next_unit());
    let b: Vec<f64> = (0..n).map(|_| rng.next_unit()).collect();

    let t0 = std::time::Instant::now();
    let mut a = a0.clone();
    let (piv, lu) = lu_factor_blocked(blas, &mut a, config.nb)?;
    let x = lu_solve(&a, &piv, &b);
    let wall_s = t0.elapsed().as_secs_f64();

    // Projected time: accelerated gemm + host panel/trsm + solve (host
    // level-2 at the calibrated rate).
    let model = crate::epiphany::timing::CalibratedModel::default();
    let solve_flops = 2.0 * (n * n) as f64;
    let projected_s =
        lu.total_projected_s() + solve_flops / (model.host_level2_f64_gflops * 1e9);
    let residual = hpl_residual(&a0, &x, &b);
    Ok(HplResult {
        config,
        projected_s,
        projected_gflops: config.flops() / projected_s / 1e9,
        wall_s,
        residual,
        lu,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};

    fn blas() -> Blas {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        Blas::new(svc)
    }

    #[test]
    fn small_hpl_run_is_single_precision_correct() {
        let blas = blas();
        let res = run_hpl(&blas, HplConfig::small(192, 96)).unwrap();
        // Raw residue in the f32 band (paper: 2.34e-6 at N=4608).
        assert!(res.residual.raw > 1e-12 && res.residual.raw < 1e-4, "raw {}", res.residual.raw);
        assert!(res.projected_gflops > 0.0);
        assert!(res.wall_s > 0.0);
    }

    #[test]
    fn non_unit_grid_rejected() {
        let blas = blas();
        let mut cfg = HplConfig::small(64, 32);
        cfg.p = 2;
        assert!(run_hpl(&blas, cfg).is_err());
    }

    #[test]
    fn flops_formula() {
        let cfg = HplConfig::paper();
        // 2/3·4608³ ≈ 65.2 GFLOP.
        assert!((cfg.flops() / 1e9 - 65.24).abs() < 0.1);
    }
}
