//! An eSDK-like ("e-hal") host driver API over the simulated chip.
//!
//! The paper's micro-kernel is written against Adapteva's eSDK: open the
//! device, define workgroups, load kernels, `e_write`/`e_read` the shared
//! window, signal, and finalize. Reproducing that API surface keeps the
//! host code (`host::microkernel`) structurally faithful to the paper's C —
//! including the eSDK wart the paper reports: `e_init`/`e_finalize` cannot
//! be safely called many times by one process, which is exactly why the
//! service process exists (§3.2). The driver enforces that here: a process
//! (in our model: a [`EHal`] value) that re-initializes more than
//! [`MAX_REINIT`] times starts failing, so tests can demonstrate the
//! failure mode the paper designed around.

use crate::epiphany::kernel::{Command, KernelGeometry};
use crate::epiphany::timing::CalibratedModel;
use crate::epiphany::Chip;
use anyhow::{bail, ensure, Result};

/// How many `e_init` cycles one process survives (the paper found "some of
/// the initialize/finalize functions had technical problems when called
/// many times by the same process"; the exact count is not documented —
/// the simulator picks a small number so the failure is reproducible).
pub const MAX_REINIT: usize = 8;

/// Seconds charged per `e_init` + program load + workgroup setup — the
/// "take a lot of time" cost (§3.2) that motivates the resident service.
pub const INIT_COST_S: f64 = 0.85;
/// Seconds charged per `e_finalize`.
pub const FINALIZE_COST_S: f64 = 0.12;

/// Device state machine.
enum DevState {
    Closed,
    Open(Box<Chip>),
}

/// The e-hal driver handle: one per OS process in the paper's world.
pub struct EHal {
    state: DevState,
    model: CalibratedModel,
    init_count: usize,
    /// Projected seconds spent in init/finalize (fed to the timing story).
    pub overhead_s: f64,
}

impl EHal {
    /// A closed driver handle pricing its calls with `model`.
    pub fn new(model: CalibratedModel) -> Self {
        EHal { state: DevState::Closed, model, init_count: 0, overhead_s: 0.0 }
    }

    /// `e_init` + `e_reset` + workgroup + program load, collapsed: boots the
    /// chip with the kernel for `geom`.
    pub fn e_init(&mut self, geom: KernelGeometry) -> Result<()> {
        ensure!(matches!(self.state, DevState::Closed), "e_init on an open device");
        self.init_count += 1;
        if self.init_count > MAX_REINIT {
            // The eSDK failure mode the service process exists to avoid.
            bail!(
                "e_init failed after {} re-initializations in one process \
                 (eSDK init/finalize instability, paper §3.2)",
                self.init_count - 1
            );
        }
        self.overhead_s += INIT_COST_S;
        self.state = DevState::Open(Box::new(Chip::new(self.model.clone(), geom)?));
        Ok(())
    }

    /// `e_finalize`: free HC-RAM, close the device.
    pub fn e_finalize(&mut self) -> Result<()> {
        ensure!(matches!(self.state, DevState::Open(_)), "e_finalize on a closed device");
        self.overhead_s += FINALIZE_COST_S;
        self.state = DevState::Closed;
        Ok(())
    }

    /// Whether the device is currently initialized.
    pub fn is_open(&self) -> bool {
        matches!(self.state, DevState::Open(_))
    }

    fn chip_mut(&mut self) -> Result<&mut Chip> {
        match &mut self.state {
            DevState::Open(c) => Ok(c),
            DevState::Closed => bail!("device not initialized (call e_init)"),
        }
    }

    /// The booted chip; errs when the device is closed.
    pub fn chip(&self) -> Result<&Chip> {
        match &self.state {
            DevState::Open(c) => Ok(c),
            DevState::Closed => bail!("device not initialized (call e_init)"),
        }
    }

    /// `e_write` of an A panel into double buffer `selector`.
    pub fn e_write_a(&mut self, selector: usize, data: &[f32]) -> Result<()> {
        self.chip_mut()?.host_write_a_panel(selector, data);
        Ok(())
    }

    /// `e_write` of a B panel into double buffer `selector`.
    pub fn e_write_b(&mut self, selector: usize, data: &[f32]) -> Result<()> {
        self.chip_mut()?.host_write_b_panel(selector, data);
        Ok(())
    }

    /// Set command + selector and signal the workgroup to run one Task
    /// (the host-side "start" + the chip-side task, collapsed; the timing
    /// model layers the upload/compute overlap separately).
    pub fn e_signal_task(&mut self, command: Command, selector: usize) -> Result<()> {
        self.chip_mut()?.run_task(command, selector)
    }

    /// `e_read` of the result window (the slow HC-RAM read path, §5.2).
    pub fn e_read_out(&mut self, out: &mut [f32]) -> Result<()> {
        let chip = self.chip_mut()?;
        chip.host_read_out(out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_finalize_lifecycle() {
        let mut hal = EHal::new(CalibratedModel::default());
        assert!(!hal.is_open());
        hal.e_init(KernelGeometry::paper()).unwrap();
        assert!(hal.is_open());
        assert!(hal.e_init(KernelGeometry::paper()).is_err(), "double init");
        hal.e_finalize().unwrap();
        assert!(!hal.is_open());
        assert!(hal.e_finalize().is_err(), "double finalize");
    }

    #[test]
    fn repeated_reinit_eventually_fails() {
        // The eSDK instability the paper works around with the service
        // process: init/finalize many times in one process breaks.
        let mut hal = EHal::new(CalibratedModel::default());
        for _ in 0..MAX_REINIT {
            hal.e_init(KernelGeometry::paper()).unwrap();
            hal.e_finalize().unwrap();
        }
        assert!(hal.e_init(KernelGeometry::paper()).is_err());
    }

    #[test]
    fn init_overhead_accumulates() {
        let mut hal = EHal::new(CalibratedModel::default());
        hal.e_init(KernelGeometry::paper()).unwrap();
        hal.e_finalize().unwrap();
        assert!((hal.overhead_s - (INIT_COST_S + FINALIZE_COST_S)).abs() < 1e-12);
    }

    #[test]
    fn ops_require_open_device() {
        let mut hal = EHal::new(CalibratedModel::default());
        assert!(hal.e_write_a(0, &[]).is_err());
        let mut out = [];
        assert!(hal.e_read_out(&mut out).is_err());
    }
}
