//! Top-level handle: boot the service with a chosen backend and hand out
//! the generated BLAS — the "library object" a downstream user holds.

use crate::blis::{Blas, BlasLibrary};
use crate::epiphany::kernel::KernelGeometry;
use crate::epiphany::timing::CalibratedModel;
use crate::host::service::{ServiceBackend, ServiceHandle};
use anyhow::Result;
use std::sync::Arc;

/// Which engine computes the heavy part.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Functional Epiphany-16 simulator (exact paper dataflow; the
    /// offline default — always available).
    Simulator,
    /// AOT jax+pallas artifact via PJRT. Requires the `pjrt` cargo
    /// feature and `make artifacts`; boots with an error otherwise.
    Pjrt,
    /// Naive host loop (the paper's reference baseline).
    HostRef,
}

impl BackendKind {
    fn service(self) -> ServiceBackend {
        match self {
            BackendKind::Simulator => ServiceBackend::Simulator,
            BackendKind::Pjrt => ServiceBackend::Pjrt,
            BackendKind::HostRef => ServiceBackend::HostRef,
        }
    }
}

/// Builder for [`Platform`].
pub struct PlatformBuilder {
    backend: BackendKind,
    model: CalibratedModel,
    geom: KernelGeometry,
}

impl PlatformBuilder {
    pub fn backend(mut self, b: BackendKind) -> Self {
        self.backend = b;
        self
    }

    pub fn model(mut self, m: CalibratedModel) -> Self {
        self.model = m;
        self
    }

    pub fn geometry(mut self, g: KernelGeometry) -> Self {
        self.geom = g;
        self
    }

    pub fn build(self) -> Result<Platform> {
        let svc = ServiceHandle::spawn(self.backend.service(), self.model.clone(), self.geom)?;
        Ok(Platform { blas: Arc::new(Blas::new(svc)), model: self.model, backend: self.backend })
    }
}

/// A booted Parallella-BLAS stack: resident service + generated BLAS.
pub struct Platform {
    blas: Arc<Blas>,
    pub model: CalibratedModel,
    pub backend: BackendKind,
}

impl Platform {
    pub fn builder() -> PlatformBuilder {
        PlatformBuilder {
            backend: BackendKind::Simulator,
            model: CalibratedModel::default(),
            geom: KernelGeometry::paper(),
        }
    }

    pub fn blas(&self) -> &Blas {
        &self.blas
    }

    /// A shared handle to the descriptor core — what
    /// [`Blas::submit`](crate::blis::Blas::submit) tickets are issued
    /// against.
    pub fn blas_handle(&self) -> Arc<Blas> {
        Arc::clone(&self.blas)
    }

    /// The classic FORTRAN-style surface (`sgemm`, `saxpy`, …) over this
    /// platform's descriptor core.
    pub fn library(&self) -> BlasLibrary {
        BlasLibrary::new(Arc::clone(&self.blas))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::Trans;
    use crate::linalg::{max_scaled_err, Mat};

    #[test]
    fn build_and_multiply() {
        let plat = Platform::builder().backend(BackendKind::Simulator).build().unwrap();
        let a = Mat::<f32>::randn(100, 50, 1);
        let b = Mat::<f32>::randn(50, 80, 2);
        let mut c = Mat::<f32>::zeros(100, 80);
        plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).unwrap();
        let mut want = Mat::<f64>::zeros(100, 80);
        crate::blis::level3::gemm_host(
            Trans::N, Trans::N, 1.0, a.cast::<f64>().view(), b.cast::<f64>().view(), 0.0, &mut want,
        );
        assert!(max_scaled_err(c.view(), want.view()) < 1e-5);
    }
}
