//! Top-level handle: boot a chip pool with a chosen backend and hand out
//! the generated BLAS — the "library object" a downstream user holds.

use crate::blis::{autotune, AutotuneConfig, Blas, BlasLibrary, TunedParams};
use crate::epiphany::kernel::KernelGeometry;
use crate::epiphany::timing::CalibratedModel;
use crate::host::pool::{ChipPool, ShardPolicy};
use crate::host::service::ServiceBackend;
use anyhow::Result;
use std::sync::Arc;

/// Which engine computes the heavy part.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Functional Epiphany-16 simulator (exact paper dataflow; the
    /// offline default — always available).
    Simulator,
    /// AOT jax+pallas artifact via PJRT. Requires the `pjrt` cargo
    /// feature and `make artifacts`; boots with an error otherwise.
    Pjrt,
    /// Naive host loop (the paper's reference baseline).
    HostRef,
}

impl BackendKind {
    fn service(self) -> ServiceBackend {
        match self {
            BackendKind::Simulator => ServiceBackend::Simulator,
            BackendKind::Pjrt => ServiceBackend::Pjrt,
            BackendKind::HostRef => ServiceBackend::HostRef,
        }
    }
}

/// Builder for [`Platform`].
pub struct PlatformBuilder {
    backend: BackendKind,
    model: CalibratedModel,
    geom: KernelGeometry,
    chips: usize,
    policy: ShardPolicy,
    panel_cache_bytes: usize,
    autotune: Option<AutotuneConfig>,
}

impl PlatformBuilder {
    /// Select the compute engine (simulator by default).
    pub fn backend(mut self, b: BackendKind) -> Self {
        self.backend = b;
        self
    }

    /// Override the calibrated timing model.
    pub fn model(mut self, m: CalibratedModel) -> Self {
        self.model = m;
        self
    }

    /// Override the µ-kernel geometry.
    pub fn geometry(mut self, g: KernelGeometry) -> Self {
        self.geom = g;
        self
    }

    /// Boot `n` simulated Epiphany chips instead of one; level-3 gemms
    /// shard across them per the [`ShardPolicy`]. Values below 1 are
    /// treated as 1 (the degenerate plan, bit-identical to single-chip).
    pub fn chips(mut self, n: usize) -> Self {
        self.chips = n.max(1);
        self
    }

    /// How level-3 work splits across the pool (default:
    /// [`ShardPolicy::ColumnPanels`]).
    pub fn shard_policy(mut self, p: ShardPolicy) -> Self {
        self.policy = p;
        self
    }

    /// Byte budget for the packed-A panel cache (see
    /// [`crate::mem::PanelCache`]): repeated gemms over the same A skip
    /// `pack_a` on verified hits. The default budget of 0 disables the
    /// cache and keeps the gemm driver bit-identical to a cacheless
    /// build — no hashing, no lookups.
    pub fn panel_cache_bytes(mut self, budget: usize) -> Self {
        self.panel_cache_bytes = budget;
        self
    }

    /// Run the blocking autotuner (see [`crate::blis::autotune`]) before
    /// boot: the pool comes up with the tuned [`KernelGeometry`] and the
    /// BLAS with the tuned [`crate::blis::BlisContext`], overriding any
    /// explicit [`PlatformBuilder::geometry`]. The search result is kept
    /// on [`Platform::tuned`] for reporting.
    pub fn autotune(mut self, cfg: AutotuneConfig) -> Self {
        self.autotune = Some(cfg);
        self
    }

    /// Boot the pool and instantiate the BLAS over it.
    pub fn build(self) -> Result<Platform> {
        let tuned = self.autotune.as_ref().map(|cfg| autotune(&self.model, cfg));
        let geom = tuned.as_ref().map(TunedParams::geometry).unwrap_or(self.geom);
        let pool =
            ChipPool::spawn(self.chips, self.backend.service(), self.model.clone(), geom)?;
        let mut blas = Blas::with_pool(pool, self.policy);
        blas.set_panel_cache(self.panel_cache_bytes);
        if let Some(t) = &tuned {
            blas.ctx = t.context();
        }
        Ok(Platform {
            blas: Arc::new(blas),
            model: self.model,
            backend: self.backend,
            tuned,
        })
    }
}

/// A booted Parallella-BLAS stack: resident service + generated BLAS.
pub struct Platform {
    blas: Arc<Blas>,
    /// The calibrated timing model the pool was booted with.
    pub model: CalibratedModel,
    /// Which engine computes the heavy part.
    pub backend: BackendKind,
    /// The autotuner's result when the builder ran with
    /// [`PlatformBuilder::autotune`] (`None` otherwise).
    pub tuned: Option<TunedParams>,
}

impl Platform {
    /// Start configuring a stack (simulator backend, one chip,
    /// column-panel sharding by default).
    pub fn builder() -> PlatformBuilder {
        PlatformBuilder {
            backend: BackendKind::Simulator,
            model: CalibratedModel::default(),
            geom: KernelGeometry::paper(),
            chips: 1,
            policy: ShardPolicy::default(),
            panel_cache_bytes: 0,
            autotune: None,
        }
    }

    /// The generated BLAS over this platform's chip pool.
    pub fn blas(&self) -> &Blas {
        &self.blas
    }

    /// Number of chips in the booted pool.
    pub fn chips(&self) -> usize {
        self.blas.chips()
    }

    /// A shared handle to the descriptor core — what
    /// [`Blas::submit`](crate::blis::Blas::submit) tickets are issued
    /// against.
    pub fn blas_handle(&self) -> Arc<Blas> {
        Arc::clone(&self.blas)
    }

    /// The classic FORTRAN-style surface (`sgemm`, `saxpy`, …) over this
    /// platform's descriptor core.
    pub fn library(&self) -> BlasLibrary {
        BlasLibrary::new(Arc::clone(&self.blas))
    }

    /// Indices of the pool's chips currently marked healthy. A chip
    /// leaves this set when a service call on it errors, panics, or
    /// overruns the batcher's health deadline; it returns after a
    /// successful [`Platform::probe_chip`].
    pub fn healthy_chips(&self) -> Vec<usize> {
        self.blas.pool().healthy_chips()
    }

    /// Probe chip `i` with a real service-thread round trip and re-admit
    /// it on success (see [`crate::host::pool::ChipPool::probe`]).
    pub fn probe_chip(&self, i: usize) -> Result<()> {
        self.blas.pool().probe(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::Trans;
    use crate::linalg::{max_scaled_err, Mat};

    #[test]
    fn build_and_multiply() {
        let plat = Platform::builder().backend(BackendKind::Simulator).build().unwrap();
        let a = Mat::<f32>::randn(100, 50, 1);
        let b = Mat::<f32>::randn(50, 80, 2);
        let mut c = Mat::<f32>::zeros(100, 80);
        plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).unwrap();
        let mut want = Mat::<f64>::zeros(100, 80);
        crate::blis::level3::gemm_host(
            Trans::N, Trans::N, 1.0, a.cast::<f64>().view(), b.cast::<f64>().view(), 0.0, &mut want,
        );
        assert!(max_scaled_err(c.view(), want.view()) < 1e-5);
    }

    #[test]
    fn panel_cache_knob_is_bit_identical_and_hits() {
        let plain = Platform::builder().build().unwrap();
        let cached = Platform::builder().panel_cache_bytes(8 << 20).build().unwrap();
        assert!(plain.blas().panel_cache().is_none(), "cache is off by default");
        let a = Mat::<f32>::randn(100, 50, 3);
        let b = Mat::<f32>::randn(50, 80, 4);
        let mut c0 = Mat::<f32>::zeros(100, 80);
        let mut c1 = Mat::<f32>::zeros(100, 80);
        for _ in 0..2 {
            plain.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c0).unwrap();
            cached.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c1).unwrap();
            assert_eq!(c0.as_slice(), c1.as_slice(), "cache on/off must be bit-identical");
        }
        let s = cached.blas().panel_cache().unwrap().stats();
        assert!(s.hits >= 1, "second pass re-uses the packed panel: {s:?}");
    }

    #[test]
    fn autotuned_platform_builds_and_multiplies() {
        let plat = Platform::builder()
            .autotune(AutotuneConfig::for_workload(256, 256, 256))
            .build()
            .unwrap();
        let t = plat.tuned.as_ref().expect("builder ran the autotuner");
        assert_eq!(plat.blas().ctx.mr, t.geometry().m, "tuned mr flows into the BLAS");
        assert_eq!(plat.blas().ctx.nr, t.geometry().n, "tuned nr flows into the BLAS");
        let a = Mat::<f32>::randn(100, 60, 1);
        let b = Mat::<f32>::randn(60, 90, 2);
        let mut c = Mat::<f32>::zeros(100, 90);
        plat.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).unwrap();
        let mut want = Mat::<f64>::zeros(100, 90);
        crate::blis::level3::gemm_host(
            Trans::N, Trans::N, 1.0, a.cast::<f64>().view(), b.cast::<f64>().view(), 0.0, &mut want,
        );
        assert!(max_scaled_err(c.view(), want.view()) < 1e-5);
    }

    #[test]
    fn health_surface_forwards_to_pool() {
        let p = Platform::builder().chips(2).build().unwrap();
        assert_eq!(p.healthy_chips(), vec![0, 1]);
        p.blas().pool().mark_unhealthy(1);
        assert_eq!(p.healthy_chips(), vec![0]);
        p.probe_chip(1).unwrap();
        assert_eq!(p.healthy_chips(), vec![0, 1]);
        assert!(p.probe_chip(5).is_err(), "probe is range-checked");
    }

    #[test]
    fn pooled_platform_matches_single_chip() {
        let p1 = Platform::builder().build().unwrap();
        let p4 = Platform::builder().chips(4).build().unwrap();
        assert_eq!((p1.chips(), p4.chips()), (1, 4));
        let a = Mat::<f32>::randn(100, 50, 1);
        let b = Mat::<f32>::randn(50, 600, 2); // 3 column tiles to shard
        let mut c1 = Mat::<f32>::zeros(100, 600);
        let mut c4 = Mat::<f32>::zeros(100, 600);
        p1.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c1).unwrap();
        p4.blas().sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c4).unwrap();
        assert_eq!(c1.as_slice(), c4.as_slice(), "pooled gemm must be bit-identical");
    }
}
