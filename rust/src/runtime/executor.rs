//! PJRT execution of the AOT gemm artifacts.
//!
//! Layout contract (zero-copy by construction, see python/compile/model.py):
//! the artifact takes `a1` as a logical (K, m) row-major array — which is
//! byte-identical to the column-major (m, K) panel the BLIS packing layer
//! produces — `b1` as logical (K, n) row-major (the paper's row-major B
//! panel as-is), and `c` as logical (n, m) row-major (= column-major m × n).
//! No transposition happens on either side of the FFI boundary.
//!
//! The whole executor is gated behind the `pjrt` cargo feature: offline
//! builds (the default) get a stub with the same API whose constructors
//! fail, so every call site — the service boot, the experiments, the CLI —
//! compiles unconditionally and degrades to a clear runtime error.

#[cfg(feature = "pjrt")]
mod imp {
    use crate::runtime::registry::{ArtifactEntry, ArtifactRegistry};
    use anyhow::{bail, Context, Result};
    use std::collections::HashMap;

    /// A compiled sgemm/false-dgemm artifact.
    pub struct SgemmArtifact {
        /// The manifest entry this executable was compiled from.
        pub entry: ArtifactEntry,
        exe: xla::PjRtLoadedExecutable,
    }

    /// Owns the PJRT CPU client and a cache of compiled executables.
    ///
    /// Not `Send`: PJRT handles live and die on the thread that created
    /// them, which in this architecture is the Epiphany service thread
    /// (the paper's separate "service process" — §3.2).
    pub struct GemmExecutor {
        client: xla::PjRtClient,
        registry: ArtifactRegistry,
        cache: HashMap<String, SgemmArtifact>,
        /// µ-kernel tile rows (fixed per instantiation, 192 in the paper).
        pub m: usize,
        /// µ-kernel tile columns (256 in the paper).
        pub n: usize,
    }

    impl GemmExecutor {
        /// Create the CPU client and index the artifact registry.
        pub fn new(registry: ArtifactRegistry, m: usize, n: usize) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(GemmExecutor { client, registry, cache: HashMap::new(), m, n })
        }

        /// Create with the discovered registry and paper tile dims.
        pub fn discover() -> Result<Self> {
            Self::new(ArtifactRegistry::discover()?, 192, 256)
        }

        /// The artifact manifest this executor serves from.
        pub fn registry(&self) -> &ArtifactRegistry {
            &self.registry
        }

        /// Compile every manifest artifact up front (service boot) so the
        /// request path never pays PJRT compilation latency — the moral
        /// equivalent of the paper's service process pre-loading the
        /// Epiphany kernel before any µ-kernel call arrives.
        pub fn warmup(&mut self) -> Result<usize> {
            let names: Vec<String> =
                self.registry.entries().iter().map(|e| e.name.clone()).collect();
            for name in &names {
                self.artifact(name)?;
            }
            Ok(names.len())
        }

        /// Compile (or fetch cached) an artifact by name.
        pub fn artifact(&mut self, name: &str) -> Result<&SgemmArtifact> {
            if !self.cache.contains_key(name) {
                let entry = self
                    .registry
                    .get(name)
                    .with_context(|| format!("artifact {name:?} not in manifest"))?
                    .clone();
                let proto = xla::HloModuleProto::from_text_file(
                    entry.path.to_str().context("artifact path not utf-8")?,
                )
                .with_context(|| format!("parsing HLO text {}", entry.path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("PJRT compile of {name}"))?;
                self.cache.insert(name.to_string(), SgemmArtifact { entry, exe });
            }
            Ok(&self.cache[name])
        }

        /// One sgemm artifact call at its fixed K:
        /// `c_out = alpha·a1·b1 + beta·c_in` over the µ-kernel tile.
        ///
        /// * `a_panel`: column-major m × k (len m·k)
        /// * `b_panel`: row-major k × n (len k·n)
        /// * `c_panel`: column-major m × n (len m·n)
        pub fn sgemm_call(
            &mut self,
            k: usize,
            alpha: f32,
            a_panel: &[f32],
            b_panel: &[f32],
            beta: f32,
            c_panel: &[f32],
        ) -> Result<Vec<f32>> {
            let (m, n) = (self.m, self.n);
            if a_panel.len() != m * k || b_panel.len() != k * n || c_panel.len() != m * n {
                bail!(
                    "sgemm_call shape mismatch: k={k}, a={}, b={}, c={}",
                    a_panel.len(),
                    b_panel.len(),
                    c_panel.len()
                );
            }
            let name = format!("sgemm_inner_k{k}");
            let art = self.artifact(&name)?;
            let alpha_l = xla::Literal::from(alpha);
            let beta_l = xla::Literal::from(beta);
            // col-major (m, k) bytes == row-major (k, m) logical array.
            let a_l = xla::Literal::vec1(a_panel).reshape(&[k as i64, m as i64])?;
            let b_l = xla::Literal::vec1(b_panel).reshape(&[k as i64, n as i64])?;
            let c_l = xla::Literal::vec1(c_panel).reshape(&[n as i64, m as i64])?;
            let result = art.exe.execute::<xla::Literal>(&[alpha_l, a_l, b_l, beta_l, c_l])?[0][0]
                .to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }

        /// One false-dgemm artifact call (f64 API, f32 compute inside).
        pub fn false_dgemm_call(
            &mut self,
            k: usize,
            alpha: f64,
            a_panel: &[f64],
            b_panel: &[f64],
            beta: f64,
            c_panel: &[f64],
        ) -> Result<Vec<f64>> {
            let (m, n) = (self.m, self.n);
            if a_panel.len() != m * k || b_panel.len() != k * n || c_panel.len() != m * n {
                bail!("false_dgemm_call shape mismatch (k={k})");
            }
            let name = format!("false_dgemm_k{k}");
            let art = self.artifact(&name)?;
            let alpha_l = xla::Literal::from(alpha);
            let beta_l = xla::Literal::from(beta);
            let a_l = xla::Literal::vec1(a_panel).reshape(&[k as i64, m as i64])?;
            let b_l = xla::Literal::vec1(b_panel).reshape(&[k as i64, n as i64])?;
            let c_l = xla::Literal::vec1(c_panel).reshape(&[n as i64, m as i64])?;
            let result = art.exe.execute::<xla::Literal>(&[alpha_l, a_l, b_l, beta_l, c_l])?[0][0]
                .to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f64>()?)
        }

        /// Plan K-blocking for an arbitrary reduction depth: greedy
        /// descending over available artifact Ks, final remainder
        /// zero-padded up to the smallest K. Returns `(block_k, padded)`
        /// pairs.
        pub fn plan_k(&self, k_total: usize) -> Vec<(usize, bool)> {
            let ks = self.registry.sgemm_ks();
            let smallest = *ks.last().expect("at least one sgemm artifact");
            let mut plan = Vec::new();
            let mut rem = k_total;
            for &k in &ks {
                while rem >= k {
                    plan.push((k, false));
                    rem -= k;
                }
            }
            if rem > 0 {
                plan.push((smallest, true)); // zero-padded tail block
            }
            plan
        }

        /// `c_out = alpha·(a1·b1) + beta·c_in` for arbitrary K ≥ 1, chaining
        /// artifact calls with the accumulator protocol (first call applies
        /// beta, later calls accumulate with beta = 1).
        pub fn sgemm_arbitrary_k(
            &mut self,
            k_total: usize,
            alpha: f32,
            a_panel: &[f32], // col-major m × k_total
            b_panel: &[f32], // row-major k_total × n
            beta: f32,
            c_panel: &[f32], // col-major m × n
        ) -> Result<Vec<f32>> {
            let (m, n) = (self.m, self.n);
            let plan = self.plan_k(k_total);
            let mut c = c_panel.to_vec();
            let mut k_done = 0usize;
            let mut first = true;
            for (blk, padded) in plan {
                let real = blk.min(k_total - k_done);
                // Slice the panels; zero-pad the tail block if needed.
                let (a_blk, b_blk);
                let (a_store, b_store);
                if padded {
                    let mut a_p = vec![0.0f32; m * blk];
                    a_p[..m * real].copy_from_slice(&a_panel[m * k_done..m * (k_done + real)]);
                    let mut b_p = vec![0.0f32; blk * n];
                    b_p[..real * n].copy_from_slice(&b_panel[n * k_done..n * (k_done + real)]);
                    a_store = a_p;
                    b_store = b_p;
                    a_blk = a_store.as_slice();
                    b_blk = b_store.as_slice();
                } else {
                    a_blk = &a_panel[m * k_done..m * (k_done + blk)];
                    b_blk = &b_panel[n * k_done..n * (k_done + blk)];
                }
                let (call_alpha, call_beta) = if first { (alpha, beta) } else { (alpha, 1.0) };
                c = self.sgemm_call(blk, call_alpha, a_blk, b_blk, call_beta, &c)?;
                first = false;
                k_done += real;
            }
            if first {
                // K = 0 degenerate case: c = beta · c.
                for v in &mut c {
                    *v *= beta;
                }
            }
            Ok(c)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::runtime::registry::{ArtifactEntry, ArtifactRegistry};
    use anyhow::{bail, Result};

    fn unavailable(what: &str) -> anyhow::Error {
        anyhow::anyhow!(
            "{what}: this build has no PJRT runtime (the `pjrt` cargo feature is off); \
             rebuild with `--features pjrt` or use the `sim` backend"
        )
    }

    /// Stub of the compiled-artifact handle (`pjrt` feature off).
    pub struct SgemmArtifact {
        /// The manifest entry the artifact would be compiled from.
        pub entry: ArtifactEntry,
    }

    /// Stub of the PJRT executor (`pjrt` feature off). Constructors fail,
    /// so values of this type never exist at runtime; the methods keep
    /// every call site compiling.
    pub struct GemmExecutor {
        registry: ArtifactRegistry,
        /// µ-kernel tile rows (fixed per instantiation, 192 in the paper).
        pub m: usize,
        /// µ-kernel tile columns (256 in the paper).
        pub n: usize,
    }

    impl GemmExecutor {
        /// Always fails: this build has no PJRT runtime.
        pub fn new(_registry: ArtifactRegistry, _m: usize, _n: usize) -> Result<Self> {
            Err(unavailable("GemmExecutor::new"))
        }

        /// Always fails: this build has no PJRT runtime.
        pub fn discover() -> Result<Self> {
            Err(unavailable("GemmExecutor::discover"))
        }

        /// The artifact manifest this executor would serve from.
        pub fn registry(&self) -> &ArtifactRegistry {
            &self.registry
        }

        /// Always fails: this build has no PJRT runtime.
        pub fn warmup(&mut self) -> Result<usize> {
            Err(unavailable("GemmExecutor::warmup"))
        }

        /// Always fails: this build has no PJRT runtime.
        pub fn artifact(&mut self, name: &str) -> Result<&SgemmArtifact> {
            bail!("artifact {name:?} unavailable: built without the `pjrt` feature")
        }

        /// Stub: no artifacts, so the plan is always empty.
        pub fn plan_k(&self, _k_total: usize) -> Vec<(usize, bool)> {
            Vec::new()
        }

        /// Always fails: this build has no PJRT runtime.
        pub fn sgemm_call(
            &mut self,
            _k: usize,
            _alpha: f32,
            _a_panel: &[f32],
            _b_panel: &[f32],
            _beta: f32,
            _c_panel: &[f32],
        ) -> Result<Vec<f32>> {
            Err(unavailable("GemmExecutor::sgemm_call"))
        }

        /// Always fails: this build has no PJRT runtime.
        pub fn false_dgemm_call(
            &mut self,
            _k: usize,
            _alpha: f64,
            _a_panel: &[f64],
            _b_panel: &[f64],
            _beta: f64,
            _c_panel: &[f64],
        ) -> Result<Vec<f64>> {
            Err(unavailable("GemmExecutor::false_dgemm_call"))
        }

        /// Always fails: this build has no PJRT runtime.
        pub fn sgemm_arbitrary_k(
            &mut self,
            _k_total: usize,
            _alpha: f32,
            _a_panel: &[f32],
            _b_panel: &[f32],
            _beta: f32,
            _c_panel: &[f32],
        ) -> Result<Vec<f32>> {
            Err(unavailable("GemmExecutor::sgemm_arbitrary_k"))
        }
    }
}

pub use imp::{GemmExecutor, SgemmArtifact};

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::linalg::{max_scaled_err, Mat};

    fn executor() -> GemmExecutor {
        GemmExecutor::discover().expect("run `make artifacts` before cargo test")
    }

    /// Pack a col-major (k, n) Mat into a row-major panel.
    fn row_major(b: &Mat<f32>) -> Vec<f32> {
        let (k, n) = (b.rows(), b.cols());
        let mut out = vec![0.0f32; k * n];
        for l in 0..k {
            for j in 0..n {
                out[l * n + j] = b.get(l, j);
            }
        }
        out
    }

    fn oracle(alpha: f32, a: &Mat<f32>, b: &Mat<f32>, beta: f32, c: &Mat<f32>) -> Mat<f32> {
        let (m, n, k) = (a.rows(), b.cols(), a.cols());
        let mut out = Mat::<f64>::zeros(m, n);
        for j in 0..n {
            for l in 0..k {
                for i in 0..m {
                    out.set(i, j, out.get(i, j) + a.get(i, l) as f64 * b.get(l, j) as f64);
                }
            }
        }
        Mat::from_fn(m, n, |i, j| {
            (alpha as f64 * out.get(i, j) + beta as f64 * c.get(i, j) as f64) as f32
        })
    }

    #[test]
    fn artifact_k64_matches_oracle() {
        let mut ex = executor();
        let a = Mat::<f32>::randn(192, 64, 1);
        let b = Mat::<f32>::randn(64, 256, 2);
        let c = Mat::<f32>::randn(192, 256, 3);
        let got =
            ex.sgemm_call(64, 1.5, a.as_slice(), &row_major(&b), -0.5, c.as_slice()).unwrap();
        let got = Mat::from_col_major(192, 256, &got);
        let want = oracle(1.5, &a, &b, -0.5, &c);
        let e = max_scaled_err(got.view(), want.view());
        assert!(e < 1e-5, "err {e}");
    }

    #[test]
    fn chaining_matches_oracle() {
        // K = 576 = 512 + 64: exercises the descending planner.
        let mut ex = executor();
        let a = Mat::<f32>::randn(192, 576, 4);
        let b = Mat::<f32>::randn(576, 256, 5);
        let c = Mat::<f32>::randn(192, 256, 6);
        let got = ex
            .sgemm_arbitrary_k(576, 2.0, a.as_slice(), &row_major(&b), 0.5, c.as_slice())
            .unwrap();
        let got = Mat::from_col_major(192, 256, &got);
        let want = oracle(2.0, &a, &b, 0.5, &c);
        let e = max_scaled_err(got.view(), want.view());
        assert!(e < 3e-5, "err {e}");
    }

    #[test]
    fn ragged_k_zero_pads() {
        // K = 100: 64-block + padded 64-block (36 real columns).
        let mut ex = executor();
        let a = Mat::<f32>::randn(192, 100, 7);
        let b = Mat::<f32>::randn(100, 256, 8);
        let c = Mat::<f32>::zeros(192, 256);
        let got = ex
            .sgemm_arbitrary_k(100, 1.0, a.as_slice(), &row_major(&b), 0.0, c.as_slice())
            .unwrap();
        let got = Mat::from_col_major(192, 256, &got);
        let want = oracle(1.0, &a, &b, 0.0, &c);
        let e = max_scaled_err(got.view(), want.view());
        assert!(e < 3e-5, "err {e}");
    }

    #[test]
    fn plan_k_greedy_descending() {
        let ex = executor();
        assert_eq!(ex.plan_k(4096), vec![(4096, false)]);
        assert_eq!(ex.plan_k(576), vec![(512, false), (64, false)]);
        assert_eq!(ex.plan_k(100), vec![(64, false), (64, true)]);
        assert_eq!(ex.plan_k(64), vec![(64, false)]);
        assert_eq!(ex.plan_k(1), vec![(64, true)]);
    }

    #[test]
    fn false_dgemm_single_precision_result() {
        let mut ex = executor();
        let a = Mat::<f64>::randn(192, 512, 9);
        let b = Mat::<f64>::randn(512, 256, 10);
        let c = Mat::<f64>::randn(192, 256, 11);
        let mut b_rm = vec![0.0f64; 512 * 256];
        for l in 0..512 {
            for j in 0..256 {
                b_rm[l * 256 + j] = b.get(l, j);
            }
        }
        let got = ex.false_dgemm_call(512, 1.0, a.as_slice(), &b_rm, 1.0, c.as_slice()).unwrap();
        let got = Mat::from_col_major(192, 256, &got);
        // f64 oracle: error must be f32-sized (the "false" in false dgemm).
        let mut want = Mat::<f64>::zeros(192, 256);
        for j in 0..256 {
            for l in 0..512 {
                for i in 0..192 {
                    want.set(i, j, want.get(i, j) + a.get(i, l) * b.get(l, j));
                }
            }
        }
        for j in 0..256 {
            for i in 0..192 {
                want.set(i, j, want.get(i, j) + c.get(i, j));
            }
        }
        let e = max_scaled_err(got.view(), want.view());
        assert!(e > 1e-9 && e < 1e-4, "err {e} must be f32-sized, not f64");
    }
}
