//! Artifact discovery: parse `artifacts/manifest.txt` (written by
//! `python -m compile.aot`) and locate the HLO text files.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One row of the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// Artifact name, e.g. `sgemm_inner_k64`.
    pub name: String,
    /// Reduction depth the artifact was lowered for.
    pub k: usize,
    /// "f32" (sgemm) or "f64" (false dgemm).
    pub dtype: String,
    /// Path of the HLO text file.
    pub path: PathBuf,
    /// Content digest recorded by the AOT exporter.
    pub digest: String,
}

/// The set of available artifacts.
#[derive(Clone, Debug, Default)]
pub struct ArtifactRegistry {
    entries: Vec<ArtifactEntry>,
}

impl ArtifactRegistry {
    /// Load from a directory containing `manifest.txt`. The conventional
    /// location is `<repo>/artifacts`; tests and binaries can override via
    /// the `PARALLELLA_BLAS_ARTIFACTS` environment variable.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).with_context(|| {
            format!("reading {} — run `make artifacts` first", manifest.display())
        })?;
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 {
                bail!("malformed manifest row: {line:?}");
            }
            let path = dir.join(parts[3]);
            if !path.exists() {
                bail!("manifest references missing artifact {}", path.display());
            }
            entries.push(ArtifactEntry {
                name: parts[0].to_string(),
                k: parts[1].parse().with_context(|| format!("bad K in {line:?}"))?,
                dtype: parts[2].to_string(),
                path,
                digest: parts[4].to_string(),
            });
        }
        if entries.is_empty() {
            bail!("manifest {} contains no artifacts", manifest.display());
        }
        Ok(ArtifactRegistry { entries })
    }

    /// Default search: `$PARALLELLA_BLAS_ARTIFACTS`, else `./artifacts`,
    /// else `<crate root>/artifacts`.
    pub fn discover() -> Result<Self> {
        if let Ok(dir) = std::env::var("PARALLELLA_BLAS_ARTIFACTS") {
            return Self::load(Path::new(&dir));
        }
        let cwd = Path::new("artifacts");
        if cwd.join("manifest.txt").exists() {
            return Self::load(cwd);
        }
        let crate_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Self::load(&crate_root)
    }

    /// Every manifest row.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Look an artifact up by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All sgemm K variants, descending — the chaining planner wants the
    /// largest block first.
    pub fn sgemm_ks(&self) -> Vec<usize> {
        let mut ks: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.name.starts_with("sgemm_inner_k"))
            .map(|e| e.k)
            .collect();
        ks.sort_unstable_by(|a, b| b.cmp(a));
        ks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Requires `make artifacts` output on disk; only meaningful in a
    // pjrt-enabled environment.
    #[cfg(feature = "pjrt")]
    #[test]
    fn discovers_built_artifacts() {
        let reg = ArtifactRegistry::discover().expect("run `make artifacts` before cargo test");
        assert!(reg.get("sgemm_inner_k64").is_some());
        assert!(reg.get("sgemm_inner_k512").is_some());
        assert!(reg.get("false_dgemm_k512").is_some());
        let ks = reg.sgemm_ks();
        assert!(ks.windows(2).all(|w| w[0] > w[1]), "descending: {ks:?}");
        assert!(ks.contains(&64));
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = ArtifactRegistry::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
