//! The AOT bridge: load `artifacts/*.hlo.txt` (lowered once from the
//! L2 JAX model + L1 Pallas kernel by `make artifacts`) and execute them
//! on the PJRT CPU client from the rust hot path. Python never runs here.

mod executor;
mod registry;

pub use executor::{GemmExecutor, SgemmArtifact};
pub use registry::{ArtifactEntry, ArtifactRegistry};
