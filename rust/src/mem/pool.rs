//! A thread-safe buffer pool: `Vec` allocations recycled across wire
//! frames and tile staging.
//!
//! The ownership idiom is the `bytes`-crate one — a handle that owns a
//! buffer and gives it back to a shared pool when dropped — implemented
//! with `Arc` + `Mutex` so the crate keeps its no-new-deps rule. A
//! [`PoolVec`] dereferences to `Vec<T>`, so call sites that used to
//! take a fresh `Vec` compile unchanged against a pooled buffer.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Counters describing how a [`BufferPool`] has been used.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers handed out ([`BufferPool::get`] calls).
    pub gets: u64,
    /// Gets served by re-using a previously returned buffer's capacity
    /// (the allocation that did **not** happen).
    pub recycled: u64,
    /// Free buffers currently parked in the pool.
    pub retained: u64,
}

/// A bounded free-list of `Vec<T>` buffers shared across threads.
///
/// [`BufferPool::get`] hands out a zero-initialised buffer of the
/// requested length, preferring the capacity of a previously dropped
/// [`PoolVec`]; at most `max_retained` free buffers are kept, so a
/// burst can never pin unbounded memory.
pub struct BufferPool<T> {
    shelf: Mutex<Vec<Vec<T>>>,
    max_retained: usize,
    gets: AtomicU64,
    recycled: AtomicU64,
}

impl<T> BufferPool<T> {
    /// A pool retaining at most `max_retained` free buffers.
    pub fn new(max_retained: usize) -> BufferPool<T> {
        BufferPool {
            shelf: Mutex::new(Vec::new()),
            max_retained,
            gets: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        }
    }

    /// Snapshot of the pool counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            gets: self.gets.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
            retained: self.shelf.lock().unwrap().len() as u64,
        }
    }

    /// Gets that re-used a returned buffer (the `pool_recycled=` stat).
    pub fn recycled(&self) -> u64 {
        self.recycled.load(Ordering::Relaxed)
    }

    /// Park a buffer for re-use (called by [`PoolVec::drop`]; bounded
    /// by `max_retained`, beyond which the buffer is simply freed).
    fn put_back(&self, buf: Vec<T>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut shelf = self.shelf.lock().unwrap();
        if shelf.len() < self.max_retained {
            shelf.push(buf);
        }
    }
}

impl<T: Clone + Default> BufferPool<T> {
    /// A zero-initialised buffer of exactly `len` elements, re-using a
    /// parked buffer's capacity when one is large enough. Dropping the
    /// returned [`PoolVec`] parks the buffer back here.
    pub fn get(self: &Arc<Self>, len: usize) -> PoolVec<T> {
        self.gets.fetch_add(1, Ordering::Relaxed);
        let reused = {
            let mut shelf = self.shelf.lock().unwrap();
            match shelf.iter().position(|b| b.capacity() >= len) {
                Some(i) => Some(shelf.swap_remove(i)),
                None => shelf.pop(),
            }
        };
        let mut buf = match reused {
            Some(b) => {
                if b.capacity() >= len {
                    self.recycled.fetch_add(1, Ordering::Relaxed);
                }
                b
            }
            None => Vec::with_capacity(len),
        };
        buf.clear();
        buf.resize(len, T::default());
        PoolVec { buf, pool: Some(Arc::clone(self)) }
    }
}

/// An owned buffer on loan from a [`BufferPool`]: behaves like the
/// `Vec<T>` it wraps (via `Deref`/`DerefMut`) and returns the
/// allocation to its pool when dropped.
pub struct PoolVec<T> {
    buf: Vec<T>,
    pool: Option<Arc<BufferPool<T>>>,
}

impl<T> PoolVec<T> {
    /// Wrap a plain `Vec` with no backing pool (dropping it frees the
    /// buffer normally). Useful for tests and default-constructed
    /// paths.
    pub fn detached(buf: Vec<T>) -> PoolVec<T> {
        PoolVec { buf, pool: None }
    }

    /// Take the buffer out, detaching it from the pool (the allocation
    /// is not returned).
    pub fn into_vec(mut self) -> Vec<T> {
        self.pool = None;
        std::mem::take(&mut self.buf)
    }
}

impl<T> Drop for PoolVec<T> {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.put_back(std::mem::take(&mut self.buf));
        }
    }
}

impl<T> Deref for PoolVec<T> {
    type Target = Vec<T>;
    fn deref(&self) -> &Vec<T> {
        &self.buf
    }
}

impl<T> DerefMut for PoolVec<T> {
    fn deref_mut(&mut self) -> &mut Vec<T> {
        &mut self.buf
    }
}

impl<T: fmt::Debug> fmt::Debug for PoolVec<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.buf.fmt(f)
    }
}

impl<T: PartialEq> PartialEq for PoolVec<T> {
    fn eq(&self, other: &PoolVec<T>) -> bool {
        self.buf == other.buf
    }
}

impl<T: PartialEq> PartialEq<Vec<T>> for PoolVec<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        &self.buf == other
    }
}

impl<T: PartialEq> PartialEq<PoolVec<T>> for Vec<T> {
    fn eq(&self, other: &PoolVec<T>) -> bool {
        self == &other.buf
    }
}

impl<T: PartialEq> PartialEq<[T]> for PoolVec<T> {
    fn eq(&self, other: &[T]) -> bool {
        self.buf.as_slice() == other
    }
}

impl<T: PartialEq> PartialEq<&[T]> for PoolVec<T> {
    fn eq(&self, other: &&[T]) -> bool {
        self.buf.as_slice() == *other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_allocates_then_recycles() {
        let pool = Arc::new(BufferPool::<u8>::new(4));
        {
            let mut b = pool.get(16);
            b[0] = 7;
            assert_eq!(b.len(), 16);
        } // dropped → parked
        let b2 = pool.get(8);
        assert_eq!(b2.len(), 8);
        assert!(b2.iter().all(|&x| x == 0), "recycled buffers are re-zeroed");
        let s = pool.stats();
        assert_eq!((s.gets, s.recycled), (2, 1));
    }

    #[test]
    fn retention_is_bounded() {
        let pool = Arc::new(BufferPool::<f32>::new(1));
        let a = pool.get(4);
        let b = pool.get(4);
        drop(a);
        drop(b); // second return exceeds max_retained → freed
        assert_eq!(pool.stats().retained, 1);
    }

    #[test]
    fn equality_with_plain_vecs_and_slices() {
        let pool = Arc::new(BufferPool::<u8>::new(2));
        let mut b = pool.get(3);
        b.copy_from_slice(&[1, 2, 3]);
        assert_eq!(b, vec![1u8, 2, 3]);
        assert_eq!(b, &[1u8, 2, 3][..]);
        assert_eq!(vec![1u8, 2, 3], b);
        let detached = PoolVec::detached(vec![1u8, 2, 3]);
        assert_eq!(b, detached);
        assert_eq!(detached.into_vec(), vec![1u8, 2, 3]);
    }

    #[test]
    fn too_small_parked_buffer_still_serves_without_recycle_credit() {
        let pool = Arc::new(BufferPool::<u8>::new(4));
        drop(pool.get(4));
        let big = pool.get(1 << 12); // parked capacity is too small
        assert_eq!(big.len(), 1 << 12);
        assert_eq!(pool.stats().recycled, 0);
    }
}
