//! The packed-A panel cache: operand residency for "one A, many B"
//! serving traffic.
//!
//! Repeated gemms against the same weights used to re-run `pack_a` for
//! every micro-tile of every request. This cache keeps the *packed*
//! panels resident, keyed by `(hash, dims, dtype, transpose, chip)`,
//! and hands back an `Arc` on a hit so the µ-kernel reads the cached
//! panel with zero copies and zero allocations.
//!
//! Two rules, both pinned by tests:
//!
//! * **Bytewise verify on hit.** The 64-bit FNV-1a key hash is an
//!   index, not a proof: before a cached panel is served, its live
//!   region is compared element-by-element against the caller's
//!   operand — exactly the batcher's coalescing-merge rule. A hash
//!   collision therefore *misses* (and drops the stale entry) instead
//!   of serving another client's weights.
//! * **LRU by byte budget.** The cache never holds more than its
//!   configured byte budget; inserting past it evicts
//!   least-recently-used entries first (a budget of 0 disables the
//!   cache entirely — the gemm driver then behaves bit-identically to
//!   the pre-cache code path).

use crate::blis::op::{Dtype, Element};
use crate::blis::packing::pack_a;
use crate::epiphany::timing::WalkClass;
use crate::linalg::{MatRef, Real};
use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// FNV-1a over the elements of an operand view, in pack order
/// (column-major over `op(A)`). Elements hash via their `f64` widening
/// bit pattern, so f32 and f64 operands with equal values still hash
/// apart through [`PanelKey::dtype`].
pub fn hash_operand<T: Real>(op_a: MatRef<'_, T>) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for l in 0..op_a.cols() {
        for i in 0..op_a.rows() {
            h ^= op_a.get(i, l).to_f64().to_bits();
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Cache key for one packed A panel: the operand hash plus everything
/// that shapes the packed bytes ([`pack_a`]'s inputs) and the chip the
/// panel is resident for.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PanelKey {
    /// [`hash_operand`] of the full `op(A)` view.
    pub a_hash: u64,
    /// Chip in the [`ChipPool`](crate::host::pool::ChipPool) this panel
    /// is resident for.
    pub chip: usize,
    /// First row of the panel's tile.
    pub i0: usize,
    /// Live rows in the tile (the rest is zero padding).
    pub rows: usize,
    /// Panel depth (`op(A)` columns).
    pub k: usize,
    /// Padded tile height (µ-kernel `mr`).
    pub m_tile: usize,
    /// Element dtype of the panel.
    pub dtype: Dtype,
    /// Whether the source walk was strided (transposed A) — decides the
    /// packed walk class, so it is part of the identity.
    pub strided: bool,
}

impl PanelKey {
    /// The key for one micro-tile of `op_a` (rows `i0..i0+rows`, padded
    /// to `m_tile`) packed for `chip`.
    pub fn for_tile<T: Element>(
        a_hash: u64,
        chip: usize,
        op_a: MatRef<'_, T>,
        i0: usize,
        rows: usize,
        m_tile: usize,
    ) -> PanelKey {
        PanelKey {
            a_hash,
            chip,
            i0,
            rows,
            k: op_a.cols(),
            m_tile,
            dtype: T::DTYPE,
            strided: op_a.row_stride() != 1,
        }
    }
}

/// Counters describing a [`PanelCache`]'s behaviour.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PanelCacheStats {
    /// Bytewise-verified hits (pack skipped).
    pub hits: u64,
    /// Misses, including hash collisions rejected by the verify.
    pub misses: u64,
    /// Entries evicted to stay under the byte budget.
    pub evictions: u64,
    /// Bytes currently resident.
    pub resident_bytes: u64,
    /// Panels currently resident.
    pub entries: u64,
}

struct Entry {
    data: Arc<dyn Any + Send + Sync>,
    class: WalkClass,
    bytes: usize,
    seq: u64,
}

struct Inner {
    map: HashMap<PanelKey, Entry>,
    bytes: usize,
    seq: u64,
}

/// A capacity-bounded, LRU, bytewise-verified cache of packed A panels
/// (see the module docs for the two rules it lives by).
pub struct PanelCache {
    inner: Mutex<Inner>,
    budget: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PanelCache {
    /// A cache bounded to `budget_bytes` of resident panels. A budget
    /// of 0 never stores anything (every lookup misses).
    pub fn new(budget_bytes: usize) -> PanelCache {
        PanelCache {
            inner: Mutex::new(Inner { map: HashMap::new(), bytes: 0, seq: 0 }),
            budget: budget_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The configured byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Look up `key` and **verify the panel bytewise** against `op_a`
    /// before serving it. Counts a hit only when the verify passes; a
    /// mismatch (64-bit hash collision) drops the stale entry and
    /// counts a miss, so wrong weights are never served. The hit path
    /// performs no allocation — the panel returns as a shared `Arc`.
    pub fn get_verified<T: Element>(
        &self,
        key: &PanelKey,
        op_a: MatRef<'_, T>,
    ) -> Option<(Arc<Vec<T>>, WalkClass)> {
        let candidate = {
            let mut inner = self.inner.lock().unwrap();
            inner.seq += 1;
            let seq = inner.seq;
            inner.map.get_mut(key).map(|e| {
                e.seq = seq;
                (Arc::clone(&e.data), e.class)
            })
        };
        let Some((data, class)) = candidate else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        let verified =
            data.downcast::<Vec<T>>().ok().filter(|panel| panel_matches(panel, op_a, key));
        match verified {
            Some(panel) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some((panel, class))
            }
            None => {
                // Bytewise mismatch under a matching key: a 64-bit hash
                // collision. Never serve it; drop the stale entry so the
                // caller's re-pack takes its place.
                self.remove(key);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a freshly packed panel, evicting least-recently-used
    /// entries until the byte budget holds. Panels larger than the
    /// whole budget are not cached.
    pub fn insert<T: Element>(&self, key: PanelKey, panel: Arc<Vec<T>>, class: WalkClass) {
        let bytes = panel.len() * std::mem::size_of::<T>();
        if bytes == 0 || bytes > self.budget {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if let Some(old) = inner.map.remove(&key) {
            inner.bytes -= old.bytes;
        }
        while inner.bytes + bytes > self.budget {
            let victim = match inner.map.iter().min_by_key(|(_, e)| e.seq) {
                Some((k, _)) => k.clone(),
                None => break,
            };
            if let Some(e) = inner.map.remove(&victim) {
                inner.bytes -= e.bytes;
            }
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        inner.seq += 1;
        let seq = inner.seq;
        inner.bytes += bytes;
        inner.map.insert(key, Entry { data: panel, class, bytes, seq });
    }

    /// Serve one micro-tile's packed panel: a verified cache hit when
    /// the panel is resident, otherwise [`pack_a`] + insert. This is
    /// the gemm driver's `pack_a` replacement when the cache is on.
    pub fn get_or_pack<T: Element>(
        &self,
        a_hash: u64,
        chip: usize,
        op_a: MatRef<'_, T>,
        i0: usize,
        rows: usize,
        m_tile: usize,
    ) -> (Arc<Vec<T>>, WalkClass) {
        let key = PanelKey::for_tile::<T>(a_hash, chip, op_a, i0, rows, m_tile);
        if let Some(hit) = self.get_verified(&key, op_a) {
            return hit;
        }
        let (panel, class) = pack_a(op_a, i0, rows, m_tile);
        let panel = Arc::new(panel);
        self.insert::<T>(key, Arc::clone(&panel), class);
        (panel, class)
    }

    /// Drop one entry (collision cleanup).
    fn remove(&self, key: &PanelKey) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.map.remove(key) {
            inner.bytes -= e.bytes;
        }
    }

    /// Snapshot of the cache counters.
    pub fn stats(&self) -> PanelCacheStats {
        let inner = self.inner.lock().unwrap();
        PanelCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_bytes: inner.bytes as u64,
            entries: inner.map.len() as u64,
        }
    }
}

/// The bytewise verify: the panel's live region must equal what
/// [`pack_a`] would produce from `op_a` right now (padding is a
/// function of the key's dims, so only live elements are compared).
fn panel_matches<T: Element>(panel: &[T], op_a: MatRef<'_, T>, key: &PanelKey) -> bool {
    if panel.len() != key.m_tile * key.k
        || key.k != op_a.cols()
        || key.i0 + key.rows > op_a.rows()
    {
        return false;
    }
    for l in 0..key.k {
        for i in 0..key.rows {
            if panel[l * key.m_tile + i] != op_a.get(key.i0 + i, l) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn key_for(a: &Mat<f32>, i0: usize, rows: usize, m_tile: usize) -> PanelKey {
        PanelKey::for_tile::<f32>(hash_operand(a.view()), 0, a.view(), i0, rows, m_tile)
    }

    #[test]
    fn miss_pack_hit_round_trip() {
        let cache = PanelCache::new(1 << 20);
        let a = Mat::<f32>::randn(8, 6, 1);
        let h = hash_operand(a.view());
        let (p1, c1) = cache.get_or_pack::<f32>(h, 0, a.view(), 0, 8, 8);
        let (p2, c2) = cache.get_or_pack::<f32>(h, 0, a.view(), 0, 8, 8);
        assert_eq!(p1.as_slice(), p2.as_slice());
        assert_eq!(c1, c2);
        assert!(Arc::ptr_eq(&p1, &p2), "hit must serve the resident Arc, not a copy");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn collision_with_different_bytes_misses_and_replaces() {
        // Same key (forged hash), different operand bytes: the verify
        // must reject the resident panel rather than serve it.
        let cache = PanelCache::new(1 << 20);
        let a1 = Mat::<f32>::randn(4, 3, 7);
        let a2 = Mat::<f32>::randn(4, 3, 8); // different values, same dims
        let key = key_for(&a1, 0, 4, 4);
        let (panel, class) = pack_a(a1.view(), 0, 4, 4);
        cache.insert::<f32>(key.clone(), Arc::new(panel), class);
        assert!(cache.get_verified::<f32>(&key, a2.view()).is_none());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 1));
        assert_eq!(s.entries, 0, "the colliding entry is dropped, not kept");
    }

    #[test]
    fn lru_eviction_under_tiny_budget() {
        // Budget fits exactly one 4×3 f32 panel (48 bytes).
        let cache = PanelCache::new(48);
        let a = Mat::<f32>::randn(4, 3, 1);
        let b = Mat::<f32>::randn(4, 3, 2);
        let ha = hash_operand(a.view());
        let hb = hash_operand(b.view());
        cache.get_or_pack::<f32>(ha, 0, a.view(), 0, 4, 4);
        cache.get_or_pack::<f32>(hb, 0, b.view(), 0, 4, 4); // evicts a's panel
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 1);
        assert!(s.resident_bytes <= 48);
        // b is still resident → verified hit; a was evicted → miss.
        cache.get_or_pack::<f32>(hb, 0, b.view(), 0, 4, 4);
        assert_eq!(cache.stats().hits, 1);
        cache.get_or_pack::<f32>(ha, 0, a.view(), 0, 4, 4);
        assert_eq!(cache.stats().entries, 1, "budget holds exactly one panel");
    }

    #[test]
    fn zero_budget_disables_storage() {
        let cache = PanelCache::new(0);
        let a = Mat::<f32>::randn(4, 3, 1);
        let h = hash_operand(a.view());
        cache.get_or_pack::<f32>(h, 0, a.view(), 0, 4, 4);
        cache.get_or_pack::<f32>(h, 0, a.view(), 0, 4, 4);
        let s = cache.stats();
        assert_eq!((s.hits, s.entries), (0, 0));
        assert_eq!(s.misses, 2);
    }

    #[test]
    fn chips_and_dtypes_key_apart() {
        let cache = PanelCache::new(1 << 20);
        let a32 = Mat::<f32>::randn(4, 3, 1);
        let a64 = Mat::<f64>::randn(4, 3, 1);
        let h32 = hash_operand(a32.view());
        let h64 = hash_operand(a64.view());
        cache.get_or_pack::<f32>(h32, 0, a32.view(), 0, 4, 4);
        cache.get_or_pack::<f32>(h32, 1, a32.view(), 0, 4, 4); // other chip
        cache.get_or_pack::<f64>(h64, 0, a64.view(), 0, 4, 4); // other dtype
        assert_eq!(cache.stats().entries, 3);
    }
}
