//! Operand residency: the memory subsystem behind the zero-copy hot path.
//!
//! The paper's platform-level ceiling is data movement, not the chip
//! (§4: the Epiphany reaches ~85% of peak inside the chip while the
//! full Parallella stalls on host↔chip transfer). Serving traffic makes
//! it worse: the shape is "one A, many B", yet every request used to
//! re-pack A and every codec step allocated fresh `Vec`s. This module
//! is the fix, in two cooperating pieces:
//!
//! * [`BufferPool`] / [`PoolVec`] — a thread-safe recycling pool for
//!   byte and scalar staging buffers (wire frame bodies, batcher
//!   concatenation staging). A [`PoolVec`] owns its buffer like a plain
//!   `Vec` and returns it to the pool on drop, so steady-state traffic
//!   stops allocating per frame/request.
//! * [`PanelCache`] — a capacity-bounded LRU cache of *packed* A panels
//!   keyed by `(hash, dims, dtype, transpose, chip)`. Every hit is
//!   verified **bytewise** against the caller's operand (exactly like
//!   the batcher's coalescing merge), so a 64-bit hash collision can
//!   never serve another client's weights; repeated gemms against
//!   resident weights skip `pack_a` entirely.
//!
//! Both pieces expose counters (`pool_recycled`, `panel_hits=`,
//! `panel_misses=`, `panel_evictions=` on the stats wire opcode) and
//! are disabled-by-default knobs: a panel-cache budget of 0 keeps the
//! pre-residency code path bit-identical. See
//! `docs/ARCHITECTURE.md` ("Operand residency & memory pools") for the
//! keying and eviction rules, and the `residency` bench for the
//! measured cache-hit speedup and allocations/request table.

pub mod panels;
pub mod pool;

pub use panels::{hash_operand, PanelCache, PanelCacheStats, PanelKey};
pub use pool::{BufferPool, PoolStats, PoolVec};
