//! Mixed-precision iterative refinement: f32-class factorization (the
//! paper's false-dgemm trailing updates), f64 residual, correction loop.
//!
//! The paper's own HPL run (§7, Table 7) leaves the residual at f32 scale
//! (`hpl_scaled ≈ 2.1e10`) because the trailing gemm updates run on the
//! Epiphany in single precision. Classic iterative refinement (Wilkinson;
//! Langou et al. 2006 for the f32/f64 pairing) is the standard repair:
//! keep the expensive O(n³) factorization in fast low precision, compute
//! the O(n²) residual `r = b − A·x` in f64, solve the cheap correction
//! system against the existing factors, and iterate until the f64
//! residual passes HPL's own check (`hpl_scaled ≤ 16`).
//!
//! [`solve_refined`] is the driver; [`SolveOp`] is the descriptor-core
//! packaging of it, and `Opcode::Solve` its wire form.

use crate::blis::Blas;
use crate::hpl::lu::{lu_factor_blocked, lu_solve, LuReport};
use crate::hpl::residual::hpl_residual;
use crate::hpl::{potrf_lower, potrs_lower};
use crate::linalg::Mat;
use anyhow::Result;

/// Which factorization backs the refinement loop (both f32-class: their
/// trailing updates run through the false dgemm).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Factorization {
    /// Blocked LU with partial pivoting ([`crate::hpl::lu`]) — general
    /// square systems.
    Lu,
    /// Blocked lower Cholesky ([`crate::hpl::cholesky`]) — symmetric
    /// positive-definite systems.
    Cholesky,
}

/// Convergence policy for the refinement loop. Residuals are measured in
/// HPL's normalized units ([`crate::hpl::residual::HplResidual::hpl_scaled`]),
/// so the default tolerance of 16 is exactly HPL's pass criterion.
#[derive(Clone, Copy, Debug)]
pub struct RefinePolicy {
    /// Give up (as [`RefineError::DidNotConverge`]) after this many
    /// correction steps.
    pub max_iters: usize,
    /// Stop as converged once `hpl_scaled` drops to this value or below.
    pub tolerance: f64,
    /// Block size handed to the factorization (HPL's NB).
    pub nb: usize,
    /// Bail out (as [`RefineError::Diverged`]) when a step's residual
    /// exceeds `divergence_factor ×` the best residual seen so far.
    pub divergence_factor: f64,
}

impl Default for RefinePolicy {
    fn default() -> Self {
        RefinePolicy { max_iters: 30, tolerance: 16.0, nb: 64, divergence_factor: 4.0 }
    }
}

/// Accounting for one refined solve.
#[derive(Clone, Debug)]
pub struct RefineReport {
    /// Correction steps taken (0 = the first solve already passed).
    pub iters: usize,
    /// `hpl_scaled` residual after the initial solve and after each
    /// correction, in order — `residuals.last()` is the accepted one.
    pub residuals: Vec<f64>,
    /// The factorization's own flop/time accounting.
    pub factor: LuReport,
}

impl RefineReport {
    /// The accepted (final) `hpl_scaled` residual.
    pub fn final_residual(&self) -> f64 {
        self.residuals.last().copied().unwrap_or(f64::INFINITY)
    }
}

/// Typed refinement failures — the convergence policy's two exits. The
/// partially-refined state rides along so callers can still inspect the
/// best solution the loop reached.
#[derive(Clone, Debug)]
pub enum RefineError {
    /// A correction step made the residual worse than
    /// `divergence_factor ×` the best seen — the classic sign that the
    /// matrix is too ill-conditioned for f32 factors to correct.
    Diverged {
        /// Correction step that triggered the bail-out (1-based).
        iter: usize,
        /// The offending `hpl_scaled` residual.
        residual: f64,
        /// Best `hpl_scaled` residual any iterate achieved.
        best: f64,
    },
    /// `max_iters` corrections ran without reaching the tolerance.
    DidNotConverge {
        /// Correction steps taken (= the policy's `max_iters`).
        iters: usize,
        /// `hpl_scaled` residual of the last iterate.
        residual: f64,
    },
}

impl std::fmt::Display for RefineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RefineError::Diverged { iter, residual, best } => write!(
                f,
                "refinement diverged at iteration {iter}: residual {residual:.3e} \
                 (best was {best:.3e})"
            ),
            RefineError::DidNotConverge { iters, residual } => write!(
                f,
                "refinement did not converge in {iters} iterations \
                 (residual {residual:.3e})"
            ),
        }
    }
}

impl std::error::Error for RefineError {}

/// The f64 residual *vector* `r = b − A·x` (the O(n²) step the whole
/// scheme hinges on staying in double precision).
fn residual_vector(a: &Mat<f64>, x: &[f64], b: &[f64]) -> Vec<f64> {
    let n = a.rows();
    let mut r = Vec::with_capacity(n);
    for i in 0..n {
        let mut acc = 0.0f64;
        for j in 0..n {
            acc += a.get(i, j) * x[j];
        }
        r.push(b[i] - acc);
    }
    r
}

/// Solve `A·x = b` by f32-class factorization + f64 iterative refinement.
///
/// `a` is the original (unfactored) matrix; it is copied, so the caller
/// keeps it for their own residual checks. Singular / non-SPD inputs
/// surface as the factorization's own error; a diverging or stalling
/// refinement loop surfaces as a downcastable [`RefineError`].
pub fn solve_refined(
    blas: &Blas,
    a: &Mat<f64>,
    b: &[f64],
    kind: Factorization,
    policy: &RefinePolicy,
) -> Result<(Vec<f64>, RefineReport)> {
    anyhow::ensure!(a.rows() == a.cols(), "solve: A must be square, got {}x{}", a.rows(), a.cols());
    anyhow::ensure!(
        b.len() == a.rows(),
        "solve: b length {} != system order {}",
        b.len(),
        a.rows()
    );
    let nb = policy.nb.max(1);
    let mut factored = a.clone();
    let (pivots, factor_report) = match kind {
        Factorization::Lu => lu_factor_blocked(blas, &mut factored, nb)?,
        Factorization::Cholesky => {
            let rep = potrf_lower(blas, &mut factored, nb)?;
            (Vec::new(), rep)
        }
    };
    let solve_once = |rhs: &[f64]| -> Vec<f64> {
        match kind {
            Factorization::Lu => lu_solve(&factored, &pivots, rhs),
            Factorization::Cholesky => potrs_lower(&factored, rhs),
        }
    };

    let mut x = solve_once(b);
    let mut residuals = vec![hpl_residual(a, &x, b).hpl_scaled];
    let mut best = residuals[0];

    for iter in 1..=policy.max_iters {
        let current = *residuals.last().expect("at least the initial residual");
        if current <= policy.tolerance {
            let iters = iter - 1;
            return Ok((x, RefineReport { iters, residuals, factor: factor_report }));
        }
        // One correction: r = b − A·x in f64, d from the f32 factors.
        let r = residual_vector(a, &x, b);
        let d = solve_once(&r);
        for (xi, di) in x.iter_mut().zip(&d) {
            *xi += di;
        }
        let next = hpl_residual(a, &x, b).hpl_scaled;
        residuals.push(next);
        if next > policy.divergence_factor * best {
            return Err(anyhow::Error::new(RefineError::Diverged {
                iter,
                residual: next,
                best,
            }));
        }
        if next < best {
            best = next;
        }
    }

    let last = *residuals.last().expect("non-empty");
    if last <= policy.tolerance {
        let iters = policy.max_iters;
        return Ok((x, RefineReport { iters, residuals, factor: factor_report }));
    }
    Err(anyhow::Error::new(RefineError::DidNotConverge {
        iters: policy.max_iters,
        residual: last,
    }))
}

/// `A·x = b` as a descriptor: owned operands, so it can ride
/// [`Blas::submit`] like [`crate::blis::GemmTask`]. Output is the
/// solution plus the [`RefineReport`].
pub struct SolveOp {
    /// Which factorization backs the solve.
    pub factorization: Factorization,
    /// The system matrix (unfactored; copied internally).
    pub a: Mat<f64>,
    /// The right-hand side.
    pub b: Vec<f64>,
    /// Convergence policy.
    pub policy: RefinePolicy,
}

impl crate::blis::BlasOp for SolveOp {
    type Output = (Vec<f64>, RefineReport);

    fn route(&self) -> crate::blis::Route {
        // The O(n³) trailing updates inside the factorization run through
        // the accelerated gemm; they do their own ledger accounting.
        crate::blis::Route::Epiphany
    }

    fn flops(&self) -> f64 {
        let n = self.a.rows() as f64;
        match self.factorization {
            Factorization::Lu => 2.0 * n * n * n / 3.0,
            Factorization::Cholesky => n * n * n / 3.0,
        }
    }

    fn run(self, blas: &Blas) -> Result<Self::Output> {
        solve_refined(blas, &self.a, &self.b, self.factorization, &self.policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::XorShiftRng;

    fn blas() -> Blas {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        Blas::new(svc)
    }

    /// Well-conditioned diagonally-dominant system.
    fn system(n: usize, seed: u64) -> (Mat<f64>, Vec<f64>) {
        let mut rng = XorShiftRng::new(seed);
        let mut a = Mat::<f64>::from_fn(n, n, |_, _| rng.next_unit());
        for i in 0..n {
            a.set(i, i, a.get(i, i) + n as f64);
        }
        let b: Vec<f64> = (0..n).map(|_| rng.next_unit()).collect();
        (a, b)
    }

    #[test]
    fn lu_refinement_reaches_hpl_tolerance() {
        let blas = blas();
        let (a, b) = system(128, 11);
        let (x, rep) =
            solve_refined(&blas, &a, &b, Factorization::Lu, &RefinePolicy::default()).unwrap();
        let r = hpl_residual(&a, &x, &b);
        assert!(r.hpl_scaled <= 16.0, "refined residual {} too large", r.hpl_scaled);
        assert!(rep.final_residual() <= 16.0);
        assert!(
            rep.residuals[0] > rep.final_residual(),
            "refinement should improve on the f32-class first solve: {:?}",
            rep.residuals
        );
    }

    #[test]
    fn cholesky_refinement_on_spd() {
        let blas = blas();
        let n = 96;
        let m = Mat::<f64>::randn(n, n, 13);
        let mut a = Mat::<f64>::from_fn(n, n, |i, j| if i == j { n as f64 } else { 0.0 });
        crate::blis::level3::gemm_host(
            crate::blis::Trans::N,
            crate::blis::Trans::T,
            1.0,
            m.view(),
            m.view(),
            1.0,
            &mut a,
        );
        let mut rng = XorShiftRng::new(17);
        let b: Vec<f64> = (0..n).map(|_| rng.next_unit()).collect();
        let (x, rep) =
            solve_refined(&blas, &a, &b, Factorization::Cholesky, &RefinePolicy::default())
                .unwrap();
        assert!(hpl_residual(&a, &x, &b).hpl_scaled <= 16.0);
        assert!(rep.factor.gemm_flops > 0.0, "trailing updates should hit the gemm path");
    }

    #[test]
    fn impossible_policy_is_typed_divergence() {
        let blas = blas();
        let (a, b) = system(64, 19);
        // tolerance 0 is unreachable; divergence_factor 0 flags the very
        // first correction as divergent — deterministically.
        let policy = RefinePolicy { tolerance: 0.0, divergence_factor: 0.0, ..Default::default() };
        let err = solve_refined(&blas, &a, &b, Factorization::Lu, &policy).unwrap_err();
        match err.downcast_ref::<RefineError>() {
            Some(RefineError::Diverged { iter, .. }) => assert_eq!(*iter, 1),
            other => panic!("expected Diverged, got {other:?}"),
        }
    }

    #[test]
    fn exhausted_iters_is_typed_nonconvergence() {
        let blas = blas();
        let (a, b) = system(64, 23);
        let policy = RefinePolicy {
            tolerance: 0.0,
            max_iters: 2,
            divergence_factor: f64::INFINITY,
            ..Default::default()
        };
        let err = solve_refined(&blas, &a, &b, Factorization::Lu, &policy).unwrap_err();
        match err.downcast_ref::<RefineError>() {
            Some(RefineError::DidNotConverge { iters, .. }) => assert_eq!(*iters, 2),
            other => panic!("expected DidNotConverge, got {other:?}"),
        }
    }

    #[test]
    fn singular_system_reports_factorization_error() {
        let blas = blas();
        // Rank-1 dyadic A = u·vᵀ — singular by construction.
        let n = 32;
        let u: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
        let v: Vec<f64> = (0..n).map(|i| 2.0 - i as f64 / n as f64).collect();
        let a = Mat::<f64>::from_fn(n, n, |i, j| u[i] * v[j]);
        let b = vec![1.0; n];
        let err = solve_refined(&blas, &a, &b, Factorization::Lu, &RefinePolicy::default())
            .unwrap_err();
        assert!(format!("{err:#}").contains("singular"), "{err:#}");
    }
}
