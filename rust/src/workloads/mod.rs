//! Workload shapes beyond one-big-gemm: the serving surfaces the
//! Epiphany architecture actually favors.
//!
//! The paper's benchmarks (§5–7) stop at single sgemm/false-dgemm calls
//! and the HPL driver. This subsystem opens three further traffic
//! shapes on the same descriptor core and wire:
//!
//! * [`batch`] — **batched small gemm** ([`GemmBatchOp`]): hundreds of
//!   tiny matmuls per request, fanned across the chip pool item-by-item;
//!   the shape the OpenSHMEM Epiphany literature argues this chip wins on.
//! * [`refine`] — **mixed-precision iterative refinement**
//!   ([`SolveOp`], [`solve_refined`]): f32-class factorization (false
//!   dgemm where the flops are) + f64 residual + correction loop, turning
//!   the paper's f32-scale HPL residual into an f64-quality solve.
//! * [`conv`] — **im2col convolution**: a conv layer lowered to a gemm
//!   batch ([`conv2d_via_batch`]), the ML-inference-shaped demo.

pub mod batch;
pub mod conv;
pub mod refine;

pub use batch::{BatchReport, GemmBatchItem, GemmBatchOp};
pub use conv::{conv2d_naive, conv2d_via_batch, im2col, ConvShape};
pub use refine::{solve_refined, Factorization, RefineError, RefinePolicy, RefineReport, SolveOp};
