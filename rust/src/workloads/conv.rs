//! im2col convolution: lower a conv layer to batched small gemm.
//!
//! The classic lowering — each output pixel's receptive field becomes one
//! row of a patch matrix, the filter bank becomes a `kh·kw·c_in × c_out`
//! matrix, and the convolution is `patches @ filters` per image. A batch
//! of images is then exactly the [`super::batch::GemmBatchOp`] traffic
//! shape: many small gemms sharing one B operand, which the panel cache
//! keeps resident across items. The Python twin
//! (`python/compile/conv.py`) performs the same lowering on the JAX side
//! of the stack; `examples/conv_im2col.rs` drives this one.
//!
//! Layout conventions: images are NHWC (`batch × h × w × c_in`, row-major
//! in that index order), filters are HWIO (`kh × kw × c_in × c_out`).
//! Padding is "valid", stride 1 — the demo shape, not a conv zoo.

use super::batch::{BatchReport, GemmBatchItem, GemmBatchOp};
use crate::blis::Blas;
use crate::linalg::Mat;
use anyhow::{ensure, Result};

/// Shape of one conv layer (valid padding, stride 1).
#[derive(Clone, Copy, Debug)]
pub struct ConvShape {
    /// Images per batch.
    pub batch: usize,
    /// Input height.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Input channels.
    pub c_in: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Output channels (filter count).
    pub c_out: usize,
}

impl ConvShape {
    /// Output height (`h − kh + 1`).
    pub fn out_h(&self) -> usize {
        self.h + 1 - self.kh
    }

    /// Output width (`w − kw + 1`).
    pub fn out_w(&self) -> usize {
        self.w + 1 - self.kw
    }

    /// Flat NHWC input length this shape expects.
    pub fn input_len(&self) -> usize {
        self.batch * self.h * self.w * self.c_in
    }

    /// Flat HWIO filter length this shape expects.
    pub fn filter_len(&self) -> usize {
        self.kh * self.kw * self.c_in * self.c_out
    }

    fn check(&self) -> Result<()> {
        ensure!(self.batch > 0 && self.c_in > 0 && self.c_out > 0, "conv: empty shape {self:?}");
        ensure!(
            self.kh >= 1 && self.kw >= 1 && self.kh <= self.h && self.kw <= self.w,
            "conv: kernel {}x{} does not fit input {}x{}",
            self.kh,
            self.kw,
            self.h,
            self.w
        );
        Ok(())
    }
}

/// The im2col patch matrix of image `img`: `out_h·out_w × kh·kw·c_in`,
/// row `oy·out_w + ox`, column `(ky·kw + kx)·c_in + ci`.
pub fn im2col(input: &[f32], shape: &ConvShape, img: usize) -> Mat<f32> {
    let (wo, c_in, w) = (shape.out_w(), shape.c_in, shape.w);
    let base = img * shape.h * w * c_in;
    Mat::from_fn(shape.out_h() * wo, shape.kh * shape.kw * c_in, |p, q| {
        let (oy, ox) = (p / wo, p % wo);
        let ci = q % c_in;
        let (ky, kx) = ((q / c_in) / shape.kw, (q / c_in) % shape.kw);
        input[base + ((oy + ky) * w + (ox + kx)) * c_in + ci]
    })
}

/// The filter bank as a `kh·kw·c_in × c_out` matrix (HWIO flattening).
pub fn filter_matrix(filters: &[f32], shape: &ConvShape) -> Mat<f32> {
    Mat::from_fn(shape.kh * shape.kw * shape.c_in, shape.c_out, |q, f| {
        filters[q * shape.c_out + f]
    })
}

/// Run the conv layer as an im2col-lowered gemm batch: one item per
/// image, every item sharing the same filter matrix as B. Returns one
/// `out_h·out_w × c_out` matrix per image plus the batch accounting.
pub fn conv2d_via_batch(
    blas: &Blas,
    input: &[f32],
    filters: &[f32],
    shape: &ConvShape,
) -> Result<(Vec<Mat<f32>>, BatchReport)> {
    shape.check()?;
    ensure!(
        input.len() == shape.input_len(),
        "conv: input length {} != expected {}",
        input.len(),
        shape.input_len()
    );
    ensure!(
        filters.len() == shape.filter_len(),
        "conv: filter length {} != expected {}",
        filters.len(),
        shape.filter_len()
    );
    let b = filter_matrix(filters, shape);
    let items: Vec<GemmBatchItem<f32>> = (0..shape.batch)
        .map(|img| {
            GemmBatchItem::plain(
                im2col(input, shape, img),
                b.clone(),
                Mat::<f32>::zeros(shape.out_h() * shape.out_w(), shape.c_out),
            )
        })
        .collect();
    blas.execute(GemmBatchOp { items })
}

/// Direct f64-accumulated reference convolution (NHWC in, one
/// `out_h·out_w × c_out` matrix per image out) — the oracle the demo and
/// tests compare the lowered path against.
pub fn conv2d_naive(input: &[f32], filters: &[f32], shape: &ConvShape) -> Vec<Mat<f64>> {
    let (ho, wo, c_in, w) = (shape.out_h(), shape.out_w(), shape.c_in, shape.w);
    (0..shape.batch)
        .map(|img| {
            let base = img * shape.h * w * c_in;
            Mat::from_fn(ho * wo, shape.c_out, |p, f| {
                let (oy, ox) = (p / wo, p % wo);
                let mut acc = 0.0f64;
                for ky in 0..shape.kh {
                    for kx in 0..shape.kw {
                        for ci in 0..c_in {
                            let x = input[base + ((oy + ky) * w + (ox + kx)) * c_in + ci] as f64;
                            let wgt = filters
                                [((ky * shape.kw + kx) * c_in + ci) * shape.c_out + f]
                                as f64;
                            acc += x * wgt;
                        }
                    }
                }
                acc
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::{max_scaled_err, XorShiftRng};

    fn blas() -> Blas {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        Blas::new(svc)
    }

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = XorShiftRng::new(seed);
        (0..len).map(|_| rng.next_unit() as f32).collect()
    }

    #[test]
    fn lowered_conv_matches_naive_reference() {
        let blas = blas();
        let shape = ConvShape { batch: 3, h: 8, w: 8, c_in: 4, kh: 3, kw: 3, c_out: 5 };
        let input = rand_vec(shape.input_len(), 31);
        let filters = rand_vec(shape.filter_len(), 37);
        let (got, rep) = conv2d_via_batch(&blas, &input, &filters, &shape).unwrap();
        let want = conv2d_naive(&input, &filters, &shape);
        assert_eq!(rep.items, 3);
        assert_eq!(got.len(), 3);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!((g.rows(), g.cols()), (shape.out_h() * shape.out_w(), shape.c_out));
            let e = max_scaled_err(g.view(), w.view());
            assert!(e < 1e-4, "lowered conv err {e}");
        }
    }

    #[test]
    fn one_by_one_kernel_is_a_pointwise_matmul() {
        let blas = blas();
        let shape = ConvShape { batch: 1, h: 4, w: 5, c_in: 3, kh: 1, kw: 1, c_out: 2 };
        let input = rand_vec(shape.input_len(), 41);
        let filters = rand_vec(shape.filter_len(), 43);
        let (got, _) = conv2d_via_batch(&blas, &input, &filters, &shape).unwrap();
        let want = conv2d_naive(&input, &filters, &shape);
        assert_eq!(got[0].rows(), 20);
        assert!(max_scaled_err(got[0].view(), want[0].view()) < 1e-5);
    }

    #[test]
    fn oversized_kernel_rejected() {
        let blas = blas();
        let shape = ConvShape { batch: 1, h: 2, w: 2, c_in: 1, kh: 3, kw: 3, c_out: 1 };
        assert!(conv2d_via_batch(&blas, &[0.0; 4], &[0.0; 9], &shape).is_err());
    }
}
