//! Batched small gemm: one descriptor carrying hundreds of tiny matmuls.
//!
//! The OpenSHMEM Epiphany work (arXiv:1608.03545/.03549) argues this chip
//! wins on *many small resident-operand kernels*, not one huge gemm — the
//! per-crossing overhead amortizes over a batch and repeated operands stay
//! resident. [`GemmBatchOp`] is that traffic shape in the descriptor
//! core; `Opcode::GemmBatch` is its wire form, which the router fans out
//! across the [`crate::host::pool::ChipPool`] item-by-item
//! (least-loaded, health-aware, with shard-hint pins degrading to
//! preferences exactly like single gemms).
//!
//! Semantics are strictly *a loop of single gemms*: executing the batch
//! yields bit-identical results to calling [`Blas::gemm`] once per item,
//! in item order — asserted by the conformance suite on pools of 1 and 4.

use crate::blis::{Blas, BlasOp, Element, Route, Trans};
use crate::linalg::Mat;
use anyhow::Result;

/// One item of a [`GemmBatchOp`]: an owned, independent
/// `C ← α·op(A)·op(B) + β·C`.
pub struct GemmBatchItem<T: Element> {
    /// Transpose flag for A.
    pub ta: Trans,
    /// Transpose flag for B.
    pub tb: Trans,
    /// Scale on the product.
    pub alpha: T,
    /// Owned A operand.
    pub a: Mat<T>,
    /// Owned B operand.
    pub b: Mat<T>,
    /// Scale on the C input.
    pub beta: T,
    /// Owned C; handed back updated.
    pub c: Mat<T>,
}

impl<T: Element> GemmBatchItem<T> {
    /// Plain `C ← A·B + C` item (no transposes, α = β = 1).
    pub fn plain(a: Mat<T>, b: Mat<T>, c: Mat<T>) -> Self {
        GemmBatchItem { ta: Trans::N, tb: Trans::N, alpha: T::ONE, a, b, beta: T::ONE, c }
    }

    fn flops(&self) -> f64 {
        let k = if self.ta.is_trans() { self.a.rows() } else { self.a.cols() };
        2.0 * self.c.rows() as f64 * self.c.cols() as f64 * k as f64
    }
}

/// Per-batch accounting returned next to the updated C matrices.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchReport {
    /// Items executed.
    pub items: usize,
    /// Total logical flops across the batch.
    pub flops: f64,
    /// Summed projected seconds of the accelerated path.
    pub projected_s: f64,
    /// Summed µ-kernel calls.
    pub calls: u64,
}

/// A batch of independent small gemms as one descriptor (uniform or
/// per-item dims — each item carries its own shapes and flags).
pub struct GemmBatchOp<T: Element> {
    /// The batch, executed in order.
    pub items: Vec<GemmBatchItem<T>>,
}

impl<T: Element> BlasOp for GemmBatchOp<T> {
    type Output = (Vec<Mat<T>>, BatchReport);

    fn route(&self) -> Route {
        Route::Epiphany
    }

    fn flops(&self) -> f64 {
        self.items.iter().map(GemmBatchItem::flops).sum()
    }

    fn run(self, blas: &Blas) -> Result<Self::Output> {
        let mut out = Vec::with_capacity(self.items.len());
        let mut report = BatchReport::default();
        for mut item in self.items {
            report.flops += item.flops();
            let rep = blas.gemm(
                item.ta,
                item.tb,
                item.alpha,
                item.a.view(),
                item.b.view(),
                item.beta,
                &mut item.c,
            )?;
            report.items += 1;
            report.projected_s += rep.projected_s;
            report.calls += rep.calls;
            out.push(item.c);
        }
        Ok((out, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};

    fn blas() -> Blas {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        Blas::new(svc)
    }

    fn items(count: usize, m: usize, n: usize, k: usize) -> Vec<GemmBatchItem<f32>> {
        (0..count)
            .map(|i| {
                let seed = (i as u64 + 1) * 3;
                GemmBatchItem {
                    ta: Trans::N,
                    tb: Trans::N,
                    alpha: 1.0,
                    a: Mat::<f32>::randn(m, k, seed),
                    b: Mat::<f32>::randn(k, n, seed + 1),
                    beta: 0.5,
                    c: Mat::<f32>::randn(m, n, seed + 2),
                }
            })
            .collect()
    }

    #[test]
    fn batch_matches_loop_of_single_gemms_bit_identical() {
        let blas = blas();
        let batch = items(6, 16, 12, 8);
        // Reference: the same items through single Blas::gemm calls.
        let mut want = Vec::new();
        for it in items(6, 16, 12, 8) {
            let mut c = it.c.clone();
            blas.gemm(it.ta, it.tb, it.alpha, it.a.view(), it.b.view(), it.beta, &mut c).unwrap();
            want.push(c);
        }
        let (got, rep) = blas.execute(GemmBatchOp { items: batch }).unwrap();
        assert_eq!(rep.items, 6);
        assert!(rep.calls >= 6);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.as_slice(), w.as_slice(), "batch must be bit-identical to the loop");
        }
    }

    #[test]
    fn empty_batch_is_a_clean_no_op() {
        let blas = blas();
        let (got, rep) = blas.execute(GemmBatchOp::<f32> { items: Vec::new() }).unwrap();
        assert!(got.is_empty());
        assert_eq!(rep.items, 0);
    }

    #[test]
    fn bad_item_dims_error_with_item_intact_semantics() {
        let blas = blas();
        let mut batch = items(2, 8, 8, 8);
        // Break item 1: K mismatch between A and B.
        batch[1].b = Mat::<f32>::randn(5, 8, 99);
        assert!(blas.execute(GemmBatchOp { items: batch }).is_err());
    }
}
