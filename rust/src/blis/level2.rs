//! Level-2 BLAS (matrix-vector). These run on the host CPU, unaccelerated —
//! the paper's §4.3/§5 point: their low rate (vs the offloaded gemm) is
//! what capped the HPL result, and §5.3 proposes NEON/FPGA help.
//!
//! Two host paths exist: `*_simple` scalar loops (the faithful baseline)
//! and the default column-oriented loops that let LLVM auto-vectorize —
//! our stand-in for the paper's proposed NEON path (ablation-benched).

use super::params::Trans;
use crate::linalg::{MatMut, MatRef, Real};

/// y ← α·op(A)·x + β·y over strided vectors (classic BLAS `incx`/`incy`;
/// element `i` of a logical vector lives at `v[i * inc]`).
pub fn gemv<T: Real>(
    trans: Trans,
    alpha: T,
    a: MatRef<'_, T>,
    x: &[T],
    incx: usize,
    beta: T,
    y: &mut [T],
    incy: usize,
) {
    let op_a = if trans.is_trans() { a.t() } else { a };
    let (m, n) = (op_a.rows(), op_a.cols());
    assert!(incx >= 1 && incy >= 1, "gemv strides");
    assert!(n == 0 || x.len() > (n - 1) * incx, "gemv x length");
    assert!(m == 0 || y.len() > (m - 1) * incy, "gemv y length");
    for i in 0..m {
        y[i * incy] *= beta;
    }
    if op_a.row_stride() == 1 && incy == 1 {
        // Column-sweep: unit-stride inner loop (auto-vectorizable — the
        // "NEON-like" host path).
        for j in 0..n {
            let axj = alpha * x[j * incx];
            let col = op_a.col_slice(j, 0, m);
            for i in 0..m {
                y[i] += axj * col[i];
            }
        }
    } else {
        for j in 0..n {
            let axj = alpha * x[j * incx];
            for i in 0..m {
                y[i * incy] += axj * op_a.get(i, j);
            }
        }
    }
}

/// A ← α·x·yᵀ + A (rank-1 update)
pub fn ger<T: Real>(alpha: T, x: &[T], y: &[T], a: &mut MatMut<'_, T>) {
    let (m, n) = (a.rows(), a.cols());
    assert!(x.len() >= m && y.len() >= n, "ger dims");
    for j in 0..n {
        let ayj = alpha * y[j];
        if a.row_stride() == 1 {
            let col = a.col_slice_mut(j, 0, m);
            for i in 0..m {
                col[i] += ayj * x[i];
            }
        } else {
            for i in 0..m {
                a.update(i, j, |v| v + ayj * x[i]);
            }
        }
    }
}

/// y ← α·A·x + β·y for symmetric A (lower storage).
pub fn symv_lower<T: Real>(alpha: T, a: MatRef<'_, T>, x: &[T], beta: T, y: &mut [T]) {
    let n = a.rows();
    assert_eq!(a.cols(), n, "symv needs square A");
    for yi in y.iter_mut().take(n) {
        *yi *= beta;
    }
    for j in 0..n {
        // diagonal
        y[j] += alpha * a.get(j, j) * x[j];
        for i in j + 1..n {
            let v = a.get(i, j);
            y[i] += alpha * v * x[j];
            y[j] += alpha * v * x[i];
        }
    }
}

/// x ← op(A)·x for triangular A.
pub fn trmv<T: Real>(lower: bool, trans: Trans, unit: bool, a: MatRef<'_, T>, x: &mut [T]) {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    let op_a = if trans.is_trans() { a.t() } else { a };
    // After an op-transpose, "lower" flips.
    let eff_lower = lower ^ trans.is_trans();
    let mut out = vec![T::ZERO; n];
    for i in 0..n {
        let mut acc = if unit { x[i] } else { op_a.get(i, i) * x[i] };
        let (lo, hi) = if eff_lower { (0, i) } else { (i + 1, n) };
        for j in lo..hi {
            acc += op_a.get(i, j) * x[j];
        }
        out[i] = acc;
    }
    x[..n].copy_from_slice(&out);
}

/// Solve op(A)·x = b in place for triangular A.
pub fn trsv<T: Real>(lower: bool, trans: Trans, unit: bool, a: MatRef<'_, T>, x: &mut [T]) {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    let op_a = if trans.is_trans() { a.t() } else { a };
    let eff_lower = lower ^ trans.is_trans();
    if eff_lower {
        for i in 0..n {
            let mut acc = x[i];
            for j in 0..i {
                acc -= op_a.get(i, j) * x[j];
            }
            x[i] = if unit { acc } else { acc / op_a.get(i, i) };
        }
    } else {
        for i in (0..n).rev() {
            let mut acc = x[i];
            for j in i + 1..n {
                acc -= op_a.get(i, j) * x[j];
            }
            x[i] = if unit { acc } else { acc / op_a.get(i, i) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn gemv_n_and_t() {
        let a = Mat::<f64>::from_fn(2, 3, |i, j| (i * 3 + j) as f64 + 1.0);
        // A = [1 2 3; 4 5 6]
        let x = [1.0, 1.0, 1.0];
        let mut y = [0.0, 0.0];
        gemv(Trans::N, 1.0, a.view(), &x, 1, 0.0, &mut y, 1);
        assert_eq!(y, [6.0, 15.0]);
        let x2 = [1.0, 1.0];
        let mut y2 = [0.0; 3];
        gemv(Trans::T, 1.0, a.view(), &x2, 1, 0.0, &mut y2, 1);
        assert_eq!(y2, [5.0, 7.0, 9.0]);
    }

    #[test]
    fn gemv_strided_vectors() {
        // A = [1 2; 3 4]; x = [1, 10] strided by 2; y strided by 3.
        let a = Mat::<f64>::from_fn(2, 2, |i, j| (i * 2 + j) as f64 + 1.0);
        let x = [1.0, -7.0, 10.0];
        let mut y = [5.0, -1.0, -1.0, 6.0, -1.0, -1.0];
        gemv(Trans::N, 1.0, a.view(), &x, 2, 2.0, &mut y, 3);
        // y0 = 2*5 + (1*1 + 2*10) = 31; y1 = 2*6 + (3*1 + 4*10) = 55.
        assert_eq!(y, [31.0, -1.0, -1.0, 55.0, -1.0, -1.0]);
        // Transposed walk with strides exercises the non-contiguous path.
        let mut yt = [0.0, 9.0, 0.0, 9.0];
        gemv(Trans::T, 1.0, a.view(), &x, 2, 0.0, &mut yt, 2);
        // Aᵀ·[1,10] = [1*1+3*10, 2*1+4*10] = [31, 42].
        assert_eq!(yt, [31.0, 9.0, 42.0, 9.0]);
    }

    #[test]
    fn gemv_beta_accumulates() {
        let a = Mat::<f32>::full(2, 2, 1.0);
        let x = [1.0f32, 1.0];
        let mut y = [10.0f32, 20.0];
        gemv(Trans::N, 1.0, a.view(), &x, 1, 0.5, &mut y, 1);
        assert_eq!(y, [7.0, 12.0]);
    }

    #[test]
    fn ger_rank1() {
        let mut a = Mat::<f64>::zeros(2, 2);
        let mut v = a.view_mut();
        ger(2.0, &[1.0, 2.0], &[3.0, 4.0], &mut v);
        assert_eq!(a.get(0, 0), 6.0);
        assert_eq!(a.get(1, 1), 16.0);
    }

    #[test]
    fn symv_matches_full_gemv() {
        let n = 5;
        let full = {
            let lower =
                Mat::<f64>::from_fn(n, n, |i, j| if i >= j { (i + j) as f64 + 1.0 } else { 0.0 });
            Mat::from_fn(n, n, |i, j| if i >= j { lower.get(i, j) } else { lower.get(j, i) })
        };
        let lower =
            Mat::<f64>::from_fn(n, n, |i, j| if i >= j { (i + j) as f64 + 1.0 } else { -99.0 });
        let x: Vec<f64> = (0..n).map(|v| v as f64 - 2.0).collect();
        let mut y1 = vec![0.0; n];
        let mut y2 = vec![0.0; n];
        symv_lower(1.0, lower.view(), &x, 0.0, &mut y1);
        gemv(Trans::N, 1.0, full.view(), &x, 1, 0.0, &mut y2, 1);
        for i in 0..n {
            assert!((y1[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn trsv_inverts_trmv() {
        let n = 6;
        let a = Mat::<f64>::from_fn(n, n, |i, j| {
            if i > j {
                0.1 * (i + j) as f64
            } else if i == j {
                2.0 + i as f64
            } else {
                0.0
            }
        });
        for trans in [Trans::N, Trans::T] {
            for unit in [false, true] {
                let x0: Vec<f64> = (0..n).map(|v| (v as f64).sin()).collect();
                let mut x = x0.clone();
                trmv(true, trans, unit, a.view(), &mut x);
                trsv(true, trans, unit, a.view(), &mut x);
                for i in 0..n {
                    assert!((x[i] - x0[i]).abs() < 1e-10, "{trans:?} unit={unit}");
                }
            }
        }
    }
}
