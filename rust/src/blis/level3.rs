//! Level-3 BLAS beyond gemm: symm, syrk, trmm, trsm — host-side blocked
//! implementations that cast their inner products to gemm structure.
//! (The paper's library generates these through BLIS's level-3 framework;
//! only the gemm µ-kernel is Epiphany-accelerated, so these run at host
//! speed, which is also what HPL experiences for dtrsm.)

use super::params::Trans;
use crate::linalg::{Mat, MatRef, Real};

/// Plain host gemm used as the inner engine of the other level-3 ops (and
/// as an independent oracle in tests): C = α·op(A)·op(B) + β·C.
pub fn gemm_host<T: Real>(
    ta: Trans,
    tb: Trans,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut Mat<T>,
) {
    let op_a = if ta.is_trans() { a.t() } else { a };
    let op_b = if tb.is_trans() { b.t() } else { b };
    let (m, k, n) = (op_a.rows(), op_a.cols(), op_b.cols());
    assert_eq!(op_b.rows(), k, "gemm_host dims");
    assert_eq!((c.rows(), c.cols()), (m, n), "gemm_host C dims");
    // jki loop with a column accumulator: unit-stride inner loops when C
    // and op(A) are column-contiguous.
    let mut col = vec![T::ZERO; m];
    for j in 0..n {
        for v in col.iter_mut() {
            *v = T::ZERO;
        }
        for l in 0..k {
            let blj = op_b.get(l, j);
            if blj == T::ZERO {
                continue;
            }
            if op_a.row_stride() == 1 {
                let acol = op_a.col_slice(l, 0, m);
                for i in 0..m {
                    col[i] += acol[i] * blj;
                }
            } else {
                for i in 0..m {
                    col[i] += op_a.get(i, l) * blj;
                }
            }
        }
        for i in 0..m {
            let v = alpha * col[i] + beta * c.get(i, j);
            c.set(i, j, v);
        }
    }
}

/// C = α·A·B + β·C with symmetric A (lower storage), side = left.
pub fn symm_lower_left<T: Real>(
    alpha: T,
    a_lower: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut Mat<T>,
) {
    let n = a_lower.rows();
    assert_eq!(a_lower.cols(), n);
    // Materialize the symmetric operand once (host op, clarity over speed).
    let full =
        Mat::from_fn(n, n, |i, j| if i >= j { a_lower.get(i, j) } else { a_lower.get(j, i) });
    gemm_host(Trans::N, Trans::N, alpha, full.view(), b, beta, c);
}

/// C = α·A·Aᵀ + β·C, lower triangle of C updated (syrk).
pub fn syrk_lower<T: Real>(trans: Trans, alpha: T, a: MatRef<'_, T>, beta: T, c: &mut Mat<T>) {
    let op_a = if trans.is_trans() { a.t() } else { a };
    let (n, k) = (op_a.rows(), op_a.cols());
    assert_eq!((c.rows(), c.cols()), (n, n));
    for j in 0..n {
        for i in j..n {
            let mut acc = T::ZERO;
            for l in 0..k {
                acc += op_a.get(i, l) * op_a.get(j, l);
            }
            let v = alpha * acc + beta * c.get(i, j);
            c.set(i, j, v);
        }
    }
}

/// B ← α·op(A)·B for triangular A (left side).
pub fn trmm_left<T: Real>(
    lower: bool,
    trans: Trans,
    unit: bool,
    alpha: T,
    a: MatRef<'_, T>,
    b: &mut Mat<T>,
) {
    let m = a.rows();
    assert_eq!(a.cols(), m);
    assert_eq!(b.rows(), m);
    for j in 0..b.cols() {
        let mut col: Vec<T> = (0..m).map(|i| b.get(i, j)).collect();
        super::level2::trmv(lower, trans, unit, a, &mut col);
        for i in 0..m {
            b.set(i, j, alpha * col[i]);
        }
    }
}

/// Solve op(A)·X = α·B for triangular A (left side), X overwrites B.
/// Blocked: diagonal blocks solved by trsv columns, off-diagonal updates
/// via [`gemm_host`] — the standard BLIS decomposition.
pub fn trsm_left<T: Real>(
    lower: bool,
    trans: Trans,
    unit: bool,
    alpha: T,
    a: MatRef<'_, T>,
    b: &mut Mat<T>,
) {
    let m = a.rows();
    assert_eq!(a.cols(), m);
    assert_eq!(b.rows(), m);
    let n = b.cols();
    if alpha != T::ONE {
        for j in 0..n {
            for i in 0..m {
                let v = alpha * b.get(i, j);
                b.set(i, j, v);
            }
        }
    }
    const NB: usize = 64;
    let eff_lower = lower ^ trans.is_trans();
    let op_view = |i0: usize, j0: usize, r: usize, c: usize| -> (usize, usize, usize, usize) {
        // map logical op(A) block coords back to stored A coords
        if trans.is_trans() {
            (j0, i0, c, r)
        } else {
            (i0, j0, r, c)
        }
    };
    let blocks: Vec<(usize, usize)> =
        (0..m.div_ceil(NB)).map(|b| (b * NB, NB.min(m - b * NB))).collect();
    let order: Vec<usize> = if eff_lower {
        (0..blocks.len()).collect()
    } else {
        (0..blocks.len()).rev().collect()
    };
    for &bi in &order {
        let (i0, bs) = blocks[bi];
        // Solve the diagonal block against all RHS columns.
        let (di, dj, dr, dc) = op_view(i0, i0, bs, bs);
        let diag = a.sub(di, dj, dr, dc);
        for j in 0..n {
            let mut col: Vec<T> = (0..bs).map(|i| b.get(i0 + i, j)).collect();
            // `lower` describes the *storage* of the diagonal block; trsv
            // applies the op-transpose flip internally.
            super::level2::trsv(lower, trans, unit, diag, &mut col);
            for i in 0..bs {
                b.set(i0 + i, j, col[i]);
            }
        }
        // Update the remaining blocks: B_rest -= op(A)_rest,blk · X_blk.
        let rest: Vec<(usize, usize)> = if eff_lower {
            blocks[bi + 1..].to_vec()
        } else {
            blocks[..bi].to_vec()
        };
        if rest.is_empty() {
            continue;
        }
        let x_blk = Mat::from_fn(bs, n, |i, j| b.get(i0 + i, j));
        for (r0, rs) in rest {
            let (ai, aj, ar, ac) = op_view(r0, i0, rs, bs);
            let a_blk = a.sub(ai, aj, ar, ac);
            let mut update = Mat::from_fn(rs, n, |i, j| b.get(r0 + i, j));
            let ta = if trans.is_trans() { Trans::T } else { Trans::N };
            gemm_host(ta, Trans::N, T::ZERO - T::ONE, a_blk, x_blk.view(), T::ONE, &mut update);
            for j in 0..n {
                for i in 0..rs {
                    b.set(r0 + i, j, update.get(i, j));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{max_scaled_err, Mat};

    fn naive_gemm(a: &Mat<f64>, b: &Mat<f64>) -> Mat<f64> {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        Mat::from_fn(m, n, |i, j| {
            let mut acc = 0.0;
            for l in 0..k {
                acc += a.get(i, l) * b.get(l, j);
            }
            acc
        })
    }

    #[test]
    fn gemm_host_matches_naive_all_ops() {
        let (m, n, k) = (13, 9, 17);
        for ta in [Trans::N, Trans::T] {
            for tb in [Trans::N, Trans::T] {
                let a_log = Mat::<f64>::randn(m, k, 1);
                let b_log = Mat::<f64>::randn(k, n, 2);
                let a = if ta.is_trans() { a_log.transposed() } else { a_log.clone() };
                let b = if tb.is_trans() { b_log.transposed() } else { b_log.clone() };
                let mut c = Mat::<f64>::randn(m, n, 3);
                let c0 = c.clone();
                gemm_host(ta, tb, 2.0, a.view(), b.view(), -1.0, &mut c);
                let prod = naive_gemm(&a_log, &b_log);
                let want = Mat::from_fn(m, n, |i, j| 2.0 * prod.get(i, j) - c0.get(i, j));
                assert!(max_scaled_err(c.view(), want.view()) < 1e-12);
            }
        }
    }

    #[test]
    fn trsm_solves() {
        let m = 150; // > NB to exercise blocking
        let n = 7;
        let a = Mat::<f64>::from_fn(m, m, |i, j| {
            if i > j {
                0.01 * ((i * 31 + j) % 17) as f64
            } else if i == j {
                3.0 + (i % 5) as f64
            } else {
                0.0
            }
        });
        for trans in [Trans::N, Trans::T] {
            for unit in [false, true] {
                let x_true = Mat::<f64>::randn(m, n, 4);
                // B = op(A)·X
                let op_a = if trans.is_trans() { a.transposed() } else { a.clone() };
                let mut op_au = op_a.clone();
                if unit {
                    for i in 0..m {
                        op_au.set(i, i, 1.0);
                    }
                }
                let b0 = naive_gemm(&op_au, &x_true);
                let mut b = b0.clone();
                trsm_left(true, trans, unit, 1.0, a.view(), &mut b);
                let e = max_scaled_err(b.view(), x_true.view());
                assert!(e < 1e-9, "{trans:?} unit={unit} err {e}");
            }
        }
    }

    #[test]
    fn trsm_alpha_scales_rhs() {
        let a = Mat::<f64>::from_fn(3, 3, |i, j| if i == j { 2.0 } else { 0.0 });
        let mut b = Mat::<f64>::full(3, 2, 4.0);
        trsm_left(true, Trans::N, false, 0.5, a.view(), &mut b);
        // X = 0.5·B / 2 = 1.0
        for j in 0..2 {
            for i in 0..3 {
                assert!((b.get(i, j) - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn syrk_matches_gemm() {
        let n = 8;
        let k = 5;
        let a = Mat::<f64>::randn(n, k, 5);
        let mut c = Mat::<f64>::zeros(n, n);
        syrk_lower(Trans::N, 1.0, a.view(), 0.0, &mut c);
        let full = naive_gemm(&a, &a.transposed());
        for j in 0..n {
            for i in j..n {
                assert!((c.get(i, j) - full.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn symm_uses_lower_storage() {
        let n = 6;
        let lower = Mat::<f64>::from_fn(
            n,
            n,
            |i, j| if i >= j { ((i + 2 * j) % 7) as f64 } else { f64::NAN },
        );
        let b = Mat::<f64>::randn(n, 4, 6);
        let mut c = Mat::<f64>::zeros(n, 4);
        symm_lower_left(1.0, lower.view(), b.view(), 0.0, &mut c);
        assert!(c.as_slice().iter().all(|v| v.is_finite()), "NaNs leaked from upper");
    }

    #[test]
    fn trmm_matches_explicit_product() {
        let m = 5;
        let a = Mat::<f64>::from_fn(m, m, |i, j| if i >= j { (i + j + 1) as f64 } else { 0.0 });
        let b0 = Mat::<f64>::randn(m, 3, 7);
        let mut b = b0.clone();
        trmm_left(true, Trans::N, false, 2.0, a.view(), &mut b);
        let want = naive_gemm(&a, &b0);
        for j in 0..3 {
            for i in 0..m {
                assert!((b.get(i, j) - 2.0 * want.get(i, j)).abs() < 1e-10);
            }
        }
    }
}

/// Solve X·op(A) = α·B for triangular A (right side), X overwrites B.
/// Implemented via the left-side solver on the transposed system:
/// (X·op(A))ᵀ = op(A)ᵀ·Xᵀ = α·Bᵀ.
pub fn trsm_right<T: Real>(
    lower: bool,
    trans: Trans,
    unit: bool,
    alpha: T,
    a: MatRef<'_, T>,
    b: &mut Mat<T>,
) {
    let flipped = if trans.is_trans() { Trans::N } else { Trans::T };
    let mut bt = b.transposed();
    trsm_left(lower, flipped, unit, alpha, a, &mut bt);
    *b = bt.transposed();
}

/// C = α·(A·Bᵀ + B·Aᵀ) + β·C, lower triangle updated (syr2k).
pub fn syr2k_lower<T: Real>(
    trans: Trans,
    alpha: T,
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    beta: T,
    c: &mut Mat<T>,
) {
    let op_a = if trans.is_trans() { a.t() } else { a };
    let op_b = if trans.is_trans() { b.t() } else { b };
    let (n, k) = (op_a.rows(), op_a.cols());
    assert_eq!((op_b.rows(), op_b.cols()), (n, k), "syr2k dims");
    assert_eq!((c.rows(), c.cols()), (n, n));
    for j in 0..n {
        for i in j..n {
            let mut acc = T::ZERO;
            for l in 0..k {
                acc += op_a.get(i, l) * op_b.get(j, l) + op_b.get(i, l) * op_a.get(j, l);
            }
            let v = alpha * acc + beta * c.get(i, j);
            c.set(i, j, v);
        }
    }
}

#[cfg(test)]
mod tests_extra {
    use super::*;
    use crate::linalg::{max_scaled_err, Mat};

    #[test]
    fn trsm_right_solves() {
        let (m, n) = (5, 120); // n > NB exercises the blocked path
        let a = Mat::<f64>::from_fn(n, n, |i, j| {
            if i > j {
                0.02 * ((i + 3 * j) % 13) as f64
            } else if i == j {
                2.5 + (i % 3) as f64
            } else {
                0.0
            }
        });
        for trans in [Trans::N, Trans::T] {
            let x_true = Mat::<f64>::randn(m, n, 11);
            // B = X · op(A)
            let op_a = if trans.is_trans() { a.transposed() } else { a.clone() };
            let mut b = Mat::<f64>::zeros(m, n);
            gemm_host(Trans::N, Trans::N, 1.0, x_true.view(), op_a.view(), 0.0, &mut b);
            trsm_right(true, trans, false, 1.0, a.view(), &mut b);
            let e = max_scaled_err(b.view(), x_true.view());
            assert!(e < 1e-9, "{trans:?} err {e}");
        }
    }

    #[test]
    fn syr2k_matches_explicit() {
        let (n, k) = (7, 4);
        let a = Mat::<f64>::randn(n, k, 21);
        let b = Mat::<f64>::randn(n, k, 22);
        let mut c = Mat::<f64>::zeros(n, n);
        syr2k_lower(Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c);
        let mut full = Mat::<f64>::zeros(n, n);
        gemm_host(Trans::N, Trans::T, 1.0, a.view(), b.view(), 0.0, &mut full);
        let mut full2 = Mat::<f64>::zeros(n, n);
        gemm_host(Trans::N, Trans::T, 1.0, b.view(), a.view(), 0.0, &mut full2);
        for j in 0..n {
            for i in j..n {
                let want = full.get(i, j) + full2.get(i, j);
                assert!((c.get(i, j) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn syr2k_transposed_operands() {
        let (n, k) = (6, 3);
        let a = Mat::<f64>::randn(k, n, 23); // stored kxn, trans=T
        let b = Mat::<f64>::randn(k, n, 24);
        let mut c1 = Mat::<f64>::zeros(n, n);
        syr2k_lower(Trans::T, 2.0, a.view(), b.view(), 0.0, &mut c1);
        let mut c2 = Mat::<f64>::zeros(n, n);
        syr2k_lower(Trans::N, 2.0, a.transposed().view(), b.transposed().view(), 0.0, &mut c2);
        for j in 0..n {
            for i in j..n {
                assert!((c1.get(i, j) - c2.get(i, j)).abs() < 1e-12);
            }
        }
    }
}
