//! Blocking autotuner: a deterministic search over [`BlisContext`]
//! blocking parameters (`mr`/`nr`/`kc`, i.e. the micro-tile geometry plus
//! the K cap), driven by the calibrated timing model as cost function.
//!
//! Olofsson et al. (arXiv:1412.5538) and Ross/Richie (arXiv:1410.8772)
//! both report that blocking/unrolling choices dominate the achievable
//! fraction of peak on Epiphany-class chips — exactly the knob space
//! [`BlisContext`] exposes but the paper fixes by hand (m=192, n=256,
//! KSUB=64, NSUB=4). This module searches that space:
//!
//! * **Candidates** are every [`KernelGeometry`] from a fixed grid that
//!   (a) passes [`KernelGeometry::validate`], (b) fits the per-core
//!   32 KiB local memory exactly as [`crate::epiphany::chip::Chip`]
//!   would allocate it, and (c) fits both double-buffered input panels
//!   plus the output in HC-RAM — crossed with a small `kc` grid.
//! * **Cost** is the projected seconds of the caller's target workload:
//!   `⌈m/mr⌉·⌈n/nr⌉` µ-kernel calls, each priced by
//!   [`project_ukr_call`] (the same calibrated model the paper tables
//!   are reproduced from), with `kc > 0` splitting each call's K loop.
//! * **Determinism**: same model + same [`AutotuneConfig`] always yields
//!   the same [`TunedParams`] — candidates are enumerated in a fixed
//!   order and ties keep the earliest candidate. An optional
//!   *measured mode* re-ranks the model's top candidates by wall-clock
//!   of the vectorized host micro-kernel and is deliberately outside
//!   that guarantee.
//!
//! Entry points: [`autotune`] (pure function),
//! `Platform::builder().autotune(..)` (boots the pool with the tuned
//! geometry), and the CLI's `sgemm --autotune` (prints the
//! [`TunedParams::report`] dump).

use super::params::BlisContext;
use crate::epiphany::kernel::KernelGeometry;
use crate::epiphany::memory::{CODE_BYTES, STACK_CTRL_BYTES};
use crate::epiphany::timing::{CalibratedModel, WalkClass};
use crate::epiphany::{HCRAM_BYTES, LOCAL_MEM_BYTES};
use crate::host::microkernel::{host_sgemm_variant, UkrVariant};
use crate::host::projection::{project_ukr_call, ProjectionParams};
use crate::util::tables::{gf, secs, Table};

/// The fixed candidate grids. Kept small and explicit: the search must be
/// reproducible from the source alone, and every value is bounds-checked
/// against the memory model before it becomes a candidate.
const M_GRID: [usize; 8] = [32, 64, 96, 128, 160, 192, 224, 256];
const N_GRID: [usize; 6] = [64, 128, 192, 256, 384, 512];
const KSUB_GRID: [usize; 4] = [16, 32, 64, 128];
const NSUB_GRID: [usize; 4] = [1, 2, 4, 8];
const KC_GRID: [usize; 3] = [0, 1024, 4096];

/// How many model-ranked leaders the report keeps (and measured mode
/// re-times).
const LEADERBOARD: usize = 8;

/// What to tune for.
#[derive(Clone, Copy, Debug)]
pub struct AutotuneConfig {
    /// Target workload rows.
    pub m: usize,
    /// Target workload columns.
    pub n: usize,
    /// Target workload contraction depth.
    pub k: usize,
    /// Whether µ-kernel calls cross the HH-RAM service IPC (true for the
    /// production resident-service path; false for same-process ablations).
    pub ipc: bool,
    /// Measured-mode refinement: re-rank the model's top candidates by
    /// wall-clock of the vectorized host micro-kernel on real tiles.
    /// Off by default — it trades the determinism guarantee for machine
    /// feedback, which only matters when the host path does the compute.
    pub measure: bool,
}

impl AutotuneConfig {
    /// Tune for one `C = A·B` workload through the resident service
    /// (model-only: deterministic).
    pub fn for_workload(m: usize, n: usize, k: usize) -> Self {
        AutotuneConfig { m, n, k: k.max(1), ipc: true, measure: false }
    }

    /// Enable measured-mode refinement (see [`AutotuneConfig::measure`]).
    pub fn measured(mut self) -> Self {
        self.measure = true;
        self
    }
}

/// One evaluated blocking candidate.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    /// The micro-kernel geometry (defines `mr = m`, `nr = n`).
    pub geom: KernelGeometry,
    /// K cap per µ-kernel call (0 = unbounded).
    pub kc: usize,
    /// Projected seconds for the whole target workload.
    pub projected_s: f64,
    /// Workload flop rate against the projection (padding waste included,
    /// so this is what the caller would actually observe).
    pub projected_gflops: f64,
    /// Measured wall-clock seconds of one vectorized host-kernel tile
    /// call (measured mode only).
    pub measured_s: Option<f64>,
}

impl Candidate {
    /// The [`BlisContext`] this candidate tunes.
    pub fn context(&self) -> BlisContext {
        BlisContext { mr: self.geom.m, nr: self.geom.n, kc: self.kc }
    }
}

/// The autotuner's dumpable result: the winning blocking plus the
/// leaderboard it beat.
#[derive(Clone, Debug)]
pub struct TunedParams {
    /// The workload this tuning targeted (m, n, k).
    pub workload: (usize, usize, usize),
    /// The winning candidate.
    pub best: Candidate,
    /// Model-ranked leaders (ascending projected seconds; the winner is
    /// `leaders[0]` unless measured mode re-ranked).
    pub leaders: Vec<Candidate>,
    /// How many valid candidates the grid produced.
    pub evaluated: usize,
    /// Whether measured-mode refinement ran.
    pub measured: bool,
}

impl TunedParams {
    /// The tuned geometry to boot the chip pool with.
    pub fn geometry(&self) -> KernelGeometry {
        self.best.geom
    }

    /// The tuned blocking context for the BLIS driver.
    pub fn context(&self) -> BlisContext {
        self.best.context()
    }

    /// Human-readable report: the winner plus the leaderboard table.
    pub fn report(&self) -> String {
        let (m, n, k) = self.workload;
        let g = self.best.geom;
        let mode = if self.measured { "model + measured" } else { "model (deterministic)" };
        let mut t = Table::new(
            &format!("autotune {m}x{n}x{k} — {} candidates, {mode}", self.evaluated),
            &["rank", "m", "n", "ksub", "nsub", "kc", "proj s", "proj GF", "meas s"],
        );
        for (rank, c) in self.leaders.iter().enumerate() {
            t.row(&[
                format!("{}", rank + 1),
                format!("{}", c.geom.m),
                format!("{}", c.geom.n),
                format!("{}", c.geom.ksub),
                format!("{}", c.geom.nsub),
                format!("{}", c.kc),
                secs(c.projected_s),
                gf(c.projected_gflops),
                c.measured_s.map(secs).unwrap_or_else(|| "-".into()),
            ]);
        }
        format!(
            "{}\nbest: m={} n={} ksub={} nsub={} kc={} — projected {} s ({} GFLOPS)\n",
            t.render(),
            g.m,
            g.n,
            g.ksub,
            g.nsub,
            self.best.kc,
            secs(self.best.projected_s),
            gf(self.best.projected_gflops)
        )
    }
}

/// Whether `geom` fits the per-core 32 KiB local memory, mirroring the
/// exact allocation [`crate::epiphany::chip::Chip::new`] performs: the
/// 8 KiB code bank, the A/B input slices, the RES1/RES2 accumulators, and
/// the 2 KiB stack/control reserve.
pub fn fits_local_memory(geom: &KernelGeometry) -> bool {
    let elems = geom.m * geom.k_slice()
        + geom.k_slice() * geom.n
        + geom.m * geom.nsub
        + geom.m * geom.cols_per_core();
    CODE_BYTES + 4 * elems + STACK_CTRL_BYTES <= LOCAL_MEM_BYTES
}

/// Whether `geom`'s HC-RAM working set fits: both double-buffered input
/// panels (selector 0/1) plus the output segment, as laid out by the
/// chip's HC-RAM map.
pub fn fits_hcram(geom: &KernelGeometry) -> bool {
    let elems = 2 * geom.m * geom.ksub + 2 * geom.ksub * geom.n + geom.m * geom.n;
    4 * elems <= HCRAM_BYTES
}

/// Every geometry from the fixed grid that validates and fits both
/// memory budgets, in deterministic enumeration order.
pub fn candidate_geometries() -> Vec<KernelGeometry> {
    let mut out = Vec::new();
    for &m in &M_GRID {
        for &n in &N_GRID {
            for &ksub in &KSUB_GRID {
                for &nsub in &NSUB_GRID {
                    let g = KernelGeometry { m, n, ksub, nsub };
                    if g.validate().is_ok() && fits_local_memory(&g) && fits_hcram(&g) {
                        out.push(g);
                    }
                }
            }
        }
    }
    out
}

/// Projected seconds of one µ-kernel call of depth `k` at `geom` (the
/// production service path's walk classes: contiguous A, strided B).
fn call_s(model: &CalibratedModel, geom: KernelGeometry, k: usize, ipc: bool) -> f64 {
    let p = ProjectionParams {
        m: geom.m,
        n: geom.n,
        k,
        ksub: geom.ksub,
        nsub: geom.nsub,
        class_a: WalkClass::Contig,
        class_b: WalkClass::StridedB,
        ipc,
        dgemm: false,
        blis: true,
    };
    project_ukr_call(model, &p).total_s
}

/// Projected seconds of the whole target workload under one candidate:
/// the full tile cover (padded edge tiles are charged at full tile cost,
/// matching what the packed driver really does), K split by `kc`.
fn workload_s(
    model: &CalibratedModel,
    geom: KernelGeometry,
    kc: usize,
    cfg: &AutotuneConfig,
) -> f64 {
    let tiles = BlisContext::tiles(cfg.m, geom.m) * BlisContext::tiles(cfg.n, geom.n);
    let per_tile = if kc == 0 || kc >= cfg.k {
        call_s(model, geom, cfg.k, cfg.ipc)
    } else {
        let full = cfg.k / kc;
        let rem = cfg.k % kc;
        let mut s = full as f64 * call_s(model, geom, kc, cfg.ipc);
        if rem > 0 {
            s += call_s(model, geom, rem, cfg.ipc);
        }
        s
    };
    tiles as f64 * per_tile
}

/// Deterministic blocking search (see the module docs). Pure function of
/// `(model, cfg)` when `cfg.measure` is off.
pub fn autotune(model: &CalibratedModel, cfg: &AutotuneConfig) -> TunedParams {
    let flops = 2.0 * cfg.m as f64 * cfg.n as f64 * cfg.k as f64;
    let mut all: Vec<Candidate> = Vec::new();
    for geom in candidate_geometries() {
        for &kc in &KC_GRID {
            let s = workload_s(model, geom, kc, cfg);
            all.push(Candidate {
                geom,
                kc,
                projected_s: s,
                projected_gflops: flops / s / 1e9,
                measured_s: None,
            });
        }
    }
    let evaluated = all.len();
    // Total deterministic order: projected seconds, then the geometry
    // tuple (enumeration order already groups equal-cost candidates, but
    // an explicit key keeps the sort stable under any future change).
    all.sort_by(|a, b| a.projected_s.total_cmp(&b.projected_s).then_with(|| key(a).cmp(&key(b))));
    all.truncate(LEADERBOARD);
    let mut leaders = all;
    if cfg.measure {
        measure_leaders(&mut leaders, cfg);
    }
    let best = pick_best(&leaders);
    TunedParams {
        workload: (cfg.m, cfg.n, cfg.k),
        best,
        leaders,
        evaluated,
        measured: cfg.measure,
    }
}

fn key(c: &Candidate) -> (usize, usize, usize, usize, usize) {
    (c.geom.m, c.geom.n, c.geom.ksub, c.geom.nsub, c.kc)
}

/// Measured-mode refinement: time one vectorized host-kernel tile call
/// (m × n × ksub) per leader and store the wall seconds. Inputs are a
/// fixed arithmetic pattern — no RNG, so only the machine varies.
fn measure_leaders(leaders: &mut [Candidate], cfg: &AutotuneConfig) {
    let variant = UkrVariant::fastest();
    for c in leaders.iter_mut() {
        let (m, n) = (c.geom.m, c.geom.n);
        let k = c.geom.ksub.min(cfg.k.max(1));
        let fill = |len: usize, scale: f32| -> Vec<f32> {
            (0..len).map(|i| ((i % 13) as f32 - 6.0) * scale).collect()
        };
        let a = fill(m * k, 0.25);
        let b = fill(k * n, 0.125);
        let c_in = fill(m * n, 0.5);
        // One warmup, then best-of-3: tiny tiles are noisy and this path
        // is explicitly outside the determinism guarantee.
        std::hint::black_box(host_sgemm_variant(variant, m, n, k, 1.0, &a, &b, 0.5, &c_in));
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let (_, s) = crate::util::timed(|| {
                std::hint::black_box(host_sgemm_variant(variant, m, n, k, 1.0, &a, &b, 0.5, &c_in))
            });
            best = best.min(s);
        }
        c.measured_s = Some(best);
    }
}

/// The winner: measured seconds when every leader carries one (ties and
/// the model-only mode fall back to the model ranking, where index 0 is
/// already the deterministic best).
fn pick_best(leaders: &[Candidate]) -> Candidate {
    let mut best = leaders[0];
    for c in &leaders[1..] {
        if let (Some(cm), Some(bm)) = (c.measured_s, best.measured_s) {
            if cm < bm {
                best = *c;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::PEAK_GFLOPS;
    use crate::util::proptest::{forall, Config};

    #[test]
    fn paper_geometry_is_a_candidate_and_exactly_fills_local_memory() {
        let paper = KernelGeometry::paper();
        assert!(candidate_geometries().contains(&paper));
        assert!(fits_local_memory(&paper) && fits_hcram(&paper));
        // The paper config saturates the 32 KiB core budget to the byte.
        let elems = paper.m * paper.k_slice()
            + paper.k_slice() * paper.n
            + paper.m * paper.nsub
            + paper.m * paper.cols_per_core();
        assert_eq!(CODE_BYTES + 4 * elems + STACK_CTRL_BYTES, LOCAL_MEM_BYTES);
    }

    #[test]
    fn every_candidate_respects_all_bounds() {
        let geoms = candidate_geometries();
        assert!(geoms.len() > 20, "grid produced only {} candidates", geoms.len());
        for g in &geoms {
            g.validate().unwrap();
            assert!(fits_local_memory(g), "{g:?} exceeds local memory");
            assert!(fits_hcram(g), "{g:?} exceeds HC-RAM");
        }
    }

    #[test]
    fn autotune_is_deterministic_and_candidates_respect_peak_cap() {
        let model = CalibratedModel::default();
        forall(
            Config { cases: 24, seed: 0xA07 },
            |rng| {
                (
                    64 + rng.next_below(2048),
                    64 + rng.next_below(2048),
                    1 + rng.next_below(4096),
                )
            },
            |&(m, n, k)| {
                let cfg = AutotuneConfig::for_workload(m, n, k);
                let t1 = autotune(&model, &cfg);
                let t2 = autotune(&model, &cfg);
                // Determinism: same inputs → same TunedParams.
                assert_eq!(t1.best.geom, t2.best.geom);
                assert_eq!(t1.best.kc, t2.best.kc);
                assert_eq!(t1.best.projected_s.to_bits(), t2.best.projected_s.to_bits());
                assert_eq!(t1.leaders.len(), t2.leaders.len());
                for (a, b) in t1.leaders.iter().zip(&t2.leaders) {
                    assert_eq!(a.geom, b.geom);
                    assert_eq!(a.projected_s.to_bits(), b.projected_s.to_bits());
                }
                // Every emitted candidate respects the memory bounds and
                // the 19.2 GFLOPS chip peak.
                for c in &t1.leaders {
                    assert!(fits_local_memory(&c.geom) && fits_hcram(&c.geom));
                    assert!(
                        c.projected_gflops < PEAK_GFLOPS,
                        "{:?} projects {} GF over peak",
                        c.geom,
                        c.projected_gflops
                    );
                }
                t1.best.projected_s > 0.0 && t1.evaluated > 0
            },
        );
    }

    #[test]
    fn tuned_context_matches_tuned_geometry() {
        let model = CalibratedModel::default();
        let t = autotune(&model, &AutotuneConfig::for_workload(4096, 4096, 4096));
        let ctx = t.context();
        assert_eq!((ctx.mr, ctx.nr), (t.geometry().m, t.geometry().n));
        // The model has no per-call amortization to gain from capping K,
        // so the deterministic winner keeps K unblocked.
        assert_eq!(ctx.kc, 0);
        // The winner can never lose to the paper's hand blocking under
        // the same cost model.
        let paper_s = workload_s(
            &model,
            KernelGeometry::paper(),
            0,
            &AutotuneConfig::for_workload(4096, 4096, 4096),
        );
        assert!(t.best.projected_s <= paper_s);
        let report = t.report();
        assert!(report.contains("autotune 4096x4096x4096"));
        assert!(report.contains("best: m="));
    }

    #[test]
    fn measured_mode_times_every_leader() {
        let model = CalibratedModel::default();
        let t = autotune(&model, &AutotuneConfig::for_workload(256, 256, 128).measured());
        assert!(t.measured);
        assert!(t.leaders.iter().all(|c| c.measured_s.is_some()));
        assert!(t.best.measured_s.unwrap() > 0.0);
        assert!(t.report().contains("model + measured"));
    }
}
