//! The generated classic BLAS API — FORTRAN-BLAS-style names over raw
//! column-major buffers with leading dimensions, exactly what LAPACK,
//! ScaLAPACK or HPL link against (paper §3.1: BLIS "also generates the
//! classic FORTRAN BLAS library").
//!
//! Level-3 sgemm/dgemm route through the Epiphany service; everything else
//! is host compute, as in the paper's instantiation.

use super::gemm::Blas;
use super::params::Trans;
use super::{level1, level2, level3};
use crate::linalg::{Mat, MatMut, MatRef};
use anyhow::Result;

/// The library handle a "linked application" holds.
pub struct BlasLibrary {
    inner: std::sync::Arc<Blas>,
}

impl BlasLibrary {
    pub fn new(inner: std::sync::Arc<Blas>) -> Self {
        BlasLibrary { inner }
    }

    pub fn inner(&self) -> &Blas {
        &self.inner
    }

    // ---------------- level 1 (f32) ----------------

    pub fn saxpy(&self, n: usize, alpha: f32, x: &[f32], incx: usize, y: &mut [f32], incy: usize) {
        level1::axpy(n, alpha, x, incx, y, incy);
    }
    pub fn sscal(&self, n: usize, alpha: f32, x: &mut [f32], incx: usize) {
        level1::scal(n, alpha, x, incx);
    }
    pub fn scopy(&self, n: usize, x: &[f32], incx: usize, y: &mut [f32], incy: usize) {
        level1::copy(n, x, incx, y, incy);
    }
    pub fn sswap(&self, n: usize, x: &mut [f32], incx: usize, y: &mut [f32], incy: usize) {
        level1::swap(n, x, incx, y, incy);
    }
    pub fn sdot(&self, n: usize, x: &[f32], incx: usize, y: &[f32], incy: usize) -> f32 {
        level1::dot(n, x, incx, y, incy)
    }
    pub fn snrm2(&self, n: usize, x: &[f32], incx: usize) -> f32 {
        level1::nrm2(n, x, incx)
    }
    pub fn sasum(&self, n: usize, x: &[f32], incx: usize) -> f32 {
        level1::asum(n, x, incx)
    }
    pub fn isamax(&self, n: usize, x: &[f32], incx: usize) -> Option<usize> {
        level1::iamax(n, x, incx)
    }
    pub fn srot(
        &self,
        n: usize,
        x: &mut [f32],
        incx: usize,
        y: &mut [f32],
        incy: usize,
        c: f32,
        s: f32,
    ) {
        level1::rot(n, x, incx, y, incy, c, s);
    }

    // ---------------- level 1 (f64) ----------------

    pub fn daxpy(&self, n: usize, alpha: f64, x: &[f64], incx: usize, y: &mut [f64], incy: usize) {
        level1::axpy(n, alpha, x, incx, y, incy);
    }
    pub fn dscal(&self, n: usize, alpha: f64, x: &mut [f64], incx: usize) {
        level1::scal(n, alpha, x, incx);
    }
    pub fn dcopy(&self, n: usize, x: &[f64], incx: usize, y: &mut [f64], incy: usize) {
        level1::copy(n, x, incx, y, incy);
    }
    pub fn dswap(&self, n: usize, x: &mut [f64], incx: usize, y: &mut [f64], incy: usize) {
        level1::swap(n, x, incx, y, incy);
    }
    pub fn ddot(&self, n: usize, x: &[f64], incx: usize, y: &[f64], incy: usize) -> f64 {
        level1::dot(n, x, incx, y, incy)
    }
    pub fn dnrm2(&self, n: usize, x: &[f64], incx: usize) -> f64 {
        level1::nrm2(n, x, incx)
    }
    pub fn dasum(&self, n: usize, x: &[f64], incx: usize) -> f64 {
        level1::asum(n, x, incx)
    }
    pub fn idamax(&self, n: usize, x: &[f64], incx: usize) -> Option<usize> {
        level1::iamax(n, x, incx)
    }

    // ---------------- level 2 ----------------

    #[allow(clippy::too_many_arguments)]
    pub fn sgemv(
        &self,
        trans: Trans,
        m: usize,
        n: usize,
        alpha: f32,
        a: &[f32],
        lda: usize,
        x: &[f32],
        beta: f32,
        y: &mut [f32],
    ) {
        let a_v = MatRef::from_col_major(m, n, lda, a);
        level2::gemv(trans, alpha, a_v, x, beta, y);
        self.inner.charge_host_op(2.0 * m as f64 * n as f64, host_rate());
    }

    #[allow(clippy::too_many_arguments)]
    pub fn dgemv(
        &self,
        trans: Trans,
        m: usize,
        n: usize,
        alpha: f64,
        a: &[f64],
        lda: usize,
        x: &[f64],
        beta: f64,
        y: &mut [f64],
    ) {
        let a_v = MatRef::from_col_major(m, n, lda, a);
        level2::gemv(trans, alpha, a_v, x, beta, y);
        self.inner.charge_host_op(2.0 * m as f64 * n as f64, host_rate());
    }

    pub fn sger(
        &self,
        m: usize,
        n: usize,
        alpha: f32,
        x: &[f32],
        y: &[f32],
        a: &mut [f32],
        lda: usize,
    ) {
        let mut a_v = MatMut::from_col_major(m, n, lda, a);
        level2::ger(alpha, x, y, &mut a_v);
        self.inner.charge_host_op(2.0 * m as f64 * n as f64, host_rate());
    }

    pub fn dger(
        &self,
        m: usize,
        n: usize,
        alpha: f64,
        x: &[f64],
        y: &[f64],
        a: &mut [f64],
        lda: usize,
    ) {
        let mut a_v = MatMut::from_col_major(m, n, lda, a);
        level2::ger(alpha, x, y, &mut a_v);
        self.inner.charge_host_op(2.0 * m as f64 * n as f64, host_rate());
    }

    pub fn strsv(
        &self,
        lower: bool,
        trans: Trans,
        unit: bool,
        n: usize,
        a: &[f32],
        lda: usize,
        x: &mut [f32],
    ) {
        let a_v = MatRef::from_col_major(n, n, lda, a);
        level2::trsv(lower, trans, unit, a_v, x);
        self.inner.charge_host_op((n * n) as f64, host_rate());
    }

    pub fn dtrsv(
        &self,
        lower: bool,
        trans: Trans,
        unit: bool,
        n: usize,
        a: &[f64],
        lda: usize,
        x: &mut [f64],
    ) {
        let a_v = MatRef::from_col_major(n, n, lda, a);
        level2::trsv(lower, trans, unit, a_v, x);
        self.inner.charge_host_op((n * n) as f64, host_rate());
    }

    pub fn strmv(
        &self,
        lower: bool,
        trans: Trans,
        unit: bool,
        n: usize,
        a: &[f32],
        lda: usize,
        x: &mut [f32],
    ) {
        let a_v = MatRef::from_col_major(n, n, lda, a);
        level2::trmv(lower, trans, unit, a_v, x);
        self.inner.charge_host_op((n * n) as f64, host_rate());
    }

    // ---------------- level 3 ----------------

    /// The Epiphany-accelerated sgemm (the paper's headline function).
    #[allow(clippy::too_many_arguments)]
    pub fn sgemm(
        &self,
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        a: &[f32],
        lda: usize,
        b: &[f32],
        ldb: usize,
        beta: f32,
        c: &mut [f32],
        ldc: usize,
    ) -> Result<()> {
        let (ar, ac) = if ta.is_trans() { (k, m) } else { (m, k) };
        let (br, bc) = if tb.is_trans() { (n, k) } else { (k, n) };
        let a_v = MatRef::from_col_major(ar, ac, lda, a);
        let b_v = MatRef::from_col_major(br, bc, ldb, b);
        // Copy-out/copy-in for C (the facade owns layout adaptation).
        let mut c_m = Mat::from_fn(m, n, |i, j| c[i + j * ldc]);
        self.inner.sgemm(ta, tb, alpha, a_v, b_v, beta, &mut c_m)?;
        for j in 0..n {
            for i in 0..m {
                c[i + j * ldc] = c_m.get(i, j);
            }
        }
        Ok(())
    }

    /// dgemm — the paper's "false dgemm": f64 API, Epiphany f32 compute.
    #[allow(clippy::too_many_arguments)]
    pub fn dgemm(
        &self,
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        beta: f64,
        c: &mut [f64],
        ldc: usize,
    ) -> Result<()> {
        let (ar, ac) = if ta.is_trans() { (k, m) } else { (m, k) };
        let (br, bc) = if tb.is_trans() { (n, k) } else { (k, n) };
        let a_v = MatRef::from_col_major(ar, ac, lda, a);
        let b_v = MatRef::from_col_major(br, bc, ldb, b);
        let mut c_m = Mat::from_fn(m, n, |i, j| c[i + j * ldc]);
        self.inner.dgemm_false(ta, tb, alpha, a_v, b_v, beta, &mut c_m)?;
        for j in 0..n {
            for i in 0..m {
                c[i + j * ldc] = c_m.get(i, j);
            }
        }
        Ok(())
    }

    pub fn dtrsm_left(
        &self,
        lower: bool,
        trans: Trans,
        unit: bool,
        m: usize,
        n: usize,
        alpha: f64,
        a: &[f64],
        lda: usize,
        b: &mut [f64],
        ldb: usize,
    ) {
        let a_v = MatRef::from_col_major(m, m, lda, a);
        let mut b_m = Mat::from_fn(m, n, |i, j| b[i + j * ldb]);
        level3::trsm_left(lower, trans, unit, alpha, a_v, &mut b_m);
        for j in 0..n {
            for i in 0..m {
                b[i + j * ldb] = b_m.get(i, j);
            }
        }
        self.inner.charge_host_op((m * m * n) as f64, host_rate());
    }

    pub fn dsyrk_lower(
        &self,
        trans: Trans,
        n: usize,
        k: usize,
        alpha: f64,
        a: &[f64],
        lda: usize,
        beta: f64,
        c: &mut [f64],
        ldc: usize,
    ) {
        let (ar, ac) = if trans.is_trans() { (k, n) } else { (n, k) };
        let a_v = MatRef::from_col_major(ar, ac, lda, a);
        let mut c_m = Mat::from_fn(n, n, |i, j| c[i + j * ldc]);
        level3::syrk_lower(trans, alpha, a_v, beta, &mut c_m);
        for j in 0..n {
            for i in 0..n {
                c[i + j * ldc] = c_m.get(i, j);
            }
        }
        self.inner.charge_host_op((n * n * k) as f64, host_rate());
    }
}

fn host_rate() -> f64 {
    crate::epiphany::timing::CalibratedModel::default().host_level2_f64_gflops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use std::sync::Arc;

    fn lib() -> BlasLibrary {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        BlasLibrary::new(Arc::new(Blas::new(svc)))
    }

    #[test]
    fn classic_sgemm_signature() {
        let lib = lib();
        // C (2x2) = A (2x3) · B (3x2) with lda > m.
        let (m, n, k) = (2, 2, 3);
        let lda = 4;
        let mut a = vec![0.0f32; lda * k];
        // A = [1 2 3; 4 5 6] col-major with lda 4.
        for (j, col) in [[1.0f32, 4.0], [2.0, 5.0], [3.0, 6.0]].iter().enumerate() {
            a[j * lda] = col[0];
            a[j * lda + 1] = col[1];
        }
        let b = vec![1.0f32, 1.0, 1.0, 2.0, 2.0, 2.0]; // [1 2;1 2;1 2]
        let mut c = vec![0.0f32; m * n];
        lib.sgemm(Trans::N, Trans::N, m, n, k, 1.0, &a, lda, &b, k, 0.0, &mut c, m).unwrap();
        assert_eq!(c, vec![6.0, 15.0, 12.0, 30.0]);
    }

    #[test]
    fn level1_suite() {
        let lib = lib();
        let mut y = vec![1.0f32, 1.0, 1.0];
        lib.saxpy(3, 2.0, &[1.0, 2.0, 3.0], 1, &mut y, 1);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert_eq!(lib.sdot(3, &y, 1, &y, 1), 9.0 + 25.0 + 49.0);
        assert_eq!(lib.isamax(3, &y, 1), Some(2));
        let mut x64 = vec![3.0f64, 4.0];
        assert!((lib.dnrm2(2, &x64, 1) - 5.0).abs() < 1e-12);
        lib.dscal(2, 2.0, &mut x64, 1);
        assert_eq!(x64, vec![6.0, 8.0]);
    }

    #[test]
    fn gemv_ger_round_trip() {
        let lib = lib();
        let (m, n) = (3, 2);
        let mut a = vec![0.0f64; m * n];
        lib.dger(m, n, 1.0, &[1.0, 2.0, 3.0], &[10.0, 20.0], &mut a, m);
        // A = x·yᵀ; A·[1,1] = 30·x
        let mut y = vec![0.0f64; m];
        lib.dgemv(Trans::N, m, n, 1.0, &a, m, &[1.0, 1.0], 0.0, &mut y);
        assert_eq!(y, vec![30.0, 60.0, 90.0]);
    }

    #[test]
    fn dgemm_is_false_precision() {
        let lib = lib();
        let (m, n, k) = (64, 64, 64);
        let a = Mat::<f64>::randn(m, k, 1);
        let b = Mat::<f64>::randn(k, n, 2);
        let mut c = vec![0.0f64; m * n];
        #[rustfmt::skip]
        lib.dgemm(Trans::N, Trans::N, m, n, k, 1.0, a.as_slice(), m, b.as_slice(), k, 0.0, &mut c, m)
            .unwrap();
        let mut want = Mat::<f64>::zeros(m, n);
        level3::gemm_host(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut want);
        let got = Mat::from_col_major(m, n, &c);
        let e = crate::linalg::max_scaled_err(got.view(), want.view());
        assert!(e > 1e-12 && e < 1e-4, "err {e}: must be f32-class through the f64 API");
    }
}
