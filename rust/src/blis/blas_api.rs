//! The generated classic BLAS API — FORTRAN-BLAS-style names over raw
//! column-major buffers with leading dimensions, exactly what LAPACK,
//! ScaLAPACK or HPL link against (paper §3.1: BLIS "also generates the
//! classic FORTRAN BLAS library").
//!
//! Every routine here is a **thin generated-style shim**: it wraps the raw
//! buffers in views, constructs a typed descriptor from [`super::op`], and
//! delegates to [`Blas::execute`] — the single place that validates,
//! routes (level-3 gemm → Epiphany service, the rest → host) and accounts.
//!
//! # Error model
//!
//! [`Blas::execute`] is the one fallible path: descriptors report bad
//! dims/strides/lengths as recoverable `Err`s. The classic shims keep
//! their FORTRAN shapes — level-1/2 routines return values, not
//! `Result` — so, exactly like reference BLAS's `XERBLA`, a shim called
//! with arguments violating its documented preconditions aborts (panics)
//! rather than corrupting memory. Callers who want recoverable errors
//! construct descriptors and call [`Blas::execute`] directly.

use super::gemm::Blas;
use super::op::{GemmOp, GemvOp, GerOp, Level1Op, SyrkOp, TrmvOp, TrsmOp, TrsvOp};
use super::params::Trans;
use crate::linalg::{Mat, MatMut, MatRef};
use anyhow::Result;

/// The library handle a "linked application" holds.
pub struct BlasLibrary {
    inner: std::sync::Arc<Blas>,
}

/// Shim-side `XERBLA`: unwrap an `execute` result for the non-fallible
/// classic signatures.
macro_rules! xerbla {
    ($routine:literal, $r:expr) => {
        $r.unwrap_or_else(|e| panic!(concat!($routine, ": {:#}"), e))
    };
}

impl BlasLibrary {
    /// Wrap a shared [`Blas`] core as the classic library surface.
    pub fn new(inner: std::sync::Arc<Blas>) -> Self {
        BlasLibrary { inner }
    }

    /// The descriptor core the shims delegate to.
    pub fn inner(&self) -> &Blas {
        &self.inner
    }

    // ---------------- level 1 (f32) ----------------

    /// `y ← αx + y` (f32).
    pub fn saxpy(&self, n: usize, alpha: f32, x: &[f32], incx: usize, y: &mut [f32], incy: usize) {
        xerbla!("saxpy", self.inner.execute(Level1Op::Axpy { n, alpha, x, incx, y, incy }));
    }
    /// `x ← αx` (f32).
    pub fn sscal(&self, n: usize, alpha: f32, x: &mut [f32], incx: usize) {
        xerbla!("sscal", self.inner.execute(Level1Op::Scal { n, alpha, x, incx }));
    }
    /// `y ← x` (f32).
    pub fn scopy(&self, n: usize, x: &[f32], incx: usize, y: &mut [f32], incy: usize) {
        xerbla!("scopy", self.inner.execute(Level1Op::Copy { n, x, incx, y, incy }));
    }
    /// `x ↔ y` (f32).
    pub fn sswap(&self, n: usize, x: &mut [f32], incx: usize, y: &mut [f32], incy: usize) {
        xerbla!("sswap", self.inner.execute(Level1Op::Swap { n, x, incx, y, incy }));
    }
    /// `xᵀy` (f32).
    pub fn sdot(&self, n: usize, x: &[f32], incx: usize, y: &[f32], incy: usize) -> f32 {
        xerbla!("sdot", self.inner.execute(Level1Op::Dot { n, x, incx, y, incy })).scalar()
    }
    /// `‖x‖₂` (f32).
    pub fn snrm2(&self, n: usize, x: &[f32], incx: usize) -> f32 {
        xerbla!("snrm2", self.inner.execute(Level1Op::Nrm2 { n, x, incx })).scalar()
    }
    /// `Σ|xᵢ|` (f32).
    pub fn sasum(&self, n: usize, x: &[f32], incx: usize) -> f32 {
        xerbla!("sasum", self.inner.execute(Level1Op::Asum { n, x, incx })).scalar()
    }
    /// `argmax |xᵢ|` (f32; `None` when `n == 0`).
    pub fn isamax(&self, n: usize, x: &[f32], incx: usize) -> Option<usize> {
        xerbla!("isamax", self.inner.execute(Level1Op::Iamax { n, x, incx })).index()
    }
    /// Apply a Givens rotation to `(x, y)` (f32).
    pub fn srot(
        &self,
        n: usize,
        x: &mut [f32],
        incx: usize,
        y: &mut [f32],
        incy: usize,
        c: f32,
        s: f32,
    ) {
        xerbla!("srot", self.inner.execute(Level1Op::Rot { n, x, incx, y, incy, c, s }));
    }

    // ---------------- level 1 (f64) ----------------

    /// `y ← αx + y` (f64).
    pub fn daxpy(&self, n: usize, alpha: f64, x: &[f64], incx: usize, y: &mut [f64], incy: usize) {
        xerbla!("daxpy", self.inner.execute(Level1Op::Axpy { n, alpha, x, incx, y, incy }));
    }
    /// `x ← αx` (f64).
    pub fn dscal(&self, n: usize, alpha: f64, x: &mut [f64], incx: usize) {
        xerbla!("dscal", self.inner.execute(Level1Op::Scal { n, alpha, x, incx }));
    }
    /// `y ← x` (f64).
    pub fn dcopy(&self, n: usize, x: &[f64], incx: usize, y: &mut [f64], incy: usize) {
        xerbla!("dcopy", self.inner.execute(Level1Op::Copy { n, x, incx, y, incy }));
    }
    /// `x ↔ y` (f64).
    pub fn dswap(&self, n: usize, x: &mut [f64], incx: usize, y: &mut [f64], incy: usize) {
        xerbla!("dswap", self.inner.execute(Level1Op::Swap { n, x, incx, y, incy }));
    }
    /// `xᵀy` (f64).
    pub fn ddot(&self, n: usize, x: &[f64], incx: usize, y: &[f64], incy: usize) -> f64 {
        xerbla!("ddot", self.inner.execute(Level1Op::Dot { n, x, incx, y, incy })).scalar()
    }
    /// `‖x‖₂` (f64).
    pub fn dnrm2(&self, n: usize, x: &[f64], incx: usize) -> f64 {
        xerbla!("dnrm2", self.inner.execute(Level1Op::Nrm2 { n, x, incx })).scalar()
    }
    /// `Σ|xᵢ|` (f64).
    pub fn dasum(&self, n: usize, x: &[f64], incx: usize) -> f64 {
        xerbla!("dasum", self.inner.execute(Level1Op::Asum { n, x, incx })).scalar()
    }
    /// `argmax |xᵢ|` (f64; `None` when `n == 0`).
    pub fn idamax(&self, n: usize, x: &[f64], incx: usize) -> Option<usize> {
        xerbla!("idamax", self.inner.execute(Level1Op::Iamax { n, x, incx })).index()
    }

    // ---------------- level 2 ----------------

    /// Classic sgemv with both vector strides (`incx`, `incy`), as the
    /// FORTRAN BLAS takes them.
    #[allow(clippy::too_many_arguments)]
    pub fn sgemv(
        &self,
        trans: Trans,
        m: usize,
        n: usize,
        alpha: f32,
        a: &[f32],
        lda: usize,
        x: &[f32],
        incx: usize,
        beta: f32,
        y: &mut [f32],
        incy: usize,
    ) {
        let a = MatRef::from_col_major(m, n, lda, a);
        xerbla!(
            "sgemv",
            self.inner.execute(GemvOp { trans, alpha, a, x, incx, beta, y, incy })
        );
    }

    /// Classic dgemv with both vector strides (`incx`, `incy`).
    #[allow(clippy::too_many_arguments)]
    pub fn dgemv(
        &self,
        trans: Trans,
        m: usize,
        n: usize,
        alpha: f64,
        a: &[f64],
        lda: usize,
        x: &[f64],
        incx: usize,
        beta: f64,
        y: &mut [f64],
        incy: usize,
    ) {
        let a = MatRef::from_col_major(m, n, lda, a);
        xerbla!(
            "dgemv",
            self.inner.execute(GemvOp { trans, alpha, a, x, incx, beta, y, incy })
        );
    }

    /// `A ← α·x·yᵀ + A` (f32 rank-1 update).
    pub fn sger(
        &self,
        m: usize,
        n: usize,
        alpha: f32,
        x: &[f32],
        y: &[f32],
        a: &mut [f32],
        lda: usize,
    ) {
        let a = MatMut::from_col_major(m, n, lda, a);
        xerbla!("sger", self.inner.execute(GerOp { alpha, x, y, a }));
    }

    /// `A ← α·x·yᵀ + A` (f64 rank-1 update).
    pub fn dger(
        &self,
        m: usize,
        n: usize,
        alpha: f64,
        x: &[f64],
        y: &[f64],
        a: &mut [f64],
        lda: usize,
    ) {
        let a = MatMut::from_col_major(m, n, lda, a);
        xerbla!("dger", self.inner.execute(GerOp { alpha, x, y, a }));
    }

    /// Solve `op(A)·x = b` in place for triangular A (f32).
    pub fn strsv(
        &self,
        lower: bool,
        trans: Trans,
        unit: bool,
        n: usize,
        a: &[f32],
        lda: usize,
        x: &mut [f32],
    ) {
        let a = MatRef::from_col_major(n, n, lda, a);
        xerbla!("strsv", self.inner.execute(TrsvOp { lower, trans, unit, a, x }));
    }

    /// Solve `op(A)·x = b` in place for triangular A (f64).
    pub fn dtrsv(
        &self,
        lower: bool,
        trans: Trans,
        unit: bool,
        n: usize,
        a: &[f64],
        lda: usize,
        x: &mut [f64],
    ) {
        let a = MatRef::from_col_major(n, n, lda, a);
        xerbla!("dtrsv", self.inner.execute(TrsvOp { lower, trans, unit, a, x }));
    }

    /// `x ← op(A)·x` for triangular A (f32).
    pub fn strmv(
        &self,
        lower: bool,
        trans: Trans,
        unit: bool,
        n: usize,
        a: &[f32],
        lda: usize,
        x: &mut [f32],
    ) {
        let a = MatRef::from_col_major(n, n, lda, a);
        xerbla!("strmv", self.inner.execute(TrmvOp { lower, trans, unit, a, x }));
    }

    // ---------------- level 3 ----------------

    /// The Epiphany-accelerated sgemm (the paper's headline function).
    /// Level-3 keeps the fallible signature: the service crossing itself
    /// can fail, and HPL-class callers handle it.
    #[allow(clippy::too_many_arguments)]
    pub fn sgemm(
        &self,
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        a: &[f32],
        lda: usize,
        b: &[f32],
        ldb: usize,
        beta: f32,
        c: &mut [f32],
        ldc: usize,
    ) -> Result<()> {
        let (ar, ac) = if ta.is_trans() { (k, m) } else { (m, k) };
        let (br, bc) = if tb.is_trans() { (n, k) } else { (k, n) };
        let a = MatRef::from_col_major(ar, ac, lda, a);
        let b = MatRef::from_col_major(br, bc, ldb, b);
        let c = MatMut::from_col_major(m, n, ldc, c);
        self.inner.execute(GemmOp { ta, tb, alpha, a, b, beta, c })?;
        Ok(())
    }

    /// dgemm — the paper's "false dgemm": f64 API, Epiphany f32 compute.
    #[allow(clippy::too_many_arguments)]
    pub fn dgemm(
        &self,
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        beta: f64,
        c: &mut [f64],
        ldc: usize,
    ) -> Result<()> {
        let (ar, ac) = if ta.is_trans() { (k, m) } else { (m, k) };
        let (br, bc) = if tb.is_trans() { (n, k) } else { (k, n) };
        let a = MatRef::from_col_major(ar, ac, lda, a);
        let b = MatRef::from_col_major(br, bc, ldb, b);
        let c = MatMut::from_col_major(m, n, ldc, c);
        self.inner.execute(GemmOp { ta, tb, alpha, a, b, beta, c })?;
        Ok(())
    }

    /// `B ← α·op(A)⁻¹·B` for triangular A on the left (f64), with
    /// classic `lda`/`ldb` leading dimensions.
    #[allow(clippy::too_many_arguments)]
    pub fn dtrsm_left(
        &self,
        lower: bool,
        trans: Trans,
        unit: bool,
        m: usize,
        n: usize,
        alpha: f64,
        a: &[f64],
        lda: usize,
        b: &mut [f64],
        ldb: usize,
    ) {
        let a = MatRef::from_col_major(m, m, lda, a);
        // The level-3 host kernels operate on dense matrices; the facade
        // owns the lda adaptation (copy-in/copy-out).
        let mut b_m = Mat::from_fn(m, n, |i, j| b[i + j * ldb]);
        xerbla!(
            "dtrsm",
            self.inner.execute(TrsmOp { lower, trans, unit, alpha, a, b: &mut b_m })
        );
        for j in 0..n {
            for i in 0..m {
                b[i + j * ldb] = b_m.get(i, j);
            }
        }
    }

    /// `C ← α·op(A)·op(A)ᵀ + β·C`, lower triangle of C updated (f64).
    #[allow(clippy::too_many_arguments)]
    pub fn dsyrk_lower(
        &self,
        trans: Trans,
        n: usize,
        k: usize,
        alpha: f64,
        a: &[f64],
        lda: usize,
        beta: f64,
        c: &mut [f64],
        ldc: usize,
    ) {
        let (ar, ac) = if trans.is_trans() { (k, n) } else { (n, k) };
        let a = MatRef::from_col_major(ar, ac, lda, a);
        let mut c_m = Mat::from_fn(n, n, |i, j| c[i + j * ldc]);
        xerbla!("dsyrk", self.inner.execute(SyrkOp { trans, alpha, a, beta, c: &mut c_m }));
        for j in 0..n {
            for i in 0..n {
                c[i + j * ldc] = c_m.get(i, j);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::level3;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use std::sync::Arc;

    fn lib() -> BlasLibrary {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        BlasLibrary::new(Arc::new(Blas::new(svc)))
    }

    #[test]
    fn classic_sgemm_signature() {
        let lib = lib();
        // C (2x2) = A (2x3) · B (3x2) with lda > m.
        let (m, n, k) = (2, 2, 3);
        let lda = 4;
        let mut a = vec![0.0f32; lda * k];
        // A = [1 2 3; 4 5 6] col-major with lda 4.
        for (j, col) in [[1.0f32, 4.0], [2.0, 5.0], [3.0, 6.0]].iter().enumerate() {
            a[j * lda] = col[0];
            a[j * lda + 1] = col[1];
        }
        let b = vec![1.0f32, 1.0, 1.0, 2.0, 2.0, 2.0]; // [1 2;1 2;1 2]
        let mut c = vec![0.0f32; m * n];
        lib.sgemm(Trans::N, Trans::N, m, n, k, 1.0, &a, lda, &b, k, 0.0, &mut c, m).unwrap();
        assert_eq!(c, vec![6.0, 15.0, 12.0, 30.0]);
    }

    #[test]
    fn classic_minimal_c_buffer_accepted() {
        // Reference BLAS only requires ldc·(n−1)+m elements for C; a
        // tight trailing column must not be rejected.
        let lib = lib();
        let (m, n, k) = (2, 2, 3);
        let ldc = 3;
        let a = vec![1.0f32; m * k];
        let b = vec![1.0f32; k * n];
        let mut c = vec![0.0f32; ldc * (n - 1) + m]; // 5, not ldc*n = 6
        lib.sgemm(Trans::N, Trans::N, m, n, k, 1.0, &a, m, &b, k, 0.0, &mut c, ldc).unwrap();
        // Every entry of C is Σ_k 1·1 = 3; the ldc gap entry is untouched.
        assert_eq!(c, vec![3.0, 3.0, 0.0, 3.0, 3.0]);
    }

    #[test]
    fn level1_suite() {
        let lib = lib();
        let mut y = vec![1.0f32, 1.0, 1.0];
        lib.saxpy(3, 2.0, &[1.0, 2.0, 3.0], 1, &mut y, 1);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert_eq!(lib.sdot(3, &y, 1, &y, 1), 9.0 + 25.0 + 49.0);
        assert_eq!(lib.isamax(3, &y, 1), Some(2));
        let mut x64 = vec![3.0f64, 4.0];
        assert!((lib.dnrm2(2, &x64, 1) - 5.0).abs() < 1e-12);
        lib.dscal(2, 2.0, &mut x64, 1);
        assert_eq!(x64, vec![6.0, 8.0]);
    }

    #[test]
    fn gemv_ger_round_trip() {
        let lib = lib();
        let (m, n) = (3, 2);
        let mut a = vec![0.0f64; m * n];
        lib.dger(m, n, 1.0, &[1.0, 2.0, 3.0], &[10.0, 20.0], &mut a, m);
        // A = x·yᵀ; A·[1,1] = 30·x
        let mut y = vec![0.0f64; m];
        lib.dgemv(Trans::N, m, n, 1.0, &a, m, &[1.0, 1.0], 1, 0.0, &mut y, 1);
        assert_eq!(y, vec![30.0, 60.0, 90.0]);
    }

    #[test]
    fn gemv_respects_vector_strides() {
        let lib = lib();
        // A = [1 2; 3 4] col-major; logical x = [1, 10] stored at stride 2;
        // logical y stored at stride 3.
        let a = vec![1.0f32, 3.0, 2.0, 4.0];
        let x = vec![1.0f32, 99.0, 10.0];
        let mut y = vec![0.0f32, -1.0, -1.0, 0.0, -1.0, -1.0];
        lib.sgemv(Trans::N, 2, 2, 1.0, &a, 2, &x, 2, 0.0, &mut y, 3);
        assert_eq!(y, vec![21.0, -1.0, -1.0, 43.0, -1.0, -1.0]);
        // The f64 twin must agree with its own strides.
        let a64: Vec<f64> = a.iter().map(|&v| v as f64).collect();
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let mut y64 = vec![0.0f64; 2];
        lib.dgemv(Trans::N, 2, 2, 1.0, &a64, 2, &x64, 2, 0.0, &mut y64, 1);
        assert_eq!(y64, vec![21.0, 43.0]);
    }

    #[test]
    fn dgemm_is_false_precision() {
        let lib = lib();
        let (m, n, k) = (64, 64, 64);
        let a = Mat::<f64>::randn(m, k, 1);
        let b = Mat::<f64>::randn(k, n, 2);
        let mut c = vec![0.0f64; m * n];
        #[rustfmt::skip]
        lib.dgemm(Trans::N, Trans::N, m, n, k, 1.0, a.as_slice(), m, b.as_slice(), k, 0.0, &mut c, m)
            .unwrap();
        let mut want = Mat::<f64>::zeros(m, n);
        level3::gemm_host(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut want);
        let got = Mat::from_col_major(m, n, &c);
        let e = crate::linalg::max_scaled_err(got.view(), want.view());
        assert!(e > 1e-12 && e < 1e-4, "err {e}: must be f32-class through the f64 API");
    }

    #[test]
    #[should_panic(expected = "saxpy")]
    fn shim_precondition_violation_is_xerbla_panic() {
        let lib = lib();
        let x = vec![1.0f32; 2];
        let mut y = vec![0.0f32; 8];
        lib.saxpy(5, 1.0, &x, 1, &mut y, 1); // x shorter than n
    }
}
