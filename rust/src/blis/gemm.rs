//! The tiled gemm driver: BLIS's loop nest around the Epiphany µ-kernel.
//!
//! `C = α·op(A)·op(B) + β·C` for arbitrary (m, n, K) is covered by
//! `⌈m/192⌉ × ⌈n/256⌉` micro-tile calls, each packed to the µ-kernel's
//! fixed layouts and routed through the service (HH-RAM IPC included).
//! B panels are packed once per column tile and reused across row tiles.

use super::op::{BlasOp, Element, Route, Ticket};
use super::packing::{pack_a, pack_b, pack_c, unpack_c};
use super::params::{BlisContext, Trans};
use crate::host::projection::ProjectionParams;
use crate::host::service::ServiceHandle;
use crate::linalg::{Mat, MatMut, MatRef, Real};
use anyhow::{ensure, Result};
use std::sync::{mpsc, Arc, Mutex};

/// Aggregate accounting for one BLAS call (and, via [`BlasStats`], for a
/// whole run).
#[derive(Clone, Copy, Debug, Default)]
pub struct GemmReport {
    /// Projected-Parallella seconds (calibrated model).
    pub projected_s: f64,
    /// Wall-clock seconds on this machine.
    pub wall_s: f64,
    /// µ-kernel calls issued.
    pub calls: usize,
    /// Logical flops of the operation.
    pub flops: f64,
}

impl GemmReport {
    pub fn projected_gflops(&self) -> f64 {
        self.flops / self.projected_s / 1e9
    }
    pub fn wall_gflops(&self) -> f64 {
        self.flops / self.wall_s / 1e9
    }
    pub fn merge(&mut self, o: &GemmReport) {
        self.projected_s += o.projected_s;
        self.wall_s += o.wall_s;
        self.calls += o.calls;
        self.flops += o.flops;
    }
}

/// Cumulative per-category stats (level-3 offloaded vs host level-1/2) —
/// the numbers behind the paper's §4.3 "Level-2 ops limit HPL" discussion.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlasStats {
    pub gemm: GemmReport,
    /// Projected seconds spent in unaccelerated host level-1/2/3 ops.
    pub host_level12_s: f64,
    pub host_level12_flops: f64,
}

/// The generated BLAS library facade (what `BLIS` "instantiates").
pub struct Blas {
    svc: ServiceHandle,
    pub ctx: BlisContext,
    pub stats: Mutex<BlasStats>,
}

impl Blas {
    pub fn new(svc: ServiceHandle) -> Self {
        let g = svc.geometry();
        Blas {
            svc,
            ctx: BlisContext { mr: g.m, nr: g.n, kc: 0 },
            stats: Mutex::new(BlasStats::default()),
        }
    }

    pub fn service(&self) -> &ServiceHandle {
        &self.svc
    }

    /// Execute one typed operation descriptor — **the** dispatch path of
    /// the library. Owns, in one place, what the per-routine facades used
    /// to scatter:
    ///
    /// * **routing** — [`Route::Epiphany`] ops cross the service boundary
    ///   (level-3 gemm, the paper's accelerated class); [`Route::Host`]
    ///   ops run on the host CPU;
    /// * **stats accounting** — host-routed flops are charged to the
    ///   projection ledger here; Epiphany-routed tile reports are merged
    ///   by the tiled driver;
    /// * **error handling** — descriptors validate dims/strides/lengths
    ///   and return recoverable errors; nothing below this layer is
    ///   expected to fail on well-formed descriptors.
    pub fn execute<O: BlasOp>(&self, op: O) -> Result<O::Output> {
        let route = op.route();
        let flops = op.flops();
        let out = op.run(self)?;
        if route == Route::Host {
            self.charge_host_op(flops, host_rate());
        }
        Ok(out)
    }

    /// Submit an owned descriptor for asynchronous execution and get a
    /// [`Ticket`] back. The op runs on a dedicated submission thread via
    /// [`Blas::execute`]; per-µ-kernel HH-RAM crossings serialize inside
    /// the service handle, so a caller can pack/enqueue the next operation
    /// while an earlier one is still in flight (§3.2, pipelined).
    pub fn submit<O>(self: Arc<Self>, op: O) -> Ticket<O::Output>
    where
        O: BlasOp + Send + 'static,
        O::Output: Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        let join = std::thread::Builder::new()
            .name("blas-submit".into())
            .spawn(move || {
                let _ = tx.send(self.execute(op));
            })
            .expect("spawn submission thread");
        Ticket::new(rx, join)
    }

    /// Precision-generic tiled gemm: `C ← α·op(A)·op(B) + β·C` for any
    /// [`Element`]. `T = f32` is the paper's accelerated sgemm; `T = f64`
    /// its "false dgemm" (f64 API, f32 Epiphany compute) — one driver,
    /// dispatched by [`Element::service_gemm`].
    pub fn gemm<T: Element>(
        &self,
        ta: Trans,
        tb: Trans,
        alpha: T,
        a: MatRef<'_, T>,
        b: MatRef<'_, T>,
        beta: T,
        c: &mut Mat<T>,
    ) -> Result<GemmReport> {
        let mut view = c.view_mut();
        self.gemm_view(ta, tb, alpha, a, b, beta, &mut view)
    }

    /// [`Blas::gemm`] over a strided mutable view (what [`super::op::GemmOp`]
    /// descriptors carry). Merges the tile report into the stats ledger.
    pub(crate) fn gemm_view<T: Element>(
        &self,
        ta: Trans,
        tb: Trans,
        alpha: T,
        a: MatRef<'_, T>,
        b: MatRef<'_, T>,
        beta: T,
        c: &mut MatMut<'_, T>,
    ) -> Result<GemmReport> {
        let rows = c.rows();
        let cols = c.cols();
        let report = self.gemm_driver(ta, tb, a, b, rows, cols, |_k, a_p, b_p, c_p, params| {
            let (out, resp) = T::service_gemm(&self.svc, alpha, a_p, b_p, beta, c_p, params)?;
            Ok((out, resp.projection.total_s, resp.wall_s))
        }, c)?;
        self.stats.lock().unwrap().gemm.merge(&report);
        Ok(report)
    }

    /// Single-precision general matrix multiply (the accelerated path).
    /// Generated-style shim over [`Blas::gemm`].
    pub fn sgemm(
        &self,
        ta: Trans,
        tb: Trans,
        alpha: f32,
        a: MatRef<'_, f32>,
        b: MatRef<'_, f32>,
        beta: f32,
        c: &mut Mat<f32>,
    ) -> Result<GemmReport> {
        self.gemm(ta, tb, alpha, a, b, beta, c)
    }

    /// The paper's "false dgemm": double-precision API, single-precision
    /// Epiphany compute (downcast/upcast inside the service path).
    /// Generated-style shim over [`Blas::gemm`].
    pub fn dgemm_false(
        &self,
        ta: Trans,
        tb: Trans,
        alpha: f64,
        a: MatRef<'_, f64>,
        b: MatRef<'_, f64>,
        beta: f64,
        c: &mut Mat<f64>,
    ) -> Result<GemmReport> {
        self.gemm(ta, tb, alpha, a, b, beta, c)
    }

    /// Shared tile loop. `call(k, a_panel, b_panel, c_tile, params)` runs
    /// one µ-kernel invocation and returns `(c_out, projected_s, wall_s)`.
    fn gemm_driver<T: Real>(
        &self,
        ta: Trans,
        tb: Trans,
        a: MatRef<'_, T>,
        b: MatRef<'_, T>,
        m: usize,
        n: usize,
        call: impl Fn(usize, &[T], &[T], &[T], ProjectionParams) -> Result<(Vec<T>, f64, f64)>,
        c: &mut MatMut<'_, T>,
    ) -> Result<GemmReport> {
        let op_a = if ta.is_trans() { a.t() } else { a };
        let op_b = if tb.is_trans() { b.t() } else { b };
        let k = op_a.cols();
        ensure!(op_a.rows() == m, "op(A) rows {} != C rows {m}", op_a.rows());
        ensure!(op_b.rows() == k, "op(B) rows {} != K {k}", op_b.rows());
        ensure!(op_b.cols() == n, "op(B) cols {} != C cols {n}", op_b.cols());

        let (mr, nr) = (self.ctx.mr, self.ctx.nr);
        let mut report =
            GemmReport { flops: 2.0 * m as f64 * n as f64 * k as f64, ..Default::default() };

        // jc loop: column tiles; pack B once per tile, reuse across ic.
        for jc in 0..BlisContext::tiles(n, nr) {
            let j0 = jc * nr;
            let cols = nr.min(n - j0);
            let (b_panel, class_b) = pack_b(op_b, j0, cols, nr);
            // ic loop: row tiles.
            for ic in 0..BlisContext::tiles(m, mr) {
                let i0 = ic * mr;
                let rows = mr.min(m - i0);
                let (a_panel, class_a) = pack_a(op_a, i0, rows, mr);
                let c_tile = pack_c(c.as_ref(), i0, j0, rows, cols, mr, nr);
                let mut params = ProjectionParams::kernel_service(k);
                params.class_a = class_a;
                params.class_b = class_b;
                params.blis = true;
                let (out, proj_s, wall_s) = call(k, &a_panel, &b_panel, &c_tile, params)?;
                unpack_c(&out, c, i0, j0, rows, cols, mr);
                report.projected_s += proj_s;
                report.wall_s += wall_s;
                report.calls += 1;
            }
        }
        Ok(report)
    }

    /// Record an unaccelerated host op (level-1/2/3 fallbacks) against the
    /// projection ledger at the given rate.
    pub fn charge_host_op(&self, flops: f64, gflops_rate: f64) {
        let mut s = self.stats.lock().unwrap();
        s.host_level12_s += flops / (gflops_rate * 1e9);
        s.host_level12_flops += flops;
    }

    pub fn stats_snapshot(&self) -> BlasStats {
        *self.stats.lock().unwrap()
    }
}

/// Calibrated host rate used for ledger charges of unaccelerated ops
/// (the paper's §4.3 level-2 rate).
pub(crate) fn host_rate() -> f64 {
    crate::epiphany::timing::CalibratedModel::default().host_level2_f64_gflops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::max_scaled_err;

    fn blas() -> Blas {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .expect("service boots");
        Blas::new(svc)
    }

    fn oracle_f64(
        ta: Trans,
        tb: Trans,
        alpha: f64,
        a: &Mat<f32>,
        b: &Mat<f32>,
        beta: f64,
        c0: &Mat<f32>,
    ) -> Mat<f64> {
        let op_a = if ta.is_trans() { a.transposed() } else { a.clone() };
        let op_b = if tb.is_trans() { b.transposed() } else { b.clone() };
        let (m, k, n) = (op_a.rows(), op_a.cols(), op_b.cols());
        Mat::from_fn(m, n, |i, j| {
            let mut acc = 0.0f64;
            for l in 0..k {
                acc += op_a.get(i, l) as f64 * op_b.get(l, j) as f64;
            }
            alpha * acc + beta * c0.get(i, j) as f64
        })
    }

    #[test]
    fn sgemm_all_transpose_variants_small() {
        // Non-tile-aligned dims exercise padding: 200×300, K=100.
        let blas = blas();
        let (m, n, k) = (200, 300, 100);
        for ta in Trans::all() {
            for tb in Trans::all() {
                let a = if ta.is_trans() {
                    Mat::<f32>::randn(k, m, 1)
                } else {
                    Mat::<f32>::randn(m, k, 1)
                };
                let b = if tb.is_trans() {
                    Mat::<f32>::randn(n, k, 2)
                } else {
                    Mat::<f32>::randn(k, n, 2)
                };
                let c0 = Mat::<f32>::randn(m, n, 3);
                let mut c = c0.clone();
                let rep = blas
                    .sgemm(ta, tb, 1.5, a.view(), b.view(), -0.5, &mut c)
                    .unwrap();
                let want = oracle_f64(ta, tb, 1.5, &a, &b, -0.5, &c0);
                let e = max_scaled_err(c.view(), want.view());
                assert!(e < 1e-5, "{}{} err {e}", ta.code(), tb.code());
                assert_eq!(rep.calls, 2 * 2); // ⌈200/192⌉ × ⌈300/256⌉
                assert!(rep.projected_s > 0.0);
            }
        }
    }

    #[test]
    fn transposed_a_projects_slower() {
        let blas = blas();
        let (m, n, k) = (192, 256, 512);
        let a_n = Mat::<f32>::randn(m, k, 4);
        let a_t = Mat::<f32>::randn(k, m, 4);
        let b = Mat::<f32>::randn(k, n, 5);
        let mut c1 = Mat::<f32>::zeros(m, n);
        let mut c2 = Mat::<f32>::zeros(m, n);
        let rep_nn =
            blas.sgemm(Trans::N, Trans::N, 1.0, a_n.view(), b.view(), 0.0, &mut c1).unwrap();
        let rep_tn =
            blas.sgemm(Trans::T, Trans::N, 1.0, a_t.view(), b.view(), 0.0, &mut c2).unwrap();
        assert!(
            rep_tn.projected_s > rep_nn.projected_s * 1.1,
            "tn {} vs nn {}",
            rep_tn.projected_s,
            rep_nn.projected_s
        );
    }

    #[test]
    fn false_dgemm_matches_f32_precision() {
        let blas = blas();
        let (m, n, k) = (192, 256, 128);
        let a = Mat::<f64>::randn(m, k, 6);
        let b = Mat::<f64>::randn(k, n, 7);
        let c0 = Mat::<f64>::randn(m, n, 8);
        let mut c = c0.clone();
        blas.dgemm_false(Trans::N, Trans::N, 1.0, a.view(), b.view(), 1.0, &mut c).unwrap();
        let want = Mat::from_fn(m, n, |i, j| {
            let mut acc = 0.0f64;
            for l in 0..k {
                acc += a.get(i, l) * b.get(l, j);
            }
            acc + c0.get(i, j)
        });
        let e = max_scaled_err(c.view(), want.view());
        assert!(e > 1e-10 && e < 1e-4, "err {e} should be f32-sized");
    }

    #[test]
    fn dim_mismatch_rejected() {
        let blas = blas();
        let a = Mat::<f32>::randn(10, 20, 1);
        let b = Mat::<f32>::randn(21, 30, 2); // K mismatch
        let mut c = Mat::<f32>::zeros(10, 30);
        assert!(blas.sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).is_err());
    }
}
