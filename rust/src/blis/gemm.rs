//! The tiled gemm driver: BLIS's loop nest around the Epiphany µ-kernel,
//! sharded across a [`ChipPool`].
//!
//! `C = α·op(A)·op(B) + β·C` for arbitrary (m, n, K) is covered by
//! `⌈m/192⌉ × ⌈n/256⌉` micro-tile calls, each packed to the µ-kernel's
//! fixed layouts and routed through a resident service (HH-RAM IPC
//! included). B panels are packed once per column tile and reused across
//! row tiles.
//!
//! With more than one chip in the pool, the `jc` column-tile range is
//! split into contiguous shards (SUMMA-style; [`ShardPolicy`]) that
//! execute concurrently, one service crossing stream per chip. A pool of
//! one runs the original serial loop on the calling thread, so the
//! single-chip result is bit-identical to the pre-pool backend.

use super::op::{BlasOp, Element, Route, Ticket};
use super::packing::{pack_a, pack_b_into, pack_c_into, unpack_c};
use super::params::{BlisContext, Trans};
use crate::epiphany::timing::WalkClass;
use crate::host::pool::{ChipPool, ShardPolicy};
use crate::host::projection::ProjectionParams;
use crate::host::service::ServiceHandle;
use crate::linalg::{Mat, MatMut, MatRef};
use crate::mem::{hash_operand, PanelCache};
use anyhow::{anyhow, ensure, Result};
use std::sync::{mpsc, Arc, Mutex};

/// Aggregate accounting for one BLAS call (and, via [`BlasStats`], for a
/// whole run).
#[derive(Clone, Copy, Debug, Default)]
pub struct GemmReport {
    /// Projected-Parallella seconds (calibrated model). For a sharded op
    /// this is the *maximum* over the concurrent per-chip shard times —
    /// the modeled makespan — so a pool of one reports the same serial
    /// sum as before.
    pub projected_s: f64,
    /// Wall-clock seconds on this machine (same makespan semantics).
    pub wall_s: f64,
    /// µ-kernel calls issued (summed across chips).
    pub calls: usize,
    /// Logical flops of the operation.
    pub flops: f64,
    /// Chips that executed shards of this op (1 = serial plan).
    pub chips: usize,
}

impl GemmReport {
    /// Flop rate against the projected (modeled) time.
    pub fn projected_gflops(&self) -> f64 {
        self.flops / self.projected_s / 1e9
    }

    /// Flop rate against the measured wall time.
    pub fn wall_gflops(&self) -> f64 {
        self.flops / self.wall_s / 1e9
    }

    /// Fold another report into this one (cumulative-ledger semantics:
    /// times and work add, chip width takes the widest plan seen).
    pub fn merge(&mut self, o: &GemmReport) {
        self.projected_s += o.projected_s;
        self.wall_s += o.wall_s;
        self.calls += o.calls;
        self.flops += o.flops;
        self.chips = self.chips.max(o.chips);
    }
}

/// Cumulative per-category stats (level-3 offloaded vs host level-1/2) —
/// the numbers behind the paper's §4.3 "Level-2 ops limit HPL" discussion.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlasStats {
    /// Aggregate of every Epiphany-routed gemm tile report.
    pub gemm: GemmReport,
    /// Projected seconds spent in unaccelerated host level-1/2/3 ops.
    pub host_level12_s: f64,
    /// Logical flops charged to the host ledger.
    pub host_level12_flops: f64,
}

/// One µ-kernel result tile, produced by a shard worker and written back
/// into C by the coordinator after every shard joins.
struct TileOut<T> {
    i0: usize,
    j0: usize,
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

/// Pairs [`ChipPool::enter`]/[`ChipPool::exit`] through `Drop`, so the
/// pool's in-flight gauge can never leak — even when a shard panics
/// mid-tile (the scoped-thread join surfaces the panic as an error, and
/// the guard still unwinds). `calls` accumulates the crossings to charge.
struct PoolGuard<'a> {
    pool: &'a ChipPool,
    chip: usize,
    calls: u64,
}

impl<'a> PoolGuard<'a> {
    fn enter(pool: &'a ChipPool, chip: usize) -> Self {
        pool.enter(chip);
        PoolGuard { pool, chip, calls: 0 }
    }
}

impl Drop for PoolGuard<'_> {
    fn drop(&mut self) {
        self.pool.exit(self.chip, self.calls);
    }
}

/// The generated BLAS library facade (what `BLIS` "instantiates"),
/// executing over a [`ChipPool`].
pub struct Blas {
    pool: ChipPool,
    /// How level-3 gemms are split across the pool (see [`ShardPolicy`]).
    pub policy: ShardPolicy,
    /// Blocking parameters (micro-tile geometry).
    pub ctx: BlisContext,
    /// Cumulative accounting ledger.
    pub stats: Mutex<BlasStats>,
    panel_cache: Option<Arc<PanelCache>>,
}

impl Blas {
    /// Wrap one already-booted service as a single-chip BLAS (the
    /// original backend shape; bit-identical results and timing).
    pub fn new(svc: ServiceHandle) -> Self {
        Blas::with_pool(ChipPool::single(svc), ShardPolicy::default())
    }

    /// A BLAS over an explicit chip pool and shard policy.
    pub fn with_pool(pool: ChipPool, policy: ShardPolicy) -> Self {
        let g = pool.geometry();
        Blas {
            pool,
            policy,
            ctx: BlisContext { mr: g.m, nr: g.n, kc: 0 },
            stats: Mutex::new(BlasStats::default()),
            panel_cache: None,
        }
    }

    /// Enable the packed-A panel cache with the given byte budget, or
    /// disable it with 0 — disabled is the default and keeps the gemm
    /// driver bit-identical to the pre-cache code path (no hashing, no
    /// lookups). See [`PanelCache`] for the keying and verify rules.
    pub fn set_panel_cache(&mut self, budget_bytes: usize) {
        self.panel_cache =
            if budget_bytes == 0 { None } else { Some(Arc::new(PanelCache::new(budget_bytes))) };
    }

    /// The packed-A panel cache, when enabled (its hit/miss/eviction
    /// counters feed the coordinator's `panel_*` stats).
    pub fn panel_cache(&self) -> Option<&PanelCache> {
        self.panel_cache.as_deref()
    }

    /// Chip 0's service handle (the whole service for a single-chip pool;
    /// kept for the pre-pool API surface and the IPC-level tests).
    pub fn service(&self) -> &ServiceHandle {
        self.pool.chip(0)
    }

    /// The chip pool this BLAS executes on.
    pub fn pool(&self) -> &ChipPool {
        &self.pool
    }

    /// Number of chips in the pool.
    pub fn chips(&self) -> usize {
        self.pool.len()
    }

    /// Execute one typed operation descriptor — **the** dispatch path of
    /// the library. Owns, in one place, what the per-routine facades used
    /// to scatter:
    ///
    /// * **routing** — [`Route::Epiphany`] ops cross the service boundary
    ///   (level-3 gemm, the paper's accelerated class); [`Route::Host`]
    ///   ops run on the host CPU;
    /// * **stats accounting** — host-routed flops are charged to the
    ///   projection ledger here; Epiphany-routed tile reports are merged
    ///   by the tiled driver;
    /// * **error handling** — descriptors validate dims/strides/lengths
    ///   and return recoverable errors; nothing below this layer is
    ///   expected to fail on well-formed descriptors.
    ///
    /// ```
    /// use parallella_blas::blis::GemmOp;
    /// use parallella_blas::prelude::*;
    ///
    /// let plat = Platform::builder().build()?;
    /// let blas = plat.blas();
    /// let a = Mat::<f32>::randn(64, 32, 1);
    /// let b = Mat::<f32>::randn(32, 48, 2);
    /// let mut c = Mat::<f32>::zeros(64, 48);
    /// let report = blas.execute(GemmOp {
    ///     ta: Trans::N,
    ///     tb: Trans::N,
    ///     alpha: 1.0f32,
    ///     a: a.view(),
    ///     b: b.view(),
    ///     beta: 0.0,
    ///     c: c.view_mut(),
    /// })?;
    /// assert_eq!(report.calls, 1);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn execute<O: BlasOp>(&self, op: O) -> Result<O::Output> {
        let route = op.route();
        let flops = op.flops();
        let out = op.run(self)?;
        if route == Route::Host {
            self.charge_host_op(flops, host_rate());
        }
        Ok(out)
    }

    /// Submit an owned descriptor for asynchronous execution and get a
    /// [`Ticket`] back. The op runs on a dedicated submission thread via
    /// [`Blas::execute`]; per-µ-kernel HH-RAM crossings serialize inside
    /// each chip's service handle, so a caller can pack/enqueue the next
    /// operation while an earlier one is still in flight (§3.2,
    /// pipelined).
    ///
    /// ```
    /// use parallella_blas::blis::GemmTask;
    /// use parallella_blas::prelude::*;
    /// use std::sync::Arc;
    ///
    /// let plat = Platform::builder().build()?;
    /// let h = plat.blas_handle();
    /// let a = Mat::<f32>::randn(48, 16, 1);
    /// let b = Mat::<f32>::randn(16, 32, 2);
    /// let task = || GemmTask {
    ///     ta: Trans::N,
    ///     tb: Trans::N,
    ///     alpha: 1.0f32,
    ///     a: a.clone(),
    ///     b: b.clone(),
    ///     beta: 0.0,
    ///     c: Mat::zeros(48, 32),
    /// };
    /// let t1 = Arc::clone(&h).submit(task());
    /// let t2 = Arc::clone(&h).submit(task()); // both in flight
    /// let (c1, _report1) = t1.wait()?;
    /// let (c2, _report2) = t2.wait()?;
    /// assert_eq!(c1.as_slice(), c2.as_slice());
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn submit<O>(self: Arc<Self>, op: O) -> Ticket<O::Output>
    where
        O: BlasOp + Send + 'static,
        O::Output: Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        let join = std::thread::Builder::new()
            .name("blas-submit".into())
            .spawn(move || {
                let _ = tx.send(self.execute(op));
            })
            .expect("spawn submission thread");
        Ticket::new(rx, join)
    }

    /// Precision-generic tiled gemm: `C ← α·op(A)·op(B) + β·C` for any
    /// [`Element`]. `T = f32` is the paper's accelerated sgemm; `T = f64`
    /// its "false dgemm" (f64 API, f32 Epiphany compute) — one driver,
    /// dispatched by [`Element::service_gemm`]. Sharding follows
    /// [`Blas::policy`].
    pub fn gemm<T: Element>(
        &self,
        ta: Trans,
        tb: Trans,
        alpha: T,
        a: MatRef<'_, T>,
        b: MatRef<'_, T>,
        beta: T,
        c: &mut Mat<T>,
    ) -> Result<GemmReport> {
        let mut view = c.view_mut();
        self.gemm_view(ta, tb, alpha, a, b, beta, &mut view)
    }

    /// [`Blas::gemm`] pinned to one chip of the pool — every tile of the
    /// op crosses through `chip`'s service. This is what the
    /// coordinator's per-chip batcher workers call, so a coalesced batch
    /// stays on the chip whose queue it was drained from.
    pub fn gemm_on<T: Element>(
        &self,
        chip: usize,
        ta: Trans,
        tb: Trans,
        alpha: T,
        a: MatRef<'_, T>,
        b: MatRef<'_, T>,
        beta: T,
        c: &mut Mat<T>,
    ) -> Result<GemmReport> {
        let mut view = c.view_mut();
        self.gemm_view_on(chip, ta, tb, alpha, a, b, beta, &mut view)
    }

    /// [`Blas::gemm_on`] over a borrowed C view — the batcher's pooled
    /// staging path, where C lives in a recycled [`crate::mem::BufferPool`]
    /// buffer rather than an owned `Mat`.
    pub(crate) fn gemm_view_on<T: Element>(
        &self,
        chip: usize,
        ta: Trans,
        tb: Trans,
        alpha: T,
        a: MatRef<'_, T>,
        b: MatRef<'_, T>,
        beta: T,
        c: &mut MatMut<'_, T>,
    ) -> Result<GemmReport> {
        let report = self.gemm_view_with(ShardPolicy::Pinned(chip), ta, tb, alpha, a, b, beta, c)?;
        self.stats.lock().unwrap().gemm.merge(&report);
        Ok(report)
    }

    /// Single-precision general matrix multiply (the accelerated path).
    /// Generated-style shim over [`Blas::gemm`].
    pub fn sgemm(
        &self,
        ta: Trans,
        tb: Trans,
        alpha: f32,
        a: MatRef<'_, f32>,
        b: MatRef<'_, f32>,
        beta: f32,
        c: &mut Mat<f32>,
    ) -> Result<GemmReport> {
        self.gemm(ta, tb, alpha, a, b, beta, c)
    }

    /// The paper's "false dgemm": double-precision API, single-precision
    /// Epiphany compute (downcast/upcast inside the service path).
    /// Generated-style shim over [`Blas::gemm`].
    pub fn dgemm_false(
        &self,
        ta: Trans,
        tb: Trans,
        alpha: f64,
        a: MatRef<'_, f64>,
        b: MatRef<'_, f64>,
        beta: f64,
        c: &mut Mat<f64>,
    ) -> Result<GemmReport> {
        self.gemm(ta, tb, alpha, a, b, beta, c)
    }

    /// [`Blas::gemm`] over a strided mutable view (what [`super::op::GemmOp`]
    /// descriptors carry). Shards per [`Blas::policy`] and merges the
    /// aggregate report into the stats ledger.
    pub(crate) fn gemm_view<T: Element>(
        &self,
        ta: Trans,
        tb: Trans,
        alpha: T,
        a: MatRef<'_, T>,
        b: MatRef<'_, T>,
        beta: T,
        c: &mut MatMut<'_, T>,
    ) -> Result<GemmReport> {
        let report = self.gemm_view_with(self.policy, ta, tb, alpha, a, b, beta, c)?;
        self.stats.lock().unwrap().gemm.merge(&report);
        Ok(report)
    }

    /// The shard coordinator: validate, plan, fan the `jc` ranges out to
    /// the chips, join, write every result tile back into C, and merge
    /// per-chip timing into one aggregate report (makespan = max over
    /// concurrent shards).
    pub(crate) fn gemm_view_with<T: Element>(
        &self,
        policy: ShardPolicy,
        ta: Trans,
        tb: Trans,
        alpha: T,
        a: MatRef<'_, T>,
        b: MatRef<'_, T>,
        beta: T,
        c: &mut MatMut<'_, T>,
    ) -> Result<GemmReport> {
        let m = c.rows();
        let n = c.cols();
        let op_a = if ta.is_trans() { a.t() } else { a };
        let op_b = if tb.is_trans() { b.t() } else { b };
        let k = op_a.cols();
        ensure!(op_a.rows() == m, "op(A) rows {} != C rows {m}", op_a.rows());
        ensure!(op_b.rows() == k, "op(B) rows {} != K {k}", op_b.rows());
        ensure!(op_b.cols() == n, "op(B) cols {} != C cols {n}", op_b.cols());

        let plan = self.shard_plan(policy, BlisContext::tiles(n, self.ctx.nr))?;
        let mut report = GemmReport {
            flops: 2.0 * m as f64 * n as f64 * k as f64,
            chips: plan.len(),
            ..Default::default()
        };

        // Hash op(A) once per call when the panel cache is enabled (the
        // per-tile cache keys all derive from it). With the cache off
        // this is `None` and the driver runs the exact pre-cache path.
        let a_hash = self.panel_cache.as_ref().map(|_| hash_operand(op_a));

        if plan.len() == 1 {
            // Degenerate plan: run serially on the calling thread — the
            // exact pre-pool code path (same timing ledger, and each
            // result tile streams straight back into C instead of being
            // buffered, so peak memory matches the old backend too).
            let (chip, lo, hi) = plan[0];
            let shard_rep =
                match self.run_shard_streaming(chip, op_a, op_b, alpha, beta, lo, hi, c, a_hash) {
                    Ok(rep) => rep,
                    Err(e) => {
                        // A failed service call means the chip (not the
                        // operands) is the problem: stop routing to it.
                        self.pool.mark_unhealthy(chip);
                        return Err(e);
                    }
                };
            report.calls = shard_rep.calls;
            report.projected_s = shard_rep.projected_s;
            report.wall_s = shard_rep.wall_s;
            return Ok(report);
        }

        let c0 = c.as_ref();
        let shard_results: Vec<Result<(Vec<TileOut<T>>, GemmReport)>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = plan
                    .iter()
                    .map(|&(chip, lo, hi)| {
                        s.spawn(move || {
                            self.run_shard(chip, op_a, op_b, c0, alpha, beta, lo, hi, a_hash)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("shard worker panicked"))))
                    .collect()
            });

        for (result, &(chip, _, _)) in shard_results.into_iter().zip(&plan) {
            let (tiles, shard_rep) = match result {
                Ok(r) => r,
                Err(e) => {
                    // Erroring or panicking shards condemn their chip.
                    self.pool.mark_unhealthy(chip);
                    return Err(e);
                }
            };
            report.calls += shard_rep.calls;
            report.projected_s = report.projected_s.max(shard_rep.projected_s);
            report.wall_s = report.wall_s.max(shard_rep.wall_s);
            for t in tiles {
                unpack_c(&t.data, c, t.i0, t.j0, t.rows, t.cols, self.ctx.mr);
            }
        }
        Ok(report)
    }

    /// Split `jc_tiles` column tiles into per-chip contiguous ranges
    /// `(chip, jc_lo, jc_hi)` according to `policy`, planning over the
    /// pool's *healthy* chips: `ColumnPanels` spreads shards across the
    /// healthy set, and a `Pinned` target that has gone unhealthy
    /// degrades to the least-loaded healthy chip (a pin is a locality
    /// preference, not a law). With the whole pool down the plan covers
    /// every chip anyway — execution then surfaces the chip error loudly
    /// instead of refusing to plan.
    fn shard_plan(
        &self,
        policy: ShardPolicy,
        jc_tiles: usize,
    ) -> Result<Vec<(usize, usize, usize)>> {
        let nchips = self.pool.len();
        match policy {
            ShardPolicy::Pinned(i) => {
                ensure!(i < nchips, "pinned chip {i} out of range (pool has {nchips} chips)");
                let chip = if self.pool.is_healthy(i) { i } else { self.pool.least_loaded() };
                Ok(vec![(chip, 0, jc_tiles)])
            }
            ShardPolicy::ColumnPanels => {
                let mut chips = self.pool.healthy_chips();
                if chips.is_empty() {
                    chips = (0..nchips).collect();
                }
                let shards = chips.len().min(jc_tiles).max(1);
                let (base, extra) = (jc_tiles / shards, jc_tiles % shards);
                let mut plan = Vec::with_capacity(shards);
                let mut lo = 0usize;
                for (idx, &chip) in chips.iter().take(shards).enumerate() {
                    let w = base + usize::from(idx < extra);
                    plan.push((chip, lo, lo + w));
                    lo += w;
                }
                Ok(plan)
            }
        }
    }

    /// The shard tile loop: iterate this shard's jc/ic tiles in order
    /// (packing B once per column tile, reused across the ic row tiles)
    /// and hand each tile's coordinates + B panel to `tile`. Shared by
    /// the buffering (parallel) and streaming (serial) executors, so
    /// their tile order and packing can never diverge.
    fn for_each_tile<T: Element>(
        &self,
        m: usize,
        n: usize,
        op_b: MatRef<'_, T>,
        jc_lo: usize,
        jc_hi: usize,
        mut tile: impl FnMut(usize, usize, usize, usize, &[T], WalkClass) -> Result<()>,
    ) -> Result<()> {
        let (mr, nr) = (self.ctx.mr, self.ctx.nr);
        // One staging buffer for every B panel of the shard: the pack
        // re-zeroes it per jc tile, so only the first tile allocates.
        let mut b_panel: Vec<T> = Vec::new();
        for jc in jc_lo..jc_hi {
            let j0 = jc * nr;
            let cols = nr.min(n - j0);
            let class_b = pack_b_into(&mut b_panel, op_b, j0, cols, nr);
            for ic in 0..BlisContext::tiles(m, mr) {
                let i0 = ic * mr;
                let rows = mr.min(m - i0);
                tile(i0, rows, j0, cols, &b_panel, class_b)?;
            }
        }
        Ok(())
    }

    /// The tile-call residency context for one shard: the panel cache
    /// (when enabled) with the operand hash and the owning chip.
    fn residency_for(
        &self,
        chip: usize,
        a_hash: Option<u64>,
    ) -> Option<(&PanelCache, u64, usize)> {
        match (&self.panel_cache, a_hash) {
            (Some(cache), Some(h)) => Some((cache.as_ref(), h, chip)),
            _ => None,
        }
    }

    /// One shard: the serial tile loop over `jc_lo..jc_hi`, every
    /// µ-kernel call crossing through `chip`'s own service (its private
    /// HH-RAM + semaphores). Returns the result tiles and this chip's
    /// summed timing; the caller owns the write-back into C.
    fn run_shard<T: Element>(
        &self,
        chip: usize,
        op_a: MatRef<'_, T>,
        op_b: MatRef<'_, T>,
        c0: MatRef<'_, T>,
        alpha: T,
        beta: T,
        jc_lo: usize,
        jc_hi: usize,
        a_hash: Option<u64>,
    ) -> Result<(Vec<TileOut<T>>, GemmReport)> {
        let (m, n, k) = (c0.rows(), c0.cols(), op_a.cols());
        let (mr, nr) = (self.ctx.mr, self.ctx.nr);
        let svc = self.pool.chip(chip);
        let residency = self.residency_for(chip, a_hash);
        let mut guard = PoolGuard::enter(&self.pool, chip);
        let mut tiles = Vec::new();
        let mut c_scratch = Vec::new();
        let mut rep = GemmReport::default();
        self.for_each_tile(m, n, op_b, jc_lo, jc_hi, |i0, rows, j0, cols, b_p, class_b| {
            let data = tile_call(
                svc, op_a, c0, b_p, class_b, alpha, beta, k, mr, nr, i0, rows, j0, cols, residency,
                &mut c_scratch, &mut rep,
            )?;
            guard.calls += 1;
            tiles.push(TileOut { i0, j0, rows, cols, data });
            Ok(())
        })?;
        Ok((tiles, rep))
    }

    /// [`Blas`]'s degenerate serial plan: the same tile loop and timing
    /// ledger as [`Self::run_shard`], but each result tile is unpacked
    /// into C as soon as its service crossing returns — no `TileOut`
    /// buffering, matching the pre-pool backend's peak memory.
    fn run_shard_streaming<T: Element>(
        &self,
        chip: usize,
        op_a: MatRef<'_, T>,
        op_b: MatRef<'_, T>,
        alpha: T,
        beta: T,
        jc_lo: usize,
        jc_hi: usize,
        c: &mut MatMut<'_, T>,
        a_hash: Option<u64>,
    ) -> Result<GemmReport> {
        let (m, n, k) = (c.rows(), c.cols(), op_a.cols());
        let (mr, nr) = (self.ctx.mr, self.ctx.nr);
        let svc = self.pool.chip(chip);
        let residency = self.residency_for(chip, a_hash);
        let mut guard = PoolGuard::enter(&self.pool, chip);
        let mut c_scratch = Vec::new();
        let mut rep = GemmReport::default();
        self.for_each_tile(m, n, op_b, jc_lo, jc_hi, |i0, rows, j0, cols, b_p, cb| {
            let data = tile_call(
                svc,
                op_a,
                c.as_ref(),
                b_p,
                cb,
                alpha,
                beta,
                k,
                mr,
                nr,
                i0,
                rows,
                j0,
                cols,
                residency,
                &mut c_scratch,
                &mut rep,
            )?;
            guard.calls += 1;
            unpack_c(&data, c, i0, j0, rows, cols, mr);
            Ok(())
        })?;
        Ok(rep)
    }

    /// Record an unaccelerated host op (level-1/2/3 fallbacks) against the
    /// projection ledger at the given rate.
    pub fn charge_host_op(&self, flops: f64, gflops_rate: f64) {
        let mut s = self.stats.lock().unwrap();
        s.host_level12_s += flops / (gflops_rate * 1e9);
        s.host_level12_flops += flops;
    }

    /// A copy of the cumulative accounting ledger.
    pub fn stats_snapshot(&self) -> BlasStats {
        *self.stats.lock().unwrap()
    }
}

/// The A panel one tile call reads: freshly packed and owned, or a
/// shared resident panel served by the [`PanelCache`].
enum APanel<T> {
    Owned(Vec<T>),
    Cached(Arc<Vec<T>>),
}

/// One µ-kernel tile call: stage the A panel (a verified [`PanelCache`]
/// hit skips `pack_a` entirely) and the C tile (into the shard's reused
/// `c_scratch` staging buffer; B is packed once per jc tile by the
/// caller), cross `svc`, and accumulate the crossing's timing into
/// `rep`. Returns the padded result tile.
fn tile_call<T: Element>(
    svc: &ServiceHandle,
    op_a: MatRef<'_, T>,
    c_read: MatRef<'_, T>,
    b_panel: &[T],
    class_b: WalkClass,
    alpha: T,
    beta: T,
    k: usize,
    mr: usize,
    nr: usize,
    i0: usize,
    rows: usize,
    j0: usize,
    cols: usize,
    residency: Option<(&PanelCache, u64, usize)>,
    c_scratch: &mut Vec<T>,
    rep: &mut GemmReport,
) -> Result<Vec<T>> {
    let (staged, class_a) = match residency {
        Some((cache, a_hash, chip)) => {
            let (panel, class) = cache.get_or_pack(a_hash, chip, op_a, i0, rows, mr);
            (APanel::Cached(panel), class)
        }
        None => {
            let (panel, class) = pack_a(op_a, i0, rows, mr);
            (APanel::Owned(panel), class)
        }
    };
    let a_panel: &[T] = match &staged {
        APanel::Owned(v) => v,
        APanel::Cached(p) => p,
    };
    pack_c_into(c_scratch, c_read, i0, j0, rows, cols, mr, nr);
    let mut params = ProjectionParams::kernel_service(k);
    params.class_a = class_a;
    params.class_b = class_b;
    params.blis = true;
    let (data, resp) =
        T::service_gemm(svc, alpha, a_panel, b_panel, beta, c_scratch.as_slice(), params)?;
    rep.projected_s += resp.projection.total_s;
    rep.wall_s += resp.wall_s;
    rep.calls += 1;
    Ok(data)
}

/// Calibrated host rate used for ledger charges of unaccelerated ops
/// (the paper's §4.3 level-2 rate).
pub(crate) fn host_rate() -> f64 {
    crate::epiphany::timing::CalibratedModel::default().host_level2_f64_gflops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::max_scaled_err;

    fn blas() -> Blas {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .expect("service boots");
        Blas::new(svc)
    }

    fn blas_pool(n: usize) -> Blas {
        let pool = ChipPool::spawn(
            n,
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .expect("pool boots");
        Blas::with_pool(pool, ShardPolicy::ColumnPanels)
    }

    fn oracle_f64(
        ta: Trans,
        tb: Trans,
        alpha: f64,
        a: &Mat<f32>,
        b: &Mat<f32>,
        beta: f64,
        c0: &Mat<f32>,
    ) -> Mat<f64> {
        let op_a = if ta.is_trans() { a.transposed() } else { a.clone() };
        let op_b = if tb.is_trans() { b.transposed() } else { b.clone() };
        let (m, k, n) = (op_a.rows(), op_a.cols(), op_b.cols());
        Mat::from_fn(m, n, |i, j| {
            let mut acc = 0.0f64;
            for l in 0..k {
                acc += op_a.get(i, l) as f64 * op_b.get(l, j) as f64;
            }
            alpha * acc + beta * c0.get(i, j) as f64
        })
    }

    #[test]
    fn sgemm_all_transpose_variants_small() {
        // Non-tile-aligned dims exercise padding: 200×300, K=100.
        let blas = blas();
        let (m, n, k) = (200, 300, 100);
        for ta in Trans::all() {
            for tb in Trans::all() {
                let a = if ta.is_trans() {
                    Mat::<f32>::randn(k, m, 1)
                } else {
                    Mat::<f32>::randn(m, k, 1)
                };
                let b = if tb.is_trans() {
                    Mat::<f32>::randn(n, k, 2)
                } else {
                    Mat::<f32>::randn(k, n, 2)
                };
                let c0 = Mat::<f32>::randn(m, n, 3);
                let mut c = c0.clone();
                let rep = blas.sgemm(ta, tb, 1.5, a.view(), b.view(), -0.5, &mut c).unwrap();
                let want = oracle_f64(ta, tb, 1.5, &a, &b, -0.5, &c0);
                let e = max_scaled_err(c.view(), want.view());
                assert!(e < 1e-5, "{}{} err {e}", ta.code(), tb.code());
                assert_eq!(rep.calls, 2 * 2); // ⌈200/192⌉ × ⌈300/256⌉
                assert!(rep.projected_s > 0.0);
                assert_eq!(rep.chips, 1);
            }
        }
    }

    #[test]
    fn transposed_a_projects_slower() {
        let blas = blas();
        let (m, n, k) = (192, 256, 512);
        let a_n = Mat::<f32>::randn(m, k, 4);
        let a_t = Mat::<f32>::randn(k, m, 4);
        let b = Mat::<f32>::randn(k, n, 5);
        let mut c1 = Mat::<f32>::zeros(m, n);
        let mut c2 = Mat::<f32>::zeros(m, n);
        let rep_nn =
            blas.sgemm(Trans::N, Trans::N, 1.0, a_n.view(), b.view(), 0.0, &mut c1).unwrap();
        let rep_tn =
            blas.sgemm(Trans::T, Trans::N, 1.0, a_t.view(), b.view(), 0.0, &mut c2).unwrap();
        assert!(
            rep_tn.projected_s > rep_nn.projected_s * 1.1,
            "tn {} vs nn {}",
            rep_tn.projected_s,
            rep_nn.projected_s
        );
    }

    #[test]
    fn false_dgemm_matches_f32_precision() {
        let blas = blas();
        let (m, n, k) = (192, 256, 128);
        let a = Mat::<f64>::randn(m, k, 6);
        let b = Mat::<f64>::randn(k, n, 7);
        let c0 = Mat::<f64>::randn(m, n, 8);
        let mut c = c0.clone();
        blas.dgemm_false(Trans::N, Trans::N, 1.0, a.view(), b.view(), 1.0, &mut c).unwrap();
        let want = Mat::from_fn(m, n, |i, j| {
            let mut acc = 0.0f64;
            for l in 0..k {
                acc += a.get(i, l) * b.get(l, j);
            }
            acc + c0.get(i, j)
        });
        let e = max_scaled_err(c.view(), want.view());
        assert!(e > 1e-10 && e < 1e-4, "err {e} should be f32-sized");
    }

    #[test]
    fn dim_mismatch_rejected() {
        let blas = blas();
        let a = Mat::<f32>::randn(10, 20, 1);
        let b = Mat::<f32>::randn(21, 30, 2); // K mismatch
        let mut c = Mat::<f32>::zeros(10, 30);
        assert!(blas.sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).is_err());
    }

    #[test]
    fn pool4_bit_identical_to_pool1() {
        // The acceptance bar for the sharded backend: N=1 is the
        // degenerate plan, and N=4 must produce the same bits — same
        // panels, same µ-kernel math, only the jc ranges move.
        let b1 = blas_pool(1);
        let b4 = blas_pool(4);
        for (ta, tb) in [(Trans::N, Trans::N), (Trans::T, Trans::N), (Trans::N, Trans::T)] {
            let (m, n, k) = (200, 900, 96); // 4 jc tiles: one per chip
            let a = if ta.is_trans() {
                Mat::<f32>::randn(k, m, 11)
            } else {
                Mat::<f32>::randn(m, k, 11)
            };
            let b = if tb.is_trans() {
                Mat::<f32>::randn(n, k, 12)
            } else {
                Mat::<f32>::randn(k, n, 12)
            };
            let c0 = Mat::<f32>::randn(m, n, 13);
            let mut c_single = c0.clone();
            let mut c_pooled = c0.clone();
            let r1 = b1.sgemm(ta, tb, 1.25, a.view(), b.view(), -0.5, &mut c_single).unwrap();
            let r4 = b4.sgemm(ta, tb, 1.25, a.view(), b.view(), -0.5, &mut c_pooled).unwrap();
            assert_eq!(c_single.as_slice(), c_pooled.as_slice(), "{}{}", ta.code(), tb.code());
            assert_eq!(r1.calls, r4.calls);
            assert_eq!(r1.chips, 1);
            assert_eq!(r4.chips, 4);
        }
    }

    #[test]
    fn panel_cache_on_matches_off_and_hits() {
        // 200×300, K=100 → 2 row tiles × 2 column tiles: within one gemm
        // the second jc tile re-reads both A panels, and the second gemm
        // hits every tile. Results must stay bit-identical to cache-off.
        let mut b_on = blas();
        b_on.set_panel_cache(8 << 20);
        let b_off = blas();
        let (m, n, k) = (200, 300, 100);
        let a = Mat::<f32>::randn(m, k, 40);
        let b = Mat::<f32>::randn(k, n, 41);
        let c0 = Mat::<f32>::randn(m, n, 42);
        for pass in 0..2 {
            let mut c_on = c0.clone();
            let mut c_off = c0.clone();
            b_on.sgemm(Trans::N, Trans::N, 1.5, a.view(), b.view(), -0.5, &mut c_on).unwrap();
            b_off.sgemm(Trans::N, Trans::N, 1.5, a.view(), b.view(), -0.5, &mut c_off).unwrap();
            assert_eq!(c_on.as_slice(), c_off.as_slice(), "pass {pass}");
        }
        let s = b_on.panel_cache().unwrap().stats();
        assert_eq!((s.misses, s.hits), (2, 6), "2 first-sight packs, 6 resident hits");
        assert_eq!(s.entries, 2);
        assert!(b_off.panel_cache().is_none(), "cache defaults to off");
    }

    #[test]
    fn column_panels_spread_across_chips() {
        let blas = blas_pool(2);
        let (m, n, k) = (192, 512, 64); // 2 jc tiles
        let a = Mat::<f32>::randn(m, k, 20);
        let b = Mat::<f32>::randn(k, n, 21);
        let mut c = Mat::<f32>::zeros(m, n);
        let rep = blas.sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).unwrap();
        assert_eq!(rep.calls, 2);
        assert_eq!(rep.chips, 2);
        let crossings = blas.pool().crossings();
        assert_eq!(crossings, vec![1, 1], "each chip executed its own column panel");
    }

    #[test]
    fn pinned_policy_keeps_one_chip_hot() {
        let blas = blas_pool(3);
        let (m, n, k) = (64, 600, 32); // 3 jc tiles, all pinned to chip 2
        let a = Mat::<f32>::randn(m, k, 30);
        let b = Mat::<f32>::randn(k, n, 31);
        let mut c = Mat::<f32>::zeros(m, n);
        blas.gemm_on(2, Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).unwrap();
        assert_eq!(blas.pool().crossings(), vec![0, 0, 3]);
        // Out-of-range pins are recoverable errors, not panics.
        let mut c2 = Mat::<f32>::zeros(m, n);
        let r = blas.gemm_on(7, Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c2);
        assert!(r.is_err());
    }

    #[test]
    fn shard_plan_routes_around_unhealthy_chips() {
        let blas = blas_pool(3);
        blas.pool().mark_unhealthy(1);
        // ColumnPanels plans over the healthy chips only.
        let plan = blas.shard_plan(ShardPolicy::ColumnPanels, 3).unwrap();
        let chips: Vec<usize> = plan.iter().map(|&(c, _, _)| c).collect();
        assert_eq!(chips, vec![0, 2], "unhealthy chip 1 skipped");
        let tiles: usize = plan.iter().map(|&(_, lo, hi)| hi - lo).sum();
        assert_eq!(tiles, 3, "every jc tile still covered");
        // A pin on the unhealthy chip degrades to a healthy one.
        let plan = blas.shard_plan(ShardPolicy::Pinned(1), 2).unwrap();
        assert_eq!(plan.len(), 1);
        assert_ne!(plan[0].0, 1, "pin degrades off the unhealthy chip");
        // A pin on a healthy chip is honored.
        assert_eq!(blas.shard_plan(ShardPolicy::Pinned(2), 2).unwrap(), vec![(2, 0, 2)]);
        // Whole pool down: the plan covers every chip (execution will
        // surface the chip error; planning never refuses).
        blas.pool().mark_unhealthy(0);
        blas.pool().mark_unhealthy(2);
        let plan = blas.shard_plan(ShardPolicy::ColumnPanels, 3).unwrap();
        assert_eq!(plan.len(), 3);
        // Recovery: a healthy probe re-admits the chip to the planner.
        blas.pool().mark_healthy(1);
        let plan = blas.shard_plan(ShardPolicy::ColumnPanels, 3).unwrap();
        assert_eq!(plan, vec![(1, 0, 3)]);
    }

    #[test]
    fn failed_execution_marks_chip_unhealthy() {
        let blas = blas_pool(2);
        blas.pool().chip(0).fail_next_calls(usize::MAX);
        let (m, n, k) = (64, 64, 32);
        let a = Mat::<f32>::randn(m, k, 40);
        let b = Mat::<f32>::randn(k, n, 41);
        let mut c = Mat::<f32>::zeros(m, n);
        let r = blas.gemm_on(0, Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c);
        assert!(r.is_err());
        assert!(!blas.pool().is_healthy(0), "the failing chip is condemned");
        // The same call now routes around the dead chip and succeeds.
        let mut c2 = Mat::<f32>::zeros(m, n);
        blas.gemm_on(0, Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c2).unwrap();
        assert!(blas.pool().crossings()[1] > 0, "chip 1 rescued the pinned call");
    }
}
