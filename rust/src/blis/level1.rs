//! Level-1 BLAS (vector-vector), instantiated for f32 and f64 over strided
//! vectors — the unaccelerated host ops of the generated library.

use crate::linalg::Real;

/// Strided vector view helper: index `i` ↦ `data[offset + i*inc]`.
#[inline]
fn at(i: usize, inc: usize) -> usize {
    i * inc
}

/// y ← αx + y
pub fn axpy<T: Real>(n: usize, alpha: T, x: &[T], incx: usize, y: &mut [T], incy: usize) {
    for i in 0..n {
        y[at(i, incy)] += alpha * x[at(i, incx)];
    }
}

/// x ← αx
pub fn scal<T: Real>(n: usize, alpha: T, x: &mut [T], incx: usize) {
    for i in 0..n {
        x[at(i, incx)] *= alpha;
    }
}

/// y ← x
pub fn copy<T: Real>(n: usize, x: &[T], incx: usize, y: &mut [T], incy: usize) {
    for i in 0..n {
        y[at(i, incy)] = x[at(i, incx)];
    }
}

/// x ↔ y
pub fn swap<T: Real>(n: usize, x: &mut [T], incx: usize, y: &mut [T], incy: usize) {
    for i in 0..n {
        std::mem::swap(&mut x[at(i, incx)], &mut y[at(i, incy)]);
    }
}

/// xᵀy
pub fn dot<T: Real>(n: usize, x: &[T], incx: usize, y: &[T], incy: usize) -> T {
    let mut acc = T::ZERO;
    for i in 0..n {
        acc += x[at(i, incx)] * y[at(i, incy)];
    }
    acc
}

/// ‖x‖₂ (with scaling against overflow, LAPACK-style).
pub fn nrm2<T: Real>(n: usize, x: &[T], incx: usize) -> T {
    let mut scale = T::ZERO;
    let mut ssq = T::ONE;
    for i in 0..n {
        let v = x[at(i, incx)].abs();
        if v > T::ZERO {
            if scale < v {
                let r = scale / v;
                ssq = T::ONE + ssq * r * r;
                scale = v;
            } else {
                let r = v / scale;
                ssq += r * r;
            }
        }
    }
    scale * ssq.sqrt()
}

/// Σ|xᵢ|
pub fn asum<T: Real>(n: usize, x: &[T], incx: usize) -> T {
    let mut acc = T::ZERO;
    for i in 0..n {
        acc += x[at(i, incx)].abs();
    }
    acc
}

/// argmax |xᵢ| (first on ties), None when n = 0.
pub fn iamax<T: Real>(n: usize, x: &[T], incx: usize) -> Option<usize> {
    if n == 0 {
        return None;
    }
    let mut best = 0usize;
    let mut bv = x[0].abs();
    for i in 1..n {
        let v = x[at(i, incx)].abs();
        if v > bv {
            bv = v;
            best = i;
        }
    }
    Some(best)
}

/// Givens rotation application: (x, y) ← (c·x + s·y, c·y − s·x)
pub fn rot<T: Real>(n: usize, x: &mut [T], incx: usize, y: &mut [T], incy: usize, c: T, s: T) {
    for i in 0..n {
        let xi = x[at(i, incx)];
        let yi = y[at(i, incy)];
        x[at(i, incx)] = c * xi + s * yi;
        y[at(i, incy)] = c * yi - s * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(3, 2.0, &x, 1, &mut y, 1);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn strided_axpy() {
        let x = [1.0f64, 0.0, 2.0, 0.0];
        let mut y = [0.0f64; 2];
        axpy(2, 1.0, &x, 2, &mut y, 1);
        assert_eq!(y, [1.0, 2.0]);
    }

    #[test]
    fn dot_and_nrm2() {
        let x = [3.0f64, 4.0];
        assert_eq!(dot(2, &x, 1, &x, 1), 25.0);
        assert!((nrm2(2, &x, 1) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn nrm2_no_overflow() {
        let x = [1e30f32, 1e30];
        let r = nrm2(2, &x, 1);
        assert!(r.is_finite() && (r / (1e30 * 2f32.sqrt()) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn iamax_first_max() {
        let x = [1.0f32, -5.0, 5.0, 2.0];
        assert_eq!(iamax(4, &x, 1), Some(1));
        assert_eq!(iamax(0, &x, 1), None);
    }

    #[test]
    fn swap_and_copy() {
        let mut x = [1.0f32, 2.0];
        let mut y = [3.0f32, 4.0];
        swap(2, &mut x, 1, &mut y, 1);
        assert_eq!((x, y), ([3.0, 4.0], [1.0, 2.0]));
        let mut z = [0.0f32; 2];
        copy(2, &x, 1, &mut z, 1);
        assert_eq!(z, [3.0, 4.0]);
    }

    #[test]
    fn rot_rotates() {
        let mut x = [1.0f64];
        let mut y = [0.0f64];
        let (c, s) = (0.0, 1.0);
        rot(1, &mut x, 1, &mut y, 1, c, s);
        assert_eq!((x[0], y[0]), (0.0, -1.0));
    }

    #[test]
    fn asum_abs() {
        assert_eq!(asum(3, &[1.0f32, -2.0, 3.0], 1), 6.0);
    }
}
