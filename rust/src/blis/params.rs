//! BLIS context: blocking parameters and transpose/conjugation flags.

use crate::epiphany::kernel::KernelGeometry;

/// BLAS transpose parameter. For the real-domain BLAS the paper
/// instantiates, `C` (conjugate) behaves as `N` and `H` (hermitian
/// transpose) as `T` — exactly the note under the paper's Tables 4 and 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trans {
    /// No transpose.
    N,
    /// Transpose.
    T,
    /// Conjugate (= `N` in the real domain).
    C,
    /// Hermitian transpose (= `T` in the real domain).
    H,
}

impl Trans {
    /// Whether the operand is transposed in the real domain.
    pub fn is_trans(self) -> bool {
        matches!(self, Trans::T | Trans::H)
    }

    /// The BLIS testsuite single-letter code.
    pub fn code(self) -> char {
        match self {
            Trans::N => 'n',
            Trans::T => 't',
            Trans::C => 'c',
            Trans::H => 'h',
        }
    }

    /// Every transpose flag (the testsuite's parameter sweep).
    pub fn all() -> [Trans; 4] {
        [Trans::N, Trans::T, Trans::C, Trans::H]
    }
}

/// Blocking context. In this instantiation the micro-tile is the entire
/// cache-block (MR = MC = 192, NR = NC = 256) and K is unblocked — the
/// paper's µ-kernel takes arbitrary K, the chip accumulator does the rest.
#[derive(Clone, Copy, Debug)]
pub struct BlisContext {
    /// Micro-tile rows (= the Epiphany kernel's m).
    pub mr: usize,
    /// Micro-tile cols (= the Epiphany kernel's n).
    pub nr: usize,
    /// K cap per µ-kernel call (0 = unbounded). The artifact chainer and
    /// the chip accumulator both handle arbitrary K; a cap exists for
    /// ablations on HC-RAM pressure.
    pub kc: usize,
}

impl BlisContext {
    /// The paper's blocking: MR = 192, NR = 256, K unblocked.
    pub fn paper() -> Self {
        let g = KernelGeometry::paper();
        BlisContext { mr: g.m, nr: g.n, kc: 0 }
    }

    /// Tiles needed to cover `len` with tile `t`.
    pub fn tiles(len: usize, t: usize) -> usize {
        len.div_ceil(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_domain_aliases() {
        assert!(!Trans::N.is_trans());
        assert!(!Trans::C.is_trans());
        assert!(Trans::T.is_trans());
        assert!(Trans::H.is_trans());
    }

    #[test]
    fn paper_context() {
        let ctx = BlisContext::paper();
        assert_eq!((ctx.mr, ctx.nr), (192, 256));
        assert_eq!(BlisContext::tiles(4096, 192), 22);
        assert_eq!(BlisContext::tiles(4096, 256), 16);
    }
}
