//! A BLIS-like framework instantiating the BLAS (paper §3.1).
//!
//! BLIS's job in the paper: take arbitrary `C = α·op(A)·op(B) + β·C`
//! problems, block them into fixed-size micro-kernel calls (m=192, n=256,
//! arbitrary K), pack operands into the micro-kernel's prescribed layouts
//! (a1 column-major, b1 row-major), and expose the classic level-1/2/3
//! BLAS on top. This module is that engine in Rust:
//!
//! * [`op`] — the typed, precision-generic operation-descriptor core
//!   ([`op::GemmOp`], [`op::GemvOp`], [`op::Level1Op`], …) dispatched by
//!   [`Blas::execute`] and submittable asynchronously via [`Blas::submit`];
//! * [`gemm`] — the tiled driver routing micro-tile calls through the
//!   Epiphany service (the paper's custom µ-kernel);
//! * [`packing`] — layout/padding transforms, whose *walk class* (contig
//!   vs strided) is what spreads Table 4's transpose-variant GFLOPS;
//! * [`level1`], [`level2`], [`level3`] — the host-side BLAS (the paper's
//!   level-2 ops are unaccelerated, which §4.3 blames for the HPL number);
//! * [`blas_api`] — the classic FORTRAN-style surface (`sgemm`, `saxpy`,
//!   …), generated-style shims over the descriptor core;
//! * [`testsuite`] — BLIS-testsuite-style residue rows (Tables 3–6);
//! * [`autotune`] — deterministic blocking search over [`BlisContext`]
//!   candidates, priced by the calibrated timing model.
//!
//! How a level-3 call flows from [`Blas::execute`] through the shard plan
//! down to per-chip HH-RAM is drawn in `docs/ARCHITECTURE.md`.

pub mod autotune;
pub mod blas_api;
pub mod gemm;
pub mod level1;
pub mod level2;
pub mod level3;
pub mod op;
pub mod packing;
pub mod params;
pub mod testsuite;

pub use autotune::{autotune, AutotuneConfig, TunedParams};
pub use blas_api::BlasLibrary;
pub use gemm::Blas;
pub use op::{BlasOp, Dtype, Element, GemmOp, GemmTask, GemvOp, Level1Op, Route, Ticket};
pub use params::{BlisContext, Trans};
