//! BLIS-testsuite-style verification rows: run an operation over all
//! transpose-parameter combinations, compute the normalized residue
//! against an f64 oracle, and emit `blis_<dt><op>_<params>_<stor>` rows —
//! the exact format of the paper's Tables 3–6.

use super::gemm::{Blas, GemmReport};
use super::packing::{pack_a, pack_b};
use super::params::Trans;
use crate::host::microkernel::{host_sgemm_variant, UkrVariant};
use crate::linalg::{max_scaled_err, Mat, Real, XorShiftRng};
use anyhow::Result;

/// One testsuite row.
#[derive(Clone, Debug)]
pub struct TestRow {
    /// e.g. `blis_sgemm_nt_ccc`.
    pub label: String,
    /// Projected-Parallella GFLOPS.
    pub gflops_projected: f64,
    /// Wall-clock GFLOPS on this machine.
    pub gflops_wall: f64,
    /// Normalized residue vs the f64 oracle.
    pub residue: f64,
    /// The aggregate tile report behind the GFLOPS columns.
    pub report: GemmReport,
}

impl TestRow {
    /// One `blis_*` table line in the paper's Tables 3–6 format.
    pub fn render(&self) -> String {
        format!(
            "{:<22} {:>8.3} {:>10.2e}   (wall {:>8.3} GF)",
            self.label, self.gflops_projected, self.residue, self.gflops_wall
        )
    }
}

/// f64 oracle for `α·op(A)·op(B) + β·C`.
fn oracle<T: Real>(
    ta: Trans,
    tb: Trans,
    alpha: f64,
    a: &Mat<T>,
    b: &Mat<T>,
    beta: f64,
    c0: &Mat<T>,
) -> Mat<f64> {
    let a64 = a.cast::<f64>();
    let b64 = b.cast::<f64>();
    let op_a = if ta.is_trans() { a64.transposed() } else { a64 };
    let op_b = if tb.is_trans() { b64.transposed() } else { b64 };
    let mut c = c0.cast::<f64>();
    super::level3::gemm_host(Trans::N, Trans::N, alpha, op_a.view(), op_b.view(), beta, &mut c);
    c
}

/// Run `blis_sgemm_<params>_ccc` for one transpose pair.
pub fn run_sgemm_case(
    blas: &Blas,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    seed: u64,
) -> Result<TestRow> {
    let a =
        if ta.is_trans() { Mat::<f32>::randn(k, m, seed) } else { Mat::<f32>::randn(m, k, seed) };
    let b = if tb.is_trans() {
        Mat::<f32>::randn(n, k, seed + 1)
    } else {
        Mat::<f32>::randn(k, n, seed + 1)
    };
    let c0 = Mat::<f32>::randn(m, n, seed + 2);
    let mut c = c0.clone();
    let report = blas.sgemm(ta, tb, 1.0, a.view(), b.view(), 1.0, &mut c)?;
    let want = oracle(ta, tb, 1.0, &a, &b, 1.0, &c0);
    let residue = max_scaled_err(c.view(), want.view());
    Ok(TestRow {
        label: format!("blis_sgemm_{}{}_ccc", ta.code(), tb.code()),
        gflops_projected: report.projected_gflops(),
        gflops_wall: report.wall_gflops(),
        residue,
        report,
    })
}

/// Run `blis_dgemm_<params>_ccc` through the *false* dgemm.
pub fn run_false_dgemm_case(
    blas: &Blas,
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    seed: u64,
) -> Result<TestRow> {
    let a =
        if ta.is_trans() { Mat::<f64>::randn(k, m, seed) } else { Mat::<f64>::randn(m, k, seed) };
    let b = if tb.is_trans() {
        Mat::<f64>::randn(n, k, seed + 1)
    } else {
        Mat::<f64>::randn(k, n, seed + 1)
    };
    let c0 = Mat::<f64>::randn(m, n, seed + 2);
    let mut c = c0.clone();
    let report = blas.dgemm_false(ta, tb, 1.0, a.view(), b.view(), 1.0, &mut c)?;
    let want = oracle(ta, tb, 1.0, &a, &b, 1.0, &c0);
    let residue = max_scaled_err(c.view(), want.view());
    Ok(TestRow {
        label: format!("blis_dgemm_{}{}_ccc", ta.code(), tb.code()),
        gflops_projected: report.projected_gflops(),
        gflops_wall: report.wall_gflops(),
        residue,
        report,
    })
}

/// The full 16-variant sweep (Tables 4 and 6 shape).
pub fn sweep_all_variants(
    blas: &Blas,
    dgemm: bool,
    m: usize,
    n: usize,
    k: usize,
) -> Result<Vec<TestRow>> {
    let mut rows = Vec::new();
    let mut seed = 1000;
    for ta in Trans::all() {
        for tb in Trans::all() {
            let row = if dgemm {
                run_false_dgemm_case(blas, ta, tb, m, n, k, seed)?
            } else {
                run_sgemm_case(blas, ta, tb, m, n, k, seed)?
            };
            rows.push(row);
            seed += 10;
        }
    }
    Ok(rows)
}

/// Host µ-kernel conformance sweep — the lock-down for the vectorized
/// variants in [`crate::host::microkernel`]. Every compiled-in
/// [`UkrVariant`] runs every transpose pair × α,β ∈ {0, 1, −1, 0.5} ×
/// ragged shape (k = 0, 1, KSUB±1; m/n off the 8×4 register block) on
/// panels packed by the production [`pack_a`]/[`pack_b`] paths, and must
/// (a) match an f64 oracle within f32 accumulation error and (b) agree
/// *bitwise* with the scalar oracle variant. Returns the number of cases
/// checked; panics with the offending case label on the first divergence.
pub fn ukr_conformance_sweep() -> usize {
    // KSUB = 64 in the paper geometry: straddle it, the register block
    // (8×4), and the degenerate k = 0 / rank-1 k = 1 edges.
    let shapes: [(usize, usize, usize); 6] =
        [(8, 4, 16), (9, 5, 63), (13, 7, 65), (32, 16, 1), (50, 50, 0), (24, 20, 64)];
    let coeffs: [(f32, f32); 5] = [(1.0, 0.0), (1.0, 1.0), (-1.0, 0.5), (0.5, -1.0), (0.0, 1.0)];
    let mut rng = XorShiftRng::new(0xC0F);
    let mut fill = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.next_unit() as f32).collect() };
    let mut cases = 0usize;
    for &(m, n, k) in &shapes {
        for ta in Trans::all() {
            for tb in Trans::all() {
                // Source matrices in the storage the op views expect.
                let a_src = if ta.is_trans() {
                    Mat::from_col_major(k, m, &fill(k * m))
                } else {
                    Mat::from_col_major(m, k, &fill(m * k))
                };
                let b_src = if tb.is_trans() {
                    Mat::from_col_major(n, k, &fill(n * k))
                } else {
                    Mat::from_col_major(k, n, &fill(k * n))
                };
                let op_a = if ta.is_trans() { a_src.view().t() } else { a_src.view() };
                let op_b = if tb.is_trans() { b_src.view().t() } else { b_src.view() };
                let (a, _) = pack_a(op_a, 0, m, m);
                let (b, _) = pack_b(op_b, 0, n, n);
                for &(alpha, beta) in &coeffs {
                    let c0 = fill(m * n);
                    // f64 oracle over the packed panels (a col-major,
                    // b row-major, c col-major).
                    let mut want = vec![0.0f64; m * n];
                    for j in 0..n {
                        for i in 0..m {
                            let mut acc = 0.0f64;
                            for l in 0..k {
                                acc += a[i + l * m] as f64 * b[l * n + j] as f64;
                            }
                            want[i + j * m] =
                                alpha as f64 * acc + beta as f64 * c0[i + j * m] as f64;
                        }
                    }
                    let scale =
                        want.iter().fold(1.0f64, |s, v| s.max(v.abs())).max(f64::MIN_POSITIVE);
                    let reference =
                        host_sgemm_variant(UkrVariant::Scalar, m, n, k, alpha, &a, &b, beta, &c0);
                    for v in UkrVariant::all() {
                        if !v.available() {
                            continue;
                        }
                        let label = format!(
                            "{} {m}x{n}x{k} {}{} a={alpha} b={beta}",
                            v.name(),
                            ta.code(),
                            tb.code()
                        );
                        let got = host_sgemm_variant(v, m, n, k, alpha, &a, &b, beta, &c0);
                        for (g, w) in got.iter().zip(&want) {
                            let err = (*g as f64 - w).abs() / scale;
                            assert!(err < 1e-5, "{label}: err {err} vs f64 oracle");
                        }
                        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                        assert!(
                            bits(&got) == bits(&reference),
                            "{label}: diverged bitwise from the scalar oracle"
                        );
                        cases += 1;
                    }
                }
            }
        }
    }
    cases
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};

    fn blas() -> Blas {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        Blas::new(svc)
    }

    #[test]
    fn sgemm_row_kernel_size() {
        // Table 3 shape: kernel-size BLIS sgemm, residue ~1e-7.
        let blas = blas();
        let row = run_sgemm_case(&blas, Trans::N, Trans::N, 192, 256, 512, 42).unwrap();
        assert_eq!(row.label, "blis_sgemm_nn_ccc");
        assert!(row.residue > 1e-9 && row.residue < 1e-5, "residue {}", row.residue);
        assert!(row.gflops_projected > 0.5, "projected {}", row.gflops_projected);
    }

    #[test]
    fn variant_sweep_small() {
        // All 16 variants at a small size: correctness + n/c and t/h
        // equivalence of projected speed (real domain).
        let blas = blas();
        let rows = sweep_all_variants(&blas, false, 192, 256, 128).unwrap();
        assert_eq!(rows.len(), 16);
        for r in &rows {
            assert!(r.residue < 1e-5, "{} residue {}", r.label, r.residue);
        }
        let find = |code: &str| {
            rows.iter().find(|r| r.label.contains(&format!("_{code}_"))).unwrap().gflops_projected
        };
        // c ≡ n, h ≡ t in the real domain: projections must match exactly.
        assert!((find("nn") - find("cc")).abs() < 1e-9);
        assert!((find("tt") - find("hh")).abs() < 1e-9);
        // Transposed-A variants are slower (Table 4's ordering).
        assert!(find("tn") < find("nn"));
        assert!(find("nt") > find("nn"));
    }

    #[test]
    fn ukr_conformance_sweep_is_exhaustive() {
        // 6 shapes × 16 transpose pairs × 5 coefficient pairs × the
        // compiled-in variants (panics inside the sweep on any mismatch).
        let variants = UkrVariant::all().iter().filter(|v| v.available()).count();
        assert_eq!(ukr_conformance_sweep(), 6 * 16 * 5 * variants);
    }

    #[test]
    fn false_dgemm_row_has_f32_not_f64_residue() {
        let blas = blas();
        let row = run_false_dgemm_case(&blas, Trans::N, Trans::N, 192, 256, 256, 77).unwrap();
        assert_eq!(row.label, "blis_dgemm_nn_ccc");
        // Table 5/6: residues ~1e-8, far above true-f64 (~1e-15).
        assert!(row.residue > 1e-11 && row.residue < 1e-5, "residue {}", row.residue);
    }
}
