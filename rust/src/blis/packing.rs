//! Packing: copy op(A)/op(B) tiles into the µ-kernel's prescribed layouts
//! (§3.3: "a1 is column-major stored, b1 is row-major stored"), zero-padded
//! to the fixed micro-tile.
//!
//! The *walk class* of each pack is the performance story of Table 4: a
//! unit-stride source walk packs at memcpy speed; a transposed walk
//! gathers across the leading dimension and is several times slower on the
//! Zynq (calibrated in `CalibratedModel`). The class is decided here from
//! the view's strides and flows into the projection.

use crate::epiphany::timing::WalkClass;
use crate::linalg::{MatRef, Real};

/// Pack an `m_tile × k` column-major A panel from `op_a` (already the
/// logical op(A) view), rows `i0..i0+rows`, zero-padding to `m_tile`.
pub fn pack_a<T: Real>(
    op_a: MatRef<'_, T>,
    i0: usize,
    rows: usize,
    m_tile: usize,
) -> (Vec<T>, WalkClass) {
    let k = op_a.cols();
    let mut out = vec![T::ZERO; m_tile * k];
    if op_a.row_stride() == 1 {
        // Column-contiguous source: memcpy per column.
        for l in 0..k {
            let src = op_a.col_slice(l, i0, rows);
            out[l * m_tile..l * m_tile + rows].copy_from_slice(src);
        }
        (out, WalkClass::Contig)
    } else if op_a.col_stride() == 1 {
        // Transposed A (rows contiguous): loop-interchanged blocked
        // transpose. Walk TP_LANES source rows contiguously at a time and
        // store TP_LANES-wide into each output column — same bytes as the
        // naive gather, but unit-stride reads and short vectorizable
        // stores. Still the StridedA *cost* class: the projection models
        // the Zynq's gather, not this host loop.
        let rows_t = op_a.t(); // column i of rows_t = row i of op(A)
        let mut i = 0;
        while i + TP_LANES <= rows {
            let s0 = rows_t.col_slice(i0 + i, 0, k);
            let s1 = rows_t.col_slice(i0 + i + 1, 0, k);
            let s2 = rows_t.col_slice(i0 + i + 2, 0, k);
            let s3 = rows_t.col_slice(i0 + i + 3, 0, k);
            for (l, col) in out.chunks_exact_mut(m_tile).enumerate() {
                col[i..i + TP_LANES].copy_from_slice(&[s0[l], s1[l], s2[l], s3[l]]);
            }
            i += TP_LANES;
        }
        while i < rows {
            let s = rows_t.col_slice(i0 + i, 0, k);
            for (col, &v) in out.chunks_exact_mut(m_tile).zip(s) {
                col[i] = v;
            }
            i += 1;
        }
        (out, WalkClass::StridedA)
    } else {
        // Exotic strides (neither dimension contiguous): element gather.
        for l in 0..k {
            for i in 0..rows {
                out[l * m_tile + i] = op_a.get(i0 + i, l);
            }
        }
        (out, WalkClass::StridedA)
    }
}

/// Lanes per blocked-transpose step in the strided packing paths (one
/// short contiguous store per source element group).
const TP_LANES: usize = 4;

/// Pack a `k × n_tile` *row-major* B panel from `op_b` (the logical op(B)
/// view), columns `j0..j0+cols`, zero-padding to `n_tile`.
pub fn pack_b<T: Real>(
    op_b: MatRef<'_, T>,
    j0: usize,
    cols: usize,
    n_tile: usize,
) -> (Vec<T>, WalkClass) {
    let mut out = Vec::new();
    let class = pack_b_into(&mut out, op_b, j0, cols, n_tile);
    (out, class)
}

/// [`pack_b`] into a caller-owned staging buffer (cleared and re-zeroed
/// to exactly `k × n_tile`), so the gemm driver can reuse one buffer's
/// capacity across every `jc` column tile instead of allocating per
/// panel. Same bytes, same walk class as [`pack_b`].
pub fn pack_b_into<T: Real>(
    out: &mut Vec<T>,
    op_b: MatRef<'_, T>,
    j0: usize,
    cols: usize,
    n_tile: usize,
) -> WalkClass {
    let k = op_b.rows();
    out.clear();
    out.resize(k * n_tile, T::ZERO);
    if op_b.col_stride() == 1 {
        // op(B) row-contiguous (i.e. B was transposed): each output row is
        // a memcpy from a row of op(B). op(B) = Bᵀ view has rs = ldb,
        // cs = 1, so row l of op(B) is column l of the stored Bᵀ.
        let row_view = op_b.t(); // rows become columns with rs == 1
        for l in 0..k {
            let src = row_view.col_slice(l, j0, cols);
            out[l * n_tile..l * n_tile + cols].copy_from_slice(src);
        }
        WalkClass::Contig
    } else if op_b.row_stride() == 1 {
        // Plain B (columns contiguous): the row-major panel build is a
        // transpose — loop-interchanged and blocked like the strided
        // `pack_a` path, so the source walks at unit stride and each
        // output row takes TP_LANES-wide stores. Bytes are identical to
        // the naive gather; the StridedB *cost* class is unchanged (the
        // projection prices the Zynq walk, not this host loop).
        let mut j = 0;
        while j + TP_LANES <= cols {
            let s0 = op_b.col_slice(j0 + j, 0, k);
            let s1 = op_b.col_slice(j0 + j + 1, 0, k);
            let s2 = op_b.col_slice(j0 + j + 2, 0, k);
            let s3 = op_b.col_slice(j0 + j + 3, 0, k);
            for (l, row) in out.chunks_exact_mut(n_tile).enumerate() {
                row[j..j + TP_LANES].copy_from_slice(&[s0[l], s1[l], s2[l], s3[l]]);
            }
            j += TP_LANES;
        }
        while j < cols {
            let s = op_b.col_slice(j0 + j, 0, k);
            for (row, &v) in out.chunks_exact_mut(n_tile).zip(s) {
                row[j] = v;
            }
            j += 1;
        }
        WalkClass::StridedB
    } else {
        // Exotic strides: element gather.
        for l in 0..k {
            for j in 0..cols {
                out[l * n_tile + j] = op_b.get(l, j0 + j);
            }
        }
        WalkClass::StridedB
    }
}

/// Extract a zero-padded column-major `m_tile × n_tile` C tile.
pub fn pack_c<T: Real>(
    c: MatRef<'_, T>,
    i0: usize,
    j0: usize,
    rows: usize,
    cols: usize,
    m_tile: usize,
    n_tile: usize,
) -> Vec<T> {
    let mut out = Vec::new();
    pack_c_into(&mut out, c, i0, j0, rows, cols, m_tile, n_tile);
    out
}

/// [`pack_c`] into a caller-owned staging buffer (cleared and re-zeroed
/// to exactly `m_tile × n_tile`), reused across a shard's tile loop so
/// C staging stops allocating per micro-tile. Same bytes as [`pack_c`].
pub fn pack_c_into<T: Real>(
    out: &mut Vec<T>,
    c: MatRef<'_, T>,
    i0: usize,
    j0: usize,
    rows: usize,
    cols: usize,
    m_tile: usize,
    n_tile: usize,
) {
    out.clear();
    out.resize(m_tile * n_tile, T::ZERO);
    if c.row_stride() == 1 {
        for j in 0..cols {
            let src = c.col_slice(j0 + j, i0, rows);
            out[j * m_tile..j * m_tile + rows].copy_from_slice(src);
        }
    } else {
        for j in 0..cols {
            for i in 0..rows {
                out[j * m_tile + i] = c.get(i0 + i, j0 + j);
            }
        }
    }
}

/// Write the real region of a µ-kernel result tile back into C.
pub fn unpack_c<T: Real>(
    tile: &[T],
    c: &mut crate::linalg::MatMut<'_, T>,
    i0: usize,
    j0: usize,
    rows: usize,
    cols: usize,
    m_tile: usize,
) {
    if c.row_stride() == 1 {
        for j in 0..cols {
            let dst = c.col_slice_mut(j0 + j, i0, rows);
            dst.copy_from_slice(&tile[j * m_tile..j * m_tile + rows]);
        }
    } else {
        for j in 0..cols {
            for i in 0..rows {
                c.set(i0 + i, j0 + j, tile[j * m_tile + i]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn pack_a_contig_class_and_padding() {
        let a = Mat::<f32>::from_fn(5, 3, |i, j| (10 * i + j) as f32);
        let (panel, class) = pack_a(a.view(), 1, 4, 6);
        assert_eq!(class, WalkClass::Contig);
        // Column 0 rows 1..5 then zero pad rows 5..6.
        assert_eq!(&panel[0..6], &[10.0, 20.0, 30.0, 40.0, 0.0, 0.0]);
        // Column 2.
        assert_eq!(&panel[12..18], &[12.0, 22.0, 32.0, 42.0, 0.0, 0.0]);
    }

    #[test]
    fn pack_a_transposed_class() {
        let a = Mat::<f32>::from_fn(3, 5, |i, j| (10 * i + j) as f32);
        let (panel, class) = pack_a(a.t(), 0, 5, 5);
        assert_eq!(class, WalkClass::StridedA);
        // op(A) = A^T is 5x3: column l of the panel is row l of A.
        assert_eq!(&panel[0..5], &[0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn pack_b_classes() {
        let b = Mat::<f32>::from_fn(4, 6, |i, j| (10 * i + j) as f32);
        let (panel_n, class_n) = pack_b(b.view(), 2, 3, 4);
        assert_eq!(class_n, WalkClass::StridedB);
        // Row-major: row 0 = B[0, 2..5], padded to 4.
        assert_eq!(&panel_n[0..4], &[2.0, 3.0, 4.0, 0.0]);
        let bt = Mat::<f32>::from_fn(6, 4, |i, j| (10 * j + i) as f32); // Bᵀ stored
        let (panel_t, class_t) = pack_b(bt.t(), 2, 3, 4);
        assert_eq!(class_t, WalkClass::Contig);
        assert_eq!(&panel_t[0..4], &[2.0, 3.0, 4.0, 0.0]);
    }

    #[test]
    fn into_variants_match_and_reuse_capacity() {
        let b = Mat::<f32>::from_fn(4, 6, |i, j| (10 * i + j) as f32);
        let (want, want_class) = pack_b(b.view(), 2, 3, 4);
        let mut buf = Vec::new();
        let class = pack_b_into(&mut buf, b.view(), 2, 3, 4);
        assert_eq!((buf.as_slice(), class), (want.as_slice(), want_class));
        let cap = buf.capacity();
        let class2 = pack_b_into(&mut buf, b.view(), 0, 3, 4);
        assert_eq!(class2, want_class);
        assert_eq!(buf.capacity(), cap, "re-pack must reuse the staging capacity");

        let c0 = Mat::<f64>::from_fn(4, 4, |i, j| (i + 10 * j) as f64);
        let want_c = pack_c(c0.view(), 1, 1, 2, 2, 3, 3);
        let mut cbuf = vec![9.0f64]; // dirty, undersized: must be re-zeroed
        pack_c_into(&mut cbuf, c0.view(), 1, 1, 2, 2, 3, 3);
        assert_eq!(cbuf, want_c);
    }

    #[test]
    fn blocked_transpose_paths_match_naive_gather() {
        // Ragged rows/cols (not multiples of TP_LANES) exercise both the
        // 4-lane body and the single-lane tail of the interchanged loops.
        let a = Mat::<f32>::from_fn(7, 9, |i, j| (100 * i + j) as f32);
        let op_a = a.t(); // 9×7, rs = 7, cs = 1 → blocked StridedA path
        let (panel, class) = pack_a(op_a, 1, 7, 10);
        assert_eq!(class, WalkClass::StridedA);
        for l in 0..op_a.cols() {
            for i in 0..7 {
                assert_eq!(panel[l * 10 + i], op_a.get(1 + i, l), "({i},{l})");
            }
            assert_eq!(&panel[l * 10 + 7..l * 10 + 10], &[0.0; 3], "pad l={l}");
        }

        let b = Mat::<f32>::from_fn(5, 11, |i, j| (100 * i + j) as f32);
        let (panel, class) = pack_b(b.view(), 2, 7, 9); // blocked StridedB
        assert_eq!(class, WalkClass::StridedB);
        for l in 0..5 {
            for j in 0..7 {
                assert_eq!(panel[l * 9 + j], b.get(l, 2 + j), "({l},{j})");
            }
            assert_eq!(&panel[l * 9 + 7..l * 9 + 9], &[0.0; 2], "pad l={l}");
        }
    }

    #[test]
    fn c_round_trip() {
        let c0 = Mat::<f64>::from_fn(4, 4, |i, j| (i + 10 * j) as f64);
        let tile = pack_c(c0.view(), 1, 1, 2, 2, 3, 3);
        assert_eq!(tile[0], c0.get(1, 1));
        assert_eq!(tile[3 + 1], c0.get(2, 2));
        let mut c1 = Mat::<f64>::zeros(4, 4);
        let mut v = c1.view_mut();
        unpack_c(&tile, &mut v, 1, 1, 2, 2, 3);
        assert_eq!(c1.get(1, 1), c0.get(1, 1));
        assert_eq!(c1.get(2, 2), c0.get(2, 2));
        assert_eq!(c1.get(0, 0), 0.0);
    }
}
