//! Typed, precision-generic BLAS operation descriptors — the object-based
//! core the classic FORTRAN shims are generated over.
//!
//! BLIS itself exposes an object API underneath the FORTRAN names (Van Zee
//! & van de Geijn); the paper's §3.1 generation step wraps it. This module
//! is that core for the Rust instantiation:
//!
//! * every BLAS call is a value implementing [`BlasOp`] — a descriptor
//!   carrying views ([`MatRef`]/[`MatMut`]), scalars and flags;
//! * [`crate::blis::Blas::execute`] is the **single fallible dispatch
//!   path**: it validates the descriptor, routes it (level-3 gemm → the
//!   Epiphany service, everything else → host compute) and owns the stats
//!   accounting — the classic shims in [`crate::blis::blas_api`] are thin
//!   generated-style wrappers that construct descriptors and delegate;
//! * [`crate::blis::Blas::submit`] turns any `Send` descriptor into an
//!   in-flight [`Ticket`], so callers can overlap packing of the next
//!   operand with an in-flight µ-kernel batch (the paper's §3.2 service
//!   process, made pipelineable).
//!
//! Precision is a type parameter, not a name prefix: [`GemmOp<f32>`] is
//! the paper's accelerated sgemm, [`GemmOp<f64>`] its "false dgemm" (f64
//! API, f32 Epiphany compute) — both run through one driver, selected by
//! the [`Element`] trait.

use super::gemm::{Blas, GemmReport};
use super::params::Trans;
use super::{level1, level2, level3};
use crate::host::projection::ProjectionParams;
use crate::host::service::{ServiceHandle, ServiceResponse};
use crate::linalg::{Mat, MatMut, MatRef, Real};
use anyhow::{anyhow, ensure, Result};
use std::sync::mpsc;

/// Element dtype tag — shared by the descriptor core and the coordinator
/// wire protocol (one byte on the wire).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// Single precision (the paper's accelerated sgemm class).
    F32,
    /// Double precision (the "false dgemm" class: f64 API, f32 compute).
    F64,
}

impl Dtype {
    /// The one-byte wire tag of this dtype.
    pub fn code(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F64 => 1,
        }
    }

    /// Decode a wire tag; unknown tags are recoverable errors.
    pub fn from_u8(v: u8) -> Result<Dtype> {
        match v {
            0 => Ok(Dtype::F32),
            1 => Ok(Dtype::F64),
            _ => Err(anyhow!("unknown dtype tag {v}")),
        }
    }

    /// Bytes per element (wire + HH-RAM sizing).
    pub fn size_of(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
        }
    }

    /// Every dtype the stack instantiates (test-matrix helper).
    pub fn all() -> [Dtype; 2] {
        [Dtype::F32, Dtype::F64]
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Dtype::F32 => write!(f, "f32"),
            Dtype::F64 => write!(f, "f64"),
        }
    }
}

/// A [`Real`] scalar the descriptor core can dispatch: it knows its dtype
/// tag and how a packed gemm micro-panel of it crosses the service
/// boundary (f32 → the sgemm path, f64 → the paper's false dgemm).
pub trait Element: Real {
    /// The dtype tag of this element type.
    const DTYPE: Dtype;

    /// One µ-kernel call through the resident service (HH-RAM IPC
    /// included) for this precision.
    fn service_gemm(
        svc: &ServiceHandle,
        alpha: Self,
        a_panel: &[Self],
        b_panel: &[Self],
        beta: Self,
        c_in: &[Self],
        params: ProjectionParams,
    ) -> Result<(Vec<Self>, ServiceResponse)>;
}

impl Element for f32 {
    const DTYPE: Dtype = Dtype::F32;

    fn service_gemm(
        svc: &ServiceHandle,
        alpha: f32,
        a_panel: &[f32],
        b_panel: &[f32],
        beta: f32,
        c_in: &[f32],
        params: ProjectionParams,
    ) -> Result<(Vec<f32>, ServiceResponse)> {
        svc.sgemm(alpha, a_panel, b_panel, beta, c_in, params)
    }
}

impl Element for f64 {
    const DTYPE: Dtype = Dtype::F64;

    fn service_gemm(
        svc: &ServiceHandle,
        alpha: f64,
        a_panel: &[f64],
        b_panel: &[f64],
        beta: f64,
        c_in: &[f64],
        params: ProjectionParams,
    ) -> Result<(Vec<f64>, ServiceResponse)> {
        svc.false_dgemm(alpha, a_panel, b_panel, beta, c_in, params)
    }
}

/// Where an operation executes — the paper's split: only the gemm
/// µ-kernel is Epiphany-accelerated, everything else is host compute
/// (§4.3 blames exactly this split for the HPL ceiling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Through the resident service to the (simulated) Epiphany chip.
    Epiphany,
    /// Host CPU, charged to the projection ledger at the host rate.
    Host,
}

/// One BLAS operation as a value. `run` performs the computation;
/// [`Blas::execute`] is the public entry that adds routing-aware stats
/// accounting around it. Implementations validate their own descriptor
/// (dims, strides, slice lengths) with recoverable errors — this is the
/// error-reporting path the classic shims lack.
pub trait BlasOp {
    /// What the operation yields: a [`GemmReport`] for Epiphany-routed
    /// gemms, `()` for in-place host ops, a [`Level1Out`] for reductions.
    type Output;

    /// Service routing class for this op.
    fn route(&self) -> Route;

    /// Logical flop count (the stats ledger's unit).
    fn flops(&self) -> f64;

    /// Validate and compute. Called by [`Blas::execute`]; prefer that
    /// entry point — it owns the accounting.
    fn run(self, blas: &Blas) -> Result<Self::Output>;
}

/// Required stored length of a strided vector of `n` logical elements —
/// the classic BLAS `(n−1)·inc + 1`. Shared by descriptor validation and
/// the coordinator's wire-payload sizing.
pub fn strided_len(n: usize, inc: usize) -> usize {
    if n == 0 {
        0
    } else {
        (n - 1) * inc + 1
    }
}

fn check_vec<T: Real>(name: &str, v: &[T], n: usize, inc: usize) -> Result<()> {
    ensure!(inc >= 1, "{name}: stride must be >= 1, got {inc}");
    ensure!(
        v.len() >= strided_len(n, inc),
        "{name}: stored length {} < required {} (n={n}, inc={inc})",
        v.len(),
        strided_len(n, inc)
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Level 3: gemm (the accelerated op)
// ---------------------------------------------------------------------------

/// `C ← α·op(A)·op(B) + β·C`, routed through the Epiphany service.
///
/// The only descriptor whose route is [`Route::Epiphany`]; its per-tile
/// timing is merged into [`crate::blis::gemm::BlasStats::gemm`] by the
/// tiled driver itself (wall + projected seconds per µ-kernel call).
pub struct GemmOp<'a, T: Element> {
    /// Transpose flag for A.
    pub ta: Trans,
    /// Transpose flag for B.
    pub tb: Trans,
    /// Scale on the product.
    pub alpha: T,
    /// A operand (stored orientation; `ta` applies the op).
    pub a: MatRef<'a, T>,
    /// B operand (stored orientation; `tb` applies the op).
    pub b: MatRef<'a, T>,
    /// Scale on the C input.
    pub beta: T,
    /// C, updated in place.
    pub c: MatMut<'a, T>,
}

impl<T: Element> BlasOp for GemmOp<'_, T> {
    type Output = GemmReport;

    fn route(&self) -> Route {
        Route::Epiphany
    }

    fn flops(&self) -> f64 {
        let k = if self.ta.is_trans() { self.a.rows() } else { self.a.cols() };
        2.0 * self.c.rows() as f64 * self.c.cols() as f64 * k as f64
    }

    fn run(mut self, blas: &Blas) -> Result<GemmReport> {
        blas.gemm_view(self.ta, self.tb, self.alpha, self.a, self.b, self.beta, &mut self.c)
    }
}

/// Owned variant of [`GemmOp`] for asynchronous submission: the operands
/// are owned matrices, so the descriptor is `Send + 'static` and can ride
/// a [`Ticket`]. `wait()` hands C back along with the tile report.
pub struct GemmTask<T: Element> {
    /// Transpose flag for A.
    pub ta: Trans,
    /// Transpose flag for B.
    pub tb: Trans,
    /// Scale on the product.
    pub alpha: T,
    /// Owned A operand (stored orientation; `ta` applies the op).
    pub a: Mat<T>,
    /// Owned B operand (stored orientation; `tb` applies the op).
    pub b: Mat<T>,
    /// Scale on the C input.
    pub beta: T,
    /// Owned C; handed back by [`Ticket::wait`].
    pub c: Mat<T>,
}

impl<T: Element> BlasOp for GemmTask<T> {
    type Output = (Mat<T>, GemmReport);

    fn route(&self) -> Route {
        Route::Epiphany
    }

    fn flops(&self) -> f64 {
        let k = if self.ta.is_trans() { self.a.rows() } else { self.a.cols() };
        2.0 * self.c.rows() as f64 * self.c.cols() as f64 * k as f64
    }

    fn run(mut self, blas: &Blas) -> Result<(Mat<T>, GemmReport)> {
        let (a, b) = (self.a.view(), self.b.view());
        let mut view = self.c.view_mut();
        let report = blas.gemm_view(self.ta, self.tb, self.alpha, a, b, self.beta, &mut view)?;
        drop(view);
        Ok((self.c, report))
    }
}

// ---------------------------------------------------------------------------
// Level 3: host-side ops (trsm, syrk)
// ---------------------------------------------------------------------------

/// `B ← α·op(A)⁻¹·B` for triangular A (left side), host compute.
pub struct TrsmOp<'a, T: Real> {
    /// Whether A's stored triangle is the lower one.
    pub lower: bool,
    /// Transpose flag for A.
    pub trans: Trans,
    /// Whether A's diagonal is implicitly 1 (not stored).
    pub unit: bool,
    /// Scale applied to B before the solve.
    pub alpha: T,
    /// The triangular A operand.
    pub a: MatRef<'a, T>,
    /// Right-hand sides, overwritten with the solution.
    pub b: &'a mut Mat<T>,
}

impl<T: Real> BlasOp for TrsmOp<'_, T> {
    type Output = ();

    fn route(&self) -> Route {
        Route::Host
    }

    fn flops(&self) -> f64 {
        (self.a.rows() * self.a.rows() * self.b.cols()) as f64
    }

    fn run(self, _blas: &Blas) -> Result<()> {
        let m = self.a.rows();
        ensure!(self.a.cols() == m, "trsm: A must be square, got {m}x{}", self.a.cols());
        ensure!(self.b.rows() == m, "trsm: B rows {} != A order {m}", self.b.rows());
        level3::trsm_left(self.lower, self.trans, self.unit, self.alpha, self.a, self.b);
        Ok(())
    }
}

/// `C ← α·op(A)·op(A)ᵀ + β·C`, lower triangle of C updated, host compute.
pub struct SyrkOp<'a, T: Real> {
    /// `N`: `C ← α·A·Aᵀ + β·C`; transposed: `C ← α·Aᵀ·A + β·C`.
    pub trans: Trans,
    /// Scale on the rank-k product.
    pub alpha: T,
    /// The A operand.
    pub a: MatRef<'a, T>,
    /// Scale on the C input.
    pub beta: T,
    /// C, lower triangle updated in place.
    pub c: &'a mut Mat<T>,
}

impl<T: Real> BlasOp for SyrkOp<'_, T> {
    type Output = ();

    fn route(&self) -> Route {
        Route::Host
    }

    fn flops(&self) -> f64 {
        let (n, k) = if self.trans.is_trans() {
            (self.a.cols(), self.a.rows())
        } else {
            (self.a.rows(), self.a.cols())
        };
        (n * n * k) as f64
    }

    fn run(self, _blas: &Blas) -> Result<()> {
        let n = if self.trans.is_trans() { self.a.cols() } else { self.a.rows() };
        ensure!(
            self.c.rows() == n && self.c.cols() == n,
            "syrk: C must be {n}x{n}, got {}x{}",
            self.c.rows(),
            self.c.cols()
        );
        level3::syrk_lower(self.trans, self.alpha, self.a, self.beta, self.c);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Level 2 (host compute)
// ---------------------------------------------------------------------------

/// `y ← α·op(A)·x + β·y` with classic BLAS vector strides.
pub struct GemvOp<'a, T: Real> {
    /// Transpose flag for A.
    pub trans: Trans,
    /// Scale on the product.
    pub alpha: T,
    /// The A operand (stored orientation; `trans` applies the op).
    pub a: MatRef<'a, T>,
    /// Input vector, read at stride `incx`.
    pub x: &'a [T],
    /// Stride of `x` (classic BLAS `INCX`, >= 1).
    pub incx: usize,
    /// Scale on the y input.
    pub beta: T,
    /// Output vector, updated in place at stride `incy`.
    pub y: &'a mut [T],
    /// Stride of `y` (classic BLAS `INCY`, >= 1).
    pub incy: usize,
}

impl<T: Real> BlasOp for GemvOp<'_, T> {
    type Output = ();

    fn route(&self) -> Route {
        Route::Host
    }

    fn flops(&self) -> f64 {
        2.0 * self.a.rows() as f64 * self.a.cols() as f64
    }

    fn run(self, _blas: &Blas) -> Result<()> {
        let (m, n) = if self.trans.is_trans() {
            (self.a.cols(), self.a.rows())
        } else {
            (self.a.rows(), self.a.cols())
        };
        check_vec("gemv x", self.x, n, self.incx)?;
        check_vec("gemv y", self.y, m, self.incy)?;
        level2::gemv(self.trans, self.alpha, self.a, self.x, self.incx, self.beta, self.y,
            self.incy);
        Ok(())
    }
}

/// `A ← α·x·yᵀ + A` (rank-1 update), host compute.
pub struct GerOp<'a, T: Real> {
    /// Scale on the outer product.
    pub alpha: T,
    /// Column vector (length = rows of A).
    pub x: &'a [T],
    /// Row vector (length = cols of A).
    pub y: &'a [T],
    /// A, updated in place.
    pub a: MatMut<'a, T>,
}

impl<T: Real> BlasOp for GerOp<'_, T> {
    type Output = ();

    fn route(&self) -> Route {
        Route::Host
    }

    fn flops(&self) -> f64 {
        2.0 * self.a.rows() as f64 * self.a.cols() as f64
    }

    fn run(mut self, _blas: &Blas) -> Result<()> {
        let (m, n) = (self.a.rows(), self.a.cols());
        check_vec("ger x", self.x, m, 1)?;
        check_vec("ger y", self.y, n, 1)?;
        level2::ger(self.alpha, self.x, self.y, &mut self.a);
        Ok(())
    }
}

/// `x ← op(A)·x` for triangular A, host compute.
pub struct TrmvOp<'a, T: Real> {
    /// Whether A's stored triangle is the lower one.
    pub lower: bool,
    /// Transpose flag for A.
    pub trans: Trans,
    /// Whether A's diagonal is implicitly 1 (not stored).
    pub unit: bool,
    /// The triangular A operand.
    pub a: MatRef<'a, T>,
    /// Vector, overwritten with `op(A)·x`.
    pub x: &'a mut [T],
}

impl<T: Real> BlasOp for TrmvOp<'_, T> {
    type Output = ();

    fn route(&self) -> Route {
        Route::Host
    }

    fn flops(&self) -> f64 {
        (self.a.rows() * self.a.rows()) as f64
    }

    fn run(self, _blas: &Blas) -> Result<()> {
        let n = self.a.rows();
        ensure!(self.a.cols() == n, "trmv: A must be square");
        check_vec("trmv x", self.x, n, 1)?;
        level2::trmv(self.lower, self.trans, self.unit, self.a, self.x);
        Ok(())
    }
}

/// Solve `op(A)·x = b` in place for triangular A, host compute.
pub struct TrsvOp<'a, T: Real> {
    /// Whether A's stored triangle is the lower one.
    pub lower: bool,
    /// Transpose flag for A.
    pub trans: Trans,
    /// Whether A's diagonal is implicitly 1 (not stored).
    pub unit: bool,
    /// The triangular A operand.
    pub a: MatRef<'a, T>,
    /// Right-hand side, overwritten with the solution.
    pub x: &'a mut [T],
}

impl<T: Real> BlasOp for TrsvOp<'_, T> {
    type Output = ();

    fn route(&self) -> Route {
        Route::Host
    }

    fn flops(&self) -> f64 {
        (self.a.rows() * self.a.rows()) as f64
    }

    fn run(self, _blas: &Blas) -> Result<()> {
        let n = self.a.rows();
        ensure!(self.a.cols() == n, "trsv: A must be square");
        check_vec("trsv x", self.x, n, 1)?;
        level2::trsv(self.lower, self.trans, self.unit, self.a, self.x);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Level 1 (host compute)
// ---------------------------------------------------------------------------

/// One level-1 (vector-vector) operation over strided vectors.
///
/// Field conventions are the classic BLAS ones throughout: `n` is the
/// logical element count, `incx`/`incy` the strides (>= 1) of `x`/`y`.
#[allow(missing_docs)] // fields are the classic BLAS n/alpha/x/incx/y/incy
pub enum Level1Op<'a, T: Real> {
    /// `y ← αx + y`
    Axpy { n: usize, alpha: T, x: &'a [T], incx: usize, y: &'a mut [T], incy: usize },
    /// `x ← αx`
    Scal { n: usize, alpha: T, x: &'a mut [T], incx: usize },
    /// `y ← x`
    Copy { n: usize, x: &'a [T], incx: usize, y: &'a mut [T], incy: usize },
    /// `x ↔ y`
    Swap { n: usize, x: &'a mut [T], incx: usize, y: &'a mut [T], incy: usize },
    /// `xᵀy`
    Dot { n: usize, x: &'a [T], incx: usize, y: &'a [T], incy: usize },
    /// `‖x‖₂`
    Nrm2 { n: usize, x: &'a [T], incx: usize },
    /// `Σ|xᵢ|`
    Asum { n: usize, x: &'a [T], incx: usize },
    /// `argmax |xᵢ|`
    Iamax { n: usize, x: &'a [T], incx: usize },
    /// Givens rotation `(x, y) ← (c·x + s·y, c·y − s·x)`; `c`/`s` are the
    /// rotation's cosine and sine.
    Rot { n: usize, x: &'a mut [T], incx: usize, y: &'a mut [T], incy: usize, c: T, s: T },
}

/// Result of a [`Level1Op`]: either nothing (in-place update), a scalar
/// reduction, or an index (iamax).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Level1Out<T> {
    /// In-place update finished (axpy, scal, copy, swap, rot).
    Done,
    /// A scalar reduction (dot, nrm2, asum).
    Scalar(T),
    /// An index result (iamax; `None` on an empty vector).
    Index(Option<usize>),
}

impl<T: Real> Level1Out<T> {
    /// Unwrap a scalar reduction (dot, nrm2, asum).
    pub fn scalar(self) -> T {
        match self {
            Level1Out::Scalar(v) => v,
            other => panic!("level-1 output is not a scalar: {other:?}"),
        }
    }

    /// Unwrap an index result (iamax).
    pub fn index(self) -> Option<usize> {
        match self {
            Level1Out::Index(i) => i,
            other => panic!("level-1 output is not an index: {other:?}"),
        }
    }
}

impl<T: Real> BlasOp for Level1Op<'_, T> {
    type Output = Level1Out<T>;

    fn route(&self) -> Route {
        Route::Host
    }

    fn flops(&self) -> f64 {
        match self {
            Level1Op::Axpy { n, .. } | Level1Op::Dot { n, .. } | Level1Op::Nrm2 { n, .. } => {
                2.0 * *n as f64
            }
            Level1Op::Scal { n, .. } | Level1Op::Asum { n, .. } => *n as f64,
            Level1Op::Rot { n, .. } => 6.0 * *n as f64,
            Level1Op::Copy { .. } | Level1Op::Swap { .. } | Level1Op::Iamax { .. } => 0.0,
        }
    }

    fn run(self, _blas: &Blas) -> Result<Level1Out<T>> {
        Ok(match self {
            Level1Op::Axpy { n, alpha, x, incx, y, incy } => {
                check_vec("axpy x", x, n, incx)?;
                check_vec("axpy y", y, n, incy)?;
                level1::axpy(n, alpha, x, incx, y, incy);
                Level1Out::Done
            }
            Level1Op::Scal { n, alpha, x, incx } => {
                check_vec("scal x", x, n, incx)?;
                level1::scal(n, alpha, x, incx);
                Level1Out::Done
            }
            Level1Op::Copy { n, x, incx, y, incy } => {
                check_vec("copy x", x, n, incx)?;
                check_vec("copy y", y, n, incy)?;
                level1::copy(n, x, incx, y, incy);
                Level1Out::Done
            }
            Level1Op::Swap { n, x, incx, y, incy } => {
                check_vec("swap x", x, n, incx)?;
                check_vec("swap y", y, n, incy)?;
                level1::swap(n, x, incx, y, incy);
                Level1Out::Done
            }
            Level1Op::Dot { n, x, incx, y, incy } => {
                check_vec("dot x", x, n, incx)?;
                check_vec("dot y", y, n, incy)?;
                Level1Out::Scalar(level1::dot(n, x, incx, y, incy))
            }
            Level1Op::Nrm2 { n, x, incx } => {
                check_vec("nrm2 x", x, n, incx)?;
                Level1Out::Scalar(level1::nrm2(n, x, incx))
            }
            Level1Op::Asum { n, x, incx } => {
                check_vec("asum x", x, n, incx)?;
                Level1Out::Scalar(level1::asum(n, x, incx))
            }
            Level1Op::Iamax { n, x, incx } => {
                check_vec("iamax x", x, n, incx)?;
                Level1Out::Index(level1::iamax(n, x, incx))
            }
            Level1Op::Rot { n, x, incx, y, incy, c, s } => {
                check_vec("rot x", x, n, incx)?;
                check_vec("rot y", y, n, incy)?;
                level1::rot(n, x, incx, y, incy, c, s);
                Level1Out::Done
            }
        })
    }
}

// ---------------------------------------------------------------------------
// Async submission
// ---------------------------------------------------------------------------

/// Handle to an in-flight submitted operation (see [`Blas::submit`]).
///
/// The computation runs on a submission thread; the HH-RAM exchange with
/// the service serializes per µ-kernel call, so two in-flight gemms
/// interleave their packing with each other's service crossings.
pub struct Ticket<T> {
    rx: mpsc::Receiver<Result<T>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl<T> Ticket<T> {
    pub(crate) fn new(rx: mpsc::Receiver<Result<T>>, join: std::thread::JoinHandle<()>) -> Self {
        Ticket { rx, join: Some(join) }
    }

    /// Block until the submitted op completes and return its output.
    pub fn wait(mut self) -> Result<T> {
        let out = self.rx.recv().map_err(|_| anyhow!("submission worker died before replying"));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        out?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::max_scaled_err;
    use std::sync::Arc;

    fn blas() -> Arc<Blas> {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        Arc::new(Blas::new(svc))
    }

    #[test]
    fn dtype_round_trip() {
        for d in Dtype::all() {
            assert_eq!(Dtype::from_u8(d.code()).unwrap(), d);
        }
        assert!(Dtype::from_u8(9).is_err());
        assert_eq!((Dtype::F32.size_of(), Dtype::F64.size_of()), (4, 8));
    }

    #[test]
    fn execute_routes_and_accounts() {
        let blas = blas();
        // Host-routed level-1 op charges the host ledger.
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![0.0f32; 3];
        let out = blas
            .execute(Level1Op::Axpy { n: 3, alpha: 2.0, x: &x, incx: 1, y: &mut y, incy: 1 })
            .unwrap();
        assert_eq!(out, Level1Out::Done);
        assert_eq!(y, vec![2.0, 4.0, 6.0]);
        let stats = blas.stats_snapshot();
        assert!(stats.host_level12_flops >= 6.0);
        assert_eq!(stats.gemm.calls, 0);

        // Epiphany-routed gemm feeds the gemm report, not the host ledger.
        let a = Mat::<f32>::randn(32, 16, 1);
        let b = Mat::<f32>::randn(16, 24, 2);
        let mut c = Mat::<f32>::zeros(32, 24);
        let rep = blas
            .execute(GemmOp {
                ta: Trans::N,
                tb: Trans::N,
                alpha: 1.0,
                a: a.view(),
                b: b.view(),
                beta: 0.0,
                c: c.view_mut(),
            })
            .unwrap();
        assert!(rep.calls >= 1 && rep.projected_s > 0.0);
        let stats = blas.stats_snapshot();
        assert_eq!(stats.gemm.calls, rep.calls);
    }

    #[test]
    fn invalid_descriptor_is_err_not_panic() {
        let blas = blas();
        let x = vec![1.0f32; 2];
        let mut y = vec![0.0f32; 8];
        // x too short for n=5.
        let r = blas
            .execute(Level1Op::Axpy { n: 5, alpha: 1.0, x: &x, incx: 1, y: &mut y, incy: 1 });
        assert!(r.is_err());
        // zero stride rejected.
        let r = blas.execute(Level1Op::Nrm2 { n: 2, x: &x, incx: 0 });
        assert!(r.is_err());
        // gemm K mismatch.
        let a = Mat::<f32>::randn(8, 4, 1);
        let b = Mat::<f32>::randn(5, 8, 2);
        let mut c = Mat::<f32>::zeros(8, 8);
        let r = blas.execute(GemmOp {
            ta: Trans::N,
            tb: Trans::N,
            alpha: 1.0,
            a: a.view(),
            b: b.view(),
            beta: 0.0,
            c: c.view_mut(),
        });
        assert!(r.is_err());
    }

    #[test]
    fn submit_ticket_round_trip() {
        let blas = blas();
        let (m, n, k) = (64, 48, 32);
        let a = Mat::<f32>::randn(m, k, 5);
        let b = Mat::<f32>::randn(k, n, 6);
        let task = GemmTask {
            ta: Trans::N,
            tb: Trans::N,
            alpha: 1.0,
            a: a.clone(),
            b: b.clone(),
            beta: 0.0,
            c: Mat::<f32>::zeros(m, n),
        };
        let ticket = Arc::clone(&blas).submit(task);
        let (c, rep) = ticket.wait().unwrap();
        assert!(rep.calls >= 1);
        let mut want = Mat::<f64>::zeros(m, n);
        level3::gemm_host(
            Trans::N,
            Trans::N,
            1.0,
            a.cast::<f64>().view(),
            b.cast::<f64>().view(),
            0.0,
            &mut want,
        );
        assert!(max_scaled_err(c.view(), want.view()) < 1e-5);
    }
}
