//! Functional + timing simulator of the Epiphany-16 coprocessor and its
//! Parallella-side interconnect (e-link, shared DRAM window).
//!
//! The paper's evaluation ran on real silicon we do not have (repro band
//! 0/5), so this module substitutes a simulator that is:
//!
//! * **functionally exact** — the sgemm Epiphany kernel ([`kernel`]) executes
//!   the paper's actual dataflow (Epiphany Task → Column Iteration →
//!   K Iteration → inter-core pipeline → `subMatmul`/`doMult`) on real `f32`
//!   values moving through per-core 32 KB local memories, so numerics
//!   (accumulation order, rounding) match a faithful C port; and
//! * **timing-calibrated** — every byte moved and cycle burned is accounted
//!   by [`timing::CalibratedModel`], whose constants are back-derived from
//!   the paper's Tables 1–2 (see DESIGN.md §6). The simulator therefore
//!   reports *projected Parallella seconds* next to host wall-clock.
//!
//! Hardware parameters (Epiphany-16 / Parallella-16):
//! 4×4 eCore mesh @ 600 MHz, 1 FMADD/cycle/core (19.2 GFLOPS f32 peak),
//! 32 KB local memory per core in four 8 KB banks, eMesh NoC with
//! single-cycle neighbour stores, 32 MB host↔chip shared DRAM (HC-RAM)
//! reached through the Zynq FPGA e-link.
//!
//! A [`crate::host::pool::ChipPool`] can boot many of these chips side
//! by side, each behind its own service loop; how the stack shards work
//! across them is drawn in `docs/ARCHITECTURE.md`.

pub mod barrier;
pub mod chip;
pub mod dma;
pub mod kernel;
pub mod memory;
pub mod mesh;
pub mod submatmul;
pub mod timing;

/// Number of eCores on the Epiphany-16 (the paper's `CORES`).
pub const CORES: usize = 16;
/// Mesh rows (4×4 grid).
pub const MESH_ROWS: usize = 4;
/// Mesh columns (4×4 grid).
pub const MESH_COLS: usize = 4;
/// Core clock (Parallella-16: 600 MHz).
pub const CORE_HZ: f64 = 600.0e6;
/// Local memory per core (32 KB in four 8 KB banks).
pub const LOCAL_MEM_BYTES: usize = 32 * 1024;
/// One local-memory bank (8 KB; bank conflicts are the §3.4 concern).
pub const BANK_BYTES: usize = 8 * 1024;
/// Shared DRAM window visible to both host and chip (HC-RAM).
pub const HCRAM_BYTES: usize = 32 * 1024 * 1024;
/// f32 peak: 16 cores × 600 MHz × 2 flops (FMADD).
pub const PEAK_GFLOPS: f64 = 19.2;

pub use chip::{Chip, SimStats};
pub use kernel::{Command, KernelGeometry, TaskInputs};
pub use timing::CalibratedModel;
