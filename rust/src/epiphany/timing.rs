//! The calibrated Parallella performance model.
//!
//! Absolute times on a board we do not have cannot be *measured*, so they
//! are *projected* through this model. Every constant is back-derived from
//! a specific number in the paper (cited next to each field); the benches
//! then check that composing the model reproduces the paper's tables — a
//! consistency loop, but the model is also used far outside its calibration
//! points (other shapes, stride classes, ablations, HPL), which is where it
//! earns its keep.
//!
//! Derivations (summarized; full arithmetic in DESIGN.md §6):
//!
//! * Table 1 input row: 64 tasks × 112 KiB panels in 0.094648 s ⇒ host
//!   upload (incl. preprocessing) ≈ 77.5 MB/s.
//! * Table 1 coprocessor row: 0.105652 s ⇒ per task 1.651 ms; the compute
//!   part from the cycle model is 0.426 ms ⇒ HC-RAM→local DMA ≈ 93.6 MB/s.
//! * Table 1 post row: 0.005272 s for reading 192 KiB + the α/β epilogue ⇒
//!   host HC-RAM read ≈ 41 MB/s (the "very slow e_read" of §5.2).
//! * Table 2 − Table 1: 44.19 ms of HH-RAM IPC for ~15.05 MB moved ⇒
//!   ≈ 340 MB/s per direction.
//! * Table 4 nn/nt/tn/tt spread ⇒ strided-walk upload penalties.
//! * Table 7 ⇒ unaccelerated host f64 level-2 / trsm rates.

use super::{CORE_HZ, CORES};

/// All calibration constants in one place.
#[derive(Clone, Debug)]
pub struct CalibratedModel {
    // ---- chip-side cycle model -------------------------------------------------
    /// Core clock in Hz (600 MHz on Parallella-16).
    pub core_hz: f64,
    /// FMA issue cycles per `doMult` (scalar × 32-vector): 32 MACs.
    pub domult_fma_cycles: u64,
    /// Per-`doMult` setup overhead (register staging).
    pub domult_setup_cycles: u64,
    /// Loop overhead per 32-row inner block (6 per 192-row column).
    pub inner_loop_cycles: u64,
    /// Per-output-column overhead in `subMatmul`.
    pub col_loop_cycles: u64,
    /// `subMatmul` prologue/epilogue.
    pub submatmul_prologue_cycles: u64,
    /// Cost of one mesh-wide barrier (two per K Iteration).
    pub barrier_cycles: u64,
    /// Per-task control overhead (command/selector poll, start signal).
    pub task_overhead_cycles: u64,

    // ---- interconnect ----------------------------------------------------------
    /// Host → HC-RAM effective write bandwidth for contiguous walks,
    /// including host-side preprocessing (Table 1 input row). B/s.
    pub w_host_write: f64,
    /// Penalized upload rate when the A operand walk is strided
    /// (transposed A; calibrated to Table 4 `tn`/`tt`). B/s.
    pub w_host_write_strided_a: f64,
    /// Penalized upload rate when the B operand walk is strided
    /// (non-transposed B needs a row-major panel; Table 4 `nn` vs `nt`). B/s.
    pub w_host_write_strided_b: f64,
    /// HC-RAM → core local DMA over the e-link (Table 1 coproc row). B/s.
    pub w_chip_dma: f64,
    /// Core local → HC-RAM write (e-link writes are fast). B/s.
    pub w_chip_write: f64,
    /// Host read from HC-RAM (§5.2's slow `e_read` path). B/s.
    pub w_host_read: f64,

    // ---- host-side rates --------------------------------------------------------
    /// Naive triple-loop host sgemm ("Host reference code", Table 1).
    pub host_ref_gflops: f64,
    /// Streaming host flops (axpby epilogue and friends).
    pub host_stream_gflops: f64,
    /// HH-RAM (POSIX shm) copy bandwidth, each direction (Table 2 − Table 1).
    pub hh_ram_bw: f64,
    /// Semaphore round-trip cost, applied 4× per service call.
    pub ipc_signal_s: f64,
    /// f64→f32/f32→f64 cast pass (false dgemm), elements/s.
    pub cast_elems_per_s: f64,
    /// BLIS per-µ-kernel-call overhead (C-tile β scaling, loop bookkeeping).
    pub blis_call_overhead_s: f64,
    /// Unaccelerated host f64 level-2 rate (HPL panel factorization;
    /// calibrated to Table 7).
    pub host_level2_f64_gflops: f64,
    /// Unaccelerated host f64 trsm rate (calibrated to Table 7).
    pub host_trsm_f64_gflops: f64,
}

impl Default for CalibratedModel {
    fn default() -> Self {
        CalibratedModel {
            core_hz: CORE_HZ,
            domult_fma_cycles: 32,
            domult_setup_cycles: 2,
            inner_loop_cycles: 8,
            col_loop_cycles: 16,
            submatmul_prologue_cycles: 64,
            barrier_cycles: 200,
            task_overhead_cycles: 500,
            w_host_write: 77.55e6,
            w_host_write_strided_a: 44.0e6,
            w_host_write_strided_b: 58.9e6,
            w_chip_dma: 93.62e6,
            w_chip_write: 600.0e6,
            w_host_read: 41.0e6,
            host_ref_gflops: 0.107,
            host_stream_gflops: 0.30,
            hh_ram_bw: 340.0e6,
            ipc_signal_s: 50.0e-6,
            cast_elems_per_s: 105.0e6,
            blis_call_overhead_s: 6.0e-3,
            host_level2_f64_gflops: 0.175,
            host_trsm_f64_gflops: 0.165,
        }
    }
}

/// Stride class of a host upload walk, as seen by the µ-kernel's
/// input-loading stage (paper §3.3: strides are arbitrary inputs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WalkClass {
    /// Unit-stride source (memcpy-like).
    Contig,
    /// Strided A walk (transposed A).
    StridedA,
    /// Strided B walk (non-transposed B feeding a row-major panel).
    StridedB,
}

impl CalibratedModel {
    /// Cycles of one `subMatmul` call over an `m_rows × nsub` output with
    /// `k_depth` accumulation depth (the assembly version is fixed at
    /// 192×4×4 but the model generalizes for ablations).
    pub fn submatmul_cycles(&self, m_rows: usize, nsub: usize, k_depth: usize) -> u64 {
        let blocks_per_col = (m_rows as u64).div_ceil(32);
        let per_block = k_depth as u64 * (self.domult_fma_cycles + self.domult_setup_cycles)
            + self.inner_loop_cycles;
        let per_col = blocks_per_col * per_block + self.col_loop_cycles;
        nsub as u64 * per_col + self.submatmul_prologue_cycles
    }

    /// On-chip efficiency of the subMatmul micro-shape vs 1-FMA/cycle peak.
    /// The paper's lineage (Varghese et al.) is ~85%; the default constants
    /// give 0.857 for 192×4×4.
    pub fn submatmul_efficiency(&self, m_rows: usize, nsub: usize, k_depth: usize) -> f64 {
        let macs = (m_rows * nsub * k_depth) as f64;
        macs / self.submatmul_cycles(m_rows, nsub, k_depth) as f64
    }

    /// Chip compute time for one Epiphany Task (all cores lock-step):
    /// `col_iters × k_iters × (subMatmul + 2 barriers)` plus task overhead.
    pub fn task_compute_s(
        &self,
        m_rows: usize,
        nsub: usize,
        k_depth: usize,
        col_iters: usize,
        k_iters: usize,
    ) -> f64 {
        let per_k_iter = self.submatmul_cycles(m_rows, nsub, k_depth) + 2 * self.barrier_cycles;
        let cycles = (col_iters * k_iters) as u64 * per_k_iter + self.task_overhead_cycles;
        cycles as f64 / self.core_hz
    }

    /// Host upload seconds for a panel of `bytes` with the given walk class.
    pub fn upload_s(&self, bytes: usize, class: WalkClass) -> f64 {
        let bw = match class {
            WalkClass::Contig => self.w_host_write,
            WalkClass::StridedA => self.w_host_write_strided_a,
            WalkClass::StridedB => self.w_host_write_strided_b,
        };
        bytes as f64 / bw
    }

    /// Chip-side per-task time: DMA-in of the two panels plus compute.
    /// (The double buffering in HC-RAM overlaps *host upload* with this,
    /// not the DMA with compute — that matches Table 1's 82.9% / 92.6%
    /// split; see DESIGN.md §6.)
    pub fn task_coproc_s(&self, in_bytes: usize, compute_s: f64) -> f64 {
        in_bytes as f64 / self.w_chip_dma + compute_s
    }

    /// Peak of the simulated chip, for efficiency ratios.
    pub fn peak_gflops(&self) -> f64 {
        // 2 flops per FMA per core per cycle.
        2.0 * CORES as f64 * self.core_hz / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::PEAK_GFLOPS;
    use crate::host::projection::{project_ukr_call, ProjectionParams};
    use crate::util::proptest::{forall, Config};

    #[test]
    fn peak_is_19_2() {
        let m = CalibratedModel::default();
        assert!((m.peak_gflops() - PEAK_GFLOPS).abs() < 1e-9);
    }

    #[test]
    fn submatmul_matches_varghese_efficiency() {
        // The assembly subMatmul lineage is ~85% of on-chip peak.
        let m = CalibratedModel::default();
        let eff = m.submatmul_efficiency(192, 4, 4);
        assert!((0.84..0.87).contains(&eff), "eff = {eff}");
    }

    #[test]
    fn submatmul_cycle_arithmetic() {
        let m = CalibratedModel::default();
        // 6 blocks × (4×34 + 8) + 16 = 880 per col; ×4 cols + 64 = 3584.
        assert_eq!(m.submatmul_cycles(192, 4, 4), 3584);
    }

    #[test]
    fn task_compute_near_calibration() {
        // Table 1 derivation: 4 col iters × 16 k iters ⇒ 0.426 ms/task.
        let m = CalibratedModel::default();
        let t = m.task_compute_s(192, 4, 4, 4, 16);
        assert!((t - 0.426e-3).abs() < 0.01e-3, "t = {t}");
    }

    #[test]
    fn coproc_per_task_matches_table1() {
        // Table 1: 0.105652 s / 64 tasks = 1.651 ms per task.
        let m = CalibratedModel::default();
        let compute = m.task_compute_s(192, 4, 4, 4, 16);
        let per_task = m.task_coproc_s(112 * 1024, compute);
        assert!((per_task - 1.651e-3).abs() < 0.02e-3, "per_task = {per_task}");
    }

    #[test]
    fn upload_per_task_matches_table1() {
        // Table 1: 0.094648 s / 64 tasks = 1.479 ms per task for 112 KiB.
        let m = CalibratedModel::default();
        let t = m.upload_s(112 * 1024, WalkClass::Contig);
        assert!((t - 1.479e-3).abs() < 0.02e-3, "t = {t}");
    }

    // ---- property tests (crate-local mini-proptest; no external deps) ----

    #[test]
    fn prop_predicted_time_monotone_in_bytes_moved() {
        // More bytes through any channel can never be predicted faster.
        let m = CalibratedModel::default();
        forall(
            Config::default(),
            |rng| (rng.next_below(1 << 22), rng.next_below(1 << 22)),
            |&(x, y)| {
                let (lo, hi) = (x.min(y), x.max(y));
                let upload_monotone = [WalkClass::Contig, WalkClass::StridedA, WalkClass::StridedB]
                    .iter()
                    .all(|&w| m.upload_s(lo, w) <= m.upload_s(hi, w));
                upload_monotone && m.task_coproc_s(lo, 0.0) <= m.task_coproc_s(hi, 0.0)
            },
        );
    }

    #[test]
    fn prop_submatmul_efficiency_strictly_below_one() {
        // Per-doMult setup + loop overheads mean the model can never claim
        // more than 1 MAC/cycle/core — the physical issue-rate ceiling.
        forall(
            Config::default(),
            |rng| {
                let m_rows = 32 * (1 + rng.next_below(12));
                let nsub = 1 + rng.next_below(8);
                let k_depth = 1 + rng.next_below(16);
                (m_rows, nsub, k_depth)
            },
            |&(m_rows, nsub, k_depth)| {
                let eff = CalibratedModel::default().submatmul_efficiency(m_rows, nsub, k_depth);
                eff > 0.0 && eff < 1.0
            },
        );
    }

    #[test]
    fn prop_projected_sgemm_gflops_never_exceed_chip_peak() {
        // Whatever the reduction depth, a projected µ-kernel call must not
        // beat the 19.2 GFLOPS chip peak (transfers only slow it down).
        let model = CalibratedModel::default();
        forall(
            Config { cases: 128, ..Config::default() },
            |rng| 1 + rng.next_below(1 << 15),
            |&k| {
                let p = ProjectionParams::kernel_same_process(k);
                let proj = project_ukr_call(&model, &p);
                let gf = proj.gflops(192, 256, k);
                gf > 0.0 && gf < PEAK_GFLOPS
            },
        );
    }

    #[test]
    fn prop_task_compute_monotone_in_iterations() {
        // More Column/K Iterations can only add lock-step cycles.
        let m = CalibratedModel::default();
        forall(
            Config::default(),
            |rng| (1 + rng.next_below(8), 1 + rng.next_below(32)),
            |&(col_iters, k_iters)| {
                let t0 = m.task_compute_s(192, 4, 4, col_iters, k_iters);
                let t1 = m.task_compute_s(192, 4, 4, col_iters + 1, k_iters);
                let t2 = m.task_compute_s(192, 4, 4, col_iters, k_iters + 1);
                t1 > t0 && t2 > t0
            },
        );
    }
}
