//! DMA engine model: HC-RAM ↔ core-local transfers over the e-link.
//!
//! Each eCore has a DMA engine; the kernel uses it to pull its `a_ti-cj`
//! and `b_ti-cj` slices from the shared DRAM window. The e-link is a single
//! shared resource, so the timing model charges aggregate bytes at the
//! calibrated link rate rather than simulating per-channel arbitration
//! (DESIGN.md §6; the paper's numbers do not resolve finer structure).

/// Accounting for all DMA traffic in a run.
#[derive(Clone, Debug, Default)]
pub struct DmaStats {
    /// Bytes moved HC-RAM → local (input panels).
    pub in_bytes: u64,
    /// Bytes moved local → HC-RAM (result write-back).
    pub out_bytes: u64,
    /// Individual transfer descriptors issued.
    pub transfers: u64,
}

impl DmaStats {
    /// Charge one HC-RAM → local transfer of `bytes`.
    pub fn record_in(&mut self, bytes: usize) {
        self.in_bytes += bytes as u64;
        self.transfers += 1;
    }

    /// Charge one local → HC-RAM transfer of `bytes`.
    pub fn record_out(&mut self, bytes: usize) {
        self.out_bytes += bytes as u64;
        self.transfers += 1;
    }

    /// Fold another run's DMA accounting into this one.
    pub fn merge(&mut self, other: &DmaStats) {
        self.in_bytes += other.in_bytes;
        self.out_bytes += other.out_bytes;
        self.transfers += other.transfers;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut d = DmaStats::default();
        d.record_in(1024);
        d.record_in(2048);
        d.record_out(512);
        assert_eq!(d.in_bytes, 3072);
        assert_eq!(d.out_bytes, 512);
        assert_eq!(d.transfers, 3);
    }
}
