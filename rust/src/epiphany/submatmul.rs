//! `subMatmul` — "the single-core version of the Epiphany K Iteration"
//! (paper §3.4.4) — and its `doMult` building block.
//!
//! The assembly original multiplies `a ∈ R^{192×4}` by `b ∈ R^{4×4}` and
//! accumulates into a 192×4 partial, built from a `doMult` macro (one
//! scalar × a 32-float column slice, FMADD per element, dual-issued with
//! the stores of the *previous* result block). This model reproduces:
//!
//! * the exact arithmetic order — per output column, walk the four k-depth
//!   `doMult`s accumulating in "registers" (a 32-slot accumulator), then
//!   commit — so rounding matches a faithful port, and
//! * the cycle accounting of the assembly structure (32 FMA + setup per
//!   doMult, loop overheads per 32-row block / column, prologue), which is
//!   what carries the ~85%-of-peak on-chip lineage into the timing model.

use super::timing::CalibratedModel;

/// One `doMult`: `acc[0..32] += scalar * column[0..32]` using FMA rounding.
#[inline]
fn do_mult(acc: &mut [f32; 32], scalar: f32, column: &[f32]) {
    debug_assert!(column.len() >= 32);
    for r in 0..32 {
        acc[r] = column[r].mul_add(scalar, acc[r]);
    }
}

/// Result of a subMatmul call: cycles burned per the assembly model.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubMatmulStats {
    /// Cycles burned, per the assembly model.
    pub cycles: u64,
    /// Multiply-accumulate operations performed.
    pub macs: u64,
}

/// `c_next[.., 0..nsub] = c_prev[.., 0..nsub] + a @ b`
///
/// * `a`: column-major `m_rows × k_depth` (the core's `a_ti-cj` slice),
///   `m_rows` must be a multiple of 32 (the doMult vector length).
/// * `b`: column-major `k_depth × nsub` sub-block of the core's local B.
/// * `c_prev` / `c_next`: column-major `m_rows × nsub` partial-result
///   buffers — "the previous result and next result pointers are passed as
///   parameters". They may alias in the caller's world; here they are
///   distinct slices (the pipeline always reads one buffer and writes the
///   other, paper §3.4.3).
pub fn submatmul(
    model: &CalibratedModel,
    m_rows: usize,
    k_depth: usize,
    nsub: usize,
    a: &[f32],
    b: &[f32],
    c_prev: &[f32],
    c_next: &mut [f32],
) -> SubMatmulStats {
    assert_eq!(m_rows % 32, 0, "doMult operates on 32-row slices");
    assert!(a.len() >= m_rows * k_depth, "a slice too small");
    assert!(b.len() >= k_depth * nsub, "b slice too small");
    assert!(c_prev.len() >= m_rows * nsub && c_next.len() >= m_rows * nsub);

    // Outer loop: the NSUB output columns.
    for j in 0..nsub {
        // Inner loop: 32-row blocks of the output column ("a loop that
        // repeats the process 6 times" for m = 192).
        for blk in 0..m_rows / 32 {
            let base = blk * 32;
            // Load previous partial into "registers".
            let mut acc = [0.0f32; 32];
            acc.copy_from_slice(&c_prev[j * m_rows + base..j * m_rows + base + 32]);
            // k-depth doMults accumulate in registers before the store —
            // "the partial results will be accumulated 4 times in the
            // internal registers, before sending them back to memory".
            for l in 0..k_depth {
                let scalar = b[j * k_depth + l];
                do_mult(&mut acc, scalar, &a[l * m_rows + base..l * m_rows + base + 32]);
            }
            c_next[j * m_rows + base..j * m_rows + base + 32].copy_from_slice(&acc);
        }
    }

    SubMatmulStats {
        cycles: model.submatmul_cycles(m_rows, nsub, k_depth),
        macs: (m_rows * nsub * k_depth) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Mat, max_scaled_err};

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c0: &[f32]) -> Vec<f32> {
        let mut c = c0.to_vec();
        for j in 0..n {
            for l in 0..k {
                for i in 0..m {
                    c[j * m + i] += a[l * m + i] * b[j * k + l];
                }
            }
        }
        c
    }

    #[test]
    fn matches_naive_192x4x4() {
        let model = CalibratedModel::default();
        let a = Mat::<f32>::randn(192, 4, 1);
        let b = Mat::<f32>::randn(4, 4, 2);
        let c0 = Mat::<f32>::randn(192, 4, 3);
        let mut out = vec![0.0; 192 * 4];
        submatmul(&model, 192, 4, 4, a.as_slice(), b.as_slice(), c0.as_slice(), &mut out);
        let want = naive(192, 4, 4, a.as_slice(), b.as_slice(), c0.as_slice());
        let got = Mat::from_col_major(192, 4, &out);
        let want = Mat::from_col_major(192, 4, &want);
        // FMA vs separate mul+add differ in last-ulp only.
        assert!(max_scaled_err(got.view(), want.view()) < 1e-6);
    }

    #[test]
    fn accumulates_prev_partial() {
        let model = CalibratedModel::default();
        let a = vec![0.0f32; 32 * 4];
        let b = vec![0.0f32; 16];
        let prev: Vec<f32> = (0..32 * 4).map(|v| v as f32).collect();
        let mut next = vec![0.0f32; 32 * 4];
        submatmul(&model, 32, 4, 4, &a, &b, &prev, &mut next);
        assert_eq!(next, prev, "zero product must pass prev through");
    }

    #[test]
    fn cycle_count_matches_model() {
        let model = CalibratedModel::default();
        let a = vec![0.0f32; 192 * 4];
        let b = vec![0.0f32; 16];
        let prev = vec![0.0f32; 192 * 4];
        let mut next = vec![0.0f32; 192 * 4];
        let s = submatmul(&model, 192, 4, 4, &a, &b, &prev, &mut next);
        assert_eq!(s.cycles, 3584);
        assert_eq!(s.macs, 3072);
    }

    #[test]
    #[should_panic(expected = "32-row")]
    fn rejects_unaligned_m() {
        let model = CalibratedModel::default();
        let mut next = vec![0.0f32; 33 * 4];
        submatmul(&model, 33, 4, 4, &[0.0; 33 * 4], &[0.0; 16], &[0.0; 33 * 4], &mut next);
    }
}
