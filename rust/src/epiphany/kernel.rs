//! The Epiphany sgemm kernel (paper §3.4): Epiphany Task → Column
//! Iteration → K Iteration → inter-core pipeline → `subMatmul`.
//!
//! One **Task** consumes one `m × KSUB` A panel and one `KSUB × n` B panel
//! from HC-RAM and adds their product into the on-chip accumulators (or
//! sends it back, per the `command`). Internally:
//!
//! * the panels are sliced across the 16 cores in the k dimension
//!   (`a_ti-cj`: m × KSUB/16 columns, `b_ti-cj`: KSUB/16 × n rows);
//! * each **Column Iteration** finalizes, for every core, one `m × NSUB`
//!   sliver of that core's owned `n/16` output columns;
//! * each of its 16 **K Iterations** has every core run one `subMatmul`
//!   for the *rotating* target `(own - iter - 1) mod 16` and push the
//!   accumulated partial to the next core in the pipeline ring —
//!   results move, inputs stay, because the FMADD dual-issues with the
//!   remote store (paper §3.4.1);
//! * RES1/RES2 ping-pong by iteration parity so the last K Iteration
//!   lands in RES2, which persists across accumulating tasks.
//!
//! The `command` protocol (§3.3) makes the accumulator scheme explicit:
//! 0 = clear + compute, 1 = accumulate, 2 = accumulate + send back,
//! 3 = clear + compute + send back (single-task call).

use super::chip::Chip;
use super::mesh::{ring_core, ring_next};
use super::submatmul::submatmul;
use super::CORES;
use anyhow::{ensure, Result};

/// The shared "command" control variable (paper §3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Command {
    /// Clear the inner buffers and run one Task; keep results on chip.
    ClearAccumulate = 0,
    /// Run one Task accumulating onto the stored partials.
    Accumulate = 1,
    /// Run one Task, then send the accumulated results to HC-RAM.
    AccumulateSend = 2,
    /// Single-task call: clear, compute, send back.
    ClearSend = 3,
}

impl Command {
    /// Whether the command clears the accumulators before computing.
    pub fn clears(self) -> bool {
        matches!(self, Command::ClearAccumulate | Command::ClearSend)
    }
    /// Whether the command writes the results back to HC-RAM afterwards.
    pub fn sends(self) -> bool {
        matches!(self, Command::AccumulateSend | Command::ClearSend)
    }
}

/// Kernel geometry (the paper's m, n, KSUB, NSUB; CORES is fixed at 16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelGeometry {
    /// Micro-kernel rows (fixed per instantiation; 192 in the paper).
    pub m: usize,
    /// Micro-kernel columns (256 in the paper).
    pub n: usize,
    /// Panel depth per Task (64 in the paper).
    pub ksub: usize,
    /// Columns finalized per core per Column Iteration (4 in the paper).
    pub nsub: usize,
}

impl KernelGeometry {
    /// The paper's production configuration.
    pub fn paper() -> Self {
        KernelGeometry { m: 192, n: 256, ksub: 64, nsub: 4 }
    }

    /// k-depth per core per Task (`KSUB / CORES`; also the doMult repeat
    /// count in subMatmul — 4 in the paper).
    pub fn k_slice(&self) -> usize {
        self.ksub / CORES
    }

    /// Output columns owned by each core (`n / CORES`; 16 in the paper).
    pub fn cols_per_core(&self) -> usize {
        self.n / CORES
    }

    /// Column Iterations per Task (`(n/CORES) / NSUB`; 4 in the paper).
    ///
    /// Note: the paper's §3.4.2 closes with "after n/NSUB Epiphany Column
    /// Iterations the Task is completed", which double-counts by a factor
    /// of CORES (each Column Iteration finalizes CORES blocks); the
    /// consistent reading used here matches its own Figure 5.
    pub fn col_iters(&self) -> usize {
        self.cols_per_core() / self.nsub
    }

    /// K Iterations per Column Iteration (= CORES, §3.4.3).
    pub fn k_iters(&self) -> usize {
        CORES
    }

    /// Check the divisibility constraints the kernel's slicing relies on.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.m > 0 && self.m % 32 == 0,
            "m must be a positive multiple of 32 (doMult vector length), got {}",
            self.m
        );
        ensure!(
            self.ksub % CORES == 0,
            "KSUB ({}) must divide evenly across {CORES} cores",
            self.ksub
        );
        ensure!(self.k_slice() > 0, "KSUB too small");
        ensure!(
            self.n % (CORES * self.nsub) == 0,
            "n ({}) must be a multiple of CORES*NSUB ({})",
            self.n,
            CORES * self.nsub
        );
        Ok(())
    }

    /// Bytes of the two input panels per Task.
    pub fn task_in_bytes(&self) -> usize {
        4 * (self.m * self.ksub + self.ksub * self.n)
    }

    /// Bytes of the full result.
    pub fn out_bytes(&self) -> usize {
        4 * self.m * self.n
    }
}

/// Borrowed input panels for one Task (host-side formats).
pub struct TaskInputs<'a> {
    /// m × KSUB, column-major.
    pub a_panel: &'a [f32],
    /// KSUB × n, row-major.
    pub b_panel: &'a [f32],
}

impl Chip {
    /// Run one Epiphany Task against input buffer `selector`.
    ///
    /// Mirrors the on-chip control flow: DMA the per-core slices in, then
    /// `col_iters × CORES` barrier-separated K Iterations, then (per
    /// `command`) write the owned blocks back to HC-RAM.
    pub fn run_task(&mut self, command: Command, selector: usize) -> Result<()> {
        let g = self.geom;
        let sel = selector & 1;
        let (m, n, nsub) = (g.m, g.n, g.nsub);
        let k_slice = g.k_slice();
        let cols_per_core = g.cols_per_core();

        // --- per-core DMA of input slices (e-link, HC-RAM → local) -------
        for pos in 0..CORES {
            let core = ring_core(pos);
            // a_ti-cj: columns [pos*k_slice, (pos+1)*k_slice) of the
            // column-major A panel — contiguous in HC-RAM by design.
            let a_start = pos * k_slice * m;
            let a_len = k_slice * m;
            let a_src = self.hcram.slice(self.segs.a_in[sel], a_start, a_len).to_vec();
            let a_buf = self.cores[core].a;
            self.cores[core].lm.buf_mut(a_buf).copy_from_slice(&a_src);
            self.stats.dma.record_in(a_len * 4);
            // b_ti-cj: rows [pos*k_slice, (pos+1)*k_slice) of the row-major
            // B panel — also contiguous.
            let b_start = pos * k_slice * n;
            let b_len = k_slice * n;
            let b_src = self.hcram.slice(self.segs.b_in[sel], b_start, b_len).to_vec();
            let b_buf = self.cores[core].b;
            self.cores[core].lm.buf_mut(b_buf).copy_from_slice(&b_src);
            self.stats.dma.record_in(b_len * 4);
        }

        // --- command 0/3: clear the accumulators --------------------------
        if command.clears() {
            for core in &mut self.cores {
                let (r1, r2) = (core.res1, core.res2);
                core.lm.clear(r1);
                core.lm.clear(r2);
            }
        }

        // --- Column Iterations --------------------------------------------
        for col_iter in 0..g.col_iters() {
            // --- K Iterations (lock-step, barrier before and after) ------
            for k_iter in 0..g.k_iters() {
                for pos in 0..CORES {
                    self.barrier.arrive(ring_core(pos))?;
                }
                self.stats.cycles += self.model.barrier_cycles;

                let last = k_iter == g.k_iters() - 1;
                // Staged writes: on silicon the remote stores land in the
                // *next* core while every core computes in lock-step; the
                // sequential simulation stages them and commits after the
                // (conceptual) parallel step to avoid order artifacts.
                let mut staged: Vec<(usize, bool, usize, Vec<f32>)> = Vec::with_capacity(CORES);
                let mut sub_cycles = 0u64;

                for pos in 0..CORES {
                    let core_id = ring_core(pos);
                    // Rotating ownership: the block computed now ultimately
                    // belongs to ring position (pos - k_iter - 1) mod CORES.
                    let target = (pos + CORES - (k_iter % CORES) - 1) % CORES;
                    // B sub-block: columns of the target's owned region.
                    let col0 = target * cols_per_core + col_iter * nsub;

                    // Gather the k_slice × nsub B sub-block column-major
                    // (the assembly reads it strided from the row-major
                    // local panel; same values, same order of use).
                    let core = &self.cores[core_id];
                    let b_local = core.lm.buf(core.b);
                    let mut b_sub = vec![0.0f32; k_slice * nsub];
                    for jj in 0..nsub {
                        for l in 0..k_slice {
                            b_sub[jj * k_slice + l] = b_local[l * n + col0 + jj];
                        }
                    }

                    // Previous partial: parity ping-pong. Reads come from
                    // the buffer the *previous* iteration wrote into this
                    // core: even k_iter ⇒ RES2 block, odd ⇒ RES1.
                    let read_res2 = k_iter % 2 == 0;
                    let prev: Vec<f32> = if read_res2 {
                        let r2 = core.lm.buf(core.res2);
                        r2[col_iter * nsub * m..(col_iter * nsub + nsub) * m].to_vec()
                    } else {
                        core.lm.buf(core.res1)[..m * nsub].to_vec()
                    };

                    let a_local = core.lm.buf(core.a);
                    let mut next = vec![0.0f32; m * nsub];
                    let st =
                        submatmul(&self.model, m, k_slice, nsub, a_local, &b_sub, &prev, &mut next);
                    sub_cycles = sub_cycles.max(st.cycles);
                    self.stats.submatmuls += 1;
                    self.stats.macs += st.macs;

                    if last && command.sends() {
                        // Final iteration, send-out: this core computed its
                        // OWN block (target == pos); write it to HC-RAM.
                        debug_assert_eq!(target, pos);
                        let out_col0 = pos * cols_per_core + col_iter * nsub;
                        for jj in 0..nsub {
                            self.hcram
                                .slice_mut(self.segs.out, (out_col0 + jj) * m, m)
                                .copy_from_slice(&next[jj * m..(jj + 1) * m]);
                        }
                        self.stats.dma.record_out(m * nsub * 4);
                    } else {
                        // Push to the next core in the pipeline; odd
                        // iterations write RES2 (so the last write of an
                        // accumulating task persists there), even write RES1.
                        let dst_core = ring_core(ring_next(pos));
                        let to_res2 = k_iter % 2 == 1;
                        self.stats.mesh.record(core_id, dst_core, m * nsub * 4);
                        staged.push((dst_core, to_res2, col_iter, next));
                    }
                }

                // Commit the staged remote stores ("after" the parallel step).
                for (dst_core, to_res2, ci, data) in staged {
                    let dst = &mut self.cores[dst_core];
                    if to_res2 {
                        let r2 = dst.lm.buf_mut(dst.res2);
                        r2[ci * nsub * m..(ci * nsub + nsub) * m].copy_from_slice(&data);
                    } else {
                        dst.lm.buf_mut(dst.res1)[..m * nsub].copy_from_slice(&data);
                    }
                }

                self.stats.cycles += sub_cycles;
                for pos in 0..CORES {
                    self.barrier.arrive(ring_core(pos))?;
                }
                self.stats.cycles += self.model.barrier_cycles;
            }
        }

        self.stats.cycles += self.model.task_overhead_cycles;
        self.stats.tasks += 1;
        self.stats.barrier_episodes = self.barrier.episodes;
        Ok(())
    }

    /// Convenience: host writes both panels to `selector` and runs a task
    /// (the service's per-iteration body, without the upload/compute
    /// overlap that the timing layer models separately).
    pub fn upload_and_run(
        &mut self,
        inputs: TaskInputs<'_>,
        command: Command,
        selector: usize,
    ) -> Result<()> {
        self.host_write_a_panel(selector, inputs.a_panel);
        self.host_write_b_panel(selector, inputs.b_panel);
        self.run_task(command, selector)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::timing::CalibratedModel;
    use crate::linalg::{max_scaled_err, Mat};

    /// Pack B (ksub × n col-major Mat) into the row-major panel format.
    fn row_major_panel(b: &Mat<f32>) -> Vec<f32> {
        let (k, n) = (b.rows(), b.cols());
        let mut out = vec![0.0f32; k * n];
        for l in 0..k {
            for j in 0..n {
                out[l * n + j] = b.get(l, j);
            }
        }
        out
    }

    fn run_chain(geom: KernelGeometry, k_total: usize, seed: u64) -> (Mat<f32>, Mat<f32>) {
        let mut chip = Chip::new(CalibratedModel::default(), geom).unwrap();
        let a = Mat::<f32>::randn(geom.m, k_total, seed);
        let b = Mat::<f32>::randn(k_total, geom.n, seed + 1);
        let tasks = k_total / geom.ksub;
        for t in 0..tasks {
            let a_panel = a.view().sub(0, t * geom.ksub, geom.m, geom.ksub).to_mat();
            let b_panel = b.view().sub(t * geom.ksub, 0, geom.ksub, geom.n).to_mat();
            let command = match (t == 0, t == tasks - 1) {
                (true, true) => Command::ClearSend,
                (true, false) => Command::ClearAccumulate,
                (false, true) => Command::AccumulateSend,
                (false, false) => Command::Accumulate,
            };
            chip.upload_and_run(
                TaskInputs { a_panel: a_panel.as_slice(), b_panel: &row_major_panel(&b_panel) },
                command,
                t & 1,
            )
            .unwrap();
        }
        let mut out = vec![0.0f32; geom.m * geom.n];
        chip.host_read_out(&mut out);
        let got = Mat::from_col_major(geom.m, geom.n, &out);
        // f64 oracle.
        let af = a.cast::<f64>();
        let bf = b.cast::<f64>();
        let mut want = Mat::<f64>::zeros(geom.m, geom.n);
        for j in 0..geom.n {
            for l in 0..k_total {
                for i in 0..geom.m {
                    want.set(i, j, want.get(i, j) + af.get(i, l) * bf.get(l, j));
                }
            }
        }
        (got, want.cast::<f32>())
    }

    #[test]
    fn single_task_matches_oracle() {
        let geom = KernelGeometry::paper();
        let (got, want) = run_chain(geom, geom.ksub, 10);
        let e = max_scaled_err(got.view(), want.view());
        assert!(e < 1e-5, "max rel err {e}");
    }

    #[test]
    fn accumulated_tasks_match_oracle() {
        // 4 tasks chained with the accumulator protocol (commands 0,1,1,2).
        let geom = KernelGeometry::paper();
        let (got, want) = run_chain(geom, 4 * geom.ksub, 20);
        let e = max_scaled_err(got.view(), want.view());
        assert!(e < 1e-5, "max rel err {e}");
    }

    #[test]
    fn paper_scale_error_band() {
        // K = 1024 keeps the test fast while exercising 16 chained tasks;
        // the relative error must sit in the paper's 1e-8..1e-6 band.
        let geom = KernelGeometry::paper();
        let (got, want) = run_chain(geom, 1024, 30);
        let e = max_scaled_err(got.view(), want.view());
        assert!(e > 1e-9 && e < 5e-6, "max rel err {e}");
    }

    #[test]
    fn task_stats_match_structure() {
        let geom = KernelGeometry::paper();
        let mut chip = Chip::new(CalibratedModel::default(), geom).unwrap();
        let a = Mat::<f32>::randn(geom.m, geom.ksub, 1);
        let b = Mat::<f32>::randn(geom.ksub, geom.n, 2);
        chip.upload_and_run(
            TaskInputs { a_panel: a.as_slice(), b_panel: &row_major_panel(&b) },
            Command::ClearSend,
            0,
        )
        .unwrap();
        // 4 column iterations × 16 K iterations × 16 cores.
        assert_eq!(chip.stats.submatmuls, (4 * 16 * 16) as u64);
        // Total MACs = m·n·KSUB.
        assert_eq!(chip.stats.macs, (192 * 256 * 64) as u64);
        // Two barrier episodes per K iteration.
        assert_eq!(chip.stats.barrier_episodes, (2 * 4 * 16) as u64);
        // DMA in: full panels; out: full result.
        assert_eq!(chip.stats.dma.in_bytes, geom.task_in_bytes() as u64);
        assert_eq!(chip.stats.dma.out_bytes, geom.out_bytes() as u64);
        // Pipeline stores are single-hop except the ring wrap-around
        // (snake embedding: 3 hops from the last ring position to pos 0).
        assert_eq!(chip.stats.mesh.max_hops, 3);
        // 15 of 16 stores per K iteration are neighbour stores: average
        // hop count must stay well under 1.2.
        let avg_hops = chip.stats.mesh.byte_hops as f64 / chip.stats.mesh.bytes as f64;
        assert!(avg_hops < 1.2, "avg hops {avg_hops}");
    }

    #[test]
    fn onchip_efficiency_near_85pct() {
        let geom = KernelGeometry::paper();
        let mut chip = Chip::new(CalibratedModel::default(), geom).unwrap();
        let a = Mat::<f32>::randn(geom.m, geom.ksub, 1);
        let b = Mat::<f32>::randn(geom.ksub, geom.n, 2);
        chip.upload_and_run(
            TaskInputs { a_panel: a.as_slice(), b_panel: &row_major_panel(&b) },
            Command::ClearSend,
            0,
        )
        .unwrap();
        let eff = chip.stats.onchip_gflops() / chip.model.peak_gflops();
        // Barriers cost ~10%: on-chip efficiency lands near 0.77; the
        // subMatmul alone is 0.857 (see timing tests). Varghese et al.'s
        // 85% is subMatmul-level; task-level must stay within [0.7, 0.87].
        assert!((0.70..0.87).contains(&eff), "eff = {eff}");
    }

    #[test]
    fn alternate_geometry_m64() {
        // Output-streaming-style smaller m with bigger KSUB still fits and
        // stays correct: m=64, KSUB=128 ⇒ A: 64×8, B: 8×256, RES2: 64×16.
        let geom = KernelGeometry { m: 64, n: 256, ksub: 128, nsub: 4 };
        let (got, want) = run_chain(geom, 256, 40);
        let e = max_scaled_err(got.view(), want.view());
        assert!(e < 1e-5, "max rel err {e}");
    }
}
