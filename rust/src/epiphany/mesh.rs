//! The eMesh network-on-chip: 4×4 2D mesh, dimension-order (XY) routing,
//! and the ring ("pipeline", paper Fig. 7) embedding used by the sgemm
//! kernel.
//!
//! The kernel's inter-core traffic is exclusively "store my partial result
//! into the next core's buffer". On silicon those stores dual-issue with
//! FMADDs and cost zero extra cycles *when the next core is a mesh
//! neighbour*; the ring is therefore embedded as a boustrophedon (snake)
//! walk so every pipeline hop has mesh distance 1. The mesh model records
//! per-link traffic so tests can verify that embedding property and
//! ablations can price a bad embedding.

use super::{CORES, MESH_COLS};

/// Core coordinates on the mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Coord {
    /// Mesh row (0..4).
    pub row: usize,
    /// Mesh column (0..4).
    pub col: usize,
}

/// Map core id → mesh coordinate (row-major physical numbering, as the
/// Epiphany does with its (row, col) core ids).
pub fn coord_of(core: usize) -> Coord {
    assert!(core < CORES);
    Coord { row: core / MESH_COLS, col: core % MESH_COLS }
}

/// Manhattan distance between two cores (XY routing hop count).
pub fn hops(a: usize, b: usize) -> usize {
    let (ca, cb) = (coord_of(a), coord_of(b));
    ca.row.abs_diff(cb.row) + ca.col.abs_diff(cb.col)
}

/// The pipeline ring (paper Fig. 7) embedded as a snake over the mesh:
/// logical position `i` in the ring maps to this physical core.
///
/// Rows alternate direction so consecutive ring positions are always mesh
/// neighbours; the wrap-around (last → first) is the single multi-hop link.
pub fn ring_core(pos: usize) -> usize {
    assert!(pos < CORES);
    let row = pos / MESH_COLS;
    let col = if row % 2 == 0 { pos % MESH_COLS } else { MESH_COLS - 1 - pos % MESH_COLS };
    row * MESH_COLS + col
}

/// Inverse of [`ring_core`].
pub fn ring_pos(core: usize) -> usize {
    (0..CORES).find(|&p| ring_core(p) == core).expect("core id in range")
}

/// Next core in the pipeline after logical ring position `pos`.
pub fn ring_next(pos: usize) -> usize {
    (pos + 1) % CORES
}

/// Traffic accounting over mesh links.
#[derive(Clone, Debug, Default)]
pub struct MeshStats {
    /// Total bytes sent core→core, weighted by hop count.
    pub byte_hops: u64,
    /// Raw bytes sent core→core.
    pub bytes: u64,
    /// Number of store transactions.
    pub stores: u64,
    /// Max hop count seen on any transaction (1 for a good embedding,
    /// except the ring wrap-around).
    pub max_hops: usize,
}

impl MeshStats {
    /// Record a transfer of `bytes` from core `src` to core `dst`.
    pub fn record(&mut self, src: usize, dst: usize, bytes: usize) {
        let h = hops(src, dst);
        self.byte_hops += (bytes * h) as u64;
        self.bytes += bytes as u64;
        self.stores += 1;
        self.max_hops = self.max_hops.max(h);
    }

    /// Fold another run's mesh accounting into this one.
    pub fn merge(&mut self, other: &MeshStats) {
        self.byte_hops += other.byte_hops;
        self.bytes += other.bytes;
        self.stores += other.stores;
        self.max_hops = self.max_hops.max(other.max_hops);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_row_major() {
        assert_eq!(coord_of(0), Coord { row: 0, col: 0 });
        assert_eq!(coord_of(5), Coord { row: 1, col: 1 });
        assert_eq!(coord_of(15), Coord { row: 3, col: 3 });
    }

    #[test]
    fn snake_ring_is_neighbour_embedded() {
        // Every consecutive pipeline hop must be mesh distance 1 — the
        // property that makes the paper's "results move for free" claim
        // hold on the NoC.
        for pos in 0..CORES - 1 {
            let a = ring_core(pos);
            let b = ring_core(pos + 1);
            assert_eq!(hops(a, b), 1, "ring hop {pos}->{} is {a}->{b}", pos + 1);
        }
    }

    #[test]
    fn ring_is_a_permutation() {
        let mut seen = [false; CORES];
        for pos in 0..CORES {
            let c = ring_core(pos);
            assert!(!seen[c], "core {c} appears twice");
            seen[c] = true;
        }
        for pos in 0..CORES {
            assert_eq!(ring_pos(ring_core(pos)), pos);
        }
    }

    #[test]
    fn wraparound_is_the_only_long_hop() {
        // Snake over 4×4: last ring position is core 12 (row 3 reversed),
        // wrap to core 0 costs 3 hops.
        let last = ring_core(CORES - 1);
        assert_eq!(hops(last, ring_core(0)), 3);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = MeshStats::default();
        s.record(0, 1, 100); // 1 hop
        s.record(0, 15, 10); // 6 hops
        assert_eq!(s.bytes, 110);
        assert_eq!(s.byte_hops, 100 + 60);
        assert_eq!(s.stores, 2);
        assert_eq!(s.max_hops, 6);
    }
}
