//! Per-core local memory (32 KB, four 8 KB banks) and the shared DRAM
//! window (HC-RAM).
//!
//! The paper's Figure 3 memory map is reproduced as a bump allocator over
//! the 32 KB space: bank 0 is reserved for kernel code, a stack/control
//! region is reserved at the top, and the A/B/RES1/RES2 buffers must fit in
//! between — geometry that does not fit is a *configuration error*, exactly
//! as it would be on silicon. Figure 9's output-streaming map is an
//! alternative layout built through the same allocator.

use super::{BANK_BYTES, HCRAM_BYTES, LOCAL_MEM_BYTES};
use anyhow::{bail, Result};

/// Bytes reserved at the bottom for the kernel's code (bank 0, Fig. 3).
pub const CODE_BYTES: usize = BANK_BYTES;
/// Bytes reserved at the top for stack + control variables (Fig. 3).
pub const STACK_CTRL_BYTES: usize = 2 * 1024;

/// A named region inside a core's local memory.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Region {
    /// Human-readable region label (Fig. 3 names).
    pub name: &'static str,
    /// Byte offset of the region's start.
    pub offset: usize,
    /// Region size in bytes.
    pub bytes: usize,
}

/// One core's 32 KB local store with named f32 buffers.
///
/// Buffers are held as `Vec<f32>` for the functional simulation, but every
/// allocation is accounted against the 32 KB budget so capacity errors are
/// real.
pub struct LocalMemory {
    regions: Vec<Region>,
    buffers: Vec<Vec<f32>>,
    cursor: usize,
}

impl LocalMemory {
    /// Fresh local memory with code + stack/control reserved.
    pub fn new() -> Self {
        LocalMemory {
            regions: vec![Region { name: "code", offset: 0, bytes: CODE_BYTES }],
            buffers: vec![Vec::new()],
            cursor: CODE_BYTES,
        }
    }

    /// Allocate a named f32 buffer of `len` elements. Fails when the map
    /// (including the reserved stack/control region) would exceed 32 KB.
    pub fn alloc_f32(&mut self, name: &'static str, len: usize) -> Result<BufId> {
        let bytes = len * 4;
        if self.cursor + bytes + STACK_CTRL_BYTES > LOCAL_MEM_BYTES {
            bail!(
                "local memory overflow allocating '{name}' ({bytes} B at offset {}): \
                 map exceeds {} B (stack/ctrl reserves {} B)",
                self.cursor,
                LOCAL_MEM_BYTES,
                STACK_CTRL_BYTES
            );
        }
        let id = BufId(self.buffers.len());
        self.regions.push(Region { name, offset: self.cursor, bytes });
        self.buffers.push(vec![0.0; len]);
        self.cursor += bytes;
        Ok(id)
    }

    /// Bytes still available for buffers.
    pub fn free_bytes(&self) -> usize {
        LOCAL_MEM_BYTES - STACK_CTRL_BYTES - self.cursor
    }

    /// Bytes used by buffers (excluding code and stack/control).
    pub fn buffer_bytes(&self) -> usize {
        self.cursor - CODE_BYTES
    }

    /// The memory map, Figure-3 style.
    pub fn map(&self) -> &[Region] {
        &self.regions
    }

    /// Read access to a buffer's elements.
    pub fn buf(&self, id: BufId) -> &[f32] {
        &self.buffers[id.0]
    }

    /// Write access to a buffer's elements.
    pub fn buf_mut(&mut self, id: BufId) -> &mut [f32] {
        &mut self.buffers[id.0]
    }

    /// Zero a buffer (the `command = 0 / 3` clear step).
    pub fn clear(&mut self, id: BufId) {
        self.buffers[id.0].fill(0.0);
    }

    /// Render the map for docs/tests, one line per region.
    pub fn render_map(&self) -> String {
        let mut out = String::new();
        for r in &self.regions {
            out.push_str(&format!(
                "0x{:04x}..0x{:04x}  {:>6} B  {}\n",
                r.offset,
                r.offset + r.bytes,
                r.bytes,
                r.name
            ));
        }
        out.push_str(&format!(
            "0x{:04x}..0x{:04x}  {:>6} B  stack+ctrl (reserved)\n",
            LOCAL_MEM_BYTES - STACK_CTRL_BYTES,
            LOCAL_MEM_BYTES,
            STACK_CTRL_BYTES
        ));
        out
    }
}

impl Default for LocalMemory {
    fn default() -> Self {
        Self::new()
    }
}

/// Handle to a buffer in a core's local memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BufId(usize);

/// The 32 MB host↔coprocessor shared DRAM window.
///
/// Functionally a flat f32 arena with named segments; the host writes input
/// panels into the double-buffered segments and the chip DMAs them out,
/// byte counts flowing into the timing model.
pub struct HcRam {
    data: Vec<f32>,
    segments: Vec<(String, usize, usize)>, // name, offset (f32 elems), len
    cursor: usize,
}

impl HcRam {
    /// A fresh, empty 32 MB window.
    pub fn new() -> Self {
        HcRam { data: vec![0.0; HCRAM_BYTES / 4], segments: Vec::new(), cursor: 0 }
    }

    /// Reserve a named segment of `len` f32 elements.
    pub fn alloc(&mut self, name: &str, len: usize) -> Result<HcSeg> {
        if (self.cursor + len) * 4 > HCRAM_BYTES {
            bail!("HC-RAM overflow allocating '{name}' ({len} f32s)");
        }
        let seg = HcSeg { offset: self.cursor, len };
        self.segments.push((name.to_string(), self.cursor, len));
        self.cursor += len;
        Ok(seg)
    }

    /// Copy `data` into the start of a segment (host `e_write` path).
    pub fn write(&mut self, seg: HcSeg, data: &[f32]) {
        assert!(data.len() <= seg.len, "segment overflow");
        self.data[seg.offset..seg.offset + data.len()].copy_from_slice(data);
    }

    /// Copy the start of a segment into `out` (host `e_read` path).
    pub fn read(&self, seg: HcSeg, out: &mut [f32]) {
        assert!(out.len() <= seg.len, "segment overflow");
        out.copy_from_slice(&self.data[seg.offset..seg.offset + out.len()]);
    }

    /// Borrow `len` elements of a segment starting at `start`.
    pub fn slice(&self, seg: HcSeg, start: usize, len: usize) -> &[f32] {
        assert!(start + len <= seg.len);
        &self.data[seg.offset + start..seg.offset + start + len]
    }

    /// Mutably borrow `len` elements of a segment starting at `start`.
    pub fn slice_mut(&mut self, seg: HcSeg, start: usize, len: usize) -> &mut [f32] {
        assert!(start + len <= seg.len);
        &mut self.data[seg.offset + start..seg.offset + start + len]
    }

    /// Bytes currently allocated to segments.
    pub fn used_bytes(&self) -> usize {
        self.cursor * 4
    }

    /// Drop all segments (service shutdown / reset).
    pub fn reset(&mut self) {
        self.segments.clear();
        self.cursor = 0;
    }
}

impl Default for HcRam {
    fn default() -> Self {
        Self::new()
    }
}

/// Handle to an HC-RAM segment (element offsets).
#[derive(Clone, Copy, Debug)]
pub struct HcSeg {
    /// Segment start, in f32 elements from the window base.
    pub offset: usize,
    /// Segment length, in f32 elements.
    pub len: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_map_fits_exactly() {
        // Paper geometry: m=192, n=256, KSUB=64, NSUB=4, CORES=16.
        // A: 192×4, B: 4×256, RES1: 192×4, RES2: 192×16.
        let mut lm = LocalMemory::new();
        lm.alloc_f32("A", 192 * 4).unwrap();
        lm.alloc_f32("B", 4 * 256).unwrap();
        lm.alloc_f32("RES1", 192 * 4).unwrap();
        lm.alloc_f32("RES2", 192 * 16).unwrap();
        // 8K code + 3K + 4K + 3K + 12K = 30K; 2K stack/ctrl ⇒ exactly 32K.
        assert_eq!(lm.free_bytes(), 0);
    }

    #[test]
    fn oversized_geometry_rejected() {
        // KSUB=128 doubles A and B: must not fit (the paper's compromise
        // between ir and or ratios is a real capacity constraint).
        let mut lm = LocalMemory::new();
        lm.alloc_f32("A", 192 * 8).unwrap();
        lm.alloc_f32("B", 8 * 256).unwrap();
        lm.alloc_f32("RES1", 192 * 4).unwrap();
        assert!(lm.alloc_f32("RES2", 192 * 16).is_err());
    }

    #[test]
    fn map_renders_fig3_order() {
        let mut lm = LocalMemory::new();
        lm.alloc_f32("A", 16).unwrap();
        let map = lm.render_map();
        assert!(map.contains("code"));
        assert!(map.contains("stack+ctrl"));
        assert!(map.lines().count() == 3);
    }

    #[test]
    fn hcram_round_trip() {
        let mut hc = HcRam::new();
        let seg = hc.alloc("in_a", 128).unwrap();
        let data: Vec<f32> = (0..128).map(|v| v as f32).collect();
        hc.write(seg, &data);
        let mut out = vec![0.0; 128];
        hc.read(seg, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn hcram_capacity_enforced() {
        let mut hc = HcRam::new();
        assert!(hc.alloc("big", HCRAM_BYTES / 4 + 1).is_err());
        let a = hc.alloc("half", HCRAM_BYTES / 8).unwrap();
        assert_eq!(a.offset, 0);
        assert!(hc.alloc("rest", HCRAM_BYTES / 8).is_ok());
        assert!(hc.alloc("one-more", 1).is_err());
    }

    #[test]
    fn clear_zeroes_buffer() {
        let mut lm = LocalMemory::new();
        let b = lm.alloc_f32("x", 8).unwrap();
        lm.buf_mut(b).fill(3.0);
        lm.clear(b);
        assert!(lm.buf(b).iter().all(|&v| v == 0.0));
    }
}
