//! Mesh-wide barrier model.
//!
//! The kernel barriers before and after every K Iteration (paper §3.4.3).
//! The simulator executes cores sequentially inside a lock-step loop, so
//! the barrier's *functional* job is an assertion device — every core must
//! arrive exactly once per phase — while its *timing* job is a per-use
//! cycle charge in the calibrated model.

use super::CORES;
use anyhow::{bail, Result};

/// Lock-step barrier with arrival accounting.
#[derive(Debug)]
pub struct Barrier {
    arrived: [bool; CORES],
    count: usize,
    /// Completed barrier episodes (for timing: episodes × barrier_cycles).
    pub episodes: u64,
}

impl Barrier {
    /// A fresh barrier: no arrivals, no completed episodes.
    pub fn new() -> Self {
        Barrier { arrived: [false; CORES], count: 0, episodes: 0 }
    }

    /// Core `id` arrives. Double arrival within one episode is a kernel
    /// bug on silicon (deadlock or data race) and therefore an error here.
    pub fn arrive(&mut self, id: usize) -> Result<()> {
        if id >= CORES {
            bail!("barrier arrival from bogus core id {id}");
        }
        if self.arrived[id] {
            bail!("core {id} arrived twice at barrier (lock-step violation)");
        }
        self.arrived[id] = true;
        self.count += 1;
        if self.count == CORES {
            self.arrived = [false; CORES];
            self.count = 0;
            self.episodes += 1;
        }
        Ok(())
    }

    /// True when a barrier episode is partially filled (would deadlock if
    /// the remaining cores never arrive).
    pub fn pending(&self) -> bool {
        self.count != 0
    }
}

impl Default for Barrier {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_round_completes_episode() {
        let mut b = Barrier::new();
        for id in 0..CORES {
            b.arrive(id).unwrap();
        }
        assert_eq!(b.episodes, 1);
        assert!(!b.pending());
    }

    #[test]
    fn double_arrival_is_error() {
        let mut b = Barrier::new();
        b.arrive(3).unwrap();
        assert!(b.arrive(3).is_err());
    }

    #[test]
    fn partial_round_is_pending() {
        let mut b = Barrier::new();
        b.arrive(0).unwrap();
        assert!(b.pending());
        assert_eq!(b.episodes, 0);
    }

    #[test]
    fn bogus_core_rejected() {
        let mut b = Barrier::new();
        assert!(b.arrive(CORES).is_err());
    }
}
