//! Chip-level state: 16 cores with Figure-3 local memory maps, the HC-RAM
//! window with double-buffered input panels, and run statistics that feed
//! the calibrated timing model.

use super::barrier::Barrier;
use super::dma::DmaStats;
use super::kernel::KernelGeometry;
use super::memory::{BufId, HcRam, HcSeg, LocalMemory};
use super::mesh::MeshStats;
use super::timing::CalibratedModel;
use super::{CORES, CORE_HZ};
use anyhow::Result;

/// One eCore's state as the sgemm kernel sees it.
pub struct CoreState {
    /// The core's 32 KB local store with its Figure-3 region map.
    pub lm: LocalMemory,
    /// `a_ti-cj`: m × ksub/CORES, column-major.
    pub a: BufId,
    /// `b_ti-cj`: ksub/CORES × n, row-major.
    pub b: BufId,
    /// Fixed m × NSUB ping buffer.
    pub res1: BufId,
    /// m × n/CORES accumulator / pong buffer ("the entire result part that
    /// corresponds to this core"), used in m × NSUB blocks per Column
    /// Iteration.
    pub res2: BufId,
}

/// Aggregate run statistics; every figure the timing model needs.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    /// Lock-step per-core compute cycles (subMatmul + barriers + task
    /// overhead). All cores do identical work, so one counter suffices.
    pub cycles: u64,
    /// `subMatmul` invocations across all cores.
    pub submatmuls: u64,
    /// Multiply-accumulate operations across all cores.
    pub macs: u64,
    /// Epiphany Tasks executed (outermost kernel unit).
    pub tasks: u64,
    /// Completed mesh-wide barrier episodes.
    pub barrier_episodes: u64,
    /// Aggregate e-link DMA traffic.
    pub dma: DmaStats,
    /// Aggregate eMesh neighbour-store traffic.
    pub mesh: MeshStats,
}

impl SimStats {
    /// Projected coprocessor seconds under the calibrated model:
    /// e-link DMA (serial with compute, per DESIGN.md §6) + cycles +
    /// result write-back.
    pub fn coproc_s(&self, model: &CalibratedModel) -> f64 {
        self.dma.in_bytes as f64 / model.w_chip_dma
            + self.cycles as f64 / model.core_hz
            + self.dma.out_bytes as f64 / model.w_chip_write
    }

    /// Achieved on-chip GFLOPS (compute cycles only; `macs` is the total
    /// across all cores, `cycles` is per-core lock-step time) — comparable
    /// to the 85%-of-peak on-chip results of the prior work the paper cites.
    pub fn onchip_gflops(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        let secs = self.cycles as f64 / CORE_HZ;
        2.0 * self.macs as f64 / secs / 1e9
    }
}

/// HC-RAM segment handles for the kernel's shared buffers.
pub struct ChipSegments {
    /// Double-buffered input panels — "two buffers reserved for each input
    /// block" with the `selector` choosing per task.
    pub a_in: [HcSeg; 2],
    /// Double-buffered B input panels (same selector discipline as A).
    pub b_in: [HcSeg; 2],
    /// Result window, m × n column-major.
    pub out: HcSeg,
}

/// The simulated Epiphany-16 running the sgemm kernel.
pub struct Chip {
    /// The calibrated timing constants charged against this chip's runs.
    pub model: CalibratedModel,
    /// The µ-kernel geometry the memory map was laid out for.
    pub geom: KernelGeometry,
    /// Per-core state (local memory + kernel buffer handles), 16 entries.
    pub cores: Vec<CoreState>,
    /// The 32 MB shared DRAM window.
    pub hcram: HcRam,
    /// HC-RAM segment handles for the kernel's shared buffers.
    pub segs: ChipSegments,
    /// The mesh-wide barrier device.
    pub barrier: Barrier,
    /// Run statistics feeding the timing model.
    pub stats: SimStats,
}

impl Chip {
    /// Boot the chip with the Figure-3 memory map for `geom`. Fails when
    /// the geometry does not fit the 32 KB local stores.
    pub fn new(model: CalibratedModel, geom: KernelGeometry) -> Result<Self> {
        geom.validate()?;
        let mut cores = Vec::with_capacity(CORES);
        for _ in 0..CORES {
            let mut lm = LocalMemory::new();
            let a = lm.alloc_f32("A (a_ti-cj)", geom.m * geom.k_slice())?;
            let b = lm.alloc_f32("B (b_ti-cj)", geom.k_slice() * geom.n)?;
            let res1 = lm.alloc_f32("RES1", geom.m * geom.nsub)?;
            let res2 = lm.alloc_f32("RES2", geom.m * geom.cols_per_core())?;
            cores.push(CoreState { lm, a, b, res1, res2 });
        }
        let mut hcram = HcRam::new();
        let a_len = geom.m * geom.ksub;
        let b_len = geom.ksub * geom.n;
        let segs = ChipSegments {
            a_in: [hcram.alloc("a_in[0]", a_len)?, hcram.alloc("a_in[1]", a_len)?],
            b_in: [hcram.alloc("b_in[0]", b_len)?, hcram.alloc("b_in[1]", b_len)?],
            out: hcram.alloc("out", geom.m * geom.n)?,
        };
        Ok(Chip {
            model,
            geom,
            cores,
            hcram,
            segs,
            barrier: Barrier::new(),
            stats: SimStats::default(),
        })
    }

    /// Host writes an `m × ksub` column-major A panel into input buffer
    /// `selector` (the e-hal `e_write` path; timing charged by the caller).
    pub fn host_write_a_panel(&mut self, selector: usize, data: &[f32]) {
        assert_eq!(data.len(), self.geom.m * self.geom.ksub, "A panel size");
        self.hcram.write(self.segs.a_in[selector & 1], data);
    }

    /// Host writes a `ksub × n` row-major B panel into input buffer
    /// `selector`.
    pub fn host_write_b_panel(&mut self, selector: usize, data: &[f32]) {
        assert_eq!(data.len(), self.geom.ksub * self.geom.n, "B panel size");
        self.hcram.write(self.segs.b_in[selector & 1], data);
    }

    /// Host reads the m × n column-major result window.
    pub fn host_read_out(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.geom.m * self.geom.n, "result size");
        self.hcram.read(self.segs.out, out);
    }

    /// The Figure-3 memory map of core 0, for docs and layout tests.
    pub fn memory_map(&self) -> String {
        self.cores[0].lm.render_map()
    }

    /// Reset statistics (not memory) — e.g. between bench phases.
    pub fn reset_stats(&mut self) {
        self.stats = SimStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry_boots() {
        let chip = Chip::new(CalibratedModel::default(), KernelGeometry::paper()).unwrap();
        assert_eq!(chip.cores.len(), CORES);
        // Fig. 3 regions present in order.
        let map = chip.memory_map();
        let idx = |s: &str| map.find(s).unwrap_or(usize::MAX);
        assert!(idx("code") < idx("A (a_ti-cj)"));
        assert!(idx("A (a_ti-cj)") < idx("B (b_ti-cj)"));
        assert!(idx("B (b_ti-cj)") < idx("RES1"));
        assert!(idx("RES1") < idx("RES2"));
        assert!(map.contains("stack+ctrl"));
    }

    #[test]
    fn oversized_ksub_rejected() {
        // KSUB = 128 doubles the input buffers: must exceed 32 KB.
        let geom = KernelGeometry { m: 192, n: 256, ksub: 128, nsub: 4 };
        assert!(Chip::new(CalibratedModel::default(), geom).is_err());
    }

    #[test]
    fn hcram_panels_round_trip() {
        let mut chip = Chip::new(CalibratedModel::default(), KernelGeometry::paper()).unwrap();
        let g = chip.geom;
        let a: Vec<f32> = (0..g.m * g.ksub).map(|v| v as f32).collect();
        chip.host_write_a_panel(1, &a);
        let got = chip.hcram.slice(chip.segs.a_in[1], 0, a.len()).to_vec();
        assert_eq!(got, a);
    }
}
