//! # parallella-blas
//!
//! A reproduction of *"Generation of the Single Precision BLAS library for
//! the Parallella platform, with Epiphany co-processor acceleration, using
//! the BLIS framework"* (Miguel Tasende, IEEE DataCom 2016) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: a BLIS-like BLAS instantiation
//!   framework ([`blis`]), the host-side service-process architecture and
//!   sgemm inner micro-kernel ([`host`]), a functional + timing simulator of
//!   the Epiphany-16 coprocessor ([`epiphany`]), an eSDK-like driver API
//!   ([`esdk`]), an HPL Linpack substrate ([`hpl`]), a threaded BLAS
//!   network service ([`coordinator`]), and workload drivers over both —
//!   batched small gemm, mixed-precision iterative refinement, and im2col
//!   convolution ([`workloads`]).
//! * **L2 (python/compile/model.py)** — the sgemm inner micro-kernel compute
//!   graph in JAX, AOT-lowered to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/epiphany_gemm.py)** — the SUMMA-tiled
//!   Pallas kernel the L2 graph calls, mirroring the paper's Epiphany
//!   Task / Column Iteration / K Iteration structure.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API (the
//! `xla` crate) so that Python is never on the request path. That backend
//! is gated behind the off-by-default `pjrt` cargo feature — default
//! builds are fully offline, with `anyhow` as the only dependency, and
//! use the functional simulator instead.
//!
//! ## The descriptor API
//!
//! Every BLAS call is a typed, precision-generic descriptor from
//! [`blis::op`] — [`blis::GemmOp`], [`blis::GemvOp`], [`blis::Level1Op`],
//! … — executed by [`blis::Blas::execute`], the single path that
//! validates, routes (level-3 gemm → the Epiphany service, the rest →
//! host) and accounts. The classic FORTRAN-style names (`sgemm`, `saxpy`,
//! `sgemv`, …) survive as generated-style shims on
//! [`blis::BlasLibrary`]. Owned descriptors can also be submitted
//! asynchronously: [`blis::Blas::submit`] returns a [`blis::Ticket`]
//! whose `wait()` joins the in-flight op, so packing the next operand
//! overlaps the current service crossing.
//!
//! ## Quick start
//!
//! ```no_run
//! use parallella_blas::blis::{GemmOp, GemmTask};
//! use parallella_blas::prelude::*;
//! use std::sync::Arc;
//!
//! let plat = Platform::builder().backend(BackendKind::Simulator).build().unwrap();
//! let blas = plat.blas();
//! let a = Mat::<f32>::randn(192, 4096, 1);
//! let b = Mat::<f32>::randn(4096, 256, 2);
//! let mut c = Mat::<f32>::zeros(192, 256);
//!
//! // Classic shim (unchanged surface) ...
//! blas.sgemm(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c).unwrap();
//!
//! // ... or the descriptor core it delegates to ...
//! let op = GemmOp {
//!     ta: Trans::N,
//!     tb: Trans::N,
//!     alpha: 1.0f32,
//!     a: a.view(),
//!     b: b.view(),
//!     beta: 0.0,
//!     c: c.view_mut(),
//! };
//! blas.execute(op).unwrap();
//!
//! // ... or asynchronously, overlapping two in-flight gemms.
//! let h = plat.blas_handle();
//! let t1 = Arc::clone(&h).submit(GemmTask {
//!     ta: Trans::N,
//!     tb: Trans::N,
//!     alpha: 1.0f32,
//!     a: a.clone(),
//!     b: b.clone(),
//!     beta: 0.0,
//!     c: Mat::zeros(192, 256),
//! });
//! let t2 = Arc::clone(&h).submit(GemmTask {
//!     ta: Trans::N,
//!     tb: Trans::N,
//!     alpha: 1.0f32,
//!     a,
//!     b,
//!     beta: 0.0,
//!     c: Mat::zeros(192, 256),
//! });
//! let (c1, _report1) = t1.wait().unwrap();
//! let (c2, _report2) = t2.wait().unwrap();
//! # let _ = (c1, c2);
//! ```

// Idioms this model-code intentionally keeps: BLAS signatures carry many
// scalar parameters, kernels index with explicit loops to mirror the
// paper's C/assembly structure, and a few constructors return handles
// (`Arc<HhRam>`) rather than bare Self.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::new_ret_no_self,
    clippy::type_complexity,
    clippy::map_entry
)]
// Every public item carries documentation; the CI docs job turns rustdoc
// warnings (this lint included) into errors so the surface can't rot.
#![warn(missing_docs)]

pub mod blis;
pub mod coordinator;
pub mod epiphany;
pub mod esdk;
pub mod experiments;
pub mod host;
pub mod hpl;
pub mod linalg;
pub mod mem;
pub mod platform;
pub mod runtime;
pub mod util;
pub mod workloads;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::blis::{Blas, BlasLibrary, BlasOp, Dtype, Ticket, Trans};
    pub use crate::epiphany::timing::CalibratedModel;
    pub use crate::host::pool::{ChipPool, ShardPolicy};
    pub use crate::linalg::{Mat, MatMut, MatRef};
    pub use crate::platform::{BackendKind, Platform, PlatformBuilder};
}
