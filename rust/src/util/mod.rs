//! Cross-cutting helpers: wall-clock timing, table formatting for the bench
//! harnesses, and a tiny property-testing framework (no external crates are
//! available in this environment, so `proptest`-style checks are built here).

pub mod bench;
pub mod json;
pub mod proptest;
pub mod tables;

use std::time::Instant;

/// Measure wall-clock seconds of a closure, returning `(result, seconds)`.
pub fn timed<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// GFLOPS for a gemm of the given dims over `secs`.
pub fn gemm_gflops(m: usize, n: usize, k: usize, secs: f64) -> f64 {
    (2.0 * m as f64 * n as f64 * k as f64) / secs / 1e9
}

/// Human-readable byte count.
pub fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 20 {
        format!("{:.2} MiB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2} KiB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gflops_math() {
        // 2*192*256*4096 flops in 0.114114 s = 3.529 GFLOPS (paper Table 1).
        let g = gemm_gflops(192, 256, 4096, 0.114114);
        assert!((g - 3.529).abs() < 0.005, "g = {g}");
    }

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.00 MiB");
    }
}
