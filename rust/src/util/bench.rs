//! Tiny benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets in this repo use `harness = false` and call
//! [`BenchRun`] directly. Each measurement reports min/median/mean over a
//! configurable number of iterations with warmup, which is enough fidelity
//! for the paper-table comparisons (the projected-Parallella numbers come
//! from the calibrated model, not from wall-clock).

use crate::util::json::Json;
use anyhow::Result;
use std::path::PathBuf;
use std::time::Instant;

/// Write a bench's machine-readable output to `BENCH_<name>.json` at the
/// repository root (the roadmap's perf-trajectory input) and return the
/// path. The caller provides already-serialized JSON; content is written
/// atomically enough for CI (single write).
pub fn write_bench_json(name: &str, json: &str) -> std::io::Result<PathBuf> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(format!("BENCH_{name}.json"));
    std::fs::write(&path, format!("{json}\n"))?;
    Ok(path)
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// What was measured.
    pub name: String,
    /// Timed iterations (after warmup).
    pub iters: usize,
    /// Fastest sample, seconds.
    pub min_s: f64,
    /// Median sample, seconds.
    pub median_s: f64,
    /// Mean sample, seconds.
    pub mean_s: f64,
}

impl Measurement {
    /// One human-readable report line.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} iters={:<3} min={:.6}s median={:.6}s mean={:.6}s",
            self.name, self.iters, self.min_s, self.median_s, self.mean_s
        )
    }
}

/// Harness configuration; honours `BENCH_QUICK=1` for CI-speed runs.
pub struct BenchRun {
    warmup: usize,
    iters: usize,
}

impl BenchRun {
    /// Default harness: 1 warmup + 5 iters, or 0 + 1 under `BENCH_QUICK=1`.
    pub fn new() -> Self {
        if std::env::var("BENCH_QUICK").ok().as_deref() == Some("1") {
            BenchRun { warmup: 0, iters: 1 }
        } else {
            BenchRun { warmup: 1, iters: 5 }
        }
    }

    /// Explicit warmup/iteration counts.
    pub fn with_iters(warmup: usize, iters: usize) -> Self {
        BenchRun { warmup, iters }
    }

    /// Time `f` and return the measurement (also printed).
    pub fn measure<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            min_s: samples[0],
            median_s: samples[samples.len() / 2],
            mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
        };
        println!("{}", m.summary());
        m
    }
}

impl Default for BenchRun {
    fn default() -> Self {
        Self::new()
    }
}

/// One metric present in both the committed and the fresh snapshot.
#[derive(Clone, Debug)]
pub struct MetricDelta {
    /// Dotted metric path (e.g. `checks.t3.gflops`).
    pub name: String,
    /// Value in the committed snapshot.
    pub committed: f64,
    /// Value in the fresh run.
    pub fresh: f64,
    /// Whether this metric gates CI. `checks` metrics come from the
    /// deterministic calibrated model / seeded runs, so any large drift
    /// means the code changed behaviour; table-cell metrics are wall
    /// clock on whatever machine ran the bench and only annotate.
    pub gate: bool,
}

impl MetricDelta {
    /// Signed relative change `(fresh - committed) / |committed|`.
    pub fn rel_change(&self) -> f64 {
        if self.committed == 0.0 {
            if self.fresh == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            (self.fresh - self.committed) / self.committed.abs()
        }
    }
}

/// The result of diffing one fresh bench JSON against its committed
/// snapshot (see [`compare_bench_json`]).
#[derive(Clone, Debug, Default)]
pub struct BenchComparison {
    /// Metrics present on both sides.
    pub deltas: Vec<MetricDelta>,
    /// Metric names only in the committed snapshot (removed by the run).
    pub only_committed: Vec<String>,
    /// Metric names only in the fresh run (new; never gate).
    pub only_fresh: Vec<String>,
}

impl BenchComparison {
    /// Gating metrics whose |relative change| exceeds `threshold`
    /// (0.30 = the CI bench-regression bar).
    pub fn regressions(&self, threshold: f64) -> Vec<&MetricDelta> {
        self.deltas
            .iter()
            .filter(|d| d.gate && d.rel_change().abs() > threshold)
            .collect()
    }

    /// Human-readable diff report: regressions first, then report-only
    /// drift beyond the threshold, then added/removed metrics.
    pub fn render(&self, threshold: f64) -> String {
        let mut out = String::new();
        let pct = |d: &MetricDelta| format!("{:+.1}%", 100.0 * d.rel_change());
        for d in self.regressions(threshold) {
            out.push_str(&format!(
                "REGRESSION {}: {} -> {} ({})\n",
                d.name,
                d.committed,
                d.fresh,
                pct(d)
            ));
        }
        let gates = self.deltas.iter().filter(|d| d.gate).count();
        out.push_str(&format!(
            "{} gating metric(s) compared, {} over the {:.0}% bar\n",
            gates,
            self.regressions(threshold).len(),
            100.0 * threshold
        ));
        for d in &self.deltas {
            if !d.gate && d.rel_change().abs() > threshold {
                out.push_str(&format!(
                    "note (wall-clock, report-only) {}: {} -> {} ({})\n",
                    d.name,
                    d.committed,
                    d.fresh,
                    pct(d)
                ));
            }
        }
        for n in &self.only_fresh {
            out.push_str(&format!("new metric (fresh only): {n}\n"));
        }
        for n in &self.only_committed {
            out.push_str(&format!("metric removed (committed only): {n}\n"));
        }
        out
    }
}

/// Extract `(name, value, gate)` metrics from a bench JSON document.
///
/// Two shapes are understood, matching everything this repo writes:
/// objects carrying a `checks` array (`{"name","paper","ours","ratio"}`
/// rows — the deterministic table benches; `ours` gates) and
/// [`super::tables::Table::to_json`] objects (`{"title","headers","rows"}`
/// — wall-clock cells; report-only). Both are found at any nesting depth.
pub fn bench_metrics(doc: &Json) -> Vec<(String, f64, bool)> {
    let mut out = Vec::new();
    walk_metrics("", doc, &mut out);
    out
}

fn walk_metrics(path: &str, v: &Json, out: &mut Vec<(String, f64, bool)>) {
    let join = |suffix: &str| {
        if path.is_empty() {
            suffix.to_string()
        } else {
            format!("{path}.{suffix}")
        }
    };
    if let Some(checks) = v.get("checks").and_then(Json::as_arr) {
        for c in checks {
            let (Some(name), Some(ours)) = (
                c.get("name").and_then(Json::as_str),
                c.get("ours").and_then(Json::as_f64),
            ) else {
                continue;
            };
            out.push((join(&format!("checks.{name}")), ours, true));
        }
    }
    if let (Some(headers), Some(rows)) = (
        v.get("headers").and_then(Json::as_arr),
        v.get("rows").and_then(Json::as_arr),
    ) {
        for (ri, row) in rows.iter().enumerate() {
            let Some(cells) = row.as_arr() else { continue };
            let label = cells.first().and_then(Json::as_str).unwrap_or("");
            for (ci, cell) in cells.iter().enumerate().skip(1) {
                let header = headers.get(ci).and_then(Json::as_str).unwrap_or("?");
                if let Some(num) = cell.as_str().and_then(cell_num) {
                    out.push((join(&format!("{label}[{ri}].{header}")), num, false));
                }
            }
        }
    }
    if let Some(fields) = v.as_obj() {
        for (key, child) in fields {
            if matches!(key.as_str(), "checks" | "headers" | "rows" | "rendered") {
                continue;
            }
            if matches!(child, Json::Obj(_) | Json::Arr(_)) {
                walk_metrics(&join(key), child, out);
            }
        }
    }
}

/// Parse a table cell as a number: plain floats, plus `1.85x`-style
/// speedup cells. Labels like `16x16x16` or `-` yield `None`.
fn cell_num(s: &str) -> Option<f64> {
    let t = s.trim();
    t.parse::<f64>().ok().or_else(|| t.strip_suffix('x').and_then(|p| p.parse::<f64>().ok()))
}

/// Diff a fresh bench JSON against its committed snapshot: per-metric
/// deltas for shared metrics, added/removed listed separately (new
/// metrics never gate, so snapshots can grow columns without breaking
/// older CI refs). See [`BenchComparison::regressions`] for the gate.
pub fn compare_bench_json(committed: &str, fresh: &str) -> Result<BenchComparison> {
    let old = bench_metrics(&Json::parse(committed)?);
    let new = bench_metrics(&Json::parse(fresh)?);
    let mut cmp = BenchComparison::default();
    for (name, committed_v, gate) in &old {
        match new.iter().find(|(n, _, _)| n == name) {
            Some(&(_, fresh_v, _)) => cmp.deltas.push(MetricDelta {
                name: name.clone(),
                committed: *committed_v,
                fresh: fresh_v,
                gate: *gate,
            }),
            None => cmp.only_committed.push(name.clone()),
        }
    }
    for (name, _, _) in &new {
        if !old.iter().any(|(n, _, _)| n == name) {
            cmp.only_fresh.push(name.clone());
        }
    }
    Ok(cmp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = BenchRun::with_iters(0, 3);
        let m = b.measure("noop-ish", || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(m.iters, 3);
        assert!(m.min_s <= m.median_s && m.median_s <= m.mean_s * 3.0);
    }

    const COMMITTED: &str = r#"{
        "table": "t3", "rendered": "...",
        "checks": [
            {"name": "t3.gflops", "paper": 2.1, "ours": 2.0, "ratio": 0.95},
            {"name": "t3.err", "paper": 1.0e-6, "ours": 1.1e-6, "ratio": 1.1}
        ],
        "wall": {"title": "w", "headers": ["size", "s", "speedup"],
                 "rows": [["192x256", "0.5", "1.8x"], ["tiny", "-", "2.0x"]]}
    }"#;

    #[test]
    fn comparator_gates_checks_and_reports_tables() {
        // Fresh run: one gate metric regressed 50%, wall clock halved
        // (report-only), one new check appeared.
        let fresh = r#"{
            "table": "t3", "rendered": "...",
            "checks": [
                {"name": "t3.gflops", "paper": 2.1, "ours": 1.0, "ratio": 0.48},
                {"name": "t3.err", "paper": 1.0e-6, "ours": 1.1e-6, "ratio": 1.1},
                {"name": "t3.speedup", "paper": 2.0, "ours": 2.2, "ratio": 1.1}
            ],
            "wall": {"title": "w", "headers": ["size", "s", "speedup"],
                     "rows": [["192x256", "0.25", "1.9x"], ["tiny", "-", "2.0x"]]}
        }"#;
        let cmp = compare_bench_json(COMMITTED, fresh).unwrap();
        let regs = cmp.regressions(0.30);
        assert_eq!(regs.len(), 1, "only the drifted check gates: {regs:?}");
        assert_eq!(regs[0].name, "checks.t3.gflops");
        assert!((regs[0].rel_change() + 0.5).abs() < 1e-12);
        assert_eq!(cmp.only_fresh, vec!["checks.t3.speedup".to_string()]);
        assert!(cmp.only_committed.is_empty());
        // The halved wall-clock cell is present but never gates.
        let wall = cmp.deltas.iter().find(|d| d.name == "wall.192x256[0].s").unwrap();
        assert!(!wall.gate && wall.rel_change() < -0.45);
        // Speedup cells parse through the trailing 'x'; "-" cells drop out.
        assert!(cmp.deltas.iter().any(|d| d.name == "wall.192x256[0].speedup"));
        assert!(cmp.deltas.iter().any(|d| d.name == "wall.tiny[1].speedup"));
        assert!(!cmp.deltas.iter().any(|d| d.name.contains("tiny[1].s")));
        let report = cmp.render(0.30);
        assert!(report.contains("REGRESSION checks.t3.gflops"));
        assert!(report.contains("new metric (fresh only): checks.t3.speedup"));
    }

    #[test]
    fn comparator_is_clean_on_identical_snapshots() {
        let cmp = compare_bench_json(COMMITTED, COMMITTED).unwrap();
        assert!(cmp.regressions(0.30).is_empty());
        assert!(cmp.only_committed.is_empty() && cmp.only_fresh.is_empty());
        assert!(cmp.deltas.iter().all(|d| d.rel_change() == 0.0));
    }

    #[test]
    fn comparator_reads_committed_table_snapshots() {
        // Every committed BENCH_table*.json must diff cleanly against
        // itself and expose its checks as gating metrics.
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let mut seen = 0;
        for i in 1..=7 {
            let path = root.join(format!("BENCH_table{i}.json"));
            let Ok(text) = std::fs::read_to_string(&path) else { continue };
            let cmp = compare_bench_json(&text, &text).unwrap();
            assert!(
                cmp.deltas.iter().any(|d| d.gate),
                "table{i} snapshot exposes no gating metrics"
            );
            assert!(cmp.regressions(0.30).is_empty());
            seen += 1;
        }
        assert!(seen >= 5, "expected committed table snapshots, saw {seen}");
    }
}
