//! Tiny benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets in this repo use `harness = false` and call
//! [`BenchRun`] directly. Each measurement reports min/median/mean over a
//! configurable number of iterations with warmup, which is enough fidelity
//! for the paper-table comparisons (the projected-Parallella numbers come
//! from the calibrated model, not from wall-clock).

use std::path::PathBuf;
use std::time::Instant;

/// Write a bench's machine-readable output to `BENCH_<name>.json` at the
/// repository root (the roadmap's perf-trajectory input) and return the
/// path. The caller provides already-serialized JSON; content is written
/// atomically enough for CI (single write).
pub fn write_bench_json(name: &str, json: &str) -> std::io::Result<PathBuf> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(format!("BENCH_{name}.json"));
    std::fs::write(&path, format!("{json}\n"))?;
    Ok(path)
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// What was measured.
    pub name: String,
    /// Timed iterations (after warmup).
    pub iters: usize,
    /// Fastest sample, seconds.
    pub min_s: f64,
    /// Median sample, seconds.
    pub median_s: f64,
    /// Mean sample, seconds.
    pub mean_s: f64,
}

impl Measurement {
    /// One human-readable report line.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} iters={:<3} min={:.6}s median={:.6}s mean={:.6}s",
            self.name, self.iters, self.min_s, self.median_s, self.mean_s
        )
    }
}

/// Harness configuration; honours `BENCH_QUICK=1` for CI-speed runs.
pub struct BenchRun {
    warmup: usize,
    iters: usize,
}

impl BenchRun {
    /// Default harness: 1 warmup + 5 iters, or 0 + 1 under `BENCH_QUICK=1`.
    pub fn new() -> Self {
        if std::env::var("BENCH_QUICK").ok().as_deref() == Some("1") {
            BenchRun { warmup: 0, iters: 1 }
        } else {
            BenchRun { warmup: 1, iters: 5 }
        }
    }

    /// Explicit warmup/iteration counts.
    pub fn with_iters(warmup: usize, iters: usize) -> Self {
        BenchRun { warmup, iters }
    }

    /// Time `f` and return the measurement (also printed).
    pub fn measure<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            min_s: samples[0],
            median_s: samples[samples.len() / 2],
            mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
        };
        println!("{}", m.summary());
        m
    }
}

impl Default for BenchRun {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = BenchRun::with_iters(0, 3);
        let m = b.measure("noop-ish", || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(m.iters, 3);
        assert!(m.min_s <= m.median_s && m.median_s <= m.mean_s * 3.0);
    }
}
