//! Minimal property-based testing support.
//!
//! No `proptest`/`quickcheck` crates are available offline, so this module
//! provides the 10% of the idea the test suite needs: seeded generators,
//! many-case runners, and greedy shrinking for integer tuples. Failures
//! print the seed and the (shrunk) counterexample.

use crate::linalg::XorShiftRng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    /// How many generated cases to run.
    pub cases: usize,
    /// Base seed; each case derives its own stream from it.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cfg.cases` generated values. `gen` receives a fresh RNG
/// stream per case. Panics with the failing case index + seed on failure.
pub fn forall<V: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut XorShiftRng) -> V,
    mut prop: impl FnMut(&V) -> bool,
) {
    for case in 0..cfg.cases {
        let mut rng = XorShiftRng::new(cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B9));
        let v = gen(&mut rng);
        if !prop(&v) {
            panic!("property failed at case {case} (seed {:#x}): {v:?}", cfg.seed);
        }
    }
}

/// Like [`forall`] but with greedy shrinking: `shrink` proposes smaller
/// candidates; the smallest still-failing value is reported.
pub fn forall_shrink<V: std::fmt::Debug + Clone>(
    cfg: Config,
    mut gen: impl FnMut(&mut XorShiftRng) -> V,
    shrink: impl Fn(&V) -> Vec<V>,
    mut prop: impl FnMut(&V) -> bool,
) {
    for case in 0..cfg.cases {
        let mut rng = XorShiftRng::new(cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B9));
        let v = gen(&mut rng);
        if !prop(&v) {
            // Greedy descent: keep taking the first failing shrink.
            let mut worst = v.clone();
            'outer: loop {
                for cand in shrink(&worst) {
                    if !prop(&cand) {
                        worst = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed at case {case} (seed {:#x}); original {v:?}, shrunk to {worst:?}",
                cfg.seed
            );
        }
    }
}

/// Generator helpers for common shapes.
pub mod gen {
    use crate::linalg::XorShiftRng;

    /// Matrix dims `(m, n, k)` with each in `[1, max]`.
    pub fn dims(rng: &mut XorShiftRng, max: usize) -> (usize, usize, usize) {
        (1 + rng.next_below(max), 1 + rng.next_below(max), 1 + rng.next_below(max))
    }

    /// Standard shrinker for a dim triple: halve each coordinate.
    pub fn shrink_dims(d: &(usize, usize, usize)) -> Vec<(usize, usize, usize)> {
        let mut out = Vec::new();
        let (m, n, k) = *d;
        if m > 1 {
            out.push((m / 2, n, k));
        }
        if n > 1 {
            out.push((m, n / 2, k));
        }
        if k > 1 {
            out.push((m, n, k / 2));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_true_prop() {
        forall(Config::default(), |r| r.next_below(100), |&v| v < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(Config { cases: 200, seed: 1 }, |r| r.next_below(100), |&v| v < 50);
    }

    #[test]
    fn shrink_finds_minimal() {
        // Property "m < 8" fails for m >= 8; greedy halving should land at
        // a value in [8, 15] (halving once more would pass).
        let result = std::panic::catch_unwind(|| {
            forall_shrink(
                Config { cases: 50, seed: 2 },
                |r| (8 + r.next_below(100), 1usize, 1usize),
                |v| gen::shrink_dims(v),
                |&(m, _, _)| m < 8,
            )
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk to"), "{msg}");
    }
}
