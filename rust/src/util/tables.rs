//! Plain-text table rendering for the bench harnesses — the benches print
//! the same rows the paper's tables report, side by side with our numbers.

/// A simple left-aligned text table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// A titled table with the given column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (arity must match the headers).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render to aligned plain text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:<w$} | "));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Render to a machine-readable JSON object
    /// `{"title": .., "headers": [..], "rows": [[..], ..]}` (hand-rolled:
    /// no serde offline).
    pub fn to_json(&self) -> String {
        let cells = |row: &[String]| {
            let quoted: Vec<String> = row.iter().map(|c| json_string(c)).collect();
            format!("[{}]", quoted.join(","))
        };
        let rows: Vec<String> = self.rows.iter().map(|r| cells(r)).collect();
        format!(
            "{{\"title\":{},\"headers\":{},\"rows\":[{}]}}",
            json_string(&self.title),
            cells(&self.headers),
            rows.join(",")
        )
    }
}

/// Quote + escape `s` as a JSON string literal.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a float like the paper's GFLOPS columns.
pub fn gf(v: f64) -> String {
    format!("{v:.3}")
}

/// Format a residue like the paper's scientific-notation columns.
pub fn sci(v: f64) -> String {
    format!("{v:.2e}")
}

/// Format seconds.
pub fn secs(v: f64) -> String {
    format!("{v:.6}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "v"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("| a      | 1"));
        assert!(r.contains("| longer | 22"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["x".into()]);
    }

    #[test]
    fn json_rendering_escapes_and_nests() {
        let mut t = Table::new("T \"q\"", &["a", "b"]);
        t.row(&["x\n".into(), "1".into()]);
        let j = t.to_json();
        assert_eq!(
            j,
            "{\"title\":\"T \\\"q\\\"\",\"headers\":[\"a\",\"b\"],\"rows\":[[\"x\\n\",\"1\"]]}"
        );
    }

    #[test]
    fn formatters() {
        assert_eq!(gf(2.3806), "2.381");
        assert_eq!(sci(8.73e-8), "8.73e-8");
        assert_eq!(sci(1.18e-7), "1.18e-7");
        assert_eq!(secs(0.114114), "0.114114");
    }
}
