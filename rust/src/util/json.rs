//! A minimal JSON value parser (no serde offline). Only what the bench
//! snapshot comparator needs: the full JSON grammar parsed into a small
//! value tree, with path helpers. Writers stay hand-rolled (see
//! [`super::tables::Table::to_json`]); this is the matching reader.

use anyhow::{bail, ensure, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (f64 is enough for bench metrics).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object, in source order (duplicate keys keep the last).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The fields, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        ensure!(self.peek() == Some(c), "expected '{}' at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "expected '{word}' at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            fields.push((key, self.value()?));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else { bail!("unterminated string") };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else { bail!("dangling escape") };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Lone surrogates degrade to the replacement
                            // char — bench snapshots never contain them.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("bad escape '\\{}'", other as char),
                    }
                }
                _ => {
                    // Re-walk UTF-8 from the byte cursor: strings are the
                    // only place multi-byte sequences can appear.
                    let start = self.i - 1;
                    let s = std::str::from_utf8(&self.b[start..])?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_nesting() {
        let v = Json::parse(r#"{"a": [1, -2.5, 1e3], "b": {"c": null, "d": true}, "e": "x"}"#)
            .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(-2.5));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(1000.0));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Json::Null));
        assert_eq!(v.get("b").unwrap().get("d"), Some(&Json::Bool(true)));
        assert_eq!(v.get("e").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""q\"\\\n\tApäö""#).unwrap();
        assert_eq!(v.as_str(), Some("q\"\\\n\tApäö"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn round_trips_table_to_json() {
        let mut t = crate::util::tables::Table::new("T \"q\"", &["a", "b"]);
        t.row(&["x\n".into(), "1.5".into()]);
        let v = Json::parse(&t.to_json()).unwrap();
        assert_eq!(v.get("title").unwrap().as_str(), Some("T \"q\""));
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].as_arr().unwrap()[0].as_str(), Some("x\n"));
    }

    #[test]
    fn parses_every_committed_snapshot() {
        // The committed BENCH_*.json artifacts must stay parseable by the
        // comparator's own reader, not just python's.
        let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let mut seen = 0;
        for entry in std::fs::read_dir(&root).unwrap() {
            let path = entry.unwrap().path();
            let name = path.file_name().unwrap().to_string_lossy().to_string();
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                let text = std::fs::read_to_string(&path).unwrap();
                Json::parse(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
                seen += 1;
            }
        }
        assert!(seen >= 10, "expected the committed snapshots, saw {seen}");
    }
}
