//! The Parallella host side (paper §3.2–3.3): the sgemm inner micro-kernel
//! (SUMMA loop + command protocol), the separate "service process" that
//! owns the Epiphany connection, and the HH-RAM / semaphore IPC between
//! them.
//!
//! Substitutions vs the paper (DESIGN.md §2): the service is a resident
//! *thread* rather than a Linux daemon — same serialization points, same
//! data motion, no PJRT-across-processes complications — and its IPC cost
//! is charged by the calibrated model (Table 2 − Table 1).

pub mod microkernel;
pub mod pool;
pub mod projection;
pub mod service;
pub mod shm;

pub use microkernel::{InnerMicroKernel, UkrBackend, UkrOutput};
pub use pool::{ChipPool, ShardPolicy};
pub use projection::{Projection, ProjectionParams};
pub use service::{ServiceHandle, ServiceRequest, ServiceResponse};
pub use shm::{HhRam, Semaphore};
