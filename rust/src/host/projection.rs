//! Projected-Parallella timing: compose the calibrated model into the
//! paper's reported quantities (input / coprocessor / post / IPC seconds
//! and GFLOPS) for any µ-kernel call or full BLIS gemm.
//!
//! The pipeline structure follows §3.3: the host upload of panel `t+1`
//! overlaps the coprocessor's work on panel `t` (the double-buffer
//! `selector`), so total time is a max-chain, not a sum — which is how the
//! paper's Table 1 percentages (82.9% + 92.6% > 100%) come about.

use crate::epiphany::timing::{CalibratedModel, WalkClass};

/// Inputs to a µ-kernel-call projection.
#[derive(Clone, Copy, Debug)]
pub struct ProjectionParams {
    /// Tile rows (192 in the paper).
    pub m: usize,
    /// Tile columns (256 in the paper).
    pub n: usize,
    /// Contraction depth of the call.
    pub k: usize,
    /// Panel depth per Epiphany Task (64 in the paper).
    pub ksub: usize,
    /// Columns finalized per core per Column Iteration (4 in the paper).
    pub nsub: usize,
    /// Upload walk class of the A panel (contig unless op(A) = T).
    pub class_a: WalkClass,
    /// Upload walk class of the B panel (strided unless op(B) = T).
    pub class_b: WalkClass,
    /// Whether the call crosses the HH-RAM service IPC (Table 2 vs 1).
    pub ipc: bool,
    /// False dgemm: f64 HH-RAM traffic + downcast/upcast passes.
    pub dgemm: bool,
    /// BLIS-layer per-call overhead (Tables 3–6 vs custom tests).
    pub blis: bool,
}

impl ProjectionParams {
    /// The paper's custom-test configuration (Table 1 row set).
    pub fn kernel_same_process(k: usize) -> Self {
        ProjectionParams {
            m: 192,
            n: 256,
            k,
            ksub: 64,
            nsub: 4,
            class_a: WalkClass::Contig,
            class_b: WalkClass::Contig,
            ipc: false,
            dgemm: false,
            blis: false,
        }
    }

    /// Table 2: same kernel through the service process.
    pub fn kernel_service(k: usize) -> Self {
        ProjectionParams { ipc: true, ..Self::kernel_same_process(k) }
    }
}

/// Projected seconds, broken down the way the paper reports them.
#[derive(Clone, Copy, Debug, Default)]
pub struct Projection {
    /// "Input loading and host preprocessing" (overlapped with coproc).
    pub input_s: f64,
    /// "Coprocessor work" (DMA-in + compute + result write-back).
    pub coproc_s: f64,
    /// "Host data retrieving and post-processing".
    pub post_s: f64,
    /// HH-RAM + semaphore IPC (zero for same-process calls).
    pub ipc_s: f64,
    /// f64↔f32 cast passes (false dgemm only).
    pub cast_s: f64,
    /// BLIS bookkeeping overhead.
    pub blis_s: f64,
    /// End-to-end seconds respecting the upload/compute overlap.
    pub total_s: f64,
}

impl Projection {
    /// Flop rate of an (m, n, k) gemm against the projected total time.
    pub fn gflops(&self, m: usize, n: usize, k: usize) -> f64 {
        2.0 * m as f64 * n as f64 * k as f64 / self.total_s / 1e9
    }
}

/// Project one µ-kernel call (the paper's "sgemm inner micro-kernel" plus
/// its process wrapping).
pub fn project_ukr_call(model: &CalibratedModel, p: &ProjectionParams) -> Projection {
    let tasks = p.k.div_ceil(p.ksub).max(1);
    let a_bytes = p.m * p.ksub * 4;
    let b_bytes = p.ksub * p.n * 4;
    let in_bytes = a_bytes + b_bytes;
    let out_bytes = p.m * p.n * 4;

    // Per-task host upload: A and B parts may have different walk classes.
    let upload =
        model.upload_s(a_bytes, p.class_a) + model.upload_s(b_bytes, p.class_b);
    // Per-task coprocessor occupancy: e-link DMA in + lock-step compute.
    let col_iters = p.n / (crate::epiphany::CORES * p.nsub);
    let compute = model.task_compute_s(
        p.m,
        p.nsub,
        p.ksub / crate::epiphany::CORES,
        col_iters,
        crate::epiphany::CORES,
    );
    let coproc = model.task_coproc_s(in_bytes, compute);

    // The §3.3 pipeline: upload t+1 overlaps coproc t.
    let mut host_free = 0.0f64; // when the host finishes upload t
    let mut chip_free = 0.0f64; // when the chip finishes task t
    for t in 0..tasks {
        host_free += upload;
        let start = if t == 0 { host_free } else { host_free.max(chip_free) };
        // chip can't start task t before its upload is done nor before it
        // finished task t-1.
        let begin = start.max(chip_free);
        chip_free = begin + coproc;
    }
    // Result write-back (last task, command = 2/3).
    let writeback = out_bytes as f64 / model.w_chip_write;
    chip_free += writeback;

    let input_s = tasks as f64 * upload;
    let coproc_s = tasks as f64 * coproc + writeback;

    // Post: slow HC-RAM read + αβ epilogue on the host.
    let post_flops = 2.0 * (p.m * p.n) as f64;
    let post_s =
        out_bytes as f64 / model.w_host_read + post_flops / (model.host_stream_gflops * 1e9);

    // IPC through HH-RAM (write by caller + read by service, both ways).
    let elem_bytes = if p.dgemm { 8 } else { 4 };
    let in_total = (p.m * p.k + p.k * p.n + p.m * p.n) * elem_bytes;
    let out_total = p.m * p.n * elem_bytes;
    let ipc_s = if p.ipc {
        2.0 * (in_total + out_total) as f64 / model.hh_ram_bw + 4.0 * model.ipc_signal_s
    } else {
        0.0
    };

    // False dgemm: downcast inputs, upcast output (element-rate passes).
    let cast_s = if p.dgemm {
        ((p.m * p.k + p.k * p.n + p.m * p.n) + p.m * p.n) as f64 / model.cast_elems_per_s
    } else {
        0.0
    };

    let blis_s = if p.blis { model.blis_call_overhead_s } else { 0.0 };

    Projection {
        input_s,
        coproc_s,
        post_s,
        ipc_s,
        cast_s,
        blis_s,
        total_s: chip_free + post_s + ipc_s + cast_s + blis_s,
    }
}

/// Project the naive host reference gemm (Table 1 row 1).
pub fn project_host_ref(model: &CalibratedModel, m: usize, n: usize, k: usize) -> f64 {
    2.0 * m as f64 * n as f64 * k as f64 / (model.host_ref_gflops * 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CalibratedModel {
        CalibratedModel::default()
    }

    #[test]
    fn table1_reproduced_within_2pct() {
        // Paper Table 1 (same process, M=192 N=256 K=4096):
        // input 0.094648 s, coproc 0.105652 s, post 0.005272 s,
        // total 0.114114 s, 3.529 GFLOPS; host ref 3.778169 s / 0.107 GF.
        let p = ProjectionParams::kernel_same_process(4096);
        let proj = project_ukr_call(&model(), &p);
        let within = |got: f64, want: f64, tol: f64| (got / want - 1.0).abs() < tol;
        assert!(within(proj.input_s, 0.094648, 0.02), "input {}", proj.input_s);
        assert!(within(proj.coproc_s, 0.105652, 0.02), "coproc {}", proj.coproc_s);
        assert!(within(proj.post_s, 0.005272, 0.10), "post {}", proj.post_s);
        assert!(within(proj.total_s, 0.114114, 0.03), "total {}", proj.total_s);
        let gf = proj.gflops(192, 256, 4096);
        assert!(within(gf, 3.529, 0.03), "gflops {gf}");
        let href = project_host_ref(&model(), 192, 256, 4096);
        assert!(within(href, 3.778169, 0.01), "host ref {href}");
    }

    #[test]
    fn table2_reproduced_within_3pct() {
        // Paper Table 2: total 0.158303 s, 2.543 GFLOPS.
        let p = ProjectionParams::kernel_service(4096);
        let proj = project_ukr_call(&model(), &p);
        let gf = proj.gflops(192, 256, 4096);
        assert!((proj.total_s / 0.158303 - 1.0).abs() < 0.03, "total {}", proj.total_s);
        assert!((gf / 2.543 - 1.0).abs() < 0.03, "gflops {gf}");
    }

    #[test]
    fn overlap_totals_less_than_sum() {
        let p = ProjectionParams::kernel_same_process(4096);
        let proj = project_ukr_call(&model(), &p);
        // The overlap must make total < input + coproc + post (the >100%
        // percentage-column effect of Table 1).
        assert!(proj.total_s < proj.input_s + proj.coproc_s + proj.post_s);
        // And the percentages vs total reproduce the shape: both large.
        assert!(proj.input_s / proj.total_s > 0.78);
        assert!(proj.coproc_s / proj.total_s > 0.88);
    }

    #[test]
    fn strided_a_uploads_dominate() {
        // With op(A) = T the upload becomes the bottleneck (Table 4 tn row).
        let mut p = ProjectionParams::kernel_service(4096);
        p.class_a = WalkClass::StridedA;
        let slow = project_ukr_call(&model(), &p);
        let fast = project_ukr_call(&model(), &ProjectionParams::kernel_service(4096));
        assert!(slow.total_s > fast.total_s * 1.08, "{} vs {}", slow.total_s, fast.total_s);
    }

    #[test]
    fn small_k_single_task() {
        let p = ProjectionParams::kernel_same_process(64);
        let proj = project_ukr_call(&model(), &p);
        assert!(proj.total_s > 0.0 && proj.total_s < 0.01);
    }
}
