//! The "sgemm inner micro-kernel" (paper §3.3): the host-side SUMMA loop
//! that streams KSUB panel pairs to the coprocessor with the
//! command/selector protocol and applies the α/β epilogue.
//!
//! Three interchangeable backends compute the heavy part:
//!
//! * [`UkrBackend::Simulator`] — the functional Epiphany simulator behind
//!   the eSDK driver (bit-level faithful to the on-chip dataflow);
//! * [`UkrBackend::Pjrt`] — the AOT-compiled L2/L1 jax+pallas artifact via
//!   the PJRT runtime (the production path: fast numerics, model timing);
//! * [`UkrBackend::HostRef`] — the naive triple loop, i.e. the paper's
//!   "Host reference code" baseline.
//!
//! All backends produce the same mathematical result; tests pin them
//! against each other.

use super::projection::{project_ukr_call, Projection, ProjectionParams};
use crate::epiphany::kernel::{Command, KernelGeometry};
use crate::epiphany::timing::CalibratedModel;
use crate::esdk::EHal;
use crate::runtime::GemmExecutor;
use anyhow::{ensure, Result};
use std::time::Instant;

/// Who does the heavy part of the calculations.
pub enum UkrBackend {
    /// The functional Epiphany simulator behind an e-hal handle.
    Simulator(EHal),
    /// AOT jax+pallas artifacts through PJRT.
    Pjrt(GemmExecutor),
    /// Naive host loop (baseline).
    HostRef,
}

impl UkrBackend {
    /// Short backend label for reports and errors.
    pub fn name(&self) -> &'static str {
        match self {
            UkrBackend::Simulator(_) => "simulator",
            UkrBackend::Pjrt(_) => "pjrt",
            UkrBackend::HostRef => "host-ref",
        }
    }
}

/// Result of one µ-kernel call.
#[derive(Clone, Debug)]
pub struct UkrOutput {
    /// m × n column-major result.
    pub c: Vec<f32>,
    /// Wall-clock seconds on this machine.
    pub wall_s: f64,
    /// Projected-Parallella breakdown from the calibrated model.
    pub projection: Projection,
}

/// The micro-kernel: fixed (m, n) tile, arbitrary K.
pub struct InnerMicroKernel {
    /// The engine computing the tile products.
    pub backend: UkrBackend,
    /// Calibrated timing constants for the projection.
    pub model: CalibratedModel,
    /// The fixed (m, n, KSUB, NSUB) tile geometry.
    pub geom: KernelGeometry,
}

impl InnerMicroKernel {
    /// Wrap a backend; boots the simulator's e-hal once if needed.
    pub fn new(backend: UkrBackend, model: CalibratedModel, geom: KernelGeometry) -> Result<Self> {
        let mut ukr = InnerMicroKernel { backend, model, geom };
        if let UkrBackend::Simulator(hal) = &mut ukr.backend {
            if !hal.is_open() {
                hal.e_init(geom)?;
            }
        }
        Ok(ukr)
    }

    /// `c_out = alpha · a1·b1 + beta · c_in` over the fixed tile.
    ///
    /// * `a_panel`: column-major m × k
    /// * `b_panel`: row-major k × n
    /// * `c_in`: column-major m × n
    /// * `params`: projection context (walk classes, ipc/dgemm/blis flags);
    ///   its dims are overwritten from the call.
    pub fn sgemm(
        &mut self,
        alpha: f32,
        a_panel: &[f32],
        b_panel: &[f32],
        beta: f32,
        c_in: &[f32],
        mut params: ProjectionParams,
    ) -> Result<UkrOutput> {
        let (m, n) = (self.geom.m, self.geom.n);
        let k = if m > 0 { a_panel.len() / m } else { 0 };
        ensure!(a_panel.len() == m * k, "a panel not m×k");
        ensure!(b_panel.len() == k * n, "b panel len {} != k·n {}", b_panel.len(), k * n);
        ensure!(c_in.len() == m * n, "c panel not m×n");
        params.m = m;
        params.n = n;
        params.k = k;
        params.ksub = self.geom.ksub;
        params.nsub = self.geom.nsub;

        // Reference-BLAS semantics: beta == 0 means C is *not read* (an
        // uninitialized or NaN C must not poison the result). Substitute
        // zeros before any backend sees it.
        let zeros;
        let c_in = if beta == 0.0 {
            zeros = vec![0.0f32; m * n];
            &zeros[..]
        } else {
            c_in
        };

        let t0 = Instant::now();
        let c = match &mut self.backend {
            UkrBackend::HostRef => host_ref_sgemm(m, n, k, alpha, a_panel, b_panel, beta, c_in),
            UkrBackend::Pjrt(ex) => {
                ex.sgemm_arbitrary_k(k, alpha, a_panel, b_panel, beta, c_in)?
            }
            UkrBackend::Simulator(hal) => {
                simulator_sgemm(hal, self.geom, alpha, a_panel, b_panel, beta, c_in, k)?
            }
        };
        let wall_s = t0.elapsed().as_secs_f64();
        let projection = match self.backend {
            // The host reference has no coprocessor pipeline: project at
            // the calibrated naive-loop rate.
            UkrBackend::HostRef => {
                let total = super::projection::project_host_ref(&self.model, m, n, k);
                Projection { total_s: total, ..Default::default() }
            }
            _ => project_ukr_call(&self.model, &params),
        };
        Ok(UkrOutput { c, wall_s, projection })
    }

    /// The paper's "false dgemm": f64 API around the f32 kernel —
    /// downcast inputs, run sgemm, upcast the output (§4.2).
    pub fn false_dgemm(
        &mut self,
        alpha: f64,
        a_panel: &[f64],
        b_panel: &[f64],
        beta: f64,
        c_in: &[f64],
        mut params: ProjectionParams,
    ) -> Result<(Vec<f64>, f64, Projection)> {
        params.dgemm = true;
        let a32: Vec<f32> = a_panel.iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = b_panel.iter().map(|&v| v as f32).collect();
        let c32: Vec<f32> = c_in.iter().map(|&v| v as f32).collect();
        let out = self.sgemm(alpha as f32, &a32, &b32, beta as f32, &c32, params)?;
        Ok((out.c.iter().map(|&v| v as f64).collect(), out.wall_s, out.projection))
    }

    /// Simulator statistics (empty for other backends) — used by tests to
    /// cross-check the analytic projection against executed structure.
    pub fn sim_stats(&self) -> Option<&crate::epiphany::SimStats> {
        match &self.backend {
            UkrBackend::Simulator(hal) => hal.chip().ok().map(|c| &c.stats),
            _ => None,
        }
    }
}

/// The naive triple loop — the paper's "Host reference code".
pub fn host_ref_sgemm(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32], // col-major m×k
    b: &[f32], // row-major k×n
    beta: f32,
    c_in: &[f32], // col-major m×n
) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for j in 0..n {
        for i in 0..m {
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += a[l * m + i] * b[l * n + j];
            }
            c[j * m + i] = alpha * acc + beta * c_in[j * m + i];
        }
    }
    c
}

/// Drive the functional simulator through the SUMMA loop with the command
/// protocol (§3.3): clear on the first task, accumulate in between, send
/// back on the last; α/β applied by the host afterwards.
#[allow(clippy::too_many_arguments)]
fn simulator_sgemm(
    hal: &mut EHal,
    geom: KernelGeometry,
    alpha: f32,
    a_panel: &[f32],
    b_panel: &[f32],
    beta: f32,
    c_in: &[f32],
    k: usize,
) -> Result<Vec<f32>> {
    let (m, n, ksub) = (geom.m, geom.n, geom.ksub);
    let tasks = k.div_ceil(ksub).max(1);
    for t in 0..tasks {
        let selector = t & 1;
        // Slice / zero-pad this KSUB panel pair.
        let k0 = t * ksub;
        let k_real = ksub.min(k - k0.min(k));
        let mut a_t = vec![0.0f32; m * ksub];
        a_t[..m * k_real].copy_from_slice(&a_panel[m * k0..m * (k0 + k_real)]);
        let mut b_t = vec![0.0f32; ksub * n];
        b_t[..k_real * n].copy_from_slice(&b_panel[n * k0..n * (k0 + k_real)]);
        hal.e_write_a(selector, &a_t)?;
        hal.e_write_b(selector, &b_t)?;
        let command = match (t == 0, t == tasks - 1) {
            (true, true) => Command::ClearSend,
            (true, false) => Command::ClearAccumulate,
            (false, true) => Command::AccumulateSend,
            (false, false) => Command::Accumulate,
        };
        hal.e_signal_task(command, selector)?;
    }
    // Retrieve the raw accumulated product and run the host epilogue
    // ("the micro-kernel multiplies the resulting matrix by alpha and adds
    // beta·c_in").
    let mut raw = vec![0.0f32; m * n];
    hal.e_read_out(&mut raw)?;
    for idx in 0..m * n {
        raw[idx] = alpha * raw[idx] + beta * c_in[idx];
    }
    Ok(raw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::timing::WalkClass;
    use crate::linalg::{max_scaled_err, Mat};

    fn row_major(b: &Mat<f32>) -> Vec<f32> {
        let (k, n) = (b.rows(), b.cols());
        let mut out = vec![0.0f32; k * n];
        for l in 0..k {
            for j in 0..n {
                out[l * n + j] = b.get(l, j);
            }
        }
        out
    }

    fn params() -> ProjectionParams {
        ProjectionParams::kernel_same_process(0)
    }

    fn check_backend(mut ukr: InnerMicroKernel, k: usize, tol: f64) {
        let (m, n) = (ukr.geom.m, ukr.geom.n);
        let a = Mat::<f32>::randn(m, k, 100);
        let b = Mat::<f32>::randn(k, n, 101);
        let c = Mat::<f32>::randn(m, n, 102);
        let out =
            ukr.sgemm(1.25, a.as_slice(), &row_major(&b), -0.75, c.as_slice(), params()).unwrap();
        let got = Mat::from_col_major(m, n, &out.c);
        let want = Mat::from_fn(m, n, |i, j| {
            let mut acc = 0.0f64;
            for l in 0..k {
                acc += a.get(i, l) as f64 * b.get(l, j) as f64;
            }
            (1.25 * acc - 0.75 * c.get(i, j) as f64) as f32
        });
        let e = max_scaled_err(got.view(), want.view());
        assert!(e < tol, "{} backend err {e}", ukr.backend.name());
        assert!(out.wall_s > 0.0);
        assert!(out.projection.total_s > 0.0);
    }

    #[test]
    fn host_ref_backend_correct() {
        let ukr = InnerMicroKernel::new(
            UkrBackend::HostRef,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        check_backend(ukr, 192, 1e-5);
    }

    #[test]
    fn simulator_backend_correct() {
        let hal = EHal::new(CalibratedModel::default());
        let ukr = InnerMicroKernel::new(
            UkrBackend::Simulator(hal),
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        check_backend(ukr, 192, 1e-5);
    }

    // Needs the `pjrt` feature + built artifacts.
    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_backend_correct() {
        let ex = GemmExecutor::discover().expect("make artifacts first");
        let ukr = InnerMicroKernel::new(
            UkrBackend::Pjrt(ex),
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        check_backend(ukr, 192, 1e-5);
    }

    #[test]
    fn backends_agree_on_ragged_k() {
        // K = 150 is not a multiple of KSUB: every offload backend must
        // zero-pad identically and agree with the host reference.
        let k = 150;
        let geom = KernelGeometry::paper();
        let a = Mat::<f32>::randn(geom.m, k, 200);
        let b = Mat::<f32>::randn(k, geom.n, 201);
        let c = Mat::<f32>::randn(geom.m, geom.n, 202);
        let b_rm = row_major(&b);

        let run = |backend| {
            let mut ukr =
                InnerMicroKernel::new(backend, CalibratedModel::default(), geom).unwrap();
            ukr.sgemm(1.0, a.as_slice(), &b_rm, 1.0, c.as_slice(), params()).unwrap().c
        };
        let href = run(UkrBackend::HostRef);
        #[allow(unused_mut)] // mutated only when the pjrt feature is on
        let mut offload = vec![(
            "sim",
            run(UkrBackend::Simulator(EHal::new(CalibratedModel::default()))),
        )];
        #[cfg(feature = "pjrt")]
        offload.push(("pjrt", run(UkrBackend::Pjrt(GemmExecutor::discover().unwrap()))));
        let href = Mat::from_col_major(geom.m, geom.n, &href);
        for (name, got) in offload {
            let got = Mat::from_col_major(geom.m, geom.n, &got);
            let e = max_scaled_err(got.view(), href.view());
            assert!(e < 1e-5, "{name} vs host-ref err {e}");
        }
    }

    #[test]
    fn false_dgemm_downcasts() {
        let geom = KernelGeometry::paper();
        let k = 128;
        let mut ukr = InnerMicroKernel::new(
            UkrBackend::Simulator(EHal::new(CalibratedModel::default())),
            CalibratedModel::default(),
            geom,
        )
        .unwrap();
        let a = Mat::<f64>::randn(geom.m, k, 300);
        let b = Mat::<f64>::randn(k, geom.n, 301);
        let c = Mat::<f64>::randn(geom.m, geom.n, 302);
        let mut b_rm = vec![0.0f64; k * geom.n];
        for l in 0..k {
            for j in 0..geom.n {
                b_rm[l * geom.n + j] = b.get(l, j);
            }
        }
        let (got, _, proj) = ukr
            .false_dgemm(1.0, a.as_slice(), &b_rm, 1.0, c.as_slice(), params())
            .unwrap();
        let got = Mat::from_col_major(geom.m, geom.n, &got);
        let want = Mat::from_fn(geom.m, geom.n, |i, j| {
            let mut acc = 0.0f64;
            for l in 0..k {
                acc += a.get(i, l) * b.get(l, j);
            }
            acc + c.get(i, j)
        });
        let e = max_scaled_err(got.view(), want.view());
        // f32-sized error through an f64 API.
        assert!(e > 1e-10 && e < 1e-4, "err {e}");
        assert!(proj.cast_s > 0.0, "cast pass must be charged");
    }

    #[test]
    fn simulator_projection_consistent_with_executed_structure() {
        // The analytic projection's coprocessor share must agree with the
        // coproc time derived from the simulator's executed cycles/bytes.
        let geom = KernelGeometry::paper();
        let k = 4 * geom.ksub;
        let a = Mat::<f32>::randn(geom.m, k, 400);
        let b = Mat::<f32>::randn(k, geom.n, 401);
        let c = Mat::<f32>::zeros(geom.m, geom.n);
        let b_rm = row_major(&b);
        let mut ukr = InnerMicroKernel::new(
            UkrBackend::Simulator(EHal::new(CalibratedModel::default())),
            CalibratedModel::default(),
            geom,
        )
        .unwrap();
        let mut p = params();
        p.class_a = WalkClass::Contig;
        let out = ukr.sgemm(1.0, a.as_slice(), &b_rm, 0.0, c.as_slice(), p).unwrap();
        let stats = ukr.sim_stats().unwrap();
        let sim_coproc = stats.coproc_s(&ukr.model);
        let ana_coproc = out.projection.coproc_s;
        let ratio = sim_coproc / ana_coproc;
        assert!((0.97..1.03).contains(&ratio), "sim {sim_coproc} vs analytic {ana_coproc}");
    }
}
