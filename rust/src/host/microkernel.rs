//! The "sgemm inner micro-kernel" (paper §3.3): the host-side SUMMA loop
//! that streams KSUB panel pairs to the coprocessor with the
//! command/selector protocol and applies the α/β epilogue.
//!
//! Three interchangeable backends compute the heavy part:
//!
//! * [`UkrBackend::Simulator`] — the functional Epiphany simulator behind
//!   the eSDK driver (bit-level faithful to the on-chip dataflow);
//! * [`UkrBackend::Pjrt`] — the AOT-compiled L2/L1 jax+pallas artifact via
//!   the PJRT runtime (the production path: fast numerics, model timing);
//! * [`UkrBackend::HostRef`] — the host compute path, in one of several
//!   [`UkrVariant`] implementations: the paper's naive triple loop (the
//!   oracle), an unroll-and-jam register-blocked kernel that
//!   autovectorizes, and an explicit SSE kernel behind the `simd` feature.
//!
//! All backends and variants produce the same mathematical result; tests
//! pin them against each other. The host variants are in fact *bit*
//! identical: every per-element multiply-add happens in the same order
//! (k ascending, mul then add, no FMA contraction), only the grouping
//! across independent output elements changes.

use super::projection::{project_ukr_call, Projection, ProjectionParams};
use crate::epiphany::kernel::{Command, KernelGeometry};
use crate::epiphany::timing::CalibratedModel;
use crate::esdk::EHal;
use crate::runtime::GemmExecutor;
use anyhow::{ensure, Result};
use std::time::Instant;

/// Who does the heavy part of the calculations.
pub enum UkrBackend {
    /// The functional Epiphany simulator behind an e-hal handle.
    Simulator(EHal),
    /// AOT jax+pallas artifacts through PJRT.
    Pjrt(GemmExecutor),
    /// Host loop (baseline), computed with the kernel's [`UkrVariant`].
    HostRef,
}

impl UkrBackend {
    /// Short backend label for reports and errors.
    pub fn name(&self) -> &'static str {
        match self {
            UkrBackend::Simulator(_) => "simulator",
            UkrBackend::Pjrt(_) => "pjrt",
            UkrBackend::HostRef => "host-ref",
        }
    }
}

/// Register blocking of the vectorized host kernels: rows per i-block.
/// 8 f32 lanes = two SSE vectors (or one AVX vector if the compiler picks
/// it during autovectorization of the blocked form).
pub const UKR_MR: usize = 8;
/// Register blocking of the vectorized host kernels: columns per j-block.
/// 4 columns × 8 rows = 32 accumulators — the unroll-and-jam working set.
pub const UKR_NR: usize = 4;

/// How the host computes a gemm tile (the [`UkrBackend::HostRef`] path and
/// the scalar-vs-vectorized trajectory recorded by the table benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UkrVariant {
    /// The paper's naive triple loop — kept unchanged as the oracle.
    Scalar,
    /// [`UKR_MR`]`×`[`UKR_NR`] unroll-and-jam register blocking over
    /// `chunks_exact` column panels; written so LLVM autovectorizes the
    /// fixed-size accumulator loops.
    Blocked,
    /// Explicit `core::arch` SSE kernel. Only compiled with the `simd`
    /// cargo feature on x86_64; [`UkrVariant::resolve`] falls back to
    /// [`UkrVariant::Blocked`] everywhere else.
    Simd,
}

impl UkrVariant {
    /// Every variant, in conformance-sweep order.
    pub fn all() -> [UkrVariant; 3] {
        [UkrVariant::Scalar, UkrVariant::Blocked, UkrVariant::Simd]
    }

    /// Short label for reports (`scalar` / `blocked` / `simd`).
    pub fn name(self) -> &'static str {
        match self {
            UkrVariant::Scalar => "scalar",
            UkrVariant::Blocked => "blocked",
            UkrVariant::Simd => "simd",
        }
    }

    /// Whether this variant's code path is compiled into this build.
    pub fn available(self) -> bool {
        match self {
            UkrVariant::Scalar | UkrVariant::Blocked => true,
            UkrVariant::Simd => cfg!(all(feature = "simd", target_arch = "x86_64")),
        }
    }

    /// The variant that actually runs: [`UkrVariant::Simd`] degrades to
    /// [`UkrVariant::Blocked`] when the SSE path is not compiled in.
    pub fn resolve(self) -> UkrVariant {
        if self.available() {
            self
        } else {
            UkrVariant::Blocked
        }
    }

    /// The fastest variant compiled into this build.
    pub fn fastest() -> UkrVariant {
        UkrVariant::Simd.resolve()
    }

    /// Parse a variant name as used by the `PARALLELLA_UKR` env knob.
    pub fn parse(s: &str) -> Option<UkrVariant> {
        match s {
            "scalar" => Some(UkrVariant::Scalar),
            "blocked" => Some(UkrVariant::Blocked),
            "simd" => Some(UkrVariant::Simd),
            _ => None,
        }
    }

    /// Runtime selection: `PARALLELLA_UKR=scalar|blocked|simd` when set
    /// (unknown values are ignored), else [`UkrVariant::fastest`].
    pub fn from_env() -> UkrVariant {
        std::env::var("PARALLELLA_UKR")
            .ok()
            .and_then(|v| UkrVariant::parse(&v))
            .unwrap_or_else(UkrVariant::fastest)
            .resolve()
    }
}

/// Result of one µ-kernel call.
#[derive(Clone, Debug)]
pub struct UkrOutput {
    /// m × n column-major result.
    pub c: Vec<f32>,
    /// Wall-clock seconds on this machine.
    pub wall_s: f64,
    /// Projected-Parallella breakdown from the calibrated model.
    pub projection: Projection,
}

/// The micro-kernel: fixed (m, n) tile, arbitrary K.
pub struct InnerMicroKernel {
    /// The engine computing the tile products.
    pub backend: UkrBackend,
    /// Calibrated timing constants for the projection.
    pub model: CalibratedModel,
    /// The fixed (m, n, KSUB, NSUB) tile geometry.
    pub geom: KernelGeometry,
    /// Host compute variant used by [`UkrBackend::HostRef`]. The
    /// Parallella *projection* for that backend is unaffected — it models
    /// the paper's naive loop on the Zynq, not this machine.
    pub variant: UkrVariant,
    // Reusable β==0 substitute (read-only zeros; allocated once per size).
    zeros: Vec<f32>,
    // KSUB staging panels reused across simulator tasks and calls.
    sim_a: Vec<f32>,
    sim_b: Vec<f32>,
}

impl InnerMicroKernel {
    /// Wrap a backend; boots the simulator's e-hal once if needed. The
    /// host variant comes from [`UkrVariant::from_env`].
    pub fn new(backend: UkrBackend, model: CalibratedModel, geom: KernelGeometry) -> Result<Self> {
        Self::with_variant(backend, model, geom, UkrVariant::from_env())
    }

    /// [`InnerMicroKernel::new`] with an explicit host compute variant
    /// (the conformance sweep pins each variant this way).
    pub fn with_variant(
        backend: UkrBackend,
        model: CalibratedModel,
        geom: KernelGeometry,
        variant: UkrVariant,
    ) -> Result<Self> {
        let mut ukr = InnerMicroKernel {
            backend,
            model,
            geom,
            variant: variant.resolve(),
            zeros: Vec::new(),
            sim_a: Vec::new(),
            sim_b: Vec::new(),
        };
        if let UkrBackend::Simulator(hal) = &mut ukr.backend {
            if !hal.is_open() {
                hal.e_init(geom)?;
            }
        }
        Ok(ukr)
    }

    /// `c_out = alpha · a1·b1 + beta · c_in` over the fixed tile.
    ///
    /// * `a_panel`: column-major m × k
    /// * `b_panel`: row-major k × n
    /// * `c_in`: column-major m × n
    /// * `params`: projection context (walk classes, ipc/dgemm/blis flags);
    ///   its dims are overwritten from the call.
    pub fn sgemm(
        &mut self,
        alpha: f32,
        a_panel: &[f32],
        b_panel: &[f32],
        beta: f32,
        c_in: &[f32],
        mut params: ProjectionParams,
    ) -> Result<UkrOutput> {
        let (m, n) = (self.geom.m, self.geom.n);
        let k = if m > 0 { a_panel.len() / m } else { 0 };
        ensure!(a_panel.len() == m * k, "a panel not m×k");
        ensure!(b_panel.len() == k * n, "b panel len {} != k·n {}", b_panel.len(), k * n);
        ensure!(c_in.len() == m * n, "c panel not m×n");
        params.m = m;
        params.n = n;
        params.k = k;
        params.ksub = self.geom.ksub;
        params.nsub = self.geom.nsub;

        // Reference-BLAS semantics: beta == 0 means C is *not read* (an
        // uninitialized or NaN C must not poison the result). Substitute
        // the persistent zeros buffer — it is only ever read, so one
        // allocation serves every β==0 call at this geometry.
        if beta == 0.0 && self.zeros.len() != m * n {
            self.zeros = vec![0.0f32; m * n];
        }
        let c_in = if beta == 0.0 { self.zeros.as_slice() } else { c_in };

        let t0 = Instant::now();
        let c = match &mut self.backend {
            UkrBackend::HostRef => {
                host_sgemm_variant(self.variant, m, n, k, alpha, a_panel, b_panel, beta, c_in)
            }
            UkrBackend::Pjrt(ex) => {
                ex.sgemm_arbitrary_k(k, alpha, a_panel, b_panel, beta, c_in)?
            }
            UkrBackend::Simulator(hal) => simulator_sgemm(
                hal,
                self.geom,
                alpha,
                a_panel,
                b_panel,
                beta,
                c_in,
                k,
                &mut self.sim_a,
                &mut self.sim_b,
            )?,
        };
        let wall_s = t0.elapsed().as_secs_f64();
        let projection = match self.backend {
            // The host reference has no coprocessor pipeline: project at
            // the calibrated naive-loop rate.
            UkrBackend::HostRef => {
                let total = super::projection::project_host_ref(&self.model, m, n, k);
                Projection { total_s: total, ..Default::default() }
            }
            _ => project_ukr_call(&self.model, &params),
        };
        Ok(UkrOutput { c, wall_s, projection })
    }

    /// The paper's "false dgemm": f64 API around the f32 kernel —
    /// downcast inputs, run sgemm, upcast the output (§4.2).
    pub fn false_dgemm(
        &mut self,
        alpha: f64,
        a_panel: &[f64],
        b_panel: &[f64],
        beta: f64,
        c_in: &[f64],
        mut params: ProjectionParams,
    ) -> Result<(Vec<f64>, f64, Projection)> {
        params.dgemm = true;
        let a32: Vec<f32> = a_panel.iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = b_panel.iter().map(|&v| v as f32).collect();
        let c32: Vec<f32> = c_in.iter().map(|&v| v as f32).collect();
        let out = self.sgemm(alpha as f32, &a32, &b32, beta as f32, &c32, params)?;
        Ok((out.c.iter().map(|&v| v as f64).collect(), out.wall_s, out.projection))
    }

    /// Simulator statistics (empty for other backends) — used by tests to
    /// cross-check the analytic projection against executed structure.
    pub fn sim_stats(&self) -> Option<&crate::epiphany::SimStats> {
        match &self.backend {
            UkrBackend::Simulator(hal) => hal.chip().ok().map(|c| &c.stats),
            _ => None,
        }
    }
}

/// The naive triple loop — the paper's "Host reference code", kept
/// verbatim as the oracle every other variant is pinned against.
pub fn host_ref_sgemm(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32], // col-major m×k
    b: &[f32], // row-major k×n
    beta: f32,
    c_in: &[f32], // col-major m×n
) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for j in 0..n {
        for i in 0..m {
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += a[l * m + i] * b[l * n + j];
            }
            c[j * m + i] = alpha * acc + beta * c_in[j * m + i];
        }
    }
    c
}

/// Dispatch one host gemm tile to the chosen [`UkrVariant`]
/// (layouts as in [`host_ref_sgemm`]; arbitrary m/n/k, ragged included).
pub fn host_sgemm_variant(
    variant: UkrVariant,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c_in: &[f32],
) -> Vec<f32> {
    match variant.resolve() {
        UkrVariant::Scalar => host_ref_sgemm(m, n, k, alpha, a, b, beta, c_in),
        UkrVariant::Blocked => host_sgemm_blocked(m, n, k, alpha, a, b, beta, c_in),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        UkrVariant::Simd => sse::sgemm(m, n, k, alpha, a, b, beta, c_in),
        // Unreachable through resolve(); kept so the match is total in
        // builds without the SSE path.
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        UkrVariant::Simd => host_sgemm_blocked(m, n, k, alpha, a, b, beta, c_in),
    }
}

/// Unroll-and-jam host kernel: [`UKR_MR`]`×`[`UKR_NR`] register blocks,
/// column panels walked with `chunks_exact`, fixed-size accumulator
/// arrays that LLVM autovectorizes. Bit-identical to [`host_ref_sgemm`]
/// (same per-element operation order).
pub fn host_sgemm_blocked(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c_in: &[f32],
) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return c;
    }
    let m_main = m - m % UKR_MR;
    let n_main = n - n % UKR_NR;
    for j0 in (0..n_main).step_by(UKR_NR) {
        for i0 in (0..m_main).step_by(UKR_MR) {
            ukr_8x4(m, n, k, alpha, a, b, beta, c_in, &mut c, i0, j0);
        }
        ukr_edge(m, n, k, alpha, a, b, beta, c_in, &mut c, m_main, m, j0, j0 + UKR_NR);
    }
    ukr_edge(m, n, k, alpha, a, b, beta, c_in, &mut c, 0, m, n_main, n);
    c
}

/// One full [`UKR_MR`]`×`[`UKR_NR`] register block at (i0, j0).
#[inline]
fn ukr_8x4(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c_in: &[f32],
    c: &mut [f32],
    i0: usize,
    j0: usize,
) {
    let mut acc = [[0.0f32; UKR_MR]; UKR_NR];
    for (a_col, b_row) in a.chunks_exact(m).zip(b.chunks_exact(n)).take(k) {
        let av: &[f32; UKR_MR] = a_col[i0..i0 + UKR_MR].try_into().unwrap();
        let bv: &[f32; UKR_NR] = b_row[j0..j0 + UKR_NR].try_into().unwrap();
        for (acc_j, &bj) in acc.iter_mut().zip(bv) {
            for ii in 0..UKR_MR {
                acc_j[ii] += av[ii] * bj;
            }
        }
    }
    for (jj, acc_j) in acc.iter().enumerate() {
        let base = (j0 + jj) * m + i0;
        let src = &c_in[base..base + UKR_MR];
        let dst = &mut c[base..base + UKR_MR];
        for ii in 0..UKR_MR {
            dst[ii] = alpha * acc_j[ii] + beta * src[ii];
        }
    }
}

/// Ragged-edge fallback: the scalar loop over `i0..i1 × j0..j1` (same
/// operation order as [`host_ref_sgemm`], so edges stay bit-identical).
#[inline]
#[allow(clippy::too_many_arguments)]
fn ukr_edge(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c_in: &[f32],
    c: &mut [f32],
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
) {
    for j in j0..j1 {
        for i in i0..i1 {
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += a[l * m + i] * b[l * n + j];
            }
            c[j * m + i] = alpha * acc + beta * c_in[j * m + i];
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod sse {
    // Explicit SSE path (the `simd` feature). SSE is part of the x86_64
    // baseline, so no runtime detection is needed. The per-lane operation
    // order matches the scalar oracle (k ascending, mul then add, no FMA),
    // so the result is bit-identical to host_ref_sgemm.
    use super::{ukr_edge, UKR_MR, UKR_NR};
    use core::arch::x86_64::{
        _mm_add_ps, _mm_loadu_ps, _mm_mul_ps, _mm_set1_ps, _mm_setzero_ps, _mm_storeu_ps,
    };

    pub(super) fn sgemm(
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        a: &[f32],
        b: &[f32],
        beta: f32,
        c_in: &[f32],
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        if m == 0 || n == 0 {
            return c;
        }
        let m_main = m - m % UKR_MR;
        let n_main = n - n % UKR_NR;
        for j0 in (0..n_main).step_by(UKR_NR) {
            for i0 in (0..m_main).step_by(UKR_MR) {
                // SAFETY: every pointer below stays in bounds — i0+8 <= m,
                // j0+4 <= n, l < k, with a.len() = m·k, b.len() = k·n and
                // c/c_in of m·n (checked by the callers' ensure!s).
                unsafe { ukr_8x4_sse(m, n, k, alpha, a, b, beta, c_in, &mut c, i0, j0) };
            }
            ukr_edge(m, n, k, alpha, a, b, beta, c_in, &mut c, m_main, m, j0, j0 + UKR_NR);
        }
        ukr_edge(m, n, k, alpha, a, b, beta, c_in, &mut c, 0, m, n_main, n);
        c
    }

    #[allow(clippy::too_many_arguments)]
    unsafe fn ukr_8x4_sse(
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        a: &[f32],
        b: &[f32],
        beta: f32,
        c_in: &[f32],
        c: &mut [f32],
        i0: usize,
        j0: usize,
    ) {
        let mut acc = [[_mm_setzero_ps(); 2]; UKR_NR];
        for l in 0..k {
            let ap = a.as_ptr().add(l * m + i0);
            let a0 = _mm_loadu_ps(ap);
            let a1 = _mm_loadu_ps(ap.add(4));
            let bp = b.as_ptr().add(l * n + j0);
            for (jj, acc_j) in acc.iter_mut().enumerate() {
                let bj = _mm_set1_ps(*bp.add(jj));
                acc_j[0] = _mm_add_ps(acc_j[0], _mm_mul_ps(a0, bj));
                acc_j[1] = _mm_add_ps(acc_j[1], _mm_mul_ps(a1, bj));
            }
        }
        let va = _mm_set1_ps(alpha);
        let vb = _mm_set1_ps(beta);
        for (jj, acc_j) in acc.iter().enumerate() {
            let base = (j0 + jj) * m + i0;
            for (h, &acc_h) in acc_j.iter().enumerate() {
                let cin = _mm_loadu_ps(c_in.as_ptr().add(base + 4 * h));
                let v = _mm_add_ps(_mm_mul_ps(va, acc_h), _mm_mul_ps(vb, cin));
                _mm_storeu_ps(c.as_mut_ptr().add(base + 4 * h), v);
            }
        }
    }
}

/// Drive the functional simulator through the SUMMA loop with the command
/// protocol (§3.3): clear on the first task, accumulate in between, send
/// back on the last; α/β applied by the host afterwards. The KSUB staging
/// panels (`a_t`/`b_t`) are caller-owned and reused across tasks *and*
/// calls; ragged tails are re-zeroed explicitly so stale bytes from a
/// deeper earlier call can never leak into the padding.
#[allow(clippy::too_many_arguments)]
fn simulator_sgemm(
    hal: &mut EHal,
    geom: KernelGeometry,
    alpha: f32,
    a_panel: &[f32],
    b_panel: &[f32],
    beta: f32,
    c_in: &[f32],
    k: usize,
    a_t: &mut Vec<f32>,
    b_t: &mut Vec<f32>,
) -> Result<Vec<f32>> {
    let (m, n, ksub) = (geom.m, geom.n, geom.ksub);
    let tasks = k.div_ceil(ksub).max(1);
    a_t.resize(m * ksub, 0.0);
    b_t.resize(ksub * n, 0.0);
    for t in 0..tasks {
        let selector = t & 1;
        // Slice / zero-pad this KSUB panel pair into the reused staging.
        let k0 = t * ksub;
        let k_real = ksub.min(k - k0.min(k));
        a_t[..m * k_real].copy_from_slice(&a_panel[m * k0..m * (k0 + k_real)]);
        if k_real < ksub {
            a_t[m * k_real..].fill(0.0);
        }
        b_t[..k_real * n].copy_from_slice(&b_panel[n * k0..n * (k0 + k_real)]);
        if k_real < ksub {
            b_t[k_real * n..].fill(0.0);
        }
        hal.e_write_a(selector, a_t)?;
        hal.e_write_b(selector, b_t)?;
        let command = match (t == 0, t == tasks - 1) {
            (true, true) => Command::ClearSend,
            (true, false) => Command::ClearAccumulate,
            (false, true) => Command::AccumulateSend,
            (false, false) => Command::Accumulate,
        };
        hal.e_signal_task(command, selector)?;
    }
    // Retrieve the raw accumulated product and run the host epilogue
    // ("the micro-kernel multiplies the resulting matrix by alpha and adds
    // beta·c_in").
    let mut raw = vec![0.0f32; m * n];
    hal.e_read_out(&mut raw)?;
    for idx in 0..m * n {
        raw[idx] = alpha * raw[idx] + beta * c_in[idx];
    }
    Ok(raw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::timing::WalkClass;
    use crate::linalg::{max_scaled_err, Mat};

    fn row_major(b: &Mat<f32>) -> Vec<f32> {
        let (k, n) = (b.rows(), b.cols());
        let mut out = vec![0.0f32; k * n];
        for l in 0..k {
            for j in 0..n {
                out[l * n + j] = b.get(l, j);
            }
        }
        out
    }

    fn params() -> ProjectionParams {
        ProjectionParams::kernel_same_process(0)
    }

    fn check_backend(mut ukr: InnerMicroKernel, k: usize, tol: f64) {
        let (m, n) = (ukr.geom.m, ukr.geom.n);
        let a = Mat::<f32>::randn(m, k, 100);
        let b = Mat::<f32>::randn(k, n, 101);
        let c = Mat::<f32>::randn(m, n, 102);
        let out =
            ukr.sgemm(1.25, a.as_slice(), &row_major(&b), -0.75, c.as_slice(), params()).unwrap();
        let got = Mat::from_col_major(m, n, &out.c);
        let want = Mat::from_fn(m, n, |i, j| {
            let mut acc = 0.0f64;
            for l in 0..k {
                acc += a.get(i, l) as f64 * b.get(l, j) as f64;
            }
            (1.25 * acc - 0.75 * c.get(i, j) as f64) as f32
        });
        let e = max_scaled_err(got.view(), want.view());
        assert!(e < tol, "{} backend err {e}", ukr.backend.name());
        assert!(out.wall_s > 0.0);
        assert!(out.projection.total_s > 0.0);
    }

    #[test]
    fn host_ref_backend_correct() {
        let ukr = InnerMicroKernel::new(
            UkrBackend::HostRef,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        check_backend(ukr, 192, 1e-5);
    }

    #[test]
    fn every_host_variant_correct_through_backend() {
        for variant in UkrVariant::all() {
            let ukr = InnerMicroKernel::with_variant(
                UkrBackend::HostRef,
                CalibratedModel::default(),
                KernelGeometry::paper(),
                variant,
            )
            .unwrap();
            check_backend(ukr, 150, 1e-5);
        }
    }

    #[test]
    fn vectorized_variants_bitwise_match_scalar() {
        // Same per-element operation order ⇒ bit-identical results, even
        // on ragged shapes that exercise the edge kernels.
        for &(m, n, k) in
            &[(8, 4, 16), (192, 256, 64), (7, 3, 5), (33, 17, 1), (50, 50, 0), (9, 5, 63)]
        {
            let a = Mat::<f32>::randn(m, k.max(1), 500).as_slice()[..m * k].to_vec();
            let b = Mat::<f32>::randn(k.max(1), n, 501).as_slice()[..k * n].to_vec();
            let c = Mat::<f32>::randn(m, n, 502);
            let want = host_ref_sgemm(m, n, k, 1.25, &a, &b, -0.5, c.as_slice());
            for variant in [UkrVariant::Blocked, UkrVariant::Simd] {
                let got =
                    host_sgemm_variant(variant, m, n, k, 1.25, &a, &b, -0.5, c.as_slice());
                assert_eq!(got, want, "{} deviates at {m}x{n}x{k}", variant.name());
            }
        }
    }

    #[test]
    fn variant_selection_resolves() {
        assert_eq!(UkrVariant::Scalar.resolve(), UkrVariant::Scalar);
        assert_eq!(UkrVariant::Blocked.resolve(), UkrVariant::Blocked);
        let simd_on = cfg!(all(feature = "simd", target_arch = "x86_64"));
        assert_eq!(UkrVariant::Simd.available(), simd_on);
        assert_eq!(
            UkrVariant::Simd.resolve(),
            if simd_on { UkrVariant::Simd } else { UkrVariant::Blocked }
        );
        assert!(UkrVariant::fastest().available());
        assert_eq!(UkrVariant::parse("blocked"), Some(UkrVariant::Blocked));
        assert_eq!(UkrVariant::parse("avx512"), None);
    }

    #[test]
    fn simulator_backend_correct() {
        let hal = EHal::new(CalibratedModel::default());
        let ukr = InnerMicroKernel::new(
            UkrBackend::Simulator(hal),
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        check_backend(ukr, 192, 1e-5);
    }

    // Needs the `pjrt` feature + built artifacts.
    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_backend_correct() {
        let ex = GemmExecutor::discover().expect("make artifacts first");
        let ukr = InnerMicroKernel::new(
            UkrBackend::Pjrt(ex),
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        check_backend(ukr, 192, 1e-5);
    }

    #[test]
    fn backends_agree_on_ragged_k() {
        // K = 150 is not a multiple of KSUB: every offload backend must
        // zero-pad identically and agree with the host reference.
        let k = 150;
        let geom = KernelGeometry::paper();
        let a = Mat::<f32>::randn(geom.m, k, 200);
        let b = Mat::<f32>::randn(k, geom.n, 201);
        let c = Mat::<f32>::randn(geom.m, geom.n, 202);
        let b_rm = row_major(&b);

        let run = |backend| {
            let mut ukr =
                InnerMicroKernel::new(backend, CalibratedModel::default(), geom).unwrap();
            ukr.sgemm(1.0, a.as_slice(), &b_rm, 1.0, c.as_slice(), params()).unwrap().c
        };
        let href = run(UkrBackend::HostRef);
        #[allow(unused_mut)] // mutated only when the pjrt feature is on
        let mut offload = vec![(
            "sim",
            run(UkrBackend::Simulator(EHal::new(CalibratedModel::default()))),
        )];
        #[cfg(feature = "pjrt")]
        offload.push(("pjrt", run(UkrBackend::Pjrt(GemmExecutor::discover().unwrap()))));
        let href = Mat::from_col_major(geom.m, geom.n, &href);
        for (name, got) in offload {
            let got = Mat::from_col_major(geom.m, geom.n, &got);
            let e = max_scaled_err(got.view(), href.view());
            assert!(e < 1e-5, "{name} vs host-ref err {e}");
        }
    }

    #[test]
    fn staging_reuse_survives_shrinking_ragged_k() {
        // A deep call followed by a shallow ragged call on the same kernel
        // instance: the reused a_t/b_t staging must not leak the deep
        // call's bytes into the shallow call's zero padding.
        let geom = KernelGeometry::paper();
        let mut ukr = InnerMicroKernel::new(
            UkrBackend::Simulator(EHal::new(CalibratedModel::default())),
            CalibratedModel::default(),
            geom,
        )
        .unwrap();
        for &k in &[geom.ksub * 2, 30, geom.ksub + 1] {
            let a = Mat::<f32>::randn(geom.m, k, 600 + k as u64);
            let b = Mat::<f32>::randn(k, geom.n, 700 + k as u64);
            let c = Mat::<f32>::randn(geom.m, geom.n, 800 + k as u64);
            let b_rm = row_major(&b);
            let got = ukr.sgemm(1.0, a.as_slice(), &b_rm, 1.0, c.as_slice(), params()).unwrap();
            let want = host_ref_sgemm(
                geom.m,
                geom.n,
                k,
                1.0,
                a.as_slice(),
                &b_rm,
                1.0,
                c.as_slice(),
            );
            let got = Mat::from_col_major(geom.m, geom.n, &got.c);
            let want = Mat::from_col_major(geom.m, geom.n, &want);
            let e = max_scaled_err(got.view(), want.view());
            assert!(e < 1e-5, "k={k} err {e} (stale staging bytes?)");
        }
    }

    #[test]
    fn false_dgemm_downcasts() {
        let geom = KernelGeometry::paper();
        let k = 128;
        let mut ukr = InnerMicroKernel::new(
            UkrBackend::Simulator(EHal::new(CalibratedModel::default())),
            CalibratedModel::default(),
            geom,
        )
        .unwrap();
        let a = Mat::<f64>::randn(geom.m, k, 300);
        let b = Mat::<f64>::randn(k, geom.n, 301);
        let c = Mat::<f64>::randn(geom.m, geom.n, 302);
        let mut b_rm = vec![0.0f64; k * geom.n];
        for l in 0..k {
            for j in 0..geom.n {
                b_rm[l * geom.n + j] = b.get(l, j);
            }
        }
        let (got, _, proj) = ukr
            .false_dgemm(1.0, a.as_slice(), &b_rm, 1.0, c.as_slice(), params())
            .unwrap();
        let got = Mat::from_col_major(geom.m, geom.n, &got);
        let want = Mat::from_fn(geom.m, geom.n, |i, j| {
            let mut acc = 0.0f64;
            for l in 0..k {
                acc += a.get(i, l) * b.get(l, j);
            }
            acc + c.get(i, j)
        });
        let e = max_scaled_err(got.view(), want.view());
        // f32-sized error through an f64 API.
        assert!(e > 1e-10 && e < 1e-4, "err {e}");
        assert!(proj.cast_s > 0.0, "cast pass must be charged");
    }

    #[test]
    fn simulator_projection_consistent_with_executed_structure() {
        // The analytic projection's coprocessor share must agree with the
        // coproc time derived from the simulator's executed cycles/bytes.
        let geom = KernelGeometry::paper();
        let k = 4 * geom.ksub;
        let a = Mat::<f32>::randn(geom.m, k, 400);
        let b = Mat::<f32>::randn(k, geom.n, 401);
        let c = Mat::<f32>::zeros(geom.m, geom.n);
        let b_rm = row_major(&b);
        let mut ukr = InnerMicroKernel::new(
            UkrBackend::Simulator(EHal::new(CalibratedModel::default())),
            CalibratedModel::default(),
            geom,
        )
        .unwrap();
        let mut p = params();
        p.class_a = WalkClass::Contig;
        let out = ukr.sgemm(1.0, a.as_slice(), &b_rm, 0.0, c.as_slice(), p).unwrap();
        let stats = ukr.sim_stats().unwrap();
        let sim_coproc = stats.coproc_s(&ukr.model);
        let ana_coproc = out.projection.coproc_s;
        let ratio = sim_coproc / ana_coproc;
        assert!((0.97..1.03).contains(&ratio), "sim {sim_coproc} vs analytic {ana_coproc}");
    }
}
