//! The chip pool: N independent simulated Epiphany chips behind one BLAS.
//!
//! The paper's platform has exactly one Epiphany-16, and §4 shows the
//! full-problem numbers stalling on the host↔chip transfer path rather
//! than on the chip itself. The first scaling axis past that ceiling is
//! *more chips*: each [`ServiceHandle`] in a [`ChipPool`] owns its own
//! HH-RAM window, service loop and simulator state (`SimStats`), so
//! level-3 traffic sharded across the pool crosses N independent IPC
//! boundaries concurrently instead of funneling through one.
//!
//! A pool of one is the degenerate plan and behaves bit-identically to
//! the original single-chip backend — the shard executor in
//! [`crate::blis::Blas`] runs the exact same tile loop on the one chip.
//! How a gemm is split across the pool is the [`ShardPolicy`]'s call;
//! see `docs/ARCHITECTURE.md` for the full data-flow picture.

use super::service::{ServiceBackend, ServiceHandle};
use crate::epiphany::kernel::KernelGeometry;
use crate::epiphany::timing::CalibratedModel;
use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// How level-3 work is split across the chips of a [`ChipPool`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ShardPolicy {
    /// SUMMA-style column-panel sharding: the gemm's `jc` column tiles
    /// are split into contiguous, balanced ranges — one per chip — and
    /// the shards execute concurrently. With one chip (or one column
    /// tile) this degenerates to the original serial tile loop.
    #[default]
    ColumnPanels,
    /// Every tile of the operation goes to the given chip. This is what
    /// the coordinator's per-chip batcher workers use, and what a wire
    /// client's shard-hint flag requests.
    Pinned(usize),
}

/// N independent simulated Epiphany chips, each behind its own resident
/// service ([`ServiceHandle`]) with a private HH-RAM window and semaphore
/// pair.
///
/// The pool also keeps two per-chip gauges: *in-flight shards* (work
/// currently executing, behind [`ChipPool::least_loaded`] — for embedders
/// scheduling directly against the pool) and *total µ-kernel crossings*
/// (lifetime service calls, [`ChipPool::crossings`] — the shard-balance
/// evidence the tests and stats reports read). The network coordinator's
/// [`Batcher`](crate::coordinator::batcher::Batcher) schedules with its
/// own queue-aware gauge instead, since queued-but-undrained jobs are
/// invisible to the pool.
pub struct ChipPool {
    chips: Vec<ServiceHandle>,
    in_flight: Vec<AtomicUsize>,
    crossings: Vec<AtomicU64>,
    healthy: Vec<AtomicBool>,
}

impl ChipPool {
    /// Boot `n` chips of the given backend. Each chip performs its own
    /// one-time eSDK init inside its own service thread (the per-process
    /// re-init limit is per chip, so pools of any size are safe).
    pub fn spawn(
        n: usize,
        backend: ServiceBackend,
        model: CalibratedModel,
        geom: KernelGeometry,
    ) -> Result<ChipPool> {
        ensure!(n >= 1, "a chip pool needs at least one chip, got {n}");
        let mut chips = Vec::with_capacity(n);
        for _ in 0..n {
            chips.push(ServiceHandle::spawn(backend, model.clone(), geom)?);
        }
        Ok(ChipPool::from_chips(chips))
    }

    /// Wrap one already-booted service as a pool of one (the degenerate
    /// plan; bit-identical to the pre-pool single-chip backend).
    pub fn single(svc: ServiceHandle) -> ChipPool {
        ChipPool::from_chips(vec![svc])
    }

    fn from_chips(chips: Vec<ServiceHandle>) -> ChipPool {
        let n = chips.len();
        ChipPool {
            chips,
            in_flight: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            crossings: (0..n).map(|_| AtomicU64::new(0)).collect(),
            healthy: (0..n).map(|_| AtomicBool::new(true)).collect(),
        }
    }

    /// Number of chips in the pool.
    pub fn len(&self) -> usize {
        self.chips.len()
    }

    /// Whether the pool is empty (never true for a spawned pool; the
    /// constructor requires at least one chip).
    pub fn is_empty(&self) -> bool {
        self.chips.is_empty()
    }

    /// The service handle of chip `i`. Panics if `i >= len()` — callers
    /// route through a validated shard plan.
    pub fn chip(&self, i: usize) -> &ServiceHandle {
        &self.chips[i]
    }

    /// The µ-kernel geometry (identical across the pool; read from chip 0).
    pub fn geometry(&self) -> KernelGeometry {
        self.chips[0].geometry()
    }

    /// Index of the healthy chip with the least work: fewest in-flight
    /// shards, ties broken by lifetime crossings, then by lowest index
    /// (deterministic). Unhealthy chips are skipped; if *every* chip is
    /// unhealthy the scan degrades to the full pool rather than refusing
    /// to place work (the call itself will then surface the error).
    pub fn least_loaded(&self) -> usize {
        self.least_loaded_among(true).or_else(|| self.least_loaded_among(false)).unwrap_or(0)
    }

    fn least_loaded_among(&self, healthy_only: bool) -> Option<usize> {
        let mut best = None;
        let mut best_key = (usize::MAX, u64::MAX);
        for i in 0..self.chips.len() {
            if healthy_only && !self.is_healthy(i) {
                continue;
            }
            let key = (
                self.in_flight[i].load(Ordering::Relaxed),
                self.crossings[i].load(Ordering::Relaxed),
            );
            if key < best_key {
                best_key = key;
                best = Some(i);
            }
        }
        best
    }

    /// Whether chip `i` is currently marked healthy. Out-of-range indices
    /// read as unhealthy (nothing should be routed to them).
    pub fn is_healthy(&self, i: usize) -> bool {
        self.healthy.get(i).map(|h| h.load(Ordering::Relaxed)).unwrap_or(false)
    }

    /// Mark chip `i` unhealthy: `least_loaded` and the shard planner stop
    /// routing new work to it until a [`Self::mark_healthy`] probe
    /// succeeds. Idempotent; returns `true` if this call flipped the
    /// state (the chip was healthy before).
    pub fn mark_unhealthy(&self, i: usize) -> bool {
        match self.healthy.get(i) {
            Some(h) => h.swap(false, Ordering::Relaxed),
            None => false,
        }
    }

    /// Re-admit chip `i` after a successful probe (e.g. a ping round
    /// trip through its service thread). Idempotent.
    pub fn mark_healthy(&self, i: usize) {
        if let Some(h) = self.healthy.get(i) {
            h.store(true, Ordering::Relaxed);
        }
    }

    /// Indices of the chips currently marked healthy, in order.
    pub fn healthy_chips(&self) -> Vec<usize> {
        (0..self.chips.len()).filter(|&i| self.is_healthy(i)).collect()
    }

    /// Indices of the chips currently marked unhealthy, in order — what
    /// the stats report exposes as `unhealthy_chips`.
    pub fn unhealthy_chips(&self) -> Vec<usize> {
        (0..self.chips.len()).filter(|&i| !self.is_healthy(i)).collect()
    }

    /// Probe chip `i` with a real round trip through its service thread
    /// and re-admit it on success. A dead service thread keeps the chip
    /// unhealthy and returns the probe error.
    pub fn probe(&self, i: usize) -> Result<()> {
        ensure!(i < self.chips.len(), "probe of chip {i} out of range (pool has {})", self.len());
        self.chips[i].ping()?;
        self.mark_healthy(i);
        Ok(())
    }

    /// Lifetime µ-kernel crossings per chip — the shard-balance evidence
    /// the tests and the coordinator's stats report read.
    pub fn crossings(&self) -> Vec<u64> {
        self.crossings.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Mark a shard as executing on chip `i` (paired with [`Self::exit`]).
    pub(crate) fn enter(&self, i: usize) {
        self.in_flight[i].fetch_add(1, Ordering::Relaxed);
    }

    /// Mark a shard on chip `i` as finished after `calls` µ-kernel
    /// crossings.
    pub(crate) fn exit(&self, i: usize, calls: u64) {
        self.crossings[i].fetch_add(calls, Ordering::Relaxed);
        self.in_flight[i].fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(n: usize) -> ChipPool {
        ChipPool::spawn(
            n,
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap()
    }

    #[test]
    fn spawn_rejects_zero() {
        assert!(ChipPool::spawn(
            0,
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper()
        )
        .is_err());
    }

    #[test]
    fn pool_boots_independent_chips() {
        let p = pool(3);
        assert_eq!(p.len(), 3);
        assert!(!p.is_empty());
        assert_eq!(p.crossings(), vec![0, 0, 0]);
        // Each chip serves its own round trip through its own HH-RAM.
        let g = p.geometry();
        for i in 0..p.len() {
            let a = vec![1.0f32; g.m * 4];
            let b = vec![1.0f32; 4 * g.n];
            let c = vec![0.0f32; g.m * g.n];
            let params = crate::host::projection::ProjectionParams::kernel_service(4);
            let (out, _) = p.chip(i).sgemm(1.0, &a, &b, 0.0, &c, params).unwrap();
            assert_eq!(out.len(), g.m * g.n);
            assert!((out[0] - 4.0).abs() < 1e-6);
        }
    }

    #[test]
    fn least_loaded_tracks_gauges() {
        let p = pool(2);
        assert_eq!(p.least_loaded(), 0, "empty pool: lowest index wins");
        p.enter(0);
        assert_eq!(p.least_loaded(), 1, "chip 0 busy");
        p.exit(0, 5);
        // In-flight equal again; crossings break the tie toward chip 1.
        assert_eq!(p.least_loaded(), 1);
        assert_eq!(p.crossings(), vec![5, 0]);
    }

    #[test]
    fn health_state_routes_around_bad_chips() {
        let p = pool(3);
        assert_eq!(p.healthy_chips(), vec![0, 1, 2]);
        assert!(p.unhealthy_chips().is_empty());
        assert!(p.mark_unhealthy(0), "first mark flips the state");
        assert!(!p.mark_unhealthy(0), "second mark is idempotent");
        assert!(!p.is_healthy(0));
        assert_eq!(p.least_loaded(), 1, "unhealthy chip is skipped");
        assert_eq!(p.unhealthy_chips(), vec![0]);
        p.mark_unhealthy(1);
        p.mark_unhealthy(2);
        // Whole pool down: degrade to the full scan instead of refusing
        // to place (the call itself surfaces the chip error).
        assert_eq!(p.least_loaded(), 0);
        p.probe(1).unwrap();
        assert_eq!(p.healthy_chips(), vec![1]);
        assert_eq!(p.least_loaded(), 1);
        assert!(p.probe(9).is_err(), "probe is range-checked");
        assert!(!p.is_healthy(9), "out-of-range chips read unhealthy");
    }

    #[test]
    fn probe_fails_while_faults_armed() {
        let p = pool(2);
        p.chip(1).fail_next_calls(usize::MAX);
        p.mark_unhealthy(1);
        assert!(p.probe(1).is_err());
        assert!(!p.is_healthy(1), "a failed probe must not re-admit");
        p.chip(1).clear_faults();
        p.probe(1).unwrap();
        assert!(p.is_healthy(1));
    }
}
