//! HH-RAM: the host↔host POSIX shared memory + semaphore pair the paper
//! uses between the BLAS process and the service process (§3.2).
//!
//! Modeled as a mutex-guarded staging buffer plus a binary semaphore built
//! from Mutex/Condvar. Copies into and out of the region are *real* (the
//! bytes actually move, like a `/dev/shm` write) and their projected cost
//! is charged at the calibrated HH-RAM bandwidth.

use std::sync::{Arc, Condvar, Mutex};

/// Binary semaphore with the POSIX `sem_wait`/`sem_post` shape.
#[derive(Clone)]
pub struct Semaphore {
    inner: Arc<(Mutex<usize>, Condvar)>,
}

impl Semaphore {
    /// A semaphore with the given initial count.
    pub fn new(initial: usize) -> Self {
        Semaphore { inner: Arc::new((Mutex::new(initial), Condvar::new())) }
    }

    /// `sem_post`.
    pub fn post(&self) {
        let (lock, cv) = &*self.inner;
        let mut count = lock.lock().unwrap();
        *count += 1;
        cv.notify_one();
    }

    /// `sem_wait` (blocking).
    pub fn wait(&self) {
        let (lock, cv) = &*self.inner;
        let mut count = lock.lock().unwrap();
        while *count == 0 {
            count = cv.wait(count).unwrap();
        }
        *count -= 1;
    }

    /// `sem_trywait`.
    pub fn try_wait(&self) -> bool {
        let (lock, _) = &*self.inner;
        let mut count = lock.lock().unwrap();
        if *count > 0 {
            *count -= 1;
            true
        } else {
            false
        }
    }
}

/// The shared staging region. One request in flight at a time, exactly
/// like the paper's "predefined place in the HH-RAM".
pub struct HhRam {
    /// f32 staging for sgemm traffic.
    pub f32_data: Mutex<Vec<f32>>,
    /// f64 staging for false-dgemm traffic.
    pub f64_data: Mutex<Vec<f64>>,
    /// Bytes written + read through the region (for the IPC projection).
    pub traffic_bytes: Mutex<u64>,
}

impl HhRam {
    /// An empty staging region behind a shared handle.
    pub fn new() -> Arc<Self> {
        Arc::new(HhRam {
            f32_data: Mutex::new(Vec::new()),
            f64_data: Mutex::new(Vec::new()),
            traffic_bytes: Mutex::new(0),
        })
    }

    /// Stage an f32 payload from parts without a caller-side concat copy.
    pub fn write_f32_parts(&self, parts: &[&[f32]]) {
        let mut d = self.f32_data.lock().unwrap();
        d.clear();
        let total: usize = parts.iter().map(|p| p.len()).sum();
        d.reserve(total);
        for p in parts {
            d.extend_from_slice(p);
        }
        *self.traffic_bytes.lock().unwrap() += (total * 4) as u64;
    }

    /// Stage an f64 payload from parts without a caller-side concat copy.
    pub fn write_f64_parts(&self, parts: &[&[f64]]) {
        let mut d = self.f64_data.lock().unwrap();
        d.clear();
        let total: usize = parts.iter().map(|p| p.len()).sum();
        d.reserve(total);
        for p in parts {
            d.extend_from_slice(p);
        }
        *self.traffic_bytes.lock().unwrap() += (total * 8) as u64;
    }

    /// Stage an f32 payload (caller side of the IPC).
    pub fn write_f32(&self, payload: &[f32]) {
        let mut d = self.f32_data.lock().unwrap();
        d.clear();
        d.extend_from_slice(payload);
        *self.traffic_bytes.lock().unwrap() += (payload.len() * 4) as u64;
    }

    /// Drain the staged f32 payload (service side).
    pub fn take_f32(&self) -> Vec<f32> {
        let mut d = self.f32_data.lock().unwrap();
        *self.traffic_bytes.lock().unwrap() += (d.len() * 4) as u64;
        std::mem::take(&mut *d)
    }

    /// Stage an f64 payload (caller side of the IPC).
    pub fn write_f64(&self, payload: &[f64]) {
        let mut d = self.f64_data.lock().unwrap();
        d.clear();
        d.extend_from_slice(payload);
        *self.traffic_bytes.lock().unwrap() += (payload.len() * 8) as u64;
    }

    /// Drain the staged f64 payload (service side).
    pub fn take_f64(&self) -> Vec<f64> {
        let mut d = self.f64_data.lock().unwrap();
        *self.traffic_bytes.lock().unwrap() += (d.len() * 8) as u64;
        std::mem::take(&mut *d)
    }

    /// Total bytes moved through the region so far.
    pub fn traffic(&self) -> u64 {
        *self.traffic_bytes.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn semaphore_ping_pong() {
        let req = Semaphore::new(0);
        let done = Semaphore::new(0);
        let req2 = req.clone();
        let done2 = done.clone();
        let h = thread::spawn(move || {
            for _ in 0..10 {
                req2.wait();
                done2.post();
            }
        });
        for _ in 0..10 {
            req.post();
            done.wait();
        }
        h.join().unwrap();
    }

    #[test]
    fn try_wait_semantics() {
        let s = Semaphore::new(1);
        assert!(s.try_wait());
        assert!(!s.try_wait());
        s.post();
        assert!(s.try_wait());
    }

    #[test]
    fn hh_ram_round_trip_counts_traffic() {
        let shm = HhRam::new();
        let payload: Vec<f32> = (0..256).map(|v| v as f32).collect();
        shm.write_f32(&payload);
        let got = shm.take_f32();
        assert_eq!(got, payload);
        // write + read both counted (the two memcpy passes of the model).
        assert_eq!(shm.traffic(), 2 * 256 * 4);
    }
}
