//! The "separate Linux process" (paper §3.2): a resident service that owns
//! the Epiphany connection (eSDK init/finalize exactly once) and serves
//! µ-kernel calls arriving through HH-RAM + semaphores.
//!
//! The paper introduced this because (a) per-call init/finalize costs
//! ~seconds and (b) the eSDK breaks after repeated re-initialization in
//! one process — both of which the [`crate::esdk`] driver reproduces, and
//! the `service_survives_many_calls` test demonstrates the cure.

use super::microkernel::{InnerMicroKernel, UkrBackend, UkrOutput};
use super::projection::{Projection, ProjectionParams};
use super::shm::{HhRam, Semaphore};
use crate::epiphany::kernel::KernelGeometry;
use crate::epiphany::timing::CalibratedModel;
use crate::esdk::EHal;
use crate::runtime::GemmExecutor;
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Which backend the service boots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceBackend {
    /// Functional Epiphany simulator (exact paper dataflow; always
    /// available, the offline default).
    Simulator,
    /// AOT jax+pallas artifact via PJRT. Needs the `pjrt` cargo feature
    /// and built artifacts; the boot errors out otherwise.
    Pjrt,
    /// Naive host loop (baseline).
    HostRef,
}

/// A request crossing the HH-RAM boundary. The scalar arguments ride the
/// mailbox; the `[A | B | C]` panel payload is staged in HH-RAM by the
/// caller before the request is sent.
#[allow(missing_docs)] // fields are the classic alpha/beta/k gemm scalars
pub enum ServiceRequest {
    /// One f32 µ-kernel call (the accelerated sgemm tile).
    Sgemm {
        alpha: f32,
        beta: f32,
        k: usize,
        params: ProjectionParams,
    },
    /// One "false dgemm" call (f64 payload, f32 compute).
    FalseDgemm {
        alpha: f64,
        beta: f64,
        k: usize,
        params: ProjectionParams,
    },
    /// Liveness probe: a mailbox round trip with no HH-RAM exchange.
    Ping,
    /// Stop the service loop.
    Shutdown,
}

/// The service's answer (payload travels back through HH-RAM).
pub struct ServiceResponse {
    /// Wall-clock seconds the service spent on the call.
    pub wall_s: f64,
    /// Projected-Parallella timing breakdown from the calibrated model.
    pub projection: Projection,
}

struct Mailbox {
    req: mpsc::Sender<(ServiceRequest, mpsc::Sender<Result<ServiceResponse>>)>,
}

/// Client handle to the running service.
pub struct ServiceHandle {
    mailbox: Mailbox,
    shm: Arc<HhRam>,
    /// Request semaphore — part of the faithful IPC surface (used by the
    /// shm tests and the coordinator's backpressure).
    pub sem_request: Semaphore,
    /// Completion semaphore (posted by the service after staging results).
    pub sem_done: Semaphore,
    /// Serializes the client side of one HH-RAM exchange (stage → signal →
    /// reply → collect). There is exactly one staging region (§3.2), so
    /// concurrent callers — async tickets, router threads — must not
    /// interleave their payloads; packing for the *next* call can still
    /// proceed outside this critical section.
    ipc_lock: Mutex<()>,
    join: Option<JoinHandle<()>>,
    geom: KernelGeometry,
    /// Fault injection (chaos tests): the next N entries into this handle
    /// return an error before touching HH-RAM. `usize::MAX` ≈ a dead chip.
    fault_errors: AtomicUsize,
    /// Fault injection: the next N entries panic on the *caller's* thread,
    /// modelling a crash inside the host-side service call.
    fault_panics: AtomicUsize,
}

impl ServiceHandle {
    /// Spawn the service thread: it performs eSDK init (or PJRT compile)
    /// once and then serves requests until shutdown.
    pub fn spawn(
        backend: ServiceBackend,
        model: CalibratedModel,
        geom: KernelGeometry,
    ) -> Result<ServiceHandle> {
        let (tx, rx) = mpsc::channel::<(ServiceRequest, mpsc::Sender<Result<ServiceResponse>>)>();
        let shm = HhRam::new();
        let shm_thread = Arc::clone(&shm);
        let sem_request = Semaphore::new(0);
        let sem_done = Semaphore::new(0);
        let (sem_req_t, sem_done_t) = (sem_request.clone(), sem_done.clone());
        let (boot_tx, boot_rx) = mpsc::channel::<Result<()>>();

        let join = std::thread::Builder::new()
            .name("epiphany-service".into())
            .spawn(move || {
                // Boot the backend once, inside the service (GemmExecutor
                // and the chip are thread-resident, like the eSDK state).
                let ukr = (|| -> Result<InnerMicroKernel> {
                    let backend = match backend {
                        ServiceBackend::Simulator => {
                            UkrBackend::Simulator(EHal::new(model.clone()))
                        }
                        ServiceBackend::Pjrt => {
                            let mut ex = GemmExecutor::discover()?;
                            // Pre-compile all artifacts: no PJRT compile
                            // latency on the request path.
                            ex.warmup()?;
                            UkrBackend::Pjrt(ex)
                        }
                        ServiceBackend::HostRef => UkrBackend::HostRef,
                    };
                    InnerMicroKernel::new(backend, model.clone(), geom)
                })();
                let mut ukr = match ukr {
                    Ok(u) => {
                        let _ = boot_tx.send(Ok(()));
                        u
                    }
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };

                while let Ok((req, reply)) = rx.recv() {
                    if matches!(req, ServiceRequest::Shutdown) {
                        break;
                    }
                    if matches!(req, ServiceRequest::Ping) {
                        // No HH-RAM exchange; just prove the loop is alive.
                        let _ = reply.send(Ok(ServiceResponse {
                            wall_s: 0.0,
                            projection: Projection::default(),
                        }));
                        continue;
                    }
                    // Consume the caller's request semaphore (the paper's
                    // "passes the control to the service process").
                    sem_req_t.wait();
                    let resp = serve_one(&mut ukr, &shm_thread, req);
                    match resp {
                        Some(r) => {
                            // Results staged in HH-RAM; signal completion.
                            sem_done_t.post();
                            let _ = reply.send(r);
                        }
                        None => break,
                    }
                }
            })?;

        boot_rx.recv().map_err(|_| anyhow!("service thread died during boot"))??;
        Ok(ServiceHandle {
            mailbox: Mailbox { req: tx },
            shm,
            sem_request,
            sem_done,
            ipc_lock: Mutex::new(()),
            join: Some(join),
            geom,
            fault_errors: AtomicUsize::new(0),
            fault_panics: AtomicUsize::new(0),
        })
    }

    /// Consume one pending injected fault, if any. Error faults take
    /// priority over panic faults when both are armed.
    fn check_fault(&self) -> Result<()> {
        if take_one(&self.fault_errors) {
            bail!("injected fault: chip service call failed");
        }
        if take_one(&self.fault_panics) {
            panic!("injected fault: chip service call panicked");
        }
        Ok(())
    }

    /// Arm fault injection: the next `n` entries into this handle (gemm
    /// calls and pings alike) fail with an error, as a crashed or wedged
    /// chip would. `usize::MAX` keeps the chip down until
    /// [`Self::clear_faults`].
    pub fn fail_next_calls(&self, n: usize) {
        self.fault_errors.store(n, Ordering::SeqCst);
    }

    /// Arm fault injection: the next `n` entries into this handle panic on
    /// the calling thread — the failure mode that used to poison the
    /// batcher queue mutex.
    pub fn panic_next_calls(&self, n: usize) {
        self.fault_panics.store(n, Ordering::SeqCst);
    }

    /// Disarm all pending injected faults (the chip "comes back").
    pub fn clear_faults(&self) {
        self.fault_errors.store(0, Ordering::SeqCst);
        self.fault_panics.store(0, Ordering::SeqCst);
    }

    /// Liveness probe: a mailbox round trip through the service thread
    /// with no HH-RAM exchange. Errors if the thread is gone or a fault
    /// is armed — the health probe path in
    /// [`ChipPool`](crate::host::pool::ChipPool) builds on this.
    pub fn ping(&self) -> Result<()> {
        self.check_fault()?;
        let (rtx, rrx) = mpsc::channel();
        self.mailbox
            .req
            .send((ServiceRequest::Ping, rtx))
            .map_err(|_| anyhow!("service thread gone"))?;
        rrx.recv().map_err(|_| anyhow!("service thread dropped reply"))??;
        Ok(())
    }

    /// The µ-kernel geometry this service was booted with.
    pub fn geometry(&self) -> KernelGeometry {
        self.geom
    }

    /// sgemm through the service: panels go through HH-RAM (real copies),
    /// the semaphore pair sequences the exchange, the reply carries the
    /// timing breakdown. `params.ipc` is forced on — this *is* the IPC
    /// path.
    pub fn sgemm(
        &self,
        alpha: f32,
        a_panel: &[f32],
        b_panel: &[f32],
        beta: f32,
        c_in: &[f32],
        mut params: ProjectionParams,
    ) -> Result<(Vec<f32>, ServiceResponse)> {
        self.check_fault()?;
        params.ipc = true;
        let k = a_panel.len() / self.geom.m;
        let _ipc = self.ipc_lock.lock().unwrap();
        // Stage request payload into HH-RAM: [a | b | c] (single copy).
        self.shm.write_f32_parts(&[a_panel, b_panel, c_in]);
        self.sem_request.post();

        let (rtx, rrx) = mpsc::channel();
        self.mailbox
            .req
            .send((ServiceRequest::Sgemm { alpha, beta, k, params }, rtx))
            .map_err(|_| anyhow!("service thread gone"))?;
        let resp = rrx.recv().map_err(|_| anyhow!("service thread dropped reply"))??;
        self.sem_done.wait();
        let c_out = self.shm.take_f32();
        Ok((c_out, resp))
    }

    /// The false dgemm (f64 API) through the service.
    pub fn false_dgemm(
        &self,
        alpha: f64,
        a_panel: &[f64],
        b_panel: &[f64],
        beta: f64,
        c_in: &[f64],
        mut params: ProjectionParams,
    ) -> Result<(Vec<f64>, ServiceResponse)> {
        self.check_fault()?;
        params.ipc = true;
        params.dgemm = true;
        let k = a_panel.len() / self.geom.m;
        let _ipc = self.ipc_lock.lock().unwrap();
        self.shm.write_f64_parts(&[a_panel, b_panel, c_in]);
        self.sem_request.post();

        let (rtx, rrx) = mpsc::channel();
        self.mailbox
            .req
            .send((ServiceRequest::FalseDgemm { alpha, beta, k, params }, rtx))
            .map_err(|_| anyhow!("service thread gone"))?;
        let resp = rrx.recv().map_err(|_| anyhow!("service thread dropped reply"))??;
        self.sem_done.wait();
        let c_out = self.shm.take_f64();
        Ok((c_out, resp))
    }

    /// Graceful shutdown (e_finalize happens exactly once, on drop of the
    /// thread's state).
    pub fn shutdown(&mut self) {
        let (rtx, _rrx) = mpsc::channel();
        let _ = self.mailbox.req.send((ServiceRequest::Shutdown, rtx));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Consume one armed fault from `counter`: decrements if non-zero
/// (`usize::MAX` is sticky — a chip that stays down) and reports whether
/// a fault fired.
fn take_one(counter: &AtomicUsize) -> bool {
    counter
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| match v {
            0 => None,
            usize::MAX => Some(usize::MAX),
            v => Some(v - 1),
        })
        .is_ok()
}

/// Service-thread body for one request. Returns None on shutdown.
fn serve_one(
    ukr: &mut InnerMicroKernel,
    shm: &Arc<HhRam>,
    req: ServiceRequest,
) -> Option<Result<ServiceResponse>> {
    match req {
        ServiceRequest::Shutdown => None,
        // Pings are answered in the service loop itself (no HH-RAM); this
        // arm only keeps the match total if one ever lands here.
        ServiceRequest::Ping => {
            Some(Ok(ServiceResponse { wall_s: 0.0, projection: Projection::default() }))
        }
        ServiceRequest::Sgemm { alpha, beta, k, params } => {
            let (m, n) = (ukr.geom.m, ukr.geom.n);
            let payload = shm.take_f32();
            if payload.len() != m * k + k * n + m * n {
                return Some(Err(anyhow!(
                    "HH-RAM payload size {} != expected {} (k={k})",
                    payload.len(),
                    m * k + k * n + m * n
                )));
            }
            let (a, rest) = payload.split_at(m * k);
            let (b, c) = rest.split_at(k * n);
            Some(ukr.sgemm(alpha, a, b, beta, c, params).map(|out: UkrOutput| {
                shm.write_f32(&out.c);
                ServiceResponse { wall_s: out.wall_s, projection: out.projection }
            }))
        }
        ServiceRequest::FalseDgemm { alpha, beta, k, params } => {
            let (m, n) = (ukr.geom.m, ukr.geom.n);
            let payload = shm.take_f64();
            if payload.len() != m * k + k * n + m * n {
                return Some(Err(anyhow!("HH-RAM f64 payload size mismatch (k={k})")));
            }
            let (a, rest) = payload.split_at(m * k);
            let (b, c) = rest.split_at(k * n);
            Some(ukr.false_dgemm(alpha, a, b, beta, c, params).map(|(c_out, wall_s, projection)| {
                shm.write_f64(&c_out);
                ServiceResponse { wall_s, projection }
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{max_scaled_err, Mat};

    fn row_major(b: &Mat<f32>) -> Vec<f32> {
        let (k, n) = (b.rows(), b.cols());
        let mut out = vec![0.0f32; k * n];
        for l in 0..k {
            for j in 0..n {
                out[l * n + j] = b.get(l, j);
            }
        }
        out
    }

    fn service(backend: ServiceBackend) -> ServiceHandle {
        ServiceHandle::spawn(backend, CalibratedModel::default(), KernelGeometry::paper()).unwrap()
    }

    fn call(svc: &ServiceHandle, k: usize, seed: u64) -> (Mat<f32>, Mat<f32>) {
        let g = svc.geometry();
        let a = Mat::<f32>::randn(g.m, k, seed);
        let b = Mat::<f32>::randn(k, g.n, seed + 1);
        let c = Mat::<f32>::randn(g.m, g.n, seed + 2);
        let (got, resp) = svc
            .sgemm(1.0, a.as_slice(), &row_major(&b), 1.0, c.as_slice(),
                   ProjectionParams::kernel_service(k))
            .unwrap();
        assert!(resp.projection.ipc_s > 0.0, "service path must charge IPC");
        let want = Mat::from_fn(g.m, g.n, |i, j| {
            let mut acc = 0.0f64;
            for l in 0..k {
                acc += a.get(i, l) as f64 * b.get(l, j) as f64;
            }
            (acc + c.get(i, j) as f64) as f32
        });
        (Mat::from_col_major(g.m, g.n, &got), want)
    }

    #[test]
    fn service_round_trip_simulator() {
        let svc = service(ServiceBackend::Simulator);
        let (got, want) = call(&svc, 128, 50);
        let e = max_scaled_err(got.view(), want.view());
        assert!(e < 1e-5, "err {e}");
    }

    // The PJRT boot path needs the `pjrt` feature + built artifacts.
    #[cfg(feature = "pjrt")]
    #[test]
    fn service_round_trip_pjrt() {
        let svc = service(ServiceBackend::Pjrt);
        let (got, want) = call(&svc, 128, 60);
        let e = max_scaled_err(got.view(), want.view());
        assert!(e < 1e-5, "err {e}");
    }

    #[test]
    fn service_survives_many_calls() {
        // The whole point of the service: > MAX_REINIT calls through ONE
        // init. (Per-call init/finalize would fail after 8 — see esdk.)
        let svc = service(ServiceBackend::Simulator);
        for i in 0..(crate::esdk::MAX_REINIT + 4) {
            let (got, want) = call(&svc, 64, 70 + i as u64);
            let e = max_scaled_err(got.view(), want.view());
            assert!(e < 1e-5, "call {i} err {e}");
        }
    }

    #[test]
    fn ping_and_fault_injection() {
        let svc = service(ServiceBackend::Simulator);
        svc.ping().unwrap();
        svc.fail_next_calls(2);
        assert!(svc.ping().is_err());
        assert!(svc.ping().is_err());
        svc.ping().unwrap(); // counter drained
        svc.fail_next_calls(usize::MAX);
        assert!(svc.ping().is_err());
        assert!(svc.ping().is_err(), "usize::MAX stays armed");
        svc.clear_faults();
        svc.ping().unwrap();
        // Panic faults fire on the caller's thread, not the service's.
        svc.panic_next_calls(1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| svc.ping()));
        assert!(r.is_err(), "armed panic fault must unwind the caller");
        svc.ping().unwrap();
    }

    #[test]
    fn injected_error_reaches_sgemm_callers() {
        let svc = service(ServiceBackend::Simulator);
        let g = svc.geometry();
        svc.fail_next_calls(1);
        let r = svc.sgemm(
            1.0,
            &vec![0.0f32; g.m * 4],
            &vec![0.0f32; 4 * g.n],
            0.0,
            &vec![0.0f32; g.m * g.n],
            ProjectionParams::kernel_service(4),
        );
        assert!(format!("{:#}", r.unwrap_err()).contains("injected fault"));
        // The handle still serves once the fault is consumed.
        let (got, want) = call(&svc, 32, 90);
        assert!(max_scaled_err(got.view(), want.view()) < 1e-5);
    }

    #[test]
    fn false_dgemm_through_service() {
        let svc = service(ServiceBackend::Simulator);
        let g = svc.geometry();
        let k = 64;
        let a = Mat::<f64>::randn(g.m, k, 80);
        let b = Mat::<f64>::randn(k, g.n, 81);
        let c = Mat::<f64>::randn(g.m, g.n, 82);
        let mut b_rm = vec![0.0f64; k * g.n];
        for l in 0..k {
            for j in 0..g.n {
                b_rm[l * g.n + j] = b.get(l, j);
            }
        }
        let (got, resp) = svc
            .false_dgemm(1.0, a.as_slice(), &b_rm, 0.0, c.as_slice(),
                         ProjectionParams::kernel_service(k))
            .unwrap();
        assert!(resp.projection.cast_s > 0.0);
        let got = Mat::from_col_major(g.m, g.n, &got);
        let want = Mat::from_fn(g.m, g.n, |i, j| {
            let mut acc = 0.0f64;
            for l in 0..k {
                acc += a.get(i, l) * b.get(l, j);
            }
            acc
        });
        let e = max_scaled_err(got.view(), want.view());
        assert!(e > 1e-10 && e < 1e-4, "f32-sized err expected, got {e}");
    }
}
