//! Norms and error measures used by the result tables.
//!
//! The paper reports three flavours: mean/max *relative* error against a
//! double-precision reference (Tables 1–2), the BLIS-testsuite normalized
//! residue (Tables 3–6), and the HPL residual (Table 7).

use super::matrix::MatRef;
use super::scalar::Real;

/// Infinity norm: max row sum of absolute values.
pub fn inf_norm<T: Real>(a: MatRef<'_, T>) -> f64 {
    let mut best = 0.0f64;
    for i in 0..a.rows() {
        let mut s = 0.0f64;
        for j in 0..a.cols() {
            s += a.get(i, j).to_f64().abs();
        }
        best = best.max(s);
    }
    best
}

/// One norm: max column sum of absolute values.
pub fn one_norm<T: Real>(a: MatRef<'_, T>) -> f64 {
    let mut best = 0.0f64;
    for j in 0..a.cols() {
        let mut s = 0.0f64;
        for i in 0..a.rows() {
            s += a.get(i, j).to_f64().abs();
        }
        best = best.max(s);
    }
    best
}

/// Frobenius norm.
pub fn frobenius<T: Real>(a: MatRef<'_, T>) -> f64 {
    let mut s = 0.0f64;
    for j in 0..a.cols() {
        for i in 0..a.rows() {
            let v = a.get(i, j).to_f64();
            s += v * v;
        }
    }
    s.sqrt()
}

/// Largest absolute entry.
pub fn max_abs<T: Real>(a: MatRef<'_, T>) -> f64 {
    let mut best = 0.0f64;
    for j in 0..a.cols() {
        for i in 0..a.rows() {
            best = best.max(a.get(i, j).to_f64().abs());
        }
    }
    best
}

/// Mean of `|got - want| / |want|` over entries with non-negligible `want`
/// — the paper's "Mean Relative Error" row (Tables 1–2), computed against
/// an f64 reference.
pub fn mean_rel_err<T: Real, U: Real>(got: MatRef<'_, T>, want: MatRef<'_, U>) -> f64 {
    assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()));
    let mut sum = 0.0f64;
    let mut n = 0usize;
    let scale = max_abs(want).max(f64::MIN_POSITIVE);
    for j in 0..got.cols() {
        for i in 0..got.rows() {
            let w = want.get(i, j).to_f64();
            let g = got.get(i, j).to_f64();
            // Guard tiny denominators the way numeric test suites do: fall
            // back to the matrix scale.
            let denom = w.abs().max(1e-6 * scale);
            sum += (g - w).abs() / denom;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Max of `|got - want| / max|want|` — error normalized by the matrix
/// scale. Robust for testing near-zero entries (where a per-element
/// relative error is meaningless).
pub fn max_scaled_err<T: Real, U: Real>(got: MatRef<'_, T>, want: MatRef<'_, U>) -> f64 {
    assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()));
    let scale = max_abs(want).max(f64::MIN_POSITIVE);
    let mut best = 0.0f64;
    for j in 0..got.cols() {
        for i in 0..got.rows() {
            best = best.max((got.get(i, j).to_f64() - want.get(i, j).to_f64()).abs());
        }
    }
    best / scale
}

/// Max of `|got - want| / |want|` — the paper's "Maximum Relative Error".
pub fn max_rel_err<T: Real, U: Real>(got: MatRef<'_, T>, want: MatRef<'_, U>) -> f64 {
    assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()));
    let mut best = 0.0f64;
    let scale = max_abs(want).max(f64::MIN_POSITIVE);
    for j in 0..got.cols() {
        for i in 0..got.rows() {
            let w = want.get(i, j).to_f64();
            let g = got.get(i, j).to_f64();
            let denom = w.abs().max(1e-6 * scale);
            best = best.max((g - w).abs() / denom);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn inf_and_one_norms() {
        let m = Mat::<f64>::from_fn(2, 2, |i, j| if (i, j) == (0, 1) { -3.0 } else { 1.0 });
        assert_eq!(inf_norm(m.view()), 4.0); // row 0: 1 + 3
        assert_eq!(one_norm(m.view()), 4.0); // col 1: 3 + 1
    }

    #[test]
    fn frobenius_of_ones() {
        let m = Mat::<f32>::full(3, 3, 1.0);
        assert!((frobenius(m.view()) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let m = Mat::<f32>::randn(10, 10, 5);
        assert_eq!(max_rel_err(m.view(), m.view()), 0.0);
        assert_eq!(mean_rel_err(m.view(), m.view()), 0.0);
    }

    #[test]
    fn rel_err_detects_perturbation() {
        let want = Mat::<f64>::full(4, 4, 2.0);
        let mut got = want.cast::<f32>();
        got.set(1, 1, 2.0 + 2e-4);
        let e = max_rel_err(got.view(), want.view());
        assert!((e - 1e-4).abs() < 1e-6, "e = {e}");
        assert!(mean_rel_err(got.view(), want.view()) < e);
    }
}
