//! Owned matrices and strided views.
//!
//! Storage is column-major (FORTRAN order), matching both the BLAS
//! convention and the paper's micro-kernel contract (§3.3: "a1 is
//! column-major stored, b1 is row-major stored and c_in, c_out are
//! column-major stored" — a row-major `b1` is just a column-major view with
//! swapped strides).

use super::rng::XorShiftRng;
use super::scalar::Real;

/// Owned, column-major dense matrix.
#[derive(Clone, PartialEq)]
pub struct Mat<T: Real> {
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

impl<T: Real> Mat<T> {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![T::ZERO; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, v: T) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Deterministic pseudo-normal entries in roughly `[-1, 1]` — the same
    /// distribution class the BLIS testsuite uses for its residue checks.
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let data = (0..rows * cols).map(|_| T::from_f64(rng.next_unit())).collect();
        Mat { rows, cols, data }
    }

    /// Build from a column-major slice.
    pub fn from_col_major(rows: usize, cols: usize, data: &[T]) -> Self {
        assert_eq!(data.len(), rows * cols, "column-major data length mismatch");
        Mat { rows, cols, data: data.to_vec() }
    }

    /// Build from a function of `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m.set(i, j, f(i, j));
            }
        }
        m
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element `(i, j)`.
    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> T {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i + j * self.rows]
    }

    /// Store `v` at `(i, j)`.
    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i + j * self.rows] = v;
    }

    /// The raw column-major buffer.
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }
    /// The raw column-major buffer, mutably.
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Immutable full-matrix view (`rs = 1, cs = rows`).
    pub fn view(&self) -> MatRef<'_, T> {
        MatRef {
            rows: self.rows,
            cols: self.cols,
            rs: 1,
            cs: self.rows as isize,
            data: &self.data,
            offset: 0,
        }
    }

    /// Mutable full-matrix view.
    pub fn view_mut(&mut self) -> MatMut<'_, T> {
        let rows = self.rows;
        MatMut { rows, cols: self.cols, rs: 1, cs: rows as isize, data: &mut self.data, offset: 0 }
    }

    /// Transposed *view* (stride swap, no copy).
    pub fn t(&self) -> MatRef<'_, T> {
        self.view().t()
    }

    /// Materialize the transpose.
    pub fn transposed(&self) -> Mat<T> {
        Mat::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    /// Cast every element (used by the "false dgemm": f64 API, f32 compute).
    pub fn cast<U: Real>(&self) -> Mat<U> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| U::from_f64(v.to_f64())).collect(),
        }
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: T) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }
}

impl<T: Real> std::fmt::Debug for Mat<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let rmax = self.rows.min(6);
        let cmax = self.cols.min(6);
        for i in 0..rmax {
            write!(f, "  ")?;
            for j in 0..cmax {
                write!(f, "{:>12.5e} ", self.get(i, j).to_f64())?;
            }
            writeln!(f, "{}", if self.cols > cmax { "..." } else { "" })?;
        }
        if self.rows > rmax {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

/// Immutable strided view: element `(i, j)` lives at
/// `data[offset + i*rs + j*cs]`. BLIS semantics — `rs`/`cs` may be negative
/// in principle, but this crate only produces non-negative strides; `cs` is
/// kept `isize` for parity with the BLIS object API.
#[derive(Clone, Copy)]
pub struct MatRef<'a, T: Real> {
    rows: usize,
    cols: usize,
    rs: isize,
    cs: isize,
    data: &'a [T],
    offset: usize,
}

impl<'a, T: Real> MatRef<'a, T> {
    /// View over a raw column-major buffer with an explicit leading
    /// dimension (classic BLAS `lda`). Accepts the classic minimal
    /// buffer: `lda·(cols−1) + rows` elements (tight trailing column).
    pub fn from_col_major(rows: usize, cols: usize, lda: usize, data: &'a [T]) -> Self {
        assert!(lda >= rows, "lda {lda} < rows {rows}");
        let need = if cols == 0 { 0 } else { lda * (cols - 1) + rows };
        assert!(data.len() >= need, "buffer too small: {} < {need}", data.len());
        MatRef { rows, cols, rs: 1, cs: lda as isize, data, offset: 0 }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// Element step between consecutive rows of a column.
    pub fn row_stride(&self) -> isize {
        self.rs
    }
    /// Element step between consecutive columns of a row.
    pub fn col_stride(&self) -> isize {
        self.cs
    }

    /// True when columns are contiguous in memory (`rs == 1`): packing can
    /// use `copy_from_slice` per column. This is what makes the `n` variants
    /// faster than the `t` variants in Table 4.
    pub fn is_col_contiguous(&self) -> bool {
        self.rs == 1
    }

    /// Element `(i, j)`.
    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> T {
        debug_assert!(i < self.rows && j < self.cols);
        let idx = self.offset as isize + i as isize * self.rs + j as isize * self.cs;
        self.data[idx as usize]
    }

    /// Transposed view: swap dims and strides.
    pub fn t(self) -> MatRef<'a, T> {
        MatRef {
            rows: self.cols,
            cols: self.rows,
            rs: self.cs,
            cs: self.rs,
            data: self.data,
            offset: self.offset,
        }
    }

    /// Sub-view of `nr x nc` starting at `(i, j)`.
    pub fn sub(self, i: usize, j: usize, nr: usize, nc: usize) -> MatRef<'a, T> {
        assert!(i + nr <= self.rows && j + nc <= self.cols, "sub-view out of range");
        let offset = (self.offset as isize + i as isize * self.rs + j as isize * self.cs) as usize;
        MatRef { rows: nr, cols: nc, rs: self.rs, cs: self.cs, data: self.data, offset }
    }

    /// Copy into an owned matrix.
    pub fn to_mat(&self) -> Mat<T> {
        Mat::from_fn(self.rows, self.cols, |i, j| self.get(i, j))
    }

    /// Contiguous column slice when `rs == 1`.
    pub fn col_slice(&self, j: usize, i0: usize, len: usize) -> &'a [T] {
        assert!(self.rs == 1, "col_slice requires unit row stride");
        assert!(i0 + len <= self.rows);
        let start = (self.offset as isize + i0 as isize + j as isize * self.cs) as usize;
        &self.data[start..start + len]
    }
}

/// Mutable strided view (same layout rules as [`MatRef`]).
pub struct MatMut<'a, T: Real> {
    rows: usize,
    cols: usize,
    rs: isize,
    cs: isize,
    data: &'a mut [T],
    offset: usize,
}

impl<'a, T: Real> MatMut<'a, T> {
    /// See [`MatRef::from_col_major`]; the classic minimal buffer of
    /// `lda·(cols−1) + rows` elements is accepted.
    pub fn from_col_major(rows: usize, cols: usize, lda: usize, data: &'a mut [T]) -> Self {
        assert!(lda >= rows, "lda {lda} < rows {rows}");
        let need = if cols == 0 { 0 } else { lda * (cols - 1) + rows };
        assert!(data.len() >= need, "buffer too small: {} < {need}", data.len());
        MatMut { rows, cols, rs: 1, cs: lda as isize, data, offset: 0 }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// Element step between consecutive rows of a column.
    pub fn row_stride(&self) -> isize {
        self.rs
    }
    /// Element step between consecutive columns of a row.
    pub fn col_stride(&self) -> isize {
        self.cs
    }

    /// Element `(i, j)`.
    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> T {
        debug_assert!(i < self.rows && j < self.cols);
        let idx = self.offset as isize + i as isize * self.rs + j as isize * self.cs;
        self.data[idx as usize]
    }

    /// Store `v` at `(i, j)`.
    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        debug_assert!(i < self.rows && j < self.cols);
        let idx = self.offset as isize + i as isize * self.rs + j as isize * self.cs;
        self.data[idx as usize] = v;
    }

    /// Apply `f` to element `(i, j)` in place.
    #[inline(always)]
    pub fn update(&mut self, i: usize, j: usize, f: impl FnOnce(T) -> T) {
        let v = self.get(i, j);
        self.set(i, j, f(v));
    }

    /// Reborrow as an immutable view.
    pub fn as_ref(&self) -> MatRef<'_, T> {
        MatRef {
            rows: self.rows,
            cols: self.cols,
            rs: self.rs,
            cs: self.cs,
            data: self.data,
            offset: self.offset,
        }
    }

    /// Reborrow a mutable sub-view.
    pub fn sub_mut(&mut self, i: usize, j: usize, nr: usize, nc: usize) -> MatMut<'_, T> {
        assert!(i + nr <= self.rows && j + nc <= self.cols, "sub-view out of range");
        let offset = (self.offset as isize + i as isize * self.rs + j as isize * self.cs) as usize;
        MatMut { rows: nr, cols: nc, rs: self.rs, cs: self.cs, data: self.data, offset }
    }

    /// Transposed mutable view.
    pub fn t_mut(self) -> MatMut<'a, T> {
        MatMut {
            rows: self.cols,
            cols: self.rows,
            rs: self.cs,
            cs: self.rs,
            data: self.data,
            offset: self.offset,
        }
    }

    /// Contiguous mutable column slice when `rs == 1`.
    pub fn col_slice_mut(&mut self, j: usize, i0: usize, len: usize) -> &mut [T] {
        assert!(self.rs == 1, "col_slice_mut requires unit row stride");
        assert!(i0 + len <= self.rows);
        let start = (self.offset as isize + i0 as isize + j as isize * self.cs) as usize;
        &mut self.data[start..start + len]
    }

    /// Copy every element from `src` (dims must match).
    pub fn copy_from(&mut self, src: MatRef<'_, T>) {
        assert_eq!((self.rows, self.cols), (src.rows(), src.cols()));
        for j in 0..self.cols {
            for i in 0..self.rows {
                self.set(i, j, src.get(i, j));
            }
        }
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: T) {
        for j in 0..self.cols {
            for i in 0..self.rows {
                self.set(i, j, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn col_major_layout() {
        let m = Mat::<f32>::from_fn(3, 2, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.as_slice(), &[0.0, 10.0, 20.0, 1.0, 11.0, 21.0]);
    }

    #[test]
    fn transpose_is_stride_swap() {
        let m = Mat::<f32>::from_fn(3, 2, |i, j| (i * 10 + j) as f32);
        let t = m.t();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(m.get(i, j), t.get(j, i));
            }
        }
        assert!(!t.is_col_contiguous());
    }

    #[test]
    fn sub_view_indexing() {
        let m = Mat::<f64>::from_fn(5, 5, |i, j| (i * 100 + j) as f64);
        let s = m.view().sub(1, 2, 3, 2);
        assert_eq!(s.get(0, 0), 102.0);
        assert_eq!(s.get(2, 1), 303.0);
    }

    #[test]
    fn sub_mut_writes_through() {
        let mut m = Mat::<f32>::zeros(4, 4);
        {
            let mut v = m.view_mut();
            let mut s = v.sub_mut(2, 2, 2, 2);
            s.set(0, 0, 7.0);
            s.set(1, 1, 9.0);
        }
        assert_eq!(m.get(2, 2), 7.0);
        assert_eq!(m.get(3, 3), 9.0);
        assert_eq!(m.get(0, 0), 0.0);
    }

    #[test]
    fn lda_view() {
        // 2x2 window in a 4-row buffer: classic lda > rows.
        let data: Vec<f32> = (0..8).map(|v| v as f32).collect();
        let v = MatRef::from_col_major(2, 2, 4, &data);
        assert_eq!(v.get(0, 0), 0.0);
        assert_eq!(v.get(1, 0), 1.0);
        assert_eq!(v.get(0, 1), 4.0);
        assert_eq!(v.get(1, 1), 5.0);
    }

    #[test]
    fn cast_round_trip() {
        let m = Mat::<f64>::randn(8, 8, 3);
        let f = m.cast::<f32>();
        let back = f.cast::<f64>();
        for j in 0..8 {
            for i in 0..8 {
                assert!((m.get(i, j) - back.get(i, j)).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn randn_is_deterministic() {
        let a = Mat::<f32>::randn(16, 16, 42);
        let b = Mat::<f32>::randn(16, 16, 42);
        assert_eq!(a.as_slice(), b.as_slice());
        let c = Mat::<f32>::randn(16, 16, 43);
        assert_ne!(a.as_slice(), c.as_slice());
    }
}
