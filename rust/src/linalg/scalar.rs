//! The scalar abstraction: the BLAS is instantiated for `f32` (sgemm et al.)
//! and `f64` (dgemm et al., plus the paper's "false dgemm" which is an f64
//! API over f32 compute).

use std::fmt::{Debug, Display};
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Real scalar usable by every kernel in the crate.
///
/// Deliberately tiny: just what the BLAS, the simulator and HPL need.
pub trait Real:
    Copy
    + Clone
    + Debug
    + Display
    + Default
    + PartialOrd
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + Sum
    + Send
    + Sync
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    /// Machine epsilon (2^-23 for f32, 2^-53 for f64 — the paper's Table 7
    /// residue is scaled by the latter).
    const EPSILON: Self;

    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    /// Fused multiply-add; the Epiphany core's FMADD is the unit of the
    /// cycle model, and using `mul_add` here keeps rounding single-step like
    /// the hardware.
    fn mul_add(self, a: Self, b: Self) -> Self;
    fn is_finite(self) -> bool;
    fn max(self, other: Self) -> Self;
    fn min(self, other: Self) -> Self;
}

macro_rules! impl_real {
    ($t:ty, $eps:expr) => {
        impl Real for $t {
            const ZERO: Self = 0.0;
            const ONE: Self = 1.0;
            const EPSILON: Self = $eps;

            #[inline(always)]
            fn from_f64(v: f64) -> Self {
                v as $t
            }
            #[inline(always)]
            fn to_f64(self) -> f64 {
                self as f64
            }
            #[inline(always)]
            fn abs(self) -> Self {
                <$t>::abs(self)
            }
            #[inline(always)]
            fn sqrt(self) -> Self {
                <$t>::sqrt(self)
            }
            #[inline(always)]
            fn mul_add(self, a: Self, b: Self) -> Self {
                <$t>::mul_add(self, a, b)
            }
            #[inline(always)]
            fn is_finite(self) -> bool {
                <$t>::is_finite(self)
            }
            #[inline(always)]
            fn max(self, other: Self) -> Self {
                <$t>::max(self, other)
            }
            #[inline(always)]
            fn min(self, other: Self) -> Self {
                <$t>::min(self, other)
            }
        }
    };
}

impl_real!(f32, f32::EPSILON);
impl_real!(f64, f64::EPSILON);
