//! Dense column-major matrix substrate shared by every layer of the stack.
//!
//! The paper's BLAS operates on FORTRAN-style column-major matrices with
//! arbitrary leading dimensions; BLIS generalizes that to independent row
//! and column strides. [`Mat`] owns storage; [`MatRef`]/[`MatMut`] are
//! strided views with the BLIS `(rs, cs)` stride pair, so a transpose is a
//! stride swap, never a copy.

mod matrix;
mod norms;
mod rng;
mod scalar;

pub use matrix::{Mat, MatMut, MatRef};
pub use norms::{frobenius, inf_norm, max_abs, max_rel_err, max_scaled_err, mean_rel_err, one_norm};
pub use rng::XorShiftRng;
pub use scalar::Real;
