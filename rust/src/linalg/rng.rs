//! Deterministic xorshift RNG.
//!
//! Every stochastic input in the repo (test matrices, HPL systems, workload
//! generators) flows through this so runs are reproducible without pulling
//! in an external RNG crate.

/// xorshift64* generator. Not cryptographic; stable across platforms.
#[derive(Clone, Debug)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    /// Seeded generator (any seed, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        XorShiftRng { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) }
    }

    /// The next raw 64-bit sample.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in `[-1, 1)` — the BLIS-testsuite-style operand distribution.
    #[inline]
    pub fn next_unit(&mut self) -> f64 {
        2.0 * self.next_f64() - 1.0
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn next_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = XorShiftRng::new(7);
        let mut b = XorShiftRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn unit_range() {
        let mut r = XorShiftRng::new(11);
        for _ in 0..10_000 {
            let v = r.next_unit();
            assert!((-1.0..1.0).contains(&v));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = XorShiftRng::new(13);
        let mut buckets = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            buckets[(r.next_f64() * 10.0) as usize] += 1;
        }
        for &b in &buckets {
            // each bucket within 10% of expected
            assert!((b as f64 - n as f64 / 10.0).abs() < n as f64 / 100.0);
        }
    }

    #[test]
    fn zero_seed_is_not_degenerate() {
        let mut r = XorShiftRng::new(0);
        let v: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert!(v.iter().any(|&x| x != 0));
        assert_ne!(v[0], v[1]);
    }
}
