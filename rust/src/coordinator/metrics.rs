//! Service metrics: request counters, latency histogram, throughput, and
//! per-chip execution counts for the sharded pool.

use std::sync::Mutex;
use std::time::Instant;

/// Log-scaled latency histogram (µs buckets: 1, 2, 4, ... ~17 min).
const BUCKETS: usize = 30;

#[derive(Default)]
struct Inner {
    requests: u64,
    errors: u64,
    gemm_requests: u64,
    gemv_requests: u64,
    batched: u64,
    flops: f64,
    latency_us: [u64; BUCKETS],
    total_latency_s: f64,
    started: Option<Instant>,
    /// Batch executions per chip (index = chip id; grown on demand).
    chip_gemms: Vec<u64>,
}

/// Thread-safe metrics sink.
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    /// A fresh sink; uptime starts now.
    pub fn new() -> Self {
        Metrics { inner: Mutex::new(Inner { started: Some(Instant::now()), ..Default::default() }) }
    }

    /// Record one completed request of `kind` with its latency and
    /// logical flop count.
    pub fn record_request(&self, kind: RequestKind, latency_s: f64, flops: f64) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        match kind {
            RequestKind::Gemm => m.gemm_requests += 1,
            RequestKind::Gemv => m.gemv_requests += 1,
            RequestKind::Other => {}
        }
        m.flops += flops;
        m.total_latency_s += latency_s;
        let us = (latency_s * 1e6).max(1.0);
        let bucket = (us.log2() as usize).min(BUCKETS - 1);
        m.latency_us[bucket] += 1;
    }

    /// Record a failed request.
    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Record that `n` jobs executed as one coalesced batch.
    pub fn record_batched(&self, n: usize) {
        self.inner.lock().unwrap().batched += n as u64;
    }

    /// Record one chip-pinned execution on `chip` (the counter behind the
    /// `chipN_gemms` report labels). Counts batcher groups and hinted
    /// direct gemms — an *unhinted* f64 gemm shards across the whole pool
    /// and is visible in [`crate::host::pool::ChipPool::crossings`]
    /// rather than here.
    pub fn record_chip_request(&self, chip: usize) {
        let mut m = self.inner.lock().unwrap();
        if m.chip_gemms.len() <= chip {
            m.chip_gemms.resize(chip + 1, 0);
        }
        m.chip_gemms[chip] += 1;
    }

    /// Total requests recorded.
    pub fn requests(&self) -> u64 {
        self.inner.lock().unwrap().requests
    }

    /// Per-chip batch-execution counts (empty until a chip executes).
    pub fn chip_requests(&self) -> Vec<u64> {
        self.inner.lock().unwrap().chip_gemms.clone()
    }

    /// Latency below which a fraction `q` of requests fall, read from the
    /// log-scaled histogram (a bucket *upper* bound, in seconds).
    ///
    /// The edges are explicit:
    /// * no samples recorded → `0.0`, whatever `q` is;
    /// * a non-finite `q` (NaN, ±∞ — arithmetic upstream gone wrong) is
    ///   treated as `0.0`;
    /// * `q` outside `[0, 1]` is clamped, so `q <= 0` returns the
    ///   smallest occupied bucket bound and `q >= 1` the largest.
    pub fn latency_quantile(&self, q: f64) -> f64 {
        let m = self.inner.lock().unwrap();
        let total: u64 = m.latency_us.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let q = if q.is_finite() { q.clamp(0.0, 1.0) } else { 0.0 };
        let target = ((q * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in m.latency_us.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << i) as f64 / 1e6; // bucket upper bound in s
            }
        }
        (1u64 << (BUCKETS - 1)) as f64 / 1e6
    }

    /// Human-readable report (the `Stats` opcode's payload), with one
    /// `chipN_gemms` label per chip that has executed work.
    pub fn report(&self) -> String {
        let m = self.inner.lock().unwrap();
        let uptime = m.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        let mean_lat = if m.requests > 0 { m.total_latency_s / m.requests as f64 } else { 0.0 };
        let mut line = format!(
            "requests={} errors={} gemm={} gemv={} batched={} uptime_s={:.1} \
             mean_latency_s={:.6} achieved_gflops={:.3}",
            m.requests,
            m.errors,
            m.gemm_requests,
            m.gemv_requests,
            m.batched,
            uptime,
            mean_lat,
            if uptime > 0.0 { m.flops / uptime / 1e9 } else { 0.0 },
        );
        for (i, c) in m.chip_gemms.iter().enumerate() {
            line.push_str(&format!(" chip{i}_gemms={c}"));
        }
        line
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Routing category of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    /// Level-3 gemm (the Epiphany-accelerated class).
    Gemm,
    /// Level-2 gemv (host compute).
    Gemv,
    /// Anything else (control ops).
    Other,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(RequestKind::Gemm, 0.001, 1e6);
        m.record_request(RequestKind::Gemv, 0.002, 1e3);
        m.record_error();
        assert_eq!(m.requests(), 2);
        let rep = m.report();
        assert!(rep.contains("requests=2"));
        assert!(rep.contains("errors=1"));
        assert!(rep.contains("gemm=1"));
    }

    #[test]
    fn quantiles_monotone() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_request(RequestKind::Gemm, i as f64 * 1e-4, 0.0);
        }
        let p50 = m.latency_quantile(0.5);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 0.0);
    }

    #[test]
    fn empty_quantile_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile(0.9), 0.0);
        // Out-of-range and non-finite q are still 0 on no samples.
        assert_eq!(m.latency_quantile(-3.0), 0.0);
        assert_eq!(m.latency_quantile(f64::NAN), 0.0);
    }

    #[test]
    fn quantile_q_edges_clamped() {
        let m = Metrics::new();
        m.record_request(RequestKind::Gemm, 1e-5, 0.0);
        m.record_request(RequestKind::Gemm, 1e-1, 0.0);
        let lo = m.latency_quantile(0.0);
        let hi = m.latency_quantile(1.0);
        assert!(lo > 0.0 && lo <= hi);
        // q below 0 / above 1 clamp to the same edges.
        assert_eq!(m.latency_quantile(-1.0), lo);
        assert_eq!(m.latency_quantile(7.5), hi);
        // Non-finite q reads as 0.
        assert_eq!(m.latency_quantile(f64::NAN), lo);
        assert_eq!(m.latency_quantile(f64::INFINITY), lo);
    }

    #[test]
    fn per_chip_labels_in_report() {
        let m = Metrics::new();
        m.record_chip_request(1);
        m.record_chip_request(1);
        m.record_chip_request(0);
        assert_eq!(m.chip_requests(), vec![1, 2]);
        let rep = m.report();
        assert!(rep.contains("chip0_gemms=1"), "{rep}");
        assert!(rep.contains("chip1_gemms=2"), "{rep}");
    }
}
