//! Service metrics: request counters, latency histogram, throughput, and
//! per-chip execution counts for the sharded pool.

use std::sync::Mutex;
use std::time::Instant;

/// Log-scaled latency histogram (µs buckets: 1, 2, 4, ... ~17 min).
const BUCKETS: usize = 30;

/// Distinct [`RequestKind`] latency streams (Gemm, Gemv, Batch, Solve,
/// Other) — one histogram each, so a 400-item batch's latency can't
/// skew the single-gemm quantiles.
const KINDS: usize = 5;

#[derive(Default)]
struct Inner {
    requests: u64,
    errors: u64,
    io_errors: u64,
    deadline_exceeded: u64,
    rejected_in_flight: u64,
    gemm_requests: u64,
    gemv_requests: u64,
    batch_requests: u64,
    solve_requests: u64,
    batched: u64,
    requeued: u64,
    flops: f64,
    /// The combined latency histogram, all kinds (legacy quantiles).
    latency_us: [u64; BUCKETS],
    /// Per-kind latency histograms, indexed by [`RequestKind::index`].
    kind_latency_us: [[u64; BUCKETS]; KINDS],
    total_latency_s: f64,
    started: Option<Instant>,
    /// Batch executions per chip (index = chip id; grown on demand).
    chip_gemms: Vec<u64>,
}

/// A typed snapshot of the service counters — the `Stats` opcode's
/// payload since wire v2 (previously a formatted string).
///
/// The [`std::fmt::Display`] impl renders the classic `key=value` report
/// line, so text consumers (the CLI, log scrapers) keep working.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsReport {
    /// Completed requests (gemm + gemv).
    pub requests: u64,
    /// Failed requests.
    pub errors: u64,
    /// Read-side I/O failures (mid-frame disconnects, oversized frames).
    pub io_errors: u64,
    /// Requests that missed their per-request deadline.
    pub deadline_exceeded: u64,
    /// Requests bounced because a connection's in-flight window was full.
    pub rejected_in_flight: u64,
    /// Completed gemm requests.
    pub gemm_requests: u64,
    /// Completed gemv requests.
    pub gemv_requests: u64,
    /// Completed gemm-batch requests (each counted once, however many
    /// items it carried).
    pub batch_requests: u64,
    /// Completed iterative-refinement solve requests.
    pub solve_requests: u64,
    /// Jobs that executed as part of a coalesced batch.
    pub batched: u64,
    /// Jobs moved off a wounded chip onto a healthy chip's queue by the
    /// batcher's health requeue (failed groups being retried plus queued
    /// jobs drained off an unhealthy chip).
    pub requeued: u64,
    /// Packed-A panels served from the residency cache (filled in by the
    /// router from [`crate::mem::PanelCache`]; 0 when the cache is off).
    pub panel_hits: u64,
    /// Packed-A panel cache misses (each one ran a `pack_a`).
    pub panel_misses: u64,
    /// Panels evicted to hold the cache under its byte budget.
    pub panel_evictions: u64,
    /// Buffer-pool gets served by a recycled allocation (wire bodies +
    /// batcher staging; filled in by the router).
    pub pool_recycled: u64,
    /// Seconds since the metrics sink was created.
    pub uptime_s: f64,
    /// Mean request latency in seconds.
    pub mean_latency_s: f64,
    /// Total flops / uptime, in Gflop/s.
    pub achieved_gflops: f64,
    /// Median latency (histogram bucket upper bound, seconds).
    pub p50_s: f64,
    /// 99th-percentile latency (histogram bucket upper bound, seconds).
    pub p99_s: f64,
    /// p99 latency of the single-gemm stream alone (0 if none ran) —
    /// per-opcode streams keep a 400-item batch from skewing this.
    pub gemm_p99_s: f64,
    /// p99 latency of the gemv stream alone (0 if none ran).
    pub gemv_p99_s: f64,
    /// p99 latency of the gemm-batch stream alone (0 if none ran).
    pub batch_p99_s: f64,
    /// p99 latency of the solve stream alone (0 if none ran).
    pub solve_p99_s: f64,
    /// Jobs queued across every chip's batcher queue when sampled (filled
    /// in by the router; a bare [`Metrics::snapshot`] reports 0).
    pub queue_depth: u64,
    /// Batch executions per chip (index = chip id).
    pub chip_gemms: Vec<u64>,
    /// Health of every pool chip when sampled (`true` = healthy; filled
    /// in by the router from the pool — a bare [`Metrics::snapshot`]
    /// reports an empty vec, like `queue_depth`).
    pub chip_health: Vec<bool>,
}

impl StatsReport {
    /// Batch executions recorded on `chip` (0 for chips never seen).
    pub fn gemms_on(&self, chip: usize) -> u64 {
        self.chip_gemms.get(chip).copied().unwrap_or(0)
    }

    /// Whether chip `i` was healthy when sampled (`true` for chips the
    /// sampler could not see — absence of evidence is not a dead chip).
    pub fn healthy_on(&self, chip: usize) -> bool {
        self.chip_health.get(chip).copied().unwrap_or(true)
    }

    /// Number of chips marked unhealthy when sampled (the report line's
    /// `unhealthy_chips=` label).
    pub fn unhealthy_chips(&self) -> u64 {
        self.chip_health.iter().filter(|&&h| !h).count() as u64
    }
}

impl std::fmt::Display for StatsReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} errors={} gemm={} gemv={} batch={} solve={} batched={} uptime_s={:.1} \
             mean_latency_s={:.6} achieved_gflops={:.3} queue_depth={} io_errors={} \
             deadline_exceeded={} rejected_in_flight={} panel_hits={} panel_misses={} \
             panel_evictions={} pool_recycled={} p50_s={:.6} p99_s={:.6} gemm_p99_s={:.6} \
             gemv_p99_s={:.6} batch_p99_s={:.6} solve_p99_s={:.6} requeued={} \
             unhealthy_chips={}",
            self.requests,
            self.errors,
            self.gemm_requests,
            self.gemv_requests,
            self.batch_requests,
            self.solve_requests,
            self.batched,
            self.uptime_s,
            self.mean_latency_s,
            self.achieved_gflops,
            self.queue_depth,
            self.io_errors,
            self.deadline_exceeded,
            self.rejected_in_flight,
            self.panel_hits,
            self.panel_misses,
            self.panel_evictions,
            self.pool_recycled,
            self.p50_s,
            self.p99_s,
            self.gemm_p99_s,
            self.gemv_p99_s,
            self.batch_p99_s,
            self.solve_p99_s,
            self.requeued,
            self.unhealthy_chips(),
        )?;
        for (i, c) in self.chip_gemms.iter().enumerate() {
            write!(f, " chip{i}_gemms={c}")?;
        }
        for (i, h) in self.chip_health.iter().enumerate() {
            write!(f, " chip{i}_healthy={}", u8::from(*h))?;
        }
        Ok(())
    }
}

/// Thread-safe metrics sink.
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    /// A fresh sink; uptime starts now.
    pub fn new() -> Self {
        Metrics { inner: Mutex::new(Inner { started: Some(Instant::now()), ..Default::default() }) }
    }

    /// Record one completed request of `kind` with its latency and
    /// logical flop count.
    pub fn record_request(&self, kind: RequestKind, latency_s: f64, flops: f64) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        match kind {
            RequestKind::Gemm => m.gemm_requests += 1,
            RequestKind::Gemv => m.gemv_requests += 1,
            RequestKind::Batch => m.batch_requests += 1,
            RequestKind::Solve => m.solve_requests += 1,
            RequestKind::Other => {}
        }
        m.flops += flops;
        m.total_latency_s += latency_s;
        let us = (latency_s * 1e6).max(1.0);
        let bucket = (us.log2() as usize).min(BUCKETS - 1);
        m.latency_us[bucket] += 1;
        m.kind_latency_us[kind.index()][bucket] += 1;
    }

    /// Record a failed request.
    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Record a read-side I/O failure (mid-frame disconnect, oversized
    /// length prefix) — distinct from protocol errors, which get an error
    /// *response*; an I/O failure kills the connection.
    pub fn record_io_error(&self) {
        self.inner.lock().unwrap().io_errors += 1;
    }

    /// Record a request that missed its per-request deadline.
    pub fn record_deadline_exceeded(&self) {
        self.inner.lock().unwrap().deadline_exceeded += 1;
    }

    /// Record a request bounced by a full in-flight window.
    pub fn record_rejected_in_flight(&self) {
        self.inner.lock().unwrap().rejected_in_flight += 1;
    }

    /// Record that `n` jobs executed as one coalesced batch.
    pub fn record_batched(&self, n: usize) {
        self.inner.lock().unwrap().batched += n as u64;
    }

    /// Record one job moved off a wounded chip onto a healthy queue.
    pub fn record_requeued(&self) {
        self.inner.lock().unwrap().requeued += 1;
    }

    /// Record one chip-pinned execution on `chip` (the counter behind the
    /// `chipN_gemms` report labels). Counts batcher groups and hinted
    /// direct gemms — an *unhinted* f64 gemm shards across the whole pool
    /// and is visible in [`crate::host::pool::ChipPool::crossings`]
    /// rather than here.
    pub fn record_chip_request(&self, chip: usize) {
        let mut m = self.inner.lock().unwrap();
        if m.chip_gemms.len() <= chip {
            m.chip_gemms.resize(chip + 1, 0);
        }
        m.chip_gemms[chip] += 1;
    }

    /// Total requests recorded.
    pub fn requests(&self) -> u64 {
        self.inner.lock().unwrap().requests
    }

    /// Failed requests recorded.
    pub fn errors(&self) -> u64 {
        self.inner.lock().unwrap().errors
    }

    /// Health requeues recorded (jobs rescued off wounded chips).
    pub fn requeued(&self) -> u64 {
        self.inner.lock().unwrap().requeued
    }

    /// Read-side I/O failures recorded.
    pub fn io_errors(&self) -> u64 {
        self.inner.lock().unwrap().io_errors
    }

    /// Requests that missed their deadline.
    pub fn deadline_exceeded(&self) -> u64 {
        self.inner.lock().unwrap().deadline_exceeded
    }

    /// Requests bounced by a full in-flight window.
    pub fn rejected_in_flight(&self) -> u64 {
        self.inner.lock().unwrap().rejected_in_flight
    }

    /// Per-chip batch-execution counts (empty until a chip executes).
    pub fn chip_requests(&self) -> Vec<u64> {
        self.inner.lock().unwrap().chip_gemms.clone()
    }

    /// Latency below which a fraction `q` of requests fall, read from the
    /// log-scaled histogram (a bucket *upper* bound, in seconds).
    ///
    /// The edges are explicit:
    /// * no samples recorded → `0.0`, whatever `q` is;
    /// * a non-finite `q` (NaN, ±∞ — arithmetic upstream gone wrong) is
    ///   treated as `0.0`;
    /// * `q` outside `[0, 1]` is clamped, so `q <= 0` returns the
    ///   smallest occupied bucket bound and `q >= 1` the largest.
    pub fn latency_quantile(&self, q: f64) -> f64 {
        quantile_from(&self.inner.lock().unwrap().latency_us, q)
    }

    /// [`Metrics::latency_quantile`] restricted to one request kind's
    /// latency stream — a 400-item batch never lands in the single-gemm
    /// histogram, so quantiles here are shape-honest. Same edge policy.
    pub fn latency_quantile_of(&self, kind: RequestKind, q: f64) -> f64 {
        quantile_from(&self.inner.lock().unwrap().kind_latency_us[kind.index()], q)
    }

    /// A typed snapshot of every counter (the `Stats` opcode's payload).
    /// `queue_depth` is 0 here — only the router can see the batcher.
    pub fn snapshot(&self) -> StatsReport {
        let m = self.inner.lock().unwrap();
        let uptime = m.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        StatsReport {
            requests: m.requests,
            errors: m.errors,
            io_errors: m.io_errors,
            deadline_exceeded: m.deadline_exceeded,
            rejected_in_flight: m.rejected_in_flight,
            gemm_requests: m.gemm_requests,
            gemv_requests: m.gemv_requests,
            batch_requests: m.batch_requests,
            solve_requests: m.solve_requests,
            batched: m.batched,
            requeued: m.requeued,
            // Residency counters live with the cache/pools, not this sink;
            // the router overlays them (like queue_depth) before replying.
            panel_hits: 0,
            panel_misses: 0,
            panel_evictions: 0,
            pool_recycled: 0,
            uptime_s: uptime,
            mean_latency_s: if m.requests > 0 {
                m.total_latency_s / m.requests as f64
            } else {
                0.0
            },
            achieved_gflops: if uptime > 0.0 { m.flops / uptime / 1e9 } else { 0.0 },
            p50_s: quantile_from(&m.latency_us, 0.5),
            p99_s: quantile_from(&m.latency_us, 0.99),
            gemm_p99_s: quantile_from(&m.kind_latency_us[RequestKind::Gemm.index()], 0.99),
            gemv_p99_s: quantile_from(&m.kind_latency_us[RequestKind::Gemv.index()], 0.99),
            batch_p99_s: quantile_from(&m.kind_latency_us[RequestKind::Batch.index()], 0.99),
            solve_p99_s: quantile_from(&m.kind_latency_us[RequestKind::Solve.index()], 0.99),
            queue_depth: 0,
            chip_gemms: m.chip_gemms.clone(),
            // Chip health lives with the pool, not this sink; the router
            // overlays it (like queue_depth) before replying.
            chip_health: Vec::new(),
        }
    }

    /// Human-readable report line, with one `chipN_gemms` label per chip
    /// that has executed work (the rendering of [`Metrics::snapshot`]).
    pub fn report(&self) -> String {
        self.snapshot().to_string()
    }
}

/// The quantile read shared by [`Metrics::latency_quantile`] and
/// [`Metrics::snapshot`]; see `latency_quantile` for the edge policy.
fn quantile_from(hist: &[u64; BUCKETS], q: f64) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let q = if q.is_finite() { q.clamp(0.0, 1.0) } else { 0.0 };
    let target = ((q * total as f64).ceil() as u64).max(1);
    let mut seen = 0u64;
    for (i, &c) in hist.iter().enumerate() {
        seen += c;
        if seen >= target {
            return (1u64 << i) as f64 / 1e6; // bucket upper bound in s
        }
    }
    (1u64 << (BUCKETS - 1)) as f64 / 1e6
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Routing category of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    /// Level-3 gemm (the Epiphany-accelerated class).
    Gemm,
    /// Level-2 gemv (host compute).
    Gemv,
    /// Batched small-gemm fan-out (one request, many items).
    Batch,
    /// Mixed-precision iterative-refinement solve.
    Solve,
    /// Anything else (control ops).
    Other,
}

impl RequestKind {
    /// Index of this kind's latency histogram in the per-kind array.
    fn index(self) -> usize {
        match self {
            RequestKind::Gemm => 0,
            RequestKind::Gemv => 1,
            RequestKind::Batch => 2,
            RequestKind::Solve => 3,
            RequestKind::Other => 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(RequestKind::Gemm, 0.001, 1e6);
        m.record_request(RequestKind::Gemv, 0.002, 1e3);
        m.record_error();
        assert_eq!(m.requests(), 2);
        let rep = m.report();
        assert!(rep.contains("requests=2"));
        assert!(rep.contains("errors=1"));
        assert!(rep.contains("gemm=1"));
    }

    #[test]
    fn quantiles_monotone() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_request(RequestKind::Gemm, i as f64 * 1e-4, 0.0);
        }
        let p50 = m.latency_quantile(0.5);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 0.0);
    }

    #[test]
    fn empty_quantile_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile(0.9), 0.0);
        // Out-of-range and non-finite q are still 0 on no samples.
        assert_eq!(m.latency_quantile(-3.0), 0.0);
        assert_eq!(m.latency_quantile(f64::NAN), 0.0);
    }

    #[test]
    fn quantile_q_edges_clamped() {
        let m = Metrics::new();
        m.record_request(RequestKind::Gemm, 1e-5, 0.0);
        m.record_request(RequestKind::Gemm, 1e-1, 0.0);
        let lo = m.latency_quantile(0.0);
        let hi = m.latency_quantile(1.0);
        assert!(lo > 0.0 && lo <= hi);
        // q below 0 / above 1 clamp to the same edges.
        assert_eq!(m.latency_quantile(-1.0), lo);
        assert_eq!(m.latency_quantile(7.5), hi);
        // Non-finite q reads as 0.
        assert_eq!(m.latency_quantile(f64::NAN), lo);
        assert_eq!(m.latency_quantile(f64::INFINITY), lo);
    }

    #[test]
    fn snapshot_mirrors_report_line() {
        let m = Metrics::new();
        m.record_request(RequestKind::Gemm, 0.001, 1e6);
        m.record_error();
        m.record_io_error();
        m.record_deadline_exceeded();
        m.record_rejected_in_flight();
        m.record_chip_request(0);
        m.record_requeued();
        let snap = m.snapshot();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.io_errors, 1);
        assert_eq!(snap.deadline_exceeded, 1);
        assert_eq!(snap.rejected_in_flight, 1);
        assert_eq!(snap.requeued, 1);
        assert_eq!(snap.gemms_on(0), 1);
        assert_eq!(snap.gemms_on(7), 0, "unseen chips read as 0");
        assert!(snap.healthy_on(0), "unsampled health reads healthy");
        assert_eq!(snap.unhealthy_chips(), 0);
        assert!(snap.p50_s > 0.0 && snap.p50_s <= snap.p99_s);
        // The rendered line keeps every legacy label plus the new ones.
        let line = snap.to_string();
        for label in [
            "requests=1",
            "errors=1",
            "gemm=1",
            "io_errors=1",
            "deadline_exceeded=1",
            "rejected_in_flight=1",
            "panel_hits=0",
            "panel_misses=0",
            "panel_evictions=0",
            "pool_recycled=0",
            "queue_depth=0",
            "p50_s=",
            "p99_s=",
            "gemm_p99_s=",
            "gemv_p99_s=",
            "batch_p99_s=",
            "solve_p99_s=",
            "batch=0",
            "solve=0",
            "requeued=1",
            "unhealthy_chips=0",
            "chip0_gemms=1",
        ] {
            assert!(line.contains(label), "missing {label}: {line}");
        }
    }

    #[test]
    fn per_kind_quantiles_isolated() {
        let m = Metrics::new();
        // Fast single gemms and one slow 400-item batch: the combined p99
        // is dragged up by the batch, the gemm stream's is not.
        for _ in 0..99 {
            m.record_request(RequestKind::Gemm, 1e-5, 1e3);
        }
        m.record_request(RequestKind::Batch, 2.0, 4e8);
        m.record_request(RequestKind::Solve, 0.5, 1e6);
        let gemm_p99 = m.latency_quantile_of(RequestKind::Gemm, 0.99);
        let batch_p99 = m.latency_quantile_of(RequestKind::Batch, 0.99);
        assert!(gemm_p99 < 1e-3, "batch latency leaked into gemm stream: {gemm_p99}");
        // The histogram reports power-of-two bucket bounds, so a 2 s
        // sample reads back as the 2^20 µs bucket (~1.05 s).
        assert!(batch_p99 >= 1.0, "batch stream lost its own sample: {batch_p99}");
        let snap = m.snapshot();
        assert_eq!(snap.batch_requests, 1);
        assert_eq!(snap.solve_requests, 1);
        assert_eq!(snap.gemm_p99_s, gemm_p99);
        assert_eq!(snap.batch_p99_s, batch_p99);
        assert!(snap.solve_p99_s >= 0.25);
        assert!(
            snap.p99_s >= snap.gemm_p99_s,
            "combined p99 should see the slow tail the gemm stream hides"
        );
        // A kind that never ran reads 0, same as the combined empty edge.
        assert_eq!(m.latency_quantile_of(RequestKind::Other, 0.99), 0.0);
    }

    #[test]
    fn chip_health_renders_and_counts() {
        let snap = StatsReport {
            chip_health: vec![true, false, true, false],
            ..StatsReport::default()
        };
        assert_eq!(snap.unhealthy_chips(), 2);
        assert!(!snap.healthy_on(1));
        assert!(snap.healthy_on(2));
        let line = snap.to_string();
        assert!(line.contains("unhealthy_chips=2"), "{line}");
        assert!(line.contains("chip1_healthy=0"), "{line}");
        assert!(line.contains("chip2_healthy=1"), "{line}");
    }

    #[test]
    fn per_chip_labels_in_report() {
        let m = Metrics::new();
        m.record_chip_request(1);
        m.record_chip_request(1);
        m.record_chip_request(0);
        assert_eq!(m.chip_requests(), vec![1, 2]);
        let rep = m.report();
        assert!(rep.contains("chip0_gemms=1"), "{rep}");
        assert!(rep.contains("chip1_gemms=2"), "{rep}");
    }
}
