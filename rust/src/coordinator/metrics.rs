//! Service metrics: request counters, latency histogram, throughput.

use std::sync::Mutex;
use std::time::Instant;

/// Log-scaled latency histogram (µs buckets: 1, 2, 4, ... ~17 min).
const BUCKETS: usize = 30;

#[derive(Default)]
struct Inner {
    requests: u64,
    errors: u64,
    gemm_requests: u64,
    gemv_requests: u64,
    batched: u64,
    flops: f64,
    latency_us: [u64; BUCKETS],
    total_latency_s: f64,
    started: Option<Instant>,
}

/// Thread-safe metrics sink.
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { inner: Mutex::new(Inner { started: Some(Instant::now()), ..Default::default() }) }
    }

    pub fn record_request(&self, kind: RequestKind, latency_s: f64, flops: f64) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        match kind {
            RequestKind::Gemm => m.gemm_requests += 1,
            RequestKind::Gemv => m.gemv_requests += 1,
            RequestKind::Other => {}
        }
        m.flops += flops;
        m.total_latency_s += latency_s;
        let us = (latency_s * 1e6).max(1.0);
        let bucket = (us.log2() as usize).min(BUCKETS - 1);
        m.latency_us[bucket] += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn record_batched(&self, n: usize) {
        self.inner.lock().unwrap().batched += n as u64;
    }

    pub fn requests(&self) -> u64 {
        self.inner.lock().unwrap().requests
    }

    /// Latency below which `q` of requests fall (from the histogram).
    pub fn latency_quantile(&self, q: f64) -> f64 {
        let m = self.inner.lock().unwrap();
        let total: u64 = m.latency_us.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in m.latency_us.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << i) as f64 / 1e6; // bucket upper bound in s
            }
        }
        (1u64 << (BUCKETS - 1)) as f64 / 1e6
    }

    /// Human-readable report (the `Stats` opcode's payload).
    pub fn report(&self) -> String {
        let m = self.inner.lock().unwrap();
        let uptime = m.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        let mean_lat = if m.requests > 0 { m.total_latency_s / m.requests as f64 } else { 0.0 };
        format!(
            "requests={} errors={} gemm={} gemv={} batched={} uptime_s={:.1} \
             mean_latency_s={:.6} achieved_gflops={:.3}",
            m.requests,
            m.errors,
            m.gemm_requests,
            m.gemv_requests,
            m.batched,
            uptime,
            mean_lat,
            if uptime > 0.0 { m.flops / uptime / 1e9 } else { 0.0 },
        )
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Routing category of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    Gemm,
    Gemv,
    Other,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(RequestKind::Gemm, 0.001, 1e6);
        m.record_request(RequestKind::Gemv, 0.002, 1e3);
        m.record_error();
        assert_eq!(m.requests(), 2);
        let rep = m.report();
        assert!(rep.contains("requests=2"));
        assert!(rep.contains("errors=1"));
        assert!(rep.contains("gemm=1"));
    }

    #[test]
    fn quantiles_monotone() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_request(RequestKind::Gemm, i as f64 * 1e-4, 0.0);
        }
        let p50 = m.latency_quantile(0.5);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 0.0);
    }

    #[test]
    fn empty_quantile_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile(0.9), 0.0);
    }
}
