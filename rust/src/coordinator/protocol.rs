//! Wire protocol: length-prefixed binary frames over TCP, with **one**
//! frame header and **one** payload codec shared by every opcode × dtype.
//!
//! Frame layout (little-endian), identical for requests and responses:
//!
//! ```text
//! [u32 len][u8 tag][u8 dtype][u8 flags][payload]
//! ```
//!
//! where `len` counts tag + dtype + flags + payload. For requests `tag`
//! is the [`Opcode`]; for responses it is the status. `dtype` tags the
//! element type of every scalar and tensor in the payload ([`Dtype`]),
//! so an op is defined once and instantiated per precision by the codec —
//! adding a routed op adds one opcode, one descriptor struct and one
//! codec routine, not a variant per dtype across protocol/router/server.
//!
//! `flags` carries the **shard hint** on `Gemm` request frames: the low
//! nibble is `0` for "no affinity" (the server picks the least-loaded
//! chip) or `1 + chip` to pin the job to `chip`'s queue (so a remote
//! client can keep a weight matrix hot on one chip's batcher; the server
//! reduces the index modulo its pool size). The high nibble is reserved
//! and must be 0, as must the whole byte on every other frame kind —
//! pre-shard clients, which always sent 0, remain wire-compatible.
//!
//! Gemm payload: `[u8 ta][u8 tb][u32 m][u32 n][u32 k][scalar alpha]
//! [scalar beta][A][B][C]` — matrices col-major in their *stored*
//! orientation (op applied server-side, like a BLAS call), scalars and
//! elements at the dtype's width.
//!
//! Gemv payload: `[u8 ta][u32 m][u32 n][u32 incx][u32 incy]
//! [scalar alpha][scalar beta][A][x][y]` with classic BLAS vector
//! strides; stored vector length is `(len-1)*inc + 1`.
//!
//! GemmBatch payload: `[u32 count]` followed by `count` gemm payloads
//! back to back (each exactly the Gemm layout above, all at the frame
//! dtype). The shard-hint nibble applies to the **whole batch** (the
//! server fans unhinted items across least-loaded healthy chips);
//! per-item hints do not travel. The response is one `Ok` tensor: the
//! updated C buffers concatenated in item order.
//!
//! Solve payload (mixed-precision iterative refinement, see
//! [`crate::workloads::refine`]): `[u8 kind][u32 n][u32 nb]
//! [u32 max_iters][scalar tol][A n·n][b n]` with `kind` 0 = LU,
//! 1 = Cholesky. The server factorizes in the f32-class compute path,
//! refines against a true-f64 residual, and answers the solution vector
//! as an `Ok` tensor (or a typed refinement error as `Err`). Solve
//! frames must travel at dtype f64.
//!
//! # Wire v2: correlation ids and pipelining
//!
//! A client that opens with a `Hello{version}` exchange (in v1 framing)
//! upgrades the connection to **v2**, which inserts a correlation id
//! after the flags byte on every subsequent frame, both directions:
//!
//! ```text
//! [u32 len][u8 tag][u8 dtype][u8 flags][u32 correlation_id][payload]
//! ```
//!
//! Requests on a v2 connection may additionally set [`FLAG_DEADLINE`]
//! (bit 4 of `flags`), in which case a `u32 deadline_ms` budget follows
//! the correlation id. v2 responses may arrive **out of order**; the
//! correlation id is how a pipelined client matches them back up
//! ([`Request::encode_v2`] / [`Response::decode_v2`]). Clients that
//! never say hello keep the v1 framing above, bit for bit.
//!
//! Incremental framing for the server's read loop lives in
//! [`FrameAccumulator`]: bytes go in as they arrive, complete frame
//! bodies come out, and a hostile length prefix is rejected before any
//! allocation happens.

use super::metrics::StatsReport;
use crate::blis::{Dtype, Trans};
use crate::mem::{BufferPool, PoolVec};
use crate::workloads::refine::Factorization;
use anyhow::{bail, ensure, Result};
use std::io::{Read, Write};
use std::sync::Arc;

/// Wire protocol version 1: `[len][tag][dtype][flags][payload]` frames,
/// strictly request → response per connection.
pub const PROTOCOL_V1: u32 = 1;

/// Wire protocol version 2: v1 plus a correlation id on every frame,
/// optional per-request deadlines, and out-of-order responses.
pub const PROTOCOL_V2: u32 = 2;

/// `flags` bit 4 on a v2 request: a `u32 deadline_ms` follows the
/// correlation id. Rejected on v1 frames (the bit is reserved there).
pub const FLAG_DEADLINE: u8 = 0x10;

/// Hard ceiling on a frame's length prefix, both directions — a hostile
/// 4 GiB prefix must die before the body is allocated. Servers default
/// to the tighter [`DEFAULT_MAX_FRAME_LEN`].
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// The server's default accepted frame cap (256 MiB — a paper-scale
/// sgemm frame is a few MiB).
pub const DEFAULT_MAX_FRAME_LEN: usize = 1 << 28;

/// Operation codes (request tags). 1–15 are routed compute ops, 16+ are
/// control ops with empty payloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Opcode {
    /// Level-3 gemm (Epiphany-routed; may carry a shard hint in `flags`).
    Gemm = 1,
    /// Level-2 gemv (host-routed).
    Gemv = 2,
    /// A batch of small gemms executed as one request, fanned across the
    /// chip pool (Epiphany-routed; the shard hint pins the whole batch).
    GemmBatch = 3,
    /// Mixed-precision iterative-refinement solve (f32-class factorize,
    /// f64 residual; Epiphany-routed via the false-dgemm updates).
    Solve = 4,
    /// Liveness check; empty payload.
    Ping = 16,
    /// Ask for the metrics report; empty payload.
    Stats = 17,
    /// Stop the server; empty payload.
    Shutdown = 18,
    /// Version negotiation (`[u32 version]` payload). Sent as the first
    /// frame of a connection, in v1 framing; the server's text reply
    /// names the agreed version and the connection upgrades from there.
    Hello = 19,
    /// Subscribe this (v2) connection to the telemetry stream: the
    /// server acks, then pushes a periodic self-describing JSON frame
    /// (an `OkText` response under the reserved cid) with the live
    /// [`StatsReport`] — per-chip health, queue depth, in-flight,
    /// latency quantiles, panel-cache hits. Empty payload.
    Subscribe = 20,
}

impl Opcode {
    /// Decode a request tag; unknown tags are recoverable errors.
    pub fn from_u8(v: u8) -> Result<Opcode> {
        Ok(match v {
            1 => Opcode::Gemm,
            2 => Opcode::Gemv,
            3 => Opcode::GemmBatch,
            4 => Opcode::Solve,
            16 => Opcode::Ping,
            17 => Opcode::Stats,
            18 => Opcode::Shutdown,
            19 => Opcode::Hello,
            20 => Opcode::Subscribe,
            _ => bail!("unknown opcode {v}"),
        })
    }

    /// Every opcode (the property suite's round-trip sweep).
    pub fn all() -> [Opcode; 9] {
        [
            Opcode::Gemm,
            Opcode::Gemv,
            Opcode::GemmBatch,
            Opcode::Solve,
            Opcode::Ping,
            Opcode::Stats,
            Opcode::Shutdown,
            Opcode::Hello,
            Opcode::Subscribe,
        ]
    }
}

/// A dtype-tagged element buffer — the payload unit of the protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    /// Single-precision elements.
    F32(Vec<f32>),
    /// Double-precision elements.
    F64(Vec<f64>),
}

impl Tensor {
    /// The dtype tag of the carried elements.
    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::F32(_) => Dtype::F32,
            Tensor::F64(_) => Dtype::F64,
        }
    }

    /// Logical element count (not bytes).
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::F64(v) => v.len(),
        }
    }

    /// Whether the buffer holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 elements; errs on a dtype mismatch.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            Tensor::F64(_) => bail!("tensor is f64, expected f32"),
        }
    }

    /// Borrow as f64 elements; errs on a dtype mismatch.
    pub fn as_f64(&self) -> Result<&[f64]> {
        match self {
            Tensor::F64(v) => Ok(v),
            Tensor::F32(_) => bail!("tensor is f32, expected f64"),
        }
    }

    /// Take the f32 elements; errs on a dtype mismatch.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32(v) => Ok(v),
            Tensor::F64(_) => bail!("tensor is f64, expected f32"),
        }
    }

    /// Take the f64 elements; errs on a dtype mismatch.
    pub fn into_f64(self) -> Result<Vec<f64>> {
        match self {
            Tensor::F64(v) => Ok(v),
            Tensor::F32(_) => bail!("tensor is f32, expected f64"),
        }
    }
}

/// Dtype-tagged gemm descriptor: `C ← α·op(A)·op(B) + β·C`.
///
/// `alpha`/`beta` are carried as `f64` in memory but travel at the
/// dtype's width on the wire (`f32 → f64` widening is exact, so f32
/// scalars round-trip bit-identically).
#[derive(Clone, Debug)]
pub struct GemmWire {
    /// Transpose flag for A.
    pub ta: Trans,
    /// Transpose flag for B.
    pub tb: Trans,
    /// Rows of C.
    pub m: usize,
    /// Columns of C.
    pub n: usize,
    /// Inner (contraction) dimension.
    pub k: usize,
    /// Scale on the product (travels at the dtype's width).
    pub alpha: f64,
    /// Scale on the C input (travels at the dtype's width).
    pub beta: f64,
    /// Stored A operand.
    pub a: Tensor,
    /// Stored B operand.
    pub b: Tensor,
    /// C input.
    pub c: Tensor,
    /// Chip-affinity hint, carried in the frame's `flags` nibble:
    /// `None` lets the server pick the least-loaded chip; `Some(chip)`
    /// pins the job to `chip`'s batcher queue (reduced modulo the pool
    /// size server-side). At most 15 distinct pins fit the nibble, so
    /// hints above 14 encode as 14.
    pub shard_hint: Option<usize>,
}

impl GemmWire {
    /// The element dtype of the descriptor's tensors.
    pub fn dtype(&self) -> Dtype {
        self.a.dtype()
    }

    /// The `flags` byte this descriptor encodes to.
    fn flags(&self) -> u8 {
        shard_hint_flags(self.shard_hint)
    }

    /// An f32 gemm item (buffers trimmed to the exact stored sizes) —
    /// the unit clients push into [`Request::gemm_batch`].
    #[allow(clippy::too_many_arguments)]
    pub fn f32(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        beta: f32,
        mut a: Vec<f32>,
        mut b: Vec<f32>,
        mut c: Vec<f32>,
    ) -> GemmWire {
        trim_gemm(ta, tb, m, n, k, &mut a, &mut b, &mut c);
        GemmWire {
            ta,
            tb,
            m,
            n,
            k,
            alpha: alpha as f64,
            beta: beta as f64,
            a: Tensor::F32(a),
            b: Tensor::F32(b),
            c: Tensor::F32(c),
            shard_hint: None,
        }
    }

    /// An f64 gemm item (false-dgemm server-side), trimmed like
    /// [`GemmWire::f32`].
    #[allow(clippy::too_many_arguments)]
    pub fn f64(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        beta: f64,
        mut a: Vec<f64>,
        mut b: Vec<f64>,
        mut c: Vec<f64>,
    ) -> GemmWire {
        trim_gemm(ta, tb, m, n, k, &mut a, &mut b, &mut c);
        GemmWire {
            ta,
            tb,
            m,
            n,
            k,
            alpha,
            beta,
            a: Tensor::F64(a),
            b: Tensor::F64(b),
            c: Tensor::F64(c),
            shard_hint: None,
        }
    }
}

/// The `flags` nibble encoding of a chip-affinity hint.
fn shard_hint_flags(hint: Option<usize>) -> u8 {
    match hint {
        None => 0,
        Some(chip) => chip.min(14) as u8 + 1,
    }
}

/// A batch of small gemms traveling as one frame: hundreds of tiny
/// matmuls per request is the traffic shape the Epiphany architecture
/// wins on (resident operands, no per-request round trip). Every item
/// must share one dtype; the response is one `Ok` tensor holding the
/// updated C buffers concatenated in item order.
#[derive(Clone, Debug)]
pub struct GemmBatchWire {
    /// The gemm items, executed independently and answered in order.
    /// Per-item `shard_hint`s do **not** travel — the batch-level hint
    /// below pins the whole batch; unhinted batches fan least-loaded.
    pub items: Vec<GemmWire>,
    /// Chip-affinity hint for the whole batch, carried in the frame's
    /// `flags` nibble exactly like a single gemm's hint.
    pub shard_hint: Option<usize>,
}

impl GemmBatchWire {
    /// The element dtype shared by every item (empty batches are
    /// rejected by the codec; an empty in-memory value reads as f32).
    pub fn dtype(&self) -> Dtype {
        self.items.first().map_or(Dtype::F32, |g| g.dtype())
    }

    /// The `flags` byte this descriptor encodes to.
    fn flags(&self) -> u8 {
        shard_hint_flags(self.shard_hint)
    }

    /// Total logical C elements across the batch — the length of the
    /// concatenated response tensor.
    pub fn out_len(&self) -> usize {
        self.items.iter().map(|g| g.m * g.n).sum()
    }
}

/// Mixed-precision iterative-refinement solve descriptor: factorize
/// `A` once in the f32-class compute path (LU or Cholesky, trailing
/// updates via false dgemm), then refine `A·x = b` against a true-f64
/// residual until the HPL-scaled residual meets `tolerance`. See
/// [`crate::workloads::refine`] for the loop and its typed errors.
#[derive(Clone, Debug)]
pub struct SolveWire {
    /// Which factorization to use (0 = LU on the wire, 1 = Cholesky —
    /// the latter requires symmetric positive-definite input).
    pub factorization: Factorization,
    /// Matrix order (A is n×n col-major, b has n entries).
    pub n: usize,
    /// Blocked-factorization panel width (0 picks the server default).
    pub nb: usize,
    /// Refinement iteration cap (0 picks the server default).
    pub max_iters: usize,
    /// Convergence target on the HPL-scaled residual (≤ 0 picks the
    /// server default, the HPL pass criterion of 16).
    pub tolerance: f64,
    /// The coefficient matrix, col-major n×n.
    pub a: Tensor,
    /// The right-hand side, n entries.
    pub b: Tensor,
}

impl SolveWire {
    /// The element dtype of the descriptor's tensors (the router only
    /// accepts f64 — the refinement contract is a double-precision
    /// answer).
    pub fn dtype(&self) -> Dtype {
        self.a.dtype()
    }
}

/// Dtype-tagged gemv descriptor: `y ← α·op(A)·x + β·y` with strides.
///
/// For wire transport the stored vectors must have **exactly** the codec
/// lengths (`m·n` for A, `strided_len` for x/y) — the [`Request::sgemv`]
/// and [`Request::dgemv`] constructors trim slack automatically. The
/// in-process router accepts `>=` lengths.
#[derive(Clone, Debug)]
pub struct GemvWire {
    /// Transpose flag for A.
    pub ta: Trans,
    /// Rows of the stored A.
    pub m: usize,
    /// Columns of the stored A.
    pub n: usize,
    /// Stride of `x` (classic BLAS `INCX`, >= 1).
    pub incx: usize,
    /// Stride of `y` (classic BLAS `INCY`, >= 1).
    pub incy: usize,
    /// Scale on the product (travels at the dtype's width).
    pub alpha: f64,
    /// Scale on the y input (travels at the dtype's width).
    pub beta: f64,
    /// Stored A operand (col-major m×n).
    pub a: Tensor,
    /// Stored x vector (`strided_len` elements).
    pub x: Tensor,
    /// Stored y input (`strided_len` elements).
    pub y: Tensor,
}

impl GemvWire {
    /// The element dtype of the descriptor's tensors.
    pub fn dtype(&self) -> Dtype {
        self.a.dtype()
    }

    /// Logical (x, y) lengths implied by op(A)'s shape.
    pub fn xy_logical_len(&self) -> (usize, usize) {
        if self.ta.is_trans() {
            (self.m, self.n)
        } else {
            (self.n, self.m)
        }
    }
}

/// A decoded request: dtype-tagged descriptors plus control ops.
#[derive(Clone, Debug)]
pub enum Request {
    /// Level-3 gemm (Epiphany-routed).
    Gemm(GemmWire),
    /// Level-2 gemv (host-routed).
    Gemv(GemvWire),
    /// A batch of small gemms fanned across the chip pool.
    GemmBatch(GemmBatchWire),
    /// Mixed-precision iterative-refinement solve.
    Solve(SolveWire),
    /// Liveness check.
    Ping,
    /// Ask for the metrics report.
    Stats,
    /// Stop the server.
    Shutdown,
    /// Version negotiation; must be the first frame on a connection.
    Hello {
        /// The highest wire version the client speaks.
        version: u32,
    },
    /// Subscribe this v2 connection to periodic JSON telemetry pushes.
    Subscribe,
}

/// A response frame: a dtype-tagged tensor, text, typed stats, or an
/// error.
#[derive(Clone, Debug)]
pub enum Response {
    /// Success with a tensor payload (the updated C or y).
    Ok(Tensor),
    /// Success with a text payload (pong, hello ack, bye).
    OkText(String),
    /// Success with the typed stats snapshot (`Stats` requests).
    Stats(StatsReport),
    /// A recoverable server-side error, as text.
    Err(String),
}

fn trans_code(t: Trans) -> u8 {
    match t {
        Trans::N => 0,
        Trans::T => 1,
        Trans::C => 2,
        Trans::H => 3,
    }
}

fn trans_from(v: u8) -> Result<Trans> {
    Ok(match v {
        0 => Trans::N,
        1 => Trans::T,
        2 => Trans::C,
        3 => Trans::H,
        _ => bail!("bad trans code {v}"),
    })
}

pub use crate::blis::op::strided_len;

// ---------------------------------------------------------------------------
// The single payload codec
// ---------------------------------------------------------------------------

/// Builds one frame: header bytes first, then dtype-width payload items.
struct FrameWriter {
    buf: Vec<u8>,
    dtype: Dtype,
}

impl FrameWriter {
    fn new(tag: u8, dtype: Dtype) -> Self {
        FrameWriter::with_flags(tag, dtype, 0)
    }

    fn with_flags(tag: u8, dtype: Dtype, flags: u8) -> Self {
        FrameWriter { buf: vec![tag, dtype.code(), flags], dtype }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// A scalar at the frame dtype's width.
    fn scalar(&mut self, v: f64) {
        match self.dtype {
            Dtype::F32 => self.buf.extend_from_slice(&(v as f32).to_le_bytes()),
            Dtype::F64 => self.buf.extend_from_slice(&v.to_le_bytes()),
        }
    }

    /// An element buffer; must match the frame dtype (descriptor
    /// constructors guarantee this).
    fn tensor(&mut self, t: &Tensor) {
        debug_assert_eq!(t.dtype(), self.dtype, "tensor dtype != frame dtype");
        match t {
            Tensor::F32(v) => {
                for x in v {
                    self.buf.extend_from_slice(&x.to_le_bytes());
                }
            }
            Tensor::F64(v) => {
                for x in v {
                    self.buf.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// Length-prefix and return the finished frame.
    fn finish(self) -> Vec<u8> {
        let mut frame = Vec::with_capacity(4 + self.buf.len());
        frame.extend_from_slice(&(self.buf.len() as u32).to_le_bytes());
        frame.extend_from_slice(&self.buf);
        frame
    }
}

/// Parses one frame body (after the length prefix has been stripped).
struct FrameReader<'a> {
    buf: &'a [u8],
    pos: usize,
    dtype: Dtype,
}

impl<'a> FrameReader<'a> {
    /// Parse the 3-byte header; returns `(tag, flags, reader)`. Flag
    /// *policy* (which bits an opcode may carry) is the caller's job.
    fn new(body: &'a [u8]) -> Result<(u8, u8, FrameReader<'a>)> {
        ensure!(body.len() >= 3, "frame shorter than its header");
        let tag = body[0];
        let dtype = Dtype::from_u8(body[1])?;
        Ok((tag, body[2], FrameReader { buf: body, pos: 3, dtype }))
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n);
        let end = match end {
            Some(e) if e <= self.buf.len() => e,
            _ => bail!("truncated frame (want {n} more bytes)"),
        };
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A scalar at the frame dtype's width, widened to f64 (exact).
    fn scalar(&mut self) -> Result<f64> {
        Ok(match self.dtype {
            Dtype::F32 => f32::from_le_bytes(self.take(4)?.try_into().unwrap()) as f64,
            Dtype::F64 => f64::from_le_bytes(self.take(8)?.try_into().unwrap()),
        })
    }

    /// An element buffer of `n` logical elements at the frame dtype.
    fn tensor(&mut self, n: usize) -> Result<Tensor> {
        let nbytes = match n.checked_mul(self.dtype.size_of()) {
            Some(b) => b,
            None => bail!("tensor of {n} elements overflows the frame"),
        };
        Ok(match self.dtype {
            Dtype::F32 => {
                let raw = self.take(nbytes)?;
                let els = raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap()));
                Tensor::F32(els.collect())
            }
            Dtype::F64 => {
                let raw = self.take(nbytes)?;
                let els = raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap()));
                Tensor::F64(els.collect())
            }
        })
    }

    /// Every remaining byte as elements of the frame dtype.
    fn rest_tensor(&mut self) -> Result<Tensor> {
        let rest = self.buf.len() - self.pos;
        let width = self.dtype.size_of();
        ensure!(rest % width == 0, "payload length {rest} not a multiple of element width {width}");
        self.tensor(rest / width)
    }

    fn rest_bytes(&mut self) -> &'a [u8] {
        let out = &self.buf[self.pos..];
        self.pos = self.buf.len();
        out
    }

    fn finish(&self) -> Result<()> {
        let trailing = self.buf.len() - self.pos;
        ensure!(trailing == 0, "{trailing} trailing bytes in frame");
        Ok(())
    }
}

impl Request {
    fn opcode(&self) -> Opcode {
        match self {
            Request::Gemm(_) => Opcode::Gemm,
            Request::Gemv(_) => Opcode::Gemv,
            Request::GemmBatch(_) => Opcode::GemmBatch,
            Request::Solve(_) => Opcode::Solve,
            Request::Ping => Opcode::Ping,
            Request::Stats => Opcode::Stats,
            Request::Shutdown => Opcode::Shutdown,
            Request::Hello { .. } => Opcode::Hello,
            Request::Subscribe => Opcode::Subscribe,
        }
    }

    /// The frame dtype (control ops carry the default tag; their payloads
    /// are empty).
    pub fn dtype(&self) -> Dtype {
        match self {
            Request::Gemm(g) => g.dtype(),
            Request::Gemv(g) => g.dtype(),
            Request::GemmBatch(b) => b.dtype(),
            Request::Solve(s) => s.dtype(),
            _ => Dtype::F32,
        }
    }

    /// Encode into a v1 frame (including the length prefix). One code
    /// path for every opcode × dtype; gemm frames carry the shard hint in
    /// the `flags` byte.
    pub fn encode(&self) -> Vec<u8> {
        self.encode_with(None, None)
    }

    /// Encode into a v2 frame: the correlation id follows the flags byte,
    /// and a deadline budget (in ms) rides behind it when given (setting
    /// [`FLAG_DEADLINE`]). Only valid on a hello-upgraded connection.
    pub fn encode_v2(&self, correlation_id: u32, deadline_ms: Option<u32>) -> Vec<u8> {
        self.encode_with(Some(correlation_id), deadline_ms)
    }

    fn encode_with(&self, cid: Option<u32>, deadline_ms: Option<u32>) -> Vec<u8> {
        let mut flags = match self {
            Request::Gemm(g) => g.flags(),
            Request::GemmBatch(b) => b.flags(),
            _ => 0,
        };
        if cid.is_some() && deadline_ms.is_some() {
            flags |= FLAG_DEADLINE;
        }
        let mut w = FrameWriter::with_flags(self.opcode() as u8, self.dtype(), flags);
        if let Some(c) = cid {
            w.u32(c);
            if let Some(d) = deadline_ms {
                w.u32(d);
            }
        }
        match self {
            Request::Ping | Request::Stats | Request::Shutdown | Request::Subscribe => {}
            Request::Hello { version } => w.u32(*version),
            Request::Gemm(g) => write_gemm_payload(&mut w, g),
            Request::GemmBatch(b) => {
                w.u32(b.items.len() as u32);
                for g in &b.items {
                    write_gemm_payload(&mut w, g);
                }
            }
            Request::Solve(s) => {
                w.u8(factorization_code(s.factorization));
                w.u32(s.n as u32);
                w.u32(s.nb as u32);
                w.u32(s.max_iters as u32);
                w.scalar(s.tolerance);
                w.tensor(&s.a);
                w.tensor(&s.b);
            }
            Request::Gemv(g) => {
                w.u8(trans_code(g.ta));
                w.u32(g.m as u32);
                w.u32(g.n as u32);
                w.u32(g.incx as u32);
                w.u32(g.incy as u32);
                w.scalar(g.alpha);
                w.scalar(g.beta);
                w.tensor(&g.a);
                w.tensor(&g.x);
                w.tensor(&g.y);
            }
        }
        w.finish()
    }

    /// Decode a v1 frame body (without the length prefix). The same
    /// generic routine serves every dtype; payload sizes are derived from
    /// the header dims and validated.
    pub fn decode(body: &[u8]) -> Result<Request> {
        let (_, _, req) = Request::decode_with(body, false)?;
        Ok(req)
    }

    /// Decode a v2 frame body: returns the correlation id, the optional
    /// deadline budget (ms), and the request.
    pub fn decode_v2(body: &[u8]) -> Result<(u32, Option<u32>, Request)> {
        Request::decode_with(body, true)
    }

    fn decode_with(body: &[u8], v2: bool) -> Result<(u32, Option<u32>, Request)> {
        let (tag, flags, mut r) = FrameReader::new(body)?;
        let opcode = Opcode::from_u8(tag)?;
        // Flag policy: gemm and gemm-batch own the shard-hint nibble; v2
        // frames may set FLAG_DEADLINE; everything else is reserved 0.
        let mut allowed =
            if matches!(opcode, Opcode::Gemm | Opcode::GemmBatch) { 0x0Fu8 } else { 0 };
        if v2 {
            allowed |= FLAG_DEADLINE;
        }
        ensure!(
            flags & !allowed == 0,
            "reserved flag bits must be 0 on this frame, got {flags:#04x}"
        );
        let (cid, deadline_ms) = if v2 {
            let cid = r.u32()?;
            let d = if flags & FLAG_DEADLINE != 0 { Some(r.u32()?) } else { None };
            (cid, d)
        } else {
            (0, None)
        };
        let req = match opcode {
            Opcode::Ping => Request::Ping,
            Opcode::Stats => Request::Stats,
            Opcode::Shutdown => Request::Shutdown,
            Opcode::Subscribe => Request::Subscribe,
            Opcode::Hello => Request::Hello { version: r.u32()? },
            Opcode::Gemm => {
                let mut g = read_gemm_payload(&mut r)?;
                g.shard_hint = hint_from_flags(flags);
                Request::Gemm(g)
            }
            Opcode::GemmBatch => {
                let count = r.u32()? as usize;
                ensure!(count >= 1, "gemm batch must carry at least one item");
                ensure!(count <= 65_536, "implausible batch count {count}");
                // Every item reads at the frame dtype — one batch, one
                // precision, by construction.
                let mut items = Vec::with_capacity(count.min(1024));
                for _ in 0..count {
                    items.push(read_gemm_payload(&mut r)?);
                }
                Request::GemmBatch(GemmBatchWire { items, shard_hint: hint_from_flags(flags) })
            }
            Opcode::Solve => {
                let factorization = factorization_from(r.u8()?)?;
                let n = r.u32()? as usize;
                let nb = r.u32()? as usize;
                let max_iters = r.u32()? as usize;
                let tolerance = r.scalar()?;
                let a = r.tensor(n * n)?;
                let b = r.tensor(n)?;
                Request::Solve(SolveWire { factorization, n, nb, max_iters, tolerance, a, b })
            }
            Opcode::Gemv => {
                let ta = trans_from(r.u8()?)?;
                let (m, n) = (r.u32()? as usize, r.u32()? as usize);
                let (incx, incy) = (r.u32()? as usize, r.u32()? as usize);
                ensure!(incx >= 1 && incy >= 1, "gemv strides must be >= 1");
                let alpha = r.scalar()?;
                let beta = r.scalar()?;
                let a = r.tensor(m * n)?;
                let (xl, yl) = if ta.is_trans() { (m, n) } else { (n, m) };
                let x = r.tensor(strided_len(xl, incx))?;
                let y = r.tensor(strided_len(yl, incy))?;
                Request::Gemv(GemvWire { ta, m, n, incx, incy, alpha, beta, a, x, y })
            }
        };
        r.finish()?;
        Ok((cid, deadline_ms, req))
    }

    // -- generated-style constructors (what clients actually type) --
    //
    // Constructors trim each buffer to the exact stored length the codec
    // emits and the decoder expects, so a BLAS-legal slack buffer (e.g. a
    // natural `n·incx`-sized x) still produces a decodable frame.

    /// f32 gemm request (the accelerated sgemm).
    #[allow(clippy::too_many_arguments)]
    pub fn sgemm(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        beta: f32,
        mut a: Vec<f32>,
        mut b: Vec<f32>,
        mut c: Vec<f32>,
    ) -> Request {
        trim_gemm(ta, tb, m, n, k, &mut a, &mut b, &mut c);
        Request::Gemm(GemmWire {
            ta,
            tb,
            m,
            n,
            k,
            alpha: alpha as f64,
            beta: beta as f64,
            a: Tensor::F32(a),
            b: Tensor::F32(b),
            c: Tensor::F32(c),
            shard_hint: None,
        })
    }

    /// f64 gemm request (the paper's "false dgemm" path server-side).
    #[allow(clippy::too_many_arguments)]
    pub fn dgemm(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        beta: f64,
        mut a: Vec<f64>,
        mut b: Vec<f64>,
        mut c: Vec<f64>,
    ) -> Request {
        trim_gemm(ta, tb, m, n, k, &mut a, &mut b, &mut c);
        Request::Gemm(GemmWire {
            ta,
            tb,
            m,
            n,
            k,
            alpha,
            beta,
            a: Tensor::F64(a),
            b: Tensor::F64(b),
            c: Tensor::F64(c),
            shard_hint: None,
        })
    }

    /// Pin a gemm or gemm-batch request to a chip's queue via the
    /// frame's shard-hint flag nibble (no-op on other requests). Hints
    /// above 14 encode as 14 — the nibble's ceiling — and the server
    /// reduces the index modulo its pool size either way.
    pub fn with_shard_hint(mut self, chip: usize) -> Request {
        match &mut self {
            Request::Gemm(g) => g.shard_hint = Some(chip.min(14)),
            Request::GemmBatch(b) => b.shard_hint = Some(chip.min(14)),
            _ => {}
        }
        self
    }

    /// A batched-gemm request: build items with [`GemmWire::f32`] /
    /// [`GemmWire::f64`] (all one dtype). Unhinted, the server fans the
    /// items across its least-loaded healthy chips; chain
    /// [`Request::with_shard_hint`] to pin the whole batch.
    pub fn gemm_batch(items: Vec<GemmWire>) -> Request {
        Request::GemmBatch(GemmBatchWire { items, shard_hint: None })
    }

    /// A mixed-precision iterative-refinement solve request (f64 in,
    /// f64 out; the factorization runs in the f32-class compute path).
    /// Zero `nb`/`max_iters` and a non-positive `tolerance` pick the
    /// server-side defaults.
    pub fn solve(
        factorization: Factorization,
        n: usize,
        nb: usize,
        max_iters: usize,
        tolerance: f64,
        mut a: Vec<f64>,
        mut b: Vec<f64>,
    ) -> Request {
        a.truncate(n * n);
        b.truncate(n);
        Request::Solve(SolveWire {
            factorization,
            n,
            nb,
            max_iters,
            tolerance,
            a: Tensor::F64(a),
            b: Tensor::F64(b),
        })
    }

    /// f32 gemv request with classic vector strides.
    #[allow(clippy::too_many_arguments)]
    pub fn sgemv(
        ta: Trans,
        m: usize,
        n: usize,
        alpha: f32,
        mut a: Vec<f32>,
        mut x: Vec<f32>,
        incx: usize,
        beta: f32,
        mut y: Vec<f32>,
        incy: usize,
    ) -> Request {
        trim_gemv(ta, m, n, incx, incy, &mut a, &mut x, &mut y);
        Request::Gemv(GemvWire {
            ta,
            m,
            n,
            incx,
            incy,
            alpha: alpha as f64,
            beta: beta as f64,
            a: Tensor::F32(a),
            x: Tensor::F32(x),
            y: Tensor::F32(y),
        })
    }

    /// f64 gemv request with classic vector strides.
    #[allow(clippy::too_many_arguments)]
    pub fn dgemv(
        ta: Trans,
        m: usize,
        n: usize,
        alpha: f64,
        mut a: Vec<f64>,
        mut x: Vec<f64>,
        incx: usize,
        beta: f64,
        mut y: Vec<f64>,
        incy: usize,
    ) -> Request {
        trim_gemv(ta, m, n, incx, incy, &mut a, &mut x, &mut y);
        Request::Gemv(GemvWire {
            ta,
            m,
            n,
            incx,
            incy,
            alpha,
            beta,
            a: Tensor::F64(a),
            x: Tensor::F64(x),
            y: Tensor::F64(y),
        })
    }
}

/// Write one gemm payload (shared by the Gemm frame and every
/// GemmBatch item — the "single payload codec" rule).
fn write_gemm_payload(w: &mut FrameWriter, g: &GemmWire) {
    w.u8(trans_code(g.ta));
    w.u8(trans_code(g.tb));
    w.u32(g.m as u32);
    w.u32(g.n as u32);
    w.u32(g.k as u32);
    w.scalar(g.alpha);
    w.scalar(g.beta);
    w.tensor(&g.a);
    w.tensor(&g.b);
    w.tensor(&g.c);
}

/// Read one gemm payload (shard hint left `None`; the Gemm frame arm
/// overlays the flags nibble afterwards, batch items never carry one).
fn read_gemm_payload(r: &mut FrameReader<'_>) -> Result<GemmWire> {
    let ta = trans_from(r.u8()?)?;
    let tb = trans_from(r.u8()?)?;
    let (m, n, k) = (r.u32()? as usize, r.u32()? as usize, r.u32()? as usize);
    let alpha = r.scalar()?;
    let beta = r.scalar()?;
    let (am, an) = if ta.is_trans() { (k, m) } else { (m, k) };
    let (bm, bn) = if tb.is_trans() { (n, k) } else { (k, n) };
    let a = r.tensor(am * an)?;
    let b = r.tensor(bm * bn)?;
    let c = r.tensor(m * n)?;
    Ok(GemmWire { ta, tb, m, n, k, alpha, beta, a, b, c, shard_hint: None })
}

/// Decode the flags nibble back into a chip-affinity hint.
fn hint_from_flags(flags: u8) -> Option<usize> {
    if flags & 0x0F == 0 {
        None
    } else {
        Some((flags & 0x0F) as usize - 1)
    }
}

/// The wire byte for a refinement factorization kind.
fn factorization_code(f: Factorization) -> u8 {
    match f {
        Factorization::Lu => 0,
        Factorization::Cholesky => 1,
    }
}

/// Decode a factorization kind byte.
fn factorization_from(v: u8) -> Result<Factorization> {
    Ok(match v {
        0 => Factorization::Lu,
        1 => Factorization::Cholesky,
        _ => bail!("bad factorization code {v}"),
    })
}

/// Trim gemm buffers to the exact stored sizes the codec carries.
/// Undersized buffers are left as-is: the resulting short frame is
/// rejected loudly at decode, matching the router's own validation.
fn trim_gemm<T>(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    a: &mut Vec<T>,
    b: &mut Vec<T>,
    c: &mut Vec<T>,
) {
    let (am, an) = if ta.is_trans() { (k, m) } else { (m, k) };
    let (bm, bn) = if tb.is_trans() { (n, k) } else { (k, n) };
    a.truncate(am * an);
    b.truncate(bm * bn);
    c.truncate(m * n);
}

/// Trim gemv buffers to the exact stored sizes the codec carries.
fn trim_gemv<T>(
    ta: Trans,
    m: usize,
    n: usize,
    incx: usize,
    incy: usize,
    a: &mut Vec<T>,
    x: &mut Vec<T>,
    y: &mut Vec<T>,
) {
    let (xl, yl) = if ta.is_trans() { (m, n) } else { (n, m) };
    a.truncate(m * n);
    x.truncate(strided_len(xl, incx));
    y.truncate(strided_len(yl, incy));
}

const STATUS_OK: u8 = 0;
const STATUS_TEXT: u8 = 1;
const STATUS_ERR: u8 = 2;
const STATUS_STATS: u8 = 3;

impl Response {
    /// Encode into a v1 frame with the same header shape as requests; the
    /// payload of an `Ok` tensor is raw elements (count implied by the
    /// frame length).
    pub fn encode(&self) -> Vec<u8> {
        self.encode_with(None)
    }

    /// Encode into a v2 frame tagged with the request's correlation id —
    /// what lets a pipelined client match out-of-order completions.
    pub fn encode_v2(&self, correlation_id: u32) -> Vec<u8> {
        self.encode_with(Some(correlation_id))
    }

    fn encode_with(&self, cid: Option<u32>) -> Vec<u8> {
        let (tag, dtype) = match self {
            Response::Ok(t) => (STATUS_OK, t.dtype()),
            Response::OkText(_) => (STATUS_TEXT, Dtype::F32),
            Response::Stats(_) => (STATUS_STATS, Dtype::F64),
            Response::Err(_) => (STATUS_ERR, Dtype::F32),
        };
        let mut w = FrameWriter::new(tag, dtype);
        if let Some(c) = cid {
            w.u32(c);
        }
        match self {
            Response::Ok(t) => w.tensor(t),
            Response::OkText(s) => w.bytes(s.as_bytes()),
            Response::Err(e) => w.bytes(e.as_bytes()),
            Response::Stats(s) => {
                w.u64(s.requests);
                w.u64(s.errors);
                w.u64(s.io_errors);
                w.u64(s.deadline_exceeded);
                w.u64(s.rejected_in_flight);
                w.u64(s.gemm_requests);
                w.u64(s.gemv_requests);
                w.u64(s.batched);
                w.u64(s.panel_hits);
                w.u64(s.panel_misses);
                w.u64(s.panel_evictions);
                w.u64(s.pool_recycled);
                w.scalar(s.uptime_s);
                w.scalar(s.mean_latency_s);
                w.scalar(s.achieved_gflops);
                w.scalar(s.p50_s);
                w.scalar(s.p99_s);
                w.u64(s.queue_depth);
                w.u64(s.requeued);
                w.u32(s.chip_gemms.len() as u32);
                for c in &s.chip_gemms {
                    w.u64(*c);
                }
                w.u32(s.chip_health.len() as u32);
                for h in &s.chip_health {
                    w.u8(u8::from(*h));
                }
                // Per-opcode accounting rides appended (same-version
                // clients ship together; field order is the contract).
                w.u64(s.batch_requests);
                w.u64(s.solve_requests);
                w.scalar(s.gemm_p99_s);
                w.scalar(s.gemv_p99_s);
                w.scalar(s.batch_p99_s);
                w.scalar(s.solve_p99_s);
            }
        }
        w.finish()
    }

    /// Decode a v1 response frame body (without the length prefix).
    pub fn decode(body: &[u8]) -> Result<Response> {
        let (_, resp) = Response::decode_with(body, false)?;
        Ok(resp)
    }

    /// Decode a v2 response frame body: correlation id plus response.
    pub fn decode_v2(body: &[u8]) -> Result<(u32, Response)> {
        Response::decode_with(body, true)
    }

    fn decode_with(body: &[u8], v2: bool) -> Result<(u32, Response)> {
        let (tag, flags, mut r) = FrameReader::new(body)?;
        ensure!(flags == 0, "flags byte must be 0 on a response frame, got {flags:#04x}");
        let cid = if v2 { r.u32()? } else { 0 };
        let resp = match tag {
            STATUS_OK => Response::Ok(r.rest_tensor()?),
            STATUS_TEXT => Response::OkText(String::from_utf8_lossy(r.rest_bytes()).into_owned()),
            STATUS_ERR => Response::Err(String::from_utf8_lossy(r.rest_bytes()).into_owned()),
            STATUS_STATS => {
                let mut s = StatsReport {
                    requests: r.u64()?,
                    errors: r.u64()?,
                    io_errors: r.u64()?,
                    deadline_exceeded: r.u64()?,
                    rejected_in_flight: r.u64()?,
                    gemm_requests: r.u64()?,
                    gemv_requests: r.u64()?,
                    batched: r.u64()?,
                    panel_hits: r.u64()?,
                    panel_misses: r.u64()?,
                    panel_evictions: r.u64()?,
                    pool_recycled: r.u64()?,
                    uptime_s: r.scalar()?,
                    mean_latency_s: r.scalar()?,
                    achieved_gflops: r.scalar()?,
                    p50_s: r.scalar()?,
                    p99_s: r.scalar()?,
                    queue_depth: r.u64()?,
                    requeued: r.u64()?,
                    chip_gemms: Vec::new(),
                    chip_health: Vec::new(),
                    batch_requests: 0,
                    solve_requests: 0,
                    gemm_p99_s: 0.0,
                    gemv_p99_s: 0.0,
                    batch_p99_s: 0.0,
                    solve_p99_s: 0.0,
                };
                let nchips = r.u32()? as usize;
                ensure!(nchips <= 4096, "implausible chip count {nchips} in stats frame");
                s.chip_gemms.reserve(nchips);
                for _ in 0..nchips {
                    s.chip_gemms.push(r.u64()?);
                }
                let nhealth = r.u32()? as usize;
                ensure!(nhealth <= 4096, "implausible health count {nhealth} in stats frame");
                s.chip_health.reserve(nhealth);
                for _ in 0..nhealth {
                    s.chip_health.push(r.u8()? != 0);
                }
                s.batch_requests = r.u64()?;
                s.solve_requests = r.u64()?;
                s.gemm_p99_s = r.scalar()?;
                s.gemv_p99_s = r.scalar()?;
                s.batch_p99_s = r.scalar()?;
                s.solve_p99_s = r.scalar()?;
                Response::Stats(s)
            }
            other => bail!("bad response status {other}"),
        };
        r.finish()?;
        Ok((cid, resp))
    }

    /// Unwrap an f32 tensor payload, turning server errors into `Err`.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Response::Ok(t) => t.into_f32(),
            Response::OkText(s) => bail!("expected f32 payload, got text {s:?}"),
            Response::Stats(_) => bail!("expected f32 payload, got stats"),
            Response::Err(e) => bail!("server error: {e}"),
        }
    }

    /// Unwrap an f64 tensor payload, turning server errors into `Err`.
    pub fn into_f64(self) -> Result<Vec<f64>> {
        match self {
            Response::Ok(t) => t.into_f64(),
            Response::OkText(s) => bail!("expected f64 payload, got text {s:?}"),
            Response::Stats(_) => bail!("expected f64 payload, got stats"),
            Response::Err(e) => bail!("server error: {e}"),
        }
    }
}

/// Incremental frame assembly for a streamed read loop: feed raw bytes
/// in with [`FrameAccumulator::extend`], pull complete frame bodies out
/// with [`FrameAccumulator::try_frame`] — `Ok(None)` until a full frame
/// has landed, so a dribbling client costs buffering, not a blocked
/// thread mid-`read_exact`. The length prefix is validated against the
/// cap **before** any body buffer is drawn, and each popped body is a
/// [`PoolVec`] whose allocation recycles through a [`BufferPool`] when
/// the router is done with it — a steady request stream stops paying
/// one body allocation per frame.
pub struct FrameAccumulator {
    buf: Vec<u8>,
    max_len: usize,
    pool: Arc<BufferPool<u8>>,
}

impl FrameAccumulator {
    /// An empty accumulator that rejects frames longer than `max_len`
    /// body bytes (see [`DEFAULT_MAX_FRAME_LEN`]), recycling bodies
    /// through a small private pool. Servers share one pool across
    /// connections via [`FrameAccumulator::with_pool`].
    pub fn new(max_len: usize) -> FrameAccumulator {
        FrameAccumulator::with_pool(max_len, Arc::new(BufferPool::new(8)))
    }

    /// Like [`FrameAccumulator::new`], but frame bodies are drawn from
    /// (and, once dropped, returned to) the given shared pool.
    pub fn with_pool(max_len: usize, pool: Arc<BufferPool<u8>>) -> FrameAccumulator {
        FrameAccumulator { buf: Vec::new(), max_len, pool }
    }

    /// Append bytes as they arrived off the socket.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame body, `Ok(None)` if more bytes are
    /// needed, or an error for a hostile/corrupt length prefix (shorter
    /// than a frame header, or beyond the cap).
    pub fn try_frame(&mut self) -> Result<Option<PoolVec<u8>>> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
        ensure!(len >= 3, "frame length {len} shorter than its own header");
        ensure!(len <= self.max_len, "frame length {len} exceeds the cap {}", self.max_len);
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let mut body = self.pool.get(len);
        body.copy_from_slice(&self.buf[4..4 + len]);
        self.buf.drain(..4 + len);
        Ok(Some(body))
    }

    /// Whether a partial frame (or prefix) is still buffered — an EOF
    /// with `has_partial()` is a mid-frame disconnect, not a clean close.
    pub fn has_partial(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Bytes currently buffered.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len()
    }
}

/// Read one length-prefixed frame body from a stream.
pub fn read_frame(stream: &mut impl Read) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_LEN {
        bail!("frame too large: {len}");
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok(body)
}

/// [`read_frame`], but a connection that closes cleanly *between* frames
/// (EOF before the first byte of the length prefix) reads as `Ok(None)`
/// instead of an error. EOF *inside* a frame — a mid-prefix or mid-body
/// cut — is still the I/O error it always was. This is how a telemetry
/// subscriber tells "the server stopped and drained" (exit 0) from "the
/// wire broke under us" (exit nonzero).
pub fn read_frame_or_eof(stream: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        let n = match stream.read(&mut len_buf[got..]) {
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        if n == 0 {
            if got == 0 {
                return Ok(None); // clean frame-boundary EOF
            }
            bail!("connection closed mid-frame ({got} of 4 prefix bytes)");
        }
        got += n;
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_LEN {
        bail!("frame too large: {len}");
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok(Some(body))
}

/// Write one frame (already encoded with its prefix).
pub fn write_frame(stream: &mut impl Write, frame: &[u8]) -> Result<()> {
    stream.write_all(frame)?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgemm_round_trip() {
        let req = Request::sgemm(
            Trans::T,
            Trans::N,
            2,
            3,
            4,
            1.5,
            -0.5,
            (0..8).map(|v| v as f32).collect(), // k×m stored (ta=T)
            (0..12).map(|v| v as f32).collect(), // k×n
            (0..6).map(|v| v as f32).collect(),
        );
        let frame = req.encode();
        let body = &frame[4..];
        match Request::decode(body).unwrap() {
            Request::Gemm(g) => {
                assert_eq!(g.dtype(), Dtype::F32);
                assert_eq!((g.ta, g.tb), (Trans::T, Trans::N));
                assert_eq!((g.m, g.n, g.k), (2, 3, 4));
                assert_eq!((g.alpha, g.beta), (1.5, -0.5));
                assert_eq!(g.a.len(), 8);
                assert_eq!(g.b.len(), 12);
                assert_eq!(g.c.as_f32().unwrap(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn dgemm_round_trip_same_codec() {
        let req = Request::dgemm(
            Trans::N,
            Trans::H,
            2,
            2,
            3,
            2.0,
            0.0,
            vec![1.0; 6],
            vec![2.0; 6],
            vec![0.0; 4],
        );
        let frame = req.encode();
        match Request::decode(&frame[4..]).unwrap() {
            Request::Gemm(g) => {
                assert_eq!(g.dtype(), Dtype::F64);
                assert_eq!(g.tb, Trans::H);
                assert_eq!(g.k, 3);
                assert_eq!(g.b.as_f64().unwrap(), &[2.0; 6]);
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn slack_buffers_trimmed_to_decodable_frames() {
        // A natural n·incx-sized x (4 elements) exceeds the wire's exact
        // stored length ((n−1)·incx+1 = 3); the constructor trims it so
        // the frame still decodes.
        let req = Request::sgemv(
            Trans::N,
            2,
            2,
            1.0,
            vec![1.0; 4],
            vec![1.0, 0.0, 2.0, 0.0], // slack tail element
            2,
            0.0,
            vec![0.0; 2],
            1,
        );
        let frame = req.encode();
        match Request::decode(&frame[4..]).unwrap() {
            Request::Gemv(g) => {
                assert_eq!(g.x.len(), 3);
                assert_eq!(g.x.as_f32().unwrap(), &[1.0, 0.0, 2.0]);
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn gemv_strides_round_trip() {
        // x logical 3 @ incx 2 → stored 5; y logical 2 @ incy 3 → stored 4.
        let req = Request::sgemv(
            Trans::N,
            2,
            3,
            1.0,
            vec![0.5; 6],
            vec![1.0; 5],
            2,
            0.0,
            vec![2.0; 4],
            3,
        );
        let frame = req.encode();
        match Request::decode(&frame[4..]).unwrap() {
            Request::Gemv(g) => {
                assert_eq!((g.incx, g.incy), (2, 3));
                assert_eq!((g.x.len(), g.y.len()), (5, 4));
                assert_eq!(g.xy_logical_len(), (3, 2));
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    fn sample_stats() -> StatsReport {
        StatsReport {
            requests: 7,
            errors: 1,
            io_errors: 2,
            deadline_exceeded: 3,
            rejected_in_flight: 4,
            gemm_requests: 5,
            gemv_requests: 2,
            batched: 6,
            panel_hits: 11,
            panel_misses: 4,
            panel_evictions: 1,
            pool_recycled: 8,
            uptime_s: 1.5,
            mean_latency_s: 0.001,
            achieved_gflops: 2.25,
            p50_s: 0.0005,
            p99_s: 0.004,
            queue_depth: 9,
            requeued: 2,
            chip_gemms: vec![3, 0, 2],
            chip_health: vec![true, false, true],
            batch_requests: 4,
            solve_requests: 1,
            gemm_p99_s: 0.003,
            gemv_p99_s: 0.0002,
            batch_p99_s: 0.012,
            solve_p99_s: 0.08,
        }
    }

    #[test]
    fn response_variants_round_trip() {
        for resp in [
            Response::Ok(Tensor::F32(vec![1.0, 2.0])),
            Response::Ok(Tensor::F64(vec![3.0])),
            Response::OkText("pong".into()),
            Response::Stats(sample_stats()),
            Response::Err("boom".into()),
        ] {
            let frame = resp.encode();
            let back = Response::decode(&frame[4..]).unwrap();
            match (&resp, &back) {
                (Response::Ok(a), Response::Ok(b)) => assert_eq!(a, b),
                (Response::OkText(a), Response::OkText(b)) => assert_eq!(a, b),
                (Response::Stats(a), Response::Stats(b)) => assert_eq!(a, b),
                (Response::Err(a), Response::Err(b)) => assert_eq!(a, b),
                _ => panic!("variant changed in round trip"),
            }
        }
    }

    #[test]
    fn hello_round_trip_in_v1_framing() {
        let frame = Request::Hello { version: PROTOCOL_V2 }.encode();
        assert_eq!(frame[4], Opcode::Hello as u8);
        match Request::decode(&frame[4..]).unwrap() {
            Request::Hello { version } => assert_eq!(version, PROTOCOL_V2),
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn v2_correlation_id_rides_every_frame() {
        // Requests: cid (and optional deadline) sit between flags and
        // payload; the payload bytes decode identically to v1.
        let req = tiny_sgemm().with_shard_hint(2);
        let frame = req.encode_v2(0xDEAD_BEEF, None);
        assert_eq!(&frame[7..11], &0xDEAD_BEEFu32.to_le_bytes());
        let (cid, deadline, back) = Request::decode_v2(&frame[4..]).unwrap();
        assert_eq!((cid, deadline), (0xDEAD_BEEF, None));
        match back {
            Request::Gemm(g) => assert_eq!(g.shard_hint, Some(2)),
            other => panic!("wrong decode: {other:?}"),
        }
        // With a deadline, FLAG_DEADLINE is set and the budget follows.
        let frame = Request::Ping.encode_v2(7, Some(250));
        assert_eq!(frame[6] & FLAG_DEADLINE, FLAG_DEADLINE);
        let (cid, deadline, back) = Request::decode_v2(&frame[4..]).unwrap();
        assert_eq!((cid, deadline), (7, Some(250)));
        assert!(matches!(back, Request::Ping));
        // Responses: cid right after the header, any variant.
        for resp in [
            Response::Ok(Tensor::F32(vec![1.0])),
            Response::Stats(sample_stats()),
            Response::Err("late".into()),
        ] {
            let frame = resp.encode_v2(41);
            let (cid, _) = Response::decode_v2(&frame[4..]).unwrap();
            assert_eq!(cid, 41);
        }
    }

    #[test]
    fn v1_decoder_rejects_deadline_flag() {
        // FLAG_DEADLINE is a v2-only bit: the v1 path must keep treating
        // it as reserved, or a v2 frame could silently misparse as v1.
        let frame = Request::Ping.encode_v2(1, Some(10));
        assert!(Request::decode(&frame[4..]).is_err());
    }

    #[test]
    fn frame_accumulator_dribble_and_coalesce() {
        let f1 = Request::Ping.encode();
        let f2 = tiny_sgemm().encode();
        // 1-byte dribble across both frames: exactly two frames pop out,
        // each only once its last byte has landed.
        let mut acc = FrameAccumulator::new(MAX_FRAME_LEN);
        let all: Vec<u8> = f1.iter().chain(f2.iter()).copied().collect();
        let mut got = Vec::new();
        for (i, b) in all.iter().enumerate() {
            acc.extend(&[*b]);
            while let Some(body) = acc.try_frame().unwrap() {
                got.push((i, body));
            }
        }
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, f1.len() - 1, "frame 1 completes on its last byte");
        assert_eq!(got[0].1, &f1[4..]);
        assert_eq!(got[1].1, &f2[4..]);
        assert!(!acc.has_partial());
        // Two frames in one read coalesce: both pop out back to back.
        let mut acc = FrameAccumulator::new(MAX_FRAME_LEN);
        acc.extend(&all);
        assert_eq!(acc.try_frame().unwrap().unwrap(), &f1[4..]);
        assert_eq!(acc.try_frame().unwrap().unwrap(), &f2[4..]);
        assert!(acc.try_frame().unwrap().is_none());
    }

    #[test]
    fn frame_accumulator_recycles_bodies_through_pool() {
        let pool = Arc::new(BufferPool::<u8>::new(4));
        let mut acc = FrameAccumulator::with_pool(MAX_FRAME_LEN, Arc::clone(&pool));
        let f = tiny_sgemm().encode();
        acc.extend(&f);
        let first = acc.try_frame().unwrap().unwrap();
        assert_eq!(first, &f[4..]);
        drop(first); // body parks back in the shared pool
        acc.extend(&f);
        let second = acc.try_frame().unwrap().unwrap();
        assert_eq!(second, &f[4..]);
        let s = pool.stats();
        assert_eq!((s.gets, s.recycled), (2, 1), "second body re-uses the first's allocation");
    }

    #[test]
    fn frame_accumulator_rejects_hostile_length() {
        // A 4 GiB-ish length prefix dies at the prefix, before any body
        // allocation — and a sub-header length is just as dead.
        let mut acc = FrameAccumulator::new(DEFAULT_MAX_FRAME_LEN);
        acc.extend(&u32::MAX.to_le_bytes());
        assert!(acc.try_frame().is_err());
        let mut acc = FrameAccumulator::new(DEFAULT_MAX_FRAME_LEN);
        acc.extend(&1u32.to_le_bytes());
        assert!(acc.try_frame().is_err(), "length below header size");
    }

    #[test]
    fn truncated_frames_rejected() {
        let req = Request::Ping.encode();
        assert!(Request::decode(&req[4..]).is_ok());
        let bad = [Opcode::Gemm as u8, 0, 0]; // header only, no payload
        assert!(Request::decode(&bad).is_err());
        assert!(Request::decode(&[42, 0, 0]).is_err(), "unknown opcode");
        assert!(Request::decode(&[16, 9, 0]).is_err(), "unknown dtype");
        assert!(Request::decode(&[16, 0, 7]).is_err(), "nonzero reserved flags");
        assert!(Request::decode(&[16]).is_err(), "shorter than header");
    }

    fn tiny_sgemm() -> Request {
        Request::sgemm(Trans::N, Trans::N, 1, 1, 1, 1.0, 0.0, vec![1.0], vec![1.0], vec![0.0])
    }

    #[test]
    fn gemm_batch_round_trip() {
        // Ragged per-item dims (and a transposed item) through one frame.
        let items = vec![
            GemmWire::f32(Trans::N, Trans::N, 2, 3, 4, 1.0, 0.0, vec![1.0; 8], vec![2.0; 12],
                vec![0.0; 6]),
            GemmWire::f32(Trans::T, Trans::N, 3, 1, 2, 0.5, 1.0, vec![3.0; 6], vec![4.0; 2],
                vec![5.0; 3]),
        ];
        let req = Request::gemm_batch(items);
        let frame = req.encode();
        assert_eq!(frame[4], Opcode::GemmBatch as u8);
        assert_eq!(frame[6], 0, "unhinted batch keeps flags 0");
        match Request::decode(&frame[4..]).unwrap() {
            Request::GemmBatch(b) => {
                assert_eq!(b.items.len(), 2);
                assert_eq!(b.shard_hint, None);
                assert_eq!(b.out_len(), 6 + 3);
                assert_eq!((b.items[0].m, b.items[0].n, b.items[0].k), (2, 3, 4));
                assert_eq!(b.items[1].ta, Trans::T);
                assert_eq!(b.items[1].a.as_f32().unwrap(), &[3.0; 6]);
                assert_eq!(b.items[1].c.as_f32().unwrap(), &[5.0; 3]);
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn gemm_batch_hint_rides_the_flags_byte() {
        let items =
            vec![GemmWire::f64(Trans::N, Trans::N, 1, 1, 1, 1.0, 0.0, vec![1.0], vec![1.0],
                vec![0.0])];
        let frame = Request::gemm_batch(items).with_shard_hint(3).encode();
        assert_eq!(frame[6], 4, "flags nibble is chip + 1");
        match Request::decode(&frame[4..]).unwrap() {
            Request::GemmBatch(b) => {
                assert_eq!(b.shard_hint, Some(3));
                assert_eq!(b.dtype(), Dtype::F64);
                // Per-item hints never travel.
                assert_eq!(b.items[0].shard_hint, None);
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn empty_gemm_batch_rejected() {
        let frame = Request::gemm_batch(Vec::new()).encode();
        assert!(Request::decode(&frame[4..]).is_err());
    }

    #[test]
    fn solve_round_trip() {
        let n = 3usize;
        let req = Request::solve(
            Factorization::Cholesky,
            n,
            64,
            12,
            16.0,
            (0..n * n).map(|v| v as f64).collect(),
            vec![1.0; n],
        );
        let frame = req.encode();
        assert_eq!(frame[4], Opcode::Solve as u8);
        match Request::decode(&frame[4..]).unwrap() {
            Request::Solve(s) => {
                assert!(matches!(s.factorization, Factorization::Cholesky));
                assert_eq!((s.n, s.nb, s.max_iters), (3, 64, 12));
                assert_eq!(s.tolerance, 16.0);
                assert_eq!(s.dtype(), Dtype::F64);
                assert_eq!(s.a.len(), 9);
                assert_eq!(s.b.as_f64().unwrap(), &[1.0; 3]);
            }
            other => panic!("wrong decode: {other:?}"),
        }
        // The LU kind takes the other wire byte.
        let frame =
            Request::solve(Factorization::Lu, 1, 0, 0, 0.0, vec![2.0], vec![3.0]).encode();
        match Request::decode(&frame[4..]).unwrap() {
            Request::Solve(s) => assert!(matches!(s.factorization, Factorization::Lu)),
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn shard_hint_rides_the_flags_byte() {
        let frame = tiny_sgemm().with_shard_hint(3).encode();
        assert_eq!(frame[6], 4, "flags nibble is chip + 1");
        match Request::decode(&frame[4..]).unwrap() {
            Request::Gemm(g) => assert_eq!(g.shard_hint, Some(3)),
            other => panic!("wrong decode: {other:?}"),
        }
        // No hint keeps flags == 0: pre-shard clients stay compatible.
        let plain = tiny_sgemm().encode();
        assert_eq!(plain[6], 0);
        match Request::decode(&plain[4..]).unwrap() {
            Request::Gemm(g) => assert_eq!(g.shard_hint, None),
            other => panic!("wrong decode: {other:?}"),
        }
        // Hints saturate at the nibble ceiling (14).
        let big = tiny_sgemm().with_shard_hint(99).encode();
        match Request::decode(&big[4..]).unwrap() {
            Request::Gemm(g) => assert_eq!(g.shard_hint, Some(14)),
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn reserved_high_flag_bits_rejected() {
        let mut frame = tiny_sgemm().encode();
        frame[6] = 0x10; // high nibble is reserved, even on gemm frames
        assert!(Request::decode(&frame[4..]).is_err());
        // And any flags at all are rejected on non-gemm frames.
        let mut ping = Request::Ping.encode();
        ping[6] = 0x01;
        assert!(Request::decode(&ping[4..]).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut frame = Request::Ping.encode();
        frame.extend_from_slice(&[0, 0, 0, 0]);
        // Re-stamp the length prefix to cover the garbage.
        let len = (frame.len() - 4) as u32;
        frame[..4].copy_from_slice(&len.to_le_bytes());
        assert!(Request::decode(&frame[4..]).is_err());
    }

    #[test]
    fn frame_io() {
        let req = Request::Ping.encode();
        let mut buf = std::io::Cursor::new(req.clone());
        let body = read_frame(&mut buf).unwrap();
        assert_eq!(body, &req[4..]);
    }
}
