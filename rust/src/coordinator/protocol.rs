//! Wire protocol: length-prefixed binary frames over TCP.
//!
//! Frame layout (little-endian):
//! `[u32 len][u8 opcode][payload]` where `len` counts opcode + payload.
//!
//! Gemm payload: `[u8 ta][u8 tb][u32 m][u32 n][u32 k][f32/f64 alpha]
//! [f32/f64 beta][A col-major][B col-major][C col-major]` — matrices in
//! their *stored* orientation (op applied server-side, like a BLAS call).

use crate::blis::Trans;
use anyhow::{bail, Result};
use std::io::{Read, Write};

/// Operation codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Opcode {
    Sgemm = 1,
    FalseDgemm = 2,
    Sgemv = 3,
    Ping = 4,
    Stats = 5,
    Shutdown = 6,
}

impl Opcode {
    pub fn from_u8(v: u8) -> Result<Opcode> {
        Ok(match v {
            1 => Opcode::Sgemm,
            2 => Opcode::FalseDgemm,
            3 => Opcode::Sgemv,
            4 => Opcode::Ping,
            5 => Opcode::Stats,
            6 => Opcode::Shutdown,
            _ => bail!("unknown opcode {v}"),
        })
    }
}

/// A decoded request.
#[derive(Clone, Debug)]
pub enum Request {
    Sgemm {
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        beta: f32,
        a: Vec<f32>,
        b: Vec<f32>,
        c: Vec<f32>,
    },
    FalseDgemm {
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f64,
        beta: f64,
        a: Vec<f64>,
        b: Vec<f64>,
        c: Vec<f64>,
    },
    Sgemv {
        ta: Trans,
        m: usize,
        n: usize,
        alpha: f32,
        beta: f32,
        a: Vec<f32>,
        x: Vec<f32>,
        y: Vec<f32>,
    },
    Ping,
    Stats,
    Shutdown,
}

/// A response frame: status byte + payload.
#[derive(Clone, Debug)]
pub enum Response {
    /// C (or y) payload.
    OkF32(Vec<f32>),
    OkF64(Vec<f64>),
    /// Text payload (stats, pong).
    OkText(String),
    Err(String),
}

fn trans_code(t: Trans) -> u8 {
    match t {
        Trans::N => 0,
        Trans::T => 1,
        Trans::C => 2,
        Trans::H => 3,
    }
}

fn trans_from(v: u8) -> Result<Trans> {
    Ok(match v {
        0 => Trans::N,
        1 => Trans::T,
        2 => Trans::C,
        3 => Trans::H,
        _ => bail!("bad trans code {v}"),
    })
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }
    fn u8(&mut self) -> Result<u8> {
        if self.pos >= self.buf.len() {
            bail!("truncated frame");
        }
        self.pos += 1;
        Ok(self.buf[self.pos - 1])
    }
    fn u32(&mut self) -> Result<u32> {
        if self.pos + 4 > self.buf.len() {
            bail!("truncated frame");
        }
        let v = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64(&mut self) -> Result<f64> {
        if self.pos + 8 > self.buf.len() {
            bail!("truncated frame");
        }
        let v = f64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        self.pos += 8;
        Ok(v)
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        if self.pos + 4 * n > self.buf.len() {
            bail!("truncated f32 block (want {n})");
        }
        let out = self.buf[self.pos..self.pos + 4 * n]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.pos += 4 * n;
        Ok(out)
    }
    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        if self.pos + 8 * n > self.buf.len() {
            bail!("truncated f64 block (want {n})");
        }
        let out = self.buf[self.pos..self.pos + 8 * n]
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.pos += 8 * n;
        Ok(out)
    }
}

impl Request {
    /// Encode into a frame (including the length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        match self {
            Request::Ping => body.push(Opcode::Ping as u8),
            Request::Stats => body.push(Opcode::Stats as u8),
            Request::Shutdown => body.push(Opcode::Shutdown as u8),
            Request::Sgemm { ta, tb, m, n, k, alpha, beta, a, b, c } => {
                body.push(Opcode::Sgemm as u8);
                body.push(trans_code(*ta));
                body.push(trans_code(*tb));
                for v in [*m as u32, *n as u32, *k as u32] {
                    body.extend_from_slice(&v.to_le_bytes());
                }
                body.extend_from_slice(&alpha.to_le_bytes());
                body.extend_from_slice(&beta.to_le_bytes());
                for arr in [a, b, c] {
                    for v in arr.iter() {
                        body.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            Request::FalseDgemm { ta, tb, m, n, k, alpha, beta, a, b, c } => {
                body.push(Opcode::FalseDgemm as u8);
                body.push(trans_code(*ta));
                body.push(trans_code(*tb));
                for v in [*m as u32, *n as u32, *k as u32] {
                    body.extend_from_slice(&v.to_le_bytes());
                }
                body.extend_from_slice(&alpha.to_le_bytes());
                body.extend_from_slice(&beta.to_le_bytes());
                for arr in [a, b, c] {
                    for v in arr.iter() {
                        body.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            Request::Sgemv { ta, m, n, alpha, beta, a, x, y } => {
                body.push(Opcode::Sgemv as u8);
                body.push(trans_code(*ta));
                for v in [*m as u32, *n as u32] {
                    body.extend_from_slice(&v.to_le_bytes());
                }
                body.extend_from_slice(&alpha.to_le_bytes());
                body.extend_from_slice(&beta.to_le_bytes());
                for arr in [a, x, y] {
                    for v in arr.iter() {
                        body.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
        let mut frame = Vec::with_capacity(4 + body.len());
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        frame
    }

    /// Decode a frame body (without the length prefix).
    pub fn decode(body: &[u8]) -> Result<Request> {
        let mut cur = Cursor::new(body);
        let op = Opcode::from_u8(cur.u8()?)?;
        Ok(match op {
            Opcode::Ping => Request::Ping,
            Opcode::Stats => Request::Stats,
            Opcode::Shutdown => Request::Shutdown,
            Opcode::Sgemm => {
                let ta = trans_from(cur.u8()?)?;
                let tb = trans_from(cur.u8()?)?;
                let (m, n, k) = (cur.u32()? as usize, cur.u32()? as usize, cur.u32()? as usize);
                let alpha = cur.f32()?;
                let beta = cur.f32()?;
                let (am, an) = if ta.is_trans() { (k, m) } else { (m, k) };
                let (bm, bn) = if tb.is_trans() { (n, k) } else { (k, n) };
                let a = cur.f32s(am * an)?;
                let b = cur.f32s(bm * bn)?;
                let c = cur.f32s(m * n)?;
                Request::Sgemm { ta, tb, m, n, k, alpha, beta, a, b, c }
            }
            Opcode::FalseDgemm => {
                let ta = trans_from(cur.u8()?)?;
                let tb = trans_from(cur.u8()?)?;
                let (m, n, k) = (cur.u32()? as usize, cur.u32()? as usize, cur.u32()? as usize);
                let alpha = cur.f64()?;
                let beta = cur.f64()?;
                let (am, an) = if ta.is_trans() { (k, m) } else { (m, k) };
                let (bm, bn) = if tb.is_trans() { (n, k) } else { (k, n) };
                let a = cur.f64s(am * an)?;
                let b = cur.f64s(bm * bn)?;
                let c = cur.f64s(m * n)?;
                Request::FalseDgemm { ta, tb, m, n, k, alpha, beta, a, b, c }
            }
            Opcode::Sgemv => {
                let ta = trans_from(cur.u8()?)?;
                let (m, n) = (cur.u32()? as usize, cur.u32()? as usize);
                let alpha = cur.f32()?;
                let beta = cur.f32()?;
                let a = cur.f32s(m * n)?;
                let (xl, yl) = if ta.is_trans() { (m, n) } else { (n, m) };
                let x = cur.f32s(xl)?;
                let y = cur.f32s(yl)?;
                Request::Sgemv { ta, m, n, alpha, beta, a, x, y }
            }
        })
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        match self {
            Response::OkF32(v) => {
                body.push(0u8);
                body.push(0u8); // dtype f32
                for x in v {
                    body.extend_from_slice(&x.to_le_bytes());
                }
            }
            Response::OkF64(v) => {
                body.push(0u8);
                body.push(1u8);
                for x in v {
                    body.extend_from_slice(&x.to_le_bytes());
                }
            }
            Response::OkText(s) => {
                body.push(0u8);
                body.push(2u8);
                body.extend_from_slice(s.as_bytes());
            }
            Response::Err(e) => {
                body.push(1u8);
                body.extend_from_slice(e.as_bytes());
            }
        }
        let mut frame = Vec::with_capacity(4 + body.len());
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&body);
        frame
    }

    pub fn decode(body: &[u8]) -> Result<Response> {
        if body.is_empty() {
            bail!("empty response");
        }
        if body[0] == 1 {
            return Ok(Response::Err(String::from_utf8_lossy(&body[1..]).into_owned()));
        }
        if body.len() < 2 {
            bail!("truncated response");
        }
        Ok(match body[1] {
            0 => Response::OkF32(
                body[2..]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            1 => Response::OkF64(
                body[2..]
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            2 => Response::OkText(String::from_utf8_lossy(&body[2..]).into_owned()),
            d => bail!("bad dtype tag {d}"),
        })
    }
}

/// Read one length-prefixed frame body from a stream.
pub fn read_frame(stream: &mut impl Read) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > 1 << 30 {
        bail!("frame too large: {len}");
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok(body)
}

/// Write one frame (already encoded with its prefix).
pub fn write_frame(stream: &mut impl Write, frame: &[u8]) -> Result<()> {
    stream.write_all(frame)?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgemm_round_trip() {
        let req = Request::Sgemm {
            ta: Trans::T,
            tb: Trans::N,
            m: 2,
            n: 3,
            k: 4,
            alpha: 1.5,
            beta: -0.5,
            a: (0..8).map(|v| v as f32).collect(),   // k×m stored (ta=T)
            b: (0..12).map(|v| v as f32).collect(),  // k×n
            c: (0..6).map(|v| v as f32).collect(),
        };
        let frame = req.encode();
        let body = &frame[4..];
        match Request::decode(body).unwrap() {
            Request::Sgemm { ta, tb, m, n, k, alpha, beta, a, b, c } => {
                assert_eq!((ta, tb), (Trans::T, Trans::N));
                assert_eq!((m, n, k), (2, 3, 4));
                assert_eq!((alpha, beta), (1.5, -0.5));
                assert_eq!(a.len(), 8);
                assert_eq!(b.len(), 12);
                assert_eq!(c, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn false_dgemm_round_trip() {
        let req = Request::FalseDgemm {
            ta: Trans::N,
            tb: Trans::H,
            m: 2,
            n: 2,
            k: 3,
            alpha: 2.0,
            beta: 0.0,
            a: vec![1.0; 6],
            b: vec![2.0; 6],
            c: vec![0.0; 4],
        };
        let frame = req.encode();
        match Request::decode(&frame[4..]).unwrap() {
            Request::FalseDgemm { tb, k, b, .. } => {
                assert_eq!(tb, Trans::H);
                assert_eq!(k, 3);
                assert_eq!(b, vec![2.0; 6]);
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn response_variants_round_trip() {
        for resp in [
            Response::OkF32(vec![1.0, 2.0]),
            Response::OkF64(vec![3.0]),
            Response::OkText("pong".into()),
            Response::Err("boom".into()),
        ] {
            let frame = resp.encode();
            let back = Response::decode(&frame[4..]).unwrap();
            match (&resp, &back) {
                (Response::OkF32(a), Response::OkF32(b)) => assert_eq!(a, b),
                (Response::OkF64(a), Response::OkF64(b)) => assert_eq!(a, b),
                (Response::OkText(a), Response::OkText(b)) => assert_eq!(a, b),
                (Response::Err(a), Response::Err(b)) => assert_eq!(a, b),
                _ => panic!("variant changed in round trip"),
            }
        }
    }

    #[test]
    fn truncated_frames_rejected() {
        let req = Request::Ping.encode();
        assert!(Request::decode(&req[4..]).is_ok());
        let bad = [Opcode::Sgemm as u8, 0, 0]; // missing everything
        assert!(Request::decode(&bad).is_err());
        assert!(Request::decode(&[42]).is_err(), "unknown opcode");
    }

    #[test]
    fn frame_io() {
        let req = Request::Ping.encode();
        let mut buf = std::io::Cursor::new(req.clone());
        let body = read_frame(&mut buf).unwrap();
        assert_eq!(body, &req[4..]);
    }
}
