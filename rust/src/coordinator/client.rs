//! The client side of the coordinator wire: blocking v1 calls and
//! pipelined v2 sessions.
//!
//! [`BlasClient::connect`] speaks wire v1 — every [`call`] writes one
//! frame and blocks for its reply, exactly as before. ([`call`] is now
//! a thin shim over the session API, so both wire versions share one
//! code path.)
//!
//! [`BlasClient::connect_v2`] opens with `Hello{2}`; if the server
//! acks v2, the session upgrades to correlation-id framing and
//! [`submit`] becomes available: it writes the request and returns a
//! [`Pending`] ticket immediately, so many requests ride the socket
//! concurrently. [`Pending::wait`] claims the matching response —
//! tickets can be waited in any order, because a shared session reader
//! parks responses by correlation id until their ticket shows up.
//! [`drain`] blocks until every outstanding response has landed.
//!
//! Against an old server the hello negotiates down and the client
//! transparently stays on v1 (`submit` then reports an error rather
//! than corrupting the wire).
//!
//! [`call`]: BlasClient::call
//! [`submit`]: BlasClient::submit
//! [`drain`]: BlasClient::drain

use super::protocol::{
    read_frame, read_frame_or_eof, write_frame, Request, Response, PROTOCOL_V1, PROTOCOL_V2,
};
use anyhow::{bail, ensure, Context, Result};
use std::collections::{HashMap, HashSet};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::{Arc, Mutex};

/// Demultiplexes v2 responses: whoever waits pumps the socket, and
/// frames for other tickets are parked in `completed` until claimed.
struct SessionReader {
    stream: TcpStream,
    in_flight: HashSet<u32>,
    completed: HashMap<u32, Response>,
}

impl SessionReader {
    /// Read exactly one response frame and file it by correlation id.
    fn pump_one(&mut self) -> Result<()> {
        let body = read_frame(&mut self.stream)?;
        let (cid, resp) = Response::decode_v2(&body)?;
        self.in_flight.remove(&cid);
        self.completed.insert(cid, resp);
        Ok(())
    }

    /// [`pump_one`](Self::pump_one), but a clean frame-boundary EOF (the
    /// server stopped and drained) returns `Ok(false)` instead of an
    /// error; `Ok(true)` means one frame was filed. EOF mid-frame is
    /// still an error.
    fn pump_one_or_eof(&mut self) -> Result<bool> {
        let body = match read_frame_or_eof(&mut self.stream)? {
            Some(b) => b,
            None => return Ok(false),
        };
        let (cid, resp) = Response::decode_v2(&body)?;
        self.in_flight.remove(&cid);
        self.completed.insert(cid, resp);
        Ok(true)
    }
}

/// A ticket for one in-flight v2 request.
///
/// Consume it with [`Pending::wait`]; tickets may be waited in any
/// order. A dropped ticket's response is still read off the socket by
/// later waits (or [`BlasClient::drain`]) and discarded — dropping a
/// ticket never desynchronizes the session.
pub struct Pending {
    reader: Arc<Mutex<SessionReader>>,
    cid: u32,
}

impl Pending {
    /// The correlation id this ticket was submitted under.
    pub fn correlation_id(&self) -> u32 {
        self.cid
    }

    /// Block until this request's response lands and return it.
    ///
    /// Server-side failures (including `DeadlineExceeded` and
    /// `TooManyInFlight`) come back as `Ok(Response::Err(..))`; a Rust
    /// `Err` means the session itself broke (socket or codec failure).
    pub fn wait(self) -> Result<Response> {
        loop {
            let mut r = self.reader.lock().unwrap();
            if let Some(resp) = r.completed.remove(&self.cid) {
                return Ok(resp);
            }
            r.pump_one()?;
        }
    }
}

/// A blocking TCP client for [`super::server::BlasServer`].
pub struct BlasClient {
    stream: TcpStream,
    reader: Arc<Mutex<SessionReader>>,
    version: u32,
    next_cid: u32,
}

impl BlasClient {
    /// Connect speaking wire v1 (no hello): one request, one response.
    /// Works against every server version.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<BlasClient> {
        let stream = TcpStream::connect(addr).context("connecting to blas server")?;
        BlasClient::from_stream(stream, PROTOCOL_V1)
    }

    /// Connect and negotiate wire v2 with a `Hello` exchange. If the
    /// server only speaks v1 (old server, or it negotiated down), the
    /// returned client transparently stays on v1.
    pub fn connect_v2(addr: impl ToSocketAddrs) -> Result<BlasClient> {
        let mut stream = TcpStream::connect(addr).context("connecting to blas server")?;
        write_frame(&mut stream, &Request::Hello { version: PROTOCOL_V2 }.encode())?;
        let body = read_frame(&mut stream)?;
        let version = match Response::decode(&body)? {
            Response::OkText(ack) if ack == format!("hello v{PROTOCOL_V2}") => PROTOCOL_V2,
            // Anything else — an older ack, or an error from a server
            // that predates hello — means we stay on v1.
            _ => PROTOCOL_V1,
        };
        BlasClient::from_stream(stream, version)
    }

    fn from_stream(stream: TcpStream, version: u32) -> Result<BlasClient> {
        let read_half = stream.try_clone().context("cloning client stream")?;
        Ok(BlasClient {
            stream,
            reader: Arc::new(Mutex::new(SessionReader {
                stream: read_half,
                in_flight: HashSet::new(),
                completed: HashMap::new(),
            })),
            version,
            next_cid: 1,
        })
    }

    /// The wire version this session negotiated ([`PROTOCOL_V1`] or
    /// [`PROTOCOL_V2`]).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Submit a request on a v2 session without waiting; the returned
    /// [`Pending`] claims the response later. Errors on v1 sessions.
    pub fn submit(&mut self, req: &Request) -> Result<Pending> {
        self.submit_with_deadline(req, None)
    }

    /// [`submit`](BlasClient::submit) with an optional per-request
    /// deadline budget in milliseconds; a request the server cannot
    /// answer within it gets a `DeadlineExceeded` error response.
    pub fn submit_with_deadline(
        &mut self,
        req: &Request,
        deadline_ms: Option<u32>,
    ) -> Result<Pending> {
        ensure!(
            self.version >= PROTOCOL_V2,
            "submit() needs a v2 session; connect with connect_v2"
        );
        let cid = self.next_cid;
        self.next_cid = self.next_cid.wrapping_add(1);
        // Register before writing (a response pumped by a concurrent
        // waiter must find the cid known), but a failed write takes the
        // registration back out — a phantom cid that no response will
        // ever answer would wedge `drain()` forever.
        self.reader.lock().unwrap().in_flight.insert(cid);
        if let Err(e) = write_frame(&mut self.stream, &req.encode_v2(cid, deadline_ms)) {
            self.reader.lock().unwrap().in_flight.remove(&cid);
            return Err(e);
        }
        Ok(Pending { reader: Arc::clone(&self.reader), cid })
    }

    /// Block until every outstanding response has landed (including
    /// those of dropped tickets). A no-op on v1 sessions.
    pub fn drain(&mut self) -> Result<()> {
        loop {
            let mut r = self.reader.lock().unwrap();
            if r.in_flight.is_empty() {
                return Ok(());
            }
            r.pump_one()?;
        }
    }

    /// One blocking request → response round trip.
    ///
    /// On a v1 session this writes and reads the classic frames; on a
    /// v2 session it is a shim over submit-then-wait, so calls may be
    /// freely mixed with pipelined submissions.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        if self.version >= PROTOCOL_V2 {
            return self.submit(req)?.wait();
        }
        write_frame(&mut self.stream, &req.encode())?;
        let body = read_frame(&mut self.stream)?;
        Response::decode(&body)
    }

    /// Raw access to the underlying socket (used by failure-injection
    /// tests to write malformed bytes).
    pub fn stream_mut(&mut self) -> &mut TcpStream {
        &mut self.stream
    }

    /// Turn this v2 session into a live telemetry stream: send the
    /// `Subscribe` opcode and return an iterator of JSON frames the
    /// server pushes every telemetry period (the first frame is the
    /// subscribe ack). Consumes the client — a subscribed connection
    /// carries telemetry only; outstanding tickets are drained first so
    /// no response is left competing with the stream.
    pub fn subscribe(mut self) -> Result<TelemetryStream> {
        ensure!(
            self.version >= PROTOCOL_V2,
            "subscribe() needs a v2 session; connect with connect_v2"
        );
        self.drain()?;
        let cid = self.next_cid;
        self.next_cid = self.next_cid.wrapping_add(1);
        write_frame(&mut self.stream, &Request::Subscribe.encode_v2(cid, None))?;
        Ok(TelemetryStream { client: self, cid })
    }
}

/// A subscribed v2 session: yields the server's pushed telemetry frames
/// (self-describing JSON, one object per frame) until the connection
/// closes. Obtained from [`BlasClient::subscribe`].
pub struct TelemetryStream {
    client: BlasClient,
    cid: u32,
}

impl TelemetryStream {
    /// Block for the next telemetry frame and return its JSON text.
    /// Errors when the connection closes — even cleanly — or the server
    /// answers the subscription with anything but a text frame. Prefer
    /// [`try_next_frame`](Self::try_next_frame) when a clean server stop
    /// is an expected end-of-stream, not a failure.
    pub fn next_frame(&mut self) -> Result<String> {
        match self.try_next_frame()? {
            Some(json) => Ok(json),
            None => bail!("telemetry stream closed"),
        }
    }

    /// Block for the next telemetry frame: `Ok(Some(json))` on a frame,
    /// `Ok(None)` when the server closed the connection cleanly at a
    /// frame boundary (its stop-drain sends EOF to subscribers), `Err`
    /// only on real I/O or codec failures. The `client --watch` loop
    /// exits 0 on `Ok(None)` and nonzero on `Err`.
    pub fn try_next_frame(&mut self) -> Result<Option<String>> {
        loop {
            let mut r = self.client.reader.lock().unwrap();
            if let Some(resp) = r.completed.remove(&self.cid) {
                match resp {
                    Response::OkText(json) => return Ok(Some(json)),
                    Response::Err(e) => bail!("telemetry stream refused: {e}"),
                    other => bail!("unexpected telemetry frame: {other:?}"),
                }
            }
            if !r.pump_one_or_eof()? {
                return Ok(None);
            }
        }
    }
}

impl Iterator for TelemetryStream {
    type Item = Result<String>;

    /// `None` on a clean server-side close (stop-drain EOF);
    /// `Some(Err(..))` means the stream actually broke (mid-frame cut,
    /// codec failure) — callers typically stop iterating there.
    fn next(&mut self) -> Option<Result<String>> {
        self.try_next_frame().transpose()
    }
}
