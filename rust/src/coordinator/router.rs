//! Request routing: level-3 gemm traffic to the Epiphany batcher queue,
//! level-2 to host compute, control ops answered inline — the dispatch
//! stage in front of the serial coprocessor.

use super::batcher::{Batcher, GemmJob};
use super::metrics::{Metrics, RequestKind};
use super::protocol::{Request, Response};
use crate::blis::{level2, Blas};
use crate::linalg::{Mat, MatRef};
use anyhow::Result;
use std::sync::Arc;

/// The router: shared by all connection threads.
pub struct Router {
    batcher: Batcher,
    blas: Arc<Blas>,
    pub metrics: Arc<Metrics>,
}

impl Router {
    pub fn new(blas: Arc<Blas>, batcher: Batcher, metrics: Arc<Metrics>) -> Router {
        Router { batcher, blas, metrics }
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Handle one request to completion. `Shutdown` is handled by the
    /// server before reaching here.
    pub fn handle(&self, req: Request) -> Response {
        match self.dispatch(req) {
            Ok(resp) => resp,
            Err(e) => {
                self.metrics.record_error();
                Response::Err(format!("{e:#}"))
            }
        }
    }

    fn dispatch(&self, req: Request) -> Result<Response> {
        match req {
            Request::Ping => Ok(Response::OkText("pong".into())),
            Request::Stats => Ok(Response::OkText(format!(
                "{} queue_depth={}",
                self.metrics.report(),
                self.batcher.depth()
            ))),
            Request::Shutdown => Ok(Response::OkText("bye".into())),
            Request::Sgemm { ta, tb, m, n, k, alpha, beta, a, b, c } => {
                // Route to the Epiphany queue.
                let rx = self.batcher.submit(GemmJob { ta, tb, m, n, k, alpha, beta, a, b, c });
                let out = rx.recv().map_err(|_| anyhow::anyhow!("batcher gone"))??;
                Ok(Response::OkF32(out))
            }
            Request::FalseDgemm { ta, tb, m, n, k, alpha, beta, a, b, c } => {
                // f64 traffic is rare (HPL); route directly, serialized by
                // the service itself.
                let t0 = std::time::Instant::now();
                let (ar, ac) = if ta.is_trans() { (k, m) } else { (m, k) };
                let (br, bc) = if tb.is_trans() { (n, k) } else { (k, n) };
                let a_v = MatRef::from_col_major(ar, ac, ar, &a);
                let b_v = MatRef::from_col_major(br, bc, br, &b);
                let mut c_m = Mat::from_col_major(m, n, &c);
                let rep = self.blas.dgemm_false(ta, tb, alpha, a_v, b_v, beta, &mut c_m)?;
                self.metrics.record_request(
                    RequestKind::Gemm,
                    t0.elapsed().as_secs_f64(),
                    rep.flops,
                );
                Ok(Response::OkF64(c_m.as_slice().to_vec()))
            }
            Request::Sgemv { ta, m, n, alpha, beta, a, x, mut y } => {
                // Host-side level-2 (the unaccelerated class; §4.3).
                let t0 = std::time::Instant::now();
                let a_v = MatRef::from_col_major(m, n, m, &a);
                level2::gemv(ta, alpha, a_v, &x, beta, &mut y);
                let flops = 2.0 * m as f64 * n as f64;
                self.blas.charge_host_op(
                    flops,
                    crate::epiphany::timing::CalibratedModel::default().host_level2_f64_gflops,
                );
                self.metrics.record_request(RequestKind::Gemv, t0.elapsed().as_secs_f64(), flops);
                Ok(Response::OkF32(y))
            }
        }
    }
}

/// Route classification used by tests and docs.
pub fn route_of(req: &Request) -> &'static str {
    match req {
        Request::Sgemm { .. } => "epiphany-queue",
        Request::FalseDgemm { .. } => "epiphany-direct",
        Request::Sgemv { .. } => "host-pool",
        Request::Ping | Request::Stats | Request::Shutdown => "control",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::Trans;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::max_scaled_err;

    fn router() -> Router {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        let blas = Arc::new(Blas::new(svc));
        let metrics = Arc::new(Metrics::new());
        let batcher =
            Batcher::spawn(Arc::clone(&blas), BatchPolicy::default(), Arc::clone(&metrics));
        Router::new(blas, batcher, metrics)
    }

    #[test]
    fn routes_classified() {
        assert_eq!(route_of(&Request::Ping), "control");
        let gemm = Request::Sgemm {
            ta: Trans::N,
            tb: Trans::N,
            m: 1,
            n: 1,
            k: 1,
            alpha: 1.0,
            beta: 0.0,
            a: vec![1.0],
            b: vec![1.0],
            c: vec![0.0],
        };
        assert_eq!(route_of(&gemm), "epiphany-queue");
    }

    #[test]
    fn sgemm_through_router() {
        let r = router();
        let (m, n, k) = (64, 32, 48);
        let a = Mat::<f32>::randn(m, k, 1);
        let b = Mat::<f32>::randn(k, n, 2);
        let resp = r.handle(Request::Sgemm {
            ta: Trans::N,
            tb: Trans::N,
            m,
            n,
            k,
            alpha: 1.0,
            beta: 0.0,
            a: a.as_slice().to_vec(),
            b: b.as_slice().to_vec(),
            c: vec![0.0; m * n],
        });
        let out = match resp {
            Response::OkF32(v) => Mat::from_col_major(m, n, &v),
            other => panic!("{other:?}"),
        };
        let mut want = Mat::<f64>::zeros(m, n);
        crate::blis::level3::gemm_host(
            Trans::N,
            Trans::N,
            1.0,
            a.cast::<f64>().view(),
            b.cast::<f64>().view(),
            0.0,
            &mut want,
        );
        assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
        assert_eq!(r.metrics.requests(), 1);
    }

    #[test]
    fn sgemv_on_host_path() {
        let r = router();
        let (m, n) = (16, 8);
        let a = Mat::<f32>::randn(m, n, 3);
        let x: Vec<f32> = (0..n).map(|v| v as f32).collect();
        let resp = r.handle(Request::Sgemv {
            ta: Trans::N,
            m,
            n,
            alpha: 1.0,
            beta: 0.0,
            a: a.as_slice().to_vec(),
            x: x.clone(),
            y: vec![0.0; m],
        });
        let y = match resp {
            Response::OkF32(v) => v,
            other => panic!("{other:?}"),
        };
        for i in 0..m {
            let mut want = 0.0f64;
            for j in 0..n {
                want += a.get(i, j) as f64 * x[j] as f64;
            }
            assert!((y[i] as f64 - want).abs() < 1e-4);
        }
    }

    #[test]
    fn bad_request_becomes_error_response() {
        let r = router();
        // Mismatched payload sizes.
        let resp = r.handle(Request::Sgemm {
            ta: Trans::N,
            tb: Trans::N,
            m: 4,
            n: 4,
            k: 4,
            alpha: 1.0,
            beta: 0.0,
            a: vec![0.0; 3], // wrong
            b: vec![0.0; 16],
            c: vec![0.0; 16],
        });
        assert!(matches!(resp, Response::Err(_)));
    }
}
