//! Request routing: level-3 gemm traffic to the Epiphany batcher queue,
//! level-2 to host compute, control ops answered inline — the dispatch
//! stage in front of the serial coprocessor.
//!
//! Routing is decided by (opcode, dtype) of the descriptor frame: the op
//! class picks the route, the dtype picks the precision instantiation —
//! adding a routed op means one dispatch arm here, not one per dtype.

use super::batcher::{Batcher, GemmJob};
use super::metrics::{Metrics, RequestKind};
use super::protocol::{GemmBatchWire, GemmWire, GemvWire, Request, Response, SolveWire, Tensor};
use crate::blis::{Blas, Dtype, GemvOp};
use crate::linalg::{Mat, MatRef, Real};
use crate::mem::BufferPool;
use crate::workloads::refine::{solve_refined, RefinePolicy};
use anyhow::{bail, ensure, Result};
use std::sync::Arc;

/// The router: shared by all connection threads.
pub struct Router {
    batcher: Batcher,
    blas: Arc<Blas>,
    /// The server's shared wire-frame body pool, when one exists —
    /// referenced here only so the `Stats` reply can fold its recycle
    /// count into `pool_recycled=`.
    wire_pool: Option<Arc<BufferPool<u8>>>,
    /// The metrics sink every dispatch records into.
    pub metrics: Arc<Metrics>,
}

impl Router {
    /// Assemble the dispatch stage over a BLAS pool and its batcher.
    pub fn new(blas: Arc<Blas>, batcher: Batcher, metrics: Arc<Metrics>) -> Router {
        Router { batcher, blas, wire_pool: None, metrics }
    }

    /// Let `Stats` replies account the server's shared wire-frame pool
    /// alongside the batcher's staging pool.
    pub fn with_wire_pool(mut self, pool: Arc<BufferPool<u8>>) -> Router {
        self.wire_pool = Some(pool);
        self
    }

    /// Total jobs queued across every chip's batcher queue.
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Handle one request to completion. `Shutdown` is handled by the
    /// server before reaching here.
    pub fn handle(&self, req: Request) -> Response {
        match self.dispatch(req) {
            Ok(resp) => resp,
            Err(e) => {
                self.metrics.record_error();
                Response::Err(format!("{e:#}"))
            }
        }
    }

    /// Handle one request asynchronously: `done` fires exactly once with
    /// the response — possibly on another thread, possibly after this
    /// call returned. The pipelined server's path. f32 gemms ride the
    /// batcher's completion callbacks, so no thread parks per request;
    /// every other class runs on a short-lived worker thread (bounded by
    /// the connection's in-flight window).
    pub fn dispatch_async(
        self: &Arc<Self>,
        req: Request,
        done: Box<dyn FnOnce(Response) + Send + 'static>,
    ) {
        match req {
            Request::Gemm(g) if g.dtype() == Dtype::F32 => {
                if let Err(e) = validate_gemm(&g) {
                    self.metrics.record_error();
                    done(Response::Err(format!("{e:#}")));
                    return;
                }
                let hint = g.shard_hint;
                let job = match (g.a.into_f32(), g.b.into_f32(), g.c.into_f32()) {
                    (Ok(a), Ok(b), Ok(c)) => GemmJob {
                        ta: g.ta,
                        tb: g.tb,
                        m: g.m,
                        n: g.n,
                        k: g.k,
                        alpha: g.alpha as f32,
                        beta: g.beta as f32,
                        a,
                        b,
                        c,
                    },
                    _ => {
                        self.metrics.record_error();
                        done(Response::Err("mixed dtypes in gemm descriptor".into()));
                        return;
                    }
                };
                self.batcher.submit_with(
                    hint,
                    job,
                    Box::new(move |r| match r {
                        Ok(v) => done(Response::Ok(Tensor::F32(v))),
                        Err(e) => done(Response::Err(format!("{e:#}"))),
                    }),
                );
            }
            other => {
                // f64 gemm / gemv / control: the blocking handle() on a
                // short-lived thread. A spawn failure (fd/thread
                // exhaustion) drops `done` un-invoked; the connection
                // writer detects the dropped completion and errors the
                // request out rather than hanging.
                let router = Arc::clone(self);
                let _ = std::thread::Builder::new()
                    .name("blas-req".into())
                    .spawn(move || done(router.handle(other)));
            }
        }
    }

    fn dispatch(&self, req: Request) -> Result<Response> {
        match req {
            Request::Ping => Ok(Response::OkText("pong".into())),
            Request::Stats => {
                let mut rep = self.metrics.snapshot();
                rep.queue_depth = self.batcher.depth() as u64;
                // Residency counters live with the cache/pools; overlay
                // them here like queue_depth.
                if let Some(cache) = self.blas.panel_cache() {
                    let cs = cache.stats();
                    rep.panel_hits = cs.hits;
                    rep.panel_misses = cs.misses;
                    rep.panel_evictions = cs.evictions;
                }
                rep.pool_recycled = self.batcher.staging_stats().recycled
                    + self.wire_pool.as_ref().map_or(0, |p| p.recycled());
                // Chip health lives with the pool; overlay it the same way.
                let pool = self.blas.pool();
                rep.chip_health = (0..pool.len()).map(|i| pool.is_healthy(i)).collect();
                Ok(Response::Stats(rep))
            }
            Request::Shutdown => Ok(Response::OkText("bye".into())),
            Request::Subscribe => {
                // Telemetry streaming is a connection-level concern; the
                // pipelined server marks the connection subscribed before
                // routing. Reaching here means a v1 client asked for it.
                bail!("subscribe requires a pipelined v2 connection")
            }
            Request::Hello { .. } => {
                // Version negotiation is a connection-level exchange; the
                // server answers it before routing. Reaching here means a
                // client sent hello mid-stream.
                bail!("hello must be the first frame on a connection")
            }
            Request::Gemm(g) => {
                validate_gemm(&g)?;
                let (ar, ac) = if g.ta.is_trans() { (g.k, g.m) } else { (g.m, g.k) };
                let (br, bc) = if g.tb.is_trans() { (g.n, g.k) } else { (g.k, g.n) };
                match g.dtype() {
                    // f32: the serving-style traffic class — route to a
                    // per-chip Epiphany batcher queue (coalescing + FIFO).
                    // A wire shard hint pins the chip; otherwise the
                    // least-loaded queue wins.
                    Dtype::F32 => {
                        let job = GemmJob {
                            ta: g.ta,
                            tb: g.tb,
                            m: g.m,
                            n: g.n,
                            k: g.k,
                            alpha: g.alpha as f32,
                            beta: g.beta as f32,
                            a: g.a.into_f32()?,
                            b: g.b.into_f32()?,
                            c: g.c.into_f32()?,
                        };
                        let rx = match g.shard_hint {
                            Some(chip) => self.batcher.submit_to(chip, job),
                            None => self.batcher.submit(job),
                        };
                        let out = rx.recv().map_err(|_| anyhow::anyhow!("batcher gone"))??;
                        Ok(Response::Ok(Tensor::F32(out)))
                    }
                    // f64 traffic is rare (HPL); route directly, serialized
                    // by the service itself. A wire shard hint still pins
                    // the chip (reduced modulo the pool, like the batcher);
                    // unhinted requests shard per the pool's policy.
                    Dtype::F64 => {
                        let t0 = std::time::Instant::now();
                        let a = g.a.into_f64()?;
                        let b = g.b.into_f64()?;
                        let a_v = MatRef::from_col_major(ar, ac, ar, &a);
                        let b_v = MatRef::from_col_major(br, bc, br, &b);
                        let mut c_m = Mat::from_col_major(g.m, g.n, g.c.as_f64()?);
                        let rep = match g.shard_hint {
                            Some(chip) => {
                                let chip = chip % self.blas.chips();
                                let rep = self.blas.gemm_on(
                                    chip, g.ta, g.tb, g.alpha, a_v, b_v, g.beta, &mut c_m,
                                )?;
                                self.metrics.record_chip_request(chip);
                                rep
                            }
                            None => self
                                .blas
                                .dgemm_false(g.ta, g.tb, g.alpha, a_v, b_v, g.beta, &mut c_m)?,
                        };
                        self.metrics.record_request(
                            RequestKind::Gemm,
                            t0.elapsed().as_secs_f64(),
                            rep.flops,
                        );
                        Ok(Response::Ok(Tensor::F64(c_m.as_slice().to_vec())))
                    }
                }
            }
            Request::GemmBatch(b) => self.exec_gemm_batch(b),
            Request::Solve(s) => self.exec_solve(s),
            // Host-side level-2 (the unaccelerated class; §4.3): descriptor
            // dispatch through `Blas::execute`, which owns validation and
            // the host-ledger accounting — one instantiation per dtype.
            Request::Gemv(g) => {
                let t0 = std::time::Instant::now();
                let flops = 2.0 * g.m as f64 * g.n as f64;
                ensure!(g.a.len() >= g.m * g.n, "gemv A payload {} < m·n", g.a.len());
                let out = match g.dtype() {
                    Dtype::F32 => Tensor::F32(self.exec_gemv(
                        &g,
                        g.a.as_f32()?,
                        g.x.as_f32()?,
                        g.y.as_f32()?,
                    )?),
                    Dtype::F64 => Tensor::F64(self.exec_gemv(
                        &g,
                        g.a.as_f64()?,
                        g.x.as_f64()?,
                        g.y.as_f64()?,
                    )?),
                };
                self.metrics.record_request(RequestKind::Gemv, t0.elapsed().as_secs_f64(), flops);
                Ok(Response::Ok(out))
            }
        }
    }

    /// The gemm-batch route: fan the items across the chip pool and
    /// concatenate the updated C's in item order. Semantics are exactly a
    /// loop of single gemms (conformance asserts bit-identity), but the
    /// request is accounted once as [`RequestKind::Batch`] so its
    /// end-to-end latency lands in its own quantile stream.
    ///
    /// f32 items all enter their batcher queues *before* the first result
    /// is awaited, so independent items run concurrently on a multi-chip
    /// pool. A batch-level shard hint pins every item to one chip (the
    /// pin degrades to a preference if that chip is wounded, like single
    /// gemms); unhinted items each pick the least-loaded healthy queue.
    fn exec_gemm_batch(&self, batch: GemmBatchWire) -> Result<Response> {
        let t0 = std::time::Instant::now();
        for g in &batch.items {
            validate_gemm(g)?;
        }
        let total_flops: f64 =
            batch.items.iter().map(|g| 2.0 * g.m as f64 * g.n as f64 * g.k as f64).sum();
        let out_len = batch.out_len();
        let resp = match batch.dtype() {
            Dtype::F32 => {
                let mut pending = Vec::with_capacity(batch.items.len());
                for g in batch.items {
                    let job = GemmJob {
                        ta: g.ta,
                        tb: g.tb,
                        m: g.m,
                        n: g.n,
                        k: g.k,
                        alpha: g.alpha as f32,
                        beta: g.beta as f32,
                        a: g.a.into_f32()?,
                        b: g.b.into_f32()?,
                        c: g.c.into_f32()?,
                    };
                    pending.push(match batch.shard_hint {
                        Some(chip) => self.batcher.submit_to(chip, job),
                        None => self.batcher.submit(job),
                    });
                }
                let mut out = Vec::with_capacity(out_len);
                for rx in pending {
                    out.extend(rx.recv().map_err(|_| anyhow::anyhow!("batcher gone"))??);
                }
                Response::Ok(Tensor::F32(out))
            }
            Dtype::F64 => {
                // Rare (HPL-class) traffic: run each item directly, like
                // single f64 gemms — hinted items pin a chip, unhinted
                // ones shard per the pool's policy.
                let mut out = Vec::with_capacity(out_len);
                for g in batch.items {
                    let (ar, ac) = if g.ta.is_trans() { (g.k, g.m) } else { (g.m, g.k) };
                    let (br, bc) = if g.tb.is_trans() { (g.n, g.k) } else { (g.k, g.n) };
                    let a = g.a.into_f64()?;
                    let b = g.b.into_f64()?;
                    let a_v = MatRef::from_col_major(ar, ac, ar, &a);
                    let b_v = MatRef::from_col_major(br, bc, br, &b);
                    let mut c_m = Mat::from_col_major(g.m, g.n, g.c.as_f64()?);
                    match batch.shard_hint {
                        Some(chip) => {
                            let chip = chip % self.blas.chips();
                            self.blas
                                .gemm_on(chip, g.ta, g.tb, g.alpha, a_v, b_v, g.beta, &mut c_m)?;
                            self.metrics.record_chip_request(chip);
                        }
                        None => {
                            self.blas
                                .dgemm_false(g.ta, g.tb, g.alpha, a_v, b_v, g.beta, &mut c_m)?;
                        }
                    }
                    out.extend_from_slice(c_m.as_slice());
                }
                Response::Ok(Tensor::F64(out))
            }
        };
        self.metrics.record_request(RequestKind::Batch, t0.elapsed().as_secs_f64(), total_flops);
        Ok(resp)
    }

    /// The solve route: mixed-precision iterative refinement over the
    /// wire. The factorization's O(n³) trailing updates run through the
    /// accelerated (f32-class) gemm path; the O(n²) residual stays f64.
    /// Zero `nb`/`max_iters` or a non-positive `tolerance` pick the
    /// [`RefinePolicy`] defaults. Divergence and non-convergence come
    /// back as error responses carrying the typed error's message.
    fn exec_solve(&self, s: SolveWire) -> Result<Response> {
        let t0 = std::time::Instant::now();
        ensure!(s.dtype() == Dtype::F64, "solve requires f64 payloads (the refined precision)");
        let a = s.a.into_f64()?;
        let b = s.b.into_f64()?;
        ensure!(a.len() == s.n * s.n, "solve A payload {} != n² = {}", a.len(), s.n * s.n);
        ensure!(b.len() == s.n, "solve b payload {} != n = {}", b.len(), s.n);
        let a = Mat::from_col_major(s.n, s.n, &a);
        let mut policy = RefinePolicy::default();
        if s.nb > 0 {
            policy.nb = s.nb;
        }
        if s.max_iters > 0 {
            policy.max_iters = s.max_iters;
        }
        if s.tolerance > 0.0 {
            policy.tolerance = s.tolerance;
        }
        let (x, rep) = solve_refined(&self.blas, &a, &b, s.factorization, &policy)?;
        self.metrics.record_request(
            RequestKind::Solve,
            t0.elapsed().as_secs_f64(),
            rep.factor.gemm_flops + rep.factor.host_flops,
        );
        Ok(Response::Ok(Tensor::F64(x)))
    }

    /// The dtype-generic gemv route: wrap the wire payload in a
    /// [`GemvOp`] descriptor and let [`Blas::execute`] validate, run and
    /// account it (recoverable errors on malformed descriptors).
    fn exec_gemv<T: Real>(
        &self,
        g: &GemvWire,
        a: &[T],
        x: &[T],
        y: &[T],
    ) -> Result<Vec<T>> {
        let a_v = MatRef::from_col_major(g.m, g.n, g.m, a);
        let mut y = y.to_vec();
        self.blas.execute(GemvOp {
            trans: g.ta,
            alpha: T::from_f64(g.alpha),
            a: a_v,
            x,
            incx: g.incx,
            beta: T::from_f64(g.beta),
            y: &mut y,
            incy: g.incy,
        })?;
        Ok(y)
    }
}

/// Validate a gemm descriptor's payload sizes. Wire-decoded frames are
/// size-checked already; this guards hand-built descriptors so both
/// dispatch paths err, not panic (a panic in the batcher worker would
/// wedge the f32 queue).
fn validate_gemm(g: &GemmWire) -> Result<()> {
    let (ar, ac) = if g.ta.is_trans() { (g.k, g.m) } else { (g.m, g.k) };
    let (br, bc) = if g.tb.is_trans() { (g.n, g.k) } else { (g.k, g.n) };
    ensure!(g.a.len() == ar * ac, "gemm A payload {} != {ar}x{ac}", g.a.len());
    ensure!(g.b.len() == br * bc, "gemm B payload {} != {br}x{bc}", g.b.len());
    ensure!(g.c.len() == g.m * g.n, "gemm C payload {} != m·n", g.c.len());
    Ok(())
}

/// Route classification used by tests and docs.
pub fn route_of(req: &Request) -> &'static str {
    match req {
        Request::Gemm(g) if g.dtype() == Dtype::F32 => "epiphany-queue",
        Request::Gemm(_) => "epiphany-direct",
        Request::GemmBatch(b) if b.dtype() == Dtype::F32 => "epiphany-queue",
        Request::GemmBatch(_) | Request::Solve(_) => "epiphany-direct",
        Request::Gemv(_) => "host-pool",
        Request::Ping
        | Request::Stats
        | Request::Shutdown
        | Request::Subscribe
        | Request::Hello { .. } => "control",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::Trans;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::max_scaled_err;

    fn router() -> Router {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        let blas = Arc::new(Blas::new(svc));
        let metrics = Arc::new(Metrics::new());
        let batcher =
            Batcher::spawn(Arc::clone(&blas), BatchPolicy::default(), Arc::clone(&metrics));
        Router::new(blas, batcher, metrics)
    }

    #[test]
    fn routes_classified() {
        assert_eq!(route_of(&Request::Ping), "control");
        assert_eq!(route_of(&Request::Subscribe), "control");
        let sgemm = Request::sgemm(
            Trans::N,
            Trans::N,
            1,
            1,
            1,
            1.0,
            0.0,
            vec![1.0],
            vec![1.0],
            vec![0.0],
        );
        assert_eq!(route_of(&sgemm), "epiphany-queue");
        let dgemm = Request::dgemm(
            Trans::N,
            Trans::N,
            1,
            1,
            1,
            1.0,
            0.0,
            vec![1.0],
            vec![1.0],
            vec![0.0],
        );
        assert_eq!(route_of(&dgemm), "epiphany-direct");
        let gemv =
            Request::sgemv(Trans::N, 1, 1, 1.0, vec![1.0], vec![1.0], 1, 0.0, vec![0.0], 1);
        assert_eq!(route_of(&gemv), "host-pool");
        use crate::coordinator::protocol::GemmWire;
        let batch32 = Request::gemm_batch(vec![GemmWire::f32(
            Trans::N,
            Trans::N,
            1,
            1,
            1,
            1.0,
            0.0,
            vec![1.0],
            vec![1.0],
            vec![0.0],
        )]);
        assert_eq!(route_of(&batch32), "epiphany-queue");
        let batch64 = Request::gemm_batch(vec![GemmWire::f64(
            Trans::N,
            Trans::N,
            1,
            1,
            1,
            1.0,
            0.0,
            vec![1.0],
            vec![1.0],
            vec![0.0],
        )]);
        assert_eq!(route_of(&batch64), "epiphany-direct");
        let solve = Request::solve(
            crate::workloads::Factorization::Lu,
            1,
            0,
            0,
            0.0,
            vec![1.0],
            vec![1.0],
        );
        assert_eq!(route_of(&solve), "epiphany-direct");
    }

    #[test]
    fn gemm_batch_through_router_matches_single_gemms() {
        let r = router();
        let (m, n, k) = (16, 12, 8);
        let items: Vec<_> = (0..5)
            .map(|i| {
                let a = Mat::<f32>::randn(m, k, 60 + i);
                let b = Mat::<f32>::randn(k, n, 70 + i);
                crate::coordinator::protocol::GemmWire::f32(
                    Trans::N,
                    Trans::N,
                    m,
                    n,
                    k,
                    1.0,
                    0.0,
                    a.as_slice().to_vec(),
                    b.as_slice().to_vec(),
                    vec![0.0; m * n],
                )
            })
            .collect();
        // Reference: the same items as single wire gemms, in order.
        let mut want: Vec<f32> = Vec::new();
        for g in &items {
            let resp = r.handle(Request::Gemm(g.clone()));
            want.extend(resp.into_f32().unwrap());
        }
        let got = r.handle(Request::gemm_batch(items)).into_f32().unwrap();
        assert_eq!(got, want, "batch must be bit-identical to the loop of singles");
        match r.handle(Request::Stats) {
            Response::Stats(s) => {
                assert_eq!(s.batch_requests, 1, "one batch = one Batch-kind request");
                assert!(s.batch_p99_s > 0.0, "batch latency lands in its own stream");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn solve_through_router_reaches_hpl_tolerance() {
        let r = router();
        let n = 64;
        let mut rng = crate::linalg::XorShiftRng::new(77);
        let mut a = Mat::<f64>::from_fn(n, n, |_, _| rng.next_unit());
        for i in 0..n {
            a.set(i, i, a.get(i, i) + n as f64);
        }
        let b: Vec<f64> = (0..n).map(|_| rng.next_unit()).collect();
        let resp = r.handle(Request::solve(
            crate::workloads::Factorization::Lu,
            n,
            0,
            0,
            0.0,
            a.as_slice().to_vec(),
            b.clone(),
        ));
        let x = resp.into_f64().unwrap();
        let res = crate::hpl::residual::hpl_residual(&a, &x, &b);
        assert!(res.hpl_scaled <= 16.0, "wire solve residual {}", res.hpl_scaled);
        match r.handle(Request::Stats) {
            Response::Stats(s) => assert_eq!(s.solve_requests, 1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sgemm_through_router() {
        let r = router();
        let (m, n, k) = (64, 32, 48);
        let a = Mat::<f32>::randn(m, k, 1);
        let b = Mat::<f32>::randn(k, n, 2);
        let resp = r.handle(Request::sgemm(
            Trans::N,
            Trans::N,
            m,
            n,
            k,
            1.0,
            0.0,
            a.as_slice().to_vec(),
            b.as_slice().to_vec(),
            vec![0.0; m * n],
        ));
        let out = Mat::from_col_major(m, n, &resp.into_f32().unwrap());
        let mut want = Mat::<f64>::zeros(m, n);
        crate::blis::level3::gemm_host(
            Trans::N,
            Trans::N,
            1.0,
            a.cast::<f64>().view(),
            b.cast::<f64>().view(),
            0.0,
            &mut want,
        );
        assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
        assert_eq!(r.metrics.requests(), 1);
    }

    #[test]
    fn gemv_on_host_path_both_dtypes() {
        let r = router();
        let (m, n) = (16, 8);
        let a = Mat::<f32>::randn(m, n, 3);
        let x: Vec<f32> = (0..n).map(|v| v as f32).collect();
        let resp = r.handle(Request::sgemv(
            Trans::N,
            m,
            n,
            1.0,
            a.as_slice().to_vec(),
            x.clone(),
            1,
            0.0,
            vec![0.0; m],
            1,
        ));
        let y = resp.into_f32().unwrap();
        for i in 0..m {
            let mut want = 0.0f64;
            for j in 0..n {
                want += a.get(i, j) as f64 * x[j] as f64;
            }
            assert!((y[i] as f64 - want).abs() < 1e-4);
        }
        // Same wire op, f64 instantiation.
        let a64 = a.cast::<f64>();
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let resp = r.handle(Request::dgemv(
            Trans::N,
            m,
            n,
            1.0,
            a64.as_slice().to_vec(),
            x64.clone(),
            1,
            0.0,
            vec![0.0; m],
            1,
        ));
        let y64 = resp.into_f64().unwrap();
        for i in 0..m {
            let mut want = 0.0f64;
            for j in 0..n {
                want += a64.get(i, j) * x64[j];
            }
            assert!((y64[i] - want).abs() < 1e-10);
        }
    }

    #[test]
    fn strided_gemv_through_router() {
        // A = [1 2; 3 4]; x = [1, 10] at incx 2; y at incy 3.
        let r = router();
        let a = vec![1.0f32, 3.0, 2.0, 4.0];
        let resp = r.handle(Request::sgemv(
            Trans::N,
            2,
            2,
            1.0,
            a,
            vec![1.0, 0.0, 10.0],
            2,
            0.0,
            vec![0.0; 4],
            3,
        ));
        let y = resp.into_f32().unwrap();
        assert_eq!(y[0], 21.0);
        assert_eq!(y[3], 43.0);
    }

    #[test]
    fn stats_response_is_typed() {
        let r = router();
        let _ = r.handle(Request::Ping);
        match r.handle(Request::Stats) {
            Response::Stats(s) => {
                assert_eq!(s.queue_depth, 0, "drained between requests");
                assert_eq!(s.chip_health, vec![true], "pool health overlaid per chip");
                // And the rendered line keeps the legacy labels.
                assert!(s.to_string().contains("requests="));
                assert!(s.to_string().contains("chip0_healthy=1"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_surface_residency_counters() {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        let mut blas = Blas::new(svc);
        blas.set_panel_cache(4 << 20);
        let blas = Arc::new(blas);
        let metrics = Arc::new(Metrics::new());
        let batcher =
            Batcher::spawn(Arc::clone(&blas), BatchPolicy::default(), Arc::clone(&metrics));
        let r = Router::new(blas, batcher, metrics);
        let (m, n, k) = (32, 8, 16);
        let a = Mat::<f32>::randn(m, k, 9);
        let b = Mat::<f32>::randn(k, n, 10);
        let req = || {
            Request::sgemm(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.0,
                0.0,
                a.as_slice().to_vec(),
                b.as_slice().to_vec(),
                vec![0.0; m * n],
            )
        };
        // Same A twice: the first pass packs (miss), the second hits.
        r.handle(req()).into_f32().unwrap();
        r.handle(req()).into_f32().unwrap();
        match r.handle(Request::Stats) {
            Response::Stats(s) => {
                assert!(s.panel_misses >= 1, "{s:?}");
                assert!(s.panel_hits >= 1, "{s:?}");
                assert!(s.pool_recycled >= 1, "staging recycles across batches: {s:?}");
                let line = s.to_string();
                assert!(line.contains("panel_hits="), "{line}");
                assert!(line.contains("pool_recycled="), "{line}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hello_mid_stream_is_an_error() {
        let r = router();
        assert!(matches!(r.handle(Request::Hello { version: 2 }), Response::Err(_)));
        assert_eq!(route_of(&Request::Hello { version: 2 }), "control");
    }

    #[test]
    fn dispatch_async_fires_completions_for_every_class() {
        let r = Arc::new(router());
        let (m, n, k) = (32, 16, 24);
        let a = Mat::<f32>::randn(m, k, 50);
        let b = Mat::<f32>::randn(k, n, 51);
        let sgemm = Request::sgemm(
            Trans::N,
            Trans::N,
            m,
            n,
            k,
            1.0,
            0.0,
            a.as_slice().to_vec(),
            b.as_slice().to_vec(),
            vec![0.0; m * n],
        );
        let (tx, rx) = std::sync::mpsc::channel();
        for (tag, req) in [(0u8, sgemm.clone()), (1, Request::Ping), (2, sgemm)] {
            let tx = tx.clone();
            r.dispatch_async(
                req,
                Box::new(move |resp| {
                    tx.send((tag, resp)).unwrap();
                }),
            );
        }
        drop(tx);
        let mut got: Vec<(u8, Response)> = rx.iter().collect();
        assert_eq!(got.len(), 3, "every completion fired exactly once");
        got.sort_by_key(|(t, _)| *t);
        let mut want = Mat::<f64>::zeros(m, n);
        crate::blis::level3::gemm_host(
            Trans::N,
            Trans::N,
            1.0,
            a.cast::<f64>().view(),
            b.cast::<f64>().view(),
            0.0,
            &mut want,
        );
        for (tag, resp) in got {
            match tag {
                1 => assert!(matches!(resp, Response::OkText(s) if s == "pong")),
                _ => {
                    let out = Mat::from_col_major(m, n, &resp.into_f32().unwrap());
                    assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
                }
            }
        }
    }

    #[test]
    fn bad_request_becomes_error_response() {
        let r = router();
        // Mismatched payload sizes (hand-built wire struct skips the
        // constructor's implicit sizing).
        use crate::coordinator::protocol::GemmWire;
        let resp = r.handle(Request::Gemm(GemmWire {
            ta: Trans::N,
            tb: Trans::N,
            m: 4,
            n: 4,
            k: 4,
            alpha: 1.0,
            beta: 0.0,
            a: Tensor::F32(vec![0.0; 3]), // wrong
            b: Tensor::F32(vec![0.0; 16]),
            c: Tensor::F32(vec![0.0; 16]),
            shard_hint: None,
        }));
        assert!(matches!(resp, Response::Err(_)));
        // The malformed request must be rejected BEFORE reaching the
        // batcher: the worker stays alive and serves the next request.
        let (m, n, k) = (8, 8, 8);
        let a = Mat::<f32>::randn(m, k, 5);
        let b = Mat::<f32>::randn(k, n, 6);
        let good = r.handle(Request::sgemm(
            Trans::N,
            Trans::N,
            m,
            n,
            k,
            1.0,
            0.0,
            a.as_slice().to_vec(),
            b.as_slice().to_vec(),
            vec![0.0; m * n],
        ));
        assert_eq!(good.into_f32().unwrap().len(), m * n);
    }
}
