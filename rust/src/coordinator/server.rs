//! The TCP front end: accept loop + per-connection threads over the
//! router. (std::net blocking I/O with a thread per connection — the
//! request path stays pure rust, no async runtime is available offline.)

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::protocol::{read_frame, write_frame, Request, Response};
use super::router::Router;
use crate::blis::Blas;
use crate::epiphany::kernel::KernelGeometry;
use crate::epiphany::timing::CalibratedModel;
use crate::host::service::{ServiceBackend, ServiceHandle};
use anyhow::{Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// e.g. "127.0.0.1:0" (port 0 = ephemeral).
    pub addr: String,
    pub backend: ServiceBackend,
    pub batch: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            // The simulator is the always-available backend; opt into
            // `ServiceBackend::Pjrt` in pjrt-featured builds.
            backend: ServiceBackend::Simulator,
            batch: BatchPolicy::default(),
        }
    }
}

/// A running BLAS server.
pub struct BlasServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl BlasServer {
    /// Boot the full stack (service → blas → batcher → router → TCP).
    pub fn start(config: ServerConfig) -> Result<BlasServer> {
        let svc = ServiceHandle::spawn(
            config.backend,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )?;
        let blas = Arc::new(Blas::new(svc));
        let metrics = Arc::new(Metrics::new());
        let batcher = Batcher::spawn(Arc::clone(&blas), config.batch, Arc::clone(&metrics));
        let router = Arc::new(Router::new(blas, batcher, Arc::clone(&metrics)));

        let listener = TcpListener::bind(&config.addr)
            .with_context(|| format!("binding {}", config.addr))?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_accept = Arc::clone(&stop);

        let accept_thread = std::thread::Builder::new().name("blas-accept".into()).spawn(move || {
            for conn in listener.incoming() {
                if stop_accept.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let router = Arc::clone(&router);
                        let stop_conn = Arc::clone(&stop_accept);
                        let _ = std::thread::Builder::new().name("blas-conn".into()).spawn(
                            move || {
                                let _ = serve_connection(stream, &router, &stop_conn);
                            },
                        );
                    }
                    Err(_) => break,
                }
            }
        })?;

        Ok(BlasServer { local_addr, stop, accept_thread: Some(accept_thread), metrics })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Stop accepting and join the accept thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for BlasServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_connection(
    mut stream: TcpStream,
    router: &Router,
    stop: &AtomicBool,
) -> Result<()> {
    loop {
        let body = match read_frame(&mut stream) {
            Ok(b) => b,
            Err(_) => return Ok(()), // client closed
        };
        let req = match Request::decode(&body) {
            Ok(r) => r,
            Err(e) => {
                write_frame(&mut stream, &Response::Err(format!("{e:#}")).encode())?;
                continue;
            }
        };
        if matches!(req, Request::Shutdown) {
            write_frame(&mut stream, &Response::OkText("bye".into()).encode())?;
            stop.store(true, Ordering::SeqCst);
            return Ok(());
        }
        let resp = router.handle(req);
        write_frame(&mut stream, &resp.encode())?;
    }
}

/// Minimal client for examples/tests.
pub struct BlasClient {
    stream: TcpStream,
}

impl BlasClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<BlasClient> {
        Ok(BlasClient { stream: TcpStream::connect(addr)? })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.stream, &req.encode())?;
        let body = read_frame(&mut self.stream)?;
        Response::decode(&body)
    }

    /// Raw stream access (failure-injection tests hand-roll bad frames).
    pub fn stream_mut(&mut self) -> &mut TcpStream {
        &mut self.stream
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::Trans;
    use crate::linalg::{max_scaled_err, Mat};

    fn server() -> BlasServer {
        BlasServer::start(ServerConfig::default()).expect("server boots")
    }

    #[test]
    fn ping_pong() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        match cli.call(&Request::Ping).unwrap() {
            Response::OkText(s) => assert_eq!(s, "pong"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sgemm_over_tcp() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        let (m, n, k) = (64, 32, 48);
        let a = Mat::<f32>::randn(m, k, 1);
        let b = Mat::<f32>::randn(k, n, 2);
        let resp = cli
            .call(&Request::sgemm(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.0,
                0.0,
                a.as_slice().to_vec(),
                b.as_slice().to_vec(),
                vec![0.0; m * n],
            ))
            .unwrap();
        let out = Mat::from_col_major(m, n, &resp.into_f32().unwrap());
        let mut want = Mat::<f64>::zeros(m, n);
        crate::blis::level3::gemm_host(
            Trans::N,
            Trans::N,
            1.0,
            a.cast::<f64>().view(),
            b.cast::<f64>().view(),
            0.0,
            &mut want,
        );
        assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
    }

    #[test]
    fn concurrent_clients() {
        let srv = server();
        let addr = srv.addr();
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut cli = BlasClient::connect(addr).unwrap();
                for i in 0..3 {
                    let (m, n, k) = (32, 16, 24);
                    let a = Mat::<f32>::randn(m, k, t * 100 + i);
                    let b = Mat::<f32>::randn(k, n, t * 100 + i + 1);
                    let resp = cli
                        .call(&Request::sgemm(
                            Trans::N,
                            Trans::N,
                            m,
                            n,
                            k,
                            1.0,
                            0.0,
                            a.as_slice().to_vec(),
                            b.as_slice().to_vec(),
                            vec![0.0; m * n],
                        ))
                        .unwrap();
                    let out = Mat::from_col_major(m, n, &resp.into_f32().unwrap());
                    let mut want = Mat::<f64>::zeros(m, n);
                    crate::blis::level3::gemm_host(
                        Trans::N,
                        Trans::N,
                        1.0,
                        a.cast::<f64>().view(),
                        b.cast::<f64>().view(),
                        0.0,
                        &mut want,
                    );
                    assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(srv.metrics.requests() >= 12);
    }

    #[test]
    fn stats_endpoint() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        let _ = cli.call(&Request::Ping).unwrap();
        match cli.call(&Request::Stats).unwrap() {
            Response::OkText(s) => {
                assert!(s.contains("requests="), "{s}");
                assert!(s.contains("queue_depth="), "{s}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_frame_gets_error_not_crash() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        // Hand-roll a garbage frame.
        use std::io::Write;
        let body = [99u8, 1, 2, 3];
        cli.stream.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        cli.stream.write_all(&body).unwrap();
        let resp_body = super::read_frame(&mut cli.stream).unwrap();
        assert!(matches!(Response::decode(&resp_body).unwrap(), Response::Err(_)));
        // Connection still usable.
        match cli.call(&Request::Ping).unwrap() {
            Response::OkText(s) => assert_eq!(s, "pong"),
            other => panic!("{other:?}"),
        }
    }
}
