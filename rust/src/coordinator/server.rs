//! The TCP front end: accept loop + per-connection threads over the
//! router. (std::net blocking I/O with a thread per connection — the
//! request path stays pure rust, no async runtime is available offline.)
//!
//! Connections start in wire **v1**: strictly request → response, one
//! frame at a time, framed incrementally through a
//! [`FrameAccumulator`] so a dribbling client can't wedge its thread
//! mid-read. A client that opens with `Hello{version}` upgrades to
//! **v2** ([`super::protocol`]'s correlation-id framing), which splits
//! the connection into a reader and a writer thread:
//!
//! * the reader admits up to [`ServerConfig::max_in_flight`] requests
//!   into the connection's window (beyond it: a `TooManyInFlight` error
//!   response, without execution) and hands them to
//!   [`Router::dispatch_async`];
//! * completions land on the writer via a channel and are written
//!   **out of order**, tagged by correlation id;
//! * a request carrying a deadline budget that expires before its
//!   completion gets a `DeadlineExceeded` error; the late result is
//!   abandoned safely when it eventually lands.
//!
//! Shutdown drains gracefully: [`BlasServer::stop`] stops accepting,
//! shuts the read half of every live connection (its reader sees a
//! clean EOF and stops admitting), waits for the writers to flush every
//! in-flight response, and joins every connection thread — nothing
//! leaks.

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::{Metrics, StatsReport};
use super::protocol::{
    write_frame, FrameAccumulator, Request, Response, DEFAULT_MAX_FRAME_LEN, PROTOCOL_V1,
    PROTOCOL_V2,
};
use super::router::Router;
use crate::blis::Blas;
use crate::epiphany::kernel::KernelGeometry;
use crate::epiphany::timing::CalibratedModel;
use crate::host::pool::{ChipPool, ShardPolicy};
use crate::host::service::ServiceBackend;
use crate::mem::BufferPool;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

pub use super::client::{BlasClient, Pending};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// e.g. "127.0.0.1:0" (port 0 = ephemeral).
    pub addr: String,
    /// Which engine each chip of the pool computes on.
    pub backend: ServiceBackend,
    /// Per-chip batcher knobs.
    pub batch: BatchPolicy,
    /// Simulated Epiphany chips to boot (each with its own service loop
    /// and HH-RAM window; values below 1 are treated as 1).
    pub chips: usize,
    /// Per-connection pipelining window on v2 connections: at most this
    /// many requests admitted concurrently; beyond it the server answers
    /// `TooManyInFlight` without executing (values below 1 read as 1).
    pub max_in_flight: usize,
    /// Largest accepted frame body in bytes — a hostile length prefix
    /// dies before any allocation.
    pub max_frame_len: usize,
    /// Byte budget for the packed-A panel cache shared by the BLAS pool
    /// (see [`crate::mem::PanelCache`]). 0 — the default — disables the
    /// cache and keeps the gemm path bit-identical to a cacheless build.
    pub panel_cache_bytes: usize,
    /// Per-batch wall-clock budget in milliseconds: a chip whose group
    /// execution overruns it is marked unhealthy and drained (the
    /// `--health-deadline-ms` knob; overrides
    /// [`BatchPolicy::health_deadline_ms`] when nonzero, 0 — the
    /// default — leaves the policy's own value in force).
    pub health_deadline_ms: u64,
    /// Milliseconds between telemetry pushes on a subscribed v2
    /// connection (the `Subscribe` opcode's stream cadence; values
    /// below 10 read as 10).
    pub telemetry_period_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            // The simulator is the always-available backend; opt into
            // `ServiceBackend::Pjrt` in pjrt-featured builds.
            backend: ServiceBackend::Simulator,
            batch: BatchPolicy::default(),
            chips: 1,
            max_in_flight: 32,
            max_frame_len: DEFAULT_MAX_FRAME_LEN,
            panel_cache_bytes: 0,
            health_deadline_ms: 0,
            telemetry_period_ms: 200,
        }
    }
}

/// The per-connection knobs, copied out of [`ServerConfig`].
#[derive(Clone, Copy)]
struct ConnLimits {
    max_in_flight: usize,
    max_frame_len: usize,
    telemetry_period: Duration,
}

/// A live connection as the accept loop tracks it: the stream half used
/// to interrupt its reader on stop, and the thread to join.
struct ConnEntry {
    stream: TcpStream,
    join: std::thread::JoinHandle<()>,
}

/// A running BLAS server.
pub struct BlasServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<ConnEntry>>>,
    blas: Arc<Blas>,
    /// The server's metrics sink (shared with the router and batchers).
    pub metrics: Arc<Metrics>,
}

impl BlasServer {
    /// Boot the full stack (chip pool → blas → per-chip batcher →
    /// router → TCP).
    pub fn start(config: ServerConfig) -> Result<BlasServer> {
        let pool = ChipPool::spawn(
            config.chips.max(1),
            config.backend,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )?;
        let mut blas = Blas::with_pool(pool, ShardPolicy::ColumnPanels);
        blas.set_panel_cache(config.panel_cache_bytes);
        let blas = Arc::new(blas);
        let metrics = Arc::new(Metrics::new());
        let mut batch = config.batch.clone();
        if config.health_deadline_ms > 0 {
            batch.health_deadline_ms = config.health_deadline_ms;
        }
        let batcher = Batcher::spawn(Arc::clone(&blas), batch, Arc::clone(&metrics));
        // One wire-body pool shared by every connection's accumulator, so
        // frame allocations recycle across connections, not just within
        // one; the router reads its counters for `pool_recycled=`.
        let wire_pool = Arc::new(BufferPool::<u8>::new(32));
        let router = Arc::new(
            Router::new(Arc::clone(&blas), batcher, Arc::clone(&metrics))
                .with_wire_pool(Arc::clone(&wire_pool)),
        );
        let limits = ConnLimits {
            max_in_flight: config.max_in_flight.max(1),
            max_frame_len: config.max_frame_len.max(64),
            telemetry_period: Duration::from_millis(config.telemetry_period_ms.max(10)),
        };

        let listener = TcpListener::bind(&config.addr)
            .with_context(|| format!("binding {}", config.addr))?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_accept = Arc::clone(&stop);
        let conns: Arc<Mutex<Vec<ConnEntry>>> = Arc::new(Mutex::new(Vec::new()));
        let conns_accept = Arc::clone(&conns);

        let accept_thread = std::thread::Builder::new().name("blas-accept".into()).spawn(move || {
            for conn in listener.incoming() {
                if stop_accept.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let registry_half = match stream.try_clone() {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        let router = Arc::clone(&router);
                        let stop_conn = Arc::clone(&stop_accept);
                        let pool_conn = Arc::clone(&wire_pool);
                        let spawned = std::thread::Builder::new().name("blas-conn".into()).spawn(
                            move || {
                                let _ =
                                    serve_connection(stream, router, stop_conn, limits, pool_conn);
                            },
                        );
                        if let Ok(join) = spawned {
                            let mut cs = conns_accept.lock().unwrap();
                            // Prune finished threads so the registry
                            // tracks live connections, not history.
                            cs.retain(|c| !c.join.is_finished());
                            cs.push(ConnEntry { stream: registry_half, join });
                        }
                    }
                    Err(_) => break,
                }
            }
        })?;

        Ok(BlasServer {
            local_addr,
            stop,
            accept_thread: Some(accept_thread),
            conns,
            blas,
            metrics,
        })
    }

    /// The bound listen address (resolves port 0 to the real port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// A shared handle to the BLAS stack the server routes onto — the
    /// chip pool behind it carries the health state (chaos tests use
    /// this to arm per-chip fault injection and to probe recovery).
    pub fn blas_handle(&self) -> Arc<Blas> {
        Arc::clone(&self.blas)
    }

    /// Graceful drain: stop accepting, interrupt every live connection's
    /// reader (shut its read half — a clean EOF, so in-flight responses
    /// still flush), and join every connection thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let entries: Vec<ConnEntry> = self.conns.lock().unwrap().drain(..).collect();
        for e in &entries {
            let _ = e.stream.shutdown(std::net::Shutdown::Read);
        }
        for e in entries {
            let _ = e.join.join();
        }
    }
}

impl Drop for BlasServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// What the v1 frame handler tells the read loop to do next.
enum V1Flow {
    Continue,
    Upgrade,
    Close,
}

/// Serve a connection's v1 phase. Returns `Ok(())` only on a clean
/// close; read-side failures (mid-frame EOF, hostile length prefixes,
/// socket errors) bump the `io_errors` metric and return the error.
fn serve_connection(
    mut stream: TcpStream,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    limits: ConnLimits,
    wire_pool: Arc<BufferPool<u8>>,
) -> Result<()> {
    let metrics = Arc::clone(&router.metrics);
    let mut acc = FrameAccumulator::with_pool(limits.max_frame_len, wire_pool);
    let mut buf = vec![0u8; 64 * 1024];
    loop {
        loop {
            let body = match acc.try_frame() {
                Ok(Some(b)) => b,
                Ok(None) => break,
                Err(e) => {
                    // Hostile or corrupt length prefix: answer once, then
                    // kill the connection (resync is impossible).
                    metrics.record_io_error();
                    let _ = write_frame(&mut stream, &Response::Err(format!("{e:#}")).encode());
                    return Err(e);
                }
            };
            match handle_v1_frame(&body, &mut stream, &router, &stop)? {
                V1Flow::Continue => {}
                V1Flow::Upgrade => return serve_v2(stream, acc, router, stop, limits),
                V1Flow::Close => return Ok(()),
            }
        }
        match stream.read(&mut buf) {
            Ok(0) => {
                if acc.has_partial() {
                    metrics.record_io_error();
                    bail!(
                        "connection closed mid-frame ({} bytes buffered)",
                        acc.pending_bytes()
                    );
                }
                return Ok(()); // clean close
            }
            Ok(n) => acc.extend(&buf[..n]),
            Err(e) => {
                metrics.record_io_error();
                return Err(e.into());
            }
        }
    }
}

fn handle_v1_frame(
    body: &[u8],
    stream: &mut TcpStream,
    router: &Arc<Router>,
    stop: &AtomicBool,
) -> Result<V1Flow> {
    let req = match Request::decode(body) {
        Ok(r) => r,
        Err(e) => {
            write_frame(stream, &Response::Err(format!("{e:#}")).encode())?;
            return Ok(V1Flow::Continue);
        }
    };
    match req {
        Request::Hello { version } => {
            // Negotiate down to what both sides speak; the ack names the
            // agreed version so old clients can tell what they got.
            let v = version.clamp(PROTOCOL_V1, PROTOCOL_V2);
            write_frame(stream, &Response::OkText(format!("hello v{v}")).encode())?;
            Ok(if v >= PROTOCOL_V2 { V1Flow::Upgrade } else { V1Flow::Continue })
        }
        Request::Shutdown => {
            write_frame(stream, &Response::OkText("bye".into()).encode())?;
            stop.store(true, Ordering::SeqCst);
            // Nudge the accept loop so it observes the flag promptly.
            if let Ok(addr) = stream.local_addr() {
                let _ = TcpStream::connect(addr);
            }
            Ok(V1Flow::Close)
        }
        other => {
            let resp = router.handle(other);
            write_frame(stream, &resp.encode())?;
            Ok(V1Flow::Continue)
        }
    }
}

/// What the reader hands the writer thread.
enum WriterMsg {
    /// Completion for an admitted correlation id.
    Done(u32, Response),
    /// Write through immediately (rejections, decode errors, bye).
    Direct(u32, Response),
    /// Start pushing telemetry frames under this correlation id.
    Subscribe(u32),
    /// Reader is done: drain the in-flight window, then exit.
    Eof,
}

/// Deadline bookkeeping for the admitted window, shared between the
/// reader (admission) and the writer (completion/expiry).
type InFlightMap = Arc<Mutex<HashMap<u32, Option<Instant>>>>;

/// Serve a connection's v2 phase: pipelined reader + out-of-order
/// writer. `acc` carries whatever bytes arrived coalesced behind the
/// hello frame.
fn serve_v2(
    mut stream: TcpStream,
    mut acc: FrameAccumulator,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    limits: ConnLimits,
) -> Result<()> {
    let metrics = Arc::clone(&router.metrics);
    let write_half = stream.try_clone().context("cloning stream for the writer")?;
    let in_flight: InFlightMap = Arc::new(Mutex::new(HashMap::new()));
    let (tx, rx) = mpsc::channel::<WriterMsg>();
    let writer = {
        let in_flight = Arc::clone(&in_flight);
        let metrics = Arc::clone(&metrics);
        let router = Arc::clone(&router);
        let period = limits.telemetry_period;
        std::thread::Builder::new()
            .name("blas-conn-writer".into())
            .spawn(move || writer_loop(write_half, rx, in_flight, metrics, router, period))
            .context("spawning connection writer")?
    };
    let mut buf = vec![0u8; 64 * 1024];
    let mut result: Result<()> = Ok(());
    'read: loop {
        loop {
            let body = match acc.try_frame() {
                Ok(Some(b)) => b,
                Ok(None) => break,
                Err(e) => {
                    metrics.record_io_error();
                    let _ = tx.send(WriterMsg::Direct(0, Response::Err(format!("{e:#}"))));
                    result = Err(e);
                    break 'read;
                }
            };
            // Salvage the correlation id even from undecodable frames so
            // the client can match the error back to a request.
            let cid_guess = if body.len() >= 7 {
                u32::from_le_bytes(body[3..7].try_into().unwrap())
            } else {
                0
            };
            let (cid, deadline_ms, req) = match Request::decode_v2(&body) {
                Ok(t) => t,
                Err(e) => {
                    let _ =
                        tx.send(WriterMsg::Direct(cid_guess, Response::Err(format!("{e:#}"))));
                    continue;
                }
            };
            match req {
                Request::Hello { .. } => {
                    let _ = tx.send(WriterMsg::Direct(
                        cid,
                        Response::Err("hello already negotiated on this connection".into()),
                    ));
                }
                Request::Shutdown => {
                    let _ = tx.send(WriterMsg::Direct(cid, Response::OkText("bye".into())));
                    stop.store(true, Ordering::SeqCst);
                    if let Ok(addr) = stream.local_addr() {
                        let _ = TcpStream::connect(addr);
                    }
                    break 'read; // drain in-flight, then close
                }
                Request::Subscribe => {
                    // The writer owns the stream from here on out: it
                    // pushes a telemetry frame under this cid right away
                    // (the subscribe ack) and then every period.
                    let _ = tx.send(WriterMsg::Subscribe(cid));
                }
                other => {
                    // Admission control under one short lock; execution
                    // happens outside it.
                    let admitted = {
                        let mut infl = in_flight.lock().unwrap();
                        if infl.len() >= limits.max_in_flight {
                            metrics.record_rejected_in_flight();
                            Err(format!(
                                "TooManyInFlight: window of {} pipelined requests is full",
                                limits.max_in_flight
                            ))
                        } else {
                            match infl.entry(cid) {
                                std::collections::hash_map::Entry::Occupied(_) => {
                                    Err(format!("correlation id {cid} is already in flight"))
                                }
                                std::collections::hash_map::Entry::Vacant(slot) => {
                                    slot.insert(deadline_ms.map(|ms| {
                                        Instant::now() + Duration::from_millis(ms as u64)
                                    }));
                                    Ok(())
                                }
                            }
                        }
                    };
                    match admitted {
                        Err(msg) => {
                            let _ = tx.send(WriterMsg::Direct(cid, Response::Err(msg)));
                        }
                        Ok(()) => {
                            let tx = tx.clone();
                            router.dispatch_async(
                                other,
                                Box::new(move |resp| {
                                    let _ = tx.send(WriterMsg::Done(cid, resp));
                                }),
                            );
                        }
                    }
                }
            }
        }
        match stream.read(&mut buf) {
            Ok(0) => {
                if acc.has_partial() {
                    metrics.record_io_error();
                    result = Err(anyhow!(
                        "connection closed mid-frame ({} bytes buffered)",
                        acc.pending_bytes()
                    ));
                }
                break;
            }
            Ok(n) => acc.extend(&buf[..n]),
            Err(e) => {
                metrics.record_io_error();
                result = Err(e.into());
                break;
            }
        }
    }
    // Graceful drain: the writer flushes every admitted response (or its
    // deadline error) before exiting; only then does the thread die.
    let _ = tx.send(WriterMsg::Eof);
    drop(tx);
    let _ = writer.join();
    result
}

/// The v2 writer: completions out, tagged by correlation id, in
/// whatever order they land; overdue deadlines expired proactively and
/// — once a `Subscribe` lands — a telemetry frame pushed every period.
fn writer_loop(
    mut stream: TcpStream,
    rx: mpsc::Receiver<WriterMsg>,
    in_flight: InFlightMap,
    metrics: Arc<Metrics>,
    router: Arc<Router>,
    period: Duration,
) {
    let mut draining = false;
    let mut subscribed: Option<u32> = None;
    let mut next_push = Instant::now();
    loop {
        if draining && in_flight.lock().unwrap().is_empty() {
            return;
        }
        // Sleep until the next message, the nearest deadline, or — on a
        // subscribed connection — the next telemetry push.
        let next_deadline: Option<Instant> =
            in_flight.lock().unwrap().values().copied().flatten().min();
        let mut wake = next_deadline;
        if subscribed.is_some() {
            wake = Some(wake.map_or(next_push, |d| d.min(next_push)));
        }
        let timeout = match wake {
            Some(t) => t.saturating_duration_since(Instant::now()),
            None => Duration::from_millis(200),
        };
        let msg = if timeout.is_zero() {
            None // a deadline is already due: expire before blocking
        } else {
            match rx.recv_timeout(timeout) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // Every sender is gone with requests still admitted:
                    // their completions were dropped (worker spawn
                    // failure). Error them out rather than hang.
                    let orphans: Vec<u32> =
                        in_flight.lock().unwrap().drain().map(|(c, _)| c).collect();
                    for cid in orphans {
                        let resp =
                            Response::Err(format!("request {cid} was dropped by the server"));
                        let _ = write_frame(&mut stream, &resp.encode_v2(cid));
                    }
                    return;
                }
            }
        };
        match msg {
            Some(WriterMsg::Done(cid, resp)) => {
                // A cid no longer in the map already expired and was
                // answered with DeadlineExceeded: the late result is
                // abandoned safely, nothing hits the socket twice.
                if let Some(deadline) = in_flight.lock().unwrap().remove(&cid) {
                    let resp = if deadline.is_some_and(|d| Instant::now() >= d) {
                        metrics.record_deadline_exceeded();
                        deadline_response(cid)
                    } else {
                        resp
                    };
                    if write_frame(&mut stream, &resp.encode_v2(cid)).is_err() {
                        metrics.record_io_error();
                        return;
                    }
                }
            }
            Some(WriterMsg::Direct(cid, resp)) => {
                if write_frame(&mut stream, &resp.encode_v2(cid)).is_err() {
                    metrics.record_io_error();
                    return;
                }
            }
            Some(WriterMsg::Subscribe(cid)) => {
                subscribed = Some(cid);
                next_push = Instant::now(); // first frame is the ack
            }
            Some(WriterMsg::Eof) => draining = true,
            None => {
                // Expire every overdue request now.
                let now = Instant::now();
                let due: Vec<u32> = {
                    let mut infl = in_flight.lock().unwrap();
                    let due: Vec<u32> = infl
                        .iter()
                        .filter(|(_, d)| d.is_some_and(|t| now >= t))
                        .map(|(c, _)| *c)
                        .collect();
                    for c in &due {
                        infl.remove(c);
                    }
                    due
                };
                for cid in due {
                    metrics.record_deadline_exceeded();
                    if write_frame(&mut stream, &deadline_response(cid).encode_v2(cid)).is_err() {
                        metrics.record_io_error();
                        return;
                    }
                }
            }
        }
        // Telemetry push, whatever woke us: the subscribed stream keeps
        // its cadence even while completions flow.
        if let Some(cid) = subscribed {
            if Instant::now() >= next_push {
                let rep = match router.handle(Request::Stats) {
                    Response::Stats(s) => s,
                    _ => StatsReport::default(),
                };
                let n = in_flight.lock().unwrap().len();
                let frame = Response::OkText(telemetry_json(&rep, n)).encode_v2(cid);
                if write_frame(&mut stream, &frame).is_err() {
                    metrics.record_io_error();
                    return;
                }
                next_push = Instant::now() + period;
            }
        }
    }
}

/// Render one self-describing telemetry frame: the same numbers the
/// `Stats` opcode reports (with the router's pool/queue overlays), as a
/// single JSON object per push — hand-rendered, since no JSON crate is
/// available offline. `in_flight` is this connection's admitted window.
fn telemetry_json(rep: &StatsReport, in_flight: usize) -> String {
    let mut chips = String::new();
    for (i, h) in rep.chip_health.iter().enumerate() {
        if i > 0 {
            chips.push(',');
        }
        chips.push_str(&format!(
            "{{\"chip\":{i},\"healthy\":{h},\"gemms\":{}}}",
            rep.gemms_on(i)
        ));
    }
    format!(
        "{{\"type\":\"telemetry\",\"uptime_s\":{:.3},\"requests\":{},\"errors\":{},\
         \"requeued\":{},\"queue_depth\":{},\"in_flight\":{in_flight},\
         \"mean_latency_s\":{:.6},\"p50_s\":{:.6},\"p99_s\":{:.6},\
         \"panel_hits\":{},\"panel_misses\":{},\"unhealthy_chips\":{},\"chips\":[{chips}]}}",
        rep.uptime_s,
        rep.requests,
        rep.errors,
        rep.requeued,
        rep.queue_depth,
        rep.mean_latency_s,
        rep.p50_s,
        rep.p99_s,
        rep.panel_hits,
        rep.panel_misses,
        rep.unhealthy_chips(),
    )
}

/// The error a request that missed its budget gets back.
fn deadline_response(cid: u32) -> Response {
    Response::Err(format!("DeadlineExceeded: request {cid} missed its deadline"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::Trans;
    use crate::coordinator::protocol::read_frame;
    use crate::linalg::{max_scaled_err, Mat};

    fn server() -> BlasServer {
        BlasServer::start(ServerConfig::default()).expect("server boots")
    }

    #[test]
    fn ping_pong() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        match cli.call(&Request::Ping).unwrap() {
            Response::OkText(s) => assert_eq!(s, "pong"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sgemm_over_tcp() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        let (m, n, k) = (64, 32, 48);
        let a = Mat::<f32>::randn(m, k, 1);
        let b = Mat::<f32>::randn(k, n, 2);
        let resp = cli
            .call(&Request::sgemm(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.0,
                0.0,
                a.as_slice().to_vec(),
                b.as_slice().to_vec(),
                vec![0.0; m * n],
            ))
            .unwrap();
        let out = Mat::from_col_major(m, n, &resp.into_f32().unwrap());
        let mut want = Mat::<f64>::zeros(m, n);
        crate::blis::level3::gemm_host(
            Trans::N,
            Trans::N,
            1.0,
            a.cast::<f64>().view(),
            b.cast::<f64>().view(),
            0.0,
            &mut want,
        );
        assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
    }

    #[test]
    fn concurrent_clients() {
        let srv = server();
        let addr = srv.addr();
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut cli = BlasClient::connect(addr).unwrap();
                for i in 0..3 {
                    let (m, n, k) = (32, 16, 24);
                    let a = Mat::<f32>::randn(m, k, t * 100 + i);
                    let b = Mat::<f32>::randn(k, n, t * 100 + i + 1);
                    let resp = cli
                        .call(&Request::sgemm(
                            Trans::N,
                            Trans::N,
                            m,
                            n,
                            k,
                            1.0,
                            0.0,
                            a.as_slice().to_vec(),
                            b.as_slice().to_vec(),
                            vec![0.0; m * n],
                        ))
                        .unwrap();
                    let out = Mat::from_col_major(m, n, &resp.into_f32().unwrap());
                    let mut want = Mat::<f64>::zeros(m, n);
                    crate::blis::level3::gemm_host(
                        Trans::N,
                        Trans::N,
                        1.0,
                        a.cast::<f64>().view(),
                        b.cast::<f64>().view(),
                        0.0,
                        &mut want,
                    );
                    assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(srv.metrics.requests() >= 12);
    }

    #[test]
    fn sharded_server_honors_hints() {
        let srv = BlasServer::start(ServerConfig { chips: 2, ..Default::default() }).unwrap();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        let (m, n, k) = (32, 16, 24);
        let a = Mat::<f32>::randn(m, k, 7);
        let b = Mat::<f32>::randn(k, n, 8);
        let mut want = Mat::<f64>::zeros(m, n);
        crate::blis::level3::gemm_host(
            Trans::N,
            Trans::N,
            1.0,
            a.cast::<f64>().view(),
            b.cast::<f64>().view(),
            0.0,
            &mut want,
        );
        // Hints 0, 1 and 5 (= chip 1 mod 2) all route and compute right.
        for chip in [0usize, 1, 5] {
            let req = Request::sgemm(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.0,
                0.0,
                a.as_slice().to_vec(),
                b.as_slice().to_vec(),
                vec![0.0; m * n],
            )
            .with_shard_hint(chip);
            let out = Mat::from_col_major(m, n, &cli.call(&req).unwrap().into_f32().unwrap());
            assert!(max_scaled_err(out.view(), want.view()) < 1e-5, "hint {chip}");
        }
        // Both chips executed work; the typed report carries the counts
        // and its rendering keeps the per-chip labels.
        match cli.call(&Request::Stats).unwrap() {
            Response::Stats(s) => {
                assert!(s.gemms_on(0) >= 1, "{s}");
                assert!(s.gemms_on(1) >= 1, "{s}");
                assert!(s.to_string().contains("chip0_gemms="), "{s}");
                assert!(s.to_string().contains("chip1_gemms="), "{s}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_endpoint() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        let _ = cli.call(&Request::Ping).unwrap();
        match cli.call(&Request::Stats).unwrap() {
            Response::Stats(s) => {
                let line = s.to_string();
                assert!(line.contains("requests="), "{line}");
                assert!(line.contains("queue_depth="), "{line}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_frame_gets_error_not_crash() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        // Hand-roll a garbage frame.
        use std::io::Write;
        let body = [99u8, 1, 2, 3];
        cli.stream_mut().write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        cli.stream_mut().write_all(&body).unwrap();
        let resp_body = read_frame(cli.stream_mut()).unwrap();
        assert!(matches!(Response::decode(&resp_body).unwrap(), Response::Err(_)));
        // Connection still usable.
        match cli.call(&Request::Ping).unwrap() {
            Response::OkText(s) => assert_eq!(s, "pong"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn v2_session_pipelines_out_of_order_waits() {
        let srv = server();
        let mut cli = BlasClient::connect_v2(srv.addr()).unwrap();
        assert_eq!(cli.version(), PROTOCOL_V2);
        let mut pendings = Vec::new();
        let mut wants = Vec::new();
        for i in 0..4u64 {
            let (m, n, k) = (32, 16, 24);
            let a = Mat::<f32>::randn(m, k, 900 + i);
            let b = Mat::<f32>::randn(k, n, 901 + i);
            let mut want = Mat::<f64>::zeros(m, n);
            crate::blis::level3::gemm_host(
                Trans::N,
                Trans::N,
                1.0,
                a.cast::<f64>().view(),
                b.cast::<f64>().view(),
                0.0,
                &mut want,
            );
            wants.push(want);
            let req = Request::sgemm(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.0,
                0.0,
                a.as_slice().to_vec(),
                b.as_slice().to_vec(),
                vec![0.0; m * n],
            );
            pendings.push(cli.submit(&req).unwrap());
        }
        // Wait in reverse submission order: correlation ids must route
        // each response to its own request.
        for (pending, want) in pendings.into_iter().rev().zip(wants.into_iter().rev()) {
            let out = pending.wait().unwrap().into_f32().unwrap();
            let out = Mat::from_col_major(32, 16, &out);
            assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
        }
    }

    #[test]
    fn deadline_zero_is_exceeded_and_ticket_abandoned() {
        let srv = server();
        let mut cli = BlasClient::connect_v2(srv.addr()).unwrap();
        let (m, n, k) = (32, 16, 24);
        let a = Mat::<f32>::randn(m, k, 70);
        let b = Mat::<f32>::randn(k, n, 71);
        let req = Request::sgemm(
            Trans::N,
            Trans::N,
            m,
            n,
            k,
            1.0,
            0.0,
            a.as_slice().to_vec(),
            b.as_slice().to_vec(),
            vec![0.0; m * n],
        );
        // A 0 ms budget expires before any gemm can complete.
        let p = cli.submit_with_deadline(&req, Some(0)).unwrap();
        match p.wait().unwrap() {
            Response::Err(e) => assert!(e.contains("DeadlineExceeded"), "{e}"),
            other => panic!("{other:?}"),
        }
        // The connection survives the abandoned ticket and still serves.
        match cli.call(&Request::Ping).unwrap() {
            Response::OkText(s) => assert_eq!(s, "pong"),
            other => panic!("{other:?}"),
        }
        assert!(srv.metrics.deadline_exceeded() >= 1);
    }

    #[test]
    fn in_flight_window_rejects_beyond_depth() {
        let srv = BlasServer::start(ServerConfig { max_in_flight: 1, ..Default::default() })
            .unwrap();
        let mut cli = BlasClient::connect_v2(srv.addr()).unwrap();
        // One expensive gemm holds the window...
        let (m, n, k) = (192, 64, 2048);
        let a = Mat::<f32>::randn(m, k, 80);
        let b = Mat::<f32>::randn(k, n, 81);
        let big = Request::sgemm(
            Trans::N,
            Trans::N,
            m,
            n,
            k,
            1.0,
            0.0,
            a.as_slice().to_vec(),
            b.as_slice().to_vec(),
            vec![0.0; m * n],
        );
        let p1 = cli.submit(&big).unwrap();
        // ...so the next submit bounces with TooManyInFlight.
        let p2 = cli.submit(&Request::Ping).unwrap();
        match p2.wait().unwrap() {
            Response::Err(e) => assert!(e.contains("TooManyInFlight"), "{e}"),
            other => panic!("{other:?}"),
        }
        // The admitted request still completes fine.
        assert_eq!(p1.wait().unwrap().into_f32().unwrap().len(), m * n);
        assert!(srv.metrics.rejected_in_flight() >= 1);
    }

    #[test]
    fn subscribe_streams_telemetry_frames() {
        let srv = BlasServer::start(ServerConfig {
            chips: 2,
            telemetry_period_ms: 20,
            ..Default::default()
        })
        .unwrap();
        // Seed the counters with one real gemm before subscribing.
        let mut cli = BlasClient::connect_v2(srv.addr()).unwrap();
        let (m, n, k) = (32, 16, 24);
        let a = Mat::<f32>::randn(m, k, 60);
        let b = Mat::<f32>::randn(k, n, 61);
        cli.call(&Request::sgemm(
            Trans::N,
            Trans::N,
            m,
            n,
            k,
            1.0,
            0.0,
            a.as_slice().to_vec(),
            b.as_slice().to_vec(),
            vec![0.0; m * n],
        ))
        .unwrap()
        .into_f32()
        .unwrap();
        let mut stream = cli.subscribe().unwrap();
        for _ in 0..2 {
            let frame = stream.next_frame().unwrap();
            assert!(frame.contains("\"type\":\"telemetry\""), "{frame}");
            assert!(frame.contains("\"requests\":1"), "{frame}");
            assert!(frame.contains("\"unhealthy_chips\":0"), "{frame}");
            assert!(frame.contains("\"chip\":1"), "both chips reported: {frame}");
            assert!(frame.contains("\"healthy\":true"), "{frame}");
        }
        // The subscribed connection does not starve new ones: a fresh
        // client still gets served while frames keep flowing.
        let mut cli2 = BlasClient::connect(srv.addr()).unwrap();
        match cli2.call(&Request::Ping).unwrap() {
            Response::OkText(s) => assert_eq!(s, "pong"),
            other => panic!("{other:?}"),
        }
        assert!(stream.next_frame().is_ok());
    }

    #[test]
    fn subscribe_on_v1_is_an_error() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        match cli.call(&Request::Subscribe).unwrap() {
            Response::Err(e) => assert!(e.contains("v2"), "{e}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stop_drains_live_connections() {
        let mut srv = server();
        let cli = BlasClient::connect(srv.addr()).unwrap();
        let cli2 = BlasClient::connect_v2(srv.addr()).unwrap();
        // Give the accept loop a beat to register both connections.
        std::thread::sleep(std::time::Duration::from_millis(50));
        srv.stop();
        // stop() returns only after every connection thread joined; the
        // clients observe closed sockets rather than leaked threads.
        drop(cli);
        drop(cli2);
    }
}
