//! The TCP front end: accept loop + per-connection threads over the
//! router. (std::net blocking I/O with a thread per connection — the
//! request path stays pure rust, no async runtime is available offline.)

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::protocol::{read_frame, write_frame, Request, Response};
use super::router::Router;
use crate::blis::Blas;
use crate::epiphany::kernel::KernelGeometry;
use crate::epiphany::timing::CalibratedModel;
use crate::host::pool::{ChipPool, ShardPolicy};
use crate::host::service::ServiceBackend;
use anyhow::{Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// e.g. "127.0.0.1:0" (port 0 = ephemeral).
    pub addr: String,
    /// Which engine each chip of the pool computes on.
    pub backend: ServiceBackend,
    /// Per-chip batcher knobs.
    pub batch: BatchPolicy,
    /// Simulated Epiphany chips to boot (each with its own service loop
    /// and HH-RAM window; values below 1 are treated as 1).
    pub chips: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            // The simulator is the always-available backend; opt into
            // `ServiceBackend::Pjrt` in pjrt-featured builds.
            backend: ServiceBackend::Simulator,
            batch: BatchPolicy::default(),
            chips: 1,
        }
    }
}

/// A running BLAS server.
pub struct BlasServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// The server's metrics sink (shared with the router and batchers).
    pub metrics: Arc<Metrics>,
}

impl BlasServer {
    /// Boot the full stack (chip pool → blas → per-chip batcher →
    /// router → TCP).
    pub fn start(config: ServerConfig) -> Result<BlasServer> {
        let pool = ChipPool::spawn(
            config.chips.max(1),
            config.backend,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )?;
        let blas = Arc::new(Blas::with_pool(pool, ShardPolicy::ColumnPanels));
        let metrics = Arc::new(Metrics::new());
        let batcher = Batcher::spawn(Arc::clone(&blas), config.batch, Arc::clone(&metrics));
        let router = Arc::new(Router::new(blas, batcher, Arc::clone(&metrics)));

        let listener = TcpListener::bind(&config.addr)
            .with_context(|| format!("binding {}", config.addr))?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_accept = Arc::clone(&stop);

        let accept_thread = std::thread::Builder::new().name("blas-accept".into()).spawn(move || {
            for conn in listener.incoming() {
                if stop_accept.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let router = Arc::clone(&router);
                        let stop_conn = Arc::clone(&stop_accept);
                        let _ = std::thread::Builder::new().name("blas-conn".into()).spawn(
                            move || {
                                let _ = serve_connection(stream, &router, &stop_conn);
                            },
                        );
                    }
                    Err(_) => break,
                }
            }
        })?;

        Ok(BlasServer { local_addr, stop, accept_thread: Some(accept_thread), metrics })
    }

    /// The bound listen address (resolves port 0 to the real port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Stop accepting and join the accept thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for BlasServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_connection(
    mut stream: TcpStream,
    router: &Router,
    stop: &AtomicBool,
) -> Result<()> {
    loop {
        let body = match read_frame(&mut stream) {
            Ok(b) => b,
            Err(_) => return Ok(()), // client closed
        };
        let req = match Request::decode(&body) {
            Ok(r) => r,
            Err(e) => {
                write_frame(&mut stream, &Response::Err(format!("{e:#}")).encode())?;
                continue;
            }
        };
        if matches!(req, Request::Shutdown) {
            write_frame(&mut stream, &Response::OkText("bye".into()).encode())?;
            stop.store(true, Ordering::SeqCst);
            return Ok(());
        }
        let resp = router.handle(req);
        write_frame(&mut stream, &resp.encode())?;
    }
}

/// Minimal client for examples/tests.
pub struct BlasClient {
    stream: TcpStream,
}

impl BlasClient {
    /// Open a connection to a running [`BlasServer`].
    pub fn connect(addr: std::net::SocketAddr) -> Result<BlasClient> {
        Ok(BlasClient { stream: TcpStream::connect(addr)? })
    }

    /// One synchronous request/response round trip.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.stream, &req.encode())?;
        let body = read_frame(&mut self.stream)?;
        Response::decode(&body)
    }

    /// Raw stream access (failure-injection tests hand-roll bad frames).
    pub fn stream_mut(&mut self) -> &mut TcpStream {
        &mut self.stream
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blis::Trans;
    use crate::linalg::{max_scaled_err, Mat};

    fn server() -> BlasServer {
        BlasServer::start(ServerConfig::default()).expect("server boots")
    }

    #[test]
    fn ping_pong() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        match cli.call(&Request::Ping).unwrap() {
            Response::OkText(s) => assert_eq!(s, "pong"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sgemm_over_tcp() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        let (m, n, k) = (64, 32, 48);
        let a = Mat::<f32>::randn(m, k, 1);
        let b = Mat::<f32>::randn(k, n, 2);
        let resp = cli
            .call(&Request::sgemm(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.0,
                0.0,
                a.as_slice().to_vec(),
                b.as_slice().to_vec(),
                vec![0.0; m * n],
            ))
            .unwrap();
        let out = Mat::from_col_major(m, n, &resp.into_f32().unwrap());
        let mut want = Mat::<f64>::zeros(m, n);
        crate::blis::level3::gemm_host(
            Trans::N,
            Trans::N,
            1.0,
            a.cast::<f64>().view(),
            b.cast::<f64>().view(),
            0.0,
            &mut want,
        );
        assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
    }

    #[test]
    fn concurrent_clients() {
        let srv = server();
        let addr = srv.addr();
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut cli = BlasClient::connect(addr).unwrap();
                for i in 0..3 {
                    let (m, n, k) = (32, 16, 24);
                    let a = Mat::<f32>::randn(m, k, t * 100 + i);
                    let b = Mat::<f32>::randn(k, n, t * 100 + i + 1);
                    let resp = cli
                        .call(&Request::sgemm(
                            Trans::N,
                            Trans::N,
                            m,
                            n,
                            k,
                            1.0,
                            0.0,
                            a.as_slice().to_vec(),
                            b.as_slice().to_vec(),
                            vec![0.0; m * n],
                        ))
                        .unwrap();
                    let out = Mat::from_col_major(m, n, &resp.into_f32().unwrap());
                    let mut want = Mat::<f64>::zeros(m, n);
                    crate::blis::level3::gemm_host(
                        Trans::N,
                        Trans::N,
                        1.0,
                        a.cast::<f64>().view(),
                        b.cast::<f64>().view(),
                        0.0,
                        &mut want,
                    );
                    assert!(max_scaled_err(out.view(), want.view()) < 1e-5);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(srv.metrics.requests() >= 12);
    }

    #[test]
    fn sharded_server_honors_hints() {
        let srv = BlasServer::start(ServerConfig { chips: 2, ..Default::default() }).unwrap();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        let (m, n, k) = (32, 16, 24);
        let a = Mat::<f32>::randn(m, k, 7);
        let b = Mat::<f32>::randn(k, n, 8);
        let mut want = Mat::<f64>::zeros(m, n);
        crate::blis::level3::gemm_host(
            Trans::N,
            Trans::N,
            1.0,
            a.cast::<f64>().view(),
            b.cast::<f64>().view(),
            0.0,
            &mut want,
        );
        // Hints 0, 1 and 5 (= chip 1 mod 2) all route and compute right.
        for chip in [0usize, 1, 5] {
            let req = Request::sgemm(
                Trans::N,
                Trans::N,
                m,
                n,
                k,
                1.0,
                0.0,
                a.as_slice().to_vec(),
                b.as_slice().to_vec(),
                vec![0.0; m * n],
            )
            .with_shard_hint(chip);
            let out = Mat::from_col_major(m, n, &cli.call(&req).unwrap().into_f32().unwrap());
            assert!(max_scaled_err(out.view(), want.view()) < 1e-5, "hint {chip}");
        }
        // Both chips executed work, and the stats report labels them.
        match cli.call(&Request::Stats).unwrap() {
            Response::OkText(s) => {
                assert!(s.contains("chip0_gemms="), "{s}");
                assert!(s.contains("chip1_gemms="), "{s}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_endpoint() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        let _ = cli.call(&Request::Ping).unwrap();
        match cli.call(&Request::Stats).unwrap() {
            Response::OkText(s) => {
                assert!(s.contains("requests="), "{s}");
                assert!(s.contains("queue_depth="), "{s}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_frame_gets_error_not_crash() {
        let srv = server();
        let mut cli = BlasClient::connect(srv.addr()).unwrap();
        // Hand-roll a garbage frame.
        use std::io::Write;
        let body = [99u8, 1, 2, 3];
        cli.stream.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        cli.stream.write_all(&body).unwrap();
        let resp_body = super::read_frame(&mut cli.stream).unwrap();
        assert!(matches!(Response::decode(&resp_body).unwrap(), Response::Err(_)));
        // Connection still usable.
        match cli.call(&Request::Ping).unwrap() {
            Response::OkText(s) => assert_eq!(s, "pong"),
            other => panic!("{other:?}"),
        }
    }
}
