//! L3 coordinator: a network-facing BLAS service in front of the
//! Epiphany chip pool.
//!
//! The paper's architecture has exactly one chip and one service process,
//! so concurrent BLAS clients must be *routed, queued, and batched* onto
//! that serial resource — the same problem a vLLM-style router solves for
//! one accelerator. With a [`crate::host::pool::ChipPool`] there are N
//! such resources, and the coordinator schedules across them: one batcher
//! queue + worker per chip, least-loaded placement by default, and a wire
//! shard hint for clients that want chip affinity. This module provides:
//!
//! * [`protocol`] — a compact binary wire protocol: one frame header
//!   `[len][opcode][dtype][flags]` and one payload codec shared by every
//!   opcode × dtype (dtype-tagged descriptor structs, not per-precision
//!   enum variants); the `flags` nibble carries the shard hint;
//! * [`batcher`]  — per-chip FIFO + shape-coalescing batchers (requests
//!   with the same (op, K-class) batch their HH-RAM crossings, pinned to
//!   their queue's chip);
//! * [`router`]   — dispatch: level-3 sgemm/false-dgemm to a chip queue
//!   (hinted or least-loaded), level-1/2 to a host worker pool;
//! * [`server`]   — a threaded TCP accept loop;
//! * [`metrics`]  — counters + latency histograms + per-chip execution
//!   counts, `/stats`-style report.
//!
//! The full map — layers, wire grammar, and the sharded data flow — is
//! drawn in `docs/ARCHITECTURE.md`.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use protocol::{GemmWire, GemvWire, Opcode, Request, Response, Tensor};
pub use router::Router;
pub use server::{BlasServer, ServerConfig};
