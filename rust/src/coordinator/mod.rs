//! L3 coordinator: a network-facing BLAS service in front of the
//! Epiphany chip pool.
//!
//! The paper's architecture has exactly one chip and one service process,
//! so concurrent BLAS clients must be *routed, queued, and batched* onto
//! that serial resource — the same problem a vLLM-style router solves for
//! one accelerator. With a [`crate::host::pool::ChipPool`] there are N
//! such resources, and the coordinator schedules across them: one batcher
//! queue + worker per chip, least-loaded placement by default, and a wire
//! shard hint for clients that want chip affinity. This module provides:
//!
//! * [`protocol`] — a compact binary wire protocol: v1 frames
//!   `[len][opcode][dtype][flags]` and, after a `Hello` negotiation,
//!   v2 frames that add a correlation id (and optional deadline budget)
//!   so responses can return out of order; one payload codec shared by
//!   every opcode × dtype; incremental framing via
//!   [`protocol::FrameAccumulator`];
//! * [`batcher`]  — per-chip FIFO + shape-coalescing batchers (requests
//!   with the same (op, K-class) batch their HH-RAM crossings, pinned to
//!   their queue's chip), completion-callback driven; workers are
//!   panic-isolated and requeue a wounded chip's jobs onto healthy ones;
//! * [`router`]   — dispatch: level-3 sgemm/false-dgemm to a chip queue
//!   (hinted or least-loaded), level-1/2 to a host worker pool, gemm
//!   *batches* fanned item-by-item across the queues, refined solves to
//!   the [`crate::workloads`] driver; the async path
//!   ([`Router::dispatch_async`]) never parks a thread on a batched gemm;
//! * [`server`]   — a threaded TCP accept loop; v2 connections are
//!   pipelined (bounded in-flight window, per-request deadlines,
//!   out-of-order writer), can subscribe to periodic JSON telemetry
//!   pushes, and drain gracefully on stop;
//! * [`client`]   — blocking v1 calls and pipelined v2 sessions
//!   ([`BlasClient::submit`] → [`Pending::wait`]);
//! * [`metrics`]  — counters + latency histograms + per-chip execution
//!   counts, rendered from a typed [`StatsReport`].
//!
//! The full map — layers, wire grammar, and the sharded data flow — is
//! drawn in `docs/ARCHITECTURE.md`.

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use client::{BlasClient, Pending, TelemetryStream};
pub use metrics::{Metrics, StatsReport};
pub use protocol::{
    FrameAccumulator, GemmBatchWire, GemmWire, GemvWire, Opcode, Request, Response, SolveWire,
    Tensor, PROTOCOL_V1, PROTOCOL_V2,
};
pub use router::Router;
pub use server::{BlasServer, ServerConfig};
