//! L3 coordinator: a network-facing BLAS service in front of the single
//! Epiphany workgroup.
//!
//! The paper's architecture has exactly one chip and one service process,
//! so concurrent BLAS clients must be *routed, queued, and batched* onto
//! that serial resource — the same problem a vLLM-style router solves for
//! one accelerator. This module provides:
//!
//! * [`protocol`] — a compact binary wire protocol: one frame header
//!   `[len][opcode][dtype][flags]` and one payload codec shared by every
//!   opcode × dtype (dtype-tagged descriptor structs, not per-precision
//!   enum variants);
//! * [`batcher`]  — a FIFO + shape-coalescing batcher over the service
//!   (requests with the same (op, K-class) batch their HH-RAM crossings);
//! * [`router`]   — dispatch: level-3 sgemm/false-dgemm to the Epiphany
//!   queue, level-1/2 to a host worker pool;
//! * [`server`]   — a threaded TCP accept loop;
//! * [`metrics`]  — counters + latency histograms, `/stats`-style report.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use protocol::{GemmWire, GemvWire, Opcode, Request, Response, Tensor};
pub use router::Router;
pub use server::{BlasServer, ServerConfig};
