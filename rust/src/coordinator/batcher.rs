//! Dynamic batching in front of the Epiphany chip pool.
//!
//! The paper's platform has one chip and one service process (§3.2), so
//! all level-3 traffic funnels through one serial resource; with a
//! [`ChipPool`](crate::host::pool::ChipPool) there are N such resources.
//! The batcher keeps **one FIFO queue and one worker thread per chip**:
//!
//! * jobs enter a chip's queue FIFO (fairness) — either pinned by a wire
//!   shard hint ([`Batcher::submit_to`]) or sent to the least-loaded
//!   queue ([`Batcher::submit`]);
//! * each worker **coalesces** consecutive jobs that share the same A
//!   operand and scalars by concatenating their B/C along the n
//!   dimension — one service crossing instead of many (the serving-style
//!   case: one weight matrix, many activations);
//! * each worker executes its batches pinned to its own chip
//!   ([`crate::blis::Blas::gemm_on`]), so queues drain independently and
//!   a slow batch on one chip never blocks another chip's traffic.
//!
//! Coalescing never reorders: only *adjacent* compatible jobs merge
//! (see [`coalesce_plan`]), so per-queue FIFO latency bounds hold.

use super::metrics::Metrics;
use crate::blis::{Blas, Trans};
use crate::host::pool::ChipPool;
use crate::linalg::{MatMut, MatRef};
use crate::mem::{BufferPool, PoolStats};
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max jobs drained per batch round.
    pub max_batch: usize,
    /// Max columns after coalescing (bounds HH-RAM pressure).
    pub max_cols: usize,
    /// Health deadline in milliseconds: a chip whose batch execution
    /// exceeds this wall budget is marked unhealthy and its still-queued
    /// jobs move to healthy chips. `0` disables the deadline (default).
    pub health_deadline_ms: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_cols: 4096, health_deadline_ms: 0 }
    }
}

/// Poison-tolerant lock: a panic on some other thread must never take
/// queue readers down with it. The guarded data (a job queue) stays
/// structurally valid across a poisoning panic because every mutation is
/// a single `push_back`/`pop_front`.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One queued sgemm job (stored orientation, like the wire protocol).
pub struct GemmJob {
    /// Transpose flag for A.
    pub ta: Trans,
    /// Transpose flag for B.
    pub tb: Trans,
    /// Rows of C.
    pub m: usize,
    /// Columns of C.
    pub n: usize,
    /// Inner (contraction) dimension.
    pub k: usize,
    /// Scale on the product.
    pub alpha: f32,
    /// Scale on the C input.
    pub beta: f32,
    /// Stored A (col-major in its stored orientation).
    pub a: Vec<f32>,
    /// Stored B (col-major in its stored orientation).
    pub b: Vec<f32>,
    /// C input, col-major m×n.
    pub c: Vec<f32>,
}

/// The coalescing key of a [`GemmJob`]: two jobs may merge only when
/// op flags, m/k shape, scalars and (a hash of) the A operand all agree.
pub type CoalesceKey = (u8, u8, usize, usize, u32, u32, u64);

impl GemmJob {
    /// Coalescing key: jobs merge when op/shape/scalars/A agree.
    pub fn key(&self) -> CoalesceKey {
        (
            self.ta.code() as u8,
            self.tb.code() as u8,
            self.m,
            self.k,
            self.alpha.to_bits(),
            self.beta.to_bits(),
            hash_f32(&self.a),
        )
    }
}

fn hash_f32(v: &[f32]) -> u64 {
    // FNV-1a over the bit pattern; cheap and adequate for grouping.
    let mut h = 0xcbf29ce484222325u64;
    for x in v {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Greedy adjacent coalescing over `(key, n_cols)` pairs — the pure
/// planning step the worker applies to each drained FIFO slice.
///
/// Returns half-open index ranges `(start, end)`. Invariants (held by
/// construction, pinned by property tests):
///
/// * the ranges concatenate to exactly `0..jobs.len()` in order — no job
///   is reordered, dropped or duplicated, so FIFO latency bounds hold;
/// * every job in a range shares the first job's key;
/// * a range of more than one job never exceeds `max_cols` summed
///   columns (a single oversized job still runs, alone).
pub fn coalesce_plan(jobs: &[(CoalesceKey, usize)], max_cols: usize) -> Vec<(usize, usize)> {
    let mut plan = Vec::new();
    let mut i = 0usize;
    while i < jobs.len() {
        let key = jobs[i].0;
        let mut cols = jobs[i].1;
        let mut j = i + 1;
        while j < jobs.len() && jobs[j].0 == key && cols + jobs[j].1 <= max_cols {
            cols += jobs[j].1;
            j += 1;
        }
        plan.push((i, j));
        i = j;
    }
    plan
}

/// Completion callback invoked exactly once per submitted job, from the
/// executing chip's worker thread — the batcher's async spine. The
/// channel-returning [`Batcher::submit`]/[`Batcher::submit_to`] are thin
/// shims over it.
pub type Completion = Box<dyn FnOnce(Result<Vec<f32>>) + Send + 'static>;

/// A completion that fires exactly once. Invoking [`ReplyOnce::fire`]
/// consumes the callback; dropping it unfired answers the ticket with an
/// error instead of letting it vanish — the unwind half of the worker's
/// panic isolation: however a job dies, its submitter's `recv`/`wait`
/// always returns.
struct ReplyOnce {
    inner: Option<Completion>,
}

impl ReplyOnce {
    fn new(done: Completion) -> ReplyOnce {
        ReplyOnce { inner: Some(done) }
    }

    fn fire(mut self, r: Result<Vec<f32>>) {
        if let Some(done) = self.inner.take() {
            done(r);
        }
    }
}

impl Drop for ReplyOnce {
    fn drop(&mut self) {
        if let Some(done) = self.inner.take() {
            // Never let a panicking completion escalate a drop during an
            // unwind into a process abort.
            let _ = catch_unwind(AssertUnwindSafe(|| {
                done(Err(anyhow!("batcher dropped the job before completion")));
            }));
        }
    }
}

struct Queued {
    job: GemmJob,
    reply: ReplyOnce,
    /// Health-requeue budget already consumed; bounded by the pool size
    /// so a job cannot ping-pong between dying chips forever.
    attempts: u32,
}

struct Shared {
    queue: Mutex<VecDeque<Queued>>,
    cv: Condvar,
    stop: AtomicBool,
    /// Jobs drained off the queue and currently executing on the worker —
    /// without this the scheduler would see a chip grinding through a big
    /// batch as idle (its queue is empty) and keep feeding it.
    active: AtomicUsize,
}

/// The batcher handle: one FIFO queue + worker thread per pool chip.
/// Clone-free; share via `Arc`.
pub struct Batcher {
    shards: Vec<Arc<Shared>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Recycled staging buffers for the concatenated B/C operands every
    /// worker builds per batch — shared across chips so a group-sized
    /// allocation survives from one batch round to the next.
    staging: Arc<BufferPool<f32>>,
    /// The executor — kept so routing can consult the pool's chip-health
    /// state ([`ChipPool`](crate::host::pool::ChipPool)).
    blas: Arc<Blas>,
    /// The batching knobs every worker applies.
    pub policy: BatchPolicy,
}

/// Everything one worker thread needs: its own shard, every *other*
/// shard (health requeues push a wounded chip's jobs onto healthy
/// queues), and the shared executor/metrics/staging.
struct WorkerCtx {
    shards: Vec<Arc<Shared>>,
    chip: usize,
    blas: Arc<Blas>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
    staging: Arc<BufferPool<f32>>,
}

impl Batcher {
    /// Spawn one worker per chip of `blas`'s pool; each worker owns its
    /// chip's queue and executes batches pinned to that chip.
    pub fn spawn(blas: Arc<Blas>, policy: BatchPolicy, metrics: Arc<Metrics>) -> Batcher {
        let chips = blas.chips().max(1);
        // Two staging buffers (B and C concatenations) live per in-flight
        // batch, one batch per chip — retain exactly that many.
        let staging = Arc::new(BufferPool::new(2 * chips));
        let shards: Vec<Arc<Shared>> = (0..chips)
            .map(|_| {
                Arc::new(Shared {
                    queue: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                    stop: AtomicBool::new(false),
                    active: AtomicUsize::new(0),
                })
            })
            .collect();
        let mut workers = Vec::with_capacity(chips);
        for chip in 0..chips {
            let ctx = WorkerCtx {
                shards: shards.clone(),
                chip,
                blas: Arc::clone(&blas),
                policy,
                metrics: Arc::clone(&metrics),
                staging: Arc::clone(&staging),
            };
            let worker = std::thread::Builder::new()
                .name(format!("gemm-batcher-{chip}"))
                .spawn(move || worker_loop(ctx))
                .expect("spawn batcher worker");
            workers.push(worker);
        }
        Batcher { shards, workers, staging, blas, policy }
    }

    /// Counters of the shared staging pool (the batcher's contribution to
    /// the report's `pool_recycled=` label).
    pub fn staging_stats(&self) -> PoolStats {
        self.staging.stats()
    }

    /// Number of per-chip queues (= chips in the BLAS pool).
    pub fn chips(&self) -> usize {
        self.shards.len()
    }

    /// Submit a job to the least-loaded chip queue; returns the receiver
    /// for its result.
    pub fn submit(&self, job: GemmJob) -> mpsc::Receiver<Result<Vec<f32>>> {
        self.submit_to(self.least_loaded(), job)
    }

    /// Submit a job pinned to one chip's queue (wire shard hints land
    /// here). The index is reduced modulo the pool size, so any hint a
    /// client sends is routable.
    pub fn submit_to(&self, chip: usize, job: GemmJob) -> mpsc::Receiver<Result<Vec<f32>>> {
        let (tx, rx) = mpsc::channel();
        self.submit_with(
            Some(chip),
            job,
            Box::new(move |r| {
                let _ = tx.send(r);
            }),
        );
        rx
    }

    /// Submit a job with a completion callback instead of a channel — the
    /// pipelined server's path: no thread parks waiting on a receiver,
    /// the worker drives the response write directly. `chip: None` picks
    /// the least-loaded healthy queue; `Some` pins (reduced modulo the
    /// pool) — but a pin is a *preference*: an unhealthy target degrades
    /// to the least-loaded healthy chip instead of feeding a dead one.
    pub fn submit_with(&self, chip: Option<usize>, job: GemmJob, done: Completion) {
        let chip = match chip {
            Some(c) => {
                let c = c % self.shards.len();
                if self.blas.pool().is_healthy(c) {
                    c
                } else {
                    self.least_loaded()
                }
            }
            None => self.least_loaded(),
        };
        let shard = &self.shards[chip % self.shards.len()];
        {
            let mut q = relock(&shard.queue);
            q.push_back(Queued { job, reply: ReplyOnce::new(done), attempts: 0 });
        }
        shard.cv.notify_one();
    }

    /// The healthy chip with the least pending work — queued jobs *plus*
    /// jobs its worker has drained and is still executing, so a chip
    /// mid-batch is not mistaken for idle. Unhealthy chips are skipped
    /// unless every chip is down (then the scan degrades to the full pool
    /// and the execution error surfaces loudly). Lowest index wins ties
    /// (deterministic).
    pub fn least_loaded(&self) -> usize {
        least_loaded_shard(&self.shards, self.blas.pool(), None, false).unwrap_or(0)
    }

    /// Total queued jobs across every chip queue (for backpressure).
    pub fn depth(&self) -> usize {
        self.shards.iter().map(|s| relock(&s.queue).len()).sum()
    }

    /// Queued jobs on one chip's queue. The index is reduced modulo the
    /// pool size, matching [`Batcher::submit_to`]'s routing.
    pub fn depth_of(&self, chip: usize) -> usize {
        relock(&self.shards[chip % self.shards.len()].queue).len()
    }

    /// Stop every worker after it drains its queue, and join them.
    pub fn shutdown(&mut self) {
        for s in &self.shards {
            s.stop.store(true, Ordering::SeqCst);
            s.cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The least-loaded shard by queued + active jobs, optionally restricted
/// to healthy chips and optionally excluding one index (a wounded chip
/// picking a target for its own requeued jobs). Lowest index wins ties.
fn least_loaded_shard(
    shards: &[Arc<Shared>],
    pool: &ChipPool,
    exclude: Option<usize>,
    healthy_only: bool,
) -> Option<usize> {
    let pick = |healthy: bool| -> Option<usize> {
        let mut best = None;
        let mut best_depth = usize::MAX;
        for (i, s) in shards.iter().enumerate() {
            if Some(i) == exclude || (healthy && !pool.is_healthy(i)) {
                continue;
            }
            let d = relock(&s.queue).len() + s.active.load(Ordering::SeqCst);
            if d < best_depth {
                best_depth = d;
                best = Some(i);
            }
        }
        best
    };
    if healthy_only {
        pick(true)
    } else {
        // Prefer healthy chips, degrade to the full pool if none remain.
        pick(true).or_else(|| pick(false))
    }
}

/// Decrements the worker's active gauge by `n` on drop — on *every* exit
/// path, so a panic anywhere in group execution can never leak drained
/// jobs into the scheduler's view of the chip (the old inline decrement
/// was skipped on unwind).
struct ActiveGuard<'a> {
    shared: &'a Shared,
    n: usize,
}

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.shared.active.fetch_sub(self.n, Ordering::SeqCst);
    }
}

fn worker_loop(ctx: WorkerCtx) {
    let shared = Arc::clone(&ctx.shards[ctx.chip]);
    let deadline = match ctx.policy.health_deadline_ms {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    loop {
        // Wait for work on this chip's queue.
        let mut drained: Vec<Queued> = Vec::new();
        {
            let mut q = relock(&shared.queue);
            while q.is_empty() && !shared.stop.load(Ordering::SeqCst) {
                q = shared.cv.wait(q).unwrap_or_else(PoisonError::into_inner);
            }
            if shared.stop.load(Ordering::SeqCst) && q.is_empty() {
                return;
            }
            for _ in 0..ctx.policy.max_batch {
                match q.pop_front() {
                    Some(x) => drained.push(x),
                    None => break,
                }
            }
            // Count the drained jobs as load *before* releasing the queue
            // lock — least_loaded reads the queue under the same lock, so
            // it can never observe this chip as idle mid-drain.
            shared.active.store(drained.len(), Ordering::SeqCst);
        }
        // Coalesce adjacent same-key jobs and execute each group pinned
        // to this worker's chip; the active gauge drains as groups finish.
        // Group boundaries are planned first (the key carries only a
        // 64-bit hash of A; bytewise A equality is confirmed before a
        // merge so a hash collision can never execute one client's job
        // with another client's weights — inequality splits the run;
        // results stay correct either way), then `drained` is consumed
        // group by group: each completion fires exactly once however the
        // group dies ([`ReplyOnce`]).
        let keys: Vec<(CoalesceKey, usize)> =
            drained.iter().map(|x| (x.job.key(), x.job.n)).collect();
        let mut group_lens: Vec<usize> = Vec::new();
        for (start, end) in coalesce_plan(&keys, ctx.policy.max_cols) {
            let mut s = start;
            for i in start + 1..=end {
                if i < end && drained[i].job.a == drained[s].job.a {
                    continue;
                }
                group_lens.push(i - s);
                s = i;
            }
        }
        let mut rest = drained;
        for len in group_lens {
            let tail = rest.split_off(len);
            let group = std::mem::replace(&mut rest, tail);
            let glen = group.len();
            let _gauge = ActiveGuard { shared: &shared, n: glen };
            let t0 = Instant::now();
            match execute_group(&ctx.blas, ctx.chip, group, &ctx.metrics, &ctx.staging) {
                None => {
                    if glen > 1 {
                        ctx.metrics.record_batched(glen);
                    }
                    // A chip that answers, but slower than the health
                    // budget, is wedging its queue: stop feeding it.
                    if let Some(d) = deadline {
                        if t0.elapsed() > d {
                            wound_chip(&ctx, "health deadline exceeded");
                        }
                    }
                }
                Some((failed, err)) => {
                    wound_chip(&ctx, &format!("{err:#}"));
                    requeue(&ctx, failed, &err);
                }
            }
        }
    }
}

/// Mark this worker's chip unhealthy and move its still-queued jobs to
/// healthy chips. Idempotent per incident (the queue drain is what makes
/// a wounded chip stop wedging the work behind it).
fn wound_chip(ctx: &WorkerCtx, why: &str) {
    ctx.blas.pool().mark_unhealthy(ctx.chip);
    let waiting: Vec<Queued> = relock(&ctx.shards[ctx.chip].queue).drain(..).collect();
    if !waiting.is_empty() {
        requeue(ctx, waiting, &anyhow!("chip {} unhealthy: {why}", ctx.chip));
    }
}

/// Move jobs off a wounded chip onto the least-loaded healthy queue.
/// A job whose retry budget is exhausted — or stranded when no healthy
/// chip remains — answers its ticket with the error instead (degrade
/// loudly, never hang).
fn requeue(ctx: &WorkerCtx, jobs: Vec<Queued>, err: &anyhow::Error) {
    let budget = ctx.shards.len() as u32;
    for mut q in jobs {
        q.attempts += 1;
        let target = least_loaded_shard(&ctx.shards, ctx.blas.pool(), Some(ctx.chip), true);
        match target {
            Some(t) if q.attempts < budget => {
                ctx.metrics.record_requeued();
                let shard = &ctx.shards[t];
                relock(&shard.queue).push_back(q);
                shard.cv.notify_one();
            }
            _ => {
                ctx.metrics.record_error();
                q.reply.fire(Err(anyhow!(
                    "job failed on chip {} and no healthy chip could take it: {err:#}",
                    ctx.chip
                )));
            }
        }
    }
}

/// Best-effort text of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("opaque panic payload")
}

/// Run one (possibly coalesced) group on `chip` and fan the results back
/// out through each job's completion callback. The execution itself —
/// including the host-side service call, the historical panic source —
/// runs under `catch_unwind`, so a crashing chip unwinds into an error
/// value here instead of killing the worker thread and poisoning the
/// queue mutex. Returns `None` when every reply fired with a result, or
/// the unfired group + error for the caller to requeue or fail.
fn execute_group(
    blas: &Blas,
    chip: usize,
    group: Vec<Queued>,
    metrics: &Metrics,
    staging: &Arc<BufferPool<f32>>,
) -> Option<(Vec<Queued>, anyhow::Error)> {
    let first = &group[0].job;
    let (m, k) = (first.m, first.k);
    let cols: usize = group.iter().map(|q| q.job.n).sum();
    let computed = catch_unwind(AssertUnwindSafe(|| -> Result<Vec<Vec<f32>>> {
        // Stack op(B) and C along n by concatenating stored columns, into
        // recycled staging buffers from the shared pool — a steady stream
        // of batches stops paying two fresh allocations per crossing.
        // op(B) stored: tb=N ⇒ k×n col-major (concat natural); tb=T ⇒ n×k
        // stored: concatenate along rows — handled by per-job copies below.
        let a_stored = &first.a;
        let (ar, ac) = if first.ta.is_trans() { (k, m) } else { (m, k) };
        let a_view = MatRef::from_col_major(ar, ac, ar, a_stored);
        let mut c_cat = staging.get(m * cols);
        let mut j0 = 0usize;
        for q in &group {
            let job = &q.job;
            for j in 0..job.n {
                let dst = (j0 + j) * m;
                c_cat[dst..dst + m].copy_from_slice(&job.c[j * m..j * m + m]);
            }
            j0 += job.n;
        }
        // Build the concatenated op(B) as a stored matrix matching tb.
        let b_cat = if first.tb.is_trans() {
            // stored n×k each; stack rows into a cols×k buffer.
            let mut buf = staging.get(cols * k);
            let mut r0 = 0usize;
            for q in &group {
                let job = &q.job;
                for j in 0..k {
                    for i in 0..job.n {
                        buf[j * cols + r0 + i] = job.b[j * job.n + i];
                    }
                }
                r0 += job.n;
            }
            buf
        } else {
            // stored k×n each; stack columns.
            let mut buf = staging.get(k * cols);
            let mut c0 = 0usize;
            for q in &group {
                let job = &q.job;
                for j in 0..job.n {
                    let dst = (c0 + j) * k;
                    buf[dst..dst + k].copy_from_slice(&job.b[j * k..j * k + k]);
                }
                c0 += job.n;
            }
            buf
        };
        let (br, bc) = if first.tb.is_trans() { (cols, k) } else { (k, cols) };
        let b_view = MatRef::from_col_major(br, bc, br, &b_cat);
        let t0 = std::time::Instant::now();
        let mut c_view = MatMut::from_col_major(m, cols, m, &mut c_cat);
        let rep = blas.gemm_view_on(
            chip,
            first.ta,
            first.tb,
            first.alpha,
            a_view,
            b_view,
            first.beta,
            &mut c_view,
        )?;
        metrics.record_request(
            super::metrics::RequestKind::Gemm,
            t0.elapsed().as_secs_f64(),
            rep.flops,
        );
        metrics.record_chip_request(chip);
        // Split back per job (owned Vecs handed to the completions; the
        // staging buffers recycle on drop).
        let mut outs = Vec::with_capacity(group.len());
        let mut j0 = 0usize;
        for q in &group {
            let job = &q.job;
            let mut out = vec![0.0f32; m * job.n];
            out.copy_from_slice(&c_cat[j0 * m..(j0 + job.n) * m]);
            outs.push(out);
            j0 += job.n;
        }
        Ok(outs)
    }));
    let result: Result<Vec<Vec<f32>>> = match computed {
        Ok(r) => r,
        Err(p) => {
            Err(anyhow!("chip {chip} service call panicked: {}", panic_message(p.as_ref())))
        }
    };

    match result {
        Ok(outs) => {
            for (q, out) in group.into_iter().zip(outs) {
                q.reply.fire(Ok(out));
            }
            None
        }
        Err(e) => Some((group, e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::pool::{ChipPool, ShardPolicy};
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::{max_scaled_err, Mat};
    use crate::util::proptest::{forall, Config};

    fn batcher() -> (Batcher, Arc<Metrics>) {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        let metrics = Arc::new(Metrics::new());
        let batcher =
            Batcher::spawn(Arc::new(Blas::new(svc)), BatchPolicy::default(), Arc::clone(&metrics));
        (batcher, metrics)
    }

    fn batcher_pool(chips: usize) -> (Batcher, Arc<Metrics>) {
        let pool = ChipPool::spawn(
            chips,
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        let blas = Arc::new(Blas::with_pool(pool, ShardPolicy::ColumnPanels));
        let metrics = Arc::new(Metrics::new());
        let batcher = Batcher::spawn(blas, BatchPolicy::default(), Arc::clone(&metrics));
        (batcher, metrics)
    }

    fn job(m: usize, n: usize, k: usize, seed: u64, a: Option<Vec<f32>>) -> GemmJob {
        GemmJob {
            ta: Trans::N,
            tb: Trans::N,
            m,
            n,
            k,
            alpha: 1.0,
            beta: 0.0,
            a: a.unwrap_or_else(|| Mat::<f32>::randn(m, k, seed).as_slice().to_vec()),
            b: Mat::<f32>::randn(k, n, seed + 1).as_slice().to_vec(),
            c: vec![0.0; m * n],
        }
    }

    fn oracle(j: &GemmJob) -> Mat<f64> {
        let a = Mat::from_col_major(j.m, j.k, &j.a).cast::<f64>();
        let b = Mat::from_col_major(j.k, j.n, &j.b).cast::<f64>();
        let mut c = Mat::<f64>::zeros(j.m, j.n);
        crate::blis::level3::gemm_host(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c);
        c
    }

    #[test]
    fn single_job_round_trip() {
        let (b, _) = batcher();
        let j = job(64, 32, 48, 1, None);
        let want = oracle(&j);
        let rx = b.submit(j);
        let got = rx.recv().unwrap().unwrap();
        let got = Mat::from_col_major(64, 32, &got);
        assert!(max_scaled_err(got.view(), want.view()) < 1e-5);
    }

    #[test]
    fn shared_a_jobs_coalesce() {
        let (b, metrics) = batcher();
        let a: Vec<f32> = Mat::<f32>::randn(64, 48, 9).as_slice().to_vec();
        let jobs: Vec<GemmJob> = (0..4).map(|i| job(64, 16, 48, 20 + i, Some(a.clone()))).collect();
        let wants: Vec<Mat<f64>> = jobs.iter().map(oracle).collect();
        let rxs: Vec<_> = jobs.into_iter().map(|j| b.submit(j)).collect();
        for (rx, want) in rxs.into_iter().zip(wants) {
            let got = rx.recv().unwrap().unwrap();
            let got = Mat::from_col_major(64, 16, &got);
            assert!(max_scaled_err(got.view(), want.view()) < 1e-5);
        }
        // At least one coalesced group should have been recorded (timing-
        // dependent: the first job may run alone before the rest enqueue).
        let report = metrics.report();
        assert!(metrics.requests() >= 1, "{report}");
    }

    #[test]
    fn different_a_jobs_do_not_merge_results() {
        let (b, _) = batcher();
        let j1 = job(64, 16, 48, 30, None);
        let j2 = job(64, 16, 48, 40, None);
        let (w1, w2) = (oracle(&j1), oracle(&j2));
        let rx1 = b.submit(j1);
        let rx2 = b.submit(j2);
        let g1 = Mat::from_col_major(64, 16, &rx1.recv().unwrap().unwrap());
        let g2 = Mat::from_col_major(64, 16, &rx2.recv().unwrap().unwrap());
        assert!(max_scaled_err(g1.view(), w1.view()) < 1e-5);
        assert!(max_scaled_err(g2.view(), w2.view()) < 1e-5);
    }

    #[test]
    fn callback_submission_fires_once_with_result() {
        let (b, _) = batcher();
        let j = job(32, 8, 16, 77, None);
        let want = oracle(&j);
        let (tx, rx) = std::sync::mpsc::channel();
        b.submit_with(
            None,
            j,
            Box::new(move |r| {
                tx.send(r).unwrap();
            }),
        );
        let got = Mat::from_col_major(32, 8, &rx.recv().unwrap().unwrap());
        assert!(max_scaled_err(got.view(), want.view()) < 1e-5);
        // The sender moved into the FnOnce and dropped with it: a second
        // recv observing disconnection proves single invocation.
        assert!(rx.recv().is_err());
    }

    #[test]
    fn fifo_under_load() {
        let (b, _) = batcher();
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..12 {
            let j = job(32, 8, 16, 100 + i, None);
            wants.push(oracle(&j));
            rxs.push(b.submit(j));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            let got = Mat::from_col_major(32, 8, &rx.recv().unwrap().unwrap());
            assert!(max_scaled_err(got.view(), want.view()) < 1e-5);
        }
    }

    #[test]
    fn staging_pool_recycles_across_batches() {
        let (b, _) = batcher();
        for i in 0..3 {
            let j = job(16, 4, 8, 400 + i, None);
            let got = b.submit(j).recv().unwrap().unwrap();
            assert_eq!(got.len(), 16 * 4);
        }
        // Each batch stages B and C once; after the first batch returns
        // its buffers, later same-shape batches re-use them.
        let s = b.staging_stats();
        assert!(s.gets >= 6, "three batches stage twice each: {s:?}");
        assert!(s.recycled >= 2, "staging buffers should recycle: {s:?}");
    }

    #[test]
    fn per_chip_queues_drain_independently() {
        // Pin distinct job streams to each chip of a 2-chip pool: both
        // queues drain, each on its own chip, results all correct.
        let (b, metrics) = batcher_pool(2);
        assert_eq!(b.chips(), 2);
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..6 {
            let j = job(32, 8, 16, 200 + i, None);
            wants.push(oracle(&j));
            rxs.push(b.submit_to(i as usize % 2, j));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            let got = Mat::from_col_major(32, 8, &rx.recv().unwrap().unwrap());
            assert!(max_scaled_err(got.view(), want.view()) < 1e-5);
        }
        assert_eq!(b.depth(), 0);
        let per_chip = metrics.chip_requests();
        assert_eq!(per_chip.len(), 2, "both chips executed work: {per_chip:?}");
        assert!(per_chip.iter().all(|&c| c > 0), "both chips executed work: {per_chip:?}");
    }

    #[test]
    fn shard_hints_reduce_modulo_pool() {
        let (b, _) = batcher_pool(2);
        let j = job(16, 4, 8, 300, None);
        let want = oracle(&j);
        // Hint 7 on a 2-chip pool routes to chip 1, not out of bounds.
        let got = b.submit_to(7, j).recv().unwrap().unwrap();
        let got = Mat::from_col_major(16, 4, &got);
        assert!(max_scaled_err(got.view(), want.view()) < 1e-5);
    }

    #[test]
    fn panicking_job_answers_ticket_and_worker_survives() {
        let (b, _) = batcher();
        b.blas.pool().chip(0).panic_next_calls(1);
        let j = job(32, 8, 16, 500, None);
        let r = b.submit(j).recv().expect("ticket must be answered, not dropped");
        assert!(r.is_err(), "panicked execution answers with an error");
        assert!(!b.blas.pool().is_healthy(0), "the panicking chip is marked unhealthy");
        // The worker thread survived the unwind and the queue mutex is
        // not poisoned — readers and new submissions still work.
        assert_eq!(b.depth(), 0);
        b.blas.pool().chip(0).clear_faults();
        b.blas.pool().probe(0).unwrap();
        let j = job(32, 8, 16, 501, None);
        let want = oracle(&j);
        let got = Mat::from_col_major(32, 8, &b.submit(j).recv().unwrap().unwrap());
        assert!(max_scaled_err(got.view(), want.view()) < 1e-5, "chip recovered after probe");
    }

    #[test]
    fn wounded_chip_requeues_to_healthy_ones() {
        let (b, metrics) = batcher_pool(2);
        b.blas.pool().chip(1).fail_next_calls(usize::MAX);
        let j = job(32, 8, 16, 600, None);
        let want = oracle(&j);
        // Pinned to the chip that is about to fail: the job must still
        // complete — rescued by the healthy chip — with correct results.
        let got = Mat::from_col_major(32, 8, &b.submit_to(1, j).recv().unwrap().unwrap());
        assert!(max_scaled_err(got.view(), want.view()) < 1e-5, "job rescued on a healthy chip");
        assert!(!b.blas.pool().is_healthy(1));
        assert!(metrics.requeued() >= 1, "the rescue is counted");
        // Pinning to an unhealthy chip is a preference: it degrades to a
        // healthy queue without ever touching the dead chip again.
        let j2 = job(32, 8, 16, 601, None);
        let want2 = oracle(&j2);
        let got2 = Mat::from_col_major(32, 8, &b.submit_to(1, j2).recv().unwrap().unwrap());
        assert!(max_scaled_err(got2.view(), want2.view()) < 1e-5);
    }

    #[test]
    fn whole_pool_down_fails_tickets_instead_of_hanging() {
        let (b, metrics) = batcher_pool(2);
        b.blas.pool().chip(0).fail_next_calls(usize::MAX);
        b.blas.pool().chip(1).fail_next_calls(usize::MAX);
        let j = job(32, 8, 16, 650, None);
        let r = b.submit(j).recv().expect("ticket answered even with the whole pool down");
        assert!(r.is_err());
        assert!(metrics.errors() >= 1);
        assert_eq!(b.blas.pool().healthy_chips(), Vec::<usize>::new());
    }

    #[test]
    fn deadline_overrun_marks_chip_unhealthy() {
        let pool = ChipPool::spawn(
            2,
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        let blas = Arc::new(Blas::with_pool(pool, ShardPolicy::ColumnPanels));
        let metrics = Arc::new(Metrics::new());
        let policy = BatchPolicy { health_deadline_ms: 1, ..BatchPolicy::default() };
        let b = Batcher::spawn(blas, policy, metrics);
        // Big enough that real µ-kernel execution exceeds 1ms of wall.
        let j = job(96, 96, 1024, 700, None);
        let got = b.submit_to(0, j).recv().unwrap().unwrap();
        assert_eq!(got.len(), 96 * 96, "the slow job itself still completes");
        assert!(!b.blas.pool().is_healthy(0), "the overrun trips the health deadline");
        assert!(b.blas.pool().is_healthy(1));
    }

    // ---- coalesce_plan property tests (the FIFO/batching invariants) ----

    /// Random `(key, cols)` sequences drawn from a small key alphabet so
    /// adjacent duplicates actually occur.
    fn gen_jobs(rng: &mut crate::linalg::XorShiftRng) -> (Vec<(CoalesceKey, usize)>, usize) {
        let len = rng.next_below(24);
        let jobs: Vec<(CoalesceKey, usize)> = (0..len)
            .map(|_| {
                let key_id = rng.next_below(3) as u64;
                let cols = 1 + rng.next_below(64);
                ((0, 0, 8, 8, 0, 0, key_id), cols)
            })
            .collect();
        let max_cols = 32 + rng.next_below(96);
        (jobs, max_cols)
    }

    #[test]
    fn coalesce_plan_never_reorders_or_drops() {
        forall(Config::default(), gen_jobs, |(jobs, max_cols)| {
            let plan = coalesce_plan(jobs, *max_cols);
            // Ranges must tile 0..len exactly, in order.
            let mut next = 0usize;
            for &(start, end) in &plan {
                if start != next || end <= start {
                    return false;
                }
                next = end;
            }
            next == jobs.len()
        });
    }

    #[test]
    fn coalesce_plan_respects_max_cols_and_keys() {
        forall(Config::default(), gen_jobs, |(jobs, max_cols)| {
            let plan = coalesce_plan(jobs, *max_cols);
            plan.iter().all(|&(start, end)| {
                let group = &jobs[start..end];
                let homogeneous = group.iter().all(|(k, _)| *k == group[0].0);
                let cols: usize = group.iter().map(|(_, n)| n).sum();
                homogeneous && (group.len() == 1 || cols <= *max_cols)
            })
        });
    }

    #[test]
    fn coalesce_plan_merges_adjacent_same_key_runs() {
        // Deterministic spot check: k0 k0 k1 k0 under a generous budget
        // yields [0,2) [2,3) [3,4) — merges the run, never across keys,
        // never across the gap (no reordering).
        let k0: CoalesceKey = (0, 0, 8, 8, 0, 0, 0);
        let k1: CoalesceKey = (0, 0, 8, 8, 0, 0, 1);
        let jobs = vec![(k0, 4), (k0, 4), (k1, 4), (k0, 4)];
        assert_eq!(coalesce_plan(&jobs, 1024), vec![(0, 2), (2, 3), (3, 4)]);
        // A tight budget splits the run.
        assert_eq!(coalesce_plan(&jobs, 4), vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
        // An oversized single job still forms its own group.
        assert_eq!(coalesce_plan(&[(k0, 4096)], 16), vec![(0, 1)]);
    }
}
