//! Dynamic batching in front of the single Epiphany workgroup.
//!
//! There is exactly one chip and one service process (paper §3.2), so all
//! level-3 traffic funnels through one serial resource. The batcher:
//!
//! * queues incoming gemm jobs FIFO (fairness),
//! * **coalesces** consecutive jobs that share the same A operand and
//!   scalars by concatenating their B/C along the n dimension — one
//!   service crossing instead of many (the serving-style case: one weight
//!   matrix, many activations), and
//! * executes batches on a dedicated worker thread that owns the BLAS.
//!
//! Coalescing never reorders: only *adjacent* compatible jobs merge, so
//! FIFO latency bounds hold.

use super::metrics::Metrics;
use crate::blis::{Blas, Trans};
use crate::linalg::{Mat, MatRef};
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// Batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max jobs drained per batch round.
    pub max_batch: usize,
    /// Max columns after coalescing (bounds HH-RAM pressure).
    pub max_cols: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_cols: 4096 }
    }
}

/// One queued sgemm job (stored orientation, like the wire protocol).
pub struct GemmJob {
    pub ta: Trans,
    pub tb: Trans,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub alpha: f32,
    pub beta: f32,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub c: Vec<f32>,
}

impl GemmJob {
    /// Coalescing key: jobs merge when op/shape/scalars/A agree.
    fn key(&self) -> (u8, u8, usize, usize, u32, u32, u64) {
        (
            self.ta.code() as u8,
            self.tb.code() as u8,
            self.m,
            self.k,
            self.alpha.to_bits(),
            self.beta.to_bits(),
            hash_f32(&self.a),
        )
    }
}

fn hash_f32(v: &[f32]) -> u64 {
    // FNV-1a over the bit pattern; cheap and adequate for grouping.
    let mut h = 0xcbf29ce484222325u64;
    for x in v {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

struct Queued {
    job: GemmJob,
    reply: mpsc::Sender<Result<Vec<f32>>>,
}

struct Shared {
    queue: Mutex<VecDeque<Queued>>,
    cv: Condvar,
    stop: AtomicBool,
}

/// The batcher handle; clone-free, share via `Arc`.
pub struct Batcher {
    shared: Arc<Shared>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub policy: BatchPolicy,
}

impl Batcher {
    /// Spawn the worker that owns `blas` and drains the queue.
    pub fn spawn(blas: Arc<Blas>, policy: BatchPolicy, metrics: Arc<Metrics>) -> Batcher {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let shared_w = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("gemm-batcher".into())
            .spawn(move || worker_loop(shared_w, blas, policy, metrics))
            .expect("spawn batcher");
        Batcher { shared, worker: Some(worker), policy }
    }

    /// Submit a job; returns the receiver for its result.
    pub fn submit(&self, job: GemmJob) -> mpsc::Receiver<Result<Vec<f32>>> {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Queued { job, reply: tx });
        }
        self.shared.cv.notify_one();
        rx
    }

    /// Queue depth (for backpressure decisions).
    pub fn depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: Arc<Shared>, blas: Arc<Blas>, policy: BatchPolicy, metrics: Arc<Metrics>) {
    loop {
        // Wait for work.
        let mut drained: Vec<Queued> = Vec::new();
        {
            let mut q = shared.queue.lock().unwrap();
            while q.is_empty() && !shared.stop.load(Ordering::SeqCst) {
                q = shared.cv.wait(q).unwrap();
            }
            if shared.stop.load(Ordering::SeqCst) && q.is_empty() {
                return;
            }
            for _ in 0..policy.max_batch {
                match q.pop_front() {
                    Some(x) => drained.push(x),
                    None => break,
                }
            }
        }
        // Coalesce adjacent same-key jobs.
        let mut i = 0usize;
        while i < drained.len() {
            let key = drained[i].job.key();
            let mut group = vec![i];
            let mut cols = drained[i].job.n;
            let mut j = i + 1;
            while j < drained.len()
                && drained[j].job.key() == key
                && cols + drained[j].job.n <= policy.max_cols
            {
                cols += drained[j].job.n;
                group.push(j);
                j += 1;
            }
            execute_group(&blas, &drained[..], &group, cols, &metrics);
            if group.len() > 1 {
                metrics.record_batched(group.len());
            }
            i = j;
        }
    }
}

/// Run one (possibly coalesced) group and fan the results back out.
fn execute_group(blas: &Blas, all: &[Queued], group: &[usize], cols: usize, metrics: &Metrics) {
    let first = &all[group[0]].job;
    let (m, k) = (first.m, first.k);
    let result: Result<Vec<Vec<f32>>> = (|| {
        // Stack op(B) and C along n by concatenating stored columns.
        // op(B) stored: tb=N ⇒ k×n col-major (concat natural); tb=T ⇒ n×k
        // stored: concatenate along rows — handled by per-job views below.
        let a_stored = &first.a;
        let (ar, ac) = if first.ta.is_trans() { (k, m) } else { (m, k) };
        let a_view = MatRef::from_col_major(ar, ac, ar, a_stored);
        let mut c_cat = Mat::<f32>::zeros(m, cols);
        let mut j0 = 0usize;
        for &gi in group {
            let job = &all[gi].job;
            for j in 0..job.n {
                for i in 0..m {
                    c_cat.set(i, j0 + j, job.c[j * m + i]);
                }
            }
            j0 += job.n;
        }
        // Build the concatenated op(B) as a stored matrix matching tb.
        let b_cat_stored: Mat<f32> = if first.tb.is_trans() {
            // stored n×k each; stack rows.
            let mut mcat = Mat::<f32>::zeros(cols, k);
            let mut r0 = 0usize;
            for &gi in group {
                let job = &all[gi].job;
                for j in 0..k {
                    for i in 0..job.n {
                        mcat.set(r0 + i, j, job.b[j * job.n + i]);
                    }
                }
                r0 += job.n;
            }
            mcat
        } else {
            // stored k×n each; stack columns.
            let mut mcat = Mat::<f32>::zeros(k, cols);
            let mut c0 = 0usize;
            for &gi in group {
                let job = &all[gi].job;
                for j in 0..job.n {
                    for i in 0..k {
                        mcat.set(i, c0 + j, job.b[j * k + i]);
                    }
                }
                c0 += job.n;
            }
            mcat
        };
        let t0 = std::time::Instant::now();
        let rep = blas.sgemm(
            first.ta,
            first.tb,
            first.alpha,
            a_view,
            b_cat_stored.view(),
            first.beta,
            &mut c_cat,
        )?;
        metrics.record_request(
            super::metrics::RequestKind::Gemm,
            t0.elapsed().as_secs_f64(),
            rep.flops,
        );
        // Split back per job.
        let mut outs = Vec::with_capacity(group.len());
        let mut j0 = 0usize;
        for &gi in group {
            let job = &all[gi].job;
            let mut out = vec![0.0f32; m * job.n];
            for j in 0..job.n {
                for i in 0..m {
                    out[j * m + i] = c_cat.get(i, j0 + j);
                }
            }
            outs.push(out);
            j0 += job.n;
        }
        Ok(outs)
    })();

    match result {
        Ok(outs) => {
            for (&gi, out) in group.iter().zip(outs) {
                let _ = all[gi].reply.send(Ok(out));
            }
        }
        Err(e) => {
            metrics.record_error();
            for &gi in group {
                let _ = all[gi].reply.send(Err(anyhow!("{e:#}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::epiphany::kernel::KernelGeometry;
    use crate::epiphany::timing::CalibratedModel;
    use crate::host::service::{ServiceBackend, ServiceHandle};
    use crate::linalg::max_scaled_err;

    fn batcher() -> (Batcher, Arc<Metrics>) {
        let svc = ServiceHandle::spawn(
            ServiceBackend::Simulator,
            CalibratedModel::default(),
            KernelGeometry::paper(),
        )
        .unwrap();
        let metrics = Arc::new(Metrics::new());
        let batcher =
            Batcher::spawn(Arc::new(Blas::new(svc)), BatchPolicy::default(), Arc::clone(&metrics));
        (batcher, metrics)
    }

    fn job(m: usize, n: usize, k: usize, seed: u64, a: Option<Vec<f32>>) -> GemmJob {
        GemmJob {
            ta: Trans::N,
            tb: Trans::N,
            m,
            n,
            k,
            alpha: 1.0,
            beta: 0.0,
            a: a.unwrap_or_else(|| Mat::<f32>::randn(m, k, seed).as_slice().to_vec()),
            b: Mat::<f32>::randn(k, n, seed + 1).as_slice().to_vec(),
            c: vec![0.0; m * n],
        }
    }

    fn oracle(j: &GemmJob) -> Mat<f64> {
        let a = Mat::from_col_major(j.m, j.k, &j.a).cast::<f64>();
        let b = Mat::from_col_major(j.k, j.n, &j.b).cast::<f64>();
        let mut c = Mat::<f64>::zeros(j.m, j.n);
        crate::blis::level3::gemm_host(Trans::N, Trans::N, 1.0, a.view(), b.view(), 0.0, &mut c);
        c
    }

    #[test]
    fn single_job_round_trip() {
        let (b, _) = batcher();
        let j = job(64, 32, 48, 1, None);
        let want = oracle(&j);
        let rx = b.submit(j);
        let got = rx.recv().unwrap().unwrap();
        let got = Mat::from_col_major(64, 32, &got);
        assert!(max_scaled_err(got.view(), want.view()) < 1e-5);
    }

    #[test]
    fn shared_a_jobs_coalesce() {
        let (b, metrics) = batcher();
        let a: Vec<f32> = Mat::<f32>::randn(64, 48, 9).as_slice().to_vec();
        let jobs: Vec<GemmJob> = (0..4).map(|i| job(64, 16, 48, 20 + i, Some(a.clone()))).collect();
        let wants: Vec<Mat<f64>> = jobs.iter().map(oracle).collect();
        let rxs: Vec<_> = jobs.into_iter().map(|j| b.submit(j)).collect();
        for (rx, want) in rxs.into_iter().zip(wants) {
            let got = rx.recv().unwrap().unwrap();
            let got = Mat::from_col_major(64, 16, &got);
            assert!(max_scaled_err(got.view(), want.view()) < 1e-5);
        }
        // At least one coalesced group should have been recorded (timing-
        // dependent: the first job may run alone before the rest enqueue).
        let report = metrics.report();
        assert!(metrics.requests() >= 1, "{report}");
    }

    #[test]
    fn different_a_jobs_do_not_merge_results() {
        let (b, _) = batcher();
        let j1 = job(64, 16, 48, 30, None);
        let j2 = job(64, 16, 48, 40, None);
        let (w1, w2) = (oracle(&j1), oracle(&j2));
        let rx1 = b.submit(j1);
        let rx2 = b.submit(j2);
        let g1 = Mat::from_col_major(64, 16, &rx1.recv().unwrap().unwrap());
        let g2 = Mat::from_col_major(64, 16, &rx2.recv().unwrap().unwrap());
        assert!(max_scaled_err(g1.view(), w1.view()) < 1e-5);
        assert!(max_scaled_err(g2.view(), w2.view()) < 1e-5);
    }

    #[test]
    fn fifo_under_load() {
        let (b, _) = batcher();
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..12 {
            let j = job(32, 8, 16, 100 + i, None);
            wants.push(oracle(&j));
            rxs.push(b.submit(j));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            let got = Mat::from_col_major(32, 8, &rx.recv().unwrap().unwrap());
            assert!(max_scaled_err(got.view(), want.view()) < 1e-5);
        }
    }
}
