//! Stub of the `xla` (xla-rs) API surface that `parallella_blas`'s
//! `pjrt` feature compiles against.
//!
//! Offline CI images carry no XLA/PJRT runtime, but the PJRT executor in
//! `rust/src/runtime/executor.rs` is real integration code that must not
//! rot. This crate keeps it type-checked: every entry point exists with
//! the signature the executor uses and fails at *runtime* with a clear
//! error. Deploying the real path means replacing this path dependency
//! with an actual xla-rs build (same API) — no source changes elsewhere.

use std::fmt;

/// The stub's uniform error: "no PJRT runtime linked".
#[derive(Debug)]
pub struct Error {
    what: &'static str,
}

impl Error {
    fn new(what: &'static str) -> Self {
        Error { what }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: the `xla` stub crate is linked (no PJRT runtime in this build); \
             replace rust/xla-stub with a real xla-rs build to execute AOT artifacts",
            self.what
        )
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// A host-side literal (stub).
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::new("Literal::reshape"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::new("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::new("Literal::to_vec"))
    }
}

impl From<f32> for Literal {
    fn from(_v: f32) -> Literal {
        Literal
    }
}

impl From<f64> for Literal {
    fn from(_v: f64) -> Literal {
        Literal
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::new("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new("PjRtClient::compile"))
    }
}
